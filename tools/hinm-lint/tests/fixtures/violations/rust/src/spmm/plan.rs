//! Fixture for R3: nondeterminism tokens inside the numeric core.

use std::collections::HashMap;
use std::time::Instant;

pub fn r3_tokens() -> usize {
    let t = Instant::now();
    let m: HashMap<u32, u32> = HashMap::new();
    m.len() + t.elapsed().as_nanos() as usize
}
