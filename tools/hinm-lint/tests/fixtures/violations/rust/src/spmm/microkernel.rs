//! Fixture for R1's SAFETY-required mode (the file the test allowlists).

/// # Safety
/// `p` must be valid for reads.
pub unsafe fn with_proof(p: *const u32) -> u32 {
    // SAFETY: fixture — caller upholds the contract above.
    unsafe { *p }
}

pub fn without_proof(p: *const u32) -> u32 {
    unsafe { *p }
}
