//! Binary entry point: R4 does not apply here.

fn main() {
    Some(1).unwrap();
}
