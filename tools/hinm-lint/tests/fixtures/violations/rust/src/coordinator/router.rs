//! R3 fixture: the router policy layer owns the clock, so only the
//! default-hasher containers may fire here.

use std::collections::HashMap;

pub fn f() {
    let _clock_is_fine_here = std::time::Instant::now();
    let m: HashMap<u32, u32> = HashMap::new();
    let _ = m;
}
