//! Fixture crate root (see ARCHITECTURE.md). Cites §1 (resolves) and §9
//! (stale — R5 fires here).

/// Doc comment citing the stale §9 again (second R5 site).
pub fn stale_doc() {}

pub fn r2_token(x: f64, y: f64, z: f64) -> f64 {
    x.mul_add(y, z)
}

pub fn r4_sites(v: Option<u32>) -> u32 {
    let a = v.unwrap();
    let b: Result<u32, ()> = Ok(a);
    b.expect("boom")
}

pub fn not_r4(v: Option<u32>, r: Result<u32, u32>) -> u32 {
    v.unwrap_or_default() + r.expect_err("boundary check must skip this")
}

pub unsafe fn r1_outside_allowlist(p: *const u32) -> u32 {
    *p
}

pub fn masked_text_never_counts() -> &'static str {
    // Comment mentioning unwrap() and mul_add and unsafe: not findings.
    "unwrap() mul_add unsafe Instant::now HashMap"
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt_from_r4() {
        Some(1).unwrap();
        let r: Result<u32, ()> = Ok(2);
        r.expect("fine in tests");
    }
}
