//! R3 fixture: the router wire layer is fully clock-free.

pub fn now_us() -> u128 {
    std::time::Instant::now().elapsed().as_micros()
}
