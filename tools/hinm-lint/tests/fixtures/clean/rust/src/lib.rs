//! Clean fixture crate (see ARCHITECTURE.md), scoped by §1.

/// Returns zero (§4).
pub fn zero() -> u32 {
    0
}

/// Error-propagating library code: no unwrap/expect needed.
pub fn parse(s: &str) -> Result<u32, std::num::ParseIntError> {
    s.trim().parse()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        assert_eq!(super::parse(" 7 ").unwrap(), 7);
    }
}
