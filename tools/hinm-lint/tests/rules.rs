//! Integration tests for hinm-lint: the violations fixture must trip
//! every rule at pinned locations, the clean fixture must produce zero
//! findings, and — the gate that matters — the real repository tree must
//! be clean under the checked-in allowlist.

use hinm_lint::{cited_sections, mask, run, Allowlist, Finding, Rule};
use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn count(findings: &[Finding], rule: Rule, path: &str) -> usize {
    findings.iter().filter(|f| f.rule == rule && f.path == path).count()
}

fn has(findings: &[Finding], rule: Rule, path: &str, line: usize) -> bool {
    findings.iter().any(|f| f.rule == rule && f.path == path && f.line == line)
}

#[test]
fn violations_tree_trips_every_rule() {
    let findings = run(&fixture("violations"), &Allowlist::default()).unwrap();

    // R1: banned mode — every `unsafe` token outside an allowlisted file.
    assert!(has(&findings, Rule::R1, "rust/src/lib.rs", 21), "{findings:#?}");
    assert_eq!(count(&findings, Rule::R1, "rust/src/spmm/microkernel.rs"), 3);

    // R2: mul_add in code, +fma string in build config.
    assert!(has(&findings, Rule::R2, "rust/src/lib.rs", 8));
    assert!(has(&findings, Rule::R2, "rust/Cargo.toml", 2));

    // R3: HashMap on two lines + Instant::now inside rust/src/spmm/ (the
    // two same-line HashMap hits dedup to one finding).
    assert_eq!(count(&findings, Rule::R3, "rust/src/spmm/plan.rs"), 3);

    // R3 split scope (§19): the router wire layer is fully clock-free,
    // while the policy layer trips only on default-hasher containers —
    // its Instant::now must NOT fire.
    assert!(has(&findings, Rule::R3, "rust/src/net/route.rs", 4));
    assert_eq!(count(&findings, Rule::R3, "rust/src/coordinator/router.rs"), 2);
    assert!(findings
        .iter()
        .all(|f| f.path != "rust/src/coordinator/router.rs" || !f.msg.contains("Instant::now")));

    // R4: the two library sites; unwrap_or_default/expect_err and
    // #[cfg(test)] code must not count, and main.rs is exempt.
    assert!(has(&findings, Rule::R4, "rust/src/lib.rs", 12));
    assert!(has(&findings, Rule::R4, "rust/src/lib.rs", 14));
    assert_eq!(count(&findings, Rule::R4, "rust/src/lib.rs"), 2);
    assert!(findings.iter().all(|f| f.path != "rust/src/main.rs"));

    // R5: stale anchors in crate docs, README, ARCHITECTURE.
    assert!(has(&findings, Rule::R5, "rust/src/lib.rs", 1));
    assert!(has(&findings, Rule::R5, "rust/src/lib.rs", 4));
    assert!(has(&findings, Rule::R5, "README.md", 6));
    assert!(has(&findings, Rule::R5, "rust/ARCHITECTURE.md", 4));

    // Strings and comments never produce findings (lib.rs:26-27 mention
    // every banned token).
    assert!(findings
        .iter()
        .all(|f| f.path != "rust/src/lib.rs" || (f.line != 26 && f.line != 27)));
}

#[test]
fn r1_allowlist_switches_to_safety_required_mode() {
    let (allow, errs) = Allowlist::parse(
        "R1 rust/src/spmm/microkernel.rs — fixture: SAFETY-required mode\n",
        "lint-allow.txt",
    );
    assert!(errs.is_empty(), "{errs:#?}");
    let findings = run(&fixture("violations"), &allow).unwrap();
    // Only the SAFETY-less block remains; the `# Safety` doc and the
    // `// SAFETY:` comment cover the other two occurrences.
    assert_eq!(count(&findings, Rule::R1, "rust/src/spmm/microkernel.rs"), 1);
    assert!(has(&findings, Rule::R1, "rust/src/spmm/microkernel.rs", 11));
    // Non-R1 rules are untouched by an R1 entry.
    assert!(has(&findings, Rule::R1, "rust/src/lib.rs", 21));
}

#[test]
fn non_r1_allowlist_entries_waive_the_file() {
    let (allow, errs) = Allowlist::parse(
        "R4 rust/src/lib.rs — fixture: waived\nR3 rust/src/spmm/plan.rs — fixture: waived\n",
        "lint-allow.txt",
    );
    assert!(errs.is_empty());
    let findings = run(&fixture("violations"), &allow).unwrap();
    assert_eq!(count(&findings, Rule::R4, "rust/src/lib.rs"), 0);
    assert_eq!(count(&findings, Rule::R3, "rust/src/spmm/plan.rs"), 0);
    // Other rules in the same files still fire.
    assert!(has(&findings, Rule::R2, "rust/src/lib.rs", 8));
}

#[test]
fn allowlist_reasons_are_mandatory() {
    let (_, errs) = Allowlist::parse("R4 rust/src/lib.rs —\n", "lint-allow.txt");
    assert_eq!(errs.len(), 1, "{errs:#?}");
    assert!(errs[0].msg.contains("missing a reason"));

    let (_, errs) = Allowlist::parse("R9 rust/src/lib.rs — bogus rule\n", "lint-allow.txt");
    assert_eq!(errs.len(), 1);
    assert!(errs[0].msg.contains("malformed"));

    let (allow, errs) =
        Allowlist::parse("# comment\n\nR4 a.rs — ok\n", "lint-allow.txt");
    assert!(errs.is_empty());
    assert!(allow.contains(Rule::R4, "a.rs"));
}

#[test]
fn clean_tree_is_clean() {
    let findings = run(&fixture("clean"), &Allowlist::default()).unwrap();
    assert!(findings.is_empty(), "{findings:#?}");
}

#[test]
fn masking_understands_rust_lexing() {
    let src = r##"
fn f<'a>(x: &'a str) -> char {
    // unwrap in a comment
    let s = "unwrap() \" mul_add";
    let r = r#"unsafe "quoted" HashMap"#;
    let c = '\'';
    let l = 'x';
    /* block /* nested */ mul_add */
    let _ = (s, r, c);
    l
}
"##;
    let m = mask(src);
    assert!(!m.masked.contains("mul_add"), "{}", m.masked);
    assert!(!m.masked.contains("unwrap"));
    assert!(!m.masked.contains("unsafe"));
    assert!(!m.masked.contains("HashMap"));
    // Lifetimes survive masking (they are code, not literals).
    assert!(m.masked.contains("<'a>"));
    // The comment channel captured the comment text.
    assert!(m.comments.contains("unwrap in a comment"));
    assert!(m.comments.contains("nested"));
    // Line structure is preserved in both channels.
    assert_eq!(m.masked.lines().count(), src.lines().count());
    assert_eq!(m.comments.lines().count(), src.lines().count());
}

#[test]
fn section_citations_are_extracted_with_ranges() {
    assert_eq!(cited_sections("see §4 and §12/13"), vec![4, 12, 13]);
    assert_eq!(cited_sections("§§14, then §15–16."), vec![14, 15, 16]);
    assert_eq!(cited_sections("no anchors here, §Perf is not one"), Vec::<u32>::new());
    assert_eq!(cited_sections("edge §7"), vec![7]);
}

/// The acceptance gate: the real repository, under the checked-in
/// allowlist, has zero findings. Any new violation fails `cargo test`
/// in addition to the dedicated CI lint job.
#[test]
fn repo_tree_is_clean_under_checked_in_allowlist() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let allow_text = std::fs::read_to_string(root.join("tools/hinm-lint/lint-allow.txt"))
        .expect("checked-in allowlist");
    let (allow, errs) = Allowlist::parse(&allow_text, "tools/hinm-lint/lint-allow.txt");
    assert!(errs.is_empty(), "allowlist entries must carry reasons: {errs:#?}");
    let findings = run(&root, &allow).expect("repo scan");
    assert!(
        findings.is_empty(),
        "repo tree has lint findings:\n{}",
        findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}
