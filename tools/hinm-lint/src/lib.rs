//! Token-level source linter for the hinm repository.
//!
//! Enforces the written contracts of DESIGN.md §17 ("Enforced invariants")
//! as CI-gating diagnostics. The scan is deliberately *not* a Rust parser:
//! it masks comments and string/char literals with a small state machine
//! and then looks for boundary-checked tokens in what remains. That keeps
//! the tool std-only (no syn, no proc-macro, no regex crate), fast enough
//! to run on every push, and simple enough that its semantics are
//! reviewable in one sitting. The cost is that the rules are lexical:
//! they gate on *tokens*, not on resolved paths — good enough for every
//! contract below, all of which were written as textual conventions in the
//! first place.
//!
//! The five rules (numbering shared with DESIGN.md §17):
//!
//! - **R1** — `unsafe` only inside allowlisted modules, and every
//!   occurrence immediately preceded by a `// SAFETY:` comment.
//! - **R2** — no FMA anywhere: `mul_add`, `_mm256_fmadd_*`, `_mm_fmadd_*`,
//!   and the `-C target-feature=+fma` flag string are banned crate-wide
//!   (the bitwise ISA-equivalence contract of §16 dies the moment any tier
//!   contracts a multiply-add).
//! - **R3** — no wall-clock or hash-order nondeterminism (`Instant::now`,
//!   `SystemTime`, default-hasher `HashMap`/`HashSet`) in the numeric core
//!   (`permute/`, `spmm/`, `sparsity/`, `tensor/`) or the wire layers
//!   (`net/route.rs` per §19, `net/stage_wire.rs` per §20 — both must
//!   stay clock-free); the router's policy layer
//!   (`coordinator/router.rs`) owns the clock but still bans the
//!   default-hasher containers.
//! - **R4** — no `unwrap()`/`expect(` in library code outside `#[cfg(test)]`
//!   and `main.rs`.
//! - **R5** — every `§N` anchor cited from doc comments, README.md, or
//!   ARCHITECTURE.md must resolve to a `## §N` heading in DESIGN.md, plus
//!   the fixed cross-document links the retired CI grep step used to check.
//!
//! Waivers are file-level only, via the checked-in allowlist
//! (`tools/hinm-lint/lint-allow.txt`); every entry must carry a reason.
//! There are deliberately no inline `#[allow]`-style escape hatches: a
//! waiver is a reviewed, documented decision about a *file*, not something
//! a patch can sprinkle next to the code it excuses.

#![forbid(unsafe_code)]
#![warn(missing_docs)]

use std::collections::BTreeSet;
use std::fmt;
use std::path::{Path, PathBuf};

/// The five enforced contracts of DESIGN.md §17.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// `unsafe` confinement + `// SAFETY:` comments.
    R1,
    /// FMA ban (bitwise ISA equivalence, §16).
    R2,
    /// Nondeterminism ban in the numeric core.
    R3,
    /// `unwrap()`/`expect(` ban in library code.
    R4,
    /// `§N` anchors must resolve in DESIGN.md.
    R5,
}

impl Rule {
    fn parse(s: &str) -> Option<Rule> {
        match s {
            "R1" => Some(Rule::R1),
            "R2" => Some(Rule::R2),
            "R3" => Some(Rule::R3),
            "R4" => Some(Rule::R4),
            "R5" => Some(Rule::R5),
            _ => None,
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
        })
    }
}

/// One diagnostic: rule, repo-relative path, 1-based line, message.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    /// Which contract was violated.
    pub rule: Rule,
    /// Repo-relative path with forward slashes.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}:{}  {}", self.rule, self.path, self.line, self.msg)
    }
}

/// Parsed allowlist. Semantics per rule:
///
/// - An **R1** entry does not waive the rule; it switches the file from
///   "`unsafe` banned" to "`unsafe` permitted but every occurrence needs a
///   `// SAFETY:` comment".
/// - An entry for any other rule waives that rule for that file entirely.
#[derive(Default)]
pub struct Allowlist {
    entries: BTreeSet<(Rule, String)>,
}

impl Allowlist {
    /// Parse the `RULE path — reason` line format. Malformed or
    /// reason-less entries are returned as findings against the allowlist
    /// file itself: a waiver without a recorded justification is a
    /// violation, not a waiver.
    pub fn parse(text: &str, self_path: &str) -> (Allowlist, Vec<Finding>) {
        let mut list = Allowlist::default();
        let mut findings = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut bad = |msg: &str| {
                findings.push(Finding {
                    rule: Rule::R5,
                    path: self_path.to_string(),
                    line: i + 1,
                    msg: format!("{msg}: `{line}`"),
                });
            };
            let mut parts = line.splitn(3, char::is_whitespace);
            let rule = parts.next().and_then(Rule::parse);
            let path = parts.next().map(str::to_string);
            let rest = parts.next().unwrap_or("").trim();
            let reason = rest.trim_start_matches(['—', '-']).trim();
            match (rule, path) {
                (Some(r), Some(p)) if !reason.is_empty() => {
                    list.entries.insert((r, p));
                }
                (Some(_), Some(_)) => bad("allowlist entry missing a reason"),
                _ => bad("malformed allowlist entry (want `RULE path — reason`)"),
            }
        }
        (list, findings)
    }

    /// Is `(rule, path)` present? (For R1 this means "SAFETY-required
    /// mode", not "waived" — see the type docs.)
    pub fn contains(&self, rule: Rule, path: &str) -> bool {
        self.entries.contains(&(rule, path.to_string()))
    }
}

/// A source file with comments and literals masked out.
///
/// `masked` blanks every comment and string/char-literal character to a
/// space (newlines kept), so token searches can never fire inside prose or
/// data. `comments` is the complement: original characters where comments
/// were, spaces elsewhere — the `// SAFETY:` scan reads it. The two align
/// line-by-line with the original (every `\n` is preserved in both).
pub struct MaskedFile {
    /// Source with comments and literals blanked.
    pub masked: String,
    /// Comment text only, spaces elsewhere.
    pub comments: String,
}

/// Mask comments and string/char literals. The state machine understands
/// line comments, nested block comments, plain strings with escapes, raw
/// strings (`r"…"`, `r#"…"#`, …), and the char-literal-vs-lifetime
/// ambiguity (`'a'` vs `'a`): a quote introduces a char literal iff it is
/// followed by a backslash escape or a single character and a closing
/// quote; anything else is a lifetime and is left alone.
pub fn mask(src: &str) -> MaskedFile {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut masked = chars.clone();
    let mut comment: Vec<char> =
        chars.iter().map(|&c| if c == '\n' { '\n' } else { ' ' }).collect();

    fn blank(masked: &mut [char], from: usize, to: usize) {
        let to = to.min(masked.len());
        if from >= to {
            return;
        }
        for ch in &mut masked[from..to] {
            if *ch != '\n' {
                *ch = ' ';
            }
        }
    }

    let mut i = 0;
    while i < n {
        let c = chars[i];
        let nxt = if i + 1 < n { chars[i + 1] } else { '\0' };
        if c == '/' && nxt == '/' {
            let j = chars[i..].iter().position(|&c| c == '\n').map_or(n, |p| i + p);
            for k in i..j {
                comment[k] = chars[k];
            }
            blank(&mut masked, i, j);
            i = j;
        } else if c == '/' && nxt == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            for k in i..j.min(n) {
                comment[k] = chars[k];
            }
            blank(&mut masked, i, j);
            i = j;
        } else if c == '"' {
            let mut j = i + 1;
            while j < n {
                if chars[j] == '\\' {
                    j += 2;
                } else if chars[j] == '"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            let j = j.min(n);
            blank(&mut masked, i + 1, j.saturating_sub(1));
            i = j;
        } else if c == 'r' && raw_string_hashes(&chars, i).is_some() {
            let hashes = raw_string_hashes(&chars, i).unwrap_or(0);
            let open_len = 2 + hashes; // r + hashes + "
            let mut j = i + open_len;
            // Find `"` followed by the same number of `#`.
            let close = loop {
                if j >= n {
                    break n;
                }
                if chars[j] == '"'
                    && chars[j + 1..].iter().take_while(|&&c| c == '#').count() >= hashes
                {
                    break j + 1 + hashes;
                }
                j += 1;
            };
            blank(&mut masked, i + open_len, close.saturating_sub(1 + hashes));
            i = close;
        } else if c == '\'' {
            if nxt == '\\' {
                // Escaped char literal (`'\n'`, `'\\'`, `'\''`): the
                // closing quote is the first one whose preceding character
                // is not itself an escaping backslash.
                let mut j = i + 2;
                let end = loop {
                    match chars[j..].iter().position(|&c| c == '\'') {
                        None => break n,
                        Some(p) => {
                            let q = j + p;
                            // A quote right after a lone backslash is `\'`
                            // (escaped) — unless that backslash is the
                            // second half of `\\`.
                            if chars[q - 1] == '\\' && (q < 2 || chars[q - 2] != '\\') {
                                j = q + 1;
                            } else {
                                break q + 1;
                            }
                        }
                    }
                };
                blank(&mut masked, i + 1, end.saturating_sub(1));
                i = end;
            } else if i + 2 < n && chars[i + 2] == '\'' {
                blank(&mut masked, i + 1, i + 2);
                i += 3;
            } else {
                // Lifetime — leave it.
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    MaskedFile { masked: masked.into_iter().collect(), comments: comment.into_iter().collect() }
}

/// If `chars[i..]` starts a raw string literal `r#*"`, return the hash
/// count, else `None`.
fn raw_string_hashes(chars: &[char], i: usize) -> Option<usize> {
    debug_assert_eq!(chars.get(i), Some(&'r'));
    let mut j = i + 1;
    let mut hashes = 0usize;
    while j < chars.len() && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some(hashes)
    } else {
        None
    }
}

/// Byte spans of `#[cfg(test)]` items in the masked text: from the
/// attribute to the end of the brace-matched block that follows it (or a
/// terminating `;` at depth 0 for non-block items).
pub fn test_spans(masked: &str) -> Vec<(usize, usize)> {
    let bytes = masked.as_bytes();
    let mut spans = Vec::new();
    for (start, _) in masked.match_indices("#[cfg(test)]") {
        let mut j = start + "#[cfg(test)]".len();
        let mut depth = 0i64;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                b';' if depth == 0 => {
                    j += 1;
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        spans.push((start, j));
    }
    spans
}

fn in_spans(pos: usize, spans: &[(usize, usize)]) -> bool {
    spans.iter().any(|&(a, b)| a <= pos && pos < b)
}

fn line_of(text: &str, pos: usize) -> usize {
    text.as_bytes()[..pos].iter().filter(|&&b| b == b'\n').count() + 1
}

fn is_word_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Byte positions of `needle` in `hay` with the requested word-boundary
/// checks on each side.
fn find_token(hay: &str, needle: &str, bound_start: bool, bound_end: bool) -> Vec<usize> {
    let bytes = hay.as_bytes();
    hay.match_indices(needle)
        .filter(|&(pos, _)| {
            let pre_ok = !bound_start
                || pos == 0
                || !is_word_byte(bytes[pos - 1]);
            let end = pos + needle.len();
            let post_ok = !bound_end || end >= bytes.len() || !is_word_byte(bytes[end]);
            pre_ok && post_ok
        })
        .map(|(pos, _)| pos)
        .collect()
}

/// Positions of `.name` followed (across whitespace) by the given suffix
/// characters — matches `\.name\s*\(` (and `\s*\)` when `closed`), which
/// is how `.unwrap()` / `.expect(` are detected without also matching
/// `unwrap_or*` / `expect_err`.
fn find_method_call(hay: &str, name: &str, closed: bool) -> Vec<usize> {
    let bytes = hay.as_bytes();
    let pat = format!(".{name}");
    let mut out = Vec::new();
    for (pos, _) in hay.match_indices(&pat) {
        let mut j = pos + pat.len();
        while j < bytes.len() && bytes[j].is_ascii_whitespace() {
            j += 1;
        }
        if j >= bytes.len() || bytes[j] != b'(' {
            continue;
        }
        if closed {
            j += 1;
            while j < bytes.len() && bytes[j].is_ascii_whitespace() {
                j += 1;
            }
            if j >= bytes.len() || bytes[j] != b')' {
                continue;
            }
        }
        out.push(pos);
    }
    out
}

/// Section numbers cited on one line: every `§N`, `§§N`, or run like
/// `§12/13` / `§4–6` contributes each embedded number.
pub fn cited_sections(line: &str) -> Vec<u32> {
    let chars: Vec<char> = line.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        if chars[i] != '§' {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if chars.get(j) == Some(&'§') {
            j += 1;
        }
        if !chars.get(j).is_some_and(|c| c.is_ascii_digit()) {
            i = j;
            continue;
        }
        // Consume the token: digits plus list separators.
        let mut num = 0u32;
        let mut have = false;
        while j < chars.len() {
            let c = chars[j];
            if c.is_ascii_digit() {
                num = num.saturating_mul(10) + (c as u32 - '0' as u32);
                have = true;
            } else if matches!(c, '/' | '–' | '—' | '-') {
                if have {
                    out.push(num);
                }
                num = 0;
                have = false;
            } else {
                break;
            }
            j += 1;
        }
        if have {
            out.push(num);
        }
        i = j;
    }
    out
}

/// `## §N ` headings of DESIGN.md.
pub fn design_headings(design: &str) -> BTreeSet<u32> {
    let mut heads = BTreeSet::new();
    for line in design.lines() {
        if let Some(rest) = line.strip_prefix("## §") {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if let Ok(n) = digits.parse() {
                heads.insert(n);
            }
        }
    }
    heads
}

/// Paths (directories or single files) where the full R3 nondeterminism
/// ban applies: the numeric core plus the wire layers — the router's
/// (§19) and the stage-activation codec's (§20) — which stay clock-free
/// so every timing decision lives in the coordinator/runtime tiers.
const R3_DIRS: [&str; 6] = [
    "rust/src/permute/",
    "rust/src/spmm/",
    "rust/src/sparsity/",
    "rust/src/tensor/",
    "rust/src/net/route.rs",
    "rust/src/net/stage_wire.rs",
];

/// Files under the hash-order half of R3 only: the router's policy layer
/// legitimately reads the clock (probe timers, hedging deadlines) but its
/// dispatch order must not depend on default-hasher iteration.
const R3_HASH_FILES: [&str; 1] = ["rust/src/coordinator/router.rs"];

/// Sections ARCHITECTURE.md must anchor into DESIGN.md (carried over from
/// the retired CI grep step — presence, not just resolution).
const ARCH_REQUIRED_SECTIONS: [u32; 8] = [4, 12, 13, 14, 15, 16, 19, 20];

/// Files scanned for the raw `+fma` flag string in addition to `rust/src`.
const R2_RAW_FILES: [&str; 3] = ["Cargo.toml", "rust/Cargo.toml", ".github/workflows/ci.yml"];

struct Ctx<'a> {
    allow: &'a Allowlist,
    findings: Vec<Finding>,
}

impl Ctx<'_> {
    fn report(&mut self, rule: Rule, path: &str, line: usize, msg: String) {
        // R1 allowlist entries change the rule's mode instead of waiving
        // it, so they are consulted at the check site, not here.
        if rule != Rule::R1 && self.allow.contains(rule, path) {
            return;
        }
        self.findings.push(Finding { rule, path: path.to_string(), line, msg });
    }
}

fn scan_rs_file(ctx: &mut Ctx<'_>, rel: &str, src: &str, heads: &BTreeSet<u32>) {
    let MaskedFile { masked, comments } = mask(src);
    let spans = test_spans(&masked);
    let masked_lines: Vec<&str> = masked.split('\n').collect();
    let comment_lines: Vec<&str> = comments.split('\n').collect();

    // R1: `unsafe` confinement + SAFETY comments.
    let r1_allowed = ctx.allow.contains(Rule::R1, rel);
    for pos in find_token(&masked, "unsafe", true, true) {
        if in_spans(pos, &spans) {
            continue;
        }
        let ln = line_of(&masked, pos);
        if !r1_allowed {
            ctx.report(
                Rule::R1,
                rel,
                ln,
                "`unsafe` outside the allowlisted modules (§17 R1)".to_string(),
            );
            continue;
        }
        if !has_safety_comment(&masked_lines, &comment_lines, ln) {
            ctx.report(
                Rule::R1,
                rel,
                ln,
                "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
            );
        }
    }

    // R2: FMA tokens in code, flag string anywhere in the file.
    for pos in find_token(&masked, "mul_add", true, true) {
        let ln = line_of(&masked, pos);
        ctx.report(Rule::R2, rel, ln, "FMA token `mul_add` (§17 R2)".to_string());
    }
    for prefix in ["_mm256_fmadd", "_mm_fmadd"] {
        for pos in find_token(&masked, prefix, true, false) {
            let ln = line_of(&masked, pos);
            ctx.report(Rule::R2, rel, ln, format!("FMA intrinsic `{prefix}*` (§17 R2)"));
        }
    }
    for (pos, _) in src.match_indices("target-feature=+fma") {
        let ln = line_of(src, pos);
        ctx.report(Rule::R2, rel, ln, "`+fma` target-feature string (§17 R2)".to_string());
    }

    // R3: nondeterminism tokens. Full ban in the clock-free tiers;
    // hash-order-only ban in the router's policy layer.
    let r3_full = R3_DIRS.iter().any(|d| rel.starts_with(d));
    let r3_hash = R3_HASH_FILES.contains(&rel);
    if r3_full || r3_hash {
        let toks: [(&str, bool); 4] = [
            ("Instant::now", false),
            ("SystemTime", true),
            ("HashMap", true),
            ("HashSet", true),
        ];
        for (needle, bounded) in toks {
            if !r3_full && !matches!(needle, "HashMap" | "HashSet") {
                continue;
            }
            for pos in find_token(&masked, needle, bounded, bounded) {
                if in_spans(pos, &spans) {
                    continue;
                }
                let ln = line_of(&masked, pos);
                ctx.report(
                    Rule::R3,
                    rel,
                    ln,
                    format!("nondeterminism token `{needle}` in an R3-scoped file (§17 R3)"),
                );
            }
        }
    }

    // R4: unwrap/expect in library code.
    if rel != "rust/src/main.rs" {
        for (name, closed) in [("unwrap", true), ("expect", false)] {
            for pos in find_method_call(&masked, name, closed) {
                if in_spans(pos, &spans) {
                    continue;
                }
                let ln = line_of(&masked, pos);
                ctx.report(Rule::R4, rel, ln, format!("`.{name}(` in library code (§17 R4)"));
            }
        }
    }

    // R5: §N anchors in doc comments.
    for (i, line) in src.lines().enumerate() {
        let stripped = line.trim_start();
        if stripped.starts_with("///") || stripped.starts_with("//!") {
            for sec in cited_sections(stripped) {
                if !heads.contains(&sec) {
                    ctx.report(
                        Rule::R5,
                        rel,
                        i + 1,
                        format!("doc comment cites §{sec} but DESIGN.md has no `## §{sec}` heading"),
                    );
                }
            }
        }
    }
}

/// Upward scan for a SAFETY comment: accept a comment containing `SAFETY`
/// or `# Safety` on the same line, or on any line strictly above that is
/// blank, an attribute (`#[…]`), or a pure comment line. The first
/// non-blank, non-attribute *code* line without one stops the scan.
fn has_safety_comment(masked_lines: &[&str], comment_lines: &[&str], ln: usize) -> bool {
    fn is_safety(s: &str) -> bool {
        s.contains("SAFETY") || s.contains("# Safety")
    }
    if comment_lines.get(ln - 1).copied().is_some_and(is_safety) {
        return true;
    }
    let mut k = ln - 1;
    while k >= 1 {
        let code = masked_lines.get(k - 1).map_or("", |s| s.trim());
        let com = comment_lines.get(k - 1).copied().unwrap_or("");
        if is_safety(com) {
            return true;
        }
        if code.is_empty() || code.starts_with("#[") || !com.trim().is_empty() {
            k -= 1;
            continue;
        }
        break;
    }
    false
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("reading {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

fn read(root: &Path, rel: &str) -> Result<String, String> {
    std::fs::read_to_string(root.join(rel)).map_err(|e| format!("reading {rel}: {e}"))
}

/// Run the full R1–R5 scan over the repository at `root`. Returns the
/// sorted findings (empty = clean tree). `Err` means the tree is not a
/// hinm repo at all (missing `rust/src`), not that a rule fired.
pub fn run(root: &Path, allow: &Allowlist) -> Result<Vec<Finding>, String> {
    let mut ctx = Ctx { allow, findings: Vec::new() };

    // DESIGN.md headings anchor every R5 check; a missing/unreadable
    // DESIGN.md is itself a finding (every citation would dangle).
    let design = read(root, "rust/DESIGN.md");
    let heads = match &design {
        Ok(text) => design_headings(text),
        Err(e) => {
            ctx.report(Rule::R5, "rust/DESIGN.md", 1, format!("unreadable: {e}"));
            BTreeSet::new()
        }
    };

    let src_root = root.join("rust/src");
    let mut files = Vec::new();
    walk_rs(&src_root, &mut files)?;
    for path in &files {
        let rel = rel_path(root, path);
        let src =
            std::fs::read_to_string(path).map_err(|e| format!("reading {rel}: {e}"))?;
        scan_rs_file(&mut ctx, &rel, &src, &heads);
    }

    // R2 raw-flag scan over build configuration.
    for rel in R2_RAW_FILES {
        if let Ok(text) = read(root, rel) {
            for (pos, _) in text.match_indices("target-feature=+fma") {
                let ln = line_of(&text, pos);
                ctx.report(Rule::R2, rel, ln, "`+fma` target-feature string (§17 R2)".to_string());
            }
        }
    }

    // R5 over the cross-document anchors.
    if design.is_ok() {
        for rel in ["README.md", "rust/ARCHITECTURE.md", "rust/DESIGN.md"] {
            match read(root, rel) {
                Err(e) => ctx.report(Rule::R5, rel, 1, format!("unreadable: {e}")),
                Ok(text) => {
                    for (i, line) in text.lines().enumerate() {
                        for sec in cited_sections(line) {
                            if !heads.contains(&sec) {
                                ctx.report(
                                    Rule::R5,
                                    rel,
                                    i + 1,
                                    format!("cites §{sec} but DESIGN.md has no `## §{sec}` heading"),
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    // Fixed cross-document links carried over from the retired CI grep
    // step: the architecture narrative must stay reachable from the README
    // and the crate docs, and must keep anchoring into the load-bearing
    // DESIGN.md sections.
    if let Ok(readme) = read(root, "README.md") {
        if !readme.contains("ARCHITECTURE.md") {
            ctx.report(Rule::R5, "README.md", 1, "must link rust/ARCHITECTURE.md".to_string());
        }
    }
    if let Ok(lib) = read(root, "rust/src/lib.rs") {
        if !lib.contains("ARCHITECTURE.md") {
            ctx.report(
                Rule::R5,
                "rust/src/lib.rs",
                1,
                "crate docs must link ARCHITECTURE.md".to_string(),
            );
        }
    }
    if let Ok(arch) = read(root, "rust/ARCHITECTURE.md") {
        for sec in ARCH_REQUIRED_SECTIONS {
            if !arch.contains(&format!("§{sec}")) {
                ctx.report(
                    Rule::R5,
                    "rust/ARCHITECTURE.md",
                    1,
                    format!("must anchor into DESIGN.md §{sec}"),
                );
            }
        }
    }

    ctx.findings.sort();
    ctx.findings.dedup();
    Ok(ctx.findings)
}
