//! CLI for the hinm repository linter (DESIGN.md §17).
//!
//! Usage: `cargo run -p hinm-lint [-- --root PATH --allowlist PATH]`
//!
//! Prints one `RULE path:line  message` diagnostic per finding and exits
//! nonzero if any survive the allowlist — CI runs this as a required gate.
//! Exit codes: 0 clean, 1 findings, 2 usage or I/O failure.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut allowlist: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => return usage("--root needs a value"),
            },
            "--allowlist" => match args.next() {
                Some(v) => allowlist = Some(PathBuf::from(v)),
                None => return usage("--allowlist needs a value"),
            },
            "--help" | "-h" => {
                println!("hinm-lint [--root PATH] [--allowlist PATH]");
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }
    let allow_path = allowlist.unwrap_or_else(|| root.join("tools/hinm-lint/lint-allow.txt"));

    let allow_text = match std::fs::read_to_string(&allow_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("hinm-lint: reading allowlist {}: {e}", allow_path.display());
            return ExitCode::from(2);
        }
    };
    let (allow, mut findings) =
        hinm_lint::Allowlist::parse(&allow_text, "tools/hinm-lint/lint-allow.txt");

    match hinm_lint::run(&root, &allow) {
        Ok(more) => findings.extend(more),
        Err(e) => {
            eprintln!("hinm-lint: {e}");
            return ExitCode::from(2);
        }
    }
    findings.sort();

    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        eprintln!("hinm-lint: clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("hinm-lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("hinm-lint: {msg}\nusage: hinm-lint [--root PATH] [--allowlist PATH]");
    ExitCode::from(2)
}
