//! Hot-swap-under-traffic suite (DESIGN.md §18): concurrent HTTP clients
//! hammer `/v1/infer` while a new artifact version swaps in through
//! `POST /v1/admin/reload`. Every response must be bit-identical to
//! exactly the old model or exactly the new one — never a torn mix —
//! with zero failed requests across the swap window; post-ack requests
//! must all see the new version; swapped replicas must not serve stale
//! batch-cache entries; and a corrupt drop-in must keep the old version
//! serving.

use hinm::coordinator::{BatchServer, ModelCounters, ServeConfig};
use hinm::models::{Activation, HinmModel};
use hinm::net::{protocol, HttpClient, HttpFront, ModelService, MultiRouter, ReloadFn};
use hinm::runtime::{save_artifact, CacheStats, ModelRegistry, Provenance};
use hinm::sparsity::HinmConfig;
use hinm::tensor::Matrix;
use hinm::util::json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Barrier};
use std::time::Duration;

const D: usize = 32;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hinm-hotswap-{tag}-{}", std::process::id()))
}

fn model(seed: u64) -> HinmModel {
    HinmModel::synthetic_ffn(D, 64, &HinmConfig::with_24(8, 0.5), Activation::Relu, seed)
        .expect("synthetic model")
}

fn probe(i: usize) -> Vec<f32> {
    (0..D).map(|j| ((i * 31 + j * 7) % 17) as f32 * 0.1 - 0.8).collect()
}

/// In-process forward of a single activation column, as bit patterns.
fn expected_bits(m: &HinmModel, x: &[f32]) -> Vec<u32> {
    let y = m.forward(&Matrix::from_vec(D, 1, x.to_vec()));
    y.data.iter().map(|v| v.to_bits()).collect()
}

struct Setup {
    front: HttpFront,
    server: BatchServer,
    registry: Arc<ModelRegistry>,
}

/// One registry model behind a multi-model front on an ephemeral port,
/// with a live admin-reload hook and a per-replica batch cache.
fn start(dir: &Path, name: &str) -> Setup {
    let registry = Arc::new(ModelRegistry::open(dir).expect("registry open"));
    let slot = registry.slot(name).expect("slot");
    let stats = CacheStats::new_shared();
    let server = BatchServer::start_slot(
        slot,
        ServeConfig::new(4, Duration::from_millis(1)).with_replicas(2),
        1,
        8,
        Some(Arc::clone(&stats)),
    )
    .expect("engine start");
    let mut services = BTreeMap::new();
    services.insert(
        name.to_string(),
        ModelService { handle: server.handle.clone(), cache: Some(Arc::clone(&stats)) },
    );
    let reload: ReloadFn = {
        let reg = Arc::clone(&registry);
        Arc::new(move || Ok(reg.reload().to_json()))
    };
    let router = MultiRouter {
        services,
        default_model: name.to_string(),
        counters: ModelCounters::new_shared(),
        kernel: None,
        reload,
    };
    let front = HttpFront::start_multi("127.0.0.1:0", router, 8).expect("front start");
    Setup { front, server, registry }
}

/// POST one inference, assert 200, return the answer's bit patterns.
fn infer(c: &mut HttpClient, x: &[f32], model_field: Option<&str>) -> Vec<u32> {
    let mut req = protocol::InferRequest::new(x.to_vec());
    if let Some(m) = model_field {
        req = req.with_model(m);
    }
    let (status, body) = c.post_json("/v1/infer", &req.to_json().pretty()).expect("post");
    assert_eq!(status, 200, "body: {body}");
    protocol::parse_infer_response(&json::parse(&body).expect("json"))
        .expect("infer response")
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

/// The headline acceptance test: 8 clients × 16 requests = 128 responses
/// spanning a live swap — zero failures, zero torn reads, and every
/// request issued after the reload ack sees the new version.
#[test]
fn hot_swap_under_concurrent_traffic_has_no_torn_reads() {
    let dir = tmp("swap");
    let _ = std::fs::remove_dir_all(&dir);
    let (m_old, m_new) = (model(11), model(22));
    save_artifact(&dir, "swap", 1, &m_old, &Provenance::default()).expect("save v1");
    let f = start(&dir, "swap");
    let addr = f.front.local_addr();

    const CLIENTS: usize = 8;
    const PRE: usize = 4;
    const RACE: usize = 8;
    const POST: usize = 4;
    let traffic_up = Barrier::new(CLIENTS + 1);
    let swap_acked = Barrier::new(CLIENTS + 1);

    std::thread::scope(|s| {
        for t in 0..CLIENTS {
            let (traffic_up, swap_acked) = (&traffic_up, &swap_acked);
            let (m_old, m_new) = (&m_old, &m_new);
            s.spawn(move || {
                let mut c = HttpClient::connect(addr).expect("connect");
                // Before the swap every answer is the old model's, whether
                // the body names the model or relies on the default.
                for i in 0..PRE {
                    let x = probe(t * 1000 + i);
                    let field = if i % 2 == 0 { Some("swap") } else { None };
                    assert_eq!(
                        infer(&mut c, &x, field),
                        expected_bits(m_old, &x),
                        "pre-swap: client {t} request {i}"
                    );
                }
                traffic_up.wait();
                // Racing the reload: each answer must be *exactly* old or
                // *exactly* new — a batch runs wholly on one model.
                for i in 0..RACE {
                    let x = probe(t * 1000 + 100 + i);
                    let y = infer(&mut c, &x, None);
                    let old = expected_bits(m_old, &x);
                    let new = expected_bits(m_new, &x);
                    assert!(
                        y == old || y == new,
                        "torn response: client {t} request {i} matches neither version"
                    );
                }
                swap_acked.wait();
                // The reload response happened-before this point, so every
                // batch from here on resolves the new generation.
                for i in 0..POST {
                    let x = probe(t * 1000 + 200 + i);
                    assert_eq!(
                        infer(&mut c, &x, None),
                        expected_bits(m_new, &x),
                        "post-swap: client {t} request {i}"
                    );
                }
            });
        }

        // Main thread: wait until traffic is flowing, then drop in v2 and
        // reload under it.
        traffic_up.wait();
        save_artifact(&dir, "swap", 2, &m_new, &Provenance::default()).expect("save v2");
        let mut admin = HttpClient::connect(addr).expect("admin connect");
        let (status, body) = admin.post_json("/v1/admin/reload", "{}").expect("reload");
        assert_eq!(status, 200, "body: {body}");
        let doc = json::parse(&body).expect("reload json");
        assert_eq!(doc.get("status").as_str(), Some("ok"));
        let swapped = doc.get("report").get("swapped").as_arr().expect("swapped");
        assert_eq!(swapped.len(), 1, "body: {body}");
        assert_eq!(swapped[0].get("name").as_str(), Some("swap"));
        assert_eq!(swapped[0].get("version").as_usize(), Some(2));
        swap_acked.wait();
    });

    // Every routed request was counted, and the slot reports v2.
    let mut c = HttpClient::connect(addr).expect("connect");
    let (status, body) = c.get("/v1/models").expect("models");
    assert_eq!(status, 200);
    let doc = json::parse(&body).expect("models json");
    assert_eq!(doc.get("default").as_str(), Some("swap"));
    let models = doc.get("models").as_arr().expect("models arr");
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].get("requests").as_usize(), Some(CLIENTS * (PRE + RACE + POST)));
    assert_eq!(f.registry.slot("swap").expect("slot").version(), 2);
    drop(c);
    f.front.stop();
    f.server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A repeated batch is a cache hit before the swap and must be recomputed
/// on the new model after it — never replayed from the old cache.
#[test]
fn swap_invalidates_the_batch_cache() {
    let dir = tmp("cache");
    let _ = std::fs::remove_dir_all(&dir);
    let (m1, m2) = (model(5), model(6));
    save_artifact(&dir, "c", 1, &m1, &Provenance::default()).expect("save v1");
    let registry = ModelRegistry::open(&dir).expect("open");
    let slot = registry.slot("c").expect("slot");
    let stats = CacheStats::new_shared();
    // batch=1, one replica: each request is its own (cacheable) batch.
    let server = BatchServer::start_slot(
        slot,
        ServeConfig::new(1, Duration::from_micros(50)).with_replicas(1),
        1,
        8,
        Some(Arc::clone(&stats)),
    )
    .expect("engine start");

    let x = probe(0);
    let bits = |y: Vec<f32>| y.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
    let want1 = expected_bits(&m1, &x);
    assert_eq!(bits(server.handle.infer(x.clone()).expect("infer")), want1);
    assert_eq!(bits(server.handle.infer(x.clone()).expect("infer")), want1);
    assert!(stats.hits() >= 1, "identical batch must hit the cache pre-swap");

    save_artifact(&dir, "c", 2, &m2, &Provenance::default()).expect("save v2");
    let rep = registry.reload();
    assert_eq!(rep.swapped.len(), 1, "report: {rep:?}");

    // Same batch again: the swap rebuilt the cache empty, so this must be
    // the *new* model's answer, not a stale replay of the old one.
    assert_eq!(
        bits(server.handle.infer(x.clone()).expect("infer")),
        expected_bits(&m2, &x),
        "stale cache entry served across a swap"
    );
    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A corrupt drop-in reloads with an error report and the old version
/// keeps serving; unknown model names 404 without touching any engine.
#[test]
fn corrupt_reload_keeps_serving_and_unknown_models_404() {
    let dir = tmp("corrupt");
    let _ = std::fs::remove_dir_all(&dir);
    let m1 = model(7);
    save_artifact(&dir, "keep", 1, &m1, &Provenance::default()).expect("save v1");
    let f = start(&dir, "keep");
    let addr = f.front.local_addr();
    let mut c = HttpClient::connect(addr).expect("connect");

    let x = probe(42);
    assert_eq!(infer(&mut c, &x, Some("keep")), expected_bits(&m1, &x));

    // v2 lands with one flipped payload byte.
    save_artifact(&dir, "keep", 2, &model(8), &Provenance::default()).expect("save v2");
    let bin = dir.join("keep-v2.bin");
    let mut bytes = std::fs::read(&bin).expect("read payload");
    bytes[13] ^= 0x08;
    std::fs::write(&bin, &bytes).expect("rewrite payload");

    let (status, body) = c.post_json("/v1/admin/reload", "{}").expect("reload");
    assert_eq!(status, 200, "body: {body}");
    let doc = json::parse(&body).expect("json");
    let report = doc.get("report");
    assert_eq!(report.get("swapped").as_arr().map(|a| a.len()), Some(0), "body: {body}");
    assert_eq!(report.get("errors").as_arr().map(|a| a.len()), Some(1), "body: {body}");

    // Old version still serving, bit-for-bit.
    assert_eq!(infer(&mut c, &x, None), expected_bits(&m1, &x));
    assert_eq!(f.registry.slot("keep").expect("slot").version(), 1);

    // Unknown model → 404 with the uniform error body.
    let req = protocol::InferRequest::new(x.clone()).with_model("nope");
    let (status, body) = c.post_json("/v1/infer", &req.to_json().pretty()).expect("post");
    assert_eq!(status, 404, "body: {body}");
    let err = json::parse(&body).expect("json");
    assert_eq!(err.get("error").get("kind").as_str(), Some("unknown_model"));

    drop(c);
    f.front.stop();
    f.server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}
