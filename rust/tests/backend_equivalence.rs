//! Backend equivalence: the native CPU backend against the decompress +
//! dense-GEMM oracle (always), and native vs PJRT on the same packed model
//! (when `make artifacts` has been run and a real xla crate is linked —
//! skipped otherwise, like the other artifact-gated integration tests).

use hinm::models::{Activation, HinmLayer, HinmModel};
use hinm::runtime::backend::{packed_host_tensors, PjrtBackend};
use hinm::runtime::{NativeCpuBackend, Registry, SpmmBackend};
use hinm::sparsity::{prune_oneshot, HinmConfig};
use hinm::tensor::Matrix;
use hinm::util::rng::Xoshiro256;
use std::sync::Arc;

#[test]
fn native_backend_matches_dense_reference_chain() {
    let cfg = HinmConfig::with_24(8, 0.5);
    for (seed, act) in [(31u64, Activation::Relu), (32, Activation::Gelu), (33, Activation::None)]
    {
        let model = HinmModel::synthetic_ffn(32, 64, &cfg, act, seed).unwrap();
        let mut backend = NativeCpuBackend::new(Arc::new(model.clone()));
        let mut rng = Xoshiro256::new(seed + 100);
        let x = Matrix::randn(32, 8, 1.0, &mut rng);
        let got = backend.run_batch(&x).unwrap();
        let want = model.forward_reference(&x);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-4, "native vs reference diff {diff} (act {act:?})");
    }
}

#[test]
fn native_backend_deeper_chain_matches_reference() {
    let cfg = HinmConfig::with_24(4, 0.5);
    let mut rng = Xoshiro256::new(51);
    let dims = [24usize, 16, 32, 8];
    let mut layers = Vec::new();
    for w in dims.windows(2) {
        let (d_in, d_out) = (w[0], w[1]);
        let m = Matrix::randn(d_out, d_in, 1.0, &mut rng);
        let p = prune_oneshot(&m, &m.abs(), &cfg).packed;
        let bias: Vec<f32> = (0..d_out).map(|_| rng.normal() * 0.1).collect();
        layers.push(HinmLayer::new(p).with_bias(bias).with_activation(Activation::Relu));
    }
    let model = HinmModel::new(layers).unwrap();
    let mut backend = NativeCpuBackend::new(Arc::new(model.clone()));
    let x = Matrix::randn(24, 5, 1.0, &mut rng);
    let diff = backend.run_batch(&x).unwrap().max_abs_diff(&model.forward_reference(&x));
    assert!(diff < 1e-4, "3-layer chain diff {diff}");
}

fn registry() -> Option<Registry> {
    match hinm::runtime::open_default_registry() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e:#})");
            None
        }
    }
}

#[test]
fn native_and_pjrt_backends_agree_on_the_packed_ffn() {
    let Some(reg) = registry() else { return };
    let spec = reg.artifact("ffn_serve").unwrap().clone();
    let d = spec.meta["d"] as usize;
    let d_ff = spec.meta["d_ff"] as usize;
    let batch = spec.meta["batch"] as usize;
    let cfg = HinmConfig::with_24(spec.meta["v"] as usize, spec.meta["sv"]);

    let w1 = reg.load_data("ffn_w1_dense").unwrap();
    let w2 = reg.load_data("ffn_w2_dense").unwrap();
    let w1 = Matrix::from_vec(d_ff, d, w1.as_f32().unwrap().to_vec());
    let w2 = Matrix::from_vec(d, d_ff, w2.as_f32().unwrap().to_vec());
    let p1 = prune_oneshot(&w1, &w1.abs(), &cfg).packed;
    let p2 = prune_oneshot(&w2, &w2.abs(), &cfg).packed;

    // Same packed tensors on both sides: the native chain mirrors the
    // artifact's gelu(W1·x) → W2·h (jax.nn.gelu defaults to the tanh
    // approximation the native Gelu implements).
    let model = HinmModel::new(vec![
        HinmLayer::new(p1.clone()).with_activation(Activation::Gelu),
        HinmLayer::new(p2.clone()),
    ])
    .unwrap();
    let mut native = NativeCpuBackend::new(Arc::new(model));

    let mut fixed = packed_host_tensors(&p1);
    fixed.extend(packed_host_tensors(&p2));
    let mut pjrt = match PjrtBackend::new(&spec, &fixed, d, d, batch) {
        Ok(b) => b,
        Err(e) => {
            // Artifacts exist but PJRT itself is stubbed out in this build.
            eprintln!("SKIP: PJRT backend unavailable ({e:#})");
            return;
        }
    };

    let mut rng = Xoshiro256::new(61);
    let x = Matrix::randn(d, batch, 0.1, &mut rng);
    let y_native = native.run_batch(&x).unwrap();
    let y_pjrt = pjrt.run_batch(&x).unwrap();
    let diff = y_native.max_abs_diff(&y_pjrt);
    assert!(diff < 1e-4, "native vs pjrt diff {diff}");
}
