//! Property tests for the planned tile-parallel SpMM execution engine
//! (DESIGN.md §14): planned / batch-blocked / threaded kernels must be
//! **bit-identical** to `spmm_reference` across odd shapes, V ∈ {4, 8},
//! 1:4 and 2:4 patterns, and batch sizes that don't divide the batch
//! block — and the serve path must return bit-identical responses for any
//! `--kernel-threads` setting.

use hinm::coordinator::{BatchServer, ServeConfig};
use hinm::models::{Activation, ActivationBuffers, HinmLayer, HinmModel};
use hinm::sparsity::{prune_oneshot, HinmConfig, HinmPacked};
use hinm::spmm::{spmm_reference, Epilogue, SpmmEngine, SpmmPlan};
use hinm::tensor::Matrix;
use hinm::util::rng::Xoshiro256;
use std::sync::Arc;
use std::time::Duration;

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

fn packed_for(m: usize, n: usize, cfg: &HinmConfig, seed: u64) -> HinmPacked {
    let mut rng = Xoshiro256::new(seed);
    let w = Matrix::randn(m, n, 1.0, &mut rng);
    let p = prune_oneshot(&w, &w.abs(), cfg).packed;
    p.check_invariants().expect("packed invariants");
    p
}

/// The full acceptance sweep: odd shapes × V ∈ {4, 8} × {1:4, 2:4} ×
/// vector sparsities × awkward batch sizes, engines at 1 and 8 lanes plus
/// a deliberately misaligned batch block — all bit-identical to the dense
/// reference.
#[test]
fn planned_blocked_threaded_kernels_match_reference_bitwise() {
    let shapes: &[(usize, usize, usize)] = &[
        (8, 20, 4),   // odd column count (20 % 8 ≠ 0)
        (24, 36, 4),  // both dims non-round
        (16, 52, 8),  // V = 8, odd columns
        (40, 28, 8),  // more tiles than lanes is false here: 5 tiles, 8 lanes
    ];
    let engines = [SpmmEngine::single(), SpmmEngine::new(8)];
    let mut rng = Xoshiro256::new(500);
    let mut cases = 0usize;
    for &(m, n, v) in shapes {
        for &(n_keep, m_group) in &[(1usize, 4usize), (2, 4)] {
            for &sv in &[0.0, 0.5] {
                let cfg = HinmConfig { v, n_keep, m_group, vector_sparsity: sv };
                if cfg.validate(m, n).is_err() {
                    continue;
                }
                let p = packed_for(m, n, &cfg, 500 + cases as u64);
                let plan = SpmmPlan::new(&p);
                // A block width the batch sizes below do not divide.
                let blocked = SpmmPlan::new(&p).with_batch_block(5);
                for &batch in &[1usize, 3, 7, 33] {
                    let x = Matrix::randn(n, batch, 1.0, &mut rng);
                    let want = bits(&spmm_reference(&p, &x));
                    for (e, engine) in engines.iter().enumerate() {
                        let got = engine.spmm_planned(&plan, &x);
                        assert_eq!(
                            bits(&got),
                            want,
                            "({m}×{n} V={v} {n_keep}:{m_group} sv={sv} b={batch}) engine {e}"
                        );
                        let got = engine.spmm_planned(&blocked, &x);
                        assert_eq!(
                            bits(&got),
                            want,
                            "({m}×{n} V={v} {n_keep}:{m_group} sv={sv} b={batch}) engine {e} bb=5"
                        );
                    }
                    cases += 1;
                }
            }
        }
    }
    assert!(cases >= 48, "sweep unexpectedly small: {cases} cases");
}

/// Fused bias+ReLU epilogue is bit-identical to the unfused sequence
/// (kernel → add bias → activation) on the same batch.
#[test]
fn fused_epilogue_is_bit_identical_to_the_unfused_sequence() {
    let cfg = HinmConfig::with_24(4, 0.5);
    let p = packed_for(16, 32, &cfg, 600);
    let plan = SpmmPlan::new(&p);
    let engine = SpmmEngine::new(4);
    let mut rng = Xoshiro256::new(601);
    let bias: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
    let x = Matrix::randn(32, 9, 1.0, &mut rng);

    let mut fused = Matrix::zeros(16, 9);
    engine.execute(&plan, &x, &mut fused, &Epilogue::new(Some(&bias), Activation::Relu));

    let mut unfused = engine.spmm_planned(&plan, &x);
    for (r, &b) in bias.iter().enumerate() {
        for v in unfused.row_mut(r) {
            *v += b;
        }
    }
    Activation::Relu.apply(&mut unfused);
    assert_eq!(bits(&fused), bits(&unfused));
}

/// The model-level planned forward is bit-stable across engines, lane
/// counts, and buffer reuse — including a GELU layer (fast-tanh epilogue).
#[test]
fn model_forward_bit_stable_across_lanes_and_buffer_reuse() {
    let cfg = HinmConfig::with_24(8, 0.5);
    let model = HinmModel::synthetic_ffn(32, 64, &cfg, Activation::Gelu, 610).unwrap();
    let mut rng = Xoshiro256::new(611);
    let xs: Vec<Matrix> = (0..3).map(|_| Matrix::randn(32, 5, 1.0, &mut rng)).collect();
    let want: Vec<Vec<u32>> = xs.iter().map(|x| bits(&model.forward(x))).collect();
    for lanes in [2usize, 8] {
        let engine = SpmmEngine::new(lanes);
        let mut bufs = ActivationBuffers::new();
        for (x, w) in xs.iter().zip(&want) {
            let got = model.forward_planned(x, &engine, &mut bufs);
            assert_eq!(&bits(&got), w, "{lanes} lanes");
        }
    }
}

/// A deeper chain (4 layers, mixed widths/activations) still matches the
/// dense oracle within tolerance — the ping-pong buffers never leak state
/// between layers or calls.
#[test]
fn deep_planned_chain_matches_the_dense_oracle() {
    let cfg = HinmConfig::with_24(4, 0.5);
    let layers = vec![
        HinmLayer::new(packed_for(64, 24, &cfg, 620)).with_activation(Activation::Relu),
        HinmLayer::new(packed_for(32, 64, &cfg, 621))
            .with_bias(vec![0.05; 32])
            .with_activation(Activation::Gelu),
        HinmLayer::new(packed_for(16, 32, &cfg, 622)).with_bias(vec![-0.02; 16]),
        HinmLayer::new(packed_for(8, 16, &cfg, 623)).with_activation(Activation::Relu),
    ];
    let model = HinmModel::new(layers).unwrap();
    let engine = SpmmEngine::new(3);
    let mut bufs = ActivationBuffers::new();
    let mut rng = Xoshiro256::new(624);
    for batch in [1usize, 6, 17] {
        let x = Matrix::randn(24, batch, 1.0, &mut rng);
        let got = model.forward_planned(&x, &engine, &mut bufs);
        let want = model.forward_reference(&x);
        assert_eq!(got.shape(), (8, batch));
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-4, "batch {batch}: diff {diff}");
    }
}

/// Serve-path acceptance: the same requests through engines whose replicas
/// run 1 vs 4 kernel threads produce bit-identical responses.
#[test]
fn serve_responses_bit_identical_across_kernel_thread_counts() {
    let cfg = HinmConfig::with_24(8, 0.5);
    let model =
        Arc::new(HinmModel::synthetic_ffn(32, 64, &cfg, Activation::Gelu, 630).unwrap());
    let requests: Vec<Vec<f32>> = (0..16)
        .map(|i| (0..32).map(|j| ((i * 37 + j * 11) % 19) as f32 * 0.07 - 0.6).collect())
        .collect();
    let mut per_setting: Vec<Vec<Vec<u32>>> = Vec::new();
    for kernel_threads in [1usize, 4] {
        let server = BatchServer::start_native_threads(
            Arc::clone(&model),
            ServeConfig::new(4, Duration::from_micros(200)).with_replicas(2),
            kernel_threads,
        )
        .expect("server start");
        let handle = server.handle.clone();
        let outs: Vec<Vec<u32>> = requests
            .iter()
            .map(|x| {
                handle
                    .infer(x.clone())
                    .expect("inference")
                    .iter()
                    .map(|v| v.to_bits())
                    .collect()
            })
            .collect();
        per_setting.push(outs);
        server.stop();
    }
    assert_eq!(
        per_setting[0], per_setting[1],
        "--kernel-threads must not change a single response bit"
    );
}
