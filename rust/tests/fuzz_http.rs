//! Deterministic structure-aware fuzz smoke for the `net::http` request
//! and response parsers (DESIGN.md §17, §19).
//!
//! `read_request`/`read_response` are generic over `BufRead` precisely so
//! this harness can drive them from in-memory byte slices — no sockets,
//! no timeouts, fully deterministic from `mix_seed(BASE_SEED,
//! case_index)`. Three families each:
//!
//! 1. **Well-formed frames** built within every documented bound
//!    (header count, line length, matching Content-Length): must parse to
//!    exactly the generated fields.
//! 2. **Boundary violations**: oversized lines, too many headers,
//!    conflicting or huge Content-Length, Transfer-Encoding smuggling
//!    probes — must error (never panic, never mis-frame).
//! 3. **Byte soup**: mutations of family-1 bytes plus raw garbage.
//!
//! The response families double as the router-in-the-middle target: the
//! router parses every downstream answer through `read_response`, so
//! "mutated downstream bytes never panic the router or allocate an
//! unbounded body" is pinned here in memory, and
//! `fuzz_router_survives_mutated_downstream_responses` replays a seeded
//! slice of the same mutations through a real `Router::dispatch` over
//! sockets.
//!
//! Iteration budget: `HINM_FUZZ_ITERS` (default 10 000; CI `fuzz-long`
//! raises it under an `HINM_FUZZ_SECONDS` wall-clock bound). Failing
//! inputs land in `target/fuzz-failures/` for artifact upload.

use hinm::net::http::{read_request, read_response, MAX_BODY_BYTES, MAX_HEADERS};
use hinm::util::rng::{mix_seed, Xoshiro256};
use std::time::{Duration, Instant};

const BASE_SEED: u64 = 0x4854_5450_F077;

fn iters(default: usize) -> usize {
    if cfg!(miri) {
        return 64;
    }
    std::env::var("HINM_FUZZ_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn budget() -> Option<Duration> {
    std::env::var("HINM_FUZZ_SECONDS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_secs)
}

fn persist_failure(case: u64, bytes: &[u8]) -> String {
    let dir = std::env::var("HINM_FUZZ_ARTIFACTS")
        .unwrap_or_else(|_| "target/fuzz-failures".to_string());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/http-case{case}.bin");
    let _ = std::fs::write(&path, bytes);
    path
}

fn token(rng: &mut Xoshiro256, max_len: usize) -> String {
    const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789-_.~";
    (1..=1 + rng.below(max_len)).map(|_| CHARS[rng.below(CHARS.len())] as char).collect()
}

struct GenRequest {
    method: String,
    path: String,
    headers: Vec<(String, String)>,
    body: String,
}

/// A request inside every documented bound; must parse back exactly.
fn gen_valid(rng: &mut Xoshiro256) -> (GenRequest, Vec<u8>) {
    let method = ["GET", "POST", "PUT", "DELETE", "PATCH"][rng.below(5)].to_string();
    let path = format!("/{}", token(rng, 40));
    let body: String = (0..rng.below(200)).map(|_| char::from(b' ' + rng.below(94) as u8)).collect();
    let mut headers = Vec::new();
    for _ in 0..rng.below(8) {
        // Generated names must not collide with framing headers.
        headers.push((format!("x-{}", token(rng, 12)).to_lowercase(), token(rng, 20)));
    }
    headers.push(("content-length".to_string(), body.len().to_string()));
    let mut wire = format!("{method} {path} HTTP/1.1\r\n");
    for (k, v) in &headers {
        wire.push_str(&format!("{k}: {v}\r\n"));
    }
    wire.push_str("\r\n");
    wire.push_str(&body);
    (GenRequest { method, path, headers, body }, wire.into_bytes())
}

/// A request violating exactly one documented bound; must be rejected.
fn gen_violation(rng: &mut Xoshiro256) -> Vec<u8> {
    match rng.below(6) {
        // Header line past MAX_LINE_BYTES.
        0 => format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(9000)).into_bytes(),
        // More than MAX_HEADERS headers.
        1 => {
            let mut w = String::from("GET / HTTP/1.1\r\n");
            for i in 0..MAX_HEADERS + 2 {
                w.push_str(&format!("x-h{i}: v\r\n"));
            }
            w.push_str("\r\n");
            w.into_bytes()
        }
        // Transfer-Encoding smuggling probe.
        2 => b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 4\r\n\r\nAAAA"
            .to_vec(),
        // Conflicting Content-Length pair.
        3 => b"POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 5\r\n\r\nAAAA".to_vec(),
        // Content-Length past MAX_BODY_BYTES (body intentionally absent).
        4 => format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1)
            .into_bytes(),
        // Truncated body (Content-Length larger than what follows).
        _ => b"POST / HTTP/1.1\r\nContent-Length: 100\r\n\r\nshort".to_vec(),
    }
}

fn mutate(rng: &mut Xoshiro256, bytes: &mut Vec<u8>) {
    for _ in 0..1 + rng.below(4) {
        if bytes.is_empty() {
            return;
        }
        match rng.below(4) {
            0 => {
                let i = rng.below(bytes.len());
                bytes[i] = rng.next_u64() as u8;
            }
            1 => bytes.truncate(rng.below(bytes.len())),
            2 => {
                let i = rng.below(bytes.len());
                bytes.insert(i, *[b'\r', b'\n', b':', b' ', 0u8][rng.below(5)]);
            }
            _ => {
                let i = rng.below(bytes.len());
                bytes.remove(i);
            }
        }
    }
}

/// Invariants that must hold for ANY `Ok(Some(..))` answer, whatever the
/// input: these are what the serving layer relies on for framing.
fn check_parsed(req: &hinm::net::http::HttpRequest, case: u64, input: &[u8]) {
    let fail = |msg: &str| {
        let path = persist_failure(case, input);
        panic!("case {case}: {msg}; input at {path}");
    };
    if req.body.len() > MAX_BODY_BYTES {
        fail("body exceeds MAX_BODY_BYTES");
    }
    if req.headers.len() > MAX_HEADERS + 1 {
        fail("header count exceeds MAX_HEADERS");
    }
    if req.headers.iter().any(|(k, _)| k == "transfer-encoding") {
        fail("Transfer-Encoding passed through the smuggling guard");
    }
    if let Some(cl) = req.header("content-length") {
        if cl.parse::<usize>().ok() != Some(req.body.len()) {
            fail("body length disagrees with Content-Length");
        }
    } else if !req.body.is_empty() {
        fail("non-empty body without Content-Length");
    }
    if req.method.is_empty() || req.path.is_empty() {
        fail("empty method or path");
    }
}

#[test]
fn fuzz_http_parser_smoke() {
    let n = iters(10_000);
    let start = Instant::now();
    let deadline = budget();
    let mut done = 0usize;
    for case in 0..n as u64 {
        if deadline.is_some_and(|d| start.elapsed() > d) {
            break;
        }
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED, case));
        let (expect, bytes) = match case % 3 {
            0 => {
                let (req, bytes) = gen_valid(&mut rng);
                (Some(req), bytes)
            }
            1 => (None, gen_violation(&mut rng)),
            _ => {
                let (_, mut bytes) = gen_valid(&mut rng);
                mutate(&mut rng, &mut bytes);
                (None, bytes)
            }
        };
        let parsed = std::panic::catch_unwind(|| {
            let mut reader: &[u8] = &bytes;
            read_request(&mut reader)
        });
        match parsed {
            Err(_) => {
                let path = persist_failure(case, &bytes);
                panic!("case {case}: parser panicked; input at {path}");
            }
            Ok(Ok(Some(req))) => {
                check_parsed(&req, case, &bytes);
                if let Some(want) = &expect {
                    let got_ok = req.method == want.method
                        && req.path == want.path
                        && req.body == want.body
                        && req.headers == want.headers;
                    if !got_ok {
                        let path = persist_failure(case, &bytes);
                        panic!("case {case}: well-formed request mis-parsed; input at {path}");
                    }
                }
            }
            Ok(Ok(None)) => {
                if !bytes.is_empty() && expect.is_some() {
                    let path = persist_failure(case, &bytes);
                    panic!("case {case}: well-formed request answered EOF; input at {path}");
                }
            }
            Ok(Err(_)) => {
                if case % 3 == 0 {
                    let path = persist_failure(case, &bytes);
                    panic!("case {case}: well-formed request rejected; input at {path}");
                }
            }
        }
        done += 1;
    }
    assert!(done > 0, "fuzz budget expired before the first case");
    println!("fuzz_http: {done} cases, {:?}", start.elapsed());
}

struct GenResponse {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

/// A response inside every documented bound; must parse back exactly.
fn gen_valid_response(rng: &mut Xoshiro256) -> (GenResponse, Vec<u8>) {
    let status = [200u16, 400, 404, 500, 502, 503, 504][rng.below(7)];
    let reason = token(rng, 16);
    let body: String =
        (0..rng.below(200)).map(|_| char::from(b' ' + rng.below(94) as u8)).collect();
    let mut headers = Vec::new();
    for _ in 0..rng.below(8) {
        headers.push((format!("x-{}", token(rng, 12)).to_lowercase(), token(rng, 20)));
    }
    headers.push(("content-length".to_string(), body.len().to_string()));
    let mut wire = format!("HTTP/1.1 {status} {reason}\r\n");
    for (k, v) in &headers {
        wire.push_str(&format!("{k}: {v}\r\n"));
    }
    wire.push_str("\r\n");
    wire.push_str(&body);
    (GenResponse { status, headers, body }, wire.into_bytes())
}

/// A response violating exactly one documented bound; must be rejected.
fn gen_response_violation(rng: &mut Xoshiro256) -> Vec<u8> {
    match rng.below(8) {
        // Status line past MAX_LINE_BYTES.
        0 => format!("HTTP/1.1 200 {}\r\n\r\n", "a".repeat(9000)).into_bytes(),
        // More than MAX_HEADERS headers.
        1 => {
            let mut w = String::from("HTTP/1.1 200 OK\r\n");
            for i in 0..MAX_HEADERS + 2 {
                w.push_str(&format!("x-h{i}: v\r\n"));
            }
            w.push_str("\r\n");
            w.into_bytes()
        }
        // Transfer-Encoding smuggling probe.
        2 => b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec(),
        // Conflicting Content-Length pair.
        3 => b"HTTP/1.1 200 OK\r\nContent-Length: 4\r\nContent-Length: 5\r\n\r\nAAAA".to_vec(),
        // Content-Length past MAX_BODY_BYTES: must reject up front, never
        // allocate (the no-hung-client guarantee the router relies on).
        4 => format!("HTTP/1.1 200 OK\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1)
            .into_bytes(),
        // Truncated body.
        5 => b"HTTP/1.1 200 OK\r\nContent-Length: 100\r\n\r\nshort".to_vec(),
        // Not an HTTP status line at all.
        6 => b"ICY 200 OK\r\n\r\n".to_vec(),
        // Non-numeric status.
        _ => b"HTTP/1.1 abc OK\r\n\r\n".to_vec(),
    }
}

/// Invariants for ANY `Ok(Some(..))` response parse, whatever the input —
/// what the router relies on when a downstream (or a middlebox) answers
/// garbage.
fn check_parsed_response(
    status: u16,
    headers: &[(String, String)],
    body: &str,
    case: u64,
    input: &[u8],
) {
    let fail = |msg: &str| {
        let path = persist_failure(case, input);
        panic!("case {case}: {msg}; input at {path}");
    };
    if body.len() > MAX_BODY_BYTES {
        fail("response body exceeds MAX_BODY_BYTES");
    }
    if headers.len() > MAX_HEADERS + 1 {
        fail("response header count exceeds MAX_HEADERS");
    }
    if headers.iter().any(|(k, _)| k == "transfer-encoding") {
        fail("Transfer-Encoding passed through the smuggling guard");
    }
    if let Some((_, cl)) = headers.iter().find(|(k, _)| k == "content-length") {
        if cl.parse::<usize>().ok() != Some(body.len()) {
            fail("response body length disagrees with Content-Length");
        }
    } else if !body.is_empty() {
        fail("non-empty response body without Content-Length");
    }
    if !(100..=999).contains(&status) {
        fail("status outside the three-digit range");
    }
}

#[test]
fn fuzz_response_parser_smoke() {
    let n = iters(10_000);
    let start = Instant::now();
    let deadline = budget();
    let mut done = 0usize;
    for case in 0..n as u64 {
        if deadline.is_some_and(|d| start.elapsed() > d) {
            break;
        }
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED ^ 0x5250, case));
        let (expect, bytes) = match case % 3 {
            0 => {
                let (resp, bytes) = gen_valid_response(&mut rng);
                (Some(resp), bytes)
            }
            1 => (None, gen_response_violation(&mut rng)),
            _ => {
                let (_, mut bytes) = gen_valid_response(&mut rng);
                mutate(&mut rng, &mut bytes);
                (None, bytes)
            }
        };
        let parsed = std::panic::catch_unwind(|| {
            let mut reader: &[u8] = &bytes;
            read_response(&mut reader)
        });
        match parsed {
            Err(_) => {
                let path = persist_failure(case, &bytes);
                panic!("case {case}: response parser panicked; input at {path}");
            }
            Ok(Ok(Some((status, headers, body)))) => {
                check_parsed_response(status, &headers, &body, case, &bytes);
                if let Some(want) = &expect {
                    let got_ok =
                        status == want.status && body == want.body && headers == want.headers;
                    if !got_ok {
                        let path = persist_failure(case, &bytes);
                        panic!("case {case}: well-formed response mis-parsed; input at {path}");
                    }
                }
            }
            Ok(Ok(None)) => {
                if expect.is_some() {
                    let path = persist_failure(case, &bytes);
                    panic!("case {case}: well-formed response answered EOF; input at {path}");
                }
            }
            Ok(Err(_)) => {
                if case % 3 == 0 {
                    let path = persist_failure(case, &bytes);
                    panic!("case {case}: well-formed response rejected; input at {path}");
                }
            }
        }
        done += 1;
    }
    assert!(done > 0, "fuzz budget expired before the first case");
    println!("fuzz_http (responses): {done} cases, {:?}", start.elapsed());
}

#[test]
fn response_violation_family_is_always_rejected() {
    for k in 0..8u64 {
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED ^ 0xBAD2, k));
        // Reuse the generator but force each arm deterministically by
        // regenerating until the wanted shape appears — below(8) is
        // uniform, so pin the arms directly instead.
        let bytes = match k {
            0 => format!("HTTP/1.1 200 {}\r\n\r\n", "a".repeat(9000)).into_bytes(),
            1 => {
                let mut w = String::from("HTTP/1.1 200 OK\r\n");
                for i in 0..MAX_HEADERS + 2 {
                    w.push_str(&format!("x-h{i}: v\r\n"));
                }
                w.push_str("\r\n");
                w.into_bytes()
            }
            2 => b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec(),
            3 => b"HTTP/1.1 200 OK\r\nContent-Length: 4\r\nContent-Length: 5\r\n\r\nAAAA"
                .to_vec(),
            4 => format!("HTTP/1.1 200 OK\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1)
                .into_bytes(),
            5 => b"HTTP/1.1 200 OK\r\nContent-Length: 100\r\n\r\nshort".to_vec(),
            6 => b"ICY 200 OK\r\n\r\n".to_vec(),
            _ => gen_response_violation(&mut rng),
        };
        let mut reader: &[u8] = &bytes;
        assert!(read_response(&mut reader).is_err(), "response violation {k} accepted");
    }
}

/// Router-in-the-middle: a raw TCP "downstream" answers every request
/// with a seeded mutation of a valid response frame, and a real
/// [`hinm::coordinator::Router`] dispatches against it. The router must
/// return a reply for every request — no panic, no hang past its per-try
/// watchdog, no unbounded body — whatever bytes come back. Case count is
/// self-capped (sockets are slower than the in-memory families), so the
/// fuzz-long iteration env cannot stretch this target past its budget.
#[test]
fn fuzz_router_survives_mutated_downstream_responses() {
    use hinm::coordinator::{ProxyRequest, RouteReply, Router, RouterConfig};
    use std::io::{Read, Write};
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    if cfg!(miri) {
        return; // sockets — covered by the in-memory families under Miri
    }

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind mutant downstream");
    let addr = listener.local_addr().expect("mutant addr");
    let stopping = Arc::new(AtomicBool::new(false));
    let served = Arc::new(AtomicU64::new(0));
    let acceptor = {
        let stopping = Arc::clone(&stopping);
        let served = Arc::clone(&served);
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stopping.load(Ordering::SeqCst) {
                    return;
                }
                let Ok(mut stream) = conn else { continue };
                // Drain the request head, then answer with the next
                // seeded mutant frame and close.
                let mut buf = [0u8; 4096];
                let _ = stream.read(&mut buf);
                let case = served.fetch_add(1, Ordering::SeqCst);
                let mut rng = Xoshiro256::new(mix_seed(BASE_SEED ^ 0x4D49_4D, case));
                let bytes = match case % 4 {
                    0 => gen_valid_response(&mut rng).1,
                    1 => gen_response_violation(&mut rng),
                    _ => {
                        let (_, mut b) = gen_valid_response(&mut rng);
                        mutate(&mut rng, &mut b);
                        b
                    }
                };
                let _ = stream.write_all(&bytes);
                let _ = stream.flush();
                // Drop closes the connection: nothing is ever pooled
                // against a response the router accepted by accident.
            }
        })
    };

    let cfg = RouterConfig {
        probe_interval_ms: 600_000,
        probe_timeout_ms: 50,
        // Keep the lone backend eligible forever: this target exercises
        // the parser path, not the breaker.
        fail_threshold: 1_000_000,
        backoff_base_ms: 1,
        backoff_max_ms: 1,
        retry_backoff_ms: 1,
        hedge_floor_ms: 50,
        hedge_ceil_ms: 50,
        connect_timeout_ms: 200,
        per_try_timeout_ms: 100,
        max_attempts: 2,
        max_inflight: 8,
        drain_ms: 500,
        seed: 13,
    };
    let router =
        Router::start(vec![("mutant".to_string(), addr)], cfg).expect("router start");

    let n = iters(256).min(2048);
    let start = Instant::now();
    let deadline = budget();
    let mut done = 0usize;
    let mut replied = 0usize;
    for case in 0..n {
        if deadline.is_some_and(|d| start.elapsed() > d) {
            break;
        }
        let req = ProxyRequest {
            method: "POST",
            path: "/v1/infer",
            body: "{\"x\":[0.0]}",
            model: None,
            deadline_ms: Some(2_000),
            idempotent: true,
        };
        match router.dispatch(&req) {
            RouteReply::Replied { body, .. } => {
                assert!(
                    body.len() <= MAX_BODY_BYTES,
                    "case {case}: router relayed an oversized body"
                );
                replied += 1;
            }
            RouteReply::Failed { .. } => {}
            RouteReply::Busy { .. } => panic!("case {case}: sequential driver can't be shed"),
        }
        done += 1;
    }
    assert!(done > 0, "fuzz budget expired before the first case");
    assert!(replied > 0, "the valid-frame family must produce some relayed replies");
    router.stop();
    stopping.store(true, Ordering::SeqCst);
    let _ = std::net::TcpStream::connect(addr);
    let _ = acceptor.join();
    println!(
        "fuzz_http (router-in-the-middle): {done} dispatches, {replied} relayed, {:?}",
        start.elapsed()
    );
}

#[test]
fn violation_family_is_always_rejected() {
    // The six seeded violation shapes must each produce Err (not Ok, not
    // panic) — pinned separately from the smoke so a regression names the
    // exact guard that broke.
    for k in 0..6u64 {
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED ^ 0xBAD, k));
        // Drive below() so each arm is reachable deterministically.
        let bytes = match k {
            0 => format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(9000)).into_bytes(),
            1 => {
                let mut w = String::from("GET / HTTP/1.1\r\n");
                for i in 0..MAX_HEADERS + 2 {
                    w.push_str(&format!("x-h{i}: v\r\n"));
                }
                w.push_str("\r\n");
                w.into_bytes()
            }
            2 => b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec(),
            3 => b"POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 5\r\n\r\nAAAA".to_vec(),
            4 => format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1)
                .into_bytes(),
            _ => gen_violation(&mut rng),
        };
        let mut reader: &[u8] = &bytes;
        assert!(read_request(&mut reader).is_err(), "violation {k} accepted");
    }
}
