//! Deterministic structure-aware fuzz smoke for the `net::http` request
//! parser (DESIGN.md §17).
//!
//! `read_request` is generic over `BufRead` precisely so this harness can
//! drive it from in-memory byte slices — no sockets, no timeouts, fully
//! deterministic from `mix_seed(BASE_SEED, case_index)`. Three families:
//!
//! 1. **Well-formed requests** built within every documented bound
//!    (header count, line length, matching Content-Length): must parse to
//!    exactly the generated method/path/headers/body.
//! 2. **Boundary violations**: oversized lines, too many headers,
//!    conflicting or huge Content-Length, Transfer-Encoding smuggling
//!    probes — must error (never panic, never mis-frame).
//! 3. **Byte soup**: mutations of family-1 bytes plus raw garbage.
//!
//! Iteration budget: `HINM_FUZZ_ITERS` (default 10 000; CI `fuzz-long`
//! raises it under an `HINM_FUZZ_SECONDS` wall-clock bound). Failing
//! inputs land in `target/fuzz-failures/` for artifact upload.

use hinm::net::http::{read_request, MAX_BODY_BYTES, MAX_HEADERS};
use hinm::util::rng::{mix_seed, Xoshiro256};
use std::time::{Duration, Instant};

const BASE_SEED: u64 = 0x4854_5450_F077;

fn iters(default: usize) -> usize {
    if cfg!(miri) {
        return 64;
    }
    std::env::var("HINM_FUZZ_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn budget() -> Option<Duration> {
    std::env::var("HINM_FUZZ_SECONDS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_secs)
}

fn persist_failure(case: u64, bytes: &[u8]) -> String {
    let dir = std::env::var("HINM_FUZZ_ARTIFACTS")
        .unwrap_or_else(|_| "target/fuzz-failures".to_string());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/http-case{case}.bin");
    let _ = std::fs::write(&path, bytes);
    path
}

fn token(rng: &mut Xoshiro256, max_len: usize) -> String {
    const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789-_.~";
    (1..=1 + rng.below(max_len)).map(|_| CHARS[rng.below(CHARS.len())] as char).collect()
}

struct GenRequest {
    method: String,
    path: String,
    headers: Vec<(String, String)>,
    body: String,
}

/// A request inside every documented bound; must parse back exactly.
fn gen_valid(rng: &mut Xoshiro256) -> (GenRequest, Vec<u8>) {
    let method = ["GET", "POST", "PUT", "DELETE", "PATCH"][rng.below(5)].to_string();
    let path = format!("/{}", token(rng, 40));
    let body: String = (0..rng.below(200)).map(|_| char::from(b' ' + rng.below(94) as u8)).collect();
    let mut headers = Vec::new();
    for _ in 0..rng.below(8) {
        // Generated names must not collide with framing headers.
        headers.push((format!("x-{}", token(rng, 12)).to_lowercase(), token(rng, 20)));
    }
    headers.push(("content-length".to_string(), body.len().to_string()));
    let mut wire = format!("{method} {path} HTTP/1.1\r\n");
    for (k, v) in &headers {
        wire.push_str(&format!("{k}: {v}\r\n"));
    }
    wire.push_str("\r\n");
    wire.push_str(&body);
    (GenRequest { method, path, headers, body }, wire.into_bytes())
}

/// A request violating exactly one documented bound; must be rejected.
fn gen_violation(rng: &mut Xoshiro256) -> Vec<u8> {
    match rng.below(6) {
        // Header line past MAX_LINE_BYTES.
        0 => format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(9000)).into_bytes(),
        // More than MAX_HEADERS headers.
        1 => {
            let mut w = String::from("GET / HTTP/1.1\r\n");
            for i in 0..MAX_HEADERS + 2 {
                w.push_str(&format!("x-h{i}: v\r\n"));
            }
            w.push_str("\r\n");
            w.into_bytes()
        }
        // Transfer-Encoding smuggling probe.
        2 => b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 4\r\n\r\nAAAA"
            .to_vec(),
        // Conflicting Content-Length pair.
        3 => b"POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 5\r\n\r\nAAAA".to_vec(),
        // Content-Length past MAX_BODY_BYTES (body intentionally absent).
        4 => format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1)
            .into_bytes(),
        // Truncated body (Content-Length larger than what follows).
        _ => b"POST / HTTP/1.1\r\nContent-Length: 100\r\n\r\nshort".to_vec(),
    }
}

fn mutate(rng: &mut Xoshiro256, bytes: &mut Vec<u8>) {
    for _ in 0..1 + rng.below(4) {
        if bytes.is_empty() {
            return;
        }
        match rng.below(4) {
            0 => {
                let i = rng.below(bytes.len());
                bytes[i] = rng.next_u64() as u8;
            }
            1 => bytes.truncate(rng.below(bytes.len())),
            2 => {
                let i = rng.below(bytes.len());
                bytes.insert(i, *[b'\r', b'\n', b':', b' ', 0u8][rng.below(5)]);
            }
            _ => {
                let i = rng.below(bytes.len());
                bytes.remove(i);
            }
        }
    }
}

/// Invariants that must hold for ANY `Ok(Some(..))` answer, whatever the
/// input: these are what the serving layer relies on for framing.
fn check_parsed(req: &hinm::net::http::HttpRequest, case: u64, input: &[u8]) {
    let fail = |msg: &str| {
        let path = persist_failure(case, input);
        panic!("case {case}: {msg}; input at {path}");
    };
    if req.body.len() > MAX_BODY_BYTES {
        fail("body exceeds MAX_BODY_BYTES");
    }
    if req.headers.len() > MAX_HEADERS + 1 {
        fail("header count exceeds MAX_HEADERS");
    }
    if req.headers.iter().any(|(k, _)| k == "transfer-encoding") {
        fail("Transfer-Encoding passed through the smuggling guard");
    }
    if let Some(cl) = req.header("content-length") {
        if cl.parse::<usize>().ok() != Some(req.body.len()) {
            fail("body length disagrees with Content-Length");
        }
    } else if !req.body.is_empty() {
        fail("non-empty body without Content-Length");
    }
    if req.method.is_empty() || req.path.is_empty() {
        fail("empty method or path");
    }
}

#[test]
fn fuzz_http_parser_smoke() {
    let n = iters(10_000);
    let start = Instant::now();
    let deadline = budget();
    let mut done = 0usize;
    for case in 0..n as u64 {
        if deadline.is_some_and(|d| start.elapsed() > d) {
            break;
        }
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED, case));
        let (expect, bytes) = match case % 3 {
            0 => {
                let (req, bytes) = gen_valid(&mut rng);
                (Some(req), bytes)
            }
            1 => (None, gen_violation(&mut rng)),
            _ => {
                let (_, mut bytes) = gen_valid(&mut rng);
                mutate(&mut rng, &mut bytes);
                (None, bytes)
            }
        };
        let parsed = std::panic::catch_unwind(|| {
            let mut reader: &[u8] = &bytes;
            read_request(&mut reader)
        });
        match parsed {
            Err(_) => {
                let path = persist_failure(case, &bytes);
                panic!("case {case}: parser panicked; input at {path}");
            }
            Ok(Ok(Some(req))) => {
                check_parsed(&req, case, &bytes);
                if let Some(want) = &expect {
                    let got_ok = req.method == want.method
                        && req.path == want.path
                        && req.body == want.body
                        && req.headers == want.headers;
                    if !got_ok {
                        let path = persist_failure(case, &bytes);
                        panic!("case {case}: well-formed request mis-parsed; input at {path}");
                    }
                }
            }
            Ok(Ok(None)) => {
                if !bytes.is_empty() && expect.is_some() {
                    let path = persist_failure(case, &bytes);
                    panic!("case {case}: well-formed request answered EOF; input at {path}");
                }
            }
            Ok(Err(_)) => {
                if case % 3 == 0 {
                    let path = persist_failure(case, &bytes);
                    panic!("case {case}: well-formed request rejected; input at {path}");
                }
            }
        }
        done += 1;
    }
    assert!(done > 0, "fuzz budget expired before the first case");
    println!("fuzz_http: {done} cases, {:?}", start.elapsed());
}

#[test]
fn violation_family_is_always_rejected() {
    // The six seeded violation shapes must each produce Err (not Ok, not
    // panic) — pinned separately from the smoke so a regression names the
    // exact guard that broke.
    for k in 0..6u64 {
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED ^ 0xBAD, k));
        // Drive below() so each arm is reachable deterministically.
        let bytes = match k {
            0 => format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(9000)).into_bytes(),
            1 => {
                let mut w = String::from("GET / HTTP/1.1\r\n");
                for i in 0..MAX_HEADERS + 2 {
                    w.push_str(&format!("x-h{i}: v\r\n"));
                }
                w.push_str("\r\n");
                w.into_bytes()
            }
            2 => b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec(),
            3 => b"POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 5\r\n\r\nAAAA".to_vec(),
            4 => format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1)
                .into_bytes(),
            _ => gen_violation(&mut rng),
        };
        let mut reader: &[u8] = &bytes;
        assert!(read_request(&mut reader).is_err(), "violation {k} accepted");
    }
}
