//! Gradual-pruning orchestrator against the live LM trainer (PJRT).
//! Skipped when artifacts are absent.

use hinm::coordinator::gradual::{run_gradual_lm, GradualConfig};
use hinm::coordinator::{Corpus, LmTrainer};
use hinm::sparsity::HinmConfig;

#[test]
fn gradual_lm_ramps_and_recovers() {
    let Some(reg) = (match hinm::runtime::open_default_registry() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e:#})");
            None
        }
    }) else {
        return;
    };

    let mut trainer = LmTrainer::new(&reg).unwrap();
    let (b, s) = (trainer.batch, trainer.seq);
    let mut corpus = Corpus::new(trainer.vocab, 0.05, 1);
    let mut heldout = Corpus::new(trainer.vocab, 0.05, 2);

    // Brief pre-training so pruning has signal.
    for _ in 0..60 {
        let (t, g) = corpus.batch(b, s);
        trainer.step(&t, &g, 0.5).unwrap();
    }
    let (t, g) = heldout.batch(b, s);
    let dense_loss = trainer.eval_loss(&t, &g).unwrap();

    let mut cfg = GradualConfig::new(HinmConfig::for_total_sparsity(32, 0.75));
    cfg.ft_steps_per_stage = 15;
    let reports = run_gradual_lm(&mut trainer, &mut corpus, &mut heldout, &cfg).unwrap();

    assert_eq!(reports.len(), cfg.total_steps);
    // Vector sparsity ramps monotonically.
    for w in reports.windows(2) {
        assert!(w[1].step.vector_sparsity >= w[0].step.vector_sparsity - 1e-12);
    }
    // N:M active only in the tail.
    assert!(!reports[0].step.nm_active);
    assert!(reports.last().unwrap().step.nm_active);
    // Final masks hold the target sparsity on every pruned tensor.
    for n in trainer.mnames.clone() {
        let w = trainer.param_matrix(&n).unwrap();
        assert!(w.density() < 0.30, "{n}: density {}", w.density());
    }
    // Fine-tuning keeps the final loss in a sane band (not divergent).
    let final_loss = reports.last().unwrap().loss.unwrap();
    assert!(
        final_loss < dense_loss + 2.5,
        "gradual run diverged: dense {dense_loss} final {final_loss}"
    );
}

#[test]
fn gradual_venom_arm_runs() {
    let Some(reg) = (match hinm::runtime::open_default_registry() {
        Ok(r) => Some(r),
        Err(_) => None,
    }) else {
        return;
    };
    let mut trainer = LmTrainer::new(&reg).unwrap();
    let (b, s) = (trainer.batch, trainer.seq);
    let mut corpus = Corpus::new(trainer.vocab, 0.05, 3);
    let mut heldout = Corpus::new(trainer.vocab, 0.05, 4);
    for _ in 0..30 {
        let (t, g) = corpus.batch(b, s);
        trainer.step(&t, &g, 0.5).unwrap();
    }
    let mut cfg = GradualConfig::new(HinmConfig::for_total_sparsity(32, 0.75));
    cfg.permute = false; // VENOM-style arm
    cfg.ft_steps_per_stage = 5;
    let reports = run_gradual_lm(&mut trainer, &mut corpus, &mut heldout, &cfg).unwrap();
    assert_eq!(reports.len(), cfg.total_steps);
    assert!(reports.iter().all(|r| r.retention > 0.0 && r.retention <= 1.0 + 1e-9));
}
