//! Distributed bit-identity for cross-host pipeline stages (DESIGN.md
//! §20): real `hinm stage` child processes — spawned via
//! `CARGO_BIN_EXE_hinm`, exactly what an operator runs — serve contiguous
//! sub-chains over TCP, and a [`RemotePipelinedBackend`] head drives the
//! chain through them. The distributed output must be **bitwise
//! identical** to the in-process [`HinmModel::forward_planned`] reference
//! for every serving-catalog model × stage count × batch size, and again
//! through the full `hinm serve --stage-hosts` HTTP front.
//!
//! No weights ever cross the wire: head and stage hosts independently
//! build the same model from the same `--model`/`--seed` flags and agree
//! on stage boundaries because [`HinmModel::split_stages`] is
//! deterministic in the model. That agreement is exactly what these tests
//! pin — if construction or partitioning ever diverges between the CLI
//! and the library, dims stop lining up or bits change, and this suite
//! fails loudly rather than an operator's fleet drifting silently.

use hinm::coordinator::StageLinkMetrics;
use hinm::models::chain::ActivationBuffers;
use hinm::models::{serving_models, HinmModel};
use hinm::net::{protocol, HttpClient};
use hinm::runtime::{RemotePipelinedBackend, SpmmBackend, StageLinkConfig};
use hinm::spmm::SpmmEngine;
use hinm::tensor::Matrix;
use hinm::util::json;
use hinm::util::rng::Xoshiro256;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

fn vec_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Reference output through the unsplit planned path.
fn planned(model: &HinmModel, x: &Matrix) -> Matrix {
    let engine = SpmmEngine::single();
    let mut bufs = ActivationBuffers::new();
    model.forward_planned(x, &engine, &mut bufs)
}

/// A spawned `hinm` child whose ready line has been parsed for its bound
/// address. Killed (and reaped) on drop so a failing assertion never
/// leaks processes into the test runner.
struct CliChild {
    child: Child,
    addr: String,
}

impl CliChild {
    /// Spawn `hinm <args>` and block until a stdout line contains
    /// `ready_marker`, returning the address printed right after it.
    /// `addr_end` bounds the address token (`" |"` for stage hosts, end
    /// of line for the HTTP front).
    fn spawn(args: &[&str], ready_marker: &str, addr_end: Option<&str>) -> CliChild {
        let mut child = Command::new(env!("CARGO_BIN_EXE_hinm"))
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn hinm child");
        let stdout = child.stdout.take().expect("child stdout piped");
        let mut lines = BufReader::new(stdout).lines();
        let addr = loop {
            let line = match lines.next() {
                Some(Ok(line)) => line,
                other => {
                    let _ = child.kill();
                    let _ = child.wait();
                    panic!("child exited before ready line ({args:?}): {other:?}");
                }
            };
            if let Some(rest) = line.split(ready_marker).nth(1) {
                let addr = match addr_end {
                    Some(end) => rest.split(end).next().unwrap_or(rest),
                    None => rest,
                };
                break addr.trim().to_string();
            }
        };
        CliChild { child, addr }
    }

    fn stage(model: &str, stage: usize, stages: usize, listen: &str) -> CliChild {
        let spec = format!("{stage}/{stages}");
        CliChild::spawn(
            &["stage", "--stage", &spec, "--model", model, "--seed", "7", "--listen", listen],
            "listening on ",
            Some(" |"),
        )
    }
}

impl Drop for CliChild {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn one `hinm stage` child per stage of an S-way split of the named
/// catalog model, returning them with their host list in chain order.
fn spawn_stage_hosts(model: &str, stages: usize) -> (Vec<CliChild>, Vec<String>) {
    let children: Vec<CliChild> =
        (1..=stages).map(|k| CliChild::stage(model, k, stages, "127.0.0.1:0")).collect();
    let hosts: Vec<String> = children.iter().map(|c| c.addr.clone()).collect();
    (children, hosts)
}

/// The headline pin: for every serving-catalog model × stages {2, 3} ×
/// batches {1, 7, 33}, a head driving real `hinm stage` children returns
/// bits identical to the in-process planned forward pass.
#[test]
fn cross_host_outputs_match_forward_planned_bit_for_bit() {
    for (name, model) in serving_models(7).unwrap() {
        for &stages in &[2usize, 3] {
            if stages > model.n_layers() {
                continue; // ffn-relu has 2 layers; a 3-way split is an error, not a test.
            }
            let (_children, hosts) = spawn_stage_hosts(name, stages);
            let links = StageLinkMetrics::new(&hosts);
            let mut backend = RemotePipelinedBackend::connect(
                &hosts,
                model.d_in(),
                model.d_out(),
                StageLinkConfig::default(),
                Arc::clone(&links),
            )
            .unwrap_or_else(|e| panic!("{name}: connect {stages} stage hosts: {e}"));

            let mut rng = Xoshiro256::new(0x5747 ^ stages as u64);
            let mut batches = 0u64;
            for &batch in &[1usize, 7, 33] {
                let x = Matrix::randn(model.d_in(), batch, 1.0, &mut rng);
                let want = planned(&model, &x);
                // Two rounds so the recycled §15 hop buffers are hit.
                for round in 0..2 {
                    let got = backend.run_batch(&x).unwrap_or_else(|e| {
                        panic!("{name}: stages={stages} batch={batch} round={round}: {e}")
                    });
                    assert_eq!(got.shape(), (model.d_out(), batch));
                    assert_eq!(
                        bits(&got),
                        bits(&want),
                        "{name}: stages={stages} batch={batch} round={round} changed bits"
                    );
                    batches += 1;
                }
            }

            // Every batch crossed every link exactly once, cleanly.
            let snap = links.snapshot();
            for (row, host) in snap.links.iter().zip(&hosts) {
                assert_eq!(row.batches, batches, "{name}: {host} batches");
                assert_eq!(row.reconnects, 0, "{name}: {host} reconnects");
                assert_eq!(
                    row.failures_unreachable + row.failures_timeout + row.failures_protocol,
                    0,
                    "{name}: {host} failures"
                );
            }
        }
    }
}

/// Same pin through the entire operator surface: a real `hinm serve
/// --stage-hosts` head process (batch window, replica worker, HTTP front)
/// in front of real `hinm stage` children, answering `POST /v1/infer`
/// with bits identical to the in-process reference.
#[test]
fn stage_serve_http_front_is_bit_identical_end_to_end() {
    let (name, stages) = ("bert-mini", 3usize);
    let model = serving_models(7)
        .unwrap()
        .into_iter()
        .find(|(n, _)| *n == name)
        .map(|(_, m)| m)
        .expect("catalog model");
    let (_children, hosts) = spawn_stage_hosts(name, stages);

    let head = CliChild::spawn(
        &[
            "serve",
            "--model",
            name,
            "--seed",
            "7",
            "--stage-hosts",
            &hosts.join(","),
            "--replicas",
            "1",
            "--batch",
            "4",
            "--http",
            "127.0.0.1:0",
        ],
        "HTTP front listening on http://",
        None,
    );
    let mut client =
        HttpClient::connect(head.addr.parse().expect("front addr")).expect("connect front");

    let mut rng = Xoshiro256::new(23);
    for i in 0..12 {
        let x = Matrix::randn(model.d_in(), 1, 1.0, &mut rng);
        let want = planned(&model, &x);
        let body = protocol::InferRequest::new(x.data.clone()).to_json().compact();
        let (status, resp) = client.post_json("/v1/infer", &body).expect("infer round-trip");
        assert_eq!(status, 200, "request {i}: {resp}");
        let y = protocol::parse_infer_response(&json::parse(&resp).unwrap()).unwrap();
        assert_eq!(
            vec_bits(&y),
            vec_bits(&want.data),
            "request {i}: HTTP answer changed bits"
        );
    }

    // The head's /v1/metrics exposes one stage_links row per child, all
    // clean: 12 single-column requests grouped by the batch window into
    // at least one and at most 12 batches, zero failures.
    let (status, body) = client.get("/v1/metrics").expect("metrics");
    assert_eq!(status, 200);
    let doc = json::parse(&body).expect("metrics json");
    let rows = doc.get("stage_links").as_arr().expect("stage_links array");
    assert_eq!(rows.len(), stages, "one row per stage host: {body}");
    for (row, host) in rows.iter().zip(&hosts) {
        assert_eq!(row.get("host").as_str(), Some(host.as_str()), "{body}");
        let batches = row.get("batches").as_f64().expect("batches");
        assert!(
            (1.0..=12.0).contains(&batches),
            "{host}: 12 requests → 1..=12 batches, got {batches}: {body}"
        );
        assert_eq!(row.get("reconnects").as_f64(), Some(0.0), "{host}: {body}");
        assert_eq!(row.get("failures_unreachable").as_f64(), Some(0.0), "{host}: {body}");
        assert_eq!(row.get("failures_timeout").as_f64(), Some(0.0), "{host}: {body}");
        assert_eq!(row.get("failures_protocol").as_f64(), Some(0.0), "{host}: {body}");
    }
}

/// The CLI composition guards: a stage index outside the split and flag
/// combinations documented as non-composing must fail fast with a
/// pointed message, not limp into serving the wrong shard.
#[test]
fn stage_cli_rejects_bad_splits_and_compositions() {
    let out = Command::new(env!("CARGO_BIN_EXE_hinm"))
        .args(["stage", "--stage", "4/3", "--model", "bert-mini"])
        .output()
        .expect("spawn hinm stage");
    assert!(!out.status.success(), "stage 4/3 must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("outside"), "stderr: {err}");

    let out = Command::new(env!("CARGO_BIN_EXE_hinm"))
        .args(["stage", "--stage", "1/9", "--model", "ffn-relu"])
        .output()
        .expect("spawn hinm stage");
    assert!(!out.status.success(), "splitting 2 layers 9 ways must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("stages"), "stderr: {err}");

    let out = Command::new(env!("CARGO_BIN_EXE_hinm"))
        .args([
            "serve",
            "--stage-hosts",
            "127.0.0.1:1",
            "--pipeline-stages",
            "2",
            "--requests",
            "1",
        ])
        .output()
        .expect("spawn hinm serve");
    assert!(!out.status.success(), "stage-hosts × pipeline-stages must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--stage-hosts") && err.contains("--pipeline-stages"), "stderr: {err}");
}
