//! End-to-end HTTP front tests: a real `BatchServer` (native backend)
//! behind `HttpFront` on an ephemeral TCP port, driven through
//! `net::HttpClient` over real sockets — round-tripping inference,
//! scheduling fields, metrics, health, and every error status.

use hinm::coordinator::{BatchServer, ServeConfig};
use hinm::models::{Activation, HinmModel};
use hinm::net::{protocol, HttpClient, HttpFront};
use hinm::sparsity::HinmConfig;
use hinm::spmm::{KernelInfo, KernelIsa, ValueFormat};
use hinm::tensor::Matrix;
use hinm::util::json;
use std::sync::Arc;
use std::time::Duration;

const D: usize = 32;

struct Setup {
    front: HttpFront,
    server: BatchServer,
    model: Arc<HinmModel>,
}

fn start() -> Setup {
    let cfg = HinmConfig::with_24(8, 0.5);
    let model =
        Arc::new(HinmModel::synthetic_ffn(D, 64, &cfg, Activation::Relu, 17).unwrap());
    let server = BatchServer::start_native(
        Arc::clone(&model),
        ServeConfig::new(4, Duration::from_millis(2)).with_replicas(2),
    )
    .expect("engine start");
    // Pass the real detected kernel info so /v1/metrics exercises the
    // kernel block end-to-end over a socket.
    let kernel = KernelInfo::current(ValueFormat::F32);
    let front = HttpFront::start("127.0.0.1:0", server.handle.clone(), None, Some(kernel), 4)
        .expect("http front start");
    Setup { front, server, model }
}

fn client(s: &Setup) -> HttpClient {
    HttpClient::connect(s.front.local_addr()).expect("connect")
}

fn activation(seed: usize) -> Vec<f32> {
    (0..D).map(|i| ((seed * 31 + i * 7) % 13) as f32 * 0.1 - 0.6).collect()
}

fn infer_body(x: &[f32]) -> String {
    protocol::InferRequest::new(x.to_vec()).to_json().pretty()
}

#[test]
fn healthz_answers_ok() {
    let s = start();
    let mut c = client(&s);
    let (status, body) = c.get("/healthz").unwrap();
    assert_eq!(status, 200, "body: {body}");
    assert_eq!(json::parse(&body).unwrap().get("status").as_str(), Some("ok"));
    drop(c);
    s.front.stop();
    s.server.stop();
}

#[test]
fn infer_round_trips_over_a_real_socket() {
    let s = start();
    let mut c = client(&s);
    let x = activation(1);
    let (status, body) = c.post_json("/v1/infer", &infer_body(&x)).unwrap();
    assert_eq!(status, 200, "body: {body}");
    let y = protocol::parse_infer_response(&json::parse(&body).unwrap()).unwrap();

    // The HTTP path must agree bit-for-bit with an in-process forward of
    // the same single activation column.
    let x_col = Matrix::from_vec(D, 1, x);
    let expect = s.model.forward(&x_col);
    assert_eq!(y.len(), expect.data.len());
    assert_eq!(
        y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        expect.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "HTTP round-trip must be lossless (shortest-roundtrip JSON floats)"
    );
    drop(c);
    s.front.stop();
    s.server.stop();
}

#[test]
fn keep_alive_connection_serves_many_requests_and_metrics_count_them() {
    let s = start();
    let mut c = client(&s);
    for i in 0..8 {
        let (status, _) = c.post_json("/v1/infer", &infer_body(&activation(i))).unwrap();
        assert_eq!(status, 200);
    }
    let (status, body) = c.get("/v1/metrics").unwrap();
    assert_eq!(status, 200);
    let m = json::parse(&body).unwrap();
    assert_eq!(m.get("requests").as_usize(), Some(8));
    assert_eq!(m.get("priorities").get("normal").as_usize(), Some(8));
    assert_eq!(m.get("expired").get("in_queue").as_usize(), Some(0));
    assert_eq!(m.get("replicas").as_arr().unwrap().len(), 2);
    // The kernel block reports whatever ISA this host dispatched to.
    let isa = KernelIsa::detect();
    assert_eq!(m.get("kernel").get("isa").as_str(), Some(isa.as_str()));
    assert_eq!(m.get("kernel").get("values").as_str(), Some("f32"));
    assert!(m.get("kernel").get("panel_target_bytes").as_usize().unwrap() >= 16 * 1024);
    drop(c);
    s.front.stop();
    s.server.stop();
}

#[test]
fn metrics_prometheus_format_over_http() {
    let s = start();
    let mut c = client(&s);
    let (status, _) = c.post_json("/v1/infer", &infer_body(&activation(5))).unwrap();
    assert_eq!(status, 200);

    let (status, body) = c.get("/v1/metrics?format=prometheus").unwrap();
    assert_eq!(status, 200, "body: {body}");
    assert!(body.contains("# TYPE hinm_requests_total counter"), "{body}");
    assert!(body.contains("# TYPE hinm_request_latency_microseconds summary"));
    assert!(body.contains("hinm_requests_served_total{priority=\"normal\"} 1"), "{body}");
    assert!(body.contains("hinm_requests_expired_total{stage=\"enqueue\"} 0"));
    assert!(body.contains("hinm_replica_requests_total{replica=\"1\"}"));
    let line = body
        .lines()
        .find(|l| l.starts_with("hinm_requests_total "))
        .expect("hinm_requests_total sample");
    assert_eq!(line, "hinm_requests_total 1");
    // No cache is configured in this setup, so no cache families.
    assert!(!body.contains("hinm_cache_hits_total"), "{body}");
    // The kernel info family carries the dispatched variant as labels.
    let isa = KernelIsa::detect();
    assert!(
        body.contains(&format!("hinm_kernel_info{{isa=\"{}\",values=\"f32\"}} 1", isa.as_str())),
        "{body}"
    );
    assert!(body.contains("# TYPE hinm_kernel_panel_target_bytes gauge"), "{body}");

    // Explicit json format and the bare route stay JSON.
    let (status, body) = c.get("/v1/metrics?format=json").unwrap();
    assert_eq!(status, 200);
    assert_eq!(json::parse(&body).unwrap().get("requests").as_usize(), Some(1));
    let (status, body) = c.get("/v1/metrics").unwrap();
    assert_eq!(status, 200);
    json::parse(&body).unwrap();

    // An unknown format is a 400 with the uniform error body.
    let (status, body) = c.get("/v1/metrics?format=xml").unwrap();
    assert_eq!(status, 400, "body: {body}");
    assert_eq!(
        json::parse(&body).unwrap().get("error").get("kind").as_str(),
        Some("bad_request")
    );
    drop(c);
    s.front.stop();
    s.server.stop();
}

#[test]
fn concurrent_http_clients_all_get_their_own_answer() {
    let s = start();
    let addr = s.front.local_addr();
    std::thread::scope(|scope| {
        for t in 0..4 {
            scope.spawn(move || {
                let mut c = HttpClient::connect(addr).expect("connect");
                for i in 0..6 {
                    let x = activation(t * 100 + i);
                    let (status, body) =
                        c.post_json("/v1/infer", &infer_body(&x)).unwrap();
                    assert_eq!(status, 200, "client {t} req {i}: {body}");
                    let y =
                        protocol::parse_infer_response(&json::parse(&body).unwrap()).unwrap();
                    assert_eq!(y.len(), D);
                }
            });
        }
    });
    assert_eq!(s.server.metrics.total_requests(), 24);
    s.front.stop();
    s.server.stop();
}

#[test]
fn scheduling_fields_are_honored_over_http() {
    let s = start();
    let mut c = client(&s);

    // High priority accepted and counted per class.
    let body = format!(
        "{{\"x\": {}, \"priority\": \"high\"}}",
        json::Json::arr(activation(3).iter().map(|&v| json::Json::num(v as f64))).pretty()
    );
    let (status, _) = c.post_json("/v1/infer", &body).unwrap();
    assert_eq!(status, 200);

    // deadline_ms: 0 is already expired at enqueue → 504, never computed.
    let body = format!(
        "{{\"x\": {}, \"deadline_ms\": 0}}",
        json::Json::arr(activation(4).iter().map(|&v| json::Json::num(v as f64))).pretty()
    );
    let (status, body) = c.post_json("/v1/infer", &body).unwrap();
    assert_eq!(status, 504, "body: {body}");
    let err = json::parse(&body).unwrap();
    assert_eq!(err.get("error").get("kind").as_str(), Some("deadline_expired"));

    let (_, body) = c.get("/v1/metrics").unwrap();
    let m = json::parse(&body).unwrap();
    assert_eq!(m.get("priorities").get("high").as_usize(), Some(1));
    assert_eq!(m.get("expired").get("at_enqueue").as_usize(), Some(1));
    drop(c);
    s.front.stop();
    s.server.stop();
}

#[test]
fn error_statuses_are_mapped() {
    let s = start();
    let mut c = client(&s);

    // Unknown route → 404.
    let (status, _) = c.get("/nope").unwrap();
    assert_eq!(status, 404);

    // Wrong method on a known route → 405.
    let (status, _) = c.get("/v1/infer").unwrap();
    assert_eq!(status, 405);
    let (status, _) = c.post_json("/healthz", "{}").unwrap();
    assert_eq!(status, 405);

    // Unparseable JSON → 400.
    let (status, body) = c.post_json("/v1/infer", "{not json").unwrap();
    assert_eq!(status, 400);
    assert_eq!(
        json::parse(&body).unwrap().get("error").get("kind").as_str(),
        Some("bad_json")
    );

    // Parseable JSON but missing "x" → 400.
    let (status, _) = c.post_json("/v1/infer", "{\"y\": [1]}").unwrap();
    assert_eq!(status, 400);

    // Wrong activation length → 400 from the engine's validation.
    let (status, body) = c.post_json("/v1/infer", &infer_body(&[1.0, 2.0])).unwrap();
    assert_eq!(status, 400, "body: {body}");
    assert_eq!(
        json::parse(&body).unwrap().get("error").get("kind").as_str(),
        Some("bad_request")
    );
    drop(c);
    s.front.stop();
    s.server.stop();
}

#[test]
fn stopped_engine_maps_to_503() {
    let s = start();
    let mut c = client(&s);
    s.server.stop();
    let (status, body) = c.post_json("/v1/infer", &infer_body(&activation(9))).unwrap();
    assert_eq!(status, 503, "body: {body}");
    assert_eq!(
        json::parse(&body).unwrap().get("error").get("kind").as_str(),
        Some("server_stopped")
    );
    drop(c);
    s.front.stop();
}
