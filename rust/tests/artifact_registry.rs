//! Artifact round-trip suite (DESIGN.md §18): build → save → load must be
//! **bit-identical** for every catalog model under both value formats, and
//! every corruption class must surface as its own typed `ArtifactError` —
//! never a panic. Also pins the CLI composition rule: `--model-dir`
//! rejects `--pipeline-stages`/`--backend pjrt` with a clear startup
//! error, and `hinm build` → `hinm serve --model-dir` works end to end.

use hinm::models::{serving_models, ActivationBuffers};
use hinm::runtime::artifact::{encode_parts, load_from_parts};
use hinm::runtime::{save_artifact, load_artifact, ArtifactError, Provenance};
use hinm::spmm::{SpmmEngine, ValueFormat};
use hinm::tensor::Matrix;
use hinm::util::json::{self, Json};
use hinm::util::rng::Xoshiro256;
use std::path::PathBuf;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("hinm-artreg-{tag}-{}", std::process::id()))
}

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

/// Tentpole acceptance: every `serving_models` catalog entry survives a
/// disk round-trip bit-exactly, for f32 and bf16 plans alike.
#[test]
fn catalog_round_trips_bit_identical_for_f32_and_bf16() {
    let dir = tmp("catalog");
    let _ = std::fs::remove_dir_all(&dir);
    let engine = SpmmEngine::new(2);
    for fmt in [ValueFormat::F32, ValueFormat::Bf16] {
        let sub = dir.join(fmt.as_str());
        for (name, model) in serving_models(7).expect("catalog") {
            let model = model.with_value_format(fmt);
            let prov = Provenance { tool: "test".into(), seed: Some(7), note: None };
            let path = save_artifact(&sub, name, 1, &model, &prov)
                .unwrap_or_else(|e| panic!("save {name}/{}: {e}", fmt.as_str()));
            let loaded = load_artifact(&path)
                .unwrap_or_else(|e| panic!("load {name}/{}: {e}", fmt.as_str()));

            assert_eq!(loaded.manifest.name, name);
            assert_eq!(loaded.manifest.value_format, fmt);
            assert_eq!(loaded.model.value_format(), fmt);
            assert_eq!(loaded.model.layers(), model.layers(), "{name}: packed bits differ");

            // Planned forward through the loaded model must match the
            // in-process build bit-for-bit on a multi-column batch.
            let mut rng = Xoshiro256::new(0x5EED);
            let x = Matrix::randn(model.d_in(), 3, 1.0, &mut rng);
            let mut b0 = ActivationBuffers::new();
            let mut b1 = ActivationBuffers::new();
            let y0 = model.forward_planned(&x, &engine, &mut b0);
            let y1 = loaded.model.forward_planned(&x, &engine, &mut b1);
            assert_eq!(bits(&y0), bits(&y1), "{name} [{}]: outputs diverged", fmt.as_str());
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Every corruption class yields its own typed error, and none panics:
/// truncation, bit rot, schema skew, manifest/payload shape disagreement,
/// and outright garbage.
#[test]
fn corruption_matrix_yields_distinct_typed_errors() {
    let (name, model) = serving_models(7).expect("catalog").remove(0);
    let (text, payload) =
        encode_parts(name, 1, &model, &Provenance::default()).expect("encode");

    // Truncated payload → TruncatedPayload (length gate fires before the
    // checksum is even computed).
    let got = load_from_parts(&text, &payload[..payload.len() - 3]);
    assert!(
        matches!(got, Err(ArtifactError::TruncatedPayload { .. })),
        "truncation: {got:?}"
    );

    // One flipped payload byte → ChecksumMismatch.
    let mut flipped = payload.clone();
    flipped[payload.len() / 2] ^= 0x01;
    let got = load_from_parts(&text, &flipped);
    assert!(
        matches!(got, Err(ArtifactError::ChecksumMismatch { .. })),
        "bit rot: {got:?}"
    );

    // Future schema version → UnknownSchemaVersion, weights never touched.
    let skew = text.replace("\"schema_version\": 1", "\"schema_version\": 2");
    assert_ne!(skew, text, "replacement must hit");
    let got = load_from_parts(&skew, &payload);
    assert!(
        matches!(got, Err(ArtifactError::UnknownSchemaVersion { found: 2, .. })),
        "schema skew: {got:?}"
    );

    // Manifest whose layer shapes disagree with its own payload_bytes →
    // ShapeMismatch (mutated structurally via the JSON tree, not text).
    let mut doc = json::parse(&text).expect("manifest parses");
    if let Json::Obj(o) = &mut doc {
        if let Some(Json::Arr(layers)) = o.get_mut("layers") {
            if let Some(Json::Obj(l0)) = layers.get_mut(0) {
                let rows = l0.get("rows").and_then(|r| r.as_usize()).expect("rows");
                let v = l0.get("v").and_then(|r| r.as_usize()).expect("v");
                l0.insert("rows".to_string(), Json::num((rows + v) as f64));
            }
        }
    }
    let got = load_from_parts(&doc.pretty(), &payload);
    assert!(
        matches!(got, Err(ArtifactError::ShapeMismatch(_))),
        "shape skew: {got:?}"
    );

    // Garbage → ManifestParse.
    let got = load_from_parts("]not json[", &payload);
    assert!(matches!(got, Err(ArtifactError::ManifestParse(_))), "garbage: {got:?}");
}

/// `--model-dir` and `--pipeline-stages`/`--backend pjrt` must reject at
/// startup with an error naming the offending flag — not serve something
/// half-configured.
#[test]
fn serve_model_dir_rejects_incompatible_flags() {
    let dir = tmp("flags");
    let _ = std::fs::remove_dir_all(&dir);
    let (name, model) = serving_models(3).expect("catalog").remove(0);
    save_artifact(&dir, name, 1, &model, &Provenance::default()).expect("save");
    let dir_s = dir.to_str().expect("utf8 temp dir");

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_hinm"))
        .args(["serve", "--model-dir", dir_s, "--pipeline-stages", "2", "--requests", "1"])
        .output()
        .expect("spawn hinm");
    assert!(!out.status.success(), "pipeline-stages composition must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--pipeline-stages"), "stderr: {err}");
    assert!(err.contains("--model-dir"), "stderr: {err}");

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_hinm"))
        .args(["serve", "--model-dir", dir_s, "--backend", "pjrt", "--requests", "1"])
        .output()
        .expect("spawn hinm");
    assert!(!out.status.success(), "pjrt composition must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--model-dir"), "stderr: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// CLI end-to-end: `hinm build` writes artifacts, `hinm serve --model-dir`
/// scans them and completes a closed-loop demo against the default model.
#[test]
fn build_then_serve_demo_round_trips() {
    let dir = tmp("e2e");
    let _ = std::fs::remove_dir_all(&dir);
    let dir_s = dir.to_str().expect("utf8 temp dir");

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_hinm"))
        .args(["build", "--out", dir_s, "--models", "ffn-relu", "--seed", "9"])
        .output()
        .expect("spawn hinm build");
    assert!(
        out.status.success(),
        "build failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(dir.join("ffn-relu-v1.json").exists());
    assert!(dir.join("ffn-relu-v1.bin").exists());

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_hinm"))
        .args([
            "serve", "--model-dir", dir_s, "--requests", "8", "--clients", "2", "--batch", "2",
            "--replicas", "1",
        ])
        .output()
        .expect("spawn hinm serve");
    assert!(
        out.status.success(),
        "serve failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("default model: ffn-relu"), "stdout: {stdout}");
    assert!(stdout.contains("served 8 requests"), "stdout: {stdout}");
    let _ = std::fs::remove_dir_all(&dir);
}
