//! Deterministic structure-aware fuzz smoke for the stage-link frame
//! codec (`net::stage_wire`, DESIGN.md §20) — the fifth harness in the
//! family (`fuzz_json`, `fuzz_plan`, `fuzz_artifact`, `fuzz_http`).
//!
//! Three families, fully deterministic from `mix_seed(BASE_SEED,
//! case_index)`:
//!
//! 1. **Well-formed frames** encoded by [`FrameCodec`] itself, carrying
//!    arbitrary f32 *bit patterns* (NaNs, signed zeros, denormals): must
//!    decode to exactly the generated metadata with a bit-identical
//!    payload, consuming exactly the frame's bytes.
//! 2. **Schema violations** hand-built with a correct checksum around the
//!    lie (wrong version, unknown kind, nonzero reserved byte, dims that
//!    disagree with the payload, out-of-range length prefixes, flipped
//!    checksum trailers, non-UTF-8 error payloads): must fail with
//!    `InvalidData`/`UnexpectedEof` — kinds the §19 classifier maps to
//!    `Protocol`/`Unreachable`, never `TimedOut` (a parse error must not
//!    masquerade as a slow host).
//! 3. **Mutations** of family-1 bytes (truncation, bit flips, rewritten
//!    length prefixes, appended garbage): must never panic; anything that
//!    still decodes must satisfy the dims×payload invariant.
//!
//! Families 2–3 additionally replay over a **real TCP socket pair**
//! (`fuzz_stage_wire_over_socket_pair`), write half shut down after the
//! bytes: exactly the "peer died mid-frame" shape the head sees, pinning
//! that truncation surfaces as `UnexpectedEof` through real socket reads
//! too — skipped under Miri, which has no sockets; family 1 streams many
//! frames through one persistent connection like a live link.
//!
//! Iteration budget: `HINM_FUZZ_ITERS` (default 10 000 in-memory, 2 000
//! over sockets; CI `fuzz-long` raises it under an `HINM_FUZZ_SECONDS`
//! wall-clock bound). Failing inputs land in `target/fuzz-failures/`.

use hinm::net::route::{classify_upstream, UpstreamClass};
use hinm::net::stage_wire::{
    Frame, FrameCodec, KIND_ACTIVATIONS, KIND_ERROR, MAX_FRAME_BYTES, STAGE_WIRE_VERSION,
};
use hinm::runtime::artifact::fnv1a64;
use hinm::tensor::Matrix;
use hinm::util::rng::{mix_seed, Xoshiro256};
use std::io::{self, Write};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

const BASE_SEED: u64 = 0x5354_4147_4557; // "STAGEW"

fn iters(default: usize) -> usize {
    if cfg!(miri) {
        return 64;
    }
    std::env::var("HINM_FUZZ_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn budget() -> Option<Duration> {
    std::env::var("HINM_FUZZ_SECONDS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_secs)
}

fn persist_failure(case: u64, bytes: &[u8]) -> String {
    let dir = std::env::var("HINM_FUZZ_ARTIFACTS")
        .unwrap_or_else(|_| "target/fuzz-failures".to_string());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/stage-wire-case{case}.bin");
    let _ = std::fs::write(&path, bytes);
    path
}

/// Decode one frame from an in-memory reader with a fresh codec,
/// returning the result plus the matrix and the number of bytes left
/// unconsumed.
fn decode(bytes: &[u8]) -> (io::Result<Frame>, Matrix, usize) {
    let mut codec = FrameCodec::new();
    let mut out = Matrix::zeros(0, 0);
    let mut r = bytes;
    let res = codec.read_into(&mut r, &mut out);
    let left = r.len();
    (res, out, left)
}

/// What a family-1 frame must decode back to.
enum Expect {
    Act { seq: u64, rows: usize, cols: usize, bits: Vec<u32> },
    Err { seq: u64, message: String },
}

/// A frame encoded by the production codec itself, with payload bits
/// drawn from the whole f32 space (the wire moves bit patterns, not
/// values — NaN payloads and -0.0 must survive).
fn gen_valid(rng: &mut Xoshiro256) -> (Vec<u8>, Expect) {
    let seq = rng.next_u64();
    let mut codec = FrameCodec::new();
    let mut buf = Vec::new();
    if rng.below(4) == 0 {
        let message: String =
            (0..rng.below(40)).map(|_| char::from(b' ' + rng.below(94) as u8)).collect();
        codec.write_error(&mut buf, seq, &message).expect("encode error frame");
        (buf, Expect::Err { seq, message })
    } else {
        let (rows, cols) = (1 + rng.below(8), 1 + rng.below(8));
        let bits: Vec<u32> = (0..rows * cols).map(|_| rng.next_u64() as u32).collect();
        let m = Matrix::from_vec(rows, cols, bits.iter().map(|&b| f32::from_bits(b)).collect());
        codec.write_activations(&mut buf, seq, &m).expect("encode activation frame");
        (buf, Expect::Act { seq, rows, cols, bits })
    }
}

/// `len ‖ header ‖ payload ‖ checksum` with the checksum computed over
/// whatever lie the header tells — isolating each validation rung from
/// the checksum rung below it.
fn raw_frame(version: u16, kind: u8, reserved: u8, seq: u64, rows: u32, cols: u32, payload: &[u8]) -> Vec<u8> {
    let mut body = Vec::with_capacity(20 + payload.len());
    body.extend_from_slice(&version.to_le_bytes());
    body.push(kind);
    body.push(reserved);
    body.extend_from_slice(&seq.to_le_bytes());
    body.extend_from_slice(&rows.to_le_bytes());
    body.extend_from_slice(&cols.to_le_bytes());
    body.extend_from_slice(payload);
    let ck = fnv1a64(&body);
    let mut frame = Vec::with_capacity(4 + body.len() + 8);
    frame.extend_from_slice(&((body.len() + 8) as u32).to_le_bytes());
    frame.extend_from_slice(&body);
    frame.extend_from_slice(&ck.to_le_bytes());
    frame
}

/// One schema violation; every rung of the decoder's validation ladder
/// has at least one generator here.
fn gen_violation(rng: &mut Xoshiro256) -> Vec<u8> {
    let seq = rng.next_u64();
    let payload = [0u8; 8]; // two f32s
    match rng.below(9) {
        // Wrong version, everything else pristine.
        0 => raw_frame(STAGE_WIRE_VERSION + 1 + rng.below(9) as u16, KIND_ACTIVATIONS, 0, seq, 1, 2, &payload),
        // Unknown kind.
        1 => raw_frame(STAGE_WIRE_VERSION, 2 + rng.below(200) as u8, 0, seq, 1, 2, &payload),
        // Reserved byte set.
        2 => raw_frame(STAGE_WIRE_VERSION, KIND_ACTIVATIONS, 1 + rng.below(255) as u8, seq, 1, 2, &payload),
        // Dims disagree with the payload (including overflowing products).
        3 => {
            if rng.below(2) == 0 {
                raw_frame(STAGE_WIRE_VERSION, KIND_ACTIVATIONS, 0, seq, 3, 3, &payload)
            } else {
                raw_frame(STAGE_WIRE_VERSION, KIND_ACTIVATIONS, 0, seq, u32::MAX, u32::MAX, &payload)
            }
        }
        // Error frames must carry zero dims.
        4 => raw_frame(STAGE_WIRE_VERSION, KIND_ERROR, 0, seq, 1, 0, b"oops"),
        // Flip one checksum trailer byte on an otherwise valid frame.
        5 => {
            let mut f = raw_frame(STAGE_WIRE_VERSION, KIND_ACTIVATIONS, 0, seq, 1, 2, &payload);
            let n = f.len();
            f[n - 1 - rng.below(8)] ^= 1 << rng.below(8);
            f
        }
        // Length prefix below the minimum body size.
        6 => {
            let mut f = (rng.below(28) as u32).to_le_bytes().to_vec();
            f.extend_from_slice(&[0u8; 32]);
            f
        }
        // Length prefix above the 64 MB cap.
        7 => ((MAX_FRAME_BYTES + 1 + rng.below(1 << 20)) as u32).to_le_bytes().to_vec(),
        // Error frame whose message is not UTF-8.
        _ => raw_frame(STAGE_WIRE_VERSION, KIND_ERROR, 0, seq, 0, 0, &[0xFF, 0xFE, 0x80, 0x80]),
    }
}

/// Mutate valid bytes: truncate, flip a bit, rewrite the length prefix,
/// or append garbage.
fn mutate(rng: &mut Xoshiro256, mut bytes: Vec<u8>) -> Vec<u8> {
    match rng.below(4) {
        0 => {
            let keep = rng.below(bytes.len());
            bytes.truncate(keep);
        }
        1 => {
            let pos = rng.below(bytes.len());
            bytes[pos] ^= 1 << rng.below(8);
        }
        2 => {
            let lie = (rng.next_u64() as u32).to_le_bytes();
            bytes[..4].copy_from_slice(&lie);
        }
        _ => {
            for _ in 0..1 + rng.below(16) {
                bytes.push(rng.next_u64() as u8);
            }
        }
    }
    bytes
}

/// A decode error must carry a kind the §19 classifier reads as a dead
/// peer or a desynced stream — never as a slow one.
fn assert_error_kind(case: u64, bytes: &[u8], err: &io::Error) {
    let class = classify_upstream(err.kind());
    if class == UpstreamClass::TimedOut {
        let path = persist_failure(case, bytes);
        panic!("case {case}: decode error {err:?} classified TimedOut (input: {path})");
    }
}

/// In-memory sweep over all three families; under Miri this is the whole
/// harness (64 cases).
#[test]
fn fuzz_stage_wire_decoder_never_panics_and_round_trips() {
    let n = iters(10_000);
    let deadline = budget().map(|b| Instant::now() + b);
    let mut done = 0u64;
    for case in 0..n as u64 {
        if let Some(d) = deadline {
            if Instant::now() >= d {
                break;
            }
        }
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED, case));
        match case % 3 {
            0 => {
                let (bytes, expect) = gen_valid(&mut rng);
                let (res, m, left) = decode(&bytes);
                let frame = match res {
                    Ok(f) => f,
                    Err(e) => {
                        let path = persist_failure(case, &bytes);
                        panic!("case {case}: valid frame rejected: {e} (input: {path})");
                    }
                };
                assert_eq!(left, 0, "case {case}: valid frame not fully consumed");
                match expect {
                    Expect::Act { seq, rows, cols, bits } => {
                        assert_eq!(frame, Frame::Activations { seq }, "case {case}");
                        assert_eq!(m.shape(), (rows, cols), "case {case}");
                        let got: Vec<u32> = m.data.iter().map(|v| v.to_bits()).collect();
                        assert_eq!(got, bits, "case {case}: payload bits changed");
                    }
                    Expect::Err { seq, message } => {
                        assert_eq!(frame, Frame::Error { seq, message }, "case {case}");
                    }
                }
            }
            1 => {
                let bytes = gen_violation(&mut rng);
                let (res, _, _) = decode(&bytes);
                match res {
                    Ok(f) => {
                        let path = persist_failure(case, &bytes);
                        panic!("case {case}: violation decoded as {f:?} (input: {path})");
                    }
                    Err(e) => assert_error_kind(case, &bytes, &e),
                }
            }
            _ => {
                let (valid, _) = gen_valid(&mut rng);
                let bytes = mutate(&mut rng, valid);
                let outcome = catch_unwind(AssertUnwindSafe(|| decode(&bytes)));
                match outcome {
                    Ok((Ok(_), m, _)) => {
                        let (r, c) = m.shape();
                        assert_eq!(r * c, m.data.len(), "case {case}: dims×payload invariant");
                    }
                    Ok((Err(e), _, _)) => assert_error_kind(case, &bytes, &e),
                    Err(_) => {
                        let path = persist_failure(case, &bytes);
                        panic!("case {case}: decoder panicked (input: {path})");
                    }
                }
            }
        }
        done += 1;
    }
    assert!(done > 0, "fuzz budget expired before the first case");
    println!("fuzz_stage_wire in-memory: {done} cases");
}

/// The same families over real sockets: family 1 streams frame after
/// frame through one persistent connection (a live link's shape); each
/// family-2/3 case gets its own connection with the write half shut down
/// after the bytes, so truncation arrives exactly as a dead peer does.
#[test]
#[cfg_attr(miri, ignore)] // Miri has no sockets; family coverage lives in the in-memory sweep
fn fuzz_stage_wire_over_socket_pair() {
    use std::net::{Shutdown, TcpListener, TcpStream};

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind fuzz listener");
    let addr = listener.local_addr().expect("listener addr");
    let pair = || {
        let tx = TcpStream::connect(addr).expect("connect");
        let (rx, _) = listener.accept().expect("accept");
        // Belt over suspenders: every failure mode here ends in EOF or a
        // parse error, but a decoder bug must fail the case, not hang it.
        rx.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
        (tx, rx)
    };

    // The persistent family-1 link and both its codec ends.
    let (mut link_tx, mut link_rx) = pair();
    let mut enc = FrameCodec::new();
    let mut dec = FrameCodec::new();
    let mut out = Matrix::zeros(0, 0);

    let n = iters(2_000);
    let deadline = budget().map(|b| Instant::now() + b);
    let mut done = 0u64;
    for case in 0..n as u64 {
        if let Some(d) = deadline {
            if Instant::now() >= d {
                break;
            }
        }
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED ^ 0x50_41_49_52, case));
        match case % 3 {
            0 => {
                let seq = rng.next_u64();
                let (rows, cols) = (1 + rng.below(8), 1 + rng.below(8));
                let bits: Vec<u32> = (0..rows * cols).map(|_| rng.next_u64() as u32).collect();
                let m =
                    Matrix::from_vec(rows, cols, bits.iter().map(|&b| f32::from_bits(b)).collect());
                enc.write_activations(&mut link_tx, seq, &m).expect("send over link");
                let frame = dec.read_into(&mut link_rx, &mut out).expect("decode over link");
                assert_eq!(frame, Frame::Activations { seq }, "case {case}");
                assert_eq!(out.shape(), (rows, cols), "case {case}");
                let got: Vec<u32> = out.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, bits, "case {case}: bits changed crossing the socket");
            }
            family => {
                let bytes = if family == 1 {
                    gen_violation(&mut rng)
                } else {
                    let (valid, _) = gen_valid(&mut rng);
                    mutate(&mut rng, valid)
                };
                let (mut tx, mut rx) = pair();
                tx.write_all(&bytes).expect("send case bytes");
                tx.shutdown(Shutdown::Write).expect("half-close");
                let mut codec = FrameCodec::new();
                let mut m = Matrix::zeros(0, 0);
                let res = codec.read_into(&mut rx, &mut m);
                match res {
                    Ok(f) => {
                        if family == 1 {
                            let path = persist_failure(case, &bytes);
                            panic!("case {case}: violation decoded as {f:?} over socket (input: {path})");
                        }
                        let (r, c) = m.shape();
                        assert_eq!(r * c, m.data.len(), "case {case}: dims×payload invariant");
                    }
                    Err(e) => assert_error_kind(case, &bytes, &e),
                }
            }
        }
        done += 1;
    }
    assert!(done > 0, "fuzz budget expired before the first case");
    println!("fuzz_stage_wire socket pair: {done} cases");
}
