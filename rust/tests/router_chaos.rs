//! Chaos tests for the `hinm route` tier (DESIGN.md §19): a real
//! `Router` + `RouterFront` over scripted `FaultyBackend` downstreams,
//! driven over real sockets.
//!
//! The headline test replays a seeded fault schedule — one always-stalling
//! backend, one always-500ing backend, one healthy — and asserts the
//! router's hedge/retry/breaker counters to *exact* values in both metric
//! formats: every delay in the router is either a socket timeout or a
//! seeded jitter, so a fixed schedule yields fixed counts. Roles are
//! assigned to backends by the router's own exported consistent-hash
//! preference order, which makes the expected counts independent of which
//! ephemeral port each backend happens to bind.

use hinm::coordinator::router::{consistent_rank, model_key};
use hinm::coordinator::{BatchServer, Router, RouterConfig, ServeConfig};
use hinm::models::{Activation, HinmModel};
use hinm::net::route::Fault;
use hinm::net::{protocol, FaultyBackend, HttpClient, HttpFront, RouterFront};
use hinm::sparsity::HinmConfig;
use hinm::util::json;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

/// Router tuned so the test controls every timer: probers effectively
/// off, hedge delay pinned (floor == ceil), short per-try timeout, trip
/// after 2 consecutive failures, tripped backends stay down for the whole
/// test.
fn chaos_cfg() -> RouterConfig {
    RouterConfig {
        probe_interval_ms: 60_000,
        probe_timeout_ms: 100,
        fail_threshold: 2,
        backoff_base_ms: 60_000,
        backoff_max_ms: 60_000,
        retry_backoff_ms: 1,
        hedge_floor_ms: 40,
        hedge_ceil_ms: 40,
        connect_timeout_ms: 200,
        per_try_timeout_ms: 150,
        max_attempts: 3,
        max_inflight: 64,
        drain_ms: 1000,
        seed: 11,
    }
}

fn attempt_header(headers: &[(String, String)]) -> Option<&str> {
    headers.iter().find(|(k, _)| k == "x-hinm-attempt").map(|(_, v)| v.as_str())
}

#[test]
fn seeded_fault_schedule_replays_to_exact_metric_counts() {
    let cfg = chaos_cfg();
    // The router tries backends in consistent-rank order when in-flight
    // counts tie; compute that order and assign roles by it, so the
    // request flow is: first try → staller, hedge → failer, retry →
    // healthy, regardless of port assignment.
    let key = model_key(None);
    let mut order: Vec<usize> = vec![0, 1, 2];
    order.sort_by_key(|&i| consistent_rank(cfg.seed, key, i));

    let staller = FaultyBackend::start(vec![Fault::Stall(10_000)]).expect("staller");
    let failer = FaultyBackend::start(vec![Fault::Http500]).expect("failer");
    let healthy = FaultyBackend::start(vec![Fault::Ok]).expect("healthy");

    let mut slots: Vec<Option<(String, SocketAddr)>> = vec![None, None, None];
    slots[order[0]] = Some(("staller".to_string(), staller.addr()));
    slots[order[1]] = Some(("failer".to_string(), failer.addr()));
    slots[order[2]] = Some(("healthy".to_string(), healthy.addr()));
    let backends: Vec<(String, SocketAddr)> =
        slots.into_iter().map(|s| s.expect("all slots assigned")).collect();

    let router = Router::start(backends, cfg).expect("router start");
    let front =
        RouterFront::start("127.0.0.1:0", Arc::clone(&router), 4).expect("router front");
    let mut client = HttpClient::connect(front.local_addr()).expect("connect");

    // 6 sequential requests. Requests 1–2: first try stalls (books a
    // timeout at 150 ms), the 40 ms hedge hits the failer (books a 500),
    // the retry lands on the healthy backend → 3 attempts, 200. The
    // second round trips both bad backends (fail_threshold = 2).
    // Requests 3–6: straight to the healthy backend, 1 attempt each.
    const N: usize = 6;
    for i in 0..N {
        let (status, headers, body) = client
            .request_with_headers("POST", "/v1/infer", Some("{\"x\":[0.0]}"))
            .expect("routed request");
        assert_eq!(status, 200, "request {i}: {body}");
        assert_eq!(body, "{\"y\":[0.25,-0.5,1.0]}", "request {i}: downstream body verbatim");
        let expect_attempts = if i < 2 { "3" } else { "1" };
        assert_eq!(
            attempt_header(&headers),
            Some(expect_attempts),
            "request {i}: X-Hinm-Attempt"
        );
        // Let the abandoned stalled attempt book its timeout before the
        // next request dispatches (150 ms per-try < 300 ms settle).
        std::thread::sleep(Duration::from_millis(300));
    }

    // Exact counters, JSON format.
    let (status, body) = client.get("/v1/metrics").expect("metrics json");
    assert_eq!(status, 200);
    let doc = json::parse(&body).expect("metrics parse");
    assert_eq!(doc.get("requests").as_f64(), Some(6.0), "admitted requests: {body}");
    assert_eq!(doc.get("hedges").as_f64(), Some(2.0), "hedges: {body}");
    assert_eq!(doc.get("retries").as_f64(), Some(2.0), "retries: {body}");
    assert_eq!(doc.get("breaker_trips").as_f64(), Some(2.0), "trips: {body}");
    assert_eq!(doc.get("rejected").as_f64(), Some(0.0), "rejected: {body}");
    let backends_json = doc.get("backends").as_arr().expect("backends array");
    assert_eq!(backends_json.len(), 3);
    for b in backends_json {
        let name = b.get("name").as_str().expect("backend name");
        let state = b.get("state").as_str().expect("backend state");
        match name {
            "staller" | "failer" => {
                assert_eq!(state, "down", "{name} tripped: {body}");
                assert_eq!(b.get("failures").as_f64(), Some(2.0), "{name} failures: {body}");
                assert_eq!(b.get("requests").as_f64(), Some(0.0), "{name} successes: {body}");
            }
            "healthy" => {
                assert_eq!(state, "up", "healthy stays up: {body}");
                assert_eq!(b.get("failures").as_f64(), Some(0.0));
                assert_eq!(b.get("requests").as_f64(), Some(6.0), "healthy served all: {body}");
            }
            other => panic!("unexpected backend {other:?}"),
        }
    }

    // Same counters, Prometheus text exposition.
    let (status, text) = client.get("/v1/metrics?format=prometheus").expect("metrics prom");
    assert_eq!(status, 200);
    for needle in [
        "hinm_router_requests_total 6",
        "hinm_router_hedges_total 2",
        "hinm_router_retries_total 2",
        "hinm_router_breaker_trips_total 2",
        "hinm_router_rejected_total 0",
        "hinm_router_backend_state{backend=\"staller\",state=\"down\"} 1",
        "hinm_router_backend_state{backend=\"failer\",state=\"down\"} 1",
        "hinm_router_backend_state{backend=\"healthy\",state=\"up\"} 1",
        "hinm_router_backend_requests_total{backend=\"healthy\"} 6",
        "hinm_router_backend_failures_total{backend=\"staller\"} 2",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }

    drop(client);
    front.stop();
    staller.stop();
    failer.stop();
    healthy.stop();
}

#[test]
fn concurrent_deadlined_clients_see_only_success_or_deadline() {
    // One stalling backend, one flapping (alternating reset/ok), one
    // healthy. Concurrent clients with explicit deadlines must never see
    // a failure that isn't the deadline itself: hedges and retries absorb
    // the stalls, resets, and breaker churn.
    let cfg = RouterConfig {
        probe_interval_ms: 100,
        probe_timeout_ms: 100,
        fail_threshold: 2,
        backoff_base_ms: 100,
        backoff_max_ms: 200,
        retry_backoff_ms: 1,
        hedge_floor_ms: 30,
        hedge_ceil_ms: 30,
        connect_timeout_ms: 200,
        per_try_timeout_ms: 100,
        max_attempts: 3,
        max_inflight: 64,
        drain_ms: 1000,
        seed: 5,
    };
    let staller = FaultyBackend::start(vec![Fault::Stall(10_000)]).expect("staller");
    let flapper = FaultyBackend::start(
        (0..40).map(|i| if i % 2 == 0 { Fault::Reset } else { Fault::Ok }).collect(),
    )
    .expect("flapper");
    let healthy = FaultyBackend::start(vec![Fault::Ok]).expect("healthy");
    let router = Router::start(
        vec![
            ("staller".to_string(), staller.addr()),
            ("flapper".to_string(), flapper.addr()),
            ("healthy".to_string(), healthy.addr()),
        ],
        cfg,
    )
    .expect("router start");
    let front =
        RouterFront::start("127.0.0.1:0", Arc::clone(&router), 8).expect("router front");
    let addr = front.local_addr();

    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 4;
    let statuses: Vec<u16> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|_| {
                s.spawn(move || {
                    let mut c = HttpClient::connect(addr).expect("connect");
                    let mut out = Vec::with_capacity(PER_CLIENT);
                    for _ in 0..PER_CLIENT {
                        let (status, body) = c
                            .post_json("/v1/infer", "{\"x\":[0.0],\"deadline_ms\":800}")
                            .expect("routed request");
                        assert!(
                            status == 200 || status == 504,
                            "only success or deadline allowed, got {status}: {body}"
                        );
                        out.push(status);
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
    });
    assert_eq!(statuses.len(), CLIENTS * PER_CLIENT);
    let ok = statuses.iter().filter(|&&s| s == 200).count();
    assert!(ok > 0, "the healthy backend must serve some requests: {statuses:?}");

    front.stop();
    staller.stop();
    flapper.stop();
    healthy.stop();
}

#[test]
fn prober_trips_and_recovers_a_flapping_backend() {
    // Active probing alone (no client traffic) must walk the breaker
    // Up → Degraded → Down → HalfOpen → Up on a backend that answers two
    // 500s and then recovers.
    let cfg = RouterConfig {
        probe_interval_ms: 50,
        probe_timeout_ms: 300,
        fail_threshold: 2,
        backoff_base_ms: 50,
        backoff_max_ms: 100,
        retry_backoff_ms: 1,
        hedge_floor_ms: 10,
        hedge_ceil_ms: 10,
        connect_timeout_ms: 200,
        per_try_timeout_ms: 200,
        max_attempts: 2,
        max_inflight: 8,
        drain_ms: 500,
        seed: 3,
    };
    let b = FaultyBackend::start(vec![Fault::Http500, Fault::Http500, Fault::Ok])
        .expect("backend");
    let router =
        Router::start(vec![("flapper".to_string(), b.addr())], cfg).expect("router start");

    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    // Trips are monotonic, so poll for the trip rather than the transient
    // Down state.
    while router.snapshot().breaker_trips < 1 {
        assert!(std::time::Instant::now() < deadline, "breaker never tripped");
        std::thread::sleep(Duration::from_millis(5));
    }
    // After the cooldown a half-open probe hits the recovered backend.
    loop {
        let snap = router.snapshot();
        if snap.backends[0].health == hinm::coordinator::BackendHealth::Up {
            assert_eq!(snap.breaker_trips, 1, "exactly one trip for the 500/500/ok script");
            assert_eq!(snap.backends[0].failures, 2);
            break;
        }
        assert!(std::time::Instant::now() < deadline, "backend never recovered: {snap:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
    router.stop();
    b.stop();
}

#[test]
fn over_capacity_requests_get_503_with_retry_after() {
    let cfg = RouterConfig {
        probe_interval_ms: 60_000,
        probe_timeout_ms: 100,
        fail_threshold: 3,
        backoff_base_ms: 1000,
        backoff_max_ms: 1000,
        retry_backoff_ms: 1,
        hedge_floor_ms: 2000,
        hedge_ceil_ms: 2000,
        connect_timeout_ms: 200,
        per_try_timeout_ms: 3000,
        max_attempts: 1,
        max_inflight: 1,
        drain_ms: 2000,
        seed: 2,
    };
    let slow = FaultyBackend::start(vec![Fault::Stall(800)]).expect("slow backend");
    let router =
        Router::start(vec![("slow".to_string(), slow.addr())], cfg).expect("router start");
    let front =
        RouterFront::start("127.0.0.1:0", Arc::clone(&router), 4).expect("router front");
    let addr = front.local_addr();

    std::thread::scope(|s| {
        let occupant = s.spawn(move || {
            let mut c = HttpClient::connect(addr).expect("connect occupant");
            c.request_with_headers("POST", "/v1/infer", Some("{\"x\":[0.0]}"))
                .expect("occupant request")
        });
        // Let the occupant claim the single admission slot, then overflow.
        std::thread::sleep(Duration::from_millis(150));
        let mut c = HttpClient::connect(addr).expect("connect overflow");
        let (status, headers, body) = c
            .request_with_headers("POST", "/v1/infer", Some("{\"x\":[0.0]}"))
            .expect("overflow request");
        assert_eq!(status, 503, "over capacity: {body}");
        assert!(body.contains("busy"), "body names the condition: {body}");
        let retry_after =
            headers.iter().find(|(k, _)| k == "retry-after").map(|(_, v)| v.as_str());
        assert_eq!(retry_after, Some("1"), "Retry-After advertised");

        let (status, h, body) = occupant.join().expect("occupant thread");
        assert_eq!(status, 200, "occupant completes after the stall: {body}");
        assert_eq!(attempt_header(&h), Some("1"));
    });

    let snap = router.snapshot();
    assert_eq!(snap.requests, 1, "one admitted");
    assert_eq!(snap.rejected, 1, "one shed");

    front.stop();
    slow.stop();
}

#[test]
fn routed_responses_are_bit_identical_to_direct_ones() {
    // A real engine + HTTP front as the downstream: the response body a
    // client sees through the router must be byte-identical to the one it
    // gets talking to the backend directly; the router adds only the
    // X-Hinm-Attempt header.
    const D: usize = 32;
    let hcfg = HinmConfig::with_24(8, 0.5);
    let model =
        Arc::new(HinmModel::synthetic_ffn(D, 64, &hcfg, Activation::Relu, 17).expect("model"));
    let server = BatchServer::start_native(
        Arc::clone(&model),
        ServeConfig::new(4, Duration::from_millis(2)).with_replicas(2),
    )
    .expect("engine start");
    let backend_front =
        HttpFront::start("127.0.0.1:0", server.handle.clone(), None, None, 4).expect("front");

    let cfg = RouterConfig { probe_interval_ms: 60_000, ..RouterConfig::default() };
    let router = Router::start(
        vec![("real".to_string(), backend_front.local_addr())],
        cfg,
    )
    .expect("router start");
    let rfront =
        RouterFront::start("127.0.0.1:0", Arc::clone(&router), 4).expect("router front");

    let x: Vec<f32> = (0..D).map(|i| ((i * 7 + 3) % 13) as f32 * 0.1 - 0.6).collect();
    let body = protocol::InferRequest::new(x).to_json().pretty();

    let mut direct = HttpClient::connect(backend_front.local_addr()).expect("direct connect");
    let (direct_status, direct_body) =
        direct.post_json("/v1/infer", &body).expect("direct request");
    assert_eq!(direct_status, 200, "direct: {direct_body}");

    let mut routed = HttpClient::connect(rfront.local_addr()).expect("routed connect");
    let (routed_status, headers, routed_body) = routed
        .request_with_headers("POST", "/v1/infer", Some(&body))
        .expect("routed request");
    assert_eq!(routed_status, 200, "routed: {routed_body}");
    assert_eq!(
        routed_body.as_bytes(),
        direct_body.as_bytes(),
        "router must relay downstream bytes verbatim"
    );
    assert_eq!(attempt_header(&headers), Some("1"));

    // The router's own discovery endpoints answer alongside the proxy.
    let (status, health) = routed.get("/healthz").expect("router healthz");
    assert_eq!(status, 200);
    let doc = json::parse(&health).expect("healthz parse");
    assert_eq!(doc.get("backends_total").as_f64(), Some(1.0), "{health}");
    let (status, models) = routed.get("/v1/models").expect("router models");
    assert_eq!(status, 200);
    assert!(json::parse(&models).expect("models parse").get("models").as_arr().is_some());

    drop(direct);
    drop(routed);
    rfront.stop();
    backend_front.stop();
    server.stop();
}
