//! Pipeline-parallel serving semantics (DESIGN.md §15): bit-identity of
//! pipelined execution against `forward_planned` across the serving
//! catalog × stage counts × batch sizes, composition with the batch
//! server / cache / HTTP front, shutdown draining, per-batch stage
//! errors, and panic poisoning — mirroring the engine-level suite in
//! `tests/serve_engine.rs` over mock stages where backend independence
//! matters.

use anyhow::Result;
use hinm::coordinator::serve::{PipelineServer, PipelineStage};
use hinm::coordinator::{cached_factory, BatchServer, InferError, ServeConfig};
use hinm::models::chain::ActivationBuffers;
use hinm::models::{serving_models, HinmModel};
use hinm::net::{protocol, HttpClient, HttpFront};
use hinm::runtime::CacheStats;
use hinm::spmm::SpmmEngine;
use hinm::tensor::Matrix;
use hinm::util::json;
use hinm::util::rng::Xoshiro256;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

fn vec_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Reference output through the unsplit planned path.
fn planned(model: &HinmModel, x: &Matrix) -> Matrix {
    let engine = SpmmEngine::single();
    let mut bufs = ActivationBuffers::new();
    model.forward_planned(x, &engine, &mut bufs)
}

#[test]
fn pipelined_output_is_bit_identical_across_catalog_stages_and_batches() {
    for (name, model) in serving_models(7).unwrap() {
        let mut rng = Xoshiro256::new(11);
        for &batch in &[1usize, 7, 33] {
            let x = Matrix::randn(model.d_in(), batch, 1.0, &mut rng);
            let want = planned(&model, &x);
            let mut stage_counts: Vec<usize> =
                [1usize, 2, 4].iter().map(|&k| k.min(model.n_layers())).collect();
            stage_counts.dedup();
            for k in stage_counts {
                let ps = PipelineServer::start(&model, k, 1, 0).unwrap();
                assert_eq!(ps.n_stages(), k);
                let h = ps.handle();
                // Two rounds so the recycled hand-off buffers are hit.
                for round in 0..2 {
                    let got = h.infer_batch(&x).unwrap();
                    assert_eq!(got.shape(), (model.d_out(), batch));
                    assert_eq!(
                        bits(&got),
                        bits(&want),
                        "{name}: stages={k} batch={batch} round={round} changed bits"
                    );
                }
                ps.stop();
            }
        }
    }
}

#[test]
fn pipeline_backend_composes_with_batch_server_and_cache_bit_exactly() {
    let (_, model) =
        serving_models(7).unwrap().into_iter().find(|(n, _)| *n == "deit-mini").unwrap();
    let ps = PipelineServer::start(&model, 2, 1, 0).unwrap();
    let stats = CacheStats::new_shared();
    let factory = cached_factory(ps.backend_factory(), 8, Arc::clone(&stats));
    let server = BatchServer::start(
        factory,
        ServeConfig::new(1, Duration::from_millis(1)),
    )
    .unwrap();

    let xcol: Vec<f32> = (0..model.d_in()).map(|i| (i % 5) as f32 * 0.3 - 0.6).collect();
    let want = planned(&model, &Matrix::from_vec(model.d_in(), 1, xcol.clone()));
    let y1 = server.handle.infer(xcol.clone()).unwrap();
    assert_eq!(vec_bits(&y1), bits(&want), "pipelined engine response must match forward_planned");
    // Same activation again: the replica's cache answers without touching
    // the pipeline, bit-identically.
    let y2 = server.handle.infer(xcol).unwrap();
    assert_eq!(vec_bits(&y2), vec_bits(&y1));
    assert!(stats.hits() >= 1, "second identical request must hit the batch cache");
    server.stop();
    ps.stop();
}

#[test]
fn concurrent_replicas_keep_the_pipeline_busy_and_answers_correct() {
    let (_, model) =
        serving_models(7).unwrap().into_iter().find(|(n, _)| *n == "bert-mini").unwrap();
    let ps = PipelineServer::start(&model, 3, 1, 0).unwrap();
    let server = BatchServer::start(
        ps.backend_factory(),
        ServeConfig::new(2, Duration::from_millis(1)).with_replicas(4),
    )
    .unwrap();
    let handle = server.handle.clone();
    let d_in = model.d_in();
    std::thread::scope(|s| {
        for c in 0..16 {
            let h = handle.clone();
            let model = &model;
            s.spawn(move || {
                let xcol: Vec<f32> = (0..d_in).map(|i| ((c * 7 + i) % 9) as f32 * 0.1).collect();
                let want = planned(model, &Matrix::from_vec(d_in, 1, xcol.clone()));
                let y = h.infer(xcol).unwrap();
                assert_eq!(vec_bits(&y), bits(&want), "client {c} got a wrong answer");
            });
        }
    });
    assert_eq!(server.metrics.total_requests(), 16);
    server.stop();
    ps.stop();
}

#[test]
fn http_round_trip_over_the_pipeline_is_bit_exact() {
    let (_, model) =
        serving_models(7).unwrap().into_iter().find(|(n, _)| *n == "mixed-width").unwrap();
    let ps = PipelineServer::start(&model, 2, 1, 0).unwrap();
    let server = BatchServer::start(
        ps.backend_factory(),
        ServeConfig::new(2, Duration::from_millis(1)).with_replicas(2),
    )
    .unwrap();
    let front = HttpFront::start("127.0.0.1:0", server.handle.clone(), None, None, 2).unwrap();

    let xcol: Vec<f32> = (0..model.d_in()).map(|i| (i as f32) * 0.17 - 1.1).collect();
    let want = planned(&model, &Matrix::from_vec(model.d_in(), 1, xcol.clone()));
    let mut client = HttpClient::connect(front.local_addr()).unwrap();
    let body = protocol::InferRequest::new(xcol).to_json().compact();
    let (status, resp) = client.post_json("/v1/infer", &body).unwrap();
    assert_eq!(status, 200, "unexpected response: {resp}");
    let y = protocol::parse_infer_response(&json::parse(&resp).unwrap()).unwrap();
    assert_eq!(vec_bits(&y), bits(&want), "HTTP→engine→pipeline must round-trip bit-exactly");

    front.stop();
    server.stop();
    ps.stop();
}

// ---------------------------------------------------------------------------
// Mock stages: hand-off / shutdown / failure semantics without models.
// ---------------------------------------------------------------------------

const D: usize = 4;

/// `y = x + 1` elementwise (square stage), with optional delay, switchable
/// failure, and a panic trigger.
struct MockStage {
    delay: Duration,
    fail: Option<Arc<AtomicBool>>,
    panic_now: bool,
    calls: Arc<AtomicUsize>,
}

impl MockStage {
    fn ok(delay: Duration) -> Box<dyn PipelineStage> {
        Box::new(MockStage {
            delay,
            fail: None,
            panic_now: false,
            calls: Arc::new(AtomicUsize::new(0)),
        })
    }
}

impl PipelineStage for MockStage {
    fn d_in(&self) -> usize {
        D
    }

    fn d_out(&self) -> usize {
        D
    }

    fn run(&mut self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        if self.panic_now {
            panic!("stage exploded");
        }
        if let Some(f) = &self.fail {
            if f.load(Ordering::SeqCst) {
                anyhow::bail!("stage refused");
            }
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        out.rows = D;
        out.cols = x.cols;
        out.data.clear();
        out.data.resize(D * x.cols, 0.0);
        for (o, &v) in out.data.iter_mut().zip(&x.data) {
            *o = v + 1.0;
        }
        Ok(())
    }
}

#[test]
fn shutdown_drains_queued_batches_and_then_fails_new_submissions() {
    let stages = vec![
        MockStage::ok(Duration::from_millis(5)),
        MockStage::ok(Duration::from_millis(5)),
    ];
    let ps = PipelineServer::start_stages(stages, 8).unwrap();
    let h = ps.handle();
    let clients: Vec<_> = (0..6)
        .map(|i| {
            let h = h.clone();
            std::thread::spawn(move || {
                h.infer_batch(&Matrix::from_vec(D, 1, vec![i as f32; D])).map(|y| (i, y))
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(50)); // let them enqueue
    let t0 = Instant::now();
    ps.stop();
    assert!(t0.elapsed() < Duration::from_secs(5), "stop must not hang");
    for c in clients {
        let (i, y) = c
            .join()
            .unwrap()
            .expect("a batch queued before shutdown must still be answered");
        assert_eq!(y.data[0], i as f32 + 2.0, "two +1 stages");
    }
    // The pipeline is gone: new submissions fail fast.
    let err = h.infer_batch(&Matrix::zeros(D, 1)).unwrap_err();
    assert_eq!(err, InferError::Stopped);
}

#[test]
fn pipeline_shutdown_race_never_loses_a_response() {
    // Pinning test for the entry-link drain race documented on
    // `BoundedQueue::close` and `PipelineServer::stop` (DESIGN.md §20): a
    // batch submitted concurrently with shutdown must either complete
    // with the right answer or fail with the typed close error — never
    // hang, never a silently dropped response. Each round shifts the
    // stop() point relative to the submitters, covering
    // before/during/after interleavings.
    for round in 0..8u64 {
        let stages = vec![
            MockStage::ok(Duration::from_micros(200)),
            MockStage::ok(Duration::from_micros(200)),
        ];
        let ps = PipelineServer::start_stages(stages, 4).unwrap();
        let h = ps.handle();
        let submitters: Vec<_> = (0..8usize)
            .map(|c| {
                let h = h.clone();
                std::thread::spawn(move || {
                    let (mut ok, mut stopped) = (0usize, 0usize);
                    for i in 0..16usize {
                        let v = (c * 16 + i) as f32;
                        match h.infer_batch(&Matrix::from_vec(D, 1, vec![v; D])) {
                            Ok(y) => {
                                assert_eq!(y.data[0], v + 2.0, "two +1 stages");
                                ok += 1;
                            }
                            Err(InferError::Stopped) => stopped += 1,
                            Err(other) => panic!("shutdown race leaked error {other:?}"),
                        }
                    }
                    (ok, stopped)
                })
            })
            .collect();
        std::thread::sleep(Duration::from_micros(200 * round));
        let t0 = Instant::now();
        ps.stop();
        assert!(t0.elapsed() < Duration::from_secs(10), "stop must not hang");
        let (mut total_ok, mut total_stopped) = (0, 0);
        for s in submitters {
            let (ok, stopped) = s.join().expect("no submitter may hang or panic");
            total_ok += ok;
            total_stopped += stopped;
        }
        assert_eq!(
            total_ok + total_stopped,
            8 * 16,
            "round {round}: every submission must be answered exactly once"
        );
    }
}

#[test]
fn stage_error_fails_only_that_batch() {
    let fail = Arc::new(AtomicBool::new(true));
    let stages: Vec<Box<dyn PipelineStage>> = vec![
        MockStage::ok(Duration::ZERO),
        Box::new(MockStage {
            delay: Duration::ZERO,
            fail: Some(Arc::clone(&fail)),
            panic_now: false,
            calls: Arc::new(AtomicUsize::new(0)),
        }),
    ];
    let ps = PipelineServer::start_stages(stages, 0).unwrap();
    let h = ps.handle();
    let err = h.infer_batch(&Matrix::zeros(D, 2)).unwrap_err();
    match err {
        InferError::Backend(msg) => assert!(msg.contains("stage refused"), "got: {msg}"),
        other => panic!("expected a backend error, got {other:?}"),
    }
    // The pipeline survives a stage `Err` and keeps serving.
    fail.store(false, Ordering::SeqCst);
    let y = h.infer_batch(&Matrix::zeros(D, 2)).unwrap();
    assert!(y.data.iter().all(|&v| v == 2.0));
    ps.stop();
}

#[test]
fn stage_panic_poisons_the_pipeline_and_fails_in_flight_requests_fast() {
    let stages: Vec<Box<dyn PipelineStage>> = vec![
        MockStage::ok(Duration::ZERO),
        Box::new(MockStage {
            delay: Duration::ZERO,
            fail: None,
            panic_now: true,
            calls: Arc::new(AtomicUsize::new(0)),
        }),
    ];
    let ps = PipelineServer::start_stages(stages, 0).unwrap();
    let h = ps.handle();
    // Rides into the panicking stage → response sender drops → error, not
    // a hang.
    assert!(h.infer_batch(&Matrix::zeros(D, 1)).is_err());
    // The poison guard closed every link: later submissions error fast
    // instead of blocking on a dead pipeline.
    let t0 = Instant::now();
    assert!(h.infer_batch(&Matrix::zeros(D, 1)).is_err());
    assert!(t0.elapsed() < Duration::from_secs(5), "post-poison submission must fail fast");
    ps.stop();
}

#[test]
fn mismatched_stage_dimensions_are_rejected_at_startup() {
    struct Wide;
    impl PipelineStage for Wide {
        fn d_in(&self) -> usize {
            2 * D
        }
        fn d_out(&self) -> usize {
            2 * D
        }
        fn run(&mut self, _x: &Matrix, _out: &mut Matrix) -> Result<()> {
            unreachable!("never started")
        }
    }
    let stages: Vec<Box<dyn PipelineStage>> = vec![MockStage::ok(Duration::ZERO), Box::new(Wide)];
    let err = PipelineServer::start_stages(stages, 0).unwrap_err();
    assert!(format!("{err:#}").contains("consumes"), "got: {err:#}");
    assert!(PipelineServer::start_stages(Vec::new(), 0).is_err(), "empty pipeline rejected");
}

#[test]
fn wrong_input_channel_count_is_rejected_client_side() {
    let ps = PipelineServer::start_stages(vec![MockStage::ok(Duration::ZERO)], 0).unwrap();
    let err = ps.handle().infer_batch(&Matrix::zeros(D + 1, 1)).unwrap_err();
    assert!(matches!(err, InferError::BadRequest(_)), "got {err:?}");
    ps.stop();
}

#[test]
fn split_stage_counts_beyond_layers_are_rejected() {
    let (_, model) =
        serving_models(7).unwrap().into_iter().find(|(n, _)| *n == "ffn-relu").unwrap();
    assert_eq!(model.n_layers(), 2);
    assert!(PipelineServer::start(&model, 3, 1, 0).is_err());
    assert!(PipelineServer::start(&model, 0, 1, 0).is_err());
}
