//! The microkernel's two correctness contracts, end to end through the
//! engine (DESIGN.md §16):
//!
//! 1. **Bitwise ISA equivalence** — for any plan, any batch shape, and any
//!    lane count, every available kernel tier (scalar / SSE2 / AVX2)
//!    computes the *same bits*. The SIMD paths put batch lanes in vector
//!    lanes, so each output element's strict serial fold is unchanged; the
//!    sweep here covers odd tile shapes, ragged batch tails, misaligned
//!    batch blocks, fused epilogues, and both value formats.
//! 2. **bf16 accuracy** — the bf16 path is *not* bit-equal to f32 (it
//!    rounds both operands to bf16 before the f32 accumulate); its error
//!    is bounded by the rounding model `|y₁₆ − y₃₂| ≤ 2⁻⁷·Σ|wᵢxᵢ|`, and on
//!    cancellation-free inputs by a pure ulp budget against the f32
//!    oracle.
//!
//! **Miri note** (the pattern for every heavy sweep in this suite): under
//! Miri the `SHAPES`/`BATCHES` consts shrink via `#[cfg(miri)]` so the CI
//! `miri` job finishes in budget. That loses nothing Miri could catch —
//! `is_x86_feature_detected!` is always false under Miri, so only the
//! scalar tier runs and extra shapes add interpreter time, not UB
//! coverage; the per-tile raw-pointer arithmetic Miri *does* check is the
//! same on every shape.

use hinm::sparsity::{prune_oneshot, HinmConfig};
use hinm::spmm::{
    dense, spmm_reference, ulp_diff, Activation, Epilogue, KernelIsa, SpmmEngine, SpmmPlan,
    ValueFormat,
};
use hinm::tensor::Matrix;
use hinm::util::rng::Xoshiro256;

/// (rows, cols, V) tile shapes chosen so the sweep hits single-tile,
/// many-tile, and V=8 layouts with k_v values that are *not* multiples of
/// the SIMD widths.
#[cfg(not(miri))]
const SHAPES: &[(usize, usize, usize)] =
    &[(16, 32, 4), (8, 48, 4), (32, 64, 8), (40, 96, 8), (24, 112, 4)];
/// Miri-budget subset: one V=4 and one V=8 layout (see the header note).
#[cfg(miri)]
const SHAPES: &[(usize, usize, usize)] = &[(16, 32, 4), (32, 64, 8)];

/// Batch widths exercising every tail class of the register blocking:
/// 1 (pure scalar tail), 3/7 (sub-SSE tails), 33 (two AVX2 blocks + 1).
#[cfg(not(miri))]
const BATCHES: &[usize] = &[1, 3, 7, 33];
/// Miri-budget subset: one scalar tail, one SIMD-block width.
#[cfg(miri)]
const BATCHES: &[usize] = &[1, 7];

fn packed(m: usize, n: usize, v: usize, seed: u64) -> hinm::sparsity::HinmPacked {
    let mut rng = Xoshiro256::new(seed);
    let w = Matrix::randn(m, n, 1.0, &mut rng);
    let cfg = HinmConfig::with_24(v, 0.5);
    prune_oneshot(&w, &w.abs(), &cfg).packed
}

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn every_isa_tier_matches_the_reference_bitwise() {
    for &(m, n, v) in SHAPES {
        let p = packed(m, n, v, 7 + m as u64);
        let mut rng = Xoshiro256::new(11 + n as u64);
        for &b in BATCHES {
            let x = Matrix::randn(n, b, 1.0, &mut rng);
            let want = spmm_reference(&p, &x);
            for lanes in [1usize, 8] {
                let engine = SpmmEngine::new(lanes);
                for &isa in KernelIsa::available() {
                    let plan = SpmmPlan::new(&p).with_isa(isa);
                    let got = engine.spmm_planned(&plan, &x);
                    assert_eq!(
                        bits(&got),
                        bits(&want),
                        "{m}x{n} V={v} batch {b} lanes {lanes} isa {isa}"
                    );
                }
            }
        }
    }
}

#[test]
fn misaligned_batch_blocks_do_not_change_bits() {
    // A batch block of 5 forces every SIMD path through its scalar tail on
    // every panel pass; 13 mixes one SSE block with a 5-wide tail.
    let p = packed(24, 112, 4, 21);
    let mut rng = Xoshiro256::new(22);
    let x = Matrix::randn(112, 33, 1.0, &mut rng);
    let want = spmm_reference(&p, &x);
    let engine = SpmmEngine::single();
    for bb in [5usize, 13] {
        for &isa in KernelIsa::available() {
            let plan = SpmmPlan::new(&p).with_isa(isa).with_batch_block(bb);
            assert_eq!(bits(&engine.spmm_planned(&plan, &x)), bits(&want), "bb {bb} isa {isa}");
        }
    }
}

#[test]
fn fused_epilogues_are_isa_invariant_on_ragged_tails() {
    // Bias + ReLU fused into the epilogue, batch 7 with batch block 5, so
    // the epilogue runs on accumulator tails narrower than any vector
    // width. All tiers must still agree bitwise (the epilogue reads the
    // finished accumulator; it never sees the SIMD layout).
    let p = packed(16, 32, 4, 31);
    let mut rng = Xoshiro256::new(32);
    let x = Matrix::randn(32, 7, 1.0, &mut rng);
    let bias: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
    for act in [Activation::None, Activation::Relu, Activation::Gelu] {
        let epi = Epilogue::new(Some(&bias), act);
        let mut base: Option<Vec<u32>> = None;
        for &isa in KernelIsa::available() {
            let plan = SpmmPlan::new(&p).with_isa(isa).with_batch_block(5);
            let mut y = Matrix::zeros(16, 7);
            SpmmEngine::single().execute(&plan, &x, &mut y, &epi);
            let got = bits(&y);
            match &base {
                None => base = Some(got),
                Some(b) => assert_eq!(&got, b, "act {act:?} isa {isa}"),
            }
        }
    }
}

#[test]
fn bf16_is_bitwise_identical_across_isa_tiers() {
    // bf16 differs from f32 by rounding, but across ISAs it must be exact:
    // the widen (u16 << 16) is lossless and the accumulate chain is the
    // same strict serial fold.
    for &(m, n, v) in &[(16usize, 32usize, 4usize), (40, 96, 8)] {
        let p = packed(m, n, v, 41 + m as u64);
        let mut rng = Xoshiro256::new(42);
        for &b in BATCHES {
            let x = Matrix::randn(n, b, 1.0, &mut rng);
            let mut base: Option<Vec<u32>> = None;
            for lanes in [1usize, 8] {
                let engine = SpmmEngine::new(lanes);
                for &isa in KernelIsa::available() {
                    let plan =
                        SpmmPlan::new(&p).with_values(ValueFormat::Bf16).with_isa(isa);
                    let got = bits(&engine.spmm_planned(&plan, &x));
                    match &base {
                        None => base = Some(got),
                        Some(bse) => {
                            assert_eq!(&got, bse, "{m}x{n} batch {b} lanes {lanes} isa {isa}")
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn bf16_error_is_bounded_by_the_rounding_model_on_randn() {
    // Per product, rounding w and x to bf16 perturbs each by ≤ 2⁻⁸
    // relative (RNE, 8-bit significand), so
    //   |y₁₆ − y₃₂| ≤ (2·2⁻⁸ + 2⁻¹⁶)·Σ|wᵢxᵢ| + accumulate noise
    // — bounded here by S/128 + 1e-5 with S = |W_packed|·|X| computed
    // densely. This holds under arbitrary cancellation because the bound
    // scales with the magnitude *sum*, not the result.
    for &(m, n, v) in SHAPES {
        let p = packed(m, n, v, 51 + n as u64);
        let dense_w = p.to_dense();
        let mut rng = Xoshiro256::new(52);
        let x = Matrix::randn(n, 16, 1.0, &mut rng);
        let s = dense::matmul(&dense_w.abs(), &x.abs());
        let engine = SpmmEngine::single();
        let y32 = engine.spmm_planned(&SpmmPlan::new(&p), &x);
        let y16 =
            engine.spmm_planned(&SpmmPlan::new(&p).with_values(ValueFormat::Bf16), &x);
        for (i, ((&a, &b), &mag)) in
            y16.data.iter().zip(&y32.data).zip(&s.data).enumerate()
        {
            let bound = mag / 128.0 + 1e-5;
            assert!(
                (a - b).abs() <= bound,
                "{m}x{n} elem {i}: bf16 {a} vs f32 {b} (|Σwx| = {mag}, bound {bound})"
            );
        }
    }
}

#[test]
fn bf16_stays_within_the_ulp_budget_on_cancellation_free_inputs() {
    // All-positive weights and inputs: no cancellation, so the relative
    // error stays ≤ ~2⁻⁷ and a pure ulp budget against the f32 oracle is
    // meaningful: 2⁻⁷ relative ≈ 2¹⁷ f32 ulps; 2¹⁸ leaves slack for the
    // accumulate rounding. A dense sweep over batch columns spanning three
    // orders of magnitude checks the bound is scale-free.
    let mut rng = Xoshiro256::new(61);
    let (m, n, v) = (16usize, 64usize, 4usize);
    let w = Matrix::from_vec(
        m,
        n,
        (0..m * n).map(|_| rng.range_f32(0.05, 1.0)).collect(),
    );
    let cfg = HinmConfig::with_24(v, 0.5);
    let p = prune_oneshot(&w, &w.abs(), &cfg).packed;
    let batch = 48;
    let x = Matrix::from_vec(
        n,
        batch,
        (0..n * batch)
            .map(|i| {
                let scale = [0.01f32, 1.0, 100.0][i % 3];
                rng.range_f32(0.1, 1.0) * scale
            })
            .collect(),
    );
    let engine = SpmmEngine::single();
    let y32 = engine.spmm_planned(&SpmmPlan::new(&p), &x);
    let y16 = engine.spmm_planned(&SpmmPlan::new(&p).with_values(ValueFormat::Bf16), &x);
    for (i, (&a, &b)) in y16.data.iter().zip(&y32.data).enumerate() {
        let d = ulp_diff(a, b);
        assert!(d <= 1u64 << 18, "elem {i}: bf16 {a} vs f32 {b}: {d} ulp");
    }
}

#[test]
fn forced_scalar_plan_reports_itself() {
    // `with_isa(Scalar)` must both dispatch scalar and *say* so — serve
    // metrics report `plan.isa()`, so the accessor is part of the
    // contract.
    let p = packed(8, 16, 4, 71);
    let plan = SpmmPlan::new(&p).with_isa(KernelIsa::Scalar);
    assert_eq!(plan.isa(), KernelIsa::Scalar);
    assert_eq!(plan.values(), ValueFormat::F32);
    // The detected tier is always at least scalar and within the
    // available set.
    assert!(KernelIsa::available().contains(&KernelIsa::detect()));
    assert!(KernelIsa::detect() >= KernelIsa::Scalar);
}
