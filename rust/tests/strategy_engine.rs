//! Integration tests for the permutation strategy layer: every registered
//! OCP×ICP pair must produce valid permutations and never retain less than
//! the unpermuted baseline (the never-worse guard generalized beyond gyro),
//! and the parallel tile engine must be bit-deterministic in the worker
//! count.
//!
//! **Miri note**: the sweep sizes below shrink under Miri (`CASES`,
//! `DETERMINISM_SPECS`) so the CI `miri` job fits its budget. The suite's
//! Miri value is the thread-pool handoff in `PermutePipeline` — covered by
//! a single multi-worker run — not the breadth of the strategy sweep, which
//! is pure safe arithmetic repeated per pair.

use hinm::ensure_prop;
use hinm::permute::baselines::apex::ApexParams;
use hinm::permute::{
    IcpParams, OcpParams, PermutePipeline, StrategyParams, StrategyRegistry, StrategySpec,
    TetrisIcp,
};
use hinm::sparsity::hinm::prune_oneshot;
use hinm::sparsity::HinmConfig;
use hinm::tensor::{is_permutation, Matrix};
use hinm::util::prop::{forall, Config, Gen};
use hinm::util::rng::Xoshiro256;

/// Property-test case count: every case runs all 12 registry pairs through
/// the full pipeline, so the count dominates suite runtime (see Miri note).
const CASES: usize = if cfg!(miri) { 2 } else { 10 };

/// Determinism sweep: under Miri one spec exercises the worker-pool
/// raw-handoff path; natively we also pin the composite strategies.
const DETERMINISM_SPECS: &[&str] = if cfg!(miri) {
    &["gyro"]
} else {
    &["gyro", "gyro+tetris", "v2", "id+gyro"]
};

/// Generator for small random HiNM problem instances (kept tiny: every case
/// runs all 12 registry pairs through the full pipeline).
struct StrategyCase;

struct Case {
    w: Matrix,
    cfg: HinmConfig,
    seed: u64,
}

impl Gen for StrategyCase {
    type Value = Case;
    fn generate(&self, rng: &mut Xoshiro256, size: f64) -> Case {
        let v = [4usize, 8][rng.below(2)];
        let tiles = 1 + rng.below((2.0 * size).ceil() as usize + 1);
        let m = v * tiles;
        let n = 4 * (2 + rng.below((8.0 * size) as usize + 2));
        let sv = [0.0, 0.25, 0.5][rng.below(3)];
        let w = Matrix::from_fn(m, n, |_, _| {
            let x = rng.normal();
            if rng.next_f32() < 0.1 {
                x * 5.0
            } else {
                x
            }
        });
        Case { w, cfg: HinmConfig::with_24(v, sv), seed: rng.next_u64() }
    }
}

/// Fast strategy tuning so the exhaustive pair sweep stays quick.
fn cheap_params(seed: u64) -> StrategyParams {
    StrategyParams {
        ocp: OcpParams { max_iters: 8, patience: 4, hinm_aware: false, seed },
        icp: IcpParams { max_iters: 6, patience: 3, seed: seed ^ 0xABCD, max_partitions: 32 },
        apex: ApexParams { max_sweeps: 3, escapes: 1, seed: seed ^ 0xA9E },
        tetris: TetrisIcp { max_rounds: 3, swaps_per_round: 32, seed: seed ^ 0x7E7 },
        ovw_seed: seed,
    }
}

#[test]
fn prop_every_registry_pair_valid_and_never_worse() {
    let reg = StrategyRegistry::builtin();
    forall(&Config { cases: CASES, seed: 0xE1 }, &StrategyCase, |c| {
        let sal = c.w.abs();
        let noperm = prune_oneshot(&c.w, &sal, &c.cfg).retained;
        let params = cheap_params(c.seed);
        for o in reg.ocp_keys() {
            for i in reg.icp_keys() {
                let (ocp, icp) = reg.build(&StrategySpec::new(o, i), &params).unwrap();
                let out = PermutePipeline::default().run(
                    ocp.as_ref(),
                    icp.as_ref(),
                    &c.w,
                    &sal,
                    &c.cfg,
                );
                ensure_prop!(
                    is_permutation(&out.ocp_perm, c.w.rows),
                    "{o}+{i}: invalid OCP perm for shape {:?}",
                    c.w.shape()
                );
                for (t, ord) in out.tile_orders.iter().enumerate() {
                    ensure_prop!(
                        is_permutation(ord, out.result.packed.k_v),
                        "{o}+{i}: tile {t} order invalid"
                    );
                }
                out.result.packed.check_invariants().map_err(|e| format!("{o}+{i}: {e}"))?;
                ensure_prop!(
                    out.result.retained >= noperm - 1e-6,
                    "{o}+{i}: retained {} below noperm baseline {noperm} (shape {:?}, cfg {:?})",
                    out.result.retained,
                    c.w.shape(),
                    c.cfg
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parse_roundtrips_through_registry() {
    let reg = StrategyRegistry::builtin();
    for o in reg.ocp_keys() {
        for i in reg.icp_keys() {
            let key = format!("{o}+{i}");
            let spec = StrategySpec::parse(&key).expect(&key);
            assert_eq!(spec.key(), key);
            assert!(reg.supports(&spec));
        }
    }
}

#[test]
fn tile_engine_bit_deterministic_across_worker_counts() {
    // workers=1 and workers=8 must produce bit-identical packed output for
    // the same seed — the contract that makes the thread pool safe to use
    // everywhere (evals, CLI, coordinator).
    let mut rng = Xoshiro256::new(0xD37);
    let w = Matrix::from_fn(64, 96, |_, _| rng.normal());
    let sal = w.abs();
    let cfg = HinmConfig::with_24(8, 0.5); // 8 tiles
    let reg = StrategyRegistry::builtin();
    let params = cheap_params(0x5EED);
    for &spec in DETERMINISM_SPECS {
        let spec = StrategySpec::parse(spec).expect(spec);
        let (ocp1, icp1) = reg.build(&spec, &params).unwrap();
        let (ocp8, icp8) = reg.build(&spec, &params).unwrap();
        let a = PermutePipeline { workers: 1, guard: true }.run(ocp1.as_ref(), icp1.as_ref(), &w, &sal, &cfg);
        let b = PermutePipeline { workers: 8, guard: true }.run(ocp8.as_ref(), icp8.as_ref(), &w, &sal, &cfg);
        assert_eq!(a.ocp_perm, b.ocp_perm, "{}", spec.key());
        assert_eq!(a.tile_orders, b.tile_orders, "{}", spec.key());
        assert_eq!(a.result.packed, b.result.packed, "{}", spec.key());
        assert_eq!(a.icp_stats, b.icp_stats, "{}", spec.key());
    }
}

#[test]
fn guard_can_be_disabled_for_timing_runs() {
    // With guard=false the pipeline must still produce valid output (it just
    // skips the baseline comparison and potential re-runs).
    let mut rng = Xoshiro256::new(0xD38);
    let w = Matrix::from_fn(16, 32, |_, _| rng.normal());
    let sal = w.abs();
    let cfg = HinmConfig::with_24(4, 0.5);
    let reg = StrategyRegistry::builtin();
    let params = cheap_params(3);
    let (ocp, icp) = reg.build(&StrategySpec::parse("v2").unwrap(), &params).unwrap();
    let out = PermutePipeline { workers: 2, guard: false }.run(ocp.as_ref(), icp.as_ref(), &w, &sal, &cfg);
    out.result.packed.check_invariants().unwrap();
    assert!(is_permutation(&out.ocp_perm, 16));
}
