//! Deterministic structure-aware fuzz smoke for the artifact loader
//! (DESIGN.md §18), mirroring `fuzz_plan.rs`.
//!
//! Starting from one *valid* `(manifest_text, payload)` pair produced by
//! `encode_parts`, every case derived from `mix_seed(BASE_SEED, case)`
//! applies one mutation — byte flips, truncation, extension, splices, or
//! a benign provenance tweak — and pushes the result through
//! `load_from_parts`. The properties:
//!
//! 1. **Never panic**: any outcome other than a typed `ArtifactError` or
//!    a structurally valid model fails the harness (a panic aborts it).
//! 2. **Valid ⇒ runnable**: when a mutant still loads, the decoded model
//!    must survive a forward pass — the loader may only accept inputs it
//!    fully validated.
//!
//! 10k iterations fit the tier-1 debug-build budget; the CI `fuzz-long`
//! job scales the count via `HINM_FUZZ_ITERS` under an
//! `HINM_FUZZ_SECONDS` wall-clock bound. Failing cases persist their
//! parameters to `target/fuzz-failures/`.

use hinm::models::{Activation, HinmModel};
use hinm::runtime::artifact::{encode_parts, load_from_parts};
use hinm::runtime::Provenance;
use hinm::sparsity::HinmConfig;
use hinm::tensor::Matrix;
use hinm::util::rng::{mix_seed, Xoshiro256};
use std::time::{Duration, Instant};

const BASE_SEED: u64 = 0xA27F_1FAC_7001;

fn iters(default: usize) -> usize {
    if cfg!(miri) {
        return 32;
    }
    std::env::var("HINM_FUZZ_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn budget() -> Option<Duration> {
    std::env::var("HINM_FUZZ_SECONDS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_secs)
}

fn persist_failure(case: u64, detail: &str) -> String {
    let dir = std::env::var("HINM_FUZZ_ARTIFACTS")
        .unwrap_or_else(|_| "target/fuzz-failures".to_string());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/artifact-case{case}.txt");
    let _ = std::fs::write(&path, detail);
    path
}

/// One byte-level mutation over `bytes`. Returns a tag for the failure
/// artifact.
fn mutate_bytes(rng: &mut Xoshiro256, bytes: &mut Vec<u8>) -> &'static str {
    match rng.below(4) {
        0 => {
            let i = rng.below(bytes.len());
            bytes[i] ^= 1 << rng.below(8);
            "byte flip"
        }
        1 => {
            let keep = rng.below(bytes.len());
            bytes.truncate(keep);
            "truncate"
        }
        2 => {
            let extra = 1 + rng.below(16);
            for _ in 0..extra {
                bytes.push(rng.below(256) as u8);
            }
            "extend"
        }
        _ => {
            // Overwrite a short random region (a burst error).
            let i = rng.below(bytes.len());
            let n = (1 + rng.below(8)).min(bytes.len() - i);
            for b in &mut bytes[i..i + n] {
                *b = rng.below(256) as u8;
            }
            "splice"
        }
    }
}

#[test]
fn fuzz_artifact_loader_smoke() {
    let cfg = HinmConfig::with_24(4, 0.5);
    let model = HinmModel::synthetic_ffn(16, 32, &cfg, Activation::Relu, 7).expect("base model");
    let prov = Provenance { tool: "fuzz".to_string(), seed: Some(7), note: None };
    let (text, payload) = encode_parts("fz", 1, &model, &prov).expect("encode");

    // The unmutated pair must load — otherwise every case is vacuous.
    let base = load_from_parts(&text, &payload).expect("pristine artifact loads");
    assert_eq!(base.model.d_in(), model.d_in());

    let n_iters = iters(10_000);
    let start = Instant::now();
    let deadline = budget();
    let mut done = 0usize;
    let mut mutants_valid = 0usize;
    let mut mutants_caught = 0usize;
    for case in 0..n_iters as u64 {
        if deadline.is_some_and(|d| start.elapsed() > d) {
            break;
        }
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED, case));
        let (man, pay, tag) = match rng.below(5) {
            // Benign provenance tweak: stays a valid manifest, so the
            // valid side of property 2 is exercised every run.
            0 => (text.replace("\"tool\": \"fuzz\"", "\"tool\": \"zzuf\""), payload.clone(), "benign tool rename"),
            1 | 2 => {
                let mut m = text.clone().into_bytes();
                let tag = mutate_bytes(&mut rng, &mut m);
                match String::from_utf8(m) {
                    Ok(s) => (s, payload.clone(), tag),
                    Err(_) => {
                        // Invalid UTF-8 can never reach the &str loader;
                        // the type system caught it for us.
                        mutants_caught += 1;
                        done += 1;
                        continue;
                    }
                }
            }
            _ => {
                let mut p = payload.clone();
                let tag = mutate_bytes(&mut rng, &mut p);
                (text.clone(), p, tag)
            }
        };
        match load_from_parts(&man, &pay) {
            Err(_) => mutants_caught += 1,
            Ok(loaded) => {
                mutants_valid += 1;
                // An accepted mutant must be fully usable: forward on a
                // conforming batch must not panic.
                let b = 1 + rng.below(3);
                let x = Matrix::randn(loaded.model.d_in(), b, 1.0, &mut rng);
                let y = loaded.model.forward(&x);
                if y.rows != loaded.model.d_out() || y.cols != b {
                    let path = persist_failure(
                        case,
                        &format!("case {case} [{tag}]: accepted mutant produced {}x{}", y.rows, y.cols),
                    );
                    panic!("case {case} [{tag}]: bad forward shape; params at {path}");
                }
            }
        }
        done += 1;
    }
    assert!(done > 0, "fuzz budget expired before the first case");
    // Both sides of the accept/reject boundary must be exercised.
    if done >= 1000 {
        assert!(mutants_caught > 0, "no mutation was ever rejected");
        assert!(mutants_valid > 0, "no mutation ever stayed valid");
    }
    println!(
        "fuzz_artifact: {done} cases ({mutants_caught} mutants caught, {mutants_valid} valid), {:?}",
        start.elapsed()
    );
}
