//! Scheduler semantics of the batch engine: priority ordering under
//! contention, deadline expiry at enqueue and in the queue (expired
//! requests are answered with a timeout error and never computed), the
//! per-priority/expiry counters, and bit-identical cache hits through the
//! whole engine path. Runs everywhere — mock + native backends only.

use anyhow::Result;
use hinm::coordinator::serve::{BackendFactory, BatchServer, InferError, Priority, ServeConfig};
use hinm::coordinator::cached_factory;
use hinm::models::{Activation, HinmModel};
use hinm::runtime::{CacheStats, SpmmBackend};
use hinm::sparsity::HinmConfig;
use hinm::tensor::Matrix;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const D_IN: usize = 2;

/// Identity-ish mock: `y[0][j] = x[0][j]`, records the first row of every
/// batch it executes, optionally sleeping to keep the worker busy.
struct RecordingBackend {
    seen: Arc<Mutex<Vec<Vec<f32>>>>,
    delay: Duration,
}

impl SpmmBackend for RecordingBackend {
    fn name(&self) -> &'static str {
        "recording"
    }
    fn d_in(&self) -> usize {
        D_IN
    }
    fn d_out(&self) -> usize {
        1
    }
    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.seen.lock().unwrap().push(x.data[..x.cols].to_vec());
        let mut y = Matrix::zeros(1, x.cols);
        y.data.copy_from_slice(&x.data[..x.cols]);
        Ok(y)
    }
}

fn start_recording(cfg: ServeConfig, delay: Duration) -> (BatchServer, Arc<Mutex<Vec<Vec<f32>>>>) {
    let seen = Arc::new(Mutex::new(Vec::new()));
    let s2 = Arc::clone(&seen);
    let factory: BackendFactory = Arc::new(move |_replica| {
        let b: Box<dyn SpmmBackend> =
            Box::new(RecordingBackend { seen: Arc::clone(&s2), delay });
        Ok(b)
    });
    let server = BatchServer::start(factory, cfg).expect("engine start");
    (server, seen)
}

#[test]
fn deadline_already_expired_at_enqueue_is_rejected_without_queuing() {
    let (server, seen) = start_recording(
        ServeConfig::new(1, Duration::from_millis(1)),
        Duration::ZERO,
    );
    let err = server
        .handle
        .infer_opts(vec![7.0; D_IN], Priority::Normal, Some(Duration::ZERO))
        .unwrap_err();
    assert_eq!(err, InferError::DeadlineExpired);
    let sched = server.metrics.scheduler_stats();
    assert_eq!(sched.expired_at_enqueue, 1, "expiry at enqueue must be counted");
    assert_eq!(sched.expired_in_queue, 0);
    server.stop();
    assert!(
        seen.lock().unwrap().is_empty(),
        "an expired-at-enqueue request must never reach the backend"
    );
}

#[test]
fn request_expiring_in_the_queue_gets_timeout_and_is_never_computed() {
    // One slow replica at batch 1: a blocker occupies the worker for
    // ~150ms while a 30ms-deadline request waits in the queue. By the time
    // the worker pops it, it is dead — it must be answered with a timeout
    // error, and its payload must never reach the backend.
    let (server, seen) = start_recording(
        ServeConfig::new(1, Duration::from_millis(1)),
        Duration::from_millis(150),
    );
    let handle = server.handle.clone();
    let blocker = {
        let h = handle.clone();
        std::thread::spawn(move || h.infer(vec![1.0; D_IN]))
    };
    std::thread::sleep(Duration::from_millis(40)); // let the worker pick it up
    let t0 = Instant::now();
    let err = handle
        .infer_opts(vec![99.0; D_IN], Priority::High, Some(Duration::from_millis(30)))
        .unwrap_err();
    assert_eq!(err, InferError::DeadlineExpired);
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "expiry must be answered as soon as the worker sees the request"
    );
    blocker.join().unwrap().expect("blocker must still be served");
    let metrics = Arc::clone(&server.metrics);
    server.stop();
    let seen = seen.lock().unwrap();
    assert!(
        seen.iter().all(|batch| !batch.contains(&99.0)),
        "expired request was computed anyway: {seen:?}"
    );
    assert_eq!(metrics.scheduler_stats().expired_in_queue, 1);
}

#[test]
fn queued_high_priority_overtakes_earlier_low_priority() {
    // Priority-inversion check: Low is enqueued BEFORE High while the only
    // worker is busy; when the worker frees up it must execute High first.
    let (server, seen) = start_recording(
        ServeConfig::new(1, Duration::from_millis(1)),
        Duration::from_millis(120),
    );
    let handle = server.handle.clone();
    let blocker = {
        let h = handle.clone();
        std::thread::spawn(move || h.infer(vec![1.0; D_IN]))
    };
    std::thread::sleep(Duration::from_millis(30)); // worker now busy with the blocker
    let low = {
        let h = handle.clone();
        std::thread::spawn(move || h.infer_opts(vec![10.0; D_IN], Priority::Low, None))
    };
    std::thread::sleep(Duration::from_millis(30)); // Low is queued first…
    let high = {
        let h = handle.clone();
        std::thread::spawn(move || h.infer_opts(vec![20.0; D_IN], Priority::High, None))
    };
    blocker.join().unwrap().unwrap();
    assert_eq!(high.join().unwrap().unwrap(), vec![20.0]);
    assert_eq!(low.join().unwrap().unwrap(), vec![10.0]);
    let metrics = Arc::clone(&server.metrics);
    server.stop();

    let seen = seen.lock().unwrap();
    let first_high = seen.iter().position(|b| b.contains(&20.0)).expect("High executed");
    let first_low = seen.iter().position(|b| b.contains(&10.0)).expect("Low executed");
    assert!(
        first_high < first_low,
        "High (queued after Low) must run first; execution order: {seen:?}"
    );

    let sched = metrics.scheduler_stats();
    assert_eq!(sched.served_for(Priority::High), 1);
    assert_eq!(sched.served_for(Priority::Low), 1);
    assert_eq!(sched.served_for(Priority::Normal), 1, "the blocker ran at Normal");
}

#[test]
fn generous_deadline_does_not_fail_the_request() {
    let (server, _seen) = start_recording(
        ServeConfig::new(2, Duration::from_millis(1)),
        Duration::ZERO,
    );
    let y = server
        .handle
        .infer_opts(vec![3.0; D_IN], Priority::Normal, Some(Duration::from_secs(30)))
        .expect("a far-future deadline must not reject the request");
    assert_eq!(y, vec![3.0]);
    assert_eq!(server.metrics.scheduler_stats().expired_total(), 0);
    server.stop();
}

#[test]
fn cache_hit_through_the_engine_is_bit_identical_to_the_miss() {
    // Full path: cached_factory over the native backend, batch 1 so two
    // identical lone requests produce identical batch matrices.
    let cfg = HinmConfig::with_24(8, 0.5);
    let model =
        Arc::new(HinmModel::synthetic_ffn(32, 64, &cfg, Activation::Relu, 42).unwrap());
    let stats = CacheStats::new_shared();
    let base: BackendFactory = Arc::new(move |_replica| {
        let b: Box<dyn SpmmBackend> =
            Box::new(hinm::runtime::NativeCpuBackend::new(Arc::clone(&model)));
        Ok(b)
    });
    let factory = cached_factory(base, 8, Arc::clone(&stats));
    let server = BatchServer::start(factory, ServeConfig::new(1, Duration::from_millis(1)))
        .expect("engine start");

    let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
    let miss = server.handle.infer(x.clone()).unwrap();
    let hit = server.handle.infer(x).unwrap();
    assert_eq!(
        miss.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        hit.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "cache hit must be bit-identical to the miss that populated it"
    );
    assert_eq!(stats.misses(), 1);
    assert_eq!(stats.hits(), 1);
    server.stop();
}

#[test]
fn priority_counters_add_up_under_mixed_load() {
    let (server, _seen) = start_recording(
        ServeConfig::new(4, Duration::from_millis(1)).with_replicas(2),
        Duration::ZERO,
    );
    let handle = server.handle.clone();
    std::thread::scope(|s| {
        for i in 0..30 {
            let h = handle.clone();
            let pri = Priority::ALL[i % 3];
            s.spawn(move || {
                h.infer_opts(vec![i as f32; D_IN], pri, None).unwrap();
            });
        }
    });
    let sched = server.metrics.scheduler_stats();
    assert_eq!(sched.served_for(Priority::High), 10);
    assert_eq!(sched.served_for(Priority::Normal), 10);
    assert_eq!(sched.served_for(Priority::Low), 10);
    assert_eq!(server.metrics.total_requests(), 30);
    server.stop();
}
