//! Property-based invariants over the sparsity/permutation core, using the
//! in-repo `util::prop` framework (offline environment has no proptest).

use hinm::ensure_prop;
use hinm::permute::{gyro_permute_and_prune, GyroParams};
use hinm::sparsity::hinm::{hinm_retained, prune_oneshot};
use hinm::sparsity::unstructured::unstructured_retained;
use hinm::sparsity::HinmConfig;
use hinm::tensor::{is_permutation, Matrix};
use hinm::util::prop::{forall, Config, Gen, IntIn};
use hinm::util::rng::Xoshiro256;

/// Generator for random (weights, config) HiNM problem instances.
struct HinmCase;

struct Case {
    w: Matrix,
    cfg: HinmConfig,
}

impl Gen for HinmCase {
    type Value = Case;
    fn generate(&self, rng: &mut Xoshiro256, size: f64) -> Case {
        let v = [4usize, 8, 16][rng.below(3)];
        let tiles = 1 + rng.below((3.0 * size).ceil() as usize + 1);
        let m = v * tiles;
        let n = 4 * (2 + rng.below((14.0 * size) as usize + 2));
        let sv = [0.0, 0.25, 0.5, 0.75][rng.below(4)];
        let w = Matrix::from_fn(m, n, |_, _| {
            let x = rng.normal();
            if rng.next_f32() < 0.05 {
                x * 5.0
            } else {
                x
            }
        });
        Case { w, cfg: HinmConfig::with_24(v, sv) }
    }
}

#[test]
fn prop_packed_density_matches_config() {
    forall(&Config { cases: 40, seed: 0xD1 }, &HinmCase, |c| {
        let res = prune_oneshot(&c.w, &c.w.abs(), &c.cfg);
        res.packed.check_invariants().map_err(|e| e.to_string())?;
        // Exact expected density: keep_cols floors to a multiple of M, so
        // narrow layers deviate from the nominal total — the *exact* count
        // is keep_cols(n)/n · N/M.
        let k_v = c.cfg.keep_cols(c.w.cols);
        let want_density = (k_v as f64 / c.w.cols as f64) * c.cfg.nm_density();
        let got = 1.0 - res.mask.sparsity();
        ensure_prop!(
            (got - want_density).abs() < 1e-9,
            "density {got} vs {want_density} for {:?} {:?}",
            c.w.shape(),
            c.cfg
        );
        Ok(())
    });
}

#[test]
fn prop_kept_values_equal_original_weights() {
    forall(&Config { cases: 40, seed: 0xD2 }, &HinmCase, |c| {
        let res = prune_oneshot(&c.w, &c.w.abs(), &c.cfg);
        let dense = res.packed.to_dense();
        for r in 0..c.w.rows {
            for col in 0..c.w.cols {
                let d = dense.at(r, col);
                if d != 0.0 {
                    ensure_prop!(d == c.w.at(r, col), "value mismatch at ({r},{col})");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_unstructured_upper_bounds_hinm() {
    forall(&Config { cases: 30, seed: 0xD3 }, &HinmCase, |c| {
        let sal = c.w.abs();
        let hinm = hinm_retained(&sal, &c.cfg);
        // Unstructured at the *same kept-element budget* as the actual mask.
        let res = prune_oneshot(&c.w, &sal, &c.cfg);
        let kept = res.mask.count_kept();
        let un = hinm::sparsity::unstructured::unstructured_mask(&sal, kept).retained(&sal);
        ensure_prop!(un >= hinm - 1e-6, "unstructured {un} < hinm {hinm}");
        Ok(())
    });
}

#[test]
fn prop_gyro_never_hurts_retention() {
    forall(&Config { cases: 20, seed: 0xD4 }, &HinmCase, |c| {
        let sal = c.w.abs();
        let noperm = prune_oneshot(&c.w, &sal, &c.cfg).retained;
        let gyro = gyro_permute_and_prune(&c.w, &sal, &c.cfg, &GyroParams::default());
        ensure_prop!(
            gyro.result.retained >= noperm - 1e-6,
            "gyro {} < noperm {noperm}",
            gyro.result.retained
        );
        ensure_prop!(
            is_permutation(&gyro.ocp_perm, c.w.rows),
            "invalid OCP permutation"
        );
        gyro.result.packed.check_invariants().map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_spmm_matches_dense_reference() {
    forall(&Config { cases: 25, seed: 0xD5 }, &HinmCase, |c| {
        let res = prune_oneshot(&c.w, &c.w.abs(), &c.cfg);
        let mut rng = Xoshiro256::new(c.w.rows as u64 * 31 + c.w.cols as u64);
        let x = Matrix::randn(c.w.cols, 1 + rng.below(8), 1.0, &mut rng);
        let y = hinm::spmm::spmm(&res.packed, &x);
        let y_ref = hinm::spmm::dense::matmul(&res.packed.to_dense(), &x);
        let diff = y.max_abs_diff(&y_ref);
        ensure_prop!(diff < 1e-3, "spmm diff {diff}");
        Ok(())
    });
}

#[test]
fn prop_retention_monotone_in_sparsity() {
    forall(&Config { cases: 25, seed: 0xD6 }, &IntIn { lo: 1, hi: 4 }, |tiles| {
        let v = 8;
        let m = v * tiles;
        let n = 64;
        let mut rng = Xoshiro256::new(tiles as u64 ^ 0xBEEF);
        let sal = Matrix::randn(m, n, 1.0, &mut rng).abs();
        let mut prev = f64::INFINITY;
        for total in [0.5, 0.625, 0.75, 0.875] {
            let cfg = HinmConfig::for_total_sparsity(v, total);
            let r = hinm_retained(&sal, &cfg);
            ensure_prop!(r <= prev + 1e-9, "retention increased with sparsity at {total}");
            prev = r;
            let un = unstructured_retained(&sal, total);
            ensure_prop!(un + 1e-9 >= r, "unstructured below hinm at {total}");
        }
        Ok(())
    });
}

#[test]
fn prop_mask_rows_keep_exact_budget() {
    // Every row keeps exactly vals_per_row elements: the vector level keeps
    // K_v columns per tile and 2:4 keeps n_keep per M of them.
    forall(&Config { cases: 30, seed: 0xD7 }, &HinmCase, |c| {
        let res = prune_oneshot(&c.w, &c.w.abs(), &c.cfg);
        let keep_per_row = res.packed.vals_per_row();
        for r in 0..c.w.rows {
            let kept = (0..c.w.cols).filter(|&col| res.mask.get(r, col)).count();
            ensure_prop!(kept == keep_per_row, "row {r}: kept {kept} != {keep_per_row}");
        }
        Ok(())
    });
}
