//! Deterministic structure-aware fuzz smoke for the `util::json` parser
//! (DESIGN.md §17).
//!
//! Every case is derived from `mix_seed(BASE, case_index)`, so any failure
//! reproduces from its printed case index alone — no corpus files, no
//! cargo-fuzz, no nightly. Three input families per run:
//!
//! 1. **Valid documents**: a random [`Json`] value is generated, serialized
//!    (pretty or compact), and must parse back equal.
//! 2. **Mutated documents**: the serialized bytes are corrupted (flips,
//!    truncation, splices) and parsed via `from_utf8_lossy`; the parser
//!    may answer `Ok` or `Err` but must not panic, and any `Ok` value must
//!    survive a serialize→parse round trip unchanged.
//! 3. **Adversarial soup**: bracket runs past `MAX_DEPTH`, overflowing
//!    number literals, and random bytes from a JSON-flavored alphabet.
//!
//! Iteration budget: `HINM_FUZZ_ITERS` (default 10 000, the tier-1 smoke;
//! the CI `fuzz-long` job raises it and bounds wall clock with
//! `HINM_FUZZ_SECONDS`). Failing inputs are persisted under
//! `target/fuzz-failures/` for artifact upload before the harness panics.

use hinm::util::json::{self, Json, MAX_DEPTH};
use hinm::util::rng::{mix_seed, Xoshiro256};
use std::time::{Duration, Instant};

const BASE_SEED: u64 = 0x4A50_4E5F_F077;

fn iters(default: usize) -> usize {
    if cfg!(miri) {
        return 64;
    }
    std::env::var("HINM_FUZZ_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn budget() -> Option<Duration> {
    std::env::var("HINM_FUZZ_SECONDS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_secs)
}

/// Write the failing input to `target/fuzz-failures/` (uploaded as a CI
/// artifact by the `fuzz-long` job) and return its path for the panic
/// message.
fn persist_failure(target: &str, case: u64, bytes: &[u8]) -> String {
    let dir = std::env::var("HINM_FUZZ_ARTIFACTS")
        .unwrap_or_else(|_| "target/fuzz-failures".to_string());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/{target}-case{case}.bin");
    let _ = std::fs::write(&path, bytes);
    path
}

fn gen_string(rng: &mut Xoshiro256) -> String {
    let len = rng.below(12);
    (0..len)
        .map(|_| match rng.below(6) {
            0 => char::from(b'a' + rng.below(26) as u8),
            1 => char::from(b'0' + rng.below(10) as u8),
            2 => ['"', '\\', '/', '\n', '\t', '\r'][rng.below(6)],
            3 => char::from_u32(rng.below(0x20) as u32).unwrap_or('?'),
            4 => ['é', '→', '日', '\u{1F600}', 'π'][rng.below(5)],
            _ => ' ',
        })
        .collect()
}

fn gen_value(rng: &mut Xoshiro256, depth: usize) -> Json {
    let scalar_only = depth >= 6;
    match rng.below(if scalar_only { 4 } else { 6 }) {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => {
            let n = match rng.below(5) {
                0 => rng.below(1000) as f64,
                1 => -(rng.below(1000) as f64),
                2 => rng.next_f64() * 1e6 - 5e5,
                3 => 1.7e308 * rng.next_f64(),
                _ => rng.next_f64() * 1e-300,
            };
            Json::Num(n)
        }
        3 => Json::Str(gen_string(rng)),
        4 => {
            let n = rng.below(5);
            Json::Arr((0..n).map(|_| gen_value(rng, depth + 1)).collect())
        }
        _ => {
            let n = rng.below(5);
            Json::Obj((0..n).map(|_| (gen_string(rng), gen_value(rng, depth + 1))).collect())
        }
    }
}

fn mutate(rng: &mut Xoshiro256, bytes: &mut Vec<u8>) {
    for _ in 0..1 + rng.below(4) {
        if bytes.is_empty() {
            return;
        }
        match rng.below(5) {
            0 => {
                let i = rng.below(bytes.len());
                bytes[i] = rng.next_u64() as u8;
            }
            1 => bytes.truncate(rng.below(bytes.len())),
            2 => {
                let i = rng.below(bytes.len());
                bytes.insert(i, *[b'{', b'[', b'"', b',', b'\\', 0xE2][rng.below(6)]);
            }
            3 => {
                let i = rng.below(bytes.len());
                bytes.remove(i);
            }
            _ => {
                let i = rng.below(bytes.len());
                let j = rng.below(bytes.len());
                bytes.swap(i, j);
            }
        }
    }
}

/// Parsed values must survive a serialize→parse round trip bit-for-bit:
/// the parser only produces finite numbers and valid scalars, both of
/// which the writer re-emits losslessly.
fn check_roundtrip(v: &Json, case: u64, input: &[u8]) {
    for text in [v.compact(), v.pretty()] {
        match json::parse(&text) {
            Ok(back) if back == *v => {}
            other => {
                let path = persist_failure("json", case, input);
                panic!("case {case}: roundtrip broke ({other:?} != {v:?}); input at {path}");
            }
        }
    }
}

#[test]
fn fuzz_json_parser_smoke() {
    let n = iters(10_000);
    let start = Instant::now();
    let deadline = budget();
    let mut done = 0usize;
    for case in 0..n as u64 {
        if deadline.is_some_and(|d| start.elapsed() > d) {
            break;
        }
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED, case));
        match case % 3 {
            // Family 1: valid document → must parse back equal.
            0 => {
                let v = gen_value(&mut rng, 0);
                let text = if rng.below(2) == 0 { v.pretty() } else { v.compact() };
                match json::parse(&text) {
                    Ok(back) if back == v => {}
                    other => {
                        let path = persist_failure("json", case, text.as_bytes());
                        panic!("case {case}: valid doc mis-parsed ({other:?}); input at {path}");
                    }
                }
            }
            // Family 2: mutated document → no panic; Ok values roundtrip.
            1 => {
                let v = gen_value(&mut rng, 0);
                let mut bytes = v.compact().into_bytes();
                mutate(&mut rng, &mut bytes);
                let text = String::from_utf8_lossy(&bytes);
                let parsed = std::panic::catch_unwind(|| json::parse(&text));
                match parsed {
                    Err(_) => {
                        let path = persist_failure("json", case, &bytes);
                        panic!("case {case}: parser panicked; input at {path}");
                    }
                    Ok(Ok(got)) => check_roundtrip(&got, case, &bytes),
                    Ok(Err(_)) => {}
                }
            }
            // Family 3: adversarial soup.
            _ => {
                let text: String = match rng.below(3) {
                    0 => {
                        let d = rng.below(2 * MAX_DEPTH) + 1;
                        let open = if rng.below(2) == 0 { "[" } else { "{\"k\":" };
                        open.repeat(d)
                    }
                    1 => format!("1e{}", rng.below(2000)),
                    _ => {
                        const ALPHA: &[u8] = b"{}[]\",:0123456789eE+-.\\utrlnf ";
                        (0..rng.below(200)).map(|_| ALPHA[rng.below(ALPHA.len())] as char).collect()
                    }
                };
                let parsed = std::panic::catch_unwind(|| json::parse(&text));
                match parsed {
                    Err(_) => {
                        let path = persist_failure("json", case, text.as_bytes());
                        panic!("case {case}: parser panicked; input at {path}");
                    }
                    Ok(Ok(got)) => check_roundtrip(&got, case, text.as_bytes()),
                    Ok(Err(_)) => {}
                }
            }
        }
        done += 1;
    }
    assert!(done > 0, "fuzz budget expired before the first case");
    println!("fuzz_json: {done} cases, {:?}", start.elapsed());
}
