//! End-to-end pipeline integration (no PJRT dependency): compress a small
//! multi-layer model through the coordinator, verify cross-layer OCP fold
//! consistency, gradual-schedule behaviour, and persistence round-trip of
//! the packed format through .npy files.

use hinm::coordinator::{run_pipeline, LayerJob, Method, PipelineConfig};
use hinm::models::SyntheticGen;
use hinm::permute::{gyro_permute_and_prune, GyroParams};
use hinm::saliency::Magnitude;
use hinm::sparsity::hinm::{gradual_schedule, step_config};
use hinm::sparsity::HinmConfig;
use hinm::tensor::{invert_permutation, npy, Matrix};
use hinm::util::rng::Xoshiro256;

fn jobs(n_layers: usize, seed: u64) -> Vec<LayerJob> {
    let mut rng = Xoshiro256::new(seed);
    let gen = SyntheticGen::default();
    (0..n_layers)
        .map(|i| {
            let w = gen.weights(64, 64, &mut rng);
            LayerJob::from_saliency(&format!("l{i}"), w, &Magnitude)
        })
        .collect()
}

#[test]
fn ocp_fold_preserves_two_layer_network() {
    // y = W2 · relu(W1 · x): prune W1 with full gyro, fold σ into W2's
    // columns, and check the composed function is unchanged (paper §3.2).
    let mut rng = Xoshiro256::new(11);
    let gen = SyntheticGen::default();
    let w1 = gen.weights(64, 32, &mut rng);
    let w2 = gen.weights(16, 64, &mut rng);
    let cfg = HinmConfig::with_24(8, 0.5);

    let out = gyro_permute_and_prune(&w1, &w1.abs(), &cfg, &GyroParams::default());
    let perm = &out.ocp_perm;
    let w1_pruned_perm = out.result.packed.to_dense(); // rows in permuted order
    let w2_folded = w2.permute_cols(perm);

    // Reference: un-permuted pruned W1 with the mask mapped back.
    let mask_orig = out.result.mask.permute_rows(&invert_permutation(perm));
    let w1_pruned_orig = mask_orig.apply(&w1);

    let x = Matrix::randn(32, 5, 1.0, &mut rng);
    let relu = |m: Matrix| Matrix {
        rows: m.rows,
        cols: m.cols,
        data: m.data.iter().map(|&v| v.max(0.0)).collect(),
    };
    let y_orig = hinm::spmm::dense::matmul(&w2, &relu(hinm::spmm::dense::matmul(&w1_pruned_orig, &x)));
    let y_fold =
        hinm::spmm::dense::matmul(&w2_folded, &relu(hinm::spmm::dense::matmul(&w1_pruned_perm, &x)));
    assert!(
        y_orig.max_abs_diff(&y_fold) < 1e-4,
        "fold must preserve the function: {}",
        y_orig.max_abs_diff(&y_fold)
    );
}

#[test]
fn pipeline_all_methods_multi_layer() {
    let js = jobs(6, 21);
    for method in [Method::HinmGyro, Method::HinmNoPerm, Method::HinmV1, Method::HinmV2] {
        let pc = PipelineConfig::new(HinmConfig::with_24(8, 0.5), method);
        let out = run_pipeline(js.clone(), &pc).unwrap();
        assert_eq!(out.len(), 6);
        for l in &out {
            l.result.packed.check_invariants().unwrap();
        }
    }
}

#[test]
fn gradual_schedule_monotone_retention_loss() {
    // As the schedule tightens, retained saliency must not increase.
    let mut rng = Xoshiro256::new(31);
    let w = SyntheticGen::default().weights(32, 64, &mut rng);
    let sal = w.abs();
    let base = HinmConfig::with_24(8, 0.5);
    let steps = gradual_schedule(0.5, 4, 6);
    let mut prev = f64::INFINITY;
    for s in &steps {
        let cfg = step_config(&base, s);
        if cfg.vector_sparsity == 0.0 && !s.nm_active {
            continue;
        }
        let r = hinm::sparsity::hinm::prune_oneshot(&w, &sal, &cfg).retained;
        assert!(r <= prev + 1e-9, "retention grew along the ramp");
        prev = r;
    }
}

#[test]
fn packed_format_roundtrips_through_npy() {
    let mut rng = Xoshiro256::new(41);
    let w = SyntheticGen::default().weights(32, 64, &mut rng);
    let cfg = HinmConfig::with_24(8, 0.5);
    let res = hinm::sparsity::prune_oneshot(&w, &w.abs(), &cfg);
    let p = &res.packed;

    let dir = std::env::temp_dir().join(format!("hinm_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let t = p.tiles();
    let vpr = p.vals_per_row();
    npy::save(dir.join("vals.npy"), &npy::NpyArray::f32(vec![t, cfg.v, vpr], p.vals.clone())).unwrap();
    npy::save(dir.join("vidx.npy"), &npy::NpyArray::i32(vec![t, p.k_v], p.vec_idx.clone())).unwrap();
    npy::save(
        dir.join("nm.npy"),
        &npy::NpyArray::i32(vec![t, cfg.v, vpr], p.nm_idx.iter().map(|&o| o as i32).collect()),
    )
    .unwrap();

    let vals = npy::load(dir.join("vals.npy")).unwrap();
    let vidx = npy::load(dir.join("vidx.npy")).unwrap();
    let nm = npy::load(dir.join("nm.npy")).unwrap();
    let rebuilt = hinm::sparsity::HinmPacked {
        cfg,
        rows: p.rows,
        cols: p.cols,
        k_v: p.k_v,
        vals: vals.as_f32().unwrap().to_vec(),
        vec_idx: vidx.as_i32().unwrap().to_vec(),
        nm_idx: nm.as_i32().unwrap().iter().map(|&o| o as u8).collect(),
    };
    rebuilt.check_invariants().unwrap();
    assert_eq!(&rebuilt, p);

    // And it still multiplies correctly.
    let x = Matrix::randn(64, 3, 1.0, &mut rng);
    let a = hinm::spmm::spmm(p, &x);
    let b = hinm::spmm::spmm(&rebuilt, &x);
    assert_eq!(a, b);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pipeline_handles_heterogeneous_shapes() {
    let mut rng = Xoshiro256::new(51);
    let gen = SyntheticGen::default();
    let shapes = [(32usize, 64usize), (64, 32), (96, 128), (32, 16)];
    let js: Vec<LayerJob> = shapes
        .iter()
        .enumerate()
        .map(|(i, &(m, n))| {
            LayerJob::from_saliency(&format!("h{i}"), gen.weights(m, n, &mut rng), &Magnitude)
        })
        .collect();
    let pc = PipelineConfig::new(HinmConfig::with_24(8, 0.5), Method::HinmGyro);
    let out = run_pipeline(js, &pc).unwrap();
    for (l, &(m, n)) in out.iter().zip(&shapes) {
        assert_eq!((l.result.packed.rows, l.result.packed.cols), (m, n));
    }
}
