//! Cross-layer integration: the AOT Pallas/JAX artifacts executed through
//! PJRT must agree with the Rust-side implementations on the same packed
//! HiNM data. Requires `make artifacts`; tests are skipped (with a loud
//! message) when the artifact directory is absent.

use hinm::runtime::executor::{lit_f32, lit_packed, lit_to_f32, Executor};
use hinm::runtime::Registry;
use hinm::sparsity::{HinmConfig, HinmPacked};
use hinm::tensor::Matrix;
use hinm::util::rng::Xoshiro256;

fn registry() -> Option<Registry> {
    match hinm::runtime::open_default_registry() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e:#}); run `make artifacts`");
            None
        }
    }
}

/// Pack the python-dumped demo weights with the *rust* packer and check
/// bit-identical layout — proves the two packers implement one format.
#[test]
fn rust_and_python_packers_agree() {
    let Some(reg) = registry() else { return };
    let w_arr = reg.load_data("spmm_demo_w_dense").unwrap();
    let (m, n) = (w_arr.shape[0], w_arr.shape[1]);
    let w = Matrix::from_vec(m, n, w_arr.as_f32().unwrap().to_vec());
    let spec = reg.artifact("spmm_demo").unwrap();
    let v = spec.meta["v"] as usize;
    let sv = spec.meta["sv"];
    let cfg = HinmConfig::with_24(v, sv);
    let packed = hinm::sparsity::prune_oneshot(&w, &w.abs(), &cfg).packed;

    let py_vals = reg.load_data("spmm_demo_vals").unwrap();
    let py_vidx = reg.load_data("spmm_demo_vec_idx").unwrap();
    let py_nm = reg.load_data("spmm_demo_nm_idx").unwrap();
    assert_eq!(packed.vals, py_vals.as_f32().unwrap());
    assert_eq!(packed.vec_idx, py_vidx.as_i32().unwrap());
    let nm_i32: Vec<i32> = packed.nm_idx.iter().map(|&o| o as i32).collect();
    assert_eq!(nm_i32, py_nm.as_i32().unwrap());
}

fn demo_packed(reg: &Registry) -> (HinmPacked, usize) {
    let w_arr = reg.load_data("spmm_demo_w_dense").unwrap();
    let (m, n) = (w_arr.shape[0], w_arr.shape[1]);
    let w = Matrix::from_vec(m, n, w_arr.as_f32().unwrap().to_vec());
    let spec = reg.artifact("spmm_demo").unwrap();
    let cfg = HinmConfig::with_24(spec.meta["v"] as usize, spec.meta["sv"]);
    let batch = spec.meta["batch"] as usize;
    (hinm::sparsity::prune_oneshot(&w, &w.abs(), &cfg).packed, batch)
}

/// Pallas kernel through PJRT vs the Rust CPU SpMM on identical inputs.
#[test]
fn pallas_artifact_matches_rust_spmm() {
    let Some(reg) = registry() else { return };
    let (packed, batch) = demo_packed(&reg);
    let exe = Executor::load(reg.artifact("spmm_demo").unwrap()).unwrap();

    let mut rng = Xoshiro256::new(424242);
    let x = Matrix::randn(packed.cols, batch, 1.0, &mut rng);

    // PJRT path.
    let (vals, vidx, nm) = lit_packed(&packed).unwrap();
    let xlit = lit_f32(&x.data, &[x.rows, x.cols]).unwrap();
    let outs = exe.run(&[vals, vidx, nm, xlit]).unwrap();
    let y_pjrt = lit_to_f32(&outs[0]).unwrap();

    // Rust path.
    let y_rust = hinm::spmm::spmm(&packed, &x);

    assert_eq!(y_pjrt.len(), y_rust.data.len());
    let max_diff = y_pjrt
        .iter()
        .zip(&y_rust.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "pallas vs rust spmm max diff {max_diff}");
}

/// Input validation: wrong arity and wrong element counts are rejected
/// before reaching XLA.
#[test]
fn executor_validates_inputs() {
    let Some(reg) = registry() else { return };
    let (packed, batch) = demo_packed(&reg);
    let exe = Executor::load(reg.artifact("spmm_demo").unwrap()).unwrap();
    let (vals, vidx, nm) = lit_packed(&packed).unwrap();

    // Too few inputs.
    let err = match exe.run(&[vals, vidx, nm]) {
        Err(e) => e,
        Ok(_) => panic!("expected arity error"),
    };
    assert!(format!("{err:#}").contains("inputs"), "{err:#}");

    // Wrong shape on x.
    let (vals, vidx, nm) = lit_packed(&packed).unwrap();
    let bad_x = lit_f32(&vec![0.0; 7], &[7]).unwrap();
    let err = match exe.run(&[vals, vidx, nm, bad_x]) {
        Err(e) => e,
        Ok(_) => panic!("expected shape error"),
    };
    assert!(format!("{err:#}").contains("elements"), "{err:#}");
    let _ = batch;
}

/// The ffn_serve artifact executes and matches the rust two-layer reference.
#[test]
fn ffn_serve_artifact_matches_rust() {
    let Some(reg) = registry() else { return };
    let spec = reg.artifact("ffn_serve").unwrap();
    let d = spec.meta["d"] as usize;
    let d_ff = spec.meta["d_ff"] as usize;
    let batch = spec.meta["batch"] as usize;
    let v = spec.meta["v"] as usize;
    let sv = spec.meta["sv"];
    let cfg = HinmConfig::with_24(v, sv);

    // Rebuild the packed weights from the dumped dense FFN weights.
    let w1_arr = reg.load_data("ffn_w1_dense").unwrap();
    let w2_arr = reg.load_data("ffn_w2_dense").unwrap();
    let w1 = Matrix::from_vec(d_ff, d, w1_arr.as_f32().unwrap().to_vec());
    let w2 = Matrix::from_vec(d, d_ff, w2_arr.as_f32().unwrap().to_vec());
    let p1 = hinm::sparsity::prune_oneshot(&w1, &w1.abs(), &cfg).packed;
    let p2 = hinm::sparsity::prune_oneshot(&w2, &w2.abs(), &cfg).packed;

    // Parity with the python-side packing dumped at AOT time.
    assert_eq!(p1.vals, reg.load_data("ffn_w1_vals").unwrap().as_f32().unwrap());
    assert_eq!(p2.vec_idx, reg.load_data("ffn_w2_vec_idx").unwrap().as_i32().unwrap());

    let mut rng = Xoshiro256::new(77);
    let x = Matrix::randn(d, batch, 0.5, &mut rng);

    let exe = Executor::load(spec).unwrap();
    let (v1, i1, n1) = lit_packed(&p1).unwrap();
    let (v2, i2, n2) = lit_packed(&p2).unwrap();
    let xlit = lit_f32(&x.data, &[d, batch]).unwrap();
    let outs = exe.run(&[v1, i1, n1, v2, i2, n2, xlit]).unwrap();
    let y = lit_to_f32(&outs[0]).unwrap();

    // Rust reference: spmm → gelu → spmm.
    let h = hinm::spmm::spmm(&p1, &x);
    let h_gelu = Matrix {
        rows: h.rows,
        cols: h.cols,
        data: h.data.iter().map(|&v| gelu(v)).collect(),
    };
    let y_ref = hinm::spmm::spmm(&p2, &h_gelu);
    let max_diff = y
        .iter()
        .zip(&y_ref.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-3, "ffn pjrt vs rust max diff {max_diff}");
}

fn gelu(x: f32) -> f32 {
    // tanh approximation (matches jax.nn.gelu default approximate=True)
    let x3 = x * x * x;
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x3)) as f64).tanh() as f32)
}

/// mlp artifacts: forward produces logits, train step reduces loss, masks
/// pin pruned weights at zero — all driven from Rust.
#[test]
fn mlp_train_step_learns_and_respects_mask() {
    let Some(reg) = registry() else { return };
    let spec = reg.artifact("mlp_train_step").unwrap();
    let d_in = spec.meta["d_in"] as usize;
    let d_h = spec.meta["d_hidden"] as usize;
    let classes = spec.meta["n_classes"] as usize;
    let batch = spec.meta["batch"] as usize;
    let exe = Executor::load(spec).unwrap();

    // Initial params from the artifact dumps.
    let mut params: Vec<xla::Literal> = ["w1", "b1", "w2", "b2"]
        .iter()
        .map(|n| {
            hinm::runtime::executor::lit_from_npy(&reg.load_data(&format!("mlp_{n}")).unwrap())
                .unwrap()
        })
        .collect();

    // Mask: prune every 4th row of w1 entirely.
    let mut mask = vec![1.0f32; d_h * d_in];
    for r in (0..d_h).step_by(4) {
        for c in 0..d_in {
            mask[r * d_in + c] = 0.0;
        }
    }

    // Synthetic 2-cluster-per-class data.
    let mut rng = Xoshiro256::new(31337);
    let make_batch = |rng: &mut Xoshiro256| {
        let mut x = vec![0.0f32; batch * d_in];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            let class = rng.below(classes);
            y[b] = class as i32;
            for j in 0..d_in {
                let center = if j % classes == class { 1.5 } else { -0.5 };
                x[b * d_in + j] = center + rng.normal() * 0.3;
            }
        }
        (x, y)
    };

    let mut first_loss = None;
    let mut last_loss = 0.0;
    for step in 0..40 {
        let (x, y) = make_batch(&mut rng);
        let mut inputs = Vec::with_capacity(8);
        inputs.append(&mut params);
        inputs.push(lit_f32(&mask, &[d_h, d_in]).unwrap());
        inputs.push(lit_f32(&x, &[batch, d_in]).unwrap());
        inputs.push(hinm::runtime::executor::lit_i32(&y, &[batch]).unwrap());
        inputs.push(hinm::runtime::executor::lit_scalar(0.3));
        let mut outs = exe.run(&inputs).unwrap();
        let loss = outs.pop().unwrap().to_vec::<f32>().unwrap()[0];
        params = outs;
        if step == 0 {
            first_loss = Some(loss);
        }
        last_loss = loss;
    }
    let first = first_loss.unwrap();
    assert!(
        last_loss < first * 0.5,
        "training did not learn: first {first} last {last_loss}"
    );

    // Pruned rows of w1 stayed exactly zero.
    let w1 = params[0].to_vec::<f32>().unwrap();
    for r in (0..d_h).step_by(4) {
        for c in 0..d_in {
            assert_eq!(w1[r * d_in + c], 0.0, "mask leaked at ({r},{c})");
        }
    }
}
