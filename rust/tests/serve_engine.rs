//! Engine semantics over a mock `SpmmBackend` — batch assembly/padding,
//! window anchoring, overflow beyond the batch size, error fan-out,
//! replica sharing, backpressure, and shutdown draining. None of this
//! needs PJRT artifacts; it is the unit story the old PJRT-only server
//! could not tell.

use anyhow::Result;
use hinm::coordinator::serve::{BackendFactory, BatchServer, ServeConfig};
use hinm::runtime::SpmmBackend;
use hinm::tensor::Matrix;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const D_IN: usize = 4;
const D_OUT: usize = 2;

/// Mock backend: `y[0][j] = 2 · x[0][j]`, `y[1][j] = 1`. Declares a fixed
/// batch width (like the PJRT backend), records every padded batch it
/// executes, and asserts the padding contract.
struct MockBackend {
    batch: usize,
    calls: Arc<AtomicUsize>,
    seen: Arc<Mutex<Vec<Matrix>>>,
    fail: bool,
    delay: Duration,
}

impl SpmmBackend for MockBackend {
    fn name(&self) -> &'static str {
        "mock"
    }
    fn d_in(&self) -> usize {
        D_IN
    }
    fn d_out(&self) -> usize {
        D_OUT
    }
    fn fixed_batch(&self) -> Option<usize> {
        Some(self.batch)
    }
    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        if self.fail {
            anyhow::bail!("mock backend exploded");
        }
        assert_eq!(x.rows, D_IN, "engine must hand the backend d_in rows");
        assert_eq!(x.cols, self.batch, "engine must pad every batch to the configured size");
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.calls.fetch_add(1, Ordering::SeqCst);
        self.seen.lock().unwrap().push(x.clone());
        let b = x.cols;
        let mut y = Matrix::zeros(D_OUT, b);
        for j in 0..b {
            y.data[j] = 2.0 * x.data[j];
            y.data[b + j] = 1.0;
        }
        Ok(y)
    }
}

struct Harness {
    server: BatchServer,
    calls: Arc<AtomicUsize>,
    seen: Arc<Mutex<Vec<Matrix>>>,
}

fn start(cfg: ServeConfig, fail: bool, delay: Duration) -> Harness {
    let calls = Arc::new(AtomicUsize::new(0));
    let seen = Arc::new(Mutex::new(Vec::new()));
    let batch = cfg.batch;
    let (c2, s2) = (Arc::clone(&calls), Arc::clone(&seen));
    let factory: BackendFactory = Arc::new(move |_replica| {
        let b: Box<dyn SpmmBackend> = Box::new(MockBackend {
            batch,
            calls: Arc::clone(&c2),
            seen: Arc::clone(&s2),
            fail,
            delay,
        });
        Ok(b)
    });
    let server = BatchServer::start(factory, cfg).expect("engine start");
    Harness { server, calls, seen }
}

/// Request whose id round-trips through the mock: column = [id; 4],
/// response must be [2·id, 1].
fn fire(h: &hinm::coordinator::ServerHandle, id: f32) -> Result<Vec<f32>> {
    h.infer(vec![id; D_IN])
}

#[test]
fn batches_are_padded_and_fanned_out_per_request() {
    let h = start(ServeConfig::new(4, Duration::from_millis(50)), false, Duration::ZERO);
    let handle = h.server.handle.clone();
    std::thread::scope(|s| {
        for id in 1..=3 {
            let hd = handle.clone();
            s.spawn(move || {
                let y = fire(&hd, id as f32).unwrap();
                assert_eq!(y, vec![2.0 * id as f32, 1.0], "request {id} got someone else's answer");
            });
        }
    });
    let metrics = Arc::clone(&h.server.metrics);
    h.server.stop();
    // 3 requests < batch 4 → every recorded batch is padded to 4 columns;
    // exactly 3 columns (across however many flushes) carry request data,
    // the rest are zero padding.
    let seen = h.seen.lock().unwrap();
    let mut nonzero_cols = 0;
    for m in seen.iter() {
        assert_eq!(m.cols, 4);
        for j in 0..m.cols {
            if (0..m.rows).any(|i| m.data[i * m.cols + j] != 0.0) {
                nonzero_cols += 1;
            }
        }
    }
    assert_eq!(nonzero_cols, 3, "exactly the 3 real requests occupy columns");
    assert_eq!(metrics.total_requests(), 3);
}

#[test]
fn lone_request_window_is_anchored_at_arrival() {
    // Pre-fix, the dispatcher re-armed an already-elapsed deadline while
    // idle, so a lone request could flush nearly immediately OR the loop
    // busy-spun. Post-fix the window *starts* at the request: a lone
    // request on an idle server waits ≈ max_wait (batch never fills).
    let max_wait = Duration::from_millis(300);
    let h = start(ServeConfig::new(8, max_wait), false, Duration::ZERO);
    let t0 = Instant::now();
    let y = fire(&h.server.handle, 5.0).unwrap();
    let elapsed = t0.elapsed();
    assert_eq!(y, vec![10.0, 1.0]);
    assert!(
        elapsed >= Duration::from_millis(250),
        "window must stay open ~max_wait for a lone request, flushed after {elapsed:?}"
    );
    assert!(elapsed < Duration::from_secs(10), "flush far beyond the window: {elapsed:?}");
    h.server.stop();
}

#[test]
fn full_batch_flushes_without_waiting_for_the_window() {
    // With a 10s window, only the batch-full condition can explain a fast
    // response for `batch` concurrent requests.
    let h = start(ServeConfig::new(4, Duration::from_secs(10)), false, Duration::ZERO);
    let handle = h.server.handle.clone();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for id in 1..=4 {
            let hd = handle.clone();
            s.spawn(move || {
                let y = fire(&hd, id as f32).unwrap();
                assert_eq!(y[0], 2.0 * id as f32);
            });
        }
    });
    assert!(t0.elapsed() < Duration::from_secs(5), "full batch must short-circuit the window");
    h.server.stop();
}

#[test]
fn overflow_beyond_batch_runs_multiple_flushes() {
    let h = start(ServeConfig::new(2, Duration::from_millis(10)), false, Duration::ZERO);
    let handle = h.server.handle.clone();
    std::thread::scope(|s| {
        for id in 1..=5 {
            let hd = handle.clone();
            s.spawn(move || {
                let y = fire(&hd, id as f32).unwrap();
                assert_eq!(y, vec![2.0 * id as f32, 1.0]);
            });
        }
    });
    let metrics = Arc::clone(&h.server.metrics);
    h.server.stop();
    let calls = h.calls.load(Ordering::SeqCst);
    assert!((3..=5).contains(&calls), "5 requests at batch 2 need 3–5 flushes, got {calls}");
    assert_eq!(metrics.total_requests(), 5);
}

#[test]
fn backend_error_fans_out_to_every_request_in_the_batch() {
    let h = start(ServeConfig::new(4, Duration::from_millis(20)), true, Duration::ZERO);
    let handle = h.server.handle.clone();
    std::thread::scope(|s| {
        for id in 1..=3 {
            let hd = handle.clone();
            s.spawn(move || {
                let err = fire(&hd, id as f32).unwrap_err();
                assert!(
                    format!("{err:#}").contains("mock backend exploded"),
                    "request {id} must see the backend error, got: {err:#}"
                );
            });
        }
    });
    let failed = h.server.metrics.replica_stats(0).errors;
    assert!(failed >= 1, "failed batches must be counted");
    assert_eq!(h.server.metrics.total_requests(), 0, "errors are not successes");
    h.server.stop();
}

#[test]
fn shutdown_drains_pending_requests_promptly() {
    // Regression for the old `stop()`: the stop signal was polled once per
    // window and one handle-sender clone kept the channel alive, so stop
    // could stall a full max_wait and queued requests were silently
    // dropped. Now: enqueue under a 10s window, stop immediately — every
    // client must still get an answer, and stop must not wait out the
    // window.
    let h = start(ServeConfig::new(8, Duration::from_secs(10)), false, Duration::ZERO);
    let handle = h.server.handle.clone();
    let clients: Vec<_> = (1..=3)
        .map(|id| {
            let hd = handle.clone();
            std::thread::spawn(move || fire(&hd, id as f32))
        })
        .collect();
    std::thread::sleep(Duration::from_millis(100)); // let them enqueue
    let t0 = Instant::now();
    h.server.stop();
    assert!(t0.elapsed() < Duration::from_secs(5), "stop must not wait out the batch window");
    for c in clients {
        let y = c.join().unwrap().expect("queued request must be answered on shutdown");
        assert_eq!(y[1], 1.0);
    }
    // New submissions after stop fail fast.
    let err = fire(&handle, 9.0).unwrap_err();
    assert!(format!("{err:#}").contains("server stopped"));
}

#[test]
fn replicas_share_one_queue_and_metrics_add_up() {
    let h = start(
        ServeConfig::new(1, Duration::from_millis(1)).with_replicas(4),
        false,
        Duration::from_micros(200),
    );
    let handle = h.server.handle.clone();
    std::thread::scope(|s| {
        for id in 1..=32 {
            let hd = handle.clone();
            s.spawn(move || {
                let y = fire(&hd, id as f32).unwrap();
                assert_eq!(y[0], 2.0 * id as f32);
            });
        }
    });
    assert_eq!(h.server.metrics.total_requests(), 32);
    let per_replica: usize =
        (0..4).map(|r| h.server.metrics.replica_stats(r).requests).sum();
    assert_eq!(per_replica, 32, "per-replica counts must sum to the aggregate");
    h.server.stop();
}

#[test]
fn bounded_queue_applies_backpressure_without_losing_requests() {
    // Queue depth 2 with a slow backend: submitters block instead of
    // growing an unbounded queue, and every request completes.
    let h = start(
        ServeConfig::new(1, Duration::from_millis(1)).with_queue_depth(2),
        false,
        Duration::from_millis(2),
    );
    let handle = h.server.handle.clone();
    std::thread::scope(|s| {
        for id in 1..=16 {
            let hd = handle.clone();
            s.spawn(move || {
                let y = fire(&hd, id as f32).unwrap();
                assert_eq!(y[0], 2.0 * id as f32);
            });
        }
    });
    assert_eq!(h.server.metrics.total_requests(), 16);
    h.server.stop();
}

#[test]
fn replica_startup_failure_surfaces_and_joins_cleanly() {
    let factory: BackendFactory = Arc::new(|replica| {
        if replica == 1 {
            anyhow::bail!("replica {replica} refused to start");
        }
        let b: Box<dyn SpmmBackend> = Box::new(MockBackend {
            batch: 2,
            calls: Arc::new(AtomicUsize::new(0)),
            seen: Arc::new(Mutex::new(Vec::new())),
            fail: false,
            delay: Duration::ZERO,
        });
        Ok(b)
    });
    let err = match BatchServer::start(
        factory,
        ServeConfig::new(2, Duration::from_millis(1)).with_replicas(2),
    ) {
        Ok(_) => panic!("startup must fail when a replica's backend fails"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("refused to start"), "got: {err:#}");
}

#[test]
fn worker_panic_fails_requests_fast_instead_of_hanging() {
    // A backend that *panics* (as opposed to returning Err) kills its
    // worker; the engine must fail clients fast, not strand them on an
    // open queue forever.
    struct PanickingBackend;
    impl SpmmBackend for PanickingBackend {
        fn name(&self) -> &'static str {
            "panic"
        }
        fn d_in(&self) -> usize {
            D_IN
        }
        fn d_out(&self) -> usize {
            D_OUT
        }
        fn run_batch(&mut self, _x: &Matrix) -> Result<Matrix> {
            panic!("backend blew up");
        }
    }
    let factory: BackendFactory = Arc::new(|_replica| {
        let b: Box<dyn SpmmBackend> = Box::new(PanickingBackend);
        Ok(b)
    });
    let server =
        BatchServer::start(factory, ServeConfig::new(2, Duration::from_millis(1))).expect("start");
    let handle = server.handle.clone();
    // Rides into the panicking flush → response sender drops → error.
    assert!(handle.infer(vec![0.0; D_IN]).is_err());
    // Queue is closed (or drained) by the worker's unwind guard → errors,
    // never blocks.
    assert!(handle.infer(vec![1.0; D_IN]).is_err());
    server.stop();
}

#[test]
fn wrong_input_size_is_rejected_client_side() {
    let h = start(ServeConfig::new(2, Duration::from_millis(1)), false, Duration::ZERO);
    assert!(h.server.handle.infer(vec![0.0; D_IN + 1]).is_err());
    h.server.stop();
}
