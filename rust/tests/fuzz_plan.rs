//! Deterministic structure-aware fuzz smoke for `SpmmPlan` compilation
//! over adversarial `HinmPacked` inputs (DESIGN.md §17).
//!
//! Two properties, checked on every case derived from
//! `mix_seed(BASE_SEED, case_index)`:
//!
//! 1. **Validity is decidable**: a packing produced by `prune_oneshot`
//!    always passes `check_invariants`; a packing with one field mutated
//!    either fails `check_invariants` (the mutation was caught) or remains
//!    a *different but valid* packing.
//! 2. **Valid ⇒ runnable and bit-exact**: any packing that passes
//!    `check_invariants` must compile to a plan (any ISA tier, any batch
//!    block, any lane count) and execute bitwise-identical to
//!    `spmm_reference` on the same packing — compilation must never trust
//!    anything `check_invariants` does not guarantee.
//!
//! Shapes stay small (tiles ≤ 3, n ≤ 64, batch ≤ 8) so 10k iterations fit
//! the tier-1 debug-build budget; the CI `fuzz-long` job scales the count
//! via `HINM_FUZZ_ITERS` under an `HINM_FUZZ_SECONDS` wall-clock bound.
//! Failing cases persist their parameters to `target/fuzz-failures/`.

use hinm::sparsity::{prune_oneshot, HinmConfig, HinmPacked};
use hinm::spmm::{spmm_reference, KernelIsa, SpmmEngine, SpmmPlan, ValueFormat};
use hinm::tensor::Matrix;
use hinm::util::rng::{mix_seed, Xoshiro256};
use std::time::{Duration, Instant};

const BASE_SEED: u64 = 0x504C_414E_F077;

fn iters(default: usize) -> usize {
    if cfg!(miri) {
        return 32;
    }
    std::env::var("HINM_FUZZ_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn budget() -> Option<Duration> {
    std::env::var("HINM_FUZZ_SECONDS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_secs)
}

fn persist_failure(case: u64, detail: &str) -> String {
    let dir = std::env::var("HINM_FUZZ_ARTIFACTS")
        .unwrap_or_else(|_| "target/fuzz-failures".to_string());
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/plan-case{case}.txt");
    let _ = std::fs::write(&path, detail);
    path
}

fn gen_packed(rng: &mut Xoshiro256) -> (HinmPacked, usize) {
    let v = [4usize, 8][rng.below(2)];
    let tiles = 1 + rng.below(3);
    let m = v * tiles;
    let n = 4 * (3 + rng.below(14)); // 12..=64, multiple of M=4
    let sparsity = [0.0, 0.25, 0.5, 0.75][rng.below(4)];
    let cfg = HinmConfig::with_24(v, sparsity);
    let w = Matrix::randn(m, n, 1.0, rng);
    let sal = if rng.below(2) == 0 { w.abs() } else { Matrix::randn(m, n, 1.0, rng) };
    (prune_oneshot(&w, &sal, &cfg).packed, n)
}

/// Corrupt one structural field. Returns a human-readable tag for the
/// failure artifact.
fn mutate(rng: &mut Xoshiro256, p: &mut HinmPacked) -> &'static str {
    match rng.below(6) {
        0 => {
            // Duplicate a column id within a tile.
            let i = rng.below(p.vec_idx.len());
            let t = i / p.k_v;
            let j = t * p.k_v + rng.below(p.k_v);
            p.vec_idx[i] = p.vec_idx[j];
            "vec_idx duplicate"
        }
        1 => {
            let i = rng.below(p.vec_idx.len());
            p.vec_idx[i] = p.cols as i32 + rng.below(5) as i32;
            "vec_idx out of range"
        }
        2 => {
            let i = rng.below(p.nm_idx.len());
            p.nm_idx[i] = p.cfg.m_group as u8 + rng.below(3) as u8;
            "nm_idx out of group"
        }
        3 => {
            // Break the strictly-ascending in-group order.
            let i = rng.below(p.nm_idx.len());
            p.nm_idx[i] = 0;
            let j = (i / p.cfg.n_keep) * p.cfg.n_keep;
            p.nm_idx[j] = p.cfg.m_group as u8 - 1;
            "nm order broken"
        }
        4 => {
            p.vals.pop();
            "vals truncated"
        }
        _ => {
            // Value-only perturbation: always stays a valid packing.
            let i = rng.below(p.vals.len());
            p.vals[i] = -p.vals[i] * 3.0 + 1.0;
            "vals perturbed"
        }
    }
}

/// Property 2: any invariant-passing packing runs bit-exact vs the
/// reference under a randomly drawn execution config. `engines` is the
/// pre-spawned lane-count sweep (spawning a kernel pool per case would
/// dominate the run).
fn check_runs(
    p: &HinmPacked,
    n: usize,
    rng: &mut Xoshiro256,
    engines: &[SpmmEngine],
    case: u64,
    tag: &str,
) {
    let b = 1 + rng.below(8);
    let x = Matrix::randn(n, b, 1.0, rng);
    let want = spmm_reference(p, &x);
    let isas = KernelIsa::available();
    let isa = isas[rng.below(isas.len())];
    let mut plan = SpmmPlan::new(p).with_isa(isa);
    if rng.below(2) == 0 {
        plan = plan.with_batch_block(1 + rng.below(33));
    }
    let engine = &engines[rng.below(engines.len())];
    let got = engine.spmm_planned(&plan, &x);
    let bits = |m: &Matrix| m.data.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
    if bits(&got) != bits(&want) {
        let path = persist_failure(
            case,
            &format!("case {case} [{tag}]: {}x{} V={} batch {b} isa {isa}", p.rows, p.cols, p.cfg.v),
        );
        panic!("case {case} [{tag}]: plan output diverged from reference; params at {path}");
    }
    // bf16 arm: never bit-equal to f32, but must run and stay inside the
    // §16 rounding model |y16 − y32| ≤ Σ|wᵢxᵢ|/128 + 1e-5.
    if rng.below(4) == 0 {
        let y16 = engine.spmm_planned(&SpmmPlan::new(p).with_values(ValueFormat::Bf16), &x);
        let s = hinm::spmm::dense::matmul(&p.to_dense().abs(), &x.abs());
        for ((&a, &b32), &mag) in y16.data.iter().zip(&want.data).zip(&s.data) {
            if (a - b32).abs() > mag / 128.0 + 1e-5 {
                let path = persist_failure(case, &format!("case {case} [{tag}]: bf16 bound"));
                panic!("case {case}: bf16 outside rounding model; params at {path}");
            }
        }
    }
}

#[test]
fn fuzz_plan_compilation_smoke() {
    let n_iters = iters(10_000);
    let start = Instant::now();
    let deadline = budget();
    let mut done = 0usize;
    let mut mutants_valid = 0usize;
    let mut mutants_caught = 0usize;
    let engines: Vec<SpmmEngine> = (1..=4).map(SpmmEngine::new).collect();
    for case in 0..n_iters as u64 {
        if deadline.is_some_and(|d| start.elapsed() > d) {
            break;
        }
        let mut rng = Xoshiro256::new(mix_seed(BASE_SEED, case));
        let (packed, n) = gen_packed(&mut rng);
        if let Err(e) = packed.check_invariants() {
            let path = persist_failure(case, &format!("case {case}: fresh packing invalid: {e}"));
            panic!("case {case}: prune_oneshot produced an invalid packing ({e}); {path}");
        }
        if case % 2 == 0 {
            check_runs(&packed, n, &mut rng, &engines, case, "fresh");
        } else {
            let mut mutant = packed.clone();
            let tag = mutate(&mut rng, &mut mutant);
            match mutant.check_invariants() {
                // The invariant checker caught the corruption — done.
                Err(_) => mutants_caught += 1,
                // The mutation landed on another *valid* packing (e.g. a
                // value perturbation); then it must also run bit-exact.
                Ok(()) => {
                    mutants_valid += 1;
                    check_runs(&mutant, n, &mut rng, &engines, case, tag);
                }
            }
        }
        done += 1;
    }
    assert!(done > 0, "fuzz budget expired before the first case");
    // The generator must actually exercise both sides of property 1.
    if done >= 1000 {
        assert!(mutants_caught > 0, "no mutation was ever rejected");
        assert!(mutants_valid > 0, "no mutation ever stayed valid");
    }
    println!(
        "fuzz_plan: {done} cases ({mutants_caught} mutants caught, {mutants_valid} valid), {:?}",
        start.elapsed()
    );
}
