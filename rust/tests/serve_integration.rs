//! Batch-server integration over the real PJRT executor + ffn_serve
//! artifact: correctness under concurrency, padding of partial batches,
//! failure propagation, and clean shutdown. Skipped when artifacts are
//! absent (and the engine itself refuses to start when PJRT is stubbed,
//! which the startup-failure path covers).

use hinm::coordinator::serve::{packed_host_tensors, BatchServer, HostTensor, ServeConfig};
use hinm::runtime::Registry;
use hinm::sparsity::{prune_oneshot, HinmConfig, HinmPacked};
use hinm::tensor::Matrix;
use std::time::Duration;

fn registry() -> Option<Registry> {
    match hinm::runtime::open_default_registry() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e:#})");
            None
        }
    }
}

struct Setup {
    server: BatchServer,
    p1: HinmPacked,
    p2: HinmPacked,
    d: usize,
}

fn start(reg: &Registry) -> Option<Setup> {
    let spec = reg.artifact("ffn_serve").unwrap().clone();
    let d = spec.meta["d"] as usize;
    let d_ff = spec.meta["d_ff"] as usize;
    let batch = spec.meta["batch"] as usize;
    let cfg = HinmConfig::with_24(spec.meta["v"] as usize, spec.meta["sv"]);
    let w1 = reg.load_data("ffn_w1_dense").unwrap();
    let w2 = reg.load_data("ffn_w2_dense").unwrap();
    let w1 = Matrix::from_vec(d_ff, d, w1.as_f32().unwrap().to_vec());
    let w2 = Matrix::from_vec(d, d_ff, w2.as_f32().unwrap().to_vec());
    let p1 = prune_oneshot(&w1, &w1.abs(), &cfg).packed;
    let p2 = prune_oneshot(&w2, &w2.abs(), &cfg).packed;
    let mut fixed = packed_host_tensors(&p1);
    fixed.extend(packed_host_tensors(&p2));
    match BatchServer::start_pjrt(
        spec,
        fixed,
        d,
        d,
        ServeConfig::new(batch, Duration::from_millis(1)),
    ) {
        Ok(server) => Some(Setup { server, p1, p2, d }),
        Err(e) => {
            eprintln!("SKIP: PJRT backend unavailable ({e:#})");
            None
        }
    }
}

fn gelu(x: f32) -> f32 {
    hinm::models::chain::gelu(x)
}

fn rust_ffn(p1: &HinmPacked, p2: &HinmPacked, x: &[f32]) -> Vec<f32> {
    let xm = Matrix::from_vec(x.len(), 1, x.to_vec());
    let h = hinm::spmm::spmm(p1, &xm);
    let h = Matrix { rows: h.rows, cols: h.cols, data: h.data.iter().map(|&v| gelu(v)).collect() };
    hinm::spmm::spmm(p2, &h).data
}

#[test]
fn single_request_partial_batch_is_padded_and_correct() {
    let Some(reg) = registry() else { return };
    let Some(s) = start(&reg) else { return };
    let x: Vec<f32> = (0..s.d).map(|j| (j as f32 * 0.02).cos()).collect();
    let y = s.server.handle.infer(x.clone()).unwrap();
    let y_ref = rust_ffn(&s.p1, &s.p2, &x);
    let diff = y.iter().zip(&y_ref).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(diff < 2e-3, "diff {diff}");
    s.server.stop();
}

#[test]
fn concurrent_clients_get_their_own_answers() {
    let Some(reg) = registry() else { return };
    let Some(s) = start(&reg) else { return };
    let d = s.d;
    let handles: Vec<_> = (0..24)
        .map(|i| {
            let h = s.server.handle.clone();
            std::thread::spawn(move || {
                let x: Vec<f32> = (0..d).map(|j| ((i * 7 + j) % 11) as f32 * 0.1).collect();
                (x.clone(), h.infer(x).unwrap())
            })
        })
        .collect();
    for h in handles {
        let (x, y) = h.join().unwrap();
        let y_ref = rust_ffn(&s.p1, &s.p2, &x);
        let diff = y.iter().zip(&y_ref).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(diff < 2e-3, "concurrent response mismatch: {diff}");
    }
    assert_eq!(s.server.metrics.total_requests(), 24);
    s.server.stop();
}

#[test]
fn wrong_input_size_is_rejected_client_side() {
    let Some(reg) = registry() else { return };
    let Some(s) = start(&reg) else { return };
    assert!(s.server.handle.infer(vec![0.0; 3]).is_err());
    s.server.stop();
}

#[test]
fn bad_fixed_inputs_fail_the_first_request_not_hang() {
    let Some(reg) = registry() else { return };
    // Fixed inputs with a wrong shape: compilation succeeds (shapes are
    // only validated at run time), so the server starts; the *first
    // request* must come back as an error rather than hang.
    let spec = reg.artifact("ffn_serve").unwrap().clone();
    let d = spec.meta["d"] as usize;
    let bad_fixed = vec![HostTensor::F32(vec![0.0; 8], vec![8])];
    let server = match BatchServer::start_pjrt(
        spec,
        bad_fixed,
        d,
        d,
        ServeConfig::new(4, Duration::from_millis(1)),
    ) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: PJRT backend unavailable ({e:#})");
            return;
        }
    };
    let err = server.handle.infer(vec![0.0; d]);
    assert!(err.is_err(), "bad fixed inputs must fail the request");
    server.stop();
}
