//! Chaos tests for cross-host pipeline stages (DESIGN.md §20): a real
//! head (engine + HTTP front, or a bare [`RemotePipelinedBackend`])
//! driving stage peers that die, stall, or corrupt the stream, with the
//! §19 failure taxonomy asserted to *exact* typed errors and counter
//! values. Nothing here sleeps to "let things settle": every ordering is
//! forced by parsing child ready lines, holding scripted sockets, or the
//! head's own pinned deadlines, so the counts replay bit-for-bit.
//!
//! Scenarios:
//! - SIGKILL a stage child mid-stream → that batch fails with a typed
//!   502 (never a hang), the link reconnects once the child is back, and
//!   both metric formats show exactly one `unreachable` failure and one
//!   reconnect on that link — the other link untouched.
//! - A peer that accepts frames but never answers → typed 504 after the
//!   pinned per-try deadline, `timeout` failures counted per try,
//!   connection re-established between tries.
//! - A peer that answers with a corrupted checksum → typed 502 protocol
//!   error, the connection is dropped (a desynced stream is
//!   unrecoverable) and the retry runs clean over a fresh connection.

use hinm::coordinator::{BackendFactory, BatchServer, InferError, ServeConfig, StageLinkMetrics};
use hinm::net::stage_wire::{Frame, FrameCodec};
use hinm::net::{protocol, HttpClient, HttpFront};
use hinm::runtime::{RemotePipelinedBackend, SpmmBackend, StageLinkConfig};
use hinm::tensor::Matrix;
use hinm::util::json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A spawned `hinm stage` child, killed (SIGKILL) and reaped on drop.
struct StageChild {
    child: Child,
    addr: String,
}

impl StageChild {
    fn spawn(model: &str, stage: usize, stages: usize, listen: &str) -> StageChild {
        let spec = format!("{stage}/{stages}");
        let mut child = Command::new(env!("CARGO_BIN_EXE_hinm"))
            .args(["stage", "--stage", &spec, "--model", model, "--seed", "7", "--listen", listen])
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn hinm stage");
        let stdout = child.stdout.take().expect("child stdout piped");
        let mut lines = BufReader::new(stdout).lines();
        let addr = loop {
            let line = match lines.next() {
                Some(Ok(line)) => line,
                other => {
                    let _ = child.kill();
                    let _ = child.wait();
                    panic!("stage child exited before ready line: {other:?}");
                }
            };
            if let Some(rest) = line.split("listening on ").nth(1) {
                break rest.split(" |").next().unwrap_or(rest).trim().to_string();
            }
        };
        StageChild { child, addr }
    }

    /// SIGKILL — no shutdown handshake, exactly the chaos we are testing.
    fn sigkill(&mut self) {
        self.child.kill().expect("kill stage child");
        self.child.wait().expect("reap stage child");
    }
}

impl Drop for StageChild {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// A port we can hand to a child twice (kill + restart on the same
/// address): bind an ephemeral listener, note the port, release it.
fn reserve_port() -> u16 {
    let l = TcpListener::bind("127.0.0.1:0").expect("reserve port");
    l.local_addr().expect("reserved addr").port()
}

/// In-process `--stage-hosts` head: batch-1 single-replica engine over
/// `RemotePipelinedBackend` plus an HTTP front exposing the link
/// counters, so one HTTP request maps to exactly one link round-trip.
fn start_head(
    hosts: Vec<String>,
    dims: (usize, usize),
    lcfg: StageLinkConfig,
) -> (BatchServer, HttpFront, Arc<StageLinkMetrics>) {
    let links = StageLinkMetrics::new(&hosts);
    let factory_links = Arc::clone(&links);
    let factory: BackendFactory = Arc::new(move |_replica| {
        let b: Box<dyn SpmmBackend> = Box::new(RemotePipelinedBackend::connect(
            &hosts,
            dims.0,
            dims.1,
            lcfg.clone(),
            Arc::clone(&factory_links),
        )?);
        Ok(b)
    });
    let scfg = ServeConfig::new(1, Duration::ZERO).with_replicas(1).with_queue_depth(16);
    let server = BatchServer::start(factory, scfg).expect("start head engine");
    let front = HttpFront::start_with_links(
        "127.0.0.1:0",
        server.handle.clone(),
        None,
        None,
        Some(Arc::clone(&links)),
        2,
    )
    .expect("start http front");
    (server, front, links)
}

fn infer(client: &mut HttpClient, x: &[f32]) -> (u16, String) {
    let body = protocol::InferRequest::new(x.to_vec()).to_json().compact();
    client.post_json("/v1/infer", &body).expect("infer round-trip")
}

/// Pull the `stage_links` row for `host` out of a `/v1/metrics` body.
fn link_row(body: &str, host: &str) -> json::Json {
    let doc = json::parse(body).expect("metrics json");
    let rows = doc.get("stage_links").as_arr().expect("stage_links array");
    rows.iter()
        .find(|r| r.get("host").as_str() == Some(host))
        .cloned()
        .unwrap_or_else(|| panic!("no stage_links row for {host}: {body}"))
}

fn assert_counters(
    body: &str,
    host: &str,
    batches: f64,
    reconnects: f64,
    unreachable: f64,
    timeout: f64,
    protocol_: f64,
) {
    let row = link_row(body, host);
    assert_eq!(row.get("batches").as_f64(), Some(batches), "{host} batches: {body}");
    assert_eq!(row.get("reconnects").as_f64(), Some(reconnects), "{host} reconnects: {body}");
    assert_eq!(
        row.get("failures_unreachable").as_f64(),
        Some(unreachable),
        "{host} unreachable: {body}"
    );
    assert_eq!(row.get("failures_timeout").as_f64(), Some(timeout), "{host} timeout: {body}");
    assert_eq!(row.get("failures_protocol").as_f64(), Some(protocol_), "{host} protocol: {body}");
}

/// SIGKILL a stage host mid-stream: the in-flight batch fails with a
/// typed 502 within the link deadline (no hang, no retry storm), the
/// healthy link is untouched, and once the child is restarted on the
/// same address the next request reconnects and answers 200 — with the
/// whole story told by exact counters in both metric formats.
#[test]
fn sigkill_mid_stream_yields_typed_502_then_reconnects() {
    let port1 = reserve_port();
    let host1 = format!("127.0.0.1:{port1}");
    let mut stage1 = StageChild::spawn("ffn-relu", 1, 2, &host1);
    let stage2 = StageChild::spawn("ffn-relu", 2, 2, "127.0.0.1:0");
    let hosts = vec![stage1.addr.clone(), stage2.addr.clone()];

    let lcfg = StageLinkConfig {
        io_timeout_ms: 2_000,
        connect_attempts: 2,
        backoff_base_ms: 10,
        backoff_max_ms: 20,
        ..StageLinkConfig::default()
    };
    // ffn-relu is 32→32; the head never builds the model, it only needs
    // the end-to-end dims (the stage hosts own the weights).
    let (server, front, _links) = start_head(hosts.clone(), (32, 32), lcfg);
    let mut client = HttpClient::connect(front.local_addr()).expect("connect front");
    let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.25).sin()).collect();

    // 1. Healthy round-trip through both hosts.
    let (status, first) = infer(&mut client, &x);
    assert_eq!(status, 200, "healthy round-trip: {first}");

    // 2. SIGKILL stage 1, then infer again: the head's link is dead, the
    // batch fails with a typed 502 — bounded by the link deadline, so
    // this cannot hang even if the kernel swallowed the write.
    stage1.sigkill();
    let t0 = Instant::now();
    let (status, body) = infer(&mut client, &x);
    assert_eq!(status, 502, "dead stage host must type as bad gateway: {body}");
    assert!(body.contains("bad_gateway"), "typed error body: {body}");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "mid-batch death must fail fast, took {:?}",
        t0.elapsed()
    );

    // 3. Restart on the same address (ready line parsed before the next
    // request — no sleeps), and the link recovers on first contact.
    let stage1b = StageChild::spawn("ffn-relu", 1, 2, &host1);
    assert_eq!(stage1b.addr, host1, "restart must reclaim the reserved address");
    let (status, third) = infer(&mut client, &x);
    assert_eq!(status, 200, "post-restart round-trip: {third}");
    assert_eq!(third, first, "recovered chain must answer identically");

    // 4. Exact counters, JSON format: the dead link saw 2 good batches
    // (before + after), 1 unreachable failure, 1 reconnect; the healthy
    // link saw the same 2 batches and nothing else — the failed batch
    // never reached it.
    let (status, metrics) = client.get("/v1/metrics").expect("metrics json");
    assert_eq!(status, 200);
    assert_counters(&metrics, &hosts[0], 2.0, 1.0, 1.0, 0.0, 0.0);
    assert_counters(&metrics, &hosts[1], 2.0, 0.0, 0.0, 0.0, 0.0);

    // 5. Same counters, Prometheus text exposition format.
    let (status, prom) = client.get("/v1/metrics?format=prometheus").expect("metrics prom");
    assert_eq!(status, 200);
    for line in [
        format!("hinm_stage_link_batches_total{{host=\"{}\"}} 2", hosts[0]),
        format!("hinm_stage_link_reconnects_total{{host=\"{}\"}} 1", hosts[0]),
        format!("hinm_stage_link_failures_total{{host=\"{}\",class=\"unreachable\"}} 1", hosts[0]),
        format!("hinm_stage_link_failures_total{{host=\"{}\",class=\"timeout\"}} 0", hosts[0]),
        format!("hinm_stage_link_failures_total{{host=\"{}\",class=\"protocol\"}} 0", hosts[0]),
        format!("hinm_stage_link_batches_total{{host=\"{}\"}} 2", hosts[1]),
        format!("hinm_stage_link_reconnects_total{{host=\"{}\"}} 0", hosts[1]),
        format!("hinm_stage_link_failures_total{{host=\"{}\",class=\"unreachable\"}} 0", hosts[1]),
    ] {
        assert!(prom.contains(&line), "missing exposition line {line:?} in:\n{prom}");
    }

    front.stop();
    server.stop();
}

/// A stage peer that accepts the connection and reads frames but never
/// answers: each try fails with a typed 504 once the pinned per-try
/// deadline lapses — the head never hangs on a stalled host — and the
/// link reconnects between tries (the stalled connection is presumed
/// desynchronized and dropped).
#[test]
fn stall_past_link_deadline_yields_typed_504() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("stall peer");
    let addr = listener.local_addr().expect("peer addr").to_string();
    // Hold accepted sockets so the peer stays "up but silent"; further
    // connects succeed off the backlog even after this thread is done.
    let (tx, rx) = std::sync::mpsc::channel::<TcpStream>();
    let acceptor = std::thread::spawn(move || {
        if let Ok((s, _)) = listener.accept() {
            let _ = tx.send(s);
        }
        listener // keep the listener (and its backlog) alive with the test
    });

    let lcfg = StageLinkConfig {
        io_timeout_ms: 300,
        connect_attempts: 1,
        backoff_base_ms: 1,
        backoff_max_ms: 2,
        ..StageLinkConfig::default()
    };
    let (server, front, links) = start_head(vec![addr.clone()], (8, 8), lcfg);
    let _held = rx.recv_timeout(Duration::from_secs(10)).expect("peer accepted");
    let mut client = HttpClient::connect(front.local_addr()).expect("connect front");
    let x: Vec<f32> = (0..8).map(|i| i as f32).collect();

    for round in 1..=2u64 {
        let t0 = Instant::now();
        let (status, body) = infer(&mut client, &x);
        assert_eq!(status, 504, "round {round}: stall must type as a timeout: {body}");
        assert!(body.contains("upstream_timeout"), "round {round}: typed body: {body}");
        let took = t0.elapsed();
        assert!(
            took >= Duration::from_millis(300),
            "round {round}: failed before the 300 ms deadline ({took:?})"
        );
        assert!(
            took < Duration::from_secs(10),
            "round {round}: stalled host must not hang the head ({took:?})"
        );
    }

    // Round 1 timed out on the eagerly-connected link; round 2 had to
    // re-establish first (one reconnect) and then timed out again.
    let (status, metrics) = client.get("/v1/metrics").expect("metrics json");
    assert_eq!(status, 200);
    assert_counters(&metrics, &addr, 0.0, 1.0, 0.0, 2.0, 0.0);
    let snap = links.snapshot();
    assert_eq!(snap.links[0].failures_timeout, 2);
    assert_eq!(snap.links[0].batches, 0);

    front.stop();
    server.stop();
    let _listener = acceptor.join().expect("acceptor joins");
}

/// A stage peer that answers with a flipped payload byte (checksum no
/// longer matches): the head types the batch as a 502 protocol error and
/// drops the connection — a desynced stream is unrecoverable — then the
/// next batch re-establishes and completes over a clean connection.
#[test]
fn corrupt_frame_drops_connection_then_reestablishes() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("corrupt peer");
    let addr = listener.local_addr().expect("peer addr").to_string();

    let peer = std::thread::spawn(move || {
        let mut codec = FrameCodec::new();
        let mut m = Matrix::zeros(0, 0);

        // Connection 1: echo the activations back, but flip the first
        // payload byte after sealing the frame — the checksum in the
        // trailer no longer matches the bytes on the wire.
        let (mut s, _) = listener.accept().expect("conn 1");
        let seq = match codec.read_into(&mut s, &mut m).expect("read request 1") {
            Frame::Activations { seq } => seq,
            other => panic!("expected activations, got {other:?}"),
        };
        let mut buf = Vec::new();
        codec.write_activations(&mut buf, seq, &m).expect("encode echo");
        buf[24] ^= 0x01; // 4-byte length prefix + 20-byte header = first payload byte
        s.write_all(&buf).expect("send corrupted frame");
        s.flush().expect("flush corrupted frame");

        // The head drops that connection; serve the retry cleanly.
        let (mut s2, _) = listener.accept().expect("conn 2");
        let seq2 = match codec.read_into(&mut s2, &mut m).expect("read request 2") {
            Frame::Activations { seq } => seq,
            other => panic!("expected activations, got {other:?}"),
        };
        codec.write_activations(&mut s2, seq2, &m).expect("send clean echo");
        s2.flush().expect("flush clean echo");
    });

    let hosts = vec![addr.clone()];
    let links = StageLinkMetrics::new(&hosts);
    let lcfg = StageLinkConfig {
        io_timeout_ms: 5_000,
        connect_attempts: 2,
        backoff_base_ms: 1,
        backoff_max_ms: 2,
        ..StageLinkConfig::default()
    };
    let mut backend =
        RemotePipelinedBackend::connect(&hosts, 3, 3, lcfg, Arc::clone(&links)).expect("connect");

    let x = Matrix::from_vec(3, 2, vec![1.0, -0.0, f32::MIN_POSITIVE, 2.5, -7.0, 0.125]);

    // Batch 1: corrupted reply → typed protocol 502, connection dropped.
    let err = backend.run_batch(&x).expect_err("corrupted frame must fail the batch");
    let typed = err
        .chain()
        .find_map(|c| c.downcast_ref::<InferError>())
        .expect("typed InferError in the chain");
    assert!(
        matches!(typed, InferError::Upstream(m) if m.contains("protocol error")),
        "wrong taxonomy class for a corrupt frame: {typed:?}"
    );

    // Batch 2: reconnect + clean echo, bit-exact (the scripted peer
    // echoes, so output bits == input bits, including -0.0).
    let y = backend.run_batch(&x).expect("clean retry");
    assert_eq!(
        y.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        x.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "echo must round-trip bit-exactly"
    );

    let snap = links.snapshot();
    assert_eq!(snap.links[0].failures_protocol, 1, "exactly one protocol failure");
    assert_eq!(snap.links[0].failures_unreachable, 0);
    assert_eq!(snap.links[0].failures_timeout, 0);
    assert_eq!(snap.links[0].reconnects, 1, "exactly one re-establishment");
    assert_eq!(snap.links[0].batches, 1, "only the clean batch counts");

    drop(backend);
    peer.join().expect("peer joins");
}
