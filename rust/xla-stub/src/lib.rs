//! Host-side stub of the `xla` crate (xla-rs) API subset used by `hinm`.
//!
//! The offline build environment has no libxla/PJRT to link, but the
//! runtime layer's *host* functionality — literals as typed shaped buffers,
//! shape/dtype introspection, tuple decomposition — is ordinary Rust. This
//! crate implements that for real, so everything up to the device boundary
//! (the batch server's host tensors, the trainer's parameter plumbing, the
//! literal round-trip tests) builds and runs; only the execution entry
//! points (`PjRtClient::cpu`, `compile`, `execute`) report that PJRT is
//! unavailable. The artifact-gated integration tests already skip when
//! `make artifacts` has not run, so the stub keeps the full non-PJRT test
//! suite green.
//!
//! Swapping in the real crate is a one-line change in `rust/Cargo.toml`
//! (point the `xla` dependency at xla-rs); the signatures here are
//! compatible with the subset `hinm` calls.

use std::fmt;

/// Stub error type (the real crate's `Error` carries status codes).
#[derive(Clone, Debug)]
pub struct Error(String);

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error(s.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const NO_PJRT: &str = "PJRT unavailable: built against the in-repo `xla` stub (rust/xla-stub); link the real xla crate to compile/execute AOT artifacts";

/// Element types `hinm` produces, plus enough of the real enum that
/// downstream wildcard match arms stay reachable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F16,
    Bf16,
    F32,
    F64,
}

#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side literal: a typed buffer plus its dimensions.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Shape of an array (non-tuple) literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// Rust scalar types that map onto an XLA element type.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn rank1_literal(data: &[Self]) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn rank1_literal(data: &[Self]) -> Literal {
        Literal { data: Data::F32(data.to_vec()), dims: vec![data.len() as i64] }
    }
    fn extract(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error::msg("literal element type is not F32")),
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn rank1_literal(data: &[Self]) -> Literal {
        Literal { data: Data::I32(data.to_vec()), dims: vec![data.len() as i64] }
    }
    fn extract(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error::msg("literal element type is not S32")),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::rank1_literal(data)
    }

    /// Rank-0 f32 literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { data: Data::F32(vec![x]), dims: Vec::new() }
    }

    /// Tuple literal (as produced by multi-output computations).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { data: Data::Tuple(elements), dims: Vec::new() }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::msg(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the buffer out as a host `Vec`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    pub fn ty(&self) -> Result<ElementType> {
        match &self.data {
            Data::F32(_) => Ok(ElementType::F32),
            Data::I32(_) => Ok(ElementType::S32),
            Data::Tuple(_) => Err(Error::msg("tuple literal has no element type")),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty()? })
    }

    /// Decompose a tuple literal; a non-tuple comes back as a singleton.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Ok(vec![self]),
        }
    }
}

/// Parsed HLO module. The stub validates the file exists and is readable
/// (so "missing artifact" errors surface exactly as with the real crate)
/// but retains nothing compilable.
#[derive(Clone, Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path)
            .map_err(|e| Error::msg(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto)
    }
}

#[derive(Clone, Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT CPU client — construction always fails in the stub, which the
/// callers in `hinm::runtime` surface as a clean "artifacts cannot run
/// here" error on the PJRT path only.
#[derive(Clone, Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::msg(NO_PJRT))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::msg(NO_PJRT))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::msg(NO_PJRT))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::msg(NO_PJRT))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_shape() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.element_type(), ElementType::F32);
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
    }

    #[test]
    fn i32_literal_and_type_mismatch() {
        let lit = Literal::vec1(&[5i32, -7]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![5, -7]);
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.ty().unwrap(), ElementType::S32);
    }

    #[test]
    fn reshape_rejects_bad_element_count() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(lit.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn scalar_has_empty_dims() {
        let s = Literal::scalar(3.5);
        assert_eq!(s.element_count(), 1);
        assert!(s.array_shape().unwrap().dims().is_empty());
    }

    #[test]
    fn tuple_decomposes_and_singleton_passthrough() {
        let t = Literal::tuple(vec![Literal::scalar(1.0), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![2]);
        let single = Literal::scalar(9.0).to_tuple().unwrap();
        assert_eq!(single.len(), 1);
    }

    #[test]
    fn pjrt_entry_points_report_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"));
    }
}
