//! Request latency metrics (p50/p95/p99) and simple counters for the
//! serving path and the fine-tune driver.

use std::time::Duration;

/// Records request latencies; percentile queries sort on demand.
#[derive(Clone, Debug, Default)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}µs p50={:.1}µs p95={:.1}µs p99={:.1}µs",
            self.count(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0)
        )
    }
}

/// Throughput meter: items per second over a wall-clock window.
#[derive(Debug)]
pub struct Throughput {
    start: std::time::Instant,
    items: usize,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Self { start: std::time::Instant::now(), items: 0 }
    }
    pub fn add(&mut self, n: usize) {
        self.items += n;
    }
    pub fn per_sec(&self) -> f64 {
        self.items as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }
    pub fn items(&self) -> usize {
        self.items
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut r = LatencyRecorder::new();
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1000] {
            r.record(Duration::from_micros(us));
        }
        assert_eq!(r.count(), 10);
        assert!(r.percentile(50.0) <= r.percentile(95.0));
        assert!(r.percentile(95.0) <= r.percentile(99.0));
        assert!((r.mean() - 550.0).abs() < 1.0);
    }

    #[test]
    fn empty_recorder_safe() {
        let r = LatencyRecorder::new();
        assert_eq!(r.percentile(99.0), 0.0);
        assert_eq!(r.mean(), 0.0);
        assert!(r.summary().contains("n=0"));
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add(10);
        t.add(5);
        assert_eq!(t.items(), 15);
        assert!(t.per_sec() > 0.0);
    }
}
