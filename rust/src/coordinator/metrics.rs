//! Request metrics for the serving engine and the fine-tune driver:
//! bounded-memory latency percentiles, a throughput meter, the
//! per-replica + aggregate views the sharded batch server reports, and
//! the per-model routing counters the multi-model registry front adds
//! to `/v1/metrics` (DESIGN.md §18).

use super::serve::Priority;
use crate::util::sync::lock_unpoisoned;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Records request latencies in a fixed-capacity ring buffer.
///
/// Long-running servers record forever, so the recorder keeps (a) running
/// aggregates over *every* sample (count, mean) and (b) a bounded window of
/// the most recent `cap` samples for percentile queries. Percentile reads
/// sort the retained window once per call, however many percentiles are
/// requested — `summary()` is one sort, not three.
#[derive(Clone, Debug)]
pub struct LatencyRecorder {
    samples_us: Vec<f64>,
    /// Next overwrite position once the ring is full.
    head: usize,
    /// Total samples ever recorded (≥ retained window size).
    total: u64,
    /// Running sum over all samples ever recorded.
    sum_us: f64,
    cap: usize,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    /// Default retained-window capacity (samples).
    pub const DEFAULT_CAP: usize = 65_536;

    /// Recorder with the default retained-window capacity.
    pub fn new() -> Self {
        Self::with_capacity(Self::DEFAULT_CAP)
    }

    /// Recorder retaining at most `cap` samples for percentile queries.
    pub fn with_capacity(cap: usize) -> Self {
        Self { samples_us: Vec::new(), head: 0, total: 0, sum_us: 0.0, cap: cap.max(1) }
    }

    /// Record one latency sample.
    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    /// Record one latency sample given in microseconds.
    pub fn record_us(&mut self, us: f64) {
        self.total += 1;
        self.sum_us += us;
        if self.samples_us.len() < self.cap {
            self.samples_us.push(us);
        } else {
            self.samples_us[self.head] = us;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// Total samples ever recorded (not capped).
    pub fn count(&self) -> usize {
        self.total as usize
    }

    /// Samples currently retained for percentile queries (≤ capacity).
    pub fn retained(&self) -> usize {
        self.samples_us.len()
    }

    /// Mean over all samples ever recorded.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    /// Percentiles (in %) over the retained window; one sort per call.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.samples_us.is_empty() {
            return vec![0.0; ps.len()];
        }
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        ps.iter()
            .map(|&p| {
                let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
                s[idx.min(s.len() - 1)]
            })
            .collect()
    }

    /// One percentile (in %) over the retained window.
    pub fn percentile(&self, p: f64) -> f64 {
        self.percentiles(&[p])[0]
    }

    /// One-line `n/mean/p50/p95/p99` summary.
    pub fn summary(&self) -> String {
        let pct = self.percentiles(&[50.0, 95.0, 99.0]);
        format!(
            "n={} mean={:.1}µs p50={:.1}µs p95={:.1}µs p99={:.1}µs",
            self.count(),
            self.mean(),
            pct[0],
            pct[1],
            pct[2]
        )
    }
}

/// Throughput meter: items per second over a wall-clock window.
#[derive(Debug)]
pub struct Throughput {
    start: std::time::Instant,
    items: usize,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    /// Meter starting now with zero items.
    pub fn new() -> Self {
        Self { start: std::time::Instant::now(), items: 0 }
    }
    /// Count `n` completed items.
    pub fn add(&mut self, n: usize) {
        self.items += n;
    }
    /// Items per second since construction.
    pub fn per_sec(&self) -> f64 {
        self.items as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }
    /// Total items counted.
    pub fn items(&self) -> usize {
        self.items
    }
}

/// Scheduler-level counters: how many requests each [`Priority`] class has
/// completed, and how many were answered with a timeout error instead of
/// being computed (split by *where* the expiry was detected).
#[derive(Clone, Debug, Default)]
pub struct SchedulerStats {
    /// Successfully served requests, indexed by [`Priority::index`]
    /// (High=0, Normal=1, Low=2).
    pub served: [usize; 3],
    /// Requests rejected at submission because their deadline had already
    /// passed; they never entered the queue.
    pub expired_at_enqueue: usize,
    /// Requests whose deadline passed while they were queued (or while the
    /// batch window was open); answered with a timeout error, never
    /// executed.
    pub expired_in_queue: usize,
}

impl SchedulerStats {
    /// Served count for one priority class.
    pub fn served_for(&self, p: Priority) -> usize {
        self.served[p.index()]
    }

    /// Total requests answered with a timeout error (both expiry points).
    pub fn expired_total(&self) -> usize {
        self.expired_at_enqueue + self.expired_in_queue
    }
}

/// Per-replica counters for the sharded batch server.
#[derive(Clone, Debug, Default)]
pub struct ReplicaStats {
    /// Latency over this replica's successful requests.
    pub latency: LatencyRecorder,
    /// Batches flushed (successful executions).
    pub batches: usize,
    /// Requests answered successfully.
    pub requests: usize,
    /// Failed batch executions (every request in them got an error).
    pub errors: usize,
}

/// Aggregate + per-replica metrics for one serving engine instance.
///
/// Workers lock only their own replica slot plus the aggregate recorder per
/// flush; locks are never nested, so replicas never contend on each other.
#[derive(Debug)]
pub struct EngineMetrics {
    /// Latency over every successful request, across all replicas.
    pub aggregate: Mutex<LatencyRecorder>,
    /// Successful-request throughput since engine start.
    pub throughput: Mutex<Throughput>,
    /// One counter block per worker replica.
    pub replicas: Vec<Mutex<ReplicaStats>>,
    /// Per-priority served counts and deadline-expiry counters.
    pub scheduler: Mutex<SchedulerStats>,
}

impl EngineMetrics {
    /// Fresh metrics for an engine with `replicas` workers.
    pub fn new(replicas: usize) -> Self {
        Self {
            aggregate: Mutex::new(LatencyRecorder::new()),
            throughput: Mutex::new(Throughput::new()),
            replicas: (0..replicas).map(|_| Mutex::new(ReplicaStats::default())).collect(),
            scheduler: Mutex::new(SchedulerStats::default()),
        }
    }

    /// Requests answered successfully across all replicas.
    pub fn total_requests(&self) -> usize {
        lock_unpoisoned(&self.aggregate).count()
    }

    /// Snapshot of the aggregate latency recorder.
    pub fn aggregate_latency(&self) -> LatencyRecorder {
        lock_unpoisoned(&self.aggregate).clone()
    }

    /// Snapshot of one replica's counters.
    pub fn replica_stats(&self, replica: usize) -> ReplicaStats {
        lock_unpoisoned(&self.replicas[replica]).clone()
    }

    /// Snapshot of the scheduler counters (per-priority served + expiry).
    pub fn scheduler_stats(&self) -> SchedulerStats {
        lock_unpoisoned(&self.scheduler).clone()
    }

    /// Successful requests per second since the engine started.
    pub fn requests_per_sec(&self) -> f64 {
        lock_unpoisoned(&self.throughput).per_sec()
    }

    /// Multi-line human-readable report: aggregate latency/throughput,
    /// per-priority + expiry counts, then one line per replica.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "aggregate: {} | {:.0} req/s",
            self.aggregate_latency().summary(),
            self.requests_per_sec()
        );
        let sched = self.scheduler_stats();
        s.push_str(&format!(
            "\n  priorities: high={} normal={} low={} | expired: {} at enqueue, {} in queue",
            sched.served_for(Priority::High),
            sched.served_for(Priority::Normal),
            sched.served_for(Priority::Low),
            sched.expired_at_enqueue,
            sched.expired_in_queue
        ));
        for (i, m) in self.replicas.iter().enumerate() {
            let st = lock_unpoisoned(m);
            s.push_str(&format!(
                "\n  replica {i}: {} batches, {} reqs, {} failed batches | {}",
                st.batches,
                st.requests,
                st.errors,
                st.latency.summary()
            ));
        }
        s
    }
}

/// Per-model request counters for multi-model serving (DESIGN.md §18):
/// how many `/v1/infer` requests were *routed* to each model name,
/// counted at routing time (before queueing), so operators can see
/// traffic share per model even for requests that later expire. Shared
/// (`Arc`) between the HTTP front and whoever renders `/v1/metrics`.
/// `BTreeMap` keeps snapshots deterministically ordered by name.
#[derive(Debug, Default)]
pub struct ModelCounters {
    routed: Mutex<BTreeMap<String, u64>>,
}

impl ModelCounters {
    /// Fresh shared counters.
    pub fn new_shared() -> Arc<ModelCounters> {
        Arc::new(ModelCounters::default())
    }

    /// Count one request routed to `model`.
    pub fn record(&self, model: &str) {
        let mut m = lock_unpoisoned(&self.routed);
        *m.entry(model.to_string()).or_insert(0) += 1;
    }

    /// Snapshot of `(name, routed_requests)`, sorted by name.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        lock_unpoisoned(&self.routed).iter().map(|(k, &v)| (k.clone(), v)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_counters_accumulate_sorted() {
        let c = ModelCounters::new_shared();
        c.record("b");
        c.record("a");
        c.record("b");
        assert_eq!(
            c.snapshot(),
            vec![("a".to_string(), 1), ("b".to_string(), 2)]
        );
    }

    #[test]
    fn percentiles_ordered() {
        let mut r = LatencyRecorder::new();
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1000] {
            r.record(Duration::from_micros(us));
        }
        assert_eq!(r.count(), 10);
        assert!(r.percentile(50.0) <= r.percentile(95.0));
        assert!(r.percentile(95.0) <= r.percentile(99.0));
        assert!((r.mean() - 550.0).abs() < 1.0);
    }

    #[test]
    fn empty_recorder_safe() {
        let r = LatencyRecorder::new();
        assert_eq!(r.percentile(99.0), 0.0);
        assert_eq!(r.mean(), 0.0);
        assert!(r.summary().contains("n=0"));
    }

    #[test]
    fn ring_buffer_caps_retention_and_keeps_percentiles_ordered() {
        let mut r = LatencyRecorder::with_capacity(8);
        for us in 1..=100u64 {
            r.record(Duration::from_micros(us));
        }
        // Count/mean cover everything; percentiles cover the last 8 samples.
        assert_eq!(r.count(), 100);
        assert_eq!(r.retained(), 8);
        assert!((r.mean() - 50.5).abs() < 0.1);
        let pct = r.percentiles(&[0.0, 50.0, 95.0, 99.0]);
        assert!(pct.windows(2).all(|w| w[0] <= w[1]), "unordered: {pct:?}");
        // The retained window is exactly the most recent samples 93..=100.
        assert!(pct[0] >= 92.9, "min retained {}", pct[0]);
        assert!(pct[3] <= 100.1, "max retained {}", pct[3]);
    }

    #[test]
    fn wraparound_overwrites_oldest_first() {
        let mut r = LatencyRecorder::with_capacity(4);
        for us in [10.0, 20.0, 30.0, 40.0, 50.0] {
            r.record_us(us);
        }
        // 10 was overwritten by 50; window = {20,30,40,50}.
        assert_eq!(r.percentile(0.0), 20.0);
        assert_eq!(r.percentile(100.0), 50.0);
        assert_eq!(r.count(), 5);
    }

    #[test]
    fn summary_sorts_once_consistently() {
        let mut r = LatencyRecorder::with_capacity(16);
        for us in [5.0, 1.0, 9.0, 3.0] {
            r.record_us(us);
        }
        let pct = r.percentiles(&[50.0, 95.0, 99.0]);
        assert_eq!(pct.len(), 3);
        assert!(r.summary().contains("n=4"));
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add(10);
        t.add(5);
        assert_eq!(t.items(), 15);
        assert!(t.per_sec() > 0.0);
    }

    #[test]
    fn engine_metrics_aggregates_replicas() {
        let m = EngineMetrics::new(2);
        {
            let mut r0 = m.replicas[0].lock().unwrap();
            r0.requests += 3;
            r0.batches += 1;
            r0.latency.record_us(100.0);
        }
        {
            let mut agg = m.aggregate.lock().unwrap();
            agg.record_us(100.0);
            agg.record_us(200.0);
        }
        m.throughput.lock().unwrap().add(2);
        assert_eq!(m.total_requests(), 2);
        assert_eq!(m.replica_stats(0).requests, 3);
        assert_eq!(m.replica_stats(1).requests, 0);
        let s = m.summary();
        assert!(s.contains("replica 0"));
        assert!(s.contains("replica 1"));
    }
}
