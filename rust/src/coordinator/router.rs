//! Fault-tolerant routing over replica `hinm serve` hosts (DESIGN.md §19).
//!
//! This module is the *coordinator* half of the `hinm route` tier: it owns
//! every wall-clock decision — health probing, per-try timeouts, hedge
//! timers, retry backoff — while the wire half
//! ([`crate::net::route`]) stays clock-free and hinm-lint-R3-pinned. The
//! split mirrors the engine layering (timing lives in `coordinator/`,
//! never in the numeric or wire layers).
//!
//! Per backend, a breaker state machine:
//!
//! ```text
//!        success                    failure
//!   Up ───────────▶ Up        Up ──────────▶ Degraded
//!   Degraded ─────▶ Up        Degraded ────▶ Down       (≥ fail_threshold
//!   HalfOpen ─────▶ Up                                    consecutive, trips
//!   Down ──cooldown elapsed──▶ HalfOpen                   the breaker)
//!   HalfOpen ──failed trial──▶ Down (backoff doubles, no new trip)
//! ```
//!
//! Dispatch picks the least-loaded eligible backend (in-flight counter,
//! [`consistent_rank`] tiebreak keyed on the request's model), hedges a
//! second attempt when the first exceeds the backend's measured p95, and
//! retries failures within the request's `deadline_ms` budget with
//! [`mix_seed`]-jittered backoff — every random-looking delay is a pure
//! function of the router seed and a per-request sequence number, so a
//! seeded fault schedule replays to exact metric counts (pinned by
//! `rust/tests/router_chaos.rs`).

use crate::coordinator::metrics::LatencyRecorder;
use crate::coordinator::serve::InferError;
use crate::net::http::HttpClient;
use crate::net::route::UpstreamClass;
use crate::util::json;
use crate::util::rng::mix_seed;
use crate::util::sync::lock_unpoisoned;
use anyhow::{Context, Result};
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Most idle downstream connections kept pooled per backend.
const IDLE_POOL_CAP: usize = 8;

/// Granularity of stop-aware sleeps (probers notice shutdown this fast).
const SLEEP_CHUNK: Duration = Duration::from_millis(25);

/// Tuning knobs for [`Router`]. All fields are public so `hinm route`
/// flags and tests can set them directly; [`RouterConfig::default`] is a
/// sane serving profile.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Milliseconds between active `GET /healthz` probes per backend.
    pub probe_interval_ms: u64,
    /// Connect + read timeout for one probe, in milliseconds.
    pub probe_timeout_ms: u64,
    /// Consecutive failures that trip a backend `Up/Degraded → Down`.
    pub fail_threshold: u32,
    /// Base reprobe cooldown after a trip, in milliseconds (doubles per
    /// consecutive `Down` epoch, jittered, capped by `backoff_max_ms`).
    pub backoff_base_ms: u64,
    /// Upper bound on the reprobe cooldown, in milliseconds.
    pub backoff_max_ms: u64,
    /// Base retry backoff between attempts, in milliseconds (doubles per
    /// retry, plus seeded jitter below one base unit).
    pub retry_backoff_ms: u64,
    /// Lower clamp on the hedge delay, in milliseconds.
    pub hedge_floor_ms: u64,
    /// Upper clamp on the hedge delay (also used before any latency has
    /// been measured), in milliseconds.
    pub hedge_ceil_ms: u64,
    /// TCP connect timeout per downstream attempt, in milliseconds.
    pub connect_timeout_ms: u64,
    /// Read timeout per downstream attempt, in milliseconds (further
    /// clamped by the request's remaining deadline).
    pub per_try_timeout_ms: u64,
    /// Most downstream attempts (first try + hedges + retries) spent on
    /// one request.
    pub max_attempts: u32,
    /// Requests admitted concurrently; beyond this the router answers 503
    /// with `Retry-After` instead of queueing unboundedly.
    pub max_inflight: usize,
    /// How long `stop()` waits for in-flight requests to drain, in
    /// milliseconds.
    pub drain_ms: u64,
    /// Seed for every jittered delay and the consistent-hash tiebreak.
    pub seed: u64,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            probe_interval_ms: 1000,
            probe_timeout_ms: 500,
            fail_threshold: 3,
            backoff_base_ms: 500,
            backoff_max_ms: 10_000,
            retry_backoff_ms: 10,
            hedge_floor_ms: 5,
            hedge_ceil_ms: 500,
            connect_timeout_ms: 500,
            per_try_timeout_ms: 2000,
            max_attempts: 3,
            max_inflight: 256,
            drain_ms: 2000,
            seed: 0x48_69_4E_4D,
        }
    }
}

/// Breaker state of one backend (see the module-level state machine).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendHealth {
    /// Healthy: last contact succeeded.
    Up,
    /// Failing below the trip threshold; still dispatched to.
    Degraded,
    /// Breaker open: not dispatched to until the cooldown elapses.
    Down,
    /// Cooldown elapsed: exactly one trial request/probe may pass.
    HalfOpen,
}

impl BackendHealth {
    /// Stable lowercase name (metrics label / JSON value).
    pub fn as_str(self) -> &'static str {
        match self {
            BackendHealth::Up => "up",
            BackendHealth::Degraded => "degraded",
            BackendHealth::Down => "down",
            BackendHealth::HalfOpen => "half-open",
        }
    }
}

/// Mutable per-backend state, all behind one mutex.
struct BackendState {
    health: BackendHealth,
    consec_failures: u32,
    /// Consecutive `Down` entries without an intervening success; drives
    /// the exponential reprobe backoff.
    down_epochs: u32,
    cooldown_until: Option<Instant>,
    /// A half-open trial is currently in flight (only one may be).
    trial_pending: bool,
    inflight: usize,
    requests: u64,
    failures: u64,
    /// Models this backend advertised on `/v1/models` (empty = unknown —
    /// the backend accepts anything, e.g. a single-model front).
    models: Vec<String>,
    latency_us: LatencyRecorder,
    idle: Vec<HttpClient>,
}

/// One downstream `hinm serve` host.
struct Backend {
    name: String,
    addr: SocketAddr,
    state: Mutex<BackendState>,
}

/// Monotonic router counters (all relaxed-free `SeqCst` atomics; exact
/// counts are part of the chaos-test contract).
#[derive(Default)]
pub struct RouterMetrics {
    requests: AtomicU64,
    hedges: AtomicU64,
    retries: AtomicU64,
    breaker_trips: AtomicU64,
    rejected: AtomicU64,
}

/// Read-only copy of one backend's state for metrics rendering.
#[derive(Clone, Debug)]
pub struct BackendSnapshot {
    /// Backend name as given on the command line (`host:port`).
    pub name: String,
    /// Current breaker state.
    pub health: BackendHealth,
    /// Attempts currently in flight to this backend.
    pub inflight: usize,
    /// Consecutive failures since the last success.
    pub consec_failures: u32,
    /// Successful responses served by this backend.
    pub requests: u64,
    /// Failed attempts/probes against this backend.
    pub failures: u64,
    /// Measured p95 response latency in microseconds (0 before any
    /// sample) — the value that arms the hedge timer.
    pub p95_us: f64,
    /// Models the backend advertises (empty = unknown/any).
    pub models: Vec<String>,
}

/// Read-only copy of the router counters + per-backend state, rendered by
/// [`crate::net::protocol::router_metrics_json`] /
/// [`crate::net::protocol::router_metrics_prometheus`].
#[derive(Clone, Debug)]
pub struct RouterSnapshot {
    /// Requests admitted (answered downstream or failed after attempts).
    pub requests: u64,
    /// Hedged second attempts launched.
    pub hedges: u64,
    /// Retry attempts launched after a failure.
    pub retries: u64,
    /// Breaker trips (`Up/Degraded → Down` transitions).
    pub breaker_trips: u64,
    /// Requests rejected with 503 (backpressure or shutdown drain).
    pub rejected: u64,
    /// Per-backend state.
    pub backends: Vec<BackendSnapshot>,
}

/// One proxied request as the wire layer hands it to [`Router::dispatch`].
#[derive(Clone, Debug)]
pub struct ProxyRequest<'a> {
    /// HTTP method to send downstream.
    pub method: &'a str,
    /// Path (plus query) to send downstream.
    pub path: &'a str,
    /// Raw body bytes, forwarded verbatim (never re-serialized — the
    /// bit-identity contract).
    pub body: &'a str,
    /// Parsed `"model"` field, read-only, for per-model dispatch.
    pub model: Option<&'a str>,
    /// Parsed `"deadline_ms"` field: the retry/hedge budget.
    pub deadline_ms: Option<u64>,
    /// Whether a retry may re-send this request after bytes were written
    /// to a downstream (`POST /v1/infer` is a pure function of its body,
    /// so the router treats it as idempotent; unknown POSTs are not).
    pub idempotent: bool,
}

/// What the router tells the wire layer to answer.
#[derive(Debug)]
pub enum RouteReply {
    /// A downstream answered (any status < 500, or a final 5xx passed
    /// through after the attempt budget): relay status + body verbatim.
    Replied {
        /// Downstream status code.
        status: u16,
        /// Downstream body, byte-identical to what the backend sent.
        body: String,
        /// Attempts spent (first try + hedges + retries) — surfaced as
        /// `X-Hinm-Attempt`.
        attempts: u32,
        /// Name of the backend that won.
        backend: String,
    },
    /// No downstream could answer within the budget.
    Failed {
        /// Why — maps onto 502/504 via `protocol::status_for`.
        error: InferError,
        /// Attempts spent before giving up.
        attempts: u32,
    },
    /// Admission rejected: over `max_inflight`, or draining for shutdown.
    Busy {
        /// Suggested client backoff, surfaced as `Retry-After` seconds.
        retry_after_s: u64,
    },
}

/// Consistent-hash tiebreak: the rank of `backend` for a request keyed by
/// `model_key`. Pure in `(seed, model_key, backend)`, so tests can replay
/// the dispatch order for a seed, and requests for the same model prefer
/// the same backend when in-flight counts tie (cache affinity).
pub fn consistent_rank(seed: u64, model_key: u64, backend: usize) -> u64 {
    mix_seed(seed ^ model_key, backend as u64)
}

/// The dispatch key for an optional model name (FNV-1a64; empty string
/// for the default model).
pub fn model_key(model: Option<&str>) -> u64 {
    crate::runtime::artifact::fnv1a64(model.unwrap_or("").as_bytes())
}

/// May a failed attempt be retried elsewhere? Idempotent requests always
/// may; non-idempotent ones only while no request bytes reached a
/// downstream (a refused connect), because a written request may have
/// executed even if the response never came back.
pub fn retry_allowed(idempotent: bool, bytes_written: bool) -> bool {
    idempotent || !bytes_written
}

/// Jittered backoff before the `retry`-th retry (1-based) of request
/// `seq`: `base · 2^(retry−1)` plus a seeded jitter below one base unit.
/// Pure in `(cfg.seed, retry, seq)` — no wall-clock randomness.
pub fn retry_backoff_ms(cfg: &RouterConfig, retry: u32, seq: u64) -> u64 {
    let base = cfg.retry_backoff_ms.max(1);
    let exp = base.saturating_mul(1u64 << retry.saturating_sub(1).min(10));
    exp + mix_seed(cfg.seed, seq.wrapping_mul(8).wrapping_add(retry as u64)) % base
}

/// Jittered reprobe cooldown for a backend entering its `epoch`-th
/// consecutive `Down` (0-based): `base · 2^epoch` capped at
/// `backoff_max_ms`, plus up to 25% seeded jitter. Pure in
/// `(cfg.seed, epoch, stream)`.
pub fn reprobe_backoff_ms(cfg: &RouterConfig, epoch: u32, stream: u64) -> u64 {
    let cap = cfg.backoff_max_ms.max(cfg.backoff_base_ms.max(1));
    let exp = cfg.backoff_base_ms.max(1).saturating_mul(1u64 << epoch.min(10)).min(cap);
    exp + mix_seed(cfg.seed, stream) % (exp / 4 + 1)
}

/// Book one failure on a backend's state machine (passive mark from an
/// attempt, or a failed active probe). Trips the breaker — counted once
/// per `Up/Degraded → Down` transition — when `consec_failures` reaches
/// the threshold; a failed half-open trial re-opens the breaker with a
/// doubled cooldown but does not count a new trip.
fn note_failure(cfg: &RouterConfig, metrics: &RouterMetrics, st: &mut BackendState, now: Instant) {
    st.failures += 1;
    st.consec_failures += 1;
    match st.health {
        BackendHealth::Up | BackendHealth::Degraded => {
            if st.consec_failures >= cfg.fail_threshold {
                st.health = BackendHealth::Down;
                metrics.breaker_trips.fetch_add(1, Ordering::SeqCst);
                let ms = reprobe_backoff_ms(cfg, st.down_epochs, st.failures);
                st.down_epochs += 1;
                st.cooldown_until = Some(now + Duration::from_millis(ms));
            } else {
                st.health = BackendHealth::Degraded;
            }
        }
        BackendHealth::HalfOpen => {
            st.health = BackendHealth::Down;
            let ms = reprobe_backoff_ms(cfg, st.down_epochs, st.failures);
            st.down_epochs += 1;
            st.cooldown_until = Some(now + Duration::from_millis(ms));
            st.trial_pending = false;
        }
        BackendHealth::Down => {
            st.trial_pending = false;
        }
    }
}

/// Book one success: any state returns to `Up` and the failure streak,
/// down-epoch counter, and pending trial all clear.
fn note_success(st: &mut BackendState) {
    st.requests += 1;
    st.consec_failures = 0;
    st.down_epochs = 0;
    st.cooldown_until = None;
    st.trial_pending = false;
    st.health = BackendHealth::Up;
}

/// Outcome of one downstream attempt, sent back to the dispatcher. The
/// attempt thread books its own success/failure on the backend state
/// *before* sending, so counters stay exact even when the dispatcher has
/// already answered the client (an abandoned hedge loser still books).
struct AttemptOutcome {
    backend: usize,
    /// Request bytes reached the downstream (gates non-idempotent retry).
    bytes_written: bool,
    /// `Ok((status, body))` — any well-formed response, including 5xx;
    /// `Err((class, message))` — transport failure.
    result: std::result::Result<(u16, String), (UpstreamClass, String)>,
}

/// The router: shared state + prober threads. Create with
/// [`Router::start`]; drive with [`Router::dispatch`] (one call per
/// client request, typically from an HTTP worker thread of
/// [`crate::net::route::RouterFront`]); shut down with [`Router::stop`].
pub struct Router {
    cfg: RouterConfig,
    backends: Arc<Vec<Backend>>,
    metrics: Arc<RouterMetrics>,
    stopping: Arc<AtomicBool>,
    inflight: Arc<AtomicUsize>,
    seq: AtomicU64,
    probers: Mutex<Vec<JoinHandle<()>>>,
}

impl Router {
    /// Build the routing table over `(name, addr)` backends and spawn one
    /// health-prober thread per backend. Backends may be down at start —
    /// the probers and passive marking converge on reality.
    pub fn start(backends: Vec<(String, SocketAddr)>, cfg: RouterConfig) -> Result<Arc<Router>> {
        anyhow::ensure!(!backends.is_empty(), "router needs at least one backend");
        let backends: Arc<Vec<Backend>> = Arc::new(
            backends
                .into_iter()
                .map(|(name, addr)| Backend {
                    name,
                    addr,
                    state: Mutex::new(BackendState {
                        health: BackendHealth::Up,
                        consec_failures: 0,
                        down_epochs: 0,
                        cooldown_until: None,
                        trial_pending: false,
                        inflight: 0,
                        requests: 0,
                        failures: 0,
                        models: Vec::new(),
                        latency_us: LatencyRecorder::with_capacity(4096),
                        idle: Vec::new(),
                    }),
                })
                .collect(),
        );
        let router = Arc::new(Router {
            cfg: cfg.clone(),
            backends: Arc::clone(&backends),
            metrics: Arc::new(RouterMetrics::default()),
            stopping: Arc::new(AtomicBool::new(false)),
            inflight: Arc::new(AtomicUsize::new(0)),
            seq: AtomicU64::new(0),
            probers: Mutex::new(Vec::new()),
        });
        let mut probers = Vec::with_capacity(router.backends.len());
        for i in 0..router.backends.len() {
            let backends = Arc::clone(&router.backends);
            let metrics = Arc::clone(&router.metrics);
            let stopping = Arc::clone(&router.stopping);
            let cfg = cfg.clone();
            let t = std::thread::Builder::new()
                .name(format!("hinm-route-probe{i}"))
                .spawn(move || prober_loop(&backends[i], &cfg, &metrics, &stopping))
                .context("spawning router prober")?;
            probers.push(t);
        }
        *lock_unpoisoned(&router.probers) = probers;
        Ok(router)
    }

    /// The router's monotonic counters + per-backend breaker state.
    pub fn snapshot(&self) -> RouterSnapshot {
        RouterSnapshot {
            requests: self.metrics.requests.load(Ordering::SeqCst),
            hedges: self.metrics.hedges.load(Ordering::SeqCst),
            retries: self.metrics.retries.load(Ordering::SeqCst),
            breaker_trips: self.metrics.breaker_trips.load(Ordering::SeqCst),
            rejected: self.metrics.rejected.load(Ordering::SeqCst),
            backends: self
                .backends
                .iter()
                .map(|b| {
                    let st = lock_unpoisoned(&b.state);
                    BackendSnapshot {
                        name: b.name.clone(),
                        health: st.health,
                        inflight: st.inflight,
                        consec_failures: st.consec_failures,
                        requests: st.requests,
                        failures: st.failures,
                        p95_us: st.latency_us.percentile(95.0),
                        models: st.models.clone(),
                    }
                })
                .collect(),
        }
    }

    /// `(live, total)` backend counts for the router's `/healthz` (live =
    /// any state the dispatcher may send to).
    pub fn live_backends(&self) -> (usize, usize) {
        let live = self
            .backends
            .iter()
            .filter(|b| {
                !matches!(lock_unpoisoned(&b.state).health, BackendHealth::Down)
            })
            .count();
        (live, self.backends.len())
    }

    /// Sorted union of the models the backends advertise (router
    /// `/v1/models`).
    pub fn models_union(&self) -> Vec<String> {
        let mut all: Vec<String> = self
            .backends
            .iter()
            .flat_map(|b| lock_unpoisoned(&b.state).models.clone())
            .collect();
        all.sort();
        all.dedup();
        all
    }

    /// True once [`Router::stop`] has begun (new requests answer 503).
    pub fn is_stopping(&self) -> bool {
        self.stopping.load(Ordering::SeqCst)
    }

    /// Graceful drain: refuse new requests, wait up to `drain_ms` for
    /// in-flight ones, then join the probers.
    pub fn stop(&self) {
        self.stopping.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + Duration::from_millis(self.cfg.drain_ms);
        while self.inflight.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        for t in lock_unpoisoned(&self.probers).drain(..) {
            let _ = t.join();
        }
    }

    /// Route one request: admission check, then up to `max_attempts`
    /// downstream attempts with hedging and deadline-aware retries. Blocks
    /// the calling (HTTP worker) thread until an answer or the budget runs
    /// out.
    pub fn dispatch(&self, req: &ProxyRequest<'_>) -> RouteReply {
        if self.stopping.load(Ordering::SeqCst) {
            self.metrics.rejected.fetch_add(1, Ordering::SeqCst);
            return RouteReply::Busy { retry_after_s: 1 };
        }
        // Optimistic admission: claim a slot, back out if over the cap.
        let prev = self.inflight.fetch_add(1, Ordering::SeqCst);
        if prev >= self.cfg.max_inflight {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            self.metrics.rejected.fetch_add(1, Ordering::SeqCst);
            return RouteReply::Busy { retry_after_s: 1 };
        }
        self.metrics.requests.fetch_add(1, Ordering::SeqCst);
        let reply = self.dispatch_inner(req);
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        reply
    }

    fn dispatch_inner(&self, req: &ProxyRequest<'_>) -> RouteReply {
        let started = Instant::now();
        let hard_deadline = req.deadline_ms.map(|ms| started + Duration::from_millis(ms));
        let key = model_key(req.model);
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel::<AttemptOutcome>();

        let mut tried: Vec<usize> = Vec::new();
        let mut attempts: u32 = 0;
        let mut pending: usize = 0;
        let mut retries_done: u32 = 0;
        let mut hedged = false;
        let mut hedge_at: Option<Instant> = None;
        let mut last_fail: Option<InferError> = None;
        let mut last_5xx: Option<(u16, String, String)> = None;

        // First attempt.
        match self.launch(key, req, &mut tried, hard_deadline, &tx) {
            Some(idx) => {
                attempts += 1;
                pending += 1;
                hedge_at = Some(Instant::now() + self.hedge_delay(idx));
            }
            None => {
                return RouteReply::Failed {
                    error: InferError::Upstream("no live backend to dispatch to".to_string()),
                    attempts: 0,
                };
            }
        }

        // Worst-case duration of one attempt, as a watchdog bound.
        let attempt_cap = Duration::from_millis(
            self.cfg.connect_timeout_ms + self.cfg.per_try_timeout_ms + 1000,
        );
        let mut last_progress = Instant::now();

        loop {
            let now = Instant::now();
            if let Some(d) = hard_deadline {
                if now >= d {
                    return RouteReply::Failed { error: InferError::DeadlineExpired, attempts };
                }
            }
            if now.duration_since(last_progress) > attempt_cap {
                // Safety net: every attempt is socket-timeout-bounded, so
                // this only fires if something downstream wedged past its
                // timeouts.
                return RouteReply::Failed {
                    error: InferError::UpstreamTimeout(
                        "pending attempts exceeded the per-try budget".to_string(),
                    ),
                    attempts,
                };
            }
            let mut wait = attempt_cap;
            if let (false, Some(h), true) = (hedged, hedge_at, pending > 0) {
                wait = wait.min(h.saturating_duration_since(now).max(Duration::from_millis(1)));
            }
            if let Some(d) = hard_deadline {
                wait = wait.min(d.saturating_duration_since(now).max(Duration::from_millis(1)));
            }

            match rx.recv_timeout(wait) {
                Ok(out) => {
                    pending -= 1;
                    last_progress = Instant::now();
                    let name = self.backends[out.backend].name.clone();
                    match out.result {
                        Ok((status, body)) if status < 500 => {
                            return RouteReply::Replied { status, body, attempts, backend: name };
                        }
                        Ok((status, body)) => {
                            last_5xx = Some((status, body, name.clone()));
                            last_fail = Some(InferError::Upstream(format!(
                                "backend {name} answered {status}"
                            )));
                        }
                        Err((class, msg)) => {
                            last_fail = Some(match class {
                                UpstreamClass::TimedOut => InferError::UpstreamTimeout(format!(
                                    "backend {name}: {msg}"
                                )),
                                UpstreamClass::Unreachable | UpstreamClass::Protocol => {
                                    InferError::Upstream(format!("backend {name}: {msg}"))
                                }
                            });
                        }
                    }
                    // Retry if the budget allows.
                    if attempts < self.cfg.max_attempts
                        && retry_allowed(req.idempotent, out.bytes_written)
                    {
                        retries_done += 1;
                        let backoff =
                            Duration::from_millis(retry_backoff_ms(&self.cfg, retries_done, seq));
                        let budget_ok = match hard_deadline {
                            Some(d) => Instant::now() + backoff < d,
                            None => true,
                        };
                        if budget_ok {
                            std::thread::sleep(backoff);
                            if let Some(_idx) = self.launch(key, req, &mut tried, hard_deadline, &tx)
                            {
                                self.metrics.retries.fetch_add(1, Ordering::SeqCst);
                                attempts += 1;
                                pending += 1;
                                last_progress = Instant::now();
                                continue;
                            }
                        }
                    }
                    if pending == 0 {
                        return match last_5xx {
                            // Out of attempts: pass the downstream's own
                            // 5xx through verbatim rather than inventing a
                            // body (keeps router and direct responses
                            // bit-identical even on errors).
                            Some((status, body, backend)) => {
                                RouteReply::Replied { status, body, attempts, backend }
                            }
                            None => RouteReply::Failed {
                                error: last_fail.unwrap_or_else(|| {
                                    InferError::Upstream("all attempts failed".to_string())
                                }),
                                attempts,
                            },
                        };
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    let now = Instant::now();
                    let hedge_due = match (hedged, hedge_at) {
                        (false, Some(h)) => now >= h,
                        _ => false,
                    };
                    if hedge_due && pending > 0 && attempts < self.cfg.max_attempts {
                        hedged = true;
                        if self.launch(key, req, &mut tried, hard_deadline, &tx).is_some() {
                            self.metrics.hedges.fetch_add(1, Ordering::SeqCst);
                            attempts += 1;
                            pending += 1;
                            last_progress = now;
                        }
                    } else if hedge_due {
                        // Nothing pending to hedge against; disarm.
                        hedged = true;
                    }
                    // Deadline/watchdog checks run at the top of the loop.
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    // All attempt threads gone without a usable outcome.
                    return RouteReply::Failed {
                        error: last_fail.unwrap_or_else(|| {
                            InferError::Upstream("all attempts failed".to_string())
                        }),
                        attempts,
                    };
                }
            }
        }
    }

    /// Pick, claim, and spawn one downstream attempt. On success the
    /// chosen index is appended to `tried` and returned. The spawned
    /// thread books its own outcome on the backend state, then reports
    /// through `tx`.
    fn launch(
        &self,
        key: u64,
        req: &ProxyRequest<'_>,
        tried: &mut Vec<usize>,
        hard_deadline: Option<Instant>,
        tx: &mpsc::Sender<AttemptOutcome>,
    ) -> Option<usize> {
        let now = Instant::now();
        // Per-try read timeout: the configured cap, shrunk to the
        // request's remaining deadline.
        let mut per_try = Duration::from_millis(self.cfg.per_try_timeout_ms.max(1));
        if let Some(d) = hard_deadline {
            let remaining = d.saturating_duration_since(now);
            if remaining < Duration::from_millis(1) {
                return None;
            }
            per_try = per_try.min(remaining);
        }
        let idx = self.pick_and_claim(key, req.model, tried, now)?;
        tried.push(idx);

        let backends = Arc::clone(&self.backends);
        let metrics = Arc::clone(&self.metrics);
        let cfg = self.cfg.clone();
        let tx = tx.clone();
        let method = req.method.to_string();
        let path = req.path.to_string();
        let body = req.body.to_string();
        let spawned = std::thread::Builder::new()
            .name(format!("hinm-route-try{idx}"))
            .spawn(move || {
                run_attempt(&backends[idx], idx, &cfg, &metrics, &method, &path, &body, per_try, &tx)
            });
        match spawned {
            Ok(_) => Some(idx),
            Err(_) => {
                // Could not even spawn: un-claim and report synchronously.
                let b = &self.backends[idx];
                let mut st = lock_unpoisoned(&b.state);
                st.inflight = st.inflight.saturating_sub(1);
                note_failure(&self.cfg, &self.metrics, &mut st, Instant::now());
                drop(st);
                let _ = tx.send(AttemptOutcome {
                    backend: idx,
                    bytes_written: false,
                    result: Err((
                        UpstreamClass::Unreachable,
                        "spawning attempt thread failed".to_string(),
                    )),
                });
                Some(idx)
            }
        }
    }

    /// Least-loaded eligible backend not in `exclude`, ties broken by
    /// [`consistent_rank`]; claims it (in-flight + half-open trial slot).
    fn pick_and_claim(
        &self,
        key: u64,
        model: Option<&str>,
        exclude: &[usize],
        now: Instant,
    ) -> Option<usize> {
        // Bounded re-scan: a concurrent dispatcher can steal a half-open
        // trial slot between scan and claim.
        for _ in 0..4 {
            let mut best: Option<(usize, u64, usize)> = None;
            for (i, b) in self.backends.iter().enumerate() {
                if exclude.contains(&i) {
                    continue;
                }
                let mut st = lock_unpoisoned(&b.state);
                if st.health == BackendHealth::Down {
                    let due = match st.cooldown_until {
                        Some(t) => now >= t,
                        None => true,
                    };
                    if due {
                        st.health = BackendHealth::HalfOpen;
                        st.trial_pending = false;
                    }
                }
                let eligible = match st.health {
                    BackendHealth::Up | BackendHealth::Degraded => true,
                    BackendHealth::HalfOpen => !st.trial_pending,
                    BackendHealth::Down => false,
                };
                if !eligible {
                    continue;
                }
                if let Some(m) = model {
                    if !st.models.is_empty() && !st.models.iter().any(|x| x == m) {
                        continue;
                    }
                }
                let cand = (st.inflight, consistent_rank(self.cfg.seed, key, i), i);
                let better = match best {
                    None => true,
                    Some(b0) => cand < b0,
                };
                if better {
                    best = Some(cand);
                }
            }
            let (_, _, idx) = best?;
            let mut st = lock_unpoisoned(&self.backends[idx].state);
            let claimed = match st.health {
                BackendHealth::Up | BackendHealth::Degraded => true,
                BackendHealth::HalfOpen => {
                    if st.trial_pending {
                        false
                    } else {
                        st.trial_pending = true;
                        true
                    }
                }
                BackendHealth::Down => false,
            };
            if claimed {
                st.inflight += 1;
                return Some(idx);
            }
        }
        None
    }

    /// Hedge timer for an attempt on `idx`: the backend's measured p95,
    /// clamped to `[hedge_floor_ms, hedge_ceil_ms]`; the ceiling before
    /// any sample exists.
    fn hedge_delay(&self, idx: usize) -> Duration {
        let floor = self.cfg.hedge_floor_ms;
        let ceil = self.cfg.hedge_ceil_ms.max(floor);
        let st = lock_unpoisoned(&self.backends[idx].state);
        let ms = if st.latency_us.retained() == 0 {
            ceil
        } else {
            ((st.latency_us.percentile(95.0) / 1000.0).ceil() as u64).clamp(floor, ceil)
        };
        Duration::from_millis(ms.max(1))
    }
}

/// Body of one attempt thread: connect (or reuse a pooled connection),
/// send, read, book the outcome on the backend state, report to the
/// dispatcher. Booking happens here — exactly once per attempt — so a
/// hedge loser abandoned by the dispatcher still decrements in-flight and
/// feeds the breaker.
#[allow(clippy::too_many_arguments)]
fn run_attempt(
    b: &Backend,
    idx: usize,
    cfg: &RouterConfig,
    metrics: &RouterMetrics,
    method: &str,
    path: &str,
    body: &str,
    per_try: Duration,
    tx: &mpsc::Sender<AttemptOutcome>,
) {
    let started = Instant::now();
    let pooled = { lock_unpoisoned(&b.state).idle.pop() };
    let mut client = match pooled {
        Some(c) => c,
        None => {
            match HttpClient::connect_timeout(
                b.addr,
                Duration::from_millis(cfg.connect_timeout_ms.max(1)),
            ) {
                Ok(c) => c,
                Err(e) => {
                    let class = crate::net::route::classify_anyhow(&e);
                    let mut st = lock_unpoisoned(&b.state);
                    st.inflight = st.inflight.saturating_sub(1);
                    note_failure(cfg, metrics, &mut st, Instant::now());
                    drop(st);
                    let _ = tx.send(AttemptOutcome {
                        backend: idx,
                        bytes_written: false,
                        result: Err((class, format!("{e:#}"))),
                    });
                    return;
                }
            }
        }
    };
    if client.set_read_timeout(Some(per_try.max(Duration::from_millis(1)))).is_err() {
        // A socket we cannot configure is not trustworthy for a bounded
        // attempt; treat as unreachable.
        let mut st = lock_unpoisoned(&b.state);
        st.inflight = st.inflight.saturating_sub(1);
        note_failure(cfg, metrics, &mut st, Instant::now());
        drop(st);
        let _ = tx.send(AttemptOutcome {
            backend: idx,
            bytes_written: false,
            result: Err((UpstreamClass::Unreachable, "setting read timeout failed".to_string())),
        });
        return;
    }
    let attempt_body = if body.is_empty() { None } else { Some(body) };
    match client.request_with_headers(method, path, attempt_body) {
        Ok((status, _headers, resp_body)) => {
            let failure = status >= 500;
            let mut st = lock_unpoisoned(&b.state);
            st.inflight = st.inflight.saturating_sub(1);
            if failure {
                note_failure(cfg, metrics, &mut st, Instant::now());
            } else {
                note_success(&mut st);
                st.latency_us.record(started.elapsed());
                if st.idle.len() < IDLE_POOL_CAP {
                    st.idle.push(client);
                }
            }
            drop(st);
            let _ = tx.send(AttemptOutcome {
                backend: idx,
                bytes_written: true,
                result: Ok((status, resp_body)),
            });
        }
        Err(e) => {
            let class = crate::net::route::classify_anyhow(&e);
            let mut st = lock_unpoisoned(&b.state);
            st.inflight = st.inflight.saturating_sub(1);
            note_failure(cfg, metrics, &mut st, Instant::now());
            drop(st);
            let _ = tx.send(AttemptOutcome {
                backend: idx,
                bytes_written: true,
                result: Err((class, format!("{e:#}"))),
            });
        }
    }
}

/// One prober thread: sleep the probe interval (stop-aware), honor `Down`
/// cooldowns, claim half-open trial slots, then `GET /healthz` (+
/// `/v1/models` discovery) and book the result on the same state machine
/// the passive path uses.
fn prober_loop(b: &Backend, cfg: &RouterConfig, metrics: &RouterMetrics, stopping: &AtomicBool) {
    loop {
        if stop_aware_sleep(stopping, Duration::from_millis(cfg.probe_interval_ms.max(1))) {
            return;
        }
        let now = Instant::now();
        {
            let mut st = lock_unpoisoned(&b.state);
            match st.health {
                BackendHealth::Down => {
                    let due = match st.cooldown_until {
                        Some(t) => now >= t,
                        None => true,
                    };
                    if !due {
                        continue;
                    }
                    st.health = BackendHealth::HalfOpen;
                    st.trial_pending = true;
                }
                BackendHealth::HalfOpen => {
                    if st.trial_pending {
                        continue; // a dispatch trial is already in flight
                    }
                    st.trial_pending = true;
                }
                BackendHealth::Up | BackendHealth::Degraded => {}
            }
        }
        match probe(b.addr, cfg) {
            Ok(models) => {
                let mut st = lock_unpoisoned(&b.state);
                note_success(&mut st);
                if !models.is_empty() {
                    st.models = models;
                }
            }
            Err(_) => {
                let mut st = lock_unpoisoned(&b.state);
                note_failure(cfg, metrics, &mut st, Instant::now());
            }
        }
    }
}

/// One active probe: `GET /healthz` must answer 200; `GET /v1/models` is
/// optional capability discovery (single-model fronts 404 it — fine).
fn probe(addr: SocketAddr, cfg: &RouterConfig) -> Result<Vec<String>> {
    let t = Duration::from_millis(cfg.probe_timeout_ms.max(1));
    let mut c = HttpClient::connect_timeout(addr, t)?;
    c.set_read_timeout(Some(t))?;
    let (status, _body) = c.get("/healthz")?;
    anyhow::ensure!(status == 200, "healthz answered {status}");
    let mut models = Vec::new();
    if let Ok((200, body)) = c.get("/v1/models") {
        if let Ok(doc) = json::parse(&body) {
            if let Some(arr) = doc.get("models").as_arr() {
                for m in arr {
                    if let Some(name) = m.get("name").as_str() {
                        models.push(name.to_string());
                    }
                }
            }
        }
    }
    Ok(models)
}

/// Sleep `total` in small chunks, returning `true` as soon as `stopping`
/// is observed (so probers join promptly on shutdown).
fn stop_aware_sleep(stopping: &AtomicBool, total: Duration) -> bool {
    let mut left = total;
    while left > Duration::ZERO {
        if stopping.load(Ordering::SeqCst) {
            return true;
        }
        let chunk = left.min(SLEEP_CHUNK);
        std::thread::sleep(chunk);
        left = left.saturating_sub(chunk);
    }
    stopping.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> BackendState {
        BackendState {
            health: BackendHealth::Up,
            consec_failures: 0,
            down_epochs: 0,
            cooldown_until: None,
            trial_pending: false,
            inflight: 0,
            requests: 0,
            failures: 0,
            models: Vec::new(),
            latency_us: LatencyRecorder::with_capacity(64),
            idle: Vec::new(),
        }
    }

    fn cfg() -> RouterConfig {
        RouterConfig { fail_threshold: 2, ..RouterConfig::default() }
    }

    #[test]
    fn breaker_walks_up_degraded_down_halfopen_up() {
        let cfg = cfg();
        let m = RouterMetrics::default();
        let mut st = state();
        let now = Instant::now();

        note_failure(&cfg, &m, &mut st, now);
        assert_eq!(st.health, BackendHealth::Degraded);
        assert_eq!(m.breaker_trips.load(Ordering::SeqCst), 0);

        note_failure(&cfg, &m, &mut st, now);
        assert_eq!(st.health, BackendHealth::Down);
        assert_eq!(m.breaker_trips.load(Ordering::SeqCst), 1, "threshold trips once");
        assert!(st.cooldown_until.is_some());

        // Cooldown elapsed → half-open trial; a failed trial re-opens with
        // a longer cooldown but no new trip.
        st.health = BackendHealth::HalfOpen;
        st.trial_pending = true;
        let epoch_before = st.down_epochs;
        note_failure(&cfg, &m, &mut st, now);
        assert_eq!(st.health, BackendHealth::Down);
        assert_eq!(m.breaker_trips.load(Ordering::SeqCst), 1, "reprobe failure is not a new trip");
        assert_eq!(st.down_epochs, epoch_before + 1);

        // A success from anywhere resets everything.
        st.health = BackendHealth::HalfOpen;
        note_success(&mut st);
        assert_eq!(st.health, BackendHealth::Up);
        assert_eq!(st.consec_failures, 0);
        assert_eq!(st.down_epochs, 0);
        assert!(st.cooldown_until.is_none());
    }

    #[test]
    fn success_interrupts_the_failure_streak() {
        let cfg = cfg();
        let m = RouterMetrics::default();
        let mut st = state();
        let now = Instant::now();
        note_failure(&cfg, &m, &mut st, now);
        note_success(&mut st);
        note_failure(&cfg, &m, &mut st, now);
        assert_eq!(st.health, BackendHealth::Degraded, "streak restarted after success");
        assert_eq!(m.breaker_trips.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn backoffs_are_deterministic_exponential_and_capped() {
        let cfg = RouterConfig {
            backoff_base_ms: 100,
            backoff_max_ms: 1000,
            retry_backoff_ms: 10,
            seed: 7,
            ..RouterConfig::default()
        };
        // Same inputs → same jitter (no wall-clock randomness).
        assert_eq!(reprobe_backoff_ms(&cfg, 0, 5), reprobe_backoff_ms(&cfg, 0, 5));
        assert_eq!(retry_backoff_ms(&cfg, 1, 42), retry_backoff_ms(&cfg, 1, 42));
        // Exponential growth up to the cap (+ ≤25% jitter).
        let e0 = reprobe_backoff_ms(&cfg, 0, 1);
        let e3 = reprobe_backoff_ms(&cfg, 3, 1);
        assert!((100..=125).contains(&e0), "{e0}");
        assert!((800..=1000 + 250).contains(&e3), "{e3}");
        assert!(reprobe_backoff_ms(&cfg, 30, 1) <= 1000 + 250);
        // Retry backoff doubles per retry.
        let r1 = retry_backoff_ms(&cfg, 1, 9);
        let r3 = retry_backoff_ms(&cfg, 3, 9);
        assert!((10..20).contains(&r1), "{r1}");
        assert!((40..50).contains(&r3), "{r3}");
    }

    #[test]
    fn consistent_rank_is_pure_and_model_sensitive() {
        let k1 = model_key(Some("deit-mini"));
        let k2 = model_key(Some("ffn-relu"));
        assert_ne!(k1, k2);
        assert_eq!(model_key(None), model_key(Some("")));
        assert_eq!(consistent_rank(1, k1, 0), consistent_rank(1, k1, 0));
        // Different backends get different ranks for the same key.
        assert_ne!(consistent_rank(1, k1, 0), consistent_rank(1, k1, 1));
        // Different models reshuffle the preference order eventually.
        let order = |k: u64| {
            let mut v: Vec<usize> = (0..8).collect();
            v.sort_by_key(|&i| consistent_rank(1, k, i));
            v
        };
        assert_ne!(order(k1), order(k2), "8 backends, 2 keys: same order is ~1/40320");
    }

    #[test]
    fn retry_gate_honors_idempotency() {
        assert!(retry_allowed(true, true), "idempotent retries always");
        assert!(retry_allowed(true, false));
        assert!(retry_allowed(false, false), "nothing written yet: safe");
        assert!(!retry_allowed(false, true), "non-idempotent after write: never");
    }
}
