//! Gradual-pruning orchestrator (paper §5.1.2): drives the cubic
//! vector-sparsity ramp → N:M activation schedule across a model's layers,
//! re-running gyro-permutation at every mask update and (optionally)
//! interleaving fine-tune steps through the [`super::trainer::LmTrainer`].
//!
//! This is the coordinator-level counterpart of `eval::tab2` (which scores
//! the schedule on synthetic layers): here the schedule runs against *live*
//! model parameters and masks.

use super::trainer::{Corpus, LmTrainer};
use crate::permute::{gyro_permute_and_prune, GyroParams};
use crate::sparsity::hinm::{gradual_schedule, prune_oneshot, step_config, GradualStep};
use crate::sparsity::HinmConfig;
use anyhow::Result;

#[derive(Clone, Debug)]
/// Configuration for the gradual prune → fine-tune schedule (Tab. 2).
pub struct GradualConfig {
    /// Target HiNM config at the end of the schedule.
    pub target: HinmConfig,
    /// Steps spent ramping the vector level before N:M activates.
    pub vector_steps: usize,
    /// Total schedule steps.
    pub total_steps: usize,
    /// Fine-tune SGD steps between mask updates.
    pub ft_steps_per_stage: usize,
    /// Fine-tune learning rate.
    pub ft_lr: f32,
    /// Use gyro-permutation at each mask update (false = VENOM-style).
    pub permute: bool,
    /// Permutation tuning used when `permute` is on.
    pub gyro: GyroParams,
}

impl GradualConfig {
    /// Defaults (3 vector steps of 5, short fine-tunes) toward `target`.
    pub fn new(target: HinmConfig) -> Self {
        Self {
            target,
            vector_steps: 3,
            total_steps: 5,
            ft_steps_per_stage: 20,
            ft_lr: 0.2,
            permute: true,
            gyro: GyroParams::default(),
        }
    }
}

/// Per-stage record of a gradual run.
#[derive(Clone, Debug)]
pub struct StageReport {
    /// The schedule point this stage executed.
    pub step: GradualStep,
    /// Weighted retention across pruned tensors at this stage.
    pub retention: f64,
    /// Held-out loss after this stage's fine-tuning (if a trainer ran).
    pub loss: Option<f32>,
}

/// Run the gradual schedule against a live [`LmTrainer`]: at each stage,
/// recompute masks on the *current* weights at the stage's sparsity,
/// install them, and fine-tune. Returns the stage-by-stage report.
pub fn run_gradual_lm(
    trainer: &mut LmTrainer,
    corpus: &mut Corpus,
    heldout: &mut Corpus,
    cfg: &GradualConfig,
) -> Result<Vec<StageReport>> {
    let steps = gradual_schedule(cfg.target.vector_sparsity, cfg.vector_steps, cfg.total_steps);
    let names = trainer.mnames.clone();
    let (b, s) = (trainer.batch, trainer.seq);
    let mut reports = Vec::with_capacity(steps.len());

    for stage in &steps {
        let stage_cfg = step_config(&cfg.target, stage);
        let mut retained = 0.0f64;
        let mut total = 0.0f64;

        // Dense warmup stages (no sparsity yet): skip mask updates.
        let active = stage_cfg.vector_sparsity > 0.0 || stage.nm_active;
        if active {
            for name in &names {
                let w = trainer.param_matrix(name)?;
                let sal = w.abs();
                let result = if cfg.permute {
                    gyro_permute_and_prune(
                        &w,
                        &sal,
                        &stage_cfg,
                        &GyroParams { skip_ocp: true, ..cfg.gyro.clone() },
                    )
                    .result
                } else {
                    prune_oneshot(&w, &sal, &stage_cfg)
                };
                retained += result.retained;
                total += sal.l1();
                trainer.set_param(name, &result.mask.apply(&w))?;
                trainer.set_mask(name, &result.mask)?;
            }
        } else {
            total = 1.0;
            retained = 1.0;
        }

        // Fine-tune under the new masks.
        for _ in 0..cfg.ft_steps_per_stage {
            let (toks, tgts) = corpus.batch(b, s);
            trainer.step(&toks, &tgts, cfg.ft_lr)?;
        }
        let (toks, tgts) = heldout.batch(b, s);
        let loss = trainer.eval_loss(&toks, &tgts)?;

        reports.push(StageReport {
            step: *stage,
            retention: retained / total,
            loss: Some(loss),
        });
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_ramp_reaches_target() {
        let cfg = GradualConfig::new(HinmConfig::for_total_sparsity(32, 0.75));
        let steps = gradual_schedule(cfg.target.vector_sparsity, cfg.vector_steps, cfg.total_steps);
        assert_eq!(steps.len(), 5);
        let last = steps.last().unwrap();
        assert!(last.nm_active);
        assert!((last.vector_sparsity - 0.5).abs() < 1e-9);
        // Effective sparsity at the last stage equals the target.
        let final_cfg = step_config(&cfg.target, last);
        assert!((final_cfg.total_sparsity() - 0.75).abs() < 1e-9);
    }

    // Live-trainer behaviour is covered by rust/tests/gradual_integration.rs
    // (needs artifacts); here we check the config surface.
    #[test]
    fn config_defaults_sane() {
        let cfg = GradualConfig::new(HinmConfig::with_24(32, 0.5));
        assert!(cfg.permute);
        assert!(cfg.vector_steps < cfg.total_steps);
    }
}
