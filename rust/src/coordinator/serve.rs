//! Batched inference server over a PJRT executor.
//!
//! A vLLM-router-style request path in miniature: clients submit single
//! activations; a dispatcher thread collects them into fixed-size batches
//! (the artifact's compiled batch dimension), pads stragglers, executes on
//! PJRT, and fans the slices back to the waiting clients. Latency metrics
//! (p50/p95/p99) are recorded per request.

use super::metrics::LatencyRecorder;
use crate::runtime::executor::{lit_f32, lit_i32, lit_to_f32, Executor};
use crate::runtime::registry::ArtifactSpec;
use anyhow::{Context, Result};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Host-side tensor data, `Send`-able across threads (PJRT literals are
/// not); the dispatcher thread converts these to literals once at startup.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32(d, s) => lit_f32(d, s),
            HostTensor::I32(d, s) => lit_i32(d, s),
        }
    }
}

/// Packed HiNM weights as host tensors (vals, vec_idx, nm_idx) — the fixed
/// inputs of the `ffn_serve` artifact.
pub fn packed_host_tensors(p: &crate::sparsity::HinmPacked) -> Vec<HostTensor> {
    let t = p.tiles();
    let vpr = p.vals_per_row();
    vec![
        HostTensor::F32(p.vals.clone(), vec![t, p.cfg.v, vpr]),
        HostTensor::I32(p.vec_idx.clone(), vec![t, p.k_v]),
        HostTensor::I32(p.nm_idx.iter().map(|&o| o as i32).collect(), vec![t, p.cfg.v, vpr]),
    ]
}

/// One inference request: a single activation column of length `d_in`.
struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    resp: Sender<Result<Vec<f32>, String>>,
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
    pub d_in: usize,
    pub d_out: usize,
}

impl ServerHandle {
    /// Blocking call: submit one activation, wait for the result.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == self.d_in, "expected {} features, got {}", self.d_in, x.len());
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Request { x, enqueued: Instant::now(), resp: tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv()
            .context("server dropped request")?
            .map_err(|e| anyhow::anyhow!(e))
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Compiled batch size of the artifact (pad up to this).
    pub batch: usize,
    /// Max time to wait for a full batch before flushing a partial one.
    pub max_wait: Duration,
}

/// The server: owns the executor and its packed-weight literals.
pub struct BatchServer {
    pub handle: ServerHandle,
    pub metrics: Arc<Mutex<LatencyRecorder>>,
    shutdown: Sender<()>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl BatchServer {
    /// Start the dispatcher thread. PJRT objects are `!Send`, so the thread
    /// compiles the artifact itself; `fixed` are the artifact inputs that do
    /// not vary per request (packed weights) as host tensors; the activation
    /// matrix `[d_in, batch]` is appended as the final input.
    pub fn start(
        spec: ArtifactSpec,
        fixed: Vec<HostTensor>,
        d_in: usize,
        d_out: usize,
        cfg: ServeConfig,
    ) -> Result<BatchServer> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let metrics = Arc::new(Mutex::new(LatencyRecorder::new()));
        let m2 = Arc::clone(&metrics);
        let join = std::thread::Builder::new()
            .name("hinm-batch-server".into())
            .spawn(move || {
                let setup = (|| -> Result<(Executor, Vec<xla::Literal>)> {
                    let exe = Executor::load(&spec)?;
                    let lits = fixed.iter().map(HostTensor::to_literal).collect::<Result<_>>()?;
                    Ok((exe, lits))
                })();
                match setup {
                    Ok((exe, lits)) => {
                        let _ = ready_tx.send(Ok(()));
                        dispatcher(exe, lits, d_in, d_out, cfg, rx, stop_rx, m2);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                    }
                }
            })?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => anyhow::bail!("server startup failed: {e}"),
            Err(_) => anyhow::bail!("server thread died during startup"),
        }
        Ok(BatchServer {
            handle: ServerHandle { tx, d_in, d_out },
            metrics,
            shutdown: stop_tx,
            join: Some(join),
        })
    }

    pub fn stop(mut self) {
        let _ = self.shutdown.send(());
        // Handle sender must drop for the dispatcher loop to exit cleanly.
        drop(self.handle.tx);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatcher(
    exe: Executor,
    fixed_inputs: Vec<xla::Literal>,
    d_in: usize,
    d_out: usize,
    cfg: ServeConfig,
    rx: Receiver<Request>,
    stop: Receiver<()>,
    metrics: Arc<Mutex<LatencyRecorder>>,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.batch);
    loop {
        if stop.try_recv().is_ok() {
            break;
        }
        // Collect up to `batch` requests, flushing on timeout.
        let deadline = Instant::now() + cfg.max_wait;
        while pending.len() < cfg.batch {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left.max(Duration::from_micros(50))) {
                Ok(req) => pending.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    flush(&exe, &fixed_inputs, d_in, d_out, cfg.batch, &mut pending, &metrics);
                    return;
                }
            }
            if Instant::now() >= deadline && !pending.is_empty() {
                break;
            }
        }
        flush(&exe, &fixed_inputs, d_in, d_out, cfg.batch, &mut pending, &metrics);
    }
}

fn flush(
    exe: &Executor,
    fixed_inputs: &[xla::Literal],
    d_in: usize,
    d_out: usize,
    batch: usize,
    pending: &mut Vec<Request>,
    metrics: &Arc<Mutex<LatencyRecorder>>,
) {
    if pending.is_empty() {
        return;
    }
    let n = pending.len().min(batch);
    let reqs: Vec<Request> = pending.drain(..n).collect();
    // Column-major batch assembly: x[d_in, batch], request j in column j.
    let mut xdata = vec![0.0f32; d_in * batch];
    for (j, r) in reqs.iter().enumerate() {
        for (i, &v) in r.x.iter().enumerate() {
            xdata[i * batch + j] = v;
        }
    }
    let run = || -> Result<Vec<Vec<f32>>> {
        let xlit = lit_f32(&xdata, &[d_in, batch])?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(fixed_inputs.len() + 1);
        for l in fixed_inputs {
            // Literals are cheap to clone? They are host buffers — reuse by
            // shallow copy is unavailable; re-wrap raw data instead.
            inputs.push(clone_literal(l)?);
        }
        inputs.push(xlit);
        let outs = exe.run(&inputs)?;
        let y = lit_to_f32(&outs[0])?;
        anyhow::ensure!(y.len() == d_out * batch, "bad output size {}", y.len());
        Ok((0..batch)
            .map(|j| (0..d_out).map(|i| y[i * batch + j]).collect())
            .collect())
    };
    match run() {
        Ok(cols) => {
            let mut m = metrics.lock().unwrap();
            for (j, r) in reqs.into_iter().enumerate() {
                m.record(r.enqueued.elapsed());
                let _ = r.resp.send(Ok(cols[j].clone()));
            }
        }
        Err(e) => {
            let msg = format!("batch execution failed: {e:#}");
            for r in reqs {
                let _ = r.resp.send(Err(msg.clone()));
            }
        }
    }
}

/// Deep-copy a literal (PJRT literals are host-side buffers).
fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    use xla::ElementType;
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match l.ty()? {
        ElementType::F32 => lit_f32(&l.to_vec::<f32>()?, &dims),
        ElementType::S32 => crate::runtime::executor::lit_i32(&l.to_vec::<i32>()?, &dims),
        t => anyhow::bail!("unsupported literal type {t:?}"),
    }
}

#[cfg(test)]
mod tests {
    // Server behaviour over a real PJRT executor is covered by
    // rust/tests/serve_integration.rs (requires `make artifacts`). Unit
    // coverage here focuses on batch assembly layout.

    #[test]
    fn column_major_assembly() {
        // Mirrors the layout logic in `flush`.
        let d_in = 3;
        let batch = 4;
        let reqs = vec![vec![1.0f32, 2.0, 3.0], vec![10.0, 20.0, 30.0]];
        let mut xdata = vec![0.0f32; d_in * batch];
        for (j, r) in reqs.iter().enumerate() {
            for (i, &v) in r.iter().enumerate() {
                xdata[i * batch + j] = v;
            }
        }
        assert_eq!(xdata[0 * batch + 0], 1.0);
        assert_eq!(xdata[1 * batch + 0], 2.0);
        assert_eq!(xdata[0 * batch + 1], 10.0);
        assert_eq!(xdata[2 * batch + 1], 30.0);
        assert_eq!(xdata[0 * batch + 2], 0.0); // padding column
    }
}
