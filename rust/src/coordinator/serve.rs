//! Sharded batched-inference engine over swappable SpMM backends.
//!
//! A vLLM-router-style request path: clients submit single activations
//! into one *bounded* queue (a full queue blocks the submitter —
//! backpressure, not unbounded growth); `replicas` worker threads each own
//! a [`SpmmBackend`] instance built once at startup (weights materialized
//! per worker, never re-uploaded per batch) and pull batches off the
//! shared queue. Batching is continuous and the window is anchored at
//! first-request arrival: an idle worker *blocks* on the queue — 0% CPU —
//! and only once a request lands does it keep collecting for at most
//! `max_wait` (or until the batch fills, whichever is first) before
//! flushing. Stragglers are zero-padded up to a backend's compiled batch
//! width (flexible backends get exactly the live columns) and results
//! fanned back to the waiting clients; latency is recorded per replica and
//! in aggregate.
//!
//! Shutdown closes the queue, which wakes every worker and blocked
//! submitter: already-queued requests are drained and answered, new
//! submissions fail with "server stopped", and `stop()` returns once all
//! workers have joined.

use super::metrics::EngineMetrics;
use crate::models::chain::HinmModel;
use crate::runtime::backend::SpmmBackend;
use crate::runtime::registry::ArtifactSpec;
use crate::tensor::Matrix;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub use crate::runtime::backend::{packed_host_tensors, HostTensor, NativeCpuBackend, PjrtBackend};

// ---------------------------------------------------------------------------
// Bounded MPMC queue (condvar-based; std has no bounded multi-consumer
// channel). Closing wakes all waiters; pops drain remaining items first.
// ---------------------------------------------------------------------------

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push (backpressure). Returns the item back if closed.
    fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.cap {
                break;
            }
            st = self.not_full.wait(st).unwrap();
        }
        st.items.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pop, blocking until an item arrives. `None` only when the queue is
    /// closed *and* fully drained.
    fn pop_blocking(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Pop with a deadline. `None` on deadline expiry or on closed+drained.
    fn pop_until(&self, deadline: Instant) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Close: new pushes fail, blocked pushers/poppers wake, remaining
    /// items stay poppable until drained.
    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Non-blocking pop (panic-path draining).
    fn try_pop(&self) -> Option<T> {
        self.state.lock().unwrap().items.pop_front()
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// One inference request: a single activation column of length `d_in`.
struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    resp: Sender<Result<Vec<f32>, String>>,
}

/// Handle for submitting requests; cheap to clone and share across client
/// threads.
#[derive(Clone)]
pub struct ServerHandle {
    queue: Arc<BoundedQueue<Request>>,
    pub d_in: usize,
    pub d_out: usize,
}

impl ServerHandle {
    /// Blocking call: submit one activation, wait for the result. Blocks
    /// while the request queue is full (backpressure); errors if the server
    /// has stopped.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == self.d_in, "expected {} features, got {}", self.d_in, x.len());
        let (tx, rx) = mpsc::channel();
        self.queue
            .push(Request { x, enqueued: Instant::now(), resp: tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        rx.recv()
            .context("server dropped request")?
            .map_err(|e| anyhow::anyhow!(e))
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max requests per flush (the artifact's compiled batch dimension on
    /// the PJRT backend, which gets stragglers zero-padded up to it; the
    /// native backend receives exactly the live requests).
    pub batch: usize,
    /// Batch window: max time a worker keeps collecting after its *first*
    /// request arrives before flushing a partial batch.
    pub max_wait: Duration,
    /// Worker replicas, each with its own backend instance.
    pub replicas: usize,
    /// Request-queue bound; 0 picks `replicas * batch * 4`.
    pub queue_depth: usize,
}

impl ServeConfig {
    pub fn new(batch: usize, max_wait: Duration) -> Self {
        Self { batch, max_wait, replicas: 1, queue_depth: 0 }
    }

    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    fn effective_queue_depth(&self) -> usize {
        if self.queue_depth > 0 {
            self.queue_depth
        } else {
            (self.replicas.max(1) * self.batch.max(1) * 4).max(1)
        }
    }
}

/// Builds one backend per replica, on that replica's own thread (PJRT
/// handles are `!Send`, so construction cannot happen on the caller).
pub type BackendFactory = Arc<dyn Fn(usize) -> Result<Box<dyn SpmmBackend>> + Send + Sync>;

/// The sharded batch server.
pub struct BatchServer {
    pub handle: ServerHandle,
    pub metrics: Arc<EngineMetrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Fails the engine fast when a worker *panics* (a backend bug): without
/// this, a dead worker at replicas=1 leaves the queue open and every later
/// `infer()` blocks forever. On unwind it closes the queue (new pushes →
/// "server stopped") and drops whatever is still queued, which drops those
/// requests' response senders and errors their waiting clients. Normal
/// worker exit only happens once the queue is already closed and drained,
/// and live replicas must keep draining on shutdown, so this acts on
/// panicking threads only.
struct CloseOnExit(Arc<BoundedQueue<Request>>);

impl Drop for CloseOnExit {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.close();
            while self.0.try_pop().is_some() {}
        }
    }
}

impl BatchServer {
    /// Start `cfg.replicas` workers, each owning a backend built by
    /// `factory(replica_id)` on its own thread. Fails (after joining all
    /// workers) if any backend fails to build or replicas disagree on
    /// model dimensions.
    pub fn start(factory: BackendFactory, cfg: ServeConfig) -> Result<BatchServer> {
        anyhow::ensure!(cfg.batch >= 1, "batch must be ≥ 1");
        let replicas = cfg.replicas.max(1);
        let queue = Arc::new(BoundedQueue::new(cfg.effective_queue_depth()));
        let metrics = Arc::new(EngineMetrics::new(replicas));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize), String>>();

        let mut workers = Vec::with_capacity(replicas);
        for r in 0..replicas {
            let q = Arc::clone(&queue);
            let m = Arc::clone(&metrics);
            let f = Arc::clone(&factory);
            let ready = ready_tx.clone();
            let wcfg = cfg.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("hinm-serve-{r}"))
                .spawn(move || {
                    let _guard = CloseOnExit(Arc::clone(&q));
                    let mut backend = match (f.as_ref())(r) {
                        Ok(b) => {
                            let _ = ready.send(Ok((b.d_in(), b.d_out())));
                            b
                        }
                        Err(e) => {
                            let _ = ready.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                    drop(ready);
                    worker_loop(r, backend.as_mut(), &wcfg, &q, &m);
                });
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    queue.close();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e).context("spawning replica worker");
                }
            }
        }
        drop(ready_tx);

        let mut dims: Option<(usize, usize)> = None;
        for _ in 0..replicas {
            let msg = ready_rx.recv();
            let fail = |queue: &BoundedQueue<Request>, workers: Vec<std::thread::JoinHandle<()>>| {
                queue.close();
                for w in workers {
                    let _ = w.join();
                }
            };
            match msg {
                Ok(Ok(d)) => match dims {
                    None => dims = Some(d),
                    Some(prev) if prev == d => {}
                    Some(prev) => {
                        fail(&queue, workers);
                        anyhow::bail!("replicas disagree on model dims: {prev:?} vs {d:?}");
                    }
                },
                Ok(Err(e)) => {
                    fail(&queue, workers);
                    anyhow::bail!("replica startup failed: {e}");
                }
                Err(_) => {
                    fail(&queue, workers);
                    anyhow::bail!("server thread died during startup");
                }
            }
        }
        let (d_in, d_out) = dims.expect("at least one replica");

        Ok(BatchServer { handle: ServerHandle { queue, d_in, d_out }, metrics, workers })
    }

    /// Native-backend engine over a shared [`HinmModel`] — runs anywhere,
    /// no artifacts needed.
    pub fn start_native(model: Arc<HinmModel>, cfg: ServeConfig) -> Result<BatchServer> {
        let factory: BackendFactory = Arc::new(move |_replica| {
            let b: Box<dyn SpmmBackend> = Box::new(NativeCpuBackend::new(Arc::clone(&model)));
            Ok(b)
        });
        Self::start(factory, cfg)
    }

    /// PJRT-backend engine: each replica compiles the artifact and
    /// materializes the fixed packed-weight literals once on its thread.
    pub fn start_pjrt(
        spec: ArtifactSpec,
        fixed: Vec<HostTensor>,
        d_in: usize,
        d_out: usize,
        cfg: ServeConfig,
    ) -> Result<BatchServer> {
        let batch = cfg.batch;
        let factory: BackendFactory = Arc::new(move |_replica| {
            let b: Box<dyn SpmmBackend> =
                Box::new(PjrtBackend::new(&spec, &fixed, d_in, d_out, batch)?);
            Ok(b)
        });
        Self::start(factory, cfg)
    }

    /// Stop the engine: close the queue, answer everything still queued,
    /// join all workers. Returns promptly even mid-batch-window.
    pub fn stop(self) {
        // Drop runs the shutdown sequence.
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.handle.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Per-replica loop: block for the first request (idle costs nothing),
/// then collect until the batch fills or the window — anchored at that
/// first arrival — expires; flush; repeat. Exits once the queue is closed
/// and drained.
fn worker_loop(
    replica: usize,
    backend: &mut dyn SpmmBackend,
    cfg: &ServeConfig,
    queue: &BoundedQueue<Request>,
    metrics: &EngineMetrics,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.batch);
    while let Some(first) = queue.pop_blocking() {
        // Window anchored at the first request's *arrival*: time it spent
        // queued while workers were busy counts against the window.
        let deadline = first.enqueued + cfg.max_wait;
        pending.push(first);
        while pending.len() < cfg.batch {
            match queue.pop_until(deadline) {
                Some(req) => pending.push(req),
                None => break,
            }
        }
        flush(replica, backend, cfg.batch, &mut pending, metrics);
    }
}

/// Execute one padded batch and fan results (or the error) back out.
/// Metrics are updated before responses are sent, so a client observing
/// its reply also observes its own sample recorded.
fn flush(
    replica: usize,
    backend: &mut dyn SpmmBackend,
    batch: usize,
    pending: &mut Vec<Request>,
    metrics: &EngineMetrics,
) {
    if pending.is_empty() {
        return;
    }
    debug_assert!(pending.len() <= batch);
    let reqs: Vec<Request> = pending.drain(..).collect();
    let n = reqs.len();
    let d_in = backend.d_in();
    let d_out = backend.d_out();

    // Column-major batch assembly: request j in column j. A backend with a
    // compiled batch width gets stragglers zero-padded up to it; flexible
    // backends get exactly the live columns (no padding compute).
    let width = backend.fixed_batch().unwrap_or(n).max(n);
    let mut x = Matrix::zeros(d_in, width);
    for (j, r) in reqs.iter().enumerate() {
        for (i, &v) in r.x.iter().enumerate() {
            x.data[i * width + j] = v;
        }
    }

    let result = backend.run_batch(&x).and_then(|y| {
        anyhow::ensure!(
            y.rows == d_out && y.cols == width,
            "backend returned {}×{}, expected {}×{}",
            y.rows,
            y.cols,
            d_out,
            width
        );
        Ok(y)
    });

    match result {
        Ok(y) => {
            let mut cols = Vec::with_capacity(n);
            let mut lats = Vec::with_capacity(n);
            for (j, r) in reqs.iter().enumerate() {
                cols.push((0..d_out).map(|i| y.data[i * width + j]).collect::<Vec<f32>>());
                lats.push(r.enqueued.elapsed());
            }
            {
                let mut rep = metrics.replicas[replica].lock().unwrap();
                rep.batches += 1;
                rep.requests += n;
                for &l in &lats {
                    rep.latency.record(l);
                }
            }
            {
                let mut agg = metrics.aggregate.lock().unwrap();
                for &l in &lats {
                    agg.record(l);
                }
            }
            metrics.throughput.lock().unwrap().add(n);
            for (r, col) in reqs.into_iter().zip(cols) {
                let _ = r.resp.send(Ok(col));
            }
        }
        Err(e) => {
            metrics.replicas[replica].lock().unwrap().errors += 1;
            let msg = format!("batch execution failed: {e:#}");
            for r in reqs {
                let _ = r.resp.send(Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine-level behaviour (batching, padding, windows, shutdown,
    // replicas) lives in tests/serve_engine.rs over a mock backend; here we
    // cover the queue primitive and batch-assembly layout.

    #[test]
    fn queue_fifo_and_close_drains() {
        let q: BoundedQueue<u32> = BoundedQueue::new(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(q.push(3).is_err(), "push after close must fail");
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), Some(2));
        assert_eq!(q.pop_blocking(), None);
        assert_eq!(q.pop_until(Instant::now() + Duration::from_millis(1)), None);
    }

    #[test]
    fn queue_pop_until_times_out() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let t0 = Instant::now();
        assert_eq!(q.pop_until(t0 + Duration::from_millis(50)), None);
        assert!(t0.elapsed() >= Duration::from_millis(40), "returned too early");
    }

    #[test]
    fn queue_bounded_push_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(10u32).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push(20u32).is_ok());
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(q.len(), 1, "second push must be blocked by the bound");
        assert_eq!(q.pop_blocking(), Some(10));
        assert!(pusher.join().unwrap(), "blocked push should complete after pop");
        assert_eq!(q.pop_blocking(), Some(20));
    }

    #[test]
    fn queue_close_wakes_blocked_push() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(1u32).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push(2u32).is_err());
        std::thread::sleep(Duration::from_millis(50));
        q.close();
        assert!(pusher.join().unwrap(), "blocked push must error out on close");
    }

    #[test]
    fn column_major_assembly() {
        // Mirrors the layout logic in `flush`.
        let d_in = 3;
        let batch = 4;
        let reqs = [vec![1.0f32, 2.0, 3.0], vec![10.0, 20.0, 30.0]];
        let mut xdata = vec![0.0f32; d_in * batch];
        for (j, r) in reqs.iter().enumerate() {
            for (i, &v) in r.iter().enumerate() {
                xdata[i * batch + j] = v;
            }
        }
        assert_eq!(xdata[0], 1.0);
        assert_eq!(xdata[batch], 2.0);
        assert_eq!(xdata[1], 10.0);
        assert_eq!(xdata[2 * batch + 1], 30.0);
        assert_eq!(xdata[2], 0.0); // padding column
    }
}
