//! Sharded batched-inference engine over swappable SpMM backends.
//!
//! A vLLM-router-style request path: clients submit single activations
//! into one *bounded priority queue* (a full queue blocks the submitter —
//! backpressure, not unbounded growth); `replicas` worker threads each own
//! a [`SpmmBackend`] instance built once at startup (weights materialized
//! per worker, never re-uploaded per batch) and pull batches off the
//! shared queue. Batching is continuous and the window is anchored at
//! first-request arrival: an idle worker *blocks* on the queue — 0% CPU —
//! and only once a request lands does it keep collecting for at most
//! `max_wait` (or until the batch fills, whichever is first) before
//! flushing. Stragglers are zero-padded up to a backend's compiled batch
//! width (flexible backends get exactly the live columns) and results
//! fanned back to the waiting clients; latency is recorded per replica and
//! in aggregate.
//!
//! **Scheduling.** Each request carries a [`Priority`] and an optional
//! deadline. The queue pops strictly by `(priority, arrival)`: a queued
//! High request always runs before a queued Normal or Low one, and
//! requests of equal priority run in arrival order. A request whose
//! deadline has passed is answered with [`InferError::DeadlineExpired`]
//! *instead of being computed* — checked at enqueue (including while
//! blocked on a full queue), at pop, and once more just before batch
//! assembly (see `DESIGN.md` §13 for the exact expiry points).
//!
//! Shutdown closes the queue, which wakes every worker and blocked
//! submitter: already-queued requests are drained and answered (expired
//! ones with a timeout error), new submissions fail with
//! [`InferError::Stopped`], and `stop()` returns once all workers have
//! joined.

use super::metrics::EngineMetrics;
use crate::models::chain::HinmModel;
use crate::runtime::backend::{CacheStats, CachedBackend, SpmmBackend};
use crate::runtime::registry::ArtifactSpec;
use crate::tensor::Matrix;
use anyhow::{Context, Result};
use std::collections::BinaryHeap;
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub use crate::runtime::backend::{packed_host_tensors, HostTensor, NativeCpuBackend, PjrtBackend};

// ---------------------------------------------------------------------------
// Scheduling types
// ---------------------------------------------------------------------------

/// Scheduling class of a request. The queue always serves a higher
/// priority before a lower one; within one priority, arrival order wins.
///
/// Variants are declared lowest-first so the derived `Ord` gives
/// `Low < Normal < High`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Best-effort: runs only when no Normal/High work is queued.
    Low,
    /// The default class; what [`ServerHandle::infer`] submits.
    Normal,
    /// Latency-critical: jumps ahead of everything already queued at
    /// Normal/Low (it does not preempt a batch that is already executing).
    High,
}

impl Priority {
    /// All priorities, highest first (display/reporting order).
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Low];

    /// Wire/CLI name: `"high"`, `"normal"`, or `"low"`.
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    /// Parse the wire/CLI name (case-sensitive, lowercase).
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }

    /// Dense index for per-priority counters: High=0, Normal=1, Low=2
    /// (matches [`Priority::ALL`]).
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

/// Why an inference request failed. The HTTP front maps these onto status
/// codes (`DeadlineExpired` → 504, `Stopped` → 503, `BadRequest` → 400,
/// `Backend` → 500).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InferError {
    /// The deadline passed before the request was executed; the backend
    /// never saw it.
    DeadlineExpired,
    /// The backend failed while executing the batch carrying this request.
    Backend(String),
    /// The server stopped (or a worker died) before the request was
    /// answered.
    Stopped,
    /// The request was malformed (e.g. wrong activation length) and was
    /// rejected before queuing.
    BadRequest(String),
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::DeadlineExpired => write!(f, "deadline expired before execution (timeout)"),
            InferError::Backend(m) => write!(f, "{m}"),
            InferError::Stopped => write!(f, "server stopped"),
            InferError::BadRequest(m) => write!(f, "bad request: {m}"),
        }
    }
}

impl std::error::Error for InferError {}

// ---------------------------------------------------------------------------
// Bounded priority queue (condvar-based; std has no bounded multi-consumer
// channel). A binary heap keyed by (priority, arrival seq): pops return the
// highest queued priority, FIFO within a priority. Closing wakes all
// waiters; pops drain remaining items first.
// ---------------------------------------------------------------------------

/// Heap entry: max-heap order = higher priority first, then *lower*
/// arrival sequence first (FIFO within a priority class).
struct HeapEntry<T> {
    pri: Priority,
    seq: u64,
    item: T,
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.pri == other.pri && self.seq == other.seq
    }
}

impl<T> Eq for HeapEntry<T> {}

impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: compare priority ascending (so High is
        // greatest), then invert the sequence comparison so the *earliest*
        // arrival is greatest within a class.
        self.pri.cmp(&other.pri).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct QueueState<T> {
    items: BinaryHeap<HeapEntry<T>>,
    next_seq: u64,
    closed: bool,
}

struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

/// Why a push did not enqueue; carries the item back to the caller.
enum PushRejected<T> {
    /// The queue was closed (server stopping).
    Closed(T),
    /// The push deadline passed while blocked on a full queue.
    Expired(T),
}

impl<T> BoundedQueue<T> {
    fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(QueueState {
                items: BinaryHeap::new(),
                next_seq: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push (backpressure), bounded by an optional `deadline`: a
    /// deadline-bearing request must not wait out a long backpressure
    /// stall only to be expired later — it fails fast once its deadline
    /// passes while the queue is full.
    fn push(
        &self,
        pri: Priority,
        item: T,
        deadline: Option<Instant>,
    ) -> Result<(), PushRejected<T>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(PushRejected::Closed(item));
            }
            if st.items.len() < self.cap {
                break;
            }
            match deadline {
                None => st = self.not_full.wait(st).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(PushRejected::Expired(item));
                    }
                    let (guard, _) = self.not_full.wait_timeout(st, d - now).unwrap();
                    st = guard;
                }
            }
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.items.push(HeapEntry { pri, seq, item });
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pop the highest-priority item, blocking until one arrives. `None`
    /// only when the queue is closed *and* fully drained.
    fn pop_blocking(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(e) = st.items.pop() {
                drop(st);
                self.not_full.notify_one();
                return Some(e.item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Pop with a deadline. `None` on deadline expiry or on closed+drained.
    fn pop_until(&self, deadline: Instant) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(e) = st.items.pop() {
                drop(st);
                self.not_full.notify_one();
                return Some(e.item);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Close: new pushes fail, blocked pushers/poppers wake, remaining
    /// items stay poppable until drained.
    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Non-blocking pop (panic-path draining).
    fn try_pop(&self) -> Option<T> {
        self.state.lock().unwrap().items.pop().map(|e| e.item)
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// One inference request: a single activation column of length `d_in`.
struct Request {
    x: Vec<f32>,
    priority: Priority,
    /// Absolute expiry instant; past it the request is answered with
    /// [`InferError::DeadlineExpired`] instead of being computed.
    deadline: Option<Instant>,
    enqueued: Instant,
    resp: Sender<Result<Vec<f32>, InferError>>,
}

impl Request {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Handle for submitting requests; cheap to clone and share across client
/// threads.
#[derive(Clone)]
pub struct ServerHandle {
    queue: Arc<BoundedQueue<Request>>,
    metrics: Arc<EngineMetrics>,
    /// Uncompressed input channels each request must carry.
    pub d_in: usize,
    /// Output channels each response carries.
    pub d_out: usize,
}

impl ServerHandle {
    /// Blocking call: submit one activation at [`Priority::Normal`] with no
    /// deadline, wait for the result. Blocks while the request queue is
    /// full (backpressure); errors if the server has stopped.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.infer_opts(x, Priority::Normal, None).map_err(anyhow::Error::new)
    }

    /// Blocking call with explicit scheduling: submit one activation at
    /// `priority`, optionally bounded by `deadline` (measured from now).
    ///
    /// A request whose deadline has already passed at submission — or
    /// passes while the submitter is blocked on a full queue — is rejected
    /// with [`InferError::DeadlineExpired`] and never enters the queue;
    /// one that expires *while queued* is answered with the same error
    /// without being computed. All are counted in
    /// [`EngineMetrics::scheduler`].
    pub fn infer_opts(
        &self,
        x: Vec<f32>,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<Vec<f32>, InferError> {
        if x.len() != self.d_in {
            return Err(InferError::BadRequest(format!(
                "expected {} features, got {}",
                self.d_in,
                x.len()
            )));
        }
        let now = Instant::now();
        let deadline = deadline.map(|d| now + d);
        if deadline.is_some_and(|d| d <= now) {
            self.metrics.scheduler.lock().unwrap().expired_at_enqueue += 1;
            return Err(InferError::DeadlineExpired);
        }
        let (tx, rx) = mpsc::channel();
        let req = Request { x, priority, deadline, enqueued: now, resp: tx };
        match self.queue.push(priority, req, deadline) {
            Ok(()) => {}
            Err(PushRejected::Closed(_)) => return Err(InferError::Stopped),
            Err(PushRejected::Expired(_)) => {
                self.metrics.scheduler.lock().unwrap().expired_at_enqueue += 1;
                return Err(InferError::DeadlineExpired);
            }
        }
        match rx.recv() {
            Ok(result) => result,
            // The worker (and its response sender) died before answering.
            Err(_) => Err(InferError::Stopped),
        }
    }

    /// The engine's metrics (shared with [`BatchServer::metrics`]).
    pub fn metrics(&self) -> &Arc<EngineMetrics> {
        &self.metrics
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max requests per flush (the artifact's compiled batch dimension on
    /// the PJRT backend, which gets stragglers zero-padded up to it; the
    /// native backend receives exactly the live requests).
    pub batch: usize,
    /// Batch window: max time a worker keeps collecting after its *first*
    /// request arrives before flushing a partial batch.
    pub max_wait: Duration,
    /// Worker replicas, each with its own backend instance.
    pub replicas: usize,
    /// Request-queue bound; 0 picks `replicas * batch * 4`.
    pub queue_depth: usize,
}

impl ServeConfig {
    /// Config with the given flush size and batch window; 1 replica,
    /// default queue depth.
    pub fn new(batch: usize, max_wait: Duration) -> Self {
        Self { batch, max_wait, replicas: 1, queue_depth: 0 }
    }

    /// Set the number of worker replicas.
    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    /// Set the request-queue bound (0 = `replicas * batch * 4`).
    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    fn effective_queue_depth(&self) -> usize {
        if self.queue_depth > 0 {
            self.queue_depth
        } else {
            (self.replicas.max(1) * self.batch.max(1) * 4).max(1)
        }
    }
}

/// Builds one backend per replica, on that replica's own thread (PJRT
/// handles are `!Send`, so construction cannot happen on the caller).
pub type BackendFactory = Arc<dyn Fn(usize) -> Result<Box<dyn SpmmBackend>> + Send + Sync>;

/// Wrap a backend factory so every replica's backend is decorated with a
/// [`CachedBackend`] of `capacity` entries, all reporting into one shared
/// [`CacheStats`].
pub fn cached_factory(
    inner: BackendFactory,
    capacity: usize,
    stats: Arc<CacheStats>,
) -> BackendFactory {
    Arc::new(move |replica| {
        let backend = (inner)(replica)?;
        let cached: Box<dyn SpmmBackend> =
            Box::new(CachedBackend::with_stats(backend, capacity, Arc::clone(&stats)));
        Ok(cached)
    })
}

/// The sharded batch server.
pub struct BatchServer {
    /// Submission handle (clone freely across client threads).
    pub handle: ServerHandle,
    /// Live engine metrics (also reachable via [`ServerHandle::metrics`]).
    pub metrics: Arc<EngineMetrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Fails the engine fast when a worker *panics* (a backend bug): without
/// this, a dead worker at replicas=1 leaves the queue open and every later
/// `infer()` blocks forever. On unwind it closes the queue (new pushes →
/// "server stopped") and drops whatever is still queued, which drops those
/// requests' response senders and errors their waiting clients. Normal
/// worker exit only happens once the queue is already closed and drained,
/// and live replicas must keep draining on shutdown, so this acts on
/// panicking threads only.
struct CloseOnExit(Arc<BoundedQueue<Request>>);

impl Drop for CloseOnExit {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.close();
            while self.0.try_pop().is_some() {}
        }
    }
}

impl BatchServer {
    /// Start `cfg.replicas` workers, each owning a backend built by
    /// `factory(replica_id)` on its own thread. Fails (after joining all
    /// workers) if any backend fails to build or replicas disagree on
    /// model dimensions.
    pub fn start(factory: BackendFactory, cfg: ServeConfig) -> Result<BatchServer> {
        anyhow::ensure!(cfg.batch >= 1, "batch must be ≥ 1");
        let replicas = cfg.replicas.max(1);
        let queue = Arc::new(BoundedQueue::new(cfg.effective_queue_depth()));
        let metrics = Arc::new(EngineMetrics::new(replicas));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize), String>>();

        let mut workers = Vec::with_capacity(replicas);
        for r in 0..replicas {
            let q = Arc::clone(&queue);
            let m = Arc::clone(&metrics);
            let f = Arc::clone(&factory);
            let ready = ready_tx.clone();
            let wcfg = cfg.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("hinm-serve-{r}"))
                .spawn(move || {
                    let _guard = CloseOnExit(Arc::clone(&q));
                    let mut backend = match (f.as_ref())(r) {
                        Ok(b) => {
                            let _ = ready.send(Ok((b.d_in(), b.d_out())));
                            b
                        }
                        Err(e) => {
                            let _ = ready.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                    drop(ready);
                    worker_loop(r, backend.as_mut(), &wcfg, &q, &m);
                });
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    queue.close();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e).context("spawning replica worker");
                }
            }
        }
        drop(ready_tx);

        let mut dims: Option<(usize, usize)> = None;
        for _ in 0..replicas {
            let msg = ready_rx.recv();
            let fail = |queue: &BoundedQueue<Request>, workers: Vec<std::thread::JoinHandle<()>>| {
                queue.close();
                for w in workers {
                    let _ = w.join();
                }
            };
            match msg {
                Ok(Ok(d)) => match dims {
                    None => dims = Some(d),
                    Some(prev) if prev == d => {}
                    Some(prev) => {
                        fail(&queue, workers);
                        anyhow::bail!("replicas disagree on model dims: {prev:?} vs {d:?}");
                    }
                },
                Ok(Err(e)) => {
                    fail(&queue, workers);
                    anyhow::bail!("replica startup failed: {e}");
                }
                Err(_) => {
                    fail(&queue, workers);
                    anyhow::bail!("server thread died during startup");
                }
            }
        }
        let (d_in, d_out) = dims.expect("at least one replica");

        let handle =
            ServerHandle { queue, metrics: Arc::clone(&metrics), d_in, d_out };
        Ok(BatchServer { handle, metrics, workers })
    }

    /// Native-backend engine over a shared [`HinmModel`] — runs anywhere,
    /// no artifacts needed. Kernels execute inline on each replica thread;
    /// see [`BatchServer::start_native_threads`] for a per-replica kernel
    /// worker pool.
    pub fn start_native(model: Arc<HinmModel>, cfg: ServeConfig) -> Result<BatchServer> {
        Self::start_native_threads(model, cfg, 1)
    }

    /// Native-backend engine where every replica owns a pool of
    /// `kernel_threads` kernel lanes (0 = available parallelism) — the
    /// `--kernel-threads` CLI flag lands here. Total kernel threads in the
    /// process is `replicas × kernel_threads`; responses are bit-identical
    /// for any `kernel_threads` setting (DESIGN.md §14).
    pub fn start_native_threads(
        model: Arc<HinmModel>,
        cfg: ServeConfig,
        kernel_threads: usize,
    ) -> Result<BatchServer> {
        let factory: BackendFactory = Arc::new(move |_replica| {
            let b: Box<dyn SpmmBackend> =
                Box::new(NativeCpuBackend::with_threads(Arc::clone(&model), kernel_threads));
            Ok(b)
        });
        Self::start(factory, cfg)
    }

    /// PJRT-backend engine: each replica compiles the artifact and
    /// materializes the fixed packed-weight literals once on its thread.
    pub fn start_pjrt(
        spec: ArtifactSpec,
        fixed: Vec<HostTensor>,
        d_in: usize,
        d_out: usize,
        cfg: ServeConfig,
    ) -> Result<BatchServer> {
        let batch = cfg.batch;
        let factory: BackendFactory = Arc::new(move |_replica| {
            let b: Box<dyn SpmmBackend> =
                Box::new(PjrtBackend::new(&spec, &fixed, d_in, d_out, batch)?);
            Ok(b)
        });
        Self::start(factory, cfg)
    }

    /// Stop the engine: close the queue, answer everything still queued,
    /// join all workers. Returns promptly even mid-batch-window.
    pub fn stop(self) {
        // Drop runs the shutdown sequence.
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.handle.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Answer an expired request with a timeout error (never executed) and
/// count it.
fn expire(req: Request, metrics: &EngineMetrics) {
    metrics.scheduler.lock().unwrap().expired_in_queue += 1;
    let _ = req.resp.send(Err(InferError::DeadlineExpired));
}

/// Per-replica loop: block for the first request (idle costs nothing),
/// then collect until the batch fills or the window — anchored at that
/// first arrival — expires; flush; repeat. Requests that are already past
/// their deadline when popped are answered with a timeout error and do not
/// occupy batch slots (an expired request never anchors a window). Exits
/// once the queue is closed and drained.
fn worker_loop(
    replica: usize,
    backend: &mut dyn SpmmBackend,
    cfg: &ServeConfig,
    queue: &BoundedQueue<Request>,
    metrics: &EngineMetrics,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.batch);
    while let Some(first) = queue.pop_blocking() {
        if first.expired(Instant::now()) {
            expire(first, metrics);
            continue;
        }
        // Window anchored at the first request's *arrival*: time it spent
        // queued while workers were busy counts against the window.
        let deadline = first.enqueued + cfg.max_wait;
        pending.push(first);
        while pending.len() < cfg.batch {
            match queue.pop_until(deadline) {
                Some(req) => {
                    if req.expired(Instant::now()) {
                        expire(req, metrics);
                    } else {
                        pending.push(req);
                    }
                }
                None => break,
            }
        }
        flush(replica, backend, cfg.batch, &mut pending, metrics);
    }
}

/// Execute one padded batch and fan results (or the error) back out.
/// Requests that expired while the batch window was open are swept out and
/// answered with a timeout error first — the backend only ever sees live
/// requests. Metrics are updated before responses are sent, so a client
/// observing its reply also observes its own sample recorded.
fn flush(
    replica: usize,
    backend: &mut dyn SpmmBackend,
    batch: usize,
    pending: &mut Vec<Request>,
    metrics: &EngineMetrics,
) {
    if pending.is_empty() {
        return;
    }
    debug_assert!(pending.len() <= batch);
    let now = Instant::now();
    let mut reqs: Vec<Request> = Vec::with_capacity(pending.len());
    for r in pending.drain(..) {
        if r.expired(now) {
            expire(r, metrics);
        } else {
            reqs.push(r);
        }
    }
    if reqs.is_empty() {
        return;
    }
    let n = reqs.len();
    let d_in = backend.d_in();
    let d_out = backend.d_out();

    // Column-major batch assembly: request j in column j. A backend with a
    // compiled batch width gets stragglers zero-padded up to it; flexible
    // backends get exactly the live columns (no padding compute).
    let width = backend.fixed_batch().unwrap_or(n).max(n);
    let mut x = Matrix::zeros(d_in, width);
    for (j, r) in reqs.iter().enumerate() {
        for (i, &v) in r.x.iter().enumerate() {
            x.data[i * width + j] = v;
        }
    }

    let result = backend.run_batch(&x).and_then(|y| {
        anyhow::ensure!(
            y.rows == d_out && y.cols == width,
            "backend returned {}×{}, expected {}×{}",
            y.rows,
            y.cols,
            d_out,
            width
        );
        Ok(y)
    });

    match result {
        Ok(y) => {
            let mut cols = Vec::with_capacity(n);
            let mut lats = Vec::with_capacity(n);
            for (j, r) in reqs.iter().enumerate() {
                cols.push((0..d_out).map(|i| y.data[i * width + j]).collect::<Vec<f32>>());
                lats.push(r.enqueued.elapsed());
            }
            {
                let mut rep = metrics.replicas[replica].lock().unwrap();
                rep.batches += 1;
                rep.requests += n;
                for &l in &lats {
                    rep.latency.record(l);
                }
            }
            {
                let mut agg = metrics.aggregate.lock().unwrap();
                for &l in &lats {
                    agg.record(l);
                }
            }
            {
                let mut sched = metrics.scheduler.lock().unwrap();
                for r in &reqs {
                    sched.served[r.priority.index()] += 1;
                }
            }
            metrics.throughput.lock().unwrap().add(n);
            for (r, col) in reqs.into_iter().zip(cols) {
                let _ = r.resp.send(Ok(col));
            }
        }
        Err(e) => {
            metrics.replicas[replica].lock().unwrap().errors += 1;
            let msg = format!("batch execution failed: {e:#}");
            for r in reqs {
                let _ = r.resp.send(Err(InferError::Backend(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine-level behaviour (batching, padding, windows, shutdown,
    // replicas, priorities, deadlines) lives in tests/serve_engine.rs and
    // tests/scheduler.rs over mock backends; here we cover the queue
    // primitive and batch-assembly layout.

    #[test]
    fn queue_fifo_within_priority_and_close_drains() {
        let q: BoundedQueue<u32> = BoundedQueue::new(8);
        q.push(Priority::Normal, 1, None).unwrap();
        q.push(Priority::Normal, 2, None).unwrap();
        q.close();
        assert!(q.push(Priority::Normal, 3, None).is_err(), "push after close must fail");
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), Some(2));
        assert_eq!(q.pop_blocking(), None);
        assert_eq!(q.pop_until(Instant::now() + Duration::from_millis(1)), None);
    }

    #[test]
    fn queue_pops_by_priority_then_arrival() {
        let q: BoundedQueue<u32> = BoundedQueue::new(8);
        q.push(Priority::Low, 1, None).unwrap();
        q.push(Priority::Normal, 2, None).unwrap();
        q.push(Priority::High, 3, None).unwrap();
        q.push(Priority::High, 4, None).unwrap();
        q.push(Priority::Low, 5, None).unwrap();
        q.push(Priority::Normal, 6, None).unwrap();
        let order: Vec<u32> = (0..6).map(|_| q.pop_blocking().unwrap()).collect();
        assert_eq!(order, vec![3, 4, 2, 6, 1, 5], "(priority, arrival) ordering violated");
    }

    #[test]
    fn queue_push_with_deadline_fails_fast_on_a_full_queue() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        q.push(Priority::Normal, 1, None).unwrap();
        let t0 = Instant::now();
        let r = q.push(Priority::High, 2, Some(t0 + Duration::from_millis(50)));
        assert!(
            matches!(r, Err(PushRejected::Expired(2))),
            "a deadline-bearing push must not wait out backpressure past its deadline"
        );
        assert!(t0.elapsed() >= Duration::from_millis(40), "returned before the deadline");
        assert!(t0.elapsed() < Duration::from_secs(5), "blocked far past the deadline");
    }

    #[test]
    fn queue_pop_until_times_out() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let t0 = Instant::now();
        assert_eq!(q.pop_until(t0 + Duration::from_millis(50)), None);
        assert!(t0.elapsed() >= Duration::from_millis(40), "returned too early");
    }

    #[test]
    fn queue_bounded_push_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(Priority::Normal, 10u32, None).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push(Priority::Normal, 20u32, None).is_ok());
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(q.len(), 1, "second push must be blocked by the bound");
        assert_eq!(q.pop_blocking(), Some(10));
        assert!(pusher.join().unwrap(), "blocked push should complete after pop");
        assert_eq!(q.pop_blocking(), Some(20));
    }

    #[test]
    fn queue_close_wakes_blocked_push() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(Priority::Normal, 1u32, None).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push(Priority::High, 2u32, None).is_err());
        std::thread::sleep(Duration::from_millis(50));
        q.close();
        assert!(pusher.join().unwrap(), "blocked push must error out on close");
    }

    #[test]
    fn priority_parse_roundtrip() {
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
    }

    #[test]
    fn column_major_assembly() {
        // Mirrors the layout logic in `flush`.
        let d_in = 3;
        let batch = 4;
        let reqs = [vec![1.0f32, 2.0, 3.0], vec![10.0, 20.0, 30.0]];
        let mut xdata = vec![0.0f32; d_in * batch];
        for (j, r) in reqs.iter().enumerate() {
            for (i, &v) in r.iter().enumerate() {
                xdata[i * batch + j] = v;
            }
        }
        assert_eq!(xdata[0], 1.0);
        assert_eq!(xdata[batch], 2.0);
        assert_eq!(xdata[1], 10.0);
        assert_eq!(xdata[2 * batch + 1], 30.0);
        assert_eq!(xdata[2], 0.0); // padding column
    }
}
