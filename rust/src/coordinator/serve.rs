//! Sharded batched-inference engine over swappable SpMM backends.
//!
//! A vLLM-router-style request path: clients submit single activations
//! into one *bounded priority queue* (a full queue blocks the submitter —
//! backpressure, not unbounded growth); `replicas` worker threads each own
//! a [`SpmmBackend`] instance built once at startup (weights materialized
//! per worker, never re-uploaded per batch) and pull batches off the
//! shared queue. Batching is continuous and the window is anchored at
//! first-request arrival: an idle worker *blocks* on the queue — 0% CPU —
//! and only once a request lands does it keep collecting for at most
//! `max_wait` (or until the batch fills, whichever is first) before
//! flushing. Stragglers are zero-padded up to a backend's compiled batch
//! width (flexible backends get exactly the live columns) and results
//! fanned back to the waiting clients; latency is recorded per replica and
//! in aggregate.
//!
//! **Scheduling.** Each request carries a [`Priority`] and an optional
//! deadline. The queue pops strictly by `(priority, arrival)`: a queued
//! High request always runs before a queued Normal or Low one, and
//! requests of equal priority run in arrival order. A request whose
//! deadline has passed is answered with [`InferError::DeadlineExpired`]
//! *instead of being computed* — checked at enqueue (including while
//! blocked on a full queue), at pop, and once more just before batch
//! assembly (see `DESIGN.md` §13 for the exact expiry points).
//!
//! Shutdown closes the queue, which wakes every worker and blocked
//! submitter: already-queued requests are drained and answered (expired
//! ones with a timeout error), new submissions fail with
//! [`InferError::Stopped`], and `stop()` returns once all workers have
//! joined.
//!
//! **Pipeline parallelism.** For deep chains a second axis of parallelism
//! lives below the batch server: [`PipelineServer`] shards a
//! [`HinmModel`] into contiguous stages balanced by planned FLOPs
//! ([`HinmModel::split_stages`]), runs each stage on its own worker
//! thread with bounded hand-off queues in between, and recycles the
//! inter-stage activation buffers so the steady state allocates nothing.
//! A [`crate::runtime::PipelinedBackend`] submits whole batches into
//! stage 0 and blocks for the final stage's output, so the pipeline
//! slots under the existing engine unchanged — batch-server replicas
//! keep several batches in flight, each executing a different stage
//! concurrently (DESIGN.md §15).

use super::metrics::EngineMetrics;
use crate::models::chain::{ActivationBuffers, HinmModel};
use crate::runtime::backend::{CacheStats, CachedBackend, SpmmBackend};
use crate::runtime::registry::{ArtifactSpec, ModelSlot};
use crate::spmm::SpmmEngine;
use crate::tensor::Matrix;
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned};
use anyhow::{Context, Result};
use std::collections::BinaryHeap;
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub use crate::runtime::backend::{packed_host_tensors, HostTensor, NativeCpuBackend, PjrtBackend};

// ---------------------------------------------------------------------------
// Scheduling types
// ---------------------------------------------------------------------------

/// Scheduling class of a request. The queue always serves a higher
/// priority before a lower one; within one priority, arrival order wins.
///
/// Variants are declared lowest-first so the derived `Ord` gives
/// `Low < Normal < High`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Best-effort: runs only when no Normal/High work is queued.
    Low,
    /// The default class; what [`ServerHandle::infer`] submits.
    Normal,
    /// Latency-critical: jumps ahead of everything already queued at
    /// Normal/Low (it does not preempt a batch that is already executing).
    High,
}

impl Priority {
    /// All priorities, highest first (display/reporting order).
    pub const ALL: [Priority; 3] = [Priority::High, Priority::Normal, Priority::Low];

    /// Wire/CLI name: `"high"`, `"normal"`, or `"low"`.
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    /// Parse the wire/CLI name (case-sensitive, lowercase).
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }

    /// Dense index for per-priority counters: High=0, Normal=1, Low=2
    /// (matches [`Priority::ALL`]).
    pub fn index(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

/// Why an inference request failed. The HTTP front maps these onto status
/// codes (`DeadlineExpired` → 504, `Stopped` → 503, `BadRequest` → 400,
/// `Backend` → 500, `Upstream` → 502, `UpstreamTimeout` → 504) — one
/// taxonomy shared by the single-host front and the `hinm route` router
/// tier, so a client sees the same statuses whichever tier it talks to.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InferError {
    /// The deadline passed before the request was executed; the backend
    /// never saw it.
    DeadlineExpired,
    /// The backend failed while executing the batch carrying this request.
    Backend(String),
    /// The server stopped (or a worker died) before the request was
    /// answered.
    Stopped,
    /// The request was malformed (e.g. wrong activation length) and was
    /// rejected before queuing.
    BadRequest(String),
    /// A downstream replica host was unreachable (connection refused,
    /// reset, or closed mid-response) and no retry could answer — the
    /// request may never have reached an engine. Distinct from
    /// [`InferError::UpstreamTimeout`] so operators can tell dead hosts
    /// (502) from slow ones (504).
    Upstream(String),
    /// A downstream replica host accepted the request but did not answer
    /// within the attempt budget.
    UpstreamTimeout(String),
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::DeadlineExpired => write!(f, "deadline expired before execution (timeout)"),
            InferError::Backend(m) => write!(f, "{m}"),
            InferError::Stopped => write!(f, "server stopped"),
            InferError::BadRequest(m) => write!(f, "bad request: {m}"),
            InferError::Upstream(m) => write!(f, "upstream unreachable: {m}"),
            InferError::UpstreamTimeout(m) => write!(f, "upstream timed out: {m}"),
        }
    }
}

impl std::error::Error for InferError {}

// ---------------------------------------------------------------------------
// Bounded priority queue (condvar-based; std has no bounded multi-consumer
// channel). A binary heap keyed by (priority, arrival seq): pops return the
// highest queued priority, FIFO within a priority. Closing wakes all
// waiters; pops drain remaining items first.
// ---------------------------------------------------------------------------

/// Heap entry: max-heap order = higher priority first, then *lower*
/// arrival sequence first (FIFO within a priority class).
struct HeapEntry<T> {
    pri: Priority,
    seq: u64,
    item: T,
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.pri == other.pri && self.seq == other.seq
    }
}

impl<T> Eq for HeapEntry<T> {}

impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: compare priority ascending (so High is
        // greatest), then invert the sequence comparison so the *earliest*
        // arrival is greatest within a class.
        self.pri.cmp(&other.pri).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct QueueState<T> {
    items: BinaryHeap<HeapEntry<T>>,
    next_seq: u64,
    closed: bool,
}

struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

/// Why a push did not enqueue; carries the item back to the caller.
enum PushRejected<T> {
    /// The queue was closed (server stopping).
    Closed(T),
    /// The push deadline passed while blocked on a full queue.
    Expired(T),
}

impl<T> BoundedQueue<T> {
    fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(QueueState {
                items: BinaryHeap::new(),
                next_seq: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push (backpressure), bounded by an optional `deadline`: a
    /// deadline-bearing request must not wait out a long backpressure
    /// stall only to be expired later — it fails fast once its deadline
    /// passes while the queue is full.
    fn push(
        &self,
        pri: Priority,
        item: T,
        deadline: Option<Instant>,
    ) -> Result<(), PushRejected<T>> {
        let mut st = lock_unpoisoned(&self.state);
        loop {
            if st.closed {
                return Err(PushRejected::Closed(item));
            }
            if st.items.len() < self.cap {
                break;
            }
            match deadline {
                None => st = wait_unpoisoned(&self.not_full, st),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(PushRejected::Expired(item));
                    }
                    let (guard, _) = wait_timeout_unpoisoned(&self.not_full, st, d - now);
                    st = guard;
                }
            }
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.items.push(HeapEntry { pri, seq, item });
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pop the highest-priority item, blocking until one arrives. `None`
    /// only when the queue is closed *and* fully drained.
    fn pop_blocking(&self) -> Option<T> {
        let mut st = lock_unpoisoned(&self.state);
        loop {
            if let Some(e) = st.items.pop() {
                drop(st);
                self.not_full.notify_one();
                return Some(e.item);
            }
            if st.closed {
                return None;
            }
            st = wait_unpoisoned(&self.not_empty, st);
        }
    }

    /// Pop with a deadline. `None` on deadline expiry or on closed+drained.
    fn pop_until(&self, deadline: Instant) -> Option<T> {
        let mut st = lock_unpoisoned(&self.state);
        loop {
            if let Some(e) = st.items.pop() {
                drop(st);
                self.not_full.notify_one();
                return Some(e.item);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = wait_timeout_unpoisoned(&self.not_empty, st, deadline - now);
            st = guard;
        }
    }

    /// Close: new pushes fail, blocked pushers/poppers wake, remaining
    /// items stay poppable until drained.
    ///
    /// **Drain-race invariant** (pinned by
    /// `pipeline_shutdown_race_never_loses_a_response` in
    /// `tests/pipeline_serve.rs`): `push` re-checks `closed` under this
    /// same mutex on every wakeup, so a push racing `close` either lands
    /// *before* the flag flips — and is then drained and answered, because
    /// `pop_blocking` returns `None` only once closed **and** empty — or
    /// observes the flag and returns [`PushRejected::Closed`] (a typed
    /// stop error to the submitter). There is no interleaving in which an
    /// item enters the queue and is silently discarded by a normal
    /// shutdown; only a *panic* path (`CloseOnExit` / `PoisonPipeline`)
    /// drops queued items, and dropping them drops their response senders,
    /// which errors the waiting submitters rather than hanging them.
    fn close(&self) {
        lock_unpoisoned(&self.state).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Non-blocking pop (panic-path draining).
    fn try_pop(&self) -> Option<T> {
        lock_unpoisoned(&self.state).items.pop().map(|e| e.item)
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// One inference request: a single activation column of length `d_in`.
struct Request {
    x: Vec<f32>,
    priority: Priority,
    /// Absolute expiry instant; past it the request is answered with
    /// [`InferError::DeadlineExpired`] instead of being computed.
    deadline: Option<Instant>,
    enqueued: Instant,
    resp: Sender<Result<Vec<f32>, InferError>>,
}

impl Request {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Handle for submitting requests; cheap to clone and share across client
/// threads.
#[derive(Clone)]
pub struct ServerHandle {
    queue: Arc<BoundedQueue<Request>>,
    metrics: Arc<EngineMetrics>,
    /// Uncompressed input channels each request must carry.
    pub d_in: usize,
    /// Output channels each response carries.
    pub d_out: usize,
}

impl ServerHandle {
    /// Blocking call: submit one activation at [`Priority::Normal`] with no
    /// deadline, wait for the result. Blocks while the request queue is
    /// full (backpressure); errors if the server has stopped.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.infer_opts(x, Priority::Normal, None).map_err(anyhow::Error::new)
    }

    /// Blocking call with explicit scheduling: submit one activation at
    /// `priority`, optionally bounded by `deadline` (measured from now).
    ///
    /// A request whose deadline has already passed at submission — or
    /// passes while the submitter is blocked on a full queue — is rejected
    /// with [`InferError::DeadlineExpired`] and never enters the queue;
    /// one that expires *while queued* is answered with the same error
    /// without being computed. All are counted in
    /// [`EngineMetrics::scheduler`].
    pub fn infer_opts(
        &self,
        x: Vec<f32>,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<Vec<f32>, InferError> {
        if x.len() != self.d_in {
            return Err(InferError::BadRequest(format!(
                "expected {} features, got {}",
                self.d_in,
                x.len()
            )));
        }
        let now = Instant::now();
        let deadline = deadline.map(|d| now + d);
        if deadline.is_some_and(|d| d <= now) {
            lock_unpoisoned(&self.metrics.scheduler).expired_at_enqueue += 1;
            return Err(InferError::DeadlineExpired);
        }
        let (tx, rx) = mpsc::channel();
        let req = Request { x, priority, deadline, enqueued: now, resp: tx };
        match self.queue.push(priority, req, deadline) {
            Ok(()) => {}
            Err(PushRejected::Closed(_)) => return Err(InferError::Stopped),
            Err(PushRejected::Expired(_)) => {
                lock_unpoisoned(&self.metrics.scheduler).expired_at_enqueue += 1;
                return Err(InferError::DeadlineExpired);
            }
        }
        match rx.recv() {
            Ok(result) => result,
            // The worker (and its response sender) died before answering.
            Err(_) => Err(InferError::Stopped),
        }
    }

    /// The engine's metrics (shared with [`BatchServer::metrics`]).
    pub fn metrics(&self) -> &Arc<EngineMetrics> {
        &self.metrics
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max requests per flush (the artifact's compiled batch dimension on
    /// the PJRT backend, which gets stragglers zero-padded up to it; the
    /// native backend receives exactly the live requests).
    pub batch: usize,
    /// Batch window: max time a worker keeps collecting after its *first*
    /// request arrives before flushing a partial batch.
    pub max_wait: Duration,
    /// Worker replicas, each with its own backend instance.
    pub replicas: usize,
    /// Request-queue bound; 0 picks `replicas * batch * 4`.
    pub queue_depth: usize,
}

impl ServeConfig {
    /// Config with the given flush size and batch window; 1 replica,
    /// default queue depth.
    pub fn new(batch: usize, max_wait: Duration) -> Self {
        Self { batch, max_wait, replicas: 1, queue_depth: 0 }
    }

    /// Set the number of worker replicas.
    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    /// Set the request-queue bound (0 = `replicas * batch * 4`).
    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    fn effective_queue_depth(&self) -> usize {
        if self.queue_depth > 0 {
            self.queue_depth
        } else {
            (self.replicas.max(1) * self.batch.max(1) * 4).max(1)
        }
    }
}

/// Builds one backend per replica, on that replica's own thread (PJRT
/// handles are `!Send`, so construction cannot happen on the caller).
pub type BackendFactory = Arc<dyn Fn(usize) -> Result<Box<dyn SpmmBackend>> + Send + Sync>;

/// Wrap a backend factory so every replica's backend is decorated with a
/// [`CachedBackend`] of `capacity` entries, all reporting into one shared
/// [`CacheStats`].
pub fn cached_factory(
    inner: BackendFactory,
    capacity: usize,
    stats: Arc<CacheStats>,
) -> BackendFactory {
    Arc::new(move |replica| {
        let backend = (inner)(replica)?;
        let cached: Box<dyn SpmmBackend> =
            Box::new(CachedBackend::with_stats(backend, capacity, Arc::clone(&stats)));
        Ok(cached)
    })
}

/// The sharded batch server.
pub struct BatchServer {
    /// Submission handle (clone freely across client threads).
    pub handle: ServerHandle,
    /// Live engine metrics (also reachable via [`ServerHandle::metrics`]).
    pub metrics: Arc<EngineMetrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Fails the engine fast when a worker *panics* (a backend bug): without
/// this, a dead worker at replicas=1 leaves the queue open and every later
/// `infer()` blocks forever. On unwind it closes the queue (new pushes →
/// "server stopped") and drops whatever is still queued, which drops those
/// requests' response senders and errors their waiting clients. Normal
/// worker exit only happens once the queue is already closed and drained,
/// and live replicas must keep draining on shutdown, so this acts on
/// panicking threads only.
struct CloseOnExit(Arc<BoundedQueue<Request>>);

impl Drop for CloseOnExit {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.close();
            while self.0.try_pop().is_some() {}
        }
    }
}

impl BatchServer {
    /// Start `cfg.replicas` workers, each owning a backend built by
    /// `factory(replica_id)` on its own thread. Fails (after joining all
    /// workers) if any backend fails to build or replicas disagree on
    /// model dimensions.
    pub fn start(factory: BackendFactory, cfg: ServeConfig) -> Result<BatchServer> {
        anyhow::ensure!(cfg.batch >= 1, "batch must be ≥ 1");
        let replicas = cfg.replicas.max(1);
        let queue = Arc::new(BoundedQueue::new(cfg.effective_queue_depth()));
        let metrics = Arc::new(EngineMetrics::new(replicas));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(usize, usize), String>>();

        let mut workers = Vec::with_capacity(replicas);
        for r in 0..replicas {
            let q = Arc::clone(&queue);
            let m = Arc::clone(&metrics);
            let f = Arc::clone(&factory);
            let ready = ready_tx.clone();
            let wcfg = cfg.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("hinm-serve-{r}"))
                .spawn(move || {
                    let _guard = CloseOnExit(Arc::clone(&q));
                    let mut backend = match (f.as_ref())(r) {
                        Ok(b) => {
                            let _ = ready.send(Ok((b.d_in(), b.d_out())));
                            b
                        }
                        Err(e) => {
                            let _ = ready.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                    drop(ready);
                    worker_loop(r, backend.as_mut(), &wcfg, &q, &m);
                });
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    queue.close();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e).context("spawning replica worker");
                }
            }
        }
        drop(ready_tx);

        let mut dims: Option<(usize, usize)> = None;
        for _ in 0..replicas {
            let msg = ready_rx.recv();
            let fail = |queue: &BoundedQueue<Request>, workers: Vec<std::thread::JoinHandle<()>>| {
                queue.close();
                for w in workers {
                    let _ = w.join();
                }
            };
            match msg {
                Ok(Ok(d)) => match dims {
                    None => dims = Some(d),
                    Some(prev) if prev == d => {}
                    Some(prev) => {
                        fail(&queue, workers);
                        anyhow::bail!("replicas disagree on model dims: {prev:?} vs {d:?}");
                    }
                },
                Ok(Err(e)) => {
                    fail(&queue, workers);
                    anyhow::bail!("replica startup failed: {e}");
                }
                Err(_) => {
                    fail(&queue, workers);
                    anyhow::bail!("server thread died during startup");
                }
            }
        }
        let (d_in, d_out) = match dims {
            Some(d) => d,
            None => anyhow::bail!("no replicas configured"),
        };

        let handle =
            ServerHandle { queue, metrics: Arc::clone(&metrics), d_in, d_out };
        Ok(BatchServer { handle, metrics, workers })
    }

    /// Native-backend engine over a shared [`HinmModel`] — runs anywhere,
    /// no artifacts needed. Kernels execute inline on each replica thread;
    /// see [`BatchServer::start_native_threads`] for a per-replica kernel
    /// worker pool.
    pub fn start_native(model: Arc<HinmModel>, cfg: ServeConfig) -> Result<BatchServer> {
        Self::start_native_threads(model, cfg, 1)
    }

    /// Native-backend engine where every replica owns a pool of
    /// `kernel_threads` kernel lanes (0 = available parallelism) — the
    /// `--kernel-threads` CLI flag lands here. Total kernel threads in the
    /// process is `replicas × kernel_threads`; responses are bit-identical
    /// for any `kernel_threads` setting (DESIGN.md §14).
    ///
    /// # Examples
    ///
    /// ```
    /// use hinm::coordinator::{BatchServer, ServeConfig};
    /// use hinm::models::{Activation, HinmModel};
    /// use hinm::sparsity::HinmConfig;
    /// use std::sync::Arc;
    /// use std::time::Duration;
    ///
    /// let cfg = HinmConfig::with_24(4, 0.5);
    /// let model = Arc::new(HinmModel::synthetic_ffn(16, 32, &cfg, Activation::Relu, 7)?);
    /// let server = BatchServer::start_native_threads(
    ///     Arc::clone(&model),
    ///     ServeConfig::new(4, Duration::from_micros(100)).with_replicas(2),
    ///     1,
    /// )?;
    /// let y = server.handle.infer(vec![0.25; 16])?;
    /// assert_eq!(y.len(), 16);
    /// server.stop();
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn start_native_threads(
        model: Arc<HinmModel>,
        cfg: ServeConfig,
        kernel_threads: usize,
    ) -> Result<BatchServer> {
        let factory: BackendFactory = Arc::new(move |_replica| {
            let b: Box<dyn SpmmBackend> =
                Box::new(NativeCpuBackend::with_threads(Arc::clone(&model), kernel_threads));
            Ok(b)
        });
        Self::start(factory, cfg)
    }

    /// Engine over a hot-swappable registry [`ModelSlot`] (DESIGN.md
    /// §18): every replica's backend re-resolves the slot's current model
    /// at batch granularity, so a [`crate::runtime::ModelRegistry::reload`]
    /// takes effect under live traffic — in-flight batches finish on the
    /// old plans, subsequent batches run the new ones, and any per-replica
    /// batch cache (enabled when `cache_capacity > 0`) restarts empty on
    /// swap while `stats` keeps cumulative hit/miss counts.
    pub fn start_slot(
        slot: &Arc<ModelSlot>,
        cfg: ServeConfig,
        kernel_threads: usize,
        cache_capacity: usize,
        stats: Option<Arc<CacheStats>>,
    ) -> Result<BatchServer> {
        Self::start(slot.backend_factory(kernel_threads, cache_capacity, stats), cfg)
    }

    /// PJRT-backend engine: each replica compiles the artifact and
    /// materializes the fixed packed-weight literals once on its thread.
    pub fn start_pjrt(
        spec: ArtifactSpec,
        fixed: Vec<HostTensor>,
        d_in: usize,
        d_out: usize,
        cfg: ServeConfig,
    ) -> Result<BatchServer> {
        let batch = cfg.batch;
        let factory: BackendFactory = Arc::new(move |_replica| {
            let b: Box<dyn SpmmBackend> =
                Box::new(PjrtBackend::new(&spec, &fixed, d_in, d_out, batch)?);
            Ok(b)
        });
        Self::start(factory, cfg)
    }

    /// Stop the engine: close the queue, answer everything still queued,
    /// join all workers. Returns promptly even mid-batch-window.
    pub fn stop(self) {
        // Drop runs the shutdown sequence.
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.handle.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Answer an expired request with a timeout error (never executed) and
/// count it.
fn expire(req: Request, metrics: &EngineMetrics) {
    lock_unpoisoned(&metrics.scheduler).expired_in_queue += 1;
    let _ = req.resp.send(Err(InferError::DeadlineExpired));
}

/// Per-replica loop: block for the first request (idle costs nothing),
/// then collect until the batch fills or the window — anchored at that
/// first arrival — expires; flush; repeat. Requests that are already past
/// their deadline when popped are answered with a timeout error and do not
/// occupy batch slots (an expired request never anchors a window). Exits
/// once the queue is closed and drained.
fn worker_loop(
    replica: usize,
    backend: &mut dyn SpmmBackend,
    cfg: &ServeConfig,
    queue: &BoundedQueue<Request>,
    metrics: &EngineMetrics,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.batch);
    while let Some(first) = queue.pop_blocking() {
        if first.expired(Instant::now()) {
            expire(first, metrics);
            continue;
        }
        // Window anchored at the first request's *arrival*: time it spent
        // queued while workers were busy counts against the window.
        let deadline = first.enqueued + cfg.max_wait;
        pending.push(first);
        while pending.len() < cfg.batch {
            match queue.pop_until(deadline) {
                Some(req) => {
                    if req.expired(Instant::now()) {
                        expire(req, metrics);
                    } else {
                        pending.push(req);
                    }
                }
                None => break,
            }
        }
        flush(replica, backend, cfg.batch, &mut pending, metrics);
    }
}

/// Execute one padded batch and fan results (or the error) back out.
/// Requests that expired while the batch window was open are swept out and
/// answered with a timeout error first — the backend only ever sees live
/// requests. Metrics are updated before responses are sent, so a client
/// observing its reply also observes its own sample recorded.
fn flush(
    replica: usize,
    backend: &mut dyn SpmmBackend,
    batch: usize,
    pending: &mut Vec<Request>,
    metrics: &EngineMetrics,
) {
    if pending.is_empty() {
        return;
    }
    debug_assert!(pending.len() <= batch);
    let now = Instant::now();
    let mut reqs: Vec<Request> = Vec::with_capacity(pending.len());
    for r in pending.drain(..) {
        if r.expired(now) {
            expire(r, metrics);
        } else {
            reqs.push(r);
        }
    }
    if reqs.is_empty() {
        return;
    }
    let n = reqs.len();
    let d_in = backend.d_in();
    let d_out = backend.d_out();

    // Column-major batch assembly: request j in column j. A backend with a
    // compiled batch width gets stragglers zero-padded up to it; flexible
    // backends get exactly the live columns (no padding compute).
    let width = backend.fixed_batch().unwrap_or(n).max(n);
    let mut x = Matrix::zeros(d_in, width);
    for (j, r) in reqs.iter().enumerate() {
        for (i, &v) in r.x.iter().enumerate() {
            x.data[i * width + j] = v;
        }
    }

    let result = backend.run_batch(&x).and_then(|y| {
        anyhow::ensure!(
            y.rows == d_out && y.cols == width,
            "backend returned {}×{}, expected {}×{}",
            y.rows,
            y.cols,
            d_out,
            width
        );
        Ok(y)
    });

    match result {
        Ok(y) => {
            let mut cols = Vec::with_capacity(n);
            let mut lats = Vec::with_capacity(n);
            for (j, r) in reqs.iter().enumerate() {
                cols.push((0..d_out).map(|i| y.data[i * width + j]).collect::<Vec<f32>>());
                lats.push(r.enqueued.elapsed());
            }
            {
                let mut rep = lock_unpoisoned(&metrics.replicas[replica]);
                rep.batches += 1;
                rep.requests += n;
                for &l in &lats {
                    rep.latency.record(l);
                }
            }
            {
                let mut agg = lock_unpoisoned(&metrics.aggregate);
                for &l in &lats {
                    agg.record(l);
                }
            }
            {
                let mut sched = lock_unpoisoned(&metrics.scheduler);
                for r in &reqs {
                    sched.served[r.priority.index()] += 1;
                }
            }
            lock_unpoisoned(&metrics.throughput).add(n);
            for (r, col) in reqs.into_iter().zip(cols) {
                let _ = r.resp.send(Ok(col));
            }
        }
        Err(e) => {
            lock_unpoisoned(&metrics.replicas[replica]).errors += 1;
            // A typed [`InferError`] anywhere in the chain — e.g. a
            // [`crate::runtime::RemotePipelinedBackend`] link failure —
            // keeps its taxonomy (502 upstream / 504 upstream-timeout at
            // the HTTP front) instead of collapsing into a blanket
            // Backend 500.
            let err = e
                .chain()
                .find_map(|c| c.downcast_ref::<InferError>())
                .cloned()
                .unwrap_or_else(|| {
                    InferError::Backend(format!("batch execution failed: {e:#}"))
                });
            for r in reqs {
                let _ = r.resp.send(Err(err.clone()));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pipeline-parallel serving (DESIGN.md §15)
// ---------------------------------------------------------------------------

/// One stage of a [`PipelineServer`]: consumes a `[d_in, batch]`
/// activation batch and writes its `[d_out, batch]` output into a
/// recycled, caller-provided matrix.
///
/// The production implementation is the model-backed stage built by
/// [`PipelineServer::start`] (a contiguous [`HinmModel`] sub-chain run
/// through its own [`SpmmEngine`]); tests inject mock stages through
/// [`PipelineServer::start_stages`] to pin hand-off, shutdown, and
/// poisoning semantics backend-independently — the same seam
/// [`BackendFactory`] gives the batch server.
pub trait PipelineStage: Send {
    /// Input channels this stage consumes.
    fn d_in(&self) -> usize;
    /// Output channels this stage produces.
    fn d_out(&self) -> usize;
    /// Execute the stage. `out` arrives with arbitrary prior shape (it is
    /// a recycled hand-off buffer); implementations must reshape it to
    /// `[d_out, batch]` and overwrite every element. An `Err` fails only
    /// the current batch ([`InferError::Backend`] to its submitter); a
    /// *panic* poisons the whole pipeline.
    fn run(&mut self, x: &Matrix, out: &mut Matrix) -> Result<()>;
}

/// The model-backed stage: a contiguous sub-chain of a [`HinmModel`]
/// executed through a private engine, exactly like [`NativeCpuBackend`]
/// but writing into the recycled hand-off buffer.
struct ModelStage {
    model: HinmModel,
    engine: SpmmEngine,
    bufs: ActivationBuffers,
}

impl PipelineStage for ModelStage {
    fn d_in(&self) -> usize {
        self.model.d_in()
    }

    fn d_out(&self) -> usize {
        self.model.d_out()
    }

    fn run(&mut self, x: &Matrix, out: &mut Matrix) -> Result<()> {
        anyhow::ensure!(
            x.rows == self.model.d_in(),
            "stage batch has {} input channels, stage wants {}",
            x.rows,
            self.model.d_in()
        );
        self.model.forward_planned_into(x, &self.engine, &mut self.bufs, out);
        Ok(())
    }
}

/// One in-flight batch traveling the pipeline: the activation matrix
/// (input of the next stage / output of the previous one) plus the
/// submitter's response channel.
struct PipeJob {
    x: Matrix,
    resp: Sender<Result<Matrix, InferError>>,
}

/// How many spare hand-off buffers a link retains for its producer. Two
/// suffice for steady-state ping-pong at queue depth 1; a little slack
/// covers depth-2 links without ever letting the pool grow unboundedly.
const PIPE_RECYCLE_CAP: usize = 4;

/// The hand-off edge *into* one stage: a bounded FIFO of jobs (the
/// [`BoundedQueue`] at a single priority — same backpressure, close, and
/// drain semantics the batch server proved) plus the returned buffers the
/// link's producer reuses for its next output.
struct PipeLink {
    jobs: BoundedQueue<PipeJob>,
    recycle: Mutex<Vec<Matrix>>,
}

impl PipeLink {
    fn new(depth: usize) -> PipeLink {
        PipeLink { jobs: BoundedQueue::new(depth), recycle: Mutex::new(Vec::new()) }
    }

    /// A spare buffer previously returned by this link's consumer, or an
    /// empty matrix on a cold start (stages reshape it in place).
    fn take_buffer(&self) -> Matrix {
        lock_unpoisoned(&self.recycle).pop().unwrap_or_else(|| Matrix::zeros(0, 0))
    }

    /// Return a consumed hand-off buffer to this link's producer; extras
    /// beyond the cap are simply dropped.
    fn put_buffer(&self, m: Matrix) {
        let mut pool = lock_unpoisoned(&self.recycle);
        if pool.len() < PIPE_RECYCLE_CAP {
            pool.push(m);
        }
    }
}

/// Submission handle onto a running [`PipelineServer`]; cheap to clone
/// and share across threads (each [`crate::runtime::PipelinedBackend`]
/// replica holds one).
#[derive(Clone)]
pub struct PipelineHandle {
    entry: Arc<PipeLink>,
    /// Input channels every submitted batch must carry.
    pub d_in: usize,
    /// Output channels every returned batch carries.
    pub d_out: usize,
}

impl PipelineHandle {
    /// Run one `[d_in, batch]` activation batch through every stage and
    /// return the `[d_out, batch]` result, bit-identical to
    /// [`HinmModel::forward_planned`] on the unsplit model. Blocks while
    /// the entry queue is full (backpressure); errors with
    /// [`InferError::Stopped`] once the pipeline has stopped or poisoned.
    pub fn infer_batch(&self, x: &Matrix) -> Result<Matrix, InferError> {
        if x.rows != self.d_in {
            return Err(InferError::BadRequest(format!(
                "batch has {} input channels, pipeline wants {}",
                x.rows, self.d_in
            )));
        }
        // Stage the submission in a recycled entry buffer (reusing its
        // capacity) so steady-state submission allocates nothing.
        let mut staged = self.entry.take_buffer();
        staged.rows = x.rows;
        staged.cols = x.cols;
        staged.data.clear();
        staged.data.extend_from_slice(&x.data);
        let (tx, rx) = mpsc::channel();
        if self.entry.jobs.push(Priority::Normal, PipeJob { x: staged, resp: tx }, None).is_err() {
            return Err(InferError::Stopped);
        }
        match rx.recv() {
            Ok(result) => result,
            // A stage worker (and the job's response sender) died.
            Err(_) => Err(InferError::Stopped),
        }
    }
}

/// Pipeline-parallel execution engine for one layer chain: each stage
/// owns a contiguous sub-chain on its own worker thread, stages hand
/// activations forward through bounded FIFO links (the entry link is
/// multi-producer — every submitting replica pushes into it; the
/// inter-stage links have a single producer; every link has exactly one
/// consumer), and consumed hand-off buffers flow back upstream for
/// reuse.
/// With several batches in flight (e.g. one per batch-server replica)
/// every stage computes concurrently, so steady-state throughput
/// approaches `1/max(stage_time)` instead of `sum(stage_time)` — see
/// DESIGN.md §15 for the full semantics.
///
/// Shutdown mirrors [`BatchServer`]: closing the entry link cascades
/// stage by stage, each worker draining and *answering* everything still
/// queued before closing the next link. A panicking stage poisons the
/// pipeline — every link is closed and drained, in-flight submitters get
/// an error immediately, and later submissions fail fast.
pub struct PipelineServer {
    handle: PipelineHandle,
    n_stages: usize,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl PipelineServer {
    /// Split `model` into `stages` contiguous sub-chains balanced by
    /// planned FLOPs ([`HinmModel::split_stages`]) and start one worker
    /// per stage, each owning a private [`SpmmEngine`] with
    /// `kernel_threads` lanes (0 = available parallelism). `depth` bounds
    /// every hand-off queue (0 picks the default of 2). Errors if
    /// `stages` is 0 or exceeds the layer count.
    pub fn start(
        model: &HinmModel,
        stages: usize,
        kernel_threads: usize,
        depth: usize,
    ) -> Result<PipelineServer> {
        let stage_models = model.split_stages(stages)?;
        let boxed: Vec<Box<dyn PipelineStage>> = stage_models
            .into_iter()
            .map(|m| {
                Box::new(ModelStage {
                    model: m,
                    engine: SpmmEngine::new(kernel_threads),
                    bufs: ActivationBuffers::new(),
                }) as Box<dyn PipelineStage>
            })
            .collect();
        Self::start_stages(boxed, depth)
    }

    /// Start a pipeline over explicit stage implementations (the test
    /// seam; production code uses [`PipelineServer::start`]). Validates
    /// that consecutive stages agree on channel counts.
    pub fn start_stages(
        stages: Vec<Box<dyn PipelineStage>>,
        depth: usize,
    ) -> Result<PipelineServer> {
        anyhow::ensure!(!stages.is_empty(), "pipeline needs at least one stage");
        for (i, w) in stages.windows(2).enumerate() {
            anyhow::ensure!(
                w[1].d_in() == w[0].d_out(),
                "stage {} consumes {} channels but stage {i} produces {}",
                i + 1,
                w[1].d_in(),
                w[0].d_out()
            );
        }
        let depth = if depth == 0 { 2 } else { depth };
        let n = stages.len();
        let d_in = stages[0].d_in();
        let d_out = stages[n - 1].d_out();
        let links: Vec<Arc<PipeLink>> =
            (0..n).map(|_| Arc::new(PipeLink::new(depth))).collect();
        let mut workers = Vec::with_capacity(n);
        for (i, stage) in stages.into_iter().enumerate() {
            let inlink = Arc::clone(&links[i]);
            let outlink = links.get(i + 1).map(Arc::clone);
            let all = links.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("hinm-stage-{i}"))
                .spawn(move || stage_loop(stage, &inlink, outlink.as_deref(), all));
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    links[0].jobs.close();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e).context("spawning pipeline stage worker");
                }
            }
        }
        let handle = PipelineHandle { entry: Arc::clone(&links[0]), d_in, d_out };
        Ok(PipelineServer { handle, n_stages: n, workers })
    }

    /// A submission handle (clone freely; see
    /// [`crate::runtime::PipelinedBackend`] for the [`SpmmBackend`]
    /// adapter).
    pub fn handle(&self) -> PipelineHandle {
        self.handle.clone()
    }

    /// Number of stage workers.
    pub fn n_stages(&self) -> usize {
        self.n_stages
    }

    /// A [`BackendFactory`] producing one
    /// [`crate::runtime::PipelinedBackend`] per batch-server replica, all
    /// submitting into this pipeline — the composition point that lets
    /// the batch window, priority/deadline queue, [`CachedBackend`], and
    /// HTTP front run unchanged above pipeline-parallel execution.
    /// The pipeline must outlive the [`BatchServer`] using the factory;
    /// stop the batch server first.
    pub fn backend_factory(&self) -> BackendFactory {
        let handle = self.handle();
        Arc::new(move |_replica| {
            let b: Box<dyn SpmmBackend> =
                Box::new(crate::runtime::backend::PipelinedBackend::new(handle.clone()));
            Ok(b)
        })
    }

    /// Stop the pipeline: close the entry link, let every stage drain and
    /// answer what is queued (the cascade), join all workers.
    ///
    /// A batch submitted concurrently with this call either completes
    /// with its real output or fails with the typed
    /// [`InferError::Stopped`] — never a lost response: the entry link's
    /// close and every push race through one mutex (see
    /// `BoundedQueue::close`), the stage-0 worker drains whatever made it
    /// into the entry queue before cascading the close downstream, and a
    /// submitter whose job is dropped on the panic path is woken by its
    /// response sender dropping.
    pub fn stop(self) {
        // Drop runs the shutdown sequence.
    }
}

impl Drop for PipelineServer {
    fn drop(&mut self) {
        self.handle.entry.jobs.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fails the pipeline fast when a stage worker *panics* (a stage bug):
/// closes every link — new submissions error instead of blocking — and
/// drops everything queued, which drops those jobs' response senders and
/// errors their waiting submitters. The pipeline analogue of the batch
/// server's `CloseOnExit`; normal worker exit happens only after the
/// inbound link is closed and drained, so this acts on panics only.
struct PoisonPipeline(Vec<Arc<PipeLink>>);

impl Drop for PoisonPipeline {
    fn drop(&mut self) {
        if std::thread::panicking() {
            for l in &self.0 {
                l.jobs.close();
                while l.jobs.try_pop().is_some() {}
            }
        }
    }
}

/// Per-stage worker loop: pop a batch, compute into a buffer recycled
/// from the outbound link (the final stage allocates its output — that
/// matrix is handed to the submitter), pass the job forward, and return
/// the consumed input buffer upstream. On inbound close + drain, close
/// the outbound link so shutdown cascades stage by stage with every
/// queued batch answered.
fn stage_loop(
    mut stage: Box<dyn PipelineStage>,
    inlink: &PipeLink,
    outlink: Option<&PipeLink>,
    all_links: Vec<Arc<PipeLink>>,
) {
    let _guard = PoisonPipeline(all_links);
    while let Some(mut job) = inlink.jobs.pop_blocking() {
        let mut out = match outlink {
            Some(next) => next.take_buffer(),
            None => Matrix::zeros(0, 0),
        };
        match stage.run(&job.x, &mut out) {
            Ok(()) => {
                let input = std::mem::replace(&mut job.x, out);
                inlink.put_buffer(input);
                match outlink {
                    Some(next) => {
                        if let Err(rejected) = next.jobs.push(Priority::Normal, job, None) {
                            // Only possible mid-poison: the downstream
                            // link closed under us. Fail the client fast.
                            let (PushRejected::Closed(j) | PushRejected::Expired(j)) = rejected;
                            let _ = j.resp.send(Err(InferError::Stopped));
                        }
                    }
                    None => {
                        let PipeJob { x, resp } = job;
                        let _ = resp.send(Ok(x));
                    }
                }
            }
            Err(e) => {
                // A stage error fails this batch only; the pipeline keeps
                // serving (mirrors a backend `Err` in the batch server).
                if let Some(next) = outlink {
                    next.put_buffer(out);
                }
                let PipeJob { x, resp } = job;
                inlink.put_buffer(x);
                let _ = resp.send(Err(InferError::Backend(format!(
                    "pipeline stage failed: {e:#}"
                ))));
            }
        }
    }
    if let Some(next) = outlink {
        next.jobs.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine-level behaviour (batching, padding, windows, shutdown,
    // replicas, priorities, deadlines) lives in tests/serve_engine.rs and
    // tests/scheduler.rs over mock backends; pipeline semantics
    // (bit-identity, drain, poisoning) live in tests/pipeline_serve.rs.
    // Here we cover the queue primitive and batch-assembly layout.

    #[test]
    fn queue_fifo_within_priority_and_close_drains() {
        let q: BoundedQueue<u32> = BoundedQueue::new(8);
        q.push(Priority::Normal, 1, None).unwrap();
        q.push(Priority::Normal, 2, None).unwrap();
        q.close();
        assert!(q.push(Priority::Normal, 3, None).is_err(), "push after close must fail");
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), Some(2));
        assert_eq!(q.pop_blocking(), None);
        assert_eq!(q.pop_until(Instant::now() + Duration::from_millis(1)), None);
    }

    #[test]
    fn queue_pops_by_priority_then_arrival() {
        let q: BoundedQueue<u32> = BoundedQueue::new(8);
        q.push(Priority::Low, 1, None).unwrap();
        q.push(Priority::Normal, 2, None).unwrap();
        q.push(Priority::High, 3, None).unwrap();
        q.push(Priority::High, 4, None).unwrap();
        q.push(Priority::Low, 5, None).unwrap();
        q.push(Priority::Normal, 6, None).unwrap();
        let order: Vec<u32> = (0..6).map(|_| q.pop_blocking().unwrap()).collect();
        assert_eq!(order, vec![3, 4, 2, 6, 1, 5], "(priority, arrival) ordering violated");
    }

    #[test]
    fn queue_push_with_deadline_fails_fast_on_a_full_queue() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        q.push(Priority::Normal, 1, None).unwrap();
        let t0 = Instant::now();
        let r = q.push(Priority::High, 2, Some(t0 + Duration::from_millis(50)));
        assert!(
            matches!(r, Err(PushRejected::Expired(2))),
            "a deadline-bearing push must not wait out backpressure past its deadline"
        );
        assert!(t0.elapsed() >= Duration::from_millis(40), "returned before the deadline");
        assert!(t0.elapsed() < Duration::from_secs(5), "blocked far past the deadline");
    }

    #[test]
    fn queue_pop_until_times_out() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let t0 = Instant::now();
        assert_eq!(q.pop_until(t0 + Duration::from_millis(50)), None);
        assert!(t0.elapsed() >= Duration::from_millis(40), "returned too early");
    }

    #[test]
    fn queue_bounded_push_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(Priority::Normal, 10u32, None).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push(Priority::Normal, 20u32, None).is_ok());
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(q.len(), 1, "second push must be blocked by the bound");
        assert_eq!(q.pop_blocking(), Some(10));
        assert!(pusher.join().unwrap(), "blocked push should complete after pop");
        assert_eq!(q.pop_blocking(), Some(20));
    }

    #[test]
    fn queue_close_wakes_blocked_push() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(Priority::Normal, 1u32, None).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push(Priority::High, 2u32, None).is_err());
        std::thread::sleep(Duration::from_millis(50));
        q.close();
        assert!(pusher.join().unwrap(), "blocked push must error out on close");
    }

    #[test]
    fn priority_parse_roundtrip() {
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
    }

    #[test]
    fn column_major_assembly() {
        // Mirrors the layout logic in `flush`.
        let d_in = 3;
        let batch = 4;
        let reqs = [vec![1.0f32, 2.0, 3.0], vec![10.0, 20.0, 30.0]];
        let mut xdata = vec![0.0f32; d_in * batch];
        for (j, r) in reqs.iter().enumerate() {
            for (i, &v) in r.iter().enumerate() {
                xdata[i * batch + j] = v;
            }
        }
        assert_eq!(xdata[0], 1.0);
        assert_eq!(xdata[batch], 2.0);
        assert_eq!(xdata[1], 10.0);
        assert_eq!(xdata[2 * batch + 1], 30.0);
        assert_eq!(xdata[2], 0.0); // padding column
    }
}
