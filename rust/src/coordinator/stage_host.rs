//! Stage-host run loop and per-link metrics for cross-host pipeline
//! serving (DESIGN.md §20).
//!
//! A [`StageHost`] is the server side of `hinm stage`: it owns one
//! contiguous sub-chain of a [`HinmModel`] (selected with
//! [`HinmModel::stage_slice`]) and answers activation frames over
//! persistent TCP connections using the clock-free
//! [`crate::net::stage_wire`] codec. Each accepted connection gets its own
//! worker thread with a private [`SpmmEngine`], [`ActivationBuffers`], and
//! recycled input/output matrices, so concurrent links (one per serve-head
//! replica) execute batches concurrently — that is what keeps the §15
//! pipeline property (several batches in flight, each on a different
//! stage) across machines.
//!
//! Failure behaviour mirrors the frame taxonomy: a batch whose dimensions
//! don't fit the stage is answered with a typed error *frame* (the link
//! survives; only that batch fails), while any framing violation —
//! truncation, bad checksum, unknown version — drops the connection (the
//! stream can no longer be trusted) and the head re-establishes it with
//! seeded backoff.
//!
//! The head-side bookkeeping lives here too: [`StageLinkMetrics`] counts
//! per-link batches, reconnects, and classified failures
//! ([`crate::net::route::UpstreamClass`]) and records per-link round-trip
//! latency; `hinm serve --stage-hosts` surfaces a snapshot on
//! `/v1/metrics` in both JSON and Prometheus formats.

use super::metrics::LatencyRecorder;
use crate::models::chain::{ActivationBuffers, HinmModel};
use crate::net::route::UpstreamClass;
use crate::net::stage_wire::{Frame, FrameCodec};
use crate::spmm::SpmmEngine;
use crate::tensor::Matrix;
use crate::util::sync::lock_unpoisoned;
use anyhow::{Context, Result};
use std::io::BufWriter;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

// ---------------------------------------------------------------------------
// The stage-host server
// ---------------------------------------------------------------------------

/// Cumulative stage-host counters (SeqCst; readable while serving).
#[derive(Default)]
struct StageHostCounters {
    /// Activation frames executed and answered.
    frames: AtomicU64,
    /// Batches refused with a typed error frame (dim mismatch).
    rejected: AtomicU64,
    /// Connections dropped on a framing violation.
    protocol_drops: AtomicU64,
}

/// The `hinm stage` server: binds a listener and answers activation
/// frames with the outputs of its sub-chain, one worker thread per
/// connection.
pub struct StageHost {
    addr: SocketAddr,
    stopping: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    counters: Arc<StageHostCounters>,
    acceptor: Option<JoinHandle<()>>,
}

impl StageHost {
    /// Bind `addr` (port 0 for ephemeral) and serve `model` — already the
    /// stage's sub-chain, not the full model — with `kernel_threads`
    /// kernel lanes per connection engine (0 = available parallelism).
    pub fn start(addr: &str, model: HinmModel, kernel_threads: usize) -> Result<StageHost> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding stage host listener on {addr}"))?;
        let addr = listener.local_addr().context("resolving stage host addr")?;
        let stopping = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let counters = Arc::new(StageHostCounters::default());
        let model = Arc::new(model);
        let acceptor = {
            let stopping = Arc::clone(&stopping);
            let conns = Arc::clone(&conns);
            let counters = Arc::clone(&counters);
            std::thread::Builder::new()
                .name("hinm-stage-accept".to_string())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stopping.load(Ordering::SeqCst) {
                            return;
                        }
                        let Ok(stream) = conn else { continue };
                        let _ = stream.set_nodelay(true);
                        if let Ok(tracked) = stream.try_clone() {
                            lock_unpoisoned(&conns).push(tracked);
                        }
                        let model = Arc::clone(&model);
                        let counters = Arc::clone(&counters);
                        // Connection threads are detached; they exit when
                        // the peer closes, a framing violation forces a
                        // drop, or `stop()` shuts their socket down.
                        let _ = std::thread::Builder::new()
                            .name("hinm-stage-conn".to_string())
                            .spawn(move || {
                                stage_connection(stream, &model, kernel_threads, &counters)
                            });
                    }
                })
                .context("spawning stage host acceptor")?
        };
        Ok(StageHost { addr, stopping, conns, counters, acceptor: Some(acceptor) })
    }

    /// The bound address (resolves an ephemeral-port bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Activation frames executed so far.
    pub fn frames(&self) -> u64 {
        self.counters.frames.load(Ordering::SeqCst)
    }

    /// Batches refused with a typed error frame.
    pub fn rejected(&self) -> u64 {
        self.counters.rejected.load(Ordering::SeqCst)
    }

    /// Connections dropped on a framing violation.
    pub fn protocol_drops(&self) -> u64 {
        self.counters.protocol_drops.load(Ordering::SeqCst)
    }

    /// Stop accepting, shut every live connection down, join the acceptor.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        for s in lock_unpoisoned(&self.conns).drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        // Wake the blocking accept with one throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.acceptor.take() {
            let _ = t.join();
        }
    }
}

impl Drop for StageHost {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve one persistent link: read an activation frame, execute the
/// sub-chain, answer with the output frame. All buffers (frame scratch,
/// input/output matrices, activation ping-pong) are recycled across
/// batches, so the steady state allocates nothing.
fn stage_connection(
    stream: TcpStream,
    model: &HinmModel,
    kernel_threads: usize,
    counters: &StageHostCounters,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut read_half = read_half;
    let mut write_half = BufWriter::new(stream);
    let engine = SpmmEngine::new(kernel_threads);
    let mut bufs = ActivationBuffers::new();
    let mut codec = FrameCodec::new();
    let mut x = Matrix::zeros(0, 0);
    let mut y = Matrix::zeros(0, 0);
    loop {
        let frame = match codec.read_into(&mut read_half, &mut x) {
            Ok(f) => f,
            Err(e) => {
                // EOF between frames is a clean link close; anything
                // InvalidData means the stream is desynchronized — drop it
                // (the head reconnects) rather than guessing at a resync.
                if e.kind() == std::io::ErrorKind::InvalidData {
                    counters.protocol_drops.fetch_add(1, Ordering::SeqCst);
                }
                return;
            }
        };
        match frame {
            Frame::Activations { seq } => {
                if x.rows != model.d_in() {
                    counters.rejected.fetch_add(1, Ordering::SeqCst);
                    let msg = format!(
                        "batch has {} input channels, stage wants {}",
                        x.rows,
                        model.d_in()
                    );
                    if codec.write_error(&mut write_half, seq, &msg).is_err() {
                        return;
                    }
                    continue;
                }
                model.forward_planned_into(&x, &engine, &mut bufs, &mut y);
                counters.frames.fetch_add(1, Ordering::SeqCst);
                if codec.write_activations(&mut write_half, seq, &y).is_err() {
                    return;
                }
            }
            // Heads never send error frames; tolerate and ignore them so
            // a future schema revision can repurpose the direction.
            Frame::Error { .. } => {}
        }
    }
}

// ---------------------------------------------------------------------------
// Head-side per-link metrics
// ---------------------------------------------------------------------------

/// Per-link counters and latency on the serve head, one slot per stage
/// host in chain order. Counters are SeqCst atomics — the exact values
/// are part of the chaos-test contract (`rust/tests/stage_chaos.rs`).
pub struct StageLinkMetrics {
    links: Vec<StageLinkStats>,
}

struct StageLinkStats {
    host: String,
    batches: AtomicU64,
    reconnects: AtomicU64,
    failures_unreachable: AtomicU64,
    failures_timeout: AtomicU64,
    failures_protocol: AtomicU64,
    latency: Mutex<LatencyRecorder>,
}

/// Snapshot of [`StageLinkMetrics`] for rendering (JSON / Prometheus).
pub struct StageLinkSnapshot {
    /// One row per stage link, in chain order.
    pub links: Vec<StageLinkRow>,
}

/// One link's counters at snapshot time.
pub struct StageLinkRow {
    /// The stage host address, as configured.
    pub host: String,
    /// Batches round-tripped successfully on this link.
    pub batches: u64,
    /// Successful re-establishments after a link failure.
    pub reconnects: u64,
    /// Failures classified [`UpstreamClass::Unreachable`].
    pub failures_unreachable: u64,
    /// Failures classified [`UpstreamClass::TimedOut`].
    pub failures_timeout: u64,
    /// Failures classified [`UpstreamClass::Protocol`].
    pub failures_protocol: u64,
    /// p95 of the link round-trip, microseconds (0 with no samples).
    pub p95_us: f64,
}

impl StageLinkMetrics {
    /// One zeroed slot per stage host, in chain order.
    pub fn new(hosts: &[String]) -> Arc<StageLinkMetrics> {
        Arc::new(StageLinkMetrics {
            links: hosts
                .iter()
                .map(|h| StageLinkStats {
                    host: h.clone(),
                    batches: AtomicU64::new(0),
                    reconnects: AtomicU64::new(0),
                    failures_unreachable: AtomicU64::new(0),
                    failures_timeout: AtomicU64::new(0),
                    failures_protocol: AtomicU64::new(0),
                    latency: Mutex::new(LatencyRecorder::with_capacity(4096)),
                })
                .collect(),
        })
    }

    /// Count one successful round-trip on `link` and record its latency.
    pub fn record_batch(&self, link: usize, rtt: Duration) {
        let st = &self.links[link];
        st.batches.fetch_add(1, Ordering::SeqCst);
        lock_unpoisoned(&st.latency).record(rtt);
    }

    /// Count one failed round-trip on `link`, by taxonomy class.
    pub fn record_failure(&self, link: usize, class: UpstreamClass) {
        let st = &self.links[link];
        let counter = match class {
            UpstreamClass::Unreachable => &st.failures_unreachable,
            UpstreamClass::TimedOut => &st.failures_timeout,
            UpstreamClass::Protocol => &st.failures_protocol,
        };
        counter.fetch_add(1, Ordering::SeqCst);
    }

    /// Count one successful link re-establishment on `link`.
    pub fn record_reconnect(&self, link: usize) {
        self.links[link].reconnects.fetch_add(1, Ordering::SeqCst);
    }

    /// Reconnects summed across links (chaos-test convenience).
    pub fn total_reconnects(&self) -> u64 {
        self.links.iter().map(|l| l.reconnects.load(Ordering::SeqCst)).sum()
    }

    /// Point-in-time copy of every link's counters.
    pub fn snapshot(&self) -> StageLinkSnapshot {
        StageLinkSnapshot {
            links: self
                .links
                .iter()
                .map(|st| StageLinkRow {
                    host: st.host.clone(),
                    batches: st.batches.load(Ordering::SeqCst),
                    reconnects: st.reconnects.load(Ordering::SeqCst),
                    failures_unreachable: st.failures_unreachable.load(Ordering::SeqCst),
                    failures_timeout: st.failures_timeout.load(Ordering::SeqCst),
                    failures_protocol: st.failures_protocol.load(Ordering::SeqCst),
                    p95_us: lock_unpoisoned(&st.latency).percentile(95.0),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Activation;
    use crate::sparsity::HinmConfig;

    fn tiny_model() -> HinmModel {
        let cfg = HinmConfig::with_24(4, 0.5);
        HinmModel::synthetic_ffn(16, 32, &cfg, Activation::Relu, 11).expect("model")
    }

    #[test]
    fn stage_host_answers_frames_bit_exactly() {
        let model = tiny_model();
        let x = Matrix::from_vec(16, 3, (0..48).map(|i| (i as f32) * 0.125 - 2.0).collect());
        let want = model.forward_planned(
            &x,
            &SpmmEngine::single(),
            &mut ActivationBuffers::new(),
        );
        let host = StageHost::start("127.0.0.1:0", model, 1).expect("start");
        let mut conn = TcpStream::connect(host.local_addr()).expect("connect");
        let mut codec = FrameCodec::new();
        let mut out = Matrix::zeros(0, 0);
        for seq in [5u64, 6] {
            codec.write_activations(&mut conn, seq, &x).expect("send");
            let frame = codec.read_into(&mut conn, &mut out).expect("recv");
            assert_eq!(frame, Frame::Activations { seq });
            let wb: Vec<u32> = want.data.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = out.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "remote stage output must be bit-identical");
        }
        assert_eq!(host.frames(), 2);
        host.stop();
    }

    #[test]
    fn dim_mismatch_gets_a_typed_error_frame_and_the_link_survives() {
        let host = StageHost::start("127.0.0.1:0", tiny_model(), 1).expect("start");
        let mut conn = TcpStream::connect(host.local_addr()).expect("connect");
        let mut codec = FrameCodec::new();
        let mut out = Matrix::zeros(0, 0);
        let bad = Matrix::zeros(5, 2);
        codec.write_activations(&mut conn, 1, &bad).expect("send");
        match codec.read_into(&mut conn, &mut out).expect("recv") {
            Frame::Error { seq, message } => {
                assert_eq!(seq, 1);
                assert!(message.contains("input channels"), "{message}");
            }
            other => panic!("expected an error frame, got {other:?}"),
        }
        // The same connection still executes well-formed batches.
        let good = Matrix::from_vec(16, 1, vec![0.5; 16]);
        codec.write_activations(&mut conn, 2, &good).expect("send");
        assert_eq!(
            codec.read_into(&mut conn, &mut out).expect("recv"),
            Frame::Activations { seq: 2 }
        );
        assert_eq!(host.rejected(), 1);
        host.stop();
    }

    #[test]
    fn link_metrics_count_by_class_and_snapshot() {
        let m = StageLinkMetrics::new(&["a:1".to_string(), "b:2".to_string()]);
        m.record_batch(0, Duration::from_micros(100));
        m.record_batch(0, Duration::from_micros(300));
        m.record_failure(0, UpstreamClass::TimedOut);
        m.record_failure(1, UpstreamClass::Unreachable);
        m.record_failure(1, UpstreamClass::Protocol);
        m.record_reconnect(1);
        let s = m.snapshot();
        assert_eq!(s.links[0].host, "a:1");
        assert_eq!(s.links[0].batches, 2);
        assert_eq!(s.links[0].failures_timeout, 1);
        assert_eq!(s.links[1].failures_unreachable, 1);
        assert_eq!(s.links[1].failures_protocol, 1);
        assert_eq!(s.links[1].reconnects, 1);
        assert_eq!(m.total_reconnects(), 1);
        assert!(s.links[0].p95_us > 0.0);
    }
}
