//! L3 coordinator: the compression pipeline (prune → permute → pack), the
//! batched inference server over PJRT, the Rust-driven fine-tune trainer,
//! and request metrics.

pub mod gradual;
pub mod metrics;
pub mod pipeline;
pub mod serve;
pub mod trainer;

pub use pipeline::{compress_layer, run_pipeline, weighted_retention, LayerJob, Method, PipelineConfig};
pub use serve::{BatchServer, ServeConfig};
pub use trainer::{Corpus, LmTrainer};
