//! L3 coordinator: the compression pipeline (prune → permute → pack), the
//! sharded multi-backend inference engine with priority/deadline
//! scheduling, the fault-tolerant replica router, the cross-host stage
//! host, the Rust-driven fine-tune trainer, and request metrics.

pub mod gradual;
pub mod metrics;
pub mod pipeline;
pub mod router;
pub mod serve;
pub mod stage_host;
pub mod trainer;

pub use metrics::{
    EngineMetrics, LatencyRecorder, ModelCounters, ReplicaStats, SchedulerStats, Throughput,
};
pub use pipeline::{compress_layer, run_pipeline, weighted_retention, LayerJob, Method, PipelineConfig};
pub use router::{
    BackendHealth, BackendSnapshot, ProxyRequest, RouteReply, Router, RouterConfig, RouterSnapshot,
};
pub use serve::{
    cached_factory, BackendFactory, BatchServer, InferError, PipelineHandle, PipelineServer,
    PipelineStage, Priority, ServeConfig, ServerHandle,
};
pub use stage_host::{StageHost, StageLinkMetrics, StageLinkRow, StageLinkSnapshot};
pub use trainer::{Corpus, LmTrainer};
