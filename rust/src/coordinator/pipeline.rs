//! The offline compression pipeline — L3's production entry point.
//!
//! Takes a set of named layers (dense weights + saliency), a method and a
//! sparsity target, and compresses every layer in parallel across worker
//! threads (std::thread — the offline environment has no tokio; compression
//! is CPU-bound so a thread pool is the right tool anyway).

use crate::permute::baselines::apex::{apex_icp, ApexParams};
use crate::permute::baselines::ovw::ovw_ocp;
use crate::permute::{gyro_permute_and_prune, GyroParams};
use crate::saliency::Saliency;
use crate::sparsity::hinm::{prune_oneshot, prune_with_kept};
use crate::sparsity::vector_prune::vector_prune;
use crate::sparsity::{HinmConfig, HinmResult};
use crate::tensor::Matrix;
use anyhow::Result;
use std::sync::mpsc;
use std::sync::Arc;

/// Which permutation strategy to run before HiNM pruning.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Gyro OCP + gyro ICP (the paper's method).
    HinmGyro,
    /// No permutation at all (paper's HiNM-NoPerm arm).
    HinmNoPerm,
    /// Ablation V1: OVW balanced-K-means OCP + gyro ICP (Table 3).
    HinmV1,
    /// Ablation V2: gyro OCP + Apex swap ICP (Table 3).
    HinmV2,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "gyro" | "hinm" => Some(Method::HinmGyro),
            "noperm" => Some(Method::HinmNoPerm),
            "v1" | "hinm-v1" => Some(Method::HinmV1),
            "v2" | "hinm-v2" => Some(Method::HinmV2),
            _ => None,
        }
    }
    pub fn label(&self) -> &'static str {
        match self {
            Method::HinmGyro => "HiNM",
            Method::HinmNoPerm => "HiNM-NoPerm",
            Method::HinmV1 => "HiNM-V1",
            Method::HinmV2 => "HiNM-V2",
        }
    }
}

/// A layer queued for compression.
#[derive(Clone, Debug)]
pub struct LayerJob {
    pub name: String,
    pub weights: Matrix,
    pub saliency: Matrix,
}

impl LayerJob {
    pub fn from_saliency<S: Saliency>(name: &str, w: Matrix, estimator: &S) -> Self {
        let saliency = estimator.score(&w);
        Self { name: name.to_string(), weights: w, saliency }
    }
}

/// Compression output for one layer.
#[derive(Clone, Debug)]
pub struct CompressedLayer {
    pub name: String,
    pub result: HinmResult,
    pub ocp_perm: Vec<usize>,
    pub elapsed_ms: f64,
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub cfg: HinmConfig,
    pub method: Method,
    pub gyro: GyroParams,
    /// Worker threads (0 = available parallelism).
    pub workers: usize,
}

impl PipelineConfig {
    pub fn new(cfg: HinmConfig, method: Method) -> Self {
        Self { cfg, method, gyro: GyroParams::default(), workers: 0 }
    }
}

/// Compress one layer with the configured method.
pub fn compress_layer(job: &LayerJob, pc: &PipelineConfig) -> CompressedLayer {
    let t0 = std::time::Instant::now();
    let cfg = &pc.cfg;
    let (result, ocp_perm) = match pc.method {
        Method::HinmGyro => {
            let out = gyro_permute_and_prune(&job.weights, &job.saliency, cfg, &pc.gyro);
            (out.result, out.ocp_perm)
        }
        Method::HinmNoPerm => {
            let res = prune_oneshot(&job.weights, &job.saliency, cfg);
            (res, (0..job.weights.rows).collect())
        }
        Method::HinmV1 => {
            // OVW K-means OCP, then gyro ICP via the gyro driver with OCP skipped.
            let perm = ovw_ocp(&job.saliency, cfg, pc.gyro.ocp.seed);
            let w = job.weights.permute_rows(&perm);
            let s = job.saliency.permute_rows(&perm);
            let out = gyro_permute_and_prune(
                &w,
                &s,
                cfg,
                &GyroParams { skip_ocp: true, ..pc.gyro.clone() },
            );
            (out.result, perm)
        }
        Method::HinmV2 => {
            // Gyro OCP, then Apex swap-based ICP.
            let ocp = crate::permute::gyro_ocp(&job.saliency, cfg, &pc.gyro.ocp);
            let w = job.weights.permute_rows(&ocp.perm);
            let s = job.saliency.permute_rows(&ocp.perm);
            let vp = vector_prune(&s, cfg);
            let k_v = vp.kept[0].len();
            let tiles = cfg.tiles(w.rows);
            let mut orders = Vec::with_capacity(tiles);
            let mut buf = vec![0.0f32; cfg.v * k_v];
            for t in 0..tiles {
                crate::sparsity::hinm::gather_tile(&s, cfg, t, &vp.kept[t], &mut buf);
                let cols: Vec<Vec<f32>> = (0..k_v)
                    .map(|j| (0..cfg.v).map(|r| buf[r * k_v + j]).collect())
                    .collect();
                let (order, _) = apex_icp(&cols, cfg.v, cfg, &ApexParams::default());
                orders.push(order);
            }
            let res = prune_with_kept(&w, &s, cfg, &vp, Some(&orders));
            (res, ocp.perm)
        }
    };
    CompressedLayer {
        name: job.name.clone(),
        result,
        ocp_perm,
        elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
    }
}

/// Compress many layers in parallel. Results return in input order.
pub fn run_pipeline(jobs: Vec<LayerJob>, pc: &PipelineConfig) -> Result<Vec<CompressedLayer>> {
    let n = jobs.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = if pc.workers > 0 {
        pc.workers
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
    }
    .min(n);

    let jobs = Arc::new(jobs);
    let pc = Arc::new(pc.clone());
    let next = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let (tx, rx) = mpsc::channel::<(usize, CompressedLayer)>();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let jobs = Arc::clone(&jobs);
            let pc = Arc::clone(&pc);
            let next = Arc::clone(&next);
            let tx = tx.clone();
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let out = compress_layer(&jobs[i], &pc);
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut results: Vec<Option<CompressedLayer>> = (0..n).map(|_| None).collect();
        for (i, out) in rx {
            results[i] = Some(out);
        }
        Ok(results.into_iter().map(|r| r.expect("worker died")).collect())
    })
}

/// Aggregate retention ratio across layers, weighted by parameter count.
pub fn weighted_retention(layers: &[CompressedLayer], jobs: &[LayerJob]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (l, j) in layers.iter().zip(jobs) {
        let w = (j.weights.rows * j.weights.cols) as f64;
        num += l.result.retention_ratio * w;
        den += w;
    }
    num / den.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SyntheticGen;
    use crate::saliency::Magnitude;
    use crate::util::rng::Xoshiro256;

    fn jobs(n: usize, seed: u64) -> Vec<LayerJob> {
        let mut rng = Xoshiro256::new(seed);
        let gen = SyntheticGen::default();
        (0..n)
            .map(|i| {
                let w = gen.weights(32, 64, &mut rng);
                LayerJob::from_saliency(&format!("layer{i}"), w, &Magnitude)
            })
            .collect()
    }

    fn pc(method: Method) -> PipelineConfig {
        PipelineConfig::new(HinmConfig::with_24(8, 0.5), method)
    }

    #[test]
    fn pipeline_preserves_order_and_names() {
        let js = jobs(5, 100);
        let out = run_pipeline(js.clone(), &pc(Method::HinmNoPerm)).unwrap();
        assert_eq!(out.len(), 5);
        for (i, l) in out.iter().enumerate() {
            assert_eq!(l.name, format!("layer{i}"));
            l.result.packed.check_invariants().unwrap();
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let js = jobs(4, 101);
        let mut cfg1 = pc(Method::HinmGyro);
        cfg1.workers = 1;
        let mut cfg4 = pc(Method::HinmGyro);
        cfg4.workers = 4;
        let a = run_pipeline(js.clone(), &cfg1).unwrap();
        let b = run_pipeline(js, &cfg4).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.result.packed, y.result.packed, "{}", x.name);
        }
    }

    #[test]
    fn gyro_beats_noperm_across_methods() {
        let js = jobs(3, 102);
        let gyro = run_pipeline(js.clone(), &pc(Method::HinmGyro)).unwrap();
        let noperm = run_pipeline(js.clone(), &pc(Method::HinmNoPerm)).unwrap();
        let rg = weighted_retention(&gyro, &js);
        let rn = weighted_retention(&noperm, &js);
        assert!(rg > rn, "gyro {rg} vs noperm {rn}");
    }

    #[test]
    fn all_methods_produce_valid_output() {
        let js = jobs(2, 103);
        for m in [Method::HinmGyro, Method::HinmNoPerm, Method::HinmV1, Method::HinmV2] {
            let out = run_pipeline(js.clone(), &pc(m)).unwrap();
            for l in &out {
                l.result.packed.check_invariants().unwrap();
                assert!(crate::tensor::is_permutation(&l.ocp_perm, 32));
                assert!((l.result.mask.sparsity() - 0.75).abs() < 0.02, "{m:?}");
            }
        }
    }

    #[test]
    fn empty_pipeline_ok() {
        assert!(run_pipeline(vec![], &pc(Method::HinmGyro)).unwrap().is_empty());
    }

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("gyro"), Some(Method::HinmGyro));
        assert_eq!(Method::parse("v2"), Some(Method::HinmV2));
        assert_eq!(Method::parse("bogus"), None);
    }
}
