//! The offline compression pipeline — L3's production entry point.
//!
//! Takes a set of named layers (dense weights + saliency), a permutation
//! method and a sparsity target, and compresses every layer in parallel
//! across worker threads (std::thread — the offline environment has no
//! tokio; compression is CPU-bound so a thread pool is the right tool
//! anyway). Methods are [`StrategySpec`]s resolved through the permute
//! [`StrategyRegistry`], so any OCP×ICP pair runs here — the legacy
//! [`Method`] enum survives only as a thin parser/alias layer over it.

use crate::permute::{GyroParams, PermutePipeline, StrategyParams, StrategyRegistry, StrategySpec};
use crate::saliency::Saliency;
use crate::sparsity::{HinmConfig, HinmResult};
use crate::tensor::Matrix;
use anyhow::Result;
use std::sync::mpsc;
use std::sync::Arc;

/// The four named arms of the paper (thin aliases over registry specs).
/// Prefer [`StrategySpec`] for anything beyond these.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Gyro OCP + gyro ICP (the paper's method) — `gyro+gyro`.
    HinmGyro,
    /// No permutation at all (paper's HiNM-NoPerm arm) — `id+id`.
    HinmNoPerm,
    /// Ablation V1: OVW balanced-K-means OCP + gyro ICP (Table 3) — `ovw+gyro`.
    HinmV1,
    /// Ablation V2: gyro OCP + Apex swap ICP (Table 3) — `gyro+apex`.
    HinmV2,
}

impl Method {
    /// Parse a legacy arm name (`gyro`, `noperm`, `v1`, `v2`).
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "gyro" | "hinm" => Some(Method::HinmGyro),
            "noperm" => Some(Method::HinmNoPerm),
            "v1" | "hinm-v1" => Some(Method::HinmV1),
            "v2" | "hinm-v2" => Some(Method::HinmV2),
            _ => None,
        }
    }
    /// The paper's arm label (`HiNM`, `HiNM-V1`, …).
    pub fn label(&self) -> &'static str {
        match self {
            Method::HinmGyro => "HiNM",
            Method::HinmNoPerm => "HiNM-NoPerm",
            Method::HinmV1 => "HiNM-V1",
            Method::HinmV2 => "HiNM-V2",
        }
    }
    /// The registry spec this arm resolves to.
    pub fn spec(&self) -> StrategySpec {
        match self {
            Method::HinmGyro => StrategySpec::new("gyro", "gyro"),
            Method::HinmNoPerm => StrategySpec::new("id", "id"),
            Method::HinmV1 => StrategySpec::new("ovw", "gyro"),
            Method::HinmV2 => StrategySpec::new("gyro", "apex"),
        }
    }
}

impl From<Method> for StrategySpec {
    fn from(m: Method) -> Self {
        m.spec()
    }
}

/// A layer queued for compression.
#[derive(Clone, Debug)]
pub struct LayerJob {
    /// Layer name (reporting only).
    pub name: String,
    /// Dense weights to compress.
    pub weights: Matrix,
    /// Saliency grid (same shape as `weights`).
    pub saliency: Matrix,
}

impl LayerJob {
    /// Build a job by scoring `w` with a saliency estimator.
    pub fn from_saliency<S: Saliency>(name: &str, w: Matrix, estimator: &S) -> Self {
        let saliency = estimator.score(&w);
        Self { name: name.to_string(), weights: w, saliency }
    }
}

/// Compression output for one layer.
#[derive(Clone, Debug)]
pub struct CompressedLayer {
    /// Layer name, copied from the job.
    pub name: String,
    /// Packed layer + retention statistics.
    pub result: HinmResult,
    /// Output-channel permutation the pipeline applied.
    pub ocp_perm: Vec<usize>,
    /// Wall-clock compression time for this layer.
    pub elapsed_ms: f64,
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Target HiNM sparsity configuration.
    pub cfg: HinmConfig,
    /// Which OCP×ICP pair to run (any registry spec; `Method` coerces).
    pub method: StrategySpec,
    /// Gyro tuning; baseline strategies derive their seeds from it
    /// (see `StrategyParams::from`).
    pub gyro: GyroParams,
    /// Worker threads across layers (0 = available parallelism).
    pub workers: usize,
    /// Worker threads for the per-layer tile engine. Defaults to 1: layers
    /// already fan out across `workers`, so nesting tile parallelism would
    /// oversubscribe. Raise it when compressing few, wide layers.
    pub tile_workers: usize,
}

impl PipelineConfig {
    /// Config with default tuning for a sparsity target + method.
    pub fn new(cfg: HinmConfig, method: impl Into<StrategySpec>) -> Self {
        Self {
            cfg,
            method: method.into(),
            gyro: GyroParams::default(),
            workers: 0,
            tile_workers: 1,
        }
    }
}

/// Compress one layer with the configured method, through the strategy
/// registry and the shared [`PermutePipeline`] engine (single code path for
/// every arm — the never-worse guard applies uniformly).
pub fn compress_layer(job: &LayerJob, pc: &PipelineConfig) -> CompressedLayer {
    let t0 = std::time::Instant::now();
    let params = StrategyParams::from(&pc.gyro);
    let (ocp, icp) = StrategyRegistry::builtin()
        .build(&pc.method, &params)
        .unwrap_or_else(|| panic!("unknown method spec {:?}", pc.method.key()));
    let engine = PermutePipeline { workers: pc.tile_workers, guard: true };
    let out = engine.run(ocp.as_ref(), icp.as_ref(), &job.weights, &job.saliency, &pc.cfg);
    CompressedLayer {
        name: job.name.clone(),
        result: out.result,
        ocp_perm: out.ocp_perm,
        elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
    }
}

/// Compress many layers in parallel. Results return in input order.
pub fn run_pipeline(jobs: Vec<LayerJob>, pc: &PipelineConfig) -> Result<Vec<CompressedLayer>> {
    // Validate the spec up front: StrategySpec's fields are freely
    // constructible, and a panic inside a worker thread would otherwise
    // unwind through this Result-returning API.
    if !StrategyRegistry::builtin().supports(&pc.method) {
        anyhow::bail!("unknown method spec {:?}", pc.method.key());
    }
    let n = jobs.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let workers = if pc.workers > 0 {
        pc.workers
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
    }
    .min(n);

    let jobs = Arc::new(jobs);
    let pc = Arc::new(pc.clone());
    let next = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let (tx, rx) = mpsc::channel::<(usize, CompressedLayer)>();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let jobs = Arc::clone(&jobs);
            let pc = Arc::clone(&pc);
            let next = Arc::clone(&next);
            let tx = tx.clone();
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let out = compress_layer(&jobs[i], &pc);
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut results: Vec<Option<CompressedLayer>> = (0..n).map(|_| None).collect();
        for (i, out) in rx {
            results[i] = Some(out);
        }
        Ok(results.into_iter().map(|r| r.expect("worker died")).collect())
    })
}

/// Aggregate retention ratio across layers, weighted by parameter count.
pub fn weighted_retention(layers: &[CompressedLayer], jobs: &[LayerJob]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (l, j) in layers.iter().zip(jobs) {
        let w = (j.weights.rows * j.weights.cols) as f64;
        num += l.result.retention_ratio * w;
        den += w;
    }
    num / den.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SyntheticGen;
    use crate::saliency::Magnitude;
    use crate::util::rng::Xoshiro256;

    fn jobs(n: usize, seed: u64) -> Vec<LayerJob> {
        let mut rng = Xoshiro256::new(seed);
        let gen = SyntheticGen::default();
        (0..n)
            .map(|i| {
                let w = gen.weights(32, 64, &mut rng);
                LayerJob::from_saliency(&format!("layer{i}"), w, &Magnitude)
            })
            .collect()
    }

    fn pc(method: impl Into<StrategySpec>) -> PipelineConfig {
        PipelineConfig::new(HinmConfig::with_24(8, 0.5), method)
    }

    #[test]
    fn pipeline_preserves_order_and_names() {
        let js = jobs(5, 100);
        let out = run_pipeline(js.clone(), &pc(Method::HinmNoPerm)).unwrap();
        assert_eq!(out.len(), 5);
        for (i, l) in out.iter().enumerate() {
            assert_eq!(l.name, format!("layer{i}"));
            l.result.packed.check_invariants().unwrap();
        }
    }

    #[test]
    fn parallel_equals_serial() {
        let js = jobs(4, 101);
        let mut cfg1 = pc(Method::HinmGyro);
        cfg1.workers = 1;
        let mut cfg4 = pc(Method::HinmGyro);
        cfg4.workers = 4;
        let a = run_pipeline(js.clone(), &cfg1).unwrap();
        let b = run_pipeline(js, &cfg4).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.result.packed, y.result.packed, "{}", x.name);
        }
    }

    #[test]
    fn gyro_beats_noperm_across_methods() {
        let js = jobs(3, 102);
        let gyro = run_pipeline(js.clone(), &pc(Method::HinmGyro)).unwrap();
        let noperm = run_pipeline(js.clone(), &pc(Method::HinmNoPerm)).unwrap();
        let rg = weighted_retention(&gyro, &js);
        let rn = weighted_retention(&noperm, &js);
        assert!(rg > rn, "gyro {rg} vs noperm {rn}");
    }

    #[test]
    fn all_methods_produce_valid_output() {
        let js = jobs(2, 103);
        for m in [Method::HinmGyro, Method::HinmNoPerm, Method::HinmV1, Method::HinmV2] {
            let out = run_pipeline(js.clone(), &pc(m)).unwrap();
            for l in &out {
                l.result.packed.check_invariants().unwrap();
                assert!(crate::tensor::is_permutation(&l.ocp_perm, 32));
                assert!((l.result.mask.sparsity() - 0.75).abs() < 0.02, "{m:?}");
            }
        }
    }

    #[test]
    fn registry_combos_run_end_to_end() {
        // Beyond the four legacy arms: arbitrary OCP×ICP pairs through the
        // same pipeline, never below the noperm baseline.
        let js = jobs(2, 104);
        let noperm = weighted_retention(
            &run_pipeline(js.clone(), &pc(Method::HinmNoPerm)).unwrap(),
            &js,
        );
        for spec in ["gyro+tetris", "ovw+apex", "id+gyro", "ovw+tetris"] {
            let spec = StrategySpec::parse(spec).expect(spec);
            let out = run_pipeline(js.clone(), &pc(spec.clone())).unwrap();
            for l in &out {
                l.result.packed.check_invariants().unwrap();
                assert!(crate::tensor::is_permutation(&l.ocp_perm, 32), "{}", spec.key());
            }
            let r = weighted_retention(&out, &js);
            assert!(r >= noperm - 1e-6, "{}: {r} < noperm {noperm}", spec.key());
        }
    }

    #[test]
    fn empty_pipeline_ok() {
        assert!(run_pipeline(vec![], &pc(Method::HinmGyro)).unwrap().is_empty());
    }

    #[test]
    fn unknown_spec_is_an_error_not_a_panic() {
        // StrategySpec's fields are freely constructible; run_pipeline must
        // surface a bad key as Err, not a worker-thread panic.
        let js = jobs(1, 105);
        let bad = pc(StrategySpec::new("gyr0", "gyro"));
        assert!(run_pipeline(js, &bad).is_err());
    }

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("gyro"), Some(Method::HinmGyro));
        assert_eq!(Method::parse("v2"), Some(Method::HinmV2));
        assert_eq!(Method::parse("bogus"), None);
        // Legacy arms and registry specs agree.
        assert_eq!(Method::HinmGyro.spec(), StrategySpec::parse("gyro").unwrap());
        assert_eq!(Method::HinmV1.spec(), StrategySpec::parse("v1").unwrap());
    }
}
