//! Fine-tune driver: runs the AOT-lowered masked train steps from Rust.
//!
//! The train step (fwd + bwd + masked SGD) was lowered once by
//! `python/compile/aot.py`; this module feeds parameter literals through it
//! in a loop — training runs on the request path with Python out of the
//! process entirely.

use crate::runtime::executor::{lit_f32, lit_from_npy, lit_i32, lit_scalar, Executor};
use crate::runtime::registry::Registry;
use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};
use xla::Literal;

/// Trainer for the small transformer LM artifact set
/// (`lm_train_step` / `lm_loss` / `lm_fwd`).
pub struct LmTrainer {
    step_exe: Executor,
    loss_exe: Executor,
    /// Parameters, ordered as `manifest.meta.lm_param_names`.
    params: Vec<Literal>,
    /// Masks, ordered as `manifest.meta.lm_mask_names` (all-ones = dense).
    masks: Vec<Literal>,
    /// Parameter names, ordered as the artifact expects.
    pub pnames: Vec<String>,
    /// Mask names, ordered as the artifact expects.
    pub mnames: Vec<String>,
    /// Compiled batch size.
    pub batch: usize,
    /// Compiled sequence length.
    pub seq: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Loss per completed step, in order.
    pub losses: Vec<f32>,
}

impl LmTrainer {
    /// Load the train/loss artifacts and initial parameters from `reg`.
    pub fn new(reg: &Registry) -> Result<LmTrainer> {
        let step_spec = reg.artifact("lm_train_step")?;
        let loss_spec = reg.artifact("lm_loss")?;
        let step_exe = Executor::load(step_spec)?;
        let loss_exe = Executor::load(loss_spec)?;
        let pnames = reg.lm_param_names.clone();
        let mnames = reg.lm_mask_names.clone();
        if pnames.is_empty() {
            bail!("manifest lacks lm_param_names");
        }
        // Initial params from the npy dumps.
        let mut params = Vec::with_capacity(pnames.len());
        for n in &pnames {
            let arr = reg.load_data(&format!("lm_{}", n.replace('.', "_")))?;
            params.push(lit_from_npy(&arr)?);
        }
        // All-ones masks matching each pruned tensor's manifest spec.
        let mut masks = Vec::with_capacity(mnames.len());
        for n in &mnames {
            let spec = step_spec
                .inputs
                .iter()
                .find(|s| s.name == format!("mask.{n}"))
                .with_context(|| format!("mask input for {n} missing"))?;
            masks.push(lit_f32(&vec![1.0; spec.elements()], &spec.shape)?);
        }
        let meta = &step_spec.meta;
        Ok(LmTrainer {
            step_exe,
            loss_exe,
            params,
            masks,
            pnames,
            mnames,
            batch: meta["batch"] as usize,
            seq: meta["seq"] as usize,
            vocab: meta["vocab"] as usize,
            losses: Vec::new(),
        })
    }

    /// Fetch a parameter as a host matrix (rank-1 params come back as 1×n).
    pub fn param_matrix(&self, name: &str) -> Result<Matrix> {
        let i = self.pindex(name)?;
        let lit = &self.params[i];
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        let (r, c) = match dims.as_slice() {
            [r, c] => (*r, *c),
            [n] => (1, *n),
            s => bail!("param {name} has rank {} (dims {s:?})", s.len()),
        };
        Ok(Matrix::from_vec(r, c, data))
    }

    /// Overwrite a parameter (e.g. with its pruned version).
    pub fn set_param(&mut self, name: &str, m: &Matrix) -> Result<()> {
        let i = self.pindex(name)?;
        let shape = self.params[i].array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let want: usize = dims.iter().product();
        if want != m.data.len() {
            bail!("set_param {name}: {} elements vs expected {want}", m.data.len());
        }
        self.params[i] = lit_f32(&m.data, &dims)?;
        Ok(())
    }

    /// Set a pruning mask from a [`crate::sparsity::Mask`].
    pub fn set_mask(&mut self, name: &str, mask: &crate::sparsity::Mask) -> Result<()> {
        let i = self
            .mnames
            .iter()
            .position(|n| n == name)
            .with_context(|| format!("unknown mask {name}"))?;
        let m = mask.as_matrix();
        self.masks[i] = lit_f32(&m.data, &[m.rows, m.cols])?;
        Ok(())
    }

    fn pindex(&self, name: &str) -> Result<usize> {
        self.pnames
            .iter()
            .position(|n| n == name)
            .with_context(|| format!("unknown param {name}"))
    }

    /// One masked-SGD step. Updates params in place, returns the loss.
    pub fn step(&mut self, tokens: &[i32], targets: &[i32], lr: f32) -> Result<f32> {
        let b = self.batch;
        let s = self.seq;
        anyhow::ensure!(tokens.len() == b * s && targets.len() == b * s, "bad batch shape");
        let mut inputs: Vec<Literal> = Vec::with_capacity(self.params.len() + self.masks.len() + 3);
        inputs.append(&mut self.params);
        inputs.extend(self.masks.iter().map(clone_lit).collect::<Result<Vec<_>>>()?);
        inputs.push(lit_i32(tokens, &[b, s])?);
        inputs.push(lit_i32(targets, &[b, s])?);
        inputs.push(lit_scalar(lr));
        let mut outs = self.step_exe.run(&inputs)?;
        let loss_lit = outs.pop().context("missing loss output")?;
        let loss = loss_lit.to_vec::<f32>()?[0];
        self.params = outs;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Gradients of the loss w.r.t. the pruned matrices (one batch), in
    /// `mnames` order — the evidence for second-order (diagonal-Fisher)
    /// saliency, estimated entirely from Rust through the `lm_grad`
    /// artifact.
    pub fn grad_matrices(
        &self,
        reg: &Registry,
        tokens: &[i32],
        targets: &[i32],
    ) -> Result<Vec<Matrix>> {
        let spec = reg.artifact("lm_grad")?;
        let exe = Executor::load(spec)?;
        let b = self.batch;
        let s = self.seq;
        let mut inputs: Vec<Literal> =
            self.params.iter().map(clone_lit).collect::<Result<Vec<_>>>()?;
        inputs.push(lit_i32(tokens, &[b, s])?);
        inputs.push(lit_i32(targets, &[b, s])?);
        let outs = exe.run(&inputs)?;
        let mut grads = Vec::with_capacity(outs.len());
        for lit in &outs {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>()?;
            grads.push(Matrix::from_vec(dims[0], dims[1], data));
        }
        Ok(grads)
    }

    /// Evaluation loss on one batch (no update).
    pub fn eval_loss(&self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        let b = self.batch;
        let s = self.seq;
        let mut inputs: Vec<Literal> =
            self.params.iter().map(clone_lit).collect::<Result<Vec<_>>>()?;
        inputs.push(lit_i32(tokens, &[b, s])?);
        inputs.push(lit_i32(targets, &[b, s])?);
        let outs = self.loss_exe.run(&inputs)?;
        Ok(outs[0].to_vec::<f32>()?[0])
    }
}

fn clone_lit(l: &Literal) -> Result<Literal> {
    use xla::ElementType;
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match l.ty()? {
        ElementType::F32 => lit_f32(&l.to_vec::<f32>()?, &dims),
        ElementType::S32 => lit_i32(&l.to_vec::<i32>()?, &dims),
        t => bail!("unsupported literal type {t:?}"),
    }
}

/// Synthetic corpus for the LM: a noisy affine token chain
/// `t_{i+1} = (a·t_i + c) mod V` with flip noise — structured enough that a
/// small LM reaches well below the uniform baseline, random enough that it
/// cannot memorize trivially.
pub struct Corpus {
    /// Vocabulary size V.
    pub vocab: usize,
    /// Probability a token is replaced with a uniform draw.
    pub noise: f32,
    rng: crate::util::rng::Xoshiro256,
}

impl Corpus {
    /// Corpus with the given vocabulary, flip-noise rate, and seed.
    pub fn new(vocab: usize, noise: f32, seed: u64) -> Self {
        Self { vocab, noise, rng: crate::util::rng::Xoshiro256::new(seed) }
    }

    /// Sample a (tokens, targets) batch of shape `[batch, seq]` flattened.
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let v = self.vocab as i64;
        let mut toks = Vec::with_capacity(batch * seq);
        let mut tgts = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut t = self.rng.below(self.vocab) as i64;
            let mut row = Vec::with_capacity(seq + 1);
            row.push(t);
            for _ in 0..seq {
                t = if self.rng.next_f32() < self.noise {
                    self.rng.below(self.vocab) as i64
                } else {
                    (3 * t + 7) % v
                };
                row.push(t);
            }
            toks.extend(row[..seq].iter().map(|&x| x as i32));
            tgts.extend(row[1..seq + 1].iter().map(|&x| x as i32));
        }
        (toks, tgts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_shapes_and_determinism() {
        let mut c1 = Corpus::new(64, 0.1, 5);
        let mut c2 = Corpus::new(64, 0.1, 5);
        let (t1, g1) = c1.batch(4, 8);
        let (t2, g2) = c2.batch(4, 8);
        assert_eq!(t1.len(), 32);
        assert_eq!((&t1, &g1), (&t2, &g2));
        assert!(t1.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn corpus_targets_shifted() {
        let mut c = Corpus::new(64, 0.0, 9);
        let (toks, tgts) = c.batch(1, 16);
        // noise=0 → strictly t_{i+1} = (3 t_i + 7) % 64; targets are the
        // next-token shift of tokens.
        for i in 0..15 {
            assert_eq!(tgts[i], toks[i + 1]);
            assert_eq!(toks[i + 1] as i64, (3 * toks[i] as i64 + 7) % 64);
        }
    }

    #[test]
    fn corpus_noise_injects_randomness() {
        let mut c = Corpus::new(64, 1.0, 11);
        let (toks, _) = c.batch(1, 64);
        let breaks = toks
            .windows(2)
            .filter(|w| w[1] as i64 != (3 * w[0] as i64 + 7) % 64)
            .count();
        assert!(breaks > 32, "full noise should break the chain often, got {breaks}");
    }
}
