//! Gyro **tile-wise input-channel permutation** (ICP): within one tile,
//! rearranges the kept column vectors across `P_i = K_v/M` partitions of `M`
//! so that row-wise N:M pruning removes the least saliency (Eq. 3).
//!
//! Because each partition holds only `M` (=4) column vectors, the sampling
//! phase extracts exactly one vector per partition and clustering is skipped
//! (paper §4.2). Tiles are independent — the reordered `vec_idx` is consumed
//! by the runtime gather, so ICP is free at inference time (paper §3.2).

use super::cost::icp_group_retained;
use super::hungarian;
use crate::sparsity::config::HinmConfig;
use crate::util::rng::{mix_seed, Xoshiro256};

#[derive(Clone, Debug)]
/// Tuning knobs for the gyro ICP (per-tile Hungarian refinement).
pub struct IcpParams {
    /// Maximum refinement iterations.
    pub max_iters: usize,
    /// Stop after this many consecutive non-improving iterations.
    pub patience: usize,
    /// Base RNG seed (per-tile streams derive via `mix_seed`).
    pub seed: u64,
    /// Cap on partitions per ICP block. Wide layers (e.g. ResNet conv3x3:
    /// K_v = 2304 → 576 partitions) would make the O(P³) Hungarian the
    /// bottleneck; blocks of ≤ this many partitions are permuted
    /// independently — the same K-blocking the GPU kernel applies anyway.
    pub max_partitions: usize,
}

impl Default for IcpParams {
    fn default() -> Self {
        Self { max_iters: 40, patience: 10, seed: 0x1C9, max_partitions: 96 }
    }
}

#[derive(Clone, Debug)]
/// Outcome of one tile's ICP refinement.
pub struct IcpResult {
    /// Order over the tile's kept columns: position `i` holds kept-column
    /// index `order[i]` (an index into the tile's ascending kept list).
    pub order: Vec<usize>,
    /// Eq. 3 retained saliency of the final arrangement.
    pub retained: f64,
    /// Retained value per accepted iteration (for convergence plots).
    pub history: Vec<f64>,
    /// Iterations actually executed.
    pub iters_run: usize,
    /// Iterations that improved the objective.
    pub accepted: usize,
}

/// Objective: Σ over M-wide groups of row-wise top-N retention.
///
/// Generic over the column container so callers can pass owned columns
/// (`&[Vec<f32>]`) or borrowed views into a flat scratch buffer
/// (`&[&[f32]]`, the strategy-layer tile engine) without copying.
pub fn icp_objective<C: AsRef<[f32]>>(cols: &[C], order: &[usize], v: usize, cfg: &HinmConfig) -> f64 {
    let m = cfg.m_group;
    let mut total = 0.0;
    for grp in order.chunks_exact(m) {
        let members: Vec<&[f32]> = grp.iter().map(|&j| cols[j].as_ref()).collect();
        total += icp_group_retained(&members, v, cfg);
    }
    total
}

/// Run gyro ICP for one tile, splitting wide tiles into independent blocks
/// of at most `params.max_partitions` groups (see [`IcpParams`]).
pub fn gyro_icp<C: AsRef<[f32]>>(cols: &[C], v: usize, cfg: &HinmConfig, params: &IcpParams) -> IcpResult {
    let views: Vec<&[f32]> = cols.iter().map(|c| c.as_ref()).collect();
    let k_v = views.len();
    let m = cfg.m_group;
    let p_count = k_v / m;
    if p_count <= params.max_partitions {
        return gyro_icp_block(&views, v, cfg, params);
    }
    // Blocked: permute each segment independently, offset and concatenate.
    let block_cols = params.max_partitions * m;
    let mut order = Vec::with_capacity(k_v);
    let mut retained = 0.0;
    let mut history = vec![0.0];
    let mut iters_run = 0;
    let mut accepted = 0;
    for (bi, start) in (0..k_v).step_by(block_cols).enumerate() {
        let end = (start + block_cols).min(k_v);
        let sub_params = IcpParams {
            // SplitMix-style per-block stream derivation: block 0 must not
            // collapse to the parent seed, and nearby blocks must be
            // decorrelated (the old `seed ^ (bi << 32 | K)` left the low
            // xoshiro seed bits identical across blocks).
            seed: mix_seed(params.seed, bi as u64),
            ..params.clone()
        };
        let res = gyro_icp_block(&views[start..end], v, cfg, &sub_params);
        order.extend(res.order.iter().map(|&j| j + start));
        retained += res.retained;
        iters_run = iters_run.max(res.iters_run);
        accepted += res.accepted;
    }
    history.push(retained);
    debug_assert!(crate::tensor::is_permutation(&order, k_v));
    IcpResult { order, retained, history, iters_run, accepted }
}

/// Gyro ICP over a single block. `cols[j]` is the j-th kept column vector
/// (height `v`, column-major contiguous).
fn gyro_icp_block(cols: &[&[f32]], v: usize, cfg: &HinmConfig, params: &IcpParams) -> IcpResult {
    let k_v = cols.len();
    let m = cfg.m_group;
    assert_eq!(k_v % m, 0, "kept columns must be a multiple of M");
    assert!(cols.iter().all(|c| c.len() == v));
    let p_count = k_v / m;
    let mut rng = Xoshiro256::new(params.seed);

    let mut order: Vec<usize> = (0..k_v).collect();
    let mut best = icp_objective(cols, &order, v, cfg);
    let mut history = vec![best];
    let mut accepted = 0usize;
    let mut stale = 0usize;
    let mut iters_run = 0usize;

    if p_count <= 1 {
        return IcpResult { order, retained: best, history, iters_run: 0, accepted: 0 };
    }

    for iter in 0..params.max_iters {
        iters_run = iter + 1;

        // --- Sampling: one random vector per partition (k = 1, no clustering). ---
        let mut samples: Vec<usize> = Vec::with_capacity(p_count); // kept-col index
        let mut remainders: Vec<Vec<usize>> = Vec::with_capacity(p_count);
        for p in 0..p_count {
            let grp = &order[p * m..(p + 1) * m];
            let pick = rng.below(m);
            samples.push(grp[pick]);
            remainders.push(
                grp.iter()
                    .enumerate()
                    .filter(|(i, _)| *i != pick)
                    .map(|(_, &j)| j)
                    .collect(),
            );
        }

        // --- Assignment: Hungarian on −retained(remainder_i ∪ sample_j). ---
        let cost: Vec<Vec<f64>> = (0..p_count)
            .map(|i| {
                (0..p_count)
                    .map(|j| {
                        let members: Vec<&[f32]> = remainders[i]
                            .iter()
                            .chain(std::iter::once(&samples[j]))
                            .map(|&idx| cols[idx])
                            .collect();
                        -icp_group_retained(&members, v, cfg)
                    })
                    .collect()
            })
            .collect();
        let (assign, neg_total) = hungarian::solve(&cost);
        let cand_obj = -neg_total;

        if cand_obj > best + 1e-9 {
            // Materialize the candidate order.
            let mut new_order = Vec::with_capacity(k_v);
            for i in 0..p_count {
                new_order.extend(remainders[i].iter().copied());
                new_order.push(samples[assign[i]]);
            }
            order = new_order;
            best = cand_obj;
            accepted += 1;
            stale = 0;
            history.push(best);
        } else {
            stale += 1;
            if stale >= params.patience {
                break;
            }
        }
    }

    debug_assert!(crate::tensor::is_permutation(&order, k_v));
    IcpResult { order, retained: best, history, iters_run, accepted }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::is_permutation;

    fn cfg() -> HinmConfig {
        HinmConfig::with_24(4, 0.0)
    }

    /// Adversarial tile: group 0 = all-important vectors, group 1 = all-weak,
    /// so 2:4 in natural order discards important elements that a swap saves.
    fn adversarial_cols(v: usize) -> Vec<Vec<f32>> {
        let mut cols = Vec::new();
        for j in 0..8 {
            let hot = j < 4;
            cols.push(
                (0..v)
                    .map(|r| if hot { 5.0 + (r + j) as f32 * 0.1 } else { 0.1 })
                    .collect(),
            );
        }
        cols
    }

    #[test]
    fn improves_on_adversarial_tile() {
        let cols = adversarial_cols(8);
        let res = gyro_icp(&cols, 8, &cfg(), &IcpParams::default());
        let before = icp_objective(&cols, &(0..8).collect::<Vec<_>>(), 8, &cfg());
        assert!(res.retained > before * 1.1, "before={before} after={}", res.retained);
        assert!(is_permutation(&res.order, 8));
    }

    #[test]
    fn optimal_interleave_found_for_planted_case() {
        // 2 hot + 6 cold in each group position arrangement where the optimum
        // is to spread the 4 hot vectors across both groups (2 each).
        let cols = adversarial_cols(4);
        let res = gyro_icp(&cols, 4, &cfg(), &IcpParams { max_iters: 80, ..Default::default() });
        // Count hot vectors (< 4) per group in the final order.
        let hot_in_g0 = res.order[..4].iter().filter(|&&j| j < 4).count();
        let hot_in_g1 = res.order[4..].iter().filter(|&&j| j < 4).count();
        assert_eq!(hot_in_g0, 2, "order={:?}", res.order);
        assert_eq!(hot_in_g1, 2);
    }

    #[test]
    fn single_group_noop() {
        let cols: Vec<Vec<f32>> = (0..4).map(|j| vec![j as f32; 4]).collect();
        let res = gyro_icp(&cols, 4, &cfg(), &IcpParams::default());
        assert_eq!(res.order, vec![0, 1, 2, 3]);
        assert_eq!(res.iters_run, 0);
    }

    #[test]
    fn objective_matches_group_sum() {
        let cols = adversarial_cols(4);
        let order: Vec<usize> = (0..8).collect();
        let obj = icp_objective(&cols, &order, 4, &cfg());
        // Group of 4 hot columns: per row top2 of ~5.x values; group of cold:
        // top2 of 0.1s. Hand-check magnitude.
        assert!(obj > 40.0 && obj < 60.0, "obj={obj}");
    }

    #[test]
    fn history_monotone_and_deterministic() {
        let cols = adversarial_cols(8);
        let a = gyro_icp(&cols, 8, &cfg(), &IcpParams::default());
        let b = gyro_icp(&cols, 8, &cfg(), &IcpParams::default());
        assert_eq!(a.order, b.order);
        for w in a.history.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }
}

#[cfg(test)]
mod blocked_tests {
    use super::*;
    use crate::tensor::is_permutation;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn blocked_icp_valid_and_improves() {
        let mut rng = Xoshiro256::new(99);
        let cfg = HinmConfig::with_24(4, 0.0);
        // 64 columns, max_partitions=4 → 4 blocks of 16 cols.
        let cols: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..4).map(|_| rng.next_f32() * if rng.next_f32() < 0.3 { 5.0 } else { 0.2 }).collect())
            .collect();
        let params = IcpParams { max_partitions: 4, ..Default::default() };
        let res = gyro_icp(&cols, 4, &cfg, &params);
        assert!(is_permutation(&res.order, 64));
        let before = icp_objective(&cols, &(0..64).collect::<Vec<_>>(), 4, &cfg);
        assert!(res.retained >= before - 1e-9);
        // Each block stays within its segment.
        for (bi, chunk) in res.order.chunks(16).enumerate() {
            assert!(chunk.iter().all(|&j| j / 16 == bi), "block {bi} leaked: {chunk:?}");
        }
    }

    #[test]
    fn blocked_matches_unblocked_when_small() {
        let mut rng = Xoshiro256::new(100);
        let cfg = HinmConfig::with_24(4, 0.0);
        let cols: Vec<Vec<f32>> = (0..16).map(|_| (0..4).map(|_| rng.next_f32()).collect()).collect();
        let a = gyro_icp(&cols, 4, &cfg, &IcpParams::default());
        let b = gyro_icp(&cols, 4, &cfg, &IcpParams { max_partitions: 1000, ..Default::default() });
        assert_eq!(a.order, b.order);
    }
}
