//! Tetris baseline (Ji et al., NeurIPS'18): swap-based permutation of *both*
//! output and input channels for block-wise sparsity. Unlike gyro, the
//! input-channel permutation is global (one order shared by all tiles), so
//! adjacent layers end up with inconsistent channel orders and require an
//! explicit index-translation (gather) op at runtime — the overhead the
//! paper's §2 contrasts against. `spmm::sim` charges that extra pass when
//! asked to model a Tetris-permuted network.

use crate::sparsity::config::HinmConfig;
use crate::sparsity::hinm::hinm_retained;
use crate::tensor::Matrix;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
/// Tuning knobs for the Tetris two-axis swap search.
pub struct TetrisParams {
    /// Alternating row/column rounds before stopping.
    pub max_rounds: usize,
    /// Candidate swaps evaluated per round per axis.
    pub swaps_per_round: usize,
    /// RNG seed for candidate-swap selection.
    pub seed: u64,
}

impl Default for TetrisParams {
    fn default() -> Self {
        Self { max_rounds: 12, swaps_per_round: 64, seed: 0x7E7 }
    }
}

#[derive(Clone, Debug)]
/// Outcome of [`tetris_permute`].
pub struct TetrisResult {
    /// Final row order: position `i` holds original row `row_perm[i]`.
    pub row_perm: Vec<usize>,
    /// Final column order, same convention.
    pub col_perm: Vec<usize>,
    /// Hierarchical retention of the final arrangement.
    pub retained: f64,
    /// Rounds actually executed (early stop on no improvement).
    pub rounds_run: usize,
}

/// Alternating random-swap hill-climb on rows then columns, scored by the
/// full HiNM retention (Tetris scored block saliency; the analogous
/// objective here is the hierarchical mask's retention).
pub fn tetris_permute(sal: &Matrix, cfg: &HinmConfig, params: &TetrisParams) -> TetrisResult {
    let mut rng = Xoshiro256::new(params.seed);
    let mut row_perm: Vec<usize> = (0..sal.rows).collect();
    let mut col_perm: Vec<usize> = (0..sal.cols).collect();
    let mut cur = sal.clone();
    let mut best = hinm_retained(&cur, cfg);
    let mut rounds_run = 0;

    for _round in 0..params.max_rounds {
        rounds_run += 1;
        let mut improved = false;

        // Row swaps across partitions.
        for _ in 0..params.swaps_per_round {
            let a = rng.below(sal.rows);
            let mut b = rng.below(sal.rows);
            while b / cfg.v == a / cfg.v {
                b = rng.below(sal.rows);
            }
            swap_rows(&mut cur, a, b);
            let cand = hinm_retained(&cur, cfg);
            if cand > best + 1e-9 {
                best = cand;
                row_perm.swap(a, b);
                improved = true;
            } else {
                swap_rows(&mut cur, a, b);
            }
        }

        // Column swaps (global — the Tetris weakness).
        for _ in 0..params.swaps_per_round {
            let a = rng.below(sal.cols);
            let b = rng.below(sal.cols);
            if a == b {
                continue;
            }
            swap_cols(&mut cur, a, b);
            let cand = hinm_retained(&cur, cfg);
            if cand > best + 1e-9 {
                best = cand;
                col_perm.swap(a, b);
                improved = true;
            } else {
                swap_cols(&mut cur, a, b);
            }
        }

        if !improved {
            break;
        }
    }

    TetrisResult { row_perm, col_perm, retained: best, rounds_run }
}

fn swap_rows(m: &mut Matrix, a: usize, b: usize) {
    if a == b {
        return;
    }
    for c in 0..m.cols {
        let tmp = m.at(a, c);
        *m.at_mut(a, c) = m.at(b, c);
        *m.at_mut(b, c) = tmp;
    }
}

fn swap_cols(m: &mut Matrix, a: usize, b: usize) {
    for r in 0..m.rows {
        let tmp = m.at(r, a);
        *m.at_mut(r, a) = m.at(r, b);
        *m.at_mut(r, b) = tmp;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::is_permutation;

    #[test]
    fn permutations_valid_and_retention_monotone() {
        let mut rng = Xoshiro256::new(60);
        let sal = Matrix::from_fn(16, 16, |_, _| rng.next_f32() * if rng.next_f32() < 0.2 { 5.0 } else { 0.2 });
        let cfg = HinmConfig::with_24(4, 0.5);
        let before = hinm_retained(&sal, &cfg);
        let res = tetris_permute(&sal, &cfg, &TetrisParams::default());
        assert!(is_permutation(&res.row_perm, 16));
        assert!(is_permutation(&res.col_perm, 16));
        assert!(res.retained >= before);
    }

    #[test]
    fn reported_retention_matches_applied_permutations() {
        let mut rng = Xoshiro256::new(61);
        let sal = Matrix::from_fn(8, 16, |_, _| rng.next_f32());
        let cfg = HinmConfig::with_24(4, 0.5);
        let res = tetris_permute(&sal, &cfg, &TetrisParams { max_rounds: 4, swaps_per_round: 16, seed: 9 });
        let permuted = sal.permute_rows(&res.row_perm).permute_cols(&res.col_perm);
        let direct = hinm_retained(&permuted, &cfg);
        assert!((direct - res.retained).abs() < 1e-6 * direct.max(1.0), "{direct} vs {}", res.retained);
    }
}
