//! OVW baseline (Tan et al., NeurIPS'22 — "out-vector-wise" sparsity):
//! output-channel permutation via balanced K-means over *all* channels in a
//! single pass, grouping channels with similar saliency profiles into
//! partitions of V so whole column vectors can be removed.
//!
//! This is the `OVW` arm of Figs. 3/4 and the OCP replaced in the HiNM-V1
//! ablation (Table 3). Unlike gyro OCP it has no sampling phase and no
//! explicit prune-loss cost — exactly the two deficiencies §5.2 calls out.

use crate::permute::kmeans::balanced_kmeans;
use crate::sparsity::config::HinmConfig;
use crate::tensor::Matrix;
use crate::util::rng::Xoshiro256;

/// One-shot balanced-K-means output-channel permutation.
/// Returns `perm[i]` = original channel at permuted position `i`.
pub fn ovw_ocp(sal: &Matrix, cfg: &HinmConfig, seed: u64) -> Vec<usize> {
    cfg.validate(sal.rows, sal.cols).expect("invalid config");
    let v = cfg.v;
    let p_count = sal.rows / v;
    if p_count <= 1 {
        return (0..sal.rows).collect();
    }
    let mut rng = Xoshiro256::new(seed);
    let feats: Vec<Vec<f32>> = (0..sal.rows).map(|r| sal.row(r).to_vec()).collect();
    let clustering = balanced_kmeans(&feats, p_count, v, 16, &mut rng);
    let mut perm = Vec::with_capacity(sal.rows);
    for cluster in &clustering.clusters {
        let mut members = cluster.clone();
        members.sort_unstable();
        perm.extend(members);
    }
    perm
}

/// The complete OVW pruning arm: K-means OCP + column-wise vector pruning
/// (no N:M level — OVW is a single-level vector-sparsity method). To compare
/// at equal *total* sparsity with HiNM, the vector level must carry all of
/// it: `s_v(total) = total`.
pub fn ovw_retained(sal: &Matrix, v: usize, total_sparsity: f64, seed: u64) -> f64 {
    let cfg = HinmConfig {
        v,
        n_keep: 4,
        m_group: 4, // N==M → N:M disabled
        vector_sparsity: total_sparsity,
    };
    let perm = ovw_ocp(sal, &cfg, seed);
    let sal_p = sal.permute_rows(&perm);
    crate::sparsity::vector_prune::vector_retained(&sal_p, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::vector_prune::vector_retained;
    use crate::tensor::is_permutation;

    #[test]
    fn produces_valid_permutation() {
        let mut rng = Xoshiro256::new(30);
        let sal = Matrix::randn(16, 16, 1.0, &mut rng).abs();
        let cfg = HinmConfig::with_24(4, 0.5);
        let perm = ovw_ocp(&sal, &cfg, 1);
        assert!(is_permutation(&perm, 16));
    }

    #[test]
    fn clusters_similar_channels_improving_vector_retention() {
        // Two channel archetypes interleaved; clustering them recovers
        // homogeneous partitions, concentrating unimportant columns.
        let sal = Matrix::from_fn(16, 16, |r, c| {
            if r % 2 == 0 {
                if c < 8 { 5.0 } else { 0.1 }
            } else if c < 8 {
                0.1
            } else {
                5.0
            }
        });
        let cfg = HinmConfig::with_24(8, 0.5);
        let before = vector_retained(&sal, &cfg);
        let perm = ovw_ocp(&sal, &cfg, 2);
        let after = vector_retained(&sal.permute_rows(&perm), &cfg);
        assert!(after > before * 1.2, "before={before} after={after}");
    }

    #[test]
    fn ovw_retained_at_total_sparsity() {
        let mut rng = Xoshiro256::new(31);
        let sal = Matrix::randn(32, 32, 1.0, &mut rng).abs();
        let r50 = ovw_retained(&sal, 8, 0.5, 3);
        let r75 = ovw_retained(&sal, 8, 0.75, 3);
        assert!(r50 > r75);
        assert!(r75 > 0.0);
    }
}
