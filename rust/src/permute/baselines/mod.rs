//! Baseline permutation methods: OVW (balanced K-means OCP, Tan et al.),
//! Apex-style swap ICP (Pool & Yu), and Tetris (two-axis swap search with
//! runtime index-translation overhead).

pub mod apex;
pub mod ovw;
pub mod tetris;
