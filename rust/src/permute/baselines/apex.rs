//! NVIDIA-Apex–style input-channel permutation (Pool & Yu, NeurIPS'21):
//! greedy channel *swapping* to balance important elements across N:M
//! groups, with bounded escape moves. Re-implemented here at column-vector
//! granularity so it can stand in for gyro ICP — the HiNM-V2 ablation arm
//! of Table 3.

use crate::permute::cost::icp_group_retained;
use crate::sparsity::config::HinmConfig;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
/// Tuning knobs for the Apex-style pairwise-swap ICP (Pool & Yu).
pub struct ApexParams {
    /// Full sweeps over all column pairs.
    pub max_sweeps: usize,
    /// Escape attempts (random swap accepted regardless) when a sweep
    /// finds no improving swap — Apex's bounded-regression trick.
    pub escapes: usize,
    /// RNG seed for escape-move selection.
    pub seed: u64,
}

impl Default for ApexParams {
    fn default() -> Self {
        Self { max_sweeps: 8, escapes: 2, seed: 0xA9E }
    }
}

/// Total Eq. 3 objective of an order.
fn objective<C: AsRef<[f32]>>(cols: &[C], order: &[usize], v: usize, cfg: &HinmConfig) -> f64 {
    order
        .chunks_exact(cfg.m_group)
        .map(|grp| {
            let members: Vec<&[f32]> = grp.iter().map(|&j| cols[j].as_ref()).collect();
            icp_group_retained(&members, v, cfg)
        })
        .sum()
}

/// Greedy pairwise-swap search over column-vector positions. Generic over the
/// column container (owned `Vec<f32>` columns or borrowed slices into a flat
/// tile buffer — see the strategy layer).
pub fn apex_icp<C: AsRef<[f32]>>(cols: &[C], v: usize, cfg: &HinmConfig, params: &ApexParams) -> (Vec<usize>, f64) {
    let cols: Vec<&[f32]> = cols.iter().map(|c| c.as_ref()).collect();
    let cols = cols.as_slice();
    let k_v = cols.len();
    let m = cfg.m_group;
    assert_eq!(k_v % m, 0);
    let mut order: Vec<usize> = (0..k_v).collect();
    let mut rng = Xoshiro256::new(params.seed);
    let mut escapes_left = params.escapes;

    for _sweep in 0..params.max_sweeps {
        let mut improved = false;
        for a in 0..k_v {
            for b in (a + 1)..k_v {
                if a / m == b / m {
                    continue; // same group: swap is a no-op for the mask
                }
                order.swap(a, b);
                // Only the two touched groups change; recompute locally.
                let delta_groups = [a / m, b / m];
                let local_after: f64 = delta_groups
                    .iter()
                    .map(|&g| {
                        let grp = &order[g * m..(g + 1) * m];
                        let members: Vec<&[f32]> = grp.iter().map(|&j| cols[j]).collect();
                        icp_group_retained(&members, v, cfg)
                    })
                    .sum();
                order.swap(a, b);
                let local_before: f64 = delta_groups
                    .iter()
                    .map(|&g| {
                        let grp = &order[g * m..(g + 1) * m];
                        let members: Vec<&[f32]> = grp.iter().map(|&j| cols[j]).collect();
                        icp_group_retained(&members, v, cfg)
                    })
                    .sum();
                if local_after > local_before + 1e-9 {
                    order.swap(a, b);
                    improved = true;
                }
            }
        }
        if !improved {
            if escapes_left == 0 {
                break;
            }
            // Escape: random cross-group swap accepted unconditionally.
            escapes_left -= 1;
            let a = rng.below(k_v);
            let mut b = rng.below(k_v);
            while b / m == a / m {
                b = rng.below(k_v);
            }
            order.swap(a, b);
        }
    }
    let final_obj = objective(cols, &order, v, cfg);
    (order, final_obj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::is_permutation;

    fn cfg() -> HinmConfig {
        HinmConfig::with_24(4, 0.0)
    }

    #[test]
    fn swap_search_improves_adversarial_tile() {
        // 4 hot then 4 cold vectors: natural grouping wastes hot elements.
        let cols: Vec<Vec<f32>> = (0..8)
            .map(|j| {
                let val = if j < 4 { 5.0 } else { 0.1 };
                vec![val; 4]
            })
            .collect();
        let before = objective(&cols, &(0..8).collect::<Vec<_>>(), 4, &cfg());
        let (order, after) = apex_icp(&cols, 4, &cfg(), &ApexParams::default());
        assert!(is_permutation(&order, 8));
        assert!(after > before, "before={before} after={after}");
        // Optimum spreads hot 2/2.
        let hot0 = order[..4].iter().filter(|&&j| j < 4).count();
        assert_eq!(hot0, 2);
    }

    #[test]
    fn noop_on_uniform_tile() {
        let cols: Vec<Vec<f32>> = (0..8).map(|_| vec![1.0; 4]).collect();
        let before = objective(&cols, &(0..8).collect::<Vec<_>>(), 4, &cfg());
        let (_, after) = apex_icp(&cols, 4, &cfg(), &ApexParams::default());
        assert!((after - before).abs() < 1e-9);
    }

    #[test]
    fn incremental_objective_consistent() {
        let mut rng = Xoshiro256::new(55);
        let cols: Vec<Vec<f32>> = (0..12)
            .map(|_| (0..4).map(|_| rng.next_f32() * 3.0).collect())
            .collect();
        let (order, reported) = apex_icp(&cols, 4, &cfg(), &ApexParams::default());
        let actual = objective(&cols, &order, 4, &cfg());
        assert!((reported - actual).abs() < 1e-6, "{reported} vs {actual}");
    }
}
