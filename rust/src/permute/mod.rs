//! Channel permutation — the paper's contribution (gyro-permutation) plus
//! the baseline/ablation permutation methods it is compared against, unified
//! behind the [`strategy`] layer: [`OcpStrategy`] × [`IcpStrategy`] pairs
//! built from a string-keyed [`StrategyRegistry`] and executed by the
//! parallel [`PermutePipeline`] tile engine.

pub mod baselines;
pub mod cost;
pub mod gyro;
pub mod hungarian;
pub mod icp;
pub mod kmeans;
pub mod ocp;
pub mod sampling;
pub mod strategy;

pub use gyro::{gyro_permute_and_prune, GyroOutcome, GyroParams};
pub use icp::{gyro_icp, IcpParams};
pub use ocp::{gyro_ocp, OcpParams};
pub use strategy::{
    ApexIcp, GyroIcp, GyroOcp, IcpStrategy, IdentityIcp, IdentityOcp, OcpStrategy, OvwOcp,
    PermuteOutcome, PermutePipeline, StrategyParams, StrategyRegistry, StrategySpec, TetrisIcp,
    TileCols,
};
