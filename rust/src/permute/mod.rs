//! Channel permutation — the paper's contribution (gyro-permutation) plus
//! the baseline/ablation permutation methods it is compared against.

pub mod baselines;
pub mod cost;
pub mod gyro;
pub mod hungarian;
pub mod icp;
pub mod kmeans;
pub mod ocp;
pub mod sampling;

pub use gyro::{gyro_permute_and_prune, GyroOutcome, GyroParams};
pub use icp::{gyro_icp, IcpParams};
pub use ocp::{gyro_ocp, OcpParams};
