//! The permutation **strategy layer**: every OCP/ICP method behind two
//! composable traits, a string-keyed registry so any OCP×ICP pair is runnable
//! from the CLI/pipeline/evals/benches (`gyro+apex`, `ovw+gyro`, …), and the
//! [`PermutePipeline`] tile engine that owns the
//! OCP → vector-prune → ICP → pack sequence exactly once.
//!
//! Contracts (see DESIGN.md §4):
//!
//! * [`OcpStrategy`] maps a dense saliency grid to an output-channel
//!   permutation. It must return a valid permutation of `0..rows`; it never
//!   mutates inputs and may report `f64::NAN` when it has no Eq. 2 score.
//! * [`IcpStrategy`] maps one tile's kept column vectors (a borrowed
//!   column-major [`TileCols`] view) to an order over those columns. It must
//!   return a valid permutation of `0..k_v` and must derive any randomness
//!   from `(its seed, tile index)` only — that is what makes the parallel
//!   tile engine bit-deterministic regardless of worker count.
//! * [`PermutePipeline`] enforces the paper's never-worse guarantee
//!   centrally: if a strategy pair retains less than the unpermuted HiNM
//!   baseline, it re-invokes itself with [`IdentityOcp`] (and, for
//!   non-monotone ICPs, falls through to plain HiNM), so *no* registered
//!   method can end below `noperm`.

use super::baselines::apex::{apex_icp, ApexParams};
use super::baselines::ovw::ovw_ocp;
use super::cost::icp_group_retained;
use super::gyro::GyroParams;
use super::icp::{gyro_icp, IcpParams};
use super::ocp::{gyro_ocp, OcpParams};
use crate::sparsity::config::HinmConfig;
use crate::sparsity::hinm::{gather_tile_colmajor, hinm_retained, prune_with_kept, HinmResult};
use crate::sparsity::vector_prune::{vector_prune, VectorPruneResult};
use crate::tensor::{is_permutation, Matrix};
use crate::util::rng::{mix_seed, Xoshiro256};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

// ---------------------------------------------------------------------------
// Tile view
// ---------------------------------------------------------------------------

/// One tile's kept column vectors, borrowed column-major from a flat scratch
/// buffer: column `j` is the contiguous slice `data[j*v .. (j+1)*v]`. The
/// tile engine fills one such buffer per worker and reuses it across tiles —
/// replacing the per-tile `Vec<Vec<f32>>` materialization the legacy drivers
/// performed.
pub struct TileCols<'a> {
    data: &'a [f32],
    /// Vector height V.
    pub v: usize,
    /// Kept columns in this tile.
    pub k_v: usize,
}

impl<'a> TileCols<'a> {
    /// Borrow a filled scratch buffer as a tile view; `data.len()` must be `v * k_v`.
    pub fn new(data: &'a [f32], v: usize, k_v: usize) -> Self {
        debug_assert_eq!(data.len(), v * k_v);
        Self { data, v, k_v }
    }

    /// The `j`-th kept column vector (contiguous, height `v`).
    #[inline]
    pub fn col(&self, j: usize) -> &'a [f32] {
        &self.data[j * self.v..(j + 1) * self.v]
    }

    /// All columns as borrowed slices (no copy of the underlying data).
    pub fn col_slices(&self) -> Vec<&'a [f32]> {
        (0..self.k_v).map(|j| self.col(j)).collect()
    }
}

// ---------------------------------------------------------------------------
// Traits
// ---------------------------------------------------------------------------

/// Result of an output-channel permutation strategy.
#[derive(Clone, Debug)]
pub struct OcpOutcome {
    /// `perm[i]` = original output channel at permuted position `i`.
    pub perm: Vec<usize>,
    /// The strategy's own objective value (`f64::NAN` when not applicable).
    pub retained: f64,
}

/// Dense saliency → output-channel permutation (paper Eq. 2 level).
pub trait OcpStrategy: Send + Sync {
    /// Canonical registry key (`"gyro"`, `"ovw"`, `"id"`).
    fn key(&self) -> &'static str;
    /// `true` when [`permute`](Self::permute) always returns the identity —
    /// lets the pipeline skip the never-worse guard and re-permutation.
    fn is_identity(&self) -> bool {
        false
    }
    /// Produce the output-channel permutation for one saliency grid.
    fn permute(&self, sal: &Matrix, cfg: &HinmConfig) -> OcpOutcome;
}

/// Result of ordering one tile's kept columns.
#[derive(Clone, Debug)]
pub struct IcpTileOutcome {
    /// Permutation of `0..k_v` (positions into the tile's ascending kept
    /// list), consumed by the packer's N:M grouping.
    pub order: Vec<usize>,
    /// Refinement iterations the strategy executed for this tile.
    pub iters_run: usize,
    /// Iterations that improved the strategy's objective.
    pub accepted: usize,
}

/// Tile column vectors → per-tile order (paper Eq. 3 level). Tiles are
/// independent; `tile` is provided solely for per-tile seed derivation.
pub trait IcpStrategy: Send + Sync {
    /// Canonical registry key (`"gyro"`, `"apex"`, `"tetris"`, `"id"`).
    fn key(&self) -> &'static str;
    /// `true` when the strategy always returns the natural order.
    fn is_identity(&self) -> bool {
        false
    }
    /// Order the tile's kept columns; randomness must derive from `(seed, tile)` only.
    fn order_tile(&self, cols: &TileCols<'_>, cfg: &HinmConfig, tile: usize) -> IcpTileOutcome;
}

// ---------------------------------------------------------------------------
// OCP strategies
// ---------------------------------------------------------------------------

/// Gyro OCP: sampling → clustering → Hungarian assignment (the paper's §4.2).
#[derive(Clone, Debug, Default)]
pub struct GyroOcp {
    /// Gyro OCP tuning (iterations, sampling, seed).
    pub params: OcpParams,
}

impl OcpStrategy for GyroOcp {
    fn key(&self) -> &'static str {
        "gyro"
    }
    fn permute(&self, sal: &Matrix, cfg: &HinmConfig) -> OcpOutcome {
        let r = gyro_ocp(sal, cfg, &self.params);
        OcpOutcome { perm: r.perm, retained: r.retained }
    }
}

/// OVW baseline OCP: one-shot balanced K-means over all channels
/// (Tan et al., NeurIPS'22 — the HiNM-V1 ablation arm).
#[derive(Clone, Debug)]
pub struct OvwOcp {
    /// Seed for the balanced K-means initialization.
    pub seed: u64,
}

impl OcpStrategy for OvwOcp {
    fn key(&self) -> &'static str {
        "ovw"
    }
    fn permute(&self, sal: &Matrix, cfg: &HinmConfig) -> OcpOutcome {
        OcpOutcome { perm: ovw_ocp(sal, cfg, self.seed), retained: f64::NAN }
    }
}

/// No output-channel permutation.
#[derive(Clone, Copy, Debug, Default)]
pub struct IdentityOcp;

impl OcpStrategy for IdentityOcp {
    fn key(&self) -> &'static str {
        "id"
    }
    fn is_identity(&self) -> bool {
        true
    }
    fn permute(&self, sal: &Matrix, _cfg: &HinmConfig) -> OcpOutcome {
        OcpOutcome { perm: (0..sal.rows).collect(), retained: f64::NAN }
    }
}

// ---------------------------------------------------------------------------
// ICP strategies
// ---------------------------------------------------------------------------

/// Gyro ICP: one-sample-per-partition extraction + Hungarian assignment.
#[derive(Clone, Debug, Default)]
pub struct GyroIcp {
    /// Gyro ICP tuning (iterations, patience, base seed).
    pub params: IcpParams,
}

impl IcpStrategy for GyroIcp {
    fn key(&self) -> &'static str {
        "gyro"
    }
    fn order_tile(&self, cols: &TileCols<'_>, cfg: &HinmConfig, tile: usize) -> IcpTileOutcome {
        let views = cols.col_slices();
        let params = IcpParams { seed: mix_seed(self.params.seed, tile as u64), ..self.params.clone() };
        let res = gyro_icp(&views, cols.v, cfg, &params);
        IcpTileOutcome { order: res.order, iters_run: res.iters_run, accepted: res.accepted }
    }
}

/// Apex-style greedy pairwise-swap ICP with bounded escape moves
/// (Pool & Yu, NeurIPS'21 — the HiNM-V2 ablation arm). NOTE: escape moves
/// make this the one registered ICP that is *not* monotone w.r.t. the
/// natural order; the pipeline guard covers it.
#[derive(Clone, Debug, Default)]
pub struct ApexIcp {
    /// Apex swap-search tuning (sweeps, escapes, seed).
    pub params: ApexParams,
}

impl IcpStrategy for ApexIcp {
    fn key(&self) -> &'static str {
        "apex"
    }
    fn order_tile(&self, cols: &TileCols<'_>, cfg: &HinmConfig, tile: usize) -> IcpTileOutcome {
        let views = cols.col_slices();
        let params = ApexParams { seed: mix_seed(self.params.seed, tile as u64), ..self.params.clone() };
        let (order, _) = apex_icp(&views, cols.v, cfg, &params);
        IcpTileOutcome { order, iters_run: 0, accepted: 0 }
    }
}

/// Tetris-style random-swap hill-climb (Ji et al., NeurIPS'18), restricted to
/// one tile's columns so it slots in as an ICP. Only improving swaps are
/// accepted, so unlike the global Tetris search it is monotone per tile.
#[derive(Clone, Debug)]
pub struct TetrisIcp {
    /// Alternating hill-climb rounds before stopping.
    pub max_rounds: usize,
    /// Candidate swaps per round.
    pub swaps_per_round: usize,
    /// Base seed (per-tile streams derive via `mix_seed`).
    pub seed: u64,
}

impl Default for TetrisIcp {
    fn default() -> Self {
        Self { max_rounds: 12, swaps_per_round: 128, seed: 0x7E7 }
    }
}

impl IcpStrategy for TetrisIcp {
    fn key(&self) -> &'static str {
        "tetris"
    }
    fn order_tile(&self, cols: &TileCols<'_>, cfg: &HinmConfig, tile: usize) -> IcpTileOutcome {
        let k_v = cols.k_v;
        let m = cfg.m_group;
        let mut order: Vec<usize> = (0..k_v).collect();
        if k_v / m <= 1 {
            return IcpTileOutcome { order, iters_run: 0, accepted: 0 };
        }
        let mut rng = Xoshiro256::new(mix_seed(self.seed, tile as u64));
        let group_retained = |order: &[usize], g: usize| {
            let members: Vec<&[f32]> =
                order[g * m..(g + 1) * m].iter().map(|&j| cols.col(j)).collect();
            icp_group_retained(&members, cols.v, cfg)
        };
        let mut accepted = 0usize;
        let mut rounds = 0usize;
        for _ in 0..self.max_rounds {
            rounds += 1;
            let mut improved = false;
            for _ in 0..self.swaps_per_round {
                let a = rng.below(k_v);
                let b = rng.below(k_v);
                if a / m == b / m {
                    continue; // same group: no-op for the mask
                }
                let (ga, gb) = (a / m, b / m);
                let before = group_retained(&order, ga) + group_retained(&order, gb);
                order.swap(a, b);
                let after = group_retained(&order, ga) + group_retained(&order, gb);
                if after > before + 1e-9 {
                    accepted += 1;
                    improved = true;
                } else {
                    order.swap(a, b);
                }
            }
            if !improved {
                break;
            }
        }
        IcpTileOutcome { order, iters_run: rounds, accepted }
    }
}

/// Natural (ascending kept-index) order — no ICP.
#[derive(Clone, Copy, Debug, Default)]
pub struct IdentityIcp;

impl IcpStrategy for IdentityIcp {
    fn key(&self) -> &'static str {
        "id"
    }
    fn is_identity(&self) -> bool {
        true
    }
    fn order_tile(&self, cols: &TileCols<'_>, _cfg: &HinmConfig, _tile: usize) -> IcpTileOutcome {
        IcpTileOutcome { order: (0..cols.k_v).collect(), iters_run: 0, accepted: 0 }
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Tuning bundle the registry instantiates strategies from. One bundle per
/// pipeline run keeps seeds explicit and every table reproducible.
#[derive(Clone, Debug)]
pub struct StrategyParams {
    /// Gyro OCP tuning, also the seed source for OVW.
    pub ocp: OcpParams,
    /// Gyro ICP tuning.
    pub icp: IcpParams,
    /// Apex ICP tuning.
    pub apex: ApexParams,
    /// Tetris ICP tuning (the strategy is its own params).
    pub tetris: TetrisIcp,
    /// Seed for the OVW one-shot clustering.
    pub ovw_seed: u64,
}

impl Default for StrategyParams {
    fn default() -> Self {
        let ocp = OcpParams::default();
        let ovw_seed = ocp.seed;
        Self {
            ocp,
            icp: IcpParams::default(),
            apex: ApexParams::default(),
            tetris: TetrisIcp::default(),
            ovw_seed,
        }
    }
}

impl From<&GyroParams> for StrategyParams {
    /// Legacy bridge: the coordinator's `GyroParams` carries the gyro OCP/ICP
    /// tuning; baseline strategies reuse its seeds so a single `--seed`
    /// steers every arm.
    fn from(g: &GyroParams) -> Self {
        let mut p = Self { ocp: g.ocp.clone(), icp: g.icp.clone(), ..Self::default() };
        p.ovw_seed = p.ocp.seed;
        p.apex.seed = mix_seed(p.icp.seed, 0xA9E);
        p.tetris.seed = mix_seed(p.icp.seed, 0x7E7);
        p
    }
}

/// Resolve key aliases (`identity`/`none` → `id`) shared by both axes.
fn canon_key(key: &str) -> &str {
    match key {
        "identity" | "none" => "id",
        k => k,
    }
}

/// A parsed `<ocp>+<icp>` method specification over canonical registry keys.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StrategySpec {
    /// Canonical OCP key (`gyro`, `ovw`, `id`, or custom).
    pub ocp: String,
    /// Canonical ICP key (`gyro`, `apex`, `tetris`, `id`, or custom).
    pub icp: String,
}

impl StrategySpec {
    /// Spec from two keys; aliases (`identity`/`none`) are canonicalized.
    pub fn new(ocp: &str, icp: &str) -> Self {
        Self { ocp: canon_key(ocp).to_string(), icp: canon_key(icp).to_string() }
    }

    /// Parse a CLI method string against the **builtin** registry: the four
    /// legacy arm names (`gyro`, `noperm`, `v1`, `v2`), `v3`, or any
    /// explicit `<ocp>+<icp>` pair (`gyro+apex`, `ovw+tetris`, `id+gyro`,
    /// …). Code holding a registry with custom strategies should use
    /// [`StrategyRegistry::parse_spec`] instead, which validates against
    /// that instance's keys.
    pub fn parse(s: &str) -> Option<StrategySpec> {
        StrategyRegistry::builtin().parse_spec(s)
    }

    /// Canonical `ocp+icp` key.
    pub fn key(&self) -> String {
        format!("{}+{}", self.ocp, self.icp)
    }

    /// Human label matching the paper's arm names where one exists.
    pub fn label(&self) -> String {
        match (self.ocp.as_str(), self.icp.as_str()) {
            ("gyro", "gyro") => "HiNM".to_string(),
            ("id", "id") => "HiNM-NoPerm".to_string(),
            ("ovw", "gyro") => "HiNM-V1".to_string(),
            ("gyro", "apex") => "HiNM-V2".to_string(),
            ("gyro", "tetris") => "HiNM-V3".to_string(),
            _ => format!("HiNM[{}+{}]", self.ocp, self.icp),
        }
    }
}

type OcpFactory = fn(&StrategyParams) -> Box<dyn OcpStrategy>;
type IcpFactory = fn(&StrategyParams) -> Box<dyn IcpStrategy>;

/// String-keyed strategy registry. `builtin()` registers every method the
/// paper compares; downstream code adds methods by inserting a factory under
/// a new key (see DESIGN.md §4 "adding a method").
pub struct StrategyRegistry {
    ocp: BTreeMap<&'static str, OcpFactory>,
    icp: BTreeMap<&'static str, IcpFactory>,
}

impl StrategyRegistry {
    /// Registry with every strategy the paper compares pre-registered.
    pub fn builtin() -> Self {
        let mut ocp: BTreeMap<&'static str, OcpFactory> = BTreeMap::new();
        ocp.insert("gyro", |p| Box::new(GyroOcp { params: p.ocp.clone() }));
        ocp.insert("ovw", |p| Box::new(OvwOcp { seed: p.ovw_seed }));
        ocp.insert("id", |_| Box::new(IdentityOcp));
        let mut icp: BTreeMap<&'static str, IcpFactory> = BTreeMap::new();
        icp.insert("gyro", |p| Box::new(GyroIcp { params: p.icp.clone() }));
        icp.insert("apex", |p| Box::new(ApexIcp { params: p.apex.clone() }));
        icp.insert("tetris", |p| Box::new(p.tetris.clone()));
        icp.insert("id", |_| Box::new(IdentityIcp));
        Self { ocp, icp }
    }

    /// Register a custom OCP strategy factory under `key`.
    pub fn register_ocp(&mut self, key: &'static str, f: OcpFactory) {
        self.ocp.insert(key, f);
    }

    /// Register a custom ICP strategy factory under `key`.
    pub fn register_icp(&mut self, key: &'static str, f: IcpFactory) {
        self.icp.insert(key, f);
    }

    /// Canonical OCP keys, sorted.
    pub fn ocp_keys(&self) -> Vec<&'static str> {
        self.ocp.keys().copied().collect()
    }

    /// Canonical ICP keys, sorted.
    pub fn icp_keys(&self) -> Vec<&'static str> {
        self.icp.keys().copied().collect()
    }

    /// True when both keys of `spec` are registered.
    pub fn supports(&self, spec: &StrategySpec) -> bool {
        self.ocp.contains_key(spec.ocp.as_str()) && self.icp.contains_key(spec.icp.as_str())
    }

    /// Parse a method string against **this** registry's keys — legacy arm
    /// names plus any `<ocp>+<icp>` pair, including custom-registered keys.
    pub fn parse_spec(&self, s: &str) -> Option<StrategySpec> {
        let spec = match s {
            "gyro" | "hinm" => StrategySpec::new("gyro", "gyro"),
            "noperm" | "hinm-noperm" => StrategySpec::new("id", "id"),
            "v1" | "hinm-v1" => StrategySpec::new("ovw", "gyro"),
            "v2" | "hinm-v2" => StrategySpec::new("gyro", "apex"),
            "v3" | "hinm-v3" => StrategySpec::new("gyro", "tetris"),
            other => {
                let (o, i) = other.split_once('+')?;
                StrategySpec::new(o.trim(), i.trim())
            }
        };
        if self.supports(&spec) {
            Some(spec)
        } else {
            None
        }
    }

    /// Instantiate the OCP strategy under `key`, or `None` if unregistered.
    pub fn build_ocp(&self, key: &str, params: &StrategyParams) -> Option<Box<dyn OcpStrategy>> {
        self.ocp.get(canon_key(key)).map(|f| f(params))
    }

    /// Instantiate the ICP strategy under `key`, or `None` if unregistered.
    pub fn build_icp(&self, key: &str, params: &StrategyParams) -> Option<Box<dyn IcpStrategy>> {
        self.icp.get(canon_key(key)).map(|f| f(params))
    }

    /// Build the strategy pair for a spec, or `None` on an unknown key.
    pub fn build(
        &self,
        spec: &StrategySpec,
        params: &StrategyParams,
    ) -> Option<(Box<dyn OcpStrategy>, Box<dyn IcpStrategy>)> {
        Some((self.build_ocp(&spec.ocp, params)?, self.build_icp(&spec.icp, params)?))
    }

    /// One-line help text for CLI `--method` flags.
    pub fn method_help(&self) -> String {
        format!(
            "gyro | noperm | v1 | v2 | v3 | <ocp>+<icp> with ocp ∈ {{{}}}, icp ∈ {{{}}}",
            self.ocp_keys().join("|"),
            self.icp_keys().join("|")
        )
    }
}

// ---------------------------------------------------------------------------
// Pipeline
// ---------------------------------------------------------------------------

/// Outcome of a full permute-and-prune run (any strategy pair).
#[derive(Clone, Debug)]
pub struct PermuteOutcome {
    /// Output-channel permutation applied to rows (offline; folded into the
    /// adjacent layers, see paper §3.2).
    pub ocp_perm: Vec<usize>,
    /// Per-tile orders over kept columns (consumed by the runtime gather).
    pub tile_orders: Vec<Vec<usize>>,
    /// Final packed layer + retention stats.
    pub result: HinmResult,
    /// The OCP strategy's own objective (`NAN` for identity/OVW).
    pub ocp_retained: f64,
    /// ICP iteration stats per tile: `(iters_run, accepted)`.
    pub icp_stats: Vec<(usize, usize)>,
}

/// The generic permute-and-prune engine: owns the OCP → vector-prune → ICP →
/// pack sequence once for every strategy pair, runs tiles in parallel across
/// a chunked `std::thread` worker pool (per-worker reusable column-major
/// scratch), and enforces the never-worse guard.
#[derive(Clone, Debug)]
pub struct PermutePipeline {
    /// Tile-engine worker threads (0 = available parallelism). Output is
    /// bit-identical for any worker count.
    pub workers: usize,
    /// Enforce the never-worse guard (paper §4.1). Disable only for timing
    /// studies that must not trigger fallback re-runs.
    pub guard: bool,
}

impl Default for PermutePipeline {
    fn default() -> Self {
        Self { workers: 0, guard: true }
    }
}

impl PermutePipeline {
    /// Pipeline with an explicit tile-engine worker count (guard on).
    pub fn with_workers(workers: usize) -> Self {
        Self { workers, ..Self::default() }
    }

    /// Run one layer through `ocp` then column-wise vector pruning then
    /// per-tile `icp`, and pack. Guarantees (with `guard`) that the returned
    /// retention is never below the unpermuted HiNM baseline.
    pub fn run(
        &self,
        ocp: &dyn OcpStrategy,
        icp: &dyn IcpStrategy,
        w: &Matrix,
        sal: &Matrix,
        cfg: &HinmConfig,
    ) -> PermuteOutcome {
        cfg.validate(w.rows, w.cols).expect("invalid config");
        assert_eq!(w.shape(), sal.shape());

        let outcome = self.run_once(ocp, icp, w, sal, cfg);
        if !self.guard || (ocp.is_identity() && icp.is_identity()) {
            return outcome;
        }

        // --- Never-worse guard (hierarchical pruning awareness, §4.1):
        // OCP optimizes the *vector-level* objective (Eq. 2), which on rare
        // inputs lowers the final hierarchical retention below the
        // unpermuted baseline (elements it consolidates get re-pruned by
        // 2:4). Keep whichever arrangement retains more — permutation must
        // never hurt. ---
        let baseline = hinm_retained(sal, cfg);
        if outcome.result.retained >= baseline {
            return outcome;
        }
        // Fallback 1: drop the OCP, keep the ICP (the legacy gyro fallback).
        let best = if ocp.is_identity() {
            outcome
        } else {
            let fallback = self.run_once(&IdentityOcp, icp, w, sal, cfg);
            if fallback.result.retained >= outcome.result.retained { fallback } else { outcome }
        };
        if best.result.retained >= baseline || icp.is_identity() {
            return best;
        }
        // Fallback 2: a non-monotone ICP (Apex's escape moves) can leave
        // even the identity-OCP arrangement below the baseline; finish at
        // plain HiNM.
        let noperm = self.run_once(&IdentityOcp, &IdentityIcp, w, sal, cfg);
        if noperm.result.retained > best.result.retained {
            noperm
        } else {
            best
        }
    }

    fn run_once(
        &self,
        ocp: &dyn OcpStrategy,
        icp: &dyn IcpStrategy,
        w: &Matrix,
        sal: &Matrix,
        cfg: &HinmConfig,
    ) -> PermuteOutcome {
        // --- Phase 1: output-channel permutation (Eq. 2). ---
        let OcpOutcome { perm: ocp_perm, retained: ocp_retained } = ocp.permute(sal, cfg);
        debug_assert!(is_permutation(&ocp_perm, w.rows), "{} returned a non-permutation", ocp.key());
        let w_p: Matrix;
        let sal_p: Matrix;
        let (w_eff, sal_eff) = if ocp.is_identity() {
            (w, sal)
        } else {
            w_p = w.permute_rows(&ocp_perm);
            sal_p = sal.permute_rows(&ocp_perm);
            (&w_p, &sal_p)
        };

        // --- Phase 2: column-wise vector pruning on the permuted layout. ---
        let vp = vector_prune(sal_eff, cfg);

        // --- Phase 3: tile-wise ICP (Eq. 3), tiles independent. ---
        let (tile_orders, icp_stats) = self.order_tiles(icp, sal_eff, &vp, cfg);

        // --- Phase 4: pack with the permuted kept-column grouping. ---
        let result = prune_with_kept(w_eff, sal_eff, cfg, &vp, Some(&tile_orders));
        PermuteOutcome { ocp_perm, tile_orders, result, ocp_retained, icp_stats }
    }

    /// The parallel tile engine. Tiles are claimed off an atomic counter by
    /// `workers` scoped threads; each worker owns one reusable column-major
    /// scratch buffer for gathers. Per-tile results are written back by tile
    /// index, and every strategy seeds from `(seed, tile)` — so the packed
    /// output is bit-identical for any worker count.
    fn order_tiles(
        &self,
        icp: &dyn IcpStrategy,
        sal_p: &Matrix,
        vp: &VectorPruneResult,
        cfg: &HinmConfig,
    ) -> (Vec<Vec<usize>>, Vec<(usize, usize)>) {
        let tiles = vp.kept.len();
        let k_v = vp.kept[0].len();
        if icp.is_identity() {
            return ((0..tiles).map(|_| (0..k_v).collect()).collect(), vec![(0, 0); tiles]);
        }
        let workers = resolve_workers(self.workers).min(tiles).max(1);

        if workers == 1 {
            let mut scratch = vec![0.0f32; cfg.v * k_v];
            let mut orders = Vec::with_capacity(tiles);
            let mut stats = Vec::with_capacity(tiles);
            for t in 0..tiles {
                let (o, s) = order_one_tile(icp, sal_p, &vp.kept[t], cfg, t, &mut scratch);
                orders.push(o);
                stats.push(s);
            }
            return (orders, stats);
        }

        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, Vec<usize>, (usize, usize))>();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let next = &next;
                let kept = &vp.kept;
                scope.spawn(move || {
                    let mut scratch = vec![0.0f32; cfg.v * k_v];
                    loop {
                        let t = next.fetch_add(1, Ordering::Relaxed);
                        if t >= tiles {
                            break;
                        }
                        let (o, s) = order_one_tile(icp, sal_p, &kept[t], cfg, t, &mut scratch);
                        if tx.send((t, o, s)).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx);
            let mut orders: Vec<Option<Vec<usize>>> = (0..tiles).map(|_| None).collect();
            let mut stats = vec![(0usize, 0usize); tiles];
            for (t, o, s) in rx {
                orders[t] = Some(o);
                stats[t] = s;
            }
            (
                orders.into_iter().map(|o| o.expect("tile worker died")).collect(),
                stats,
            )
        })
    }
}

fn resolve_workers(workers: usize) -> usize {
    if workers > 0 {
        workers
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }
}

fn order_one_tile(
    icp: &dyn IcpStrategy,
    sal_p: &Matrix,
    kept: &[usize],
    cfg: &HinmConfig,
    t: usize,
    scratch: &mut Vec<f32>,
) -> (Vec<usize>, (usize, usize)) {
    let k = kept.len();
    scratch.resize(cfg.v * k, 0.0);
    gather_tile_colmajor(sal_p, cfg, t, kept, &mut scratch[..cfg.v * k]);
    let view = TileCols::new(&scratch[..cfg.v * k], cfg.v, k);
    let out = icp.order_tile(&view, cfg, t);
    debug_assert!(is_permutation(&out.order, k), "{} returned a non-permutation", icp.key());
    (out.order, (out.iters_run, out.accepted))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::hinm::prune_oneshot;
    use crate::util::rng::Xoshiro256;

    fn mixed(m: usize, n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Xoshiro256::new(seed);
        let row_scale: Vec<f32> = (0..m).map(|_| if rng.next_f32() < 0.3 { 3.0 } else { 0.3 }).collect();
        let col_scale: Vec<f32> = (0..n).map(|_| if rng.next_f32() < 0.3 { 3.0 } else { 0.3 }).collect();
        let w = Matrix::from_fn(m, n, |r, c| rng.normal() * row_scale[r] * col_scale[c]);
        let sal = w.abs();
        (w, sal)
    }

    #[test]
    fn spec_parse_legacy_and_pairs() {
        assert_eq!(StrategySpec::parse("gyro"), Some(StrategySpec::new("gyro", "gyro")));
        assert_eq!(StrategySpec::parse("noperm"), Some(StrategySpec::new("id", "id")));
        assert_eq!(StrategySpec::parse("v1"), Some(StrategySpec::new("ovw", "gyro")));
        assert_eq!(StrategySpec::parse("v2"), Some(StrategySpec::new("gyro", "apex")));
        assert_eq!(StrategySpec::parse("gyro+tetris"), Some(StrategySpec::new("gyro", "tetris")));
        assert_eq!(StrategySpec::parse("ovw+apex"), Some(StrategySpec::new("ovw", "apex")));
        assert_eq!(StrategySpec::parse("identity+gyro"), Some(StrategySpec::new("id", "gyro")));
        assert_eq!(StrategySpec::parse("bogus"), None);
        assert_eq!(StrategySpec::parse("gyro+bogus"), None);
    }

    #[test]
    fn spec_labels_match_paper_arms() {
        assert_eq!(StrategySpec::parse("gyro").unwrap().label(), "HiNM");
        assert_eq!(StrategySpec::parse("noperm").unwrap().label(), "HiNM-NoPerm");
        assert_eq!(StrategySpec::parse("v1").unwrap().label(), "HiNM-V1");
        assert_eq!(StrategySpec::parse("v2").unwrap().label(), "HiNM-V2");
        assert_eq!(StrategySpec::parse("ovw+tetris").unwrap().label(), "HiNM[ovw+tetris]");
    }

    #[test]
    fn registry_lists_all_builtin_keys() {
        let reg = StrategyRegistry::builtin();
        assert_eq!(reg.ocp_keys(), vec!["gyro", "id", "ovw"]);
        assert_eq!(reg.icp_keys(), vec!["apex", "gyro", "id", "tetris"]);
        let params = StrategyParams::default();
        for o in reg.ocp_keys() {
            for i in reg.icp_keys() {
                let (os, is) = reg.build(&StrategySpec::new(o, i), &params).unwrap();
                assert_eq!(os.key(), o);
                assert_eq!(is.key(), i);
            }
        }
    }

    #[test]
    fn identity_pair_equals_plain_oneshot() {
        let (w, sal) = mixed(16, 32, 45);
        let cfg = HinmConfig::with_24(4, 0.5);
        let out = PermutePipeline::default().run(&IdentityOcp, &IdentityIcp, &w, &sal, &cfg);
        let noperm = prune_oneshot(&w, &sal, &cfg);
        assert!((out.result.retained - noperm.retained).abs() < 1e-9);
        assert_eq!(out.result.packed, noperm.packed);
        assert_eq!(out.ocp_perm, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn tetris_icp_improves_adversarial_tile() {
        // Natural order puts 4 hot then 4 cold columns in separate groups;
        // a correct swap search interleaves them 2/2.
        let v = 4;
        let cfg = HinmConfig::with_24(v, 0.0);
        let mut data = Vec::new();
        for j in 0..8 {
            let val = if j < 4 { 5.0 } else { 0.1 };
            data.extend(std::iter::repeat(val).take(v));
        }
        let view = TileCols::new(&data, v, 8);
        let out = TetrisIcp::default().order_tile(&view, &cfg, 0);
        assert!(is_permutation(&out.order, 8));
        let hot0 = out.order[..4].iter().filter(|&&j| j < 4).count();
        assert_eq!(hot0, 2, "order={:?}", out.order);
        assert!(out.accepted > 0);
    }

    #[test]
    fn every_strategy_pair_never_below_noperm() {
        let (w, sal) = mixed(16, 32, 46);
        let cfg = HinmConfig::with_24(8, 0.5);
        let noperm = prune_oneshot(&w, &sal, &cfg).retained;
        let reg = StrategyRegistry::builtin();
        let params = StrategyParams::default();
        for o in reg.ocp_keys() {
            for i in reg.icp_keys() {
                let (os, is) = reg.build(&StrategySpec::new(o, i), &params).unwrap();
                let out = PermutePipeline::default().run(os.as_ref(), is.as_ref(), &w, &sal, &cfg);
                assert!(
                    out.result.retained >= noperm - 1e-6,
                    "{o}+{i}: {} < noperm {noperm}",
                    out.result.retained
                );
                assert!(is_permutation(&out.ocp_perm, 16), "{o}+{i}");
                for ord in &out.tile_orders {
                    assert!(is_permutation(ord, out.result.packed.k_v), "{o}+{i}");
                }
                out.result.packed.check_invariants().unwrap();
            }
        }
    }

    #[test]
    fn worker_count_does_not_change_output() {
        let (w, sal) = mixed(32, 64, 47);
        let cfg = HinmConfig::with_24(4, 0.5); // 8 tiles
        let a = PermutePipeline::with_workers(1).run(
            &GyroOcp::default(),
            &GyroIcp::default(),
            &w,
            &sal,
            &cfg,
        );
        let b = PermutePipeline::with_workers(4).run(
            &GyroOcp::default(),
            &GyroIcp::default(),
            &w,
            &sal,
            &cfg,
        );
        assert_eq!(a.tile_orders, b.tile_orders);
        assert_eq!(a.result.packed, b.result.packed);
    }
}
