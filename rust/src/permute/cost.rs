//! Eq. 4 cost primitives for the gyro **assignment** phase.
//!
//! `C[i][j] = Σρ − ‖M ⊙ ρ‖` over `P_i ∪ s_j`: the saliency lost to pruning
//! when sample/cluster `j` joins partition `i`. Because each cluster is used
//! exactly once in a perfect assignment, the `Σρ` terms are constant across
//! assignments, so the solver can equivalently minimize `−retained`; the
//! helpers here therefore return *retained saliency* and the callers negate.

use crate::sparsity::config::HinmConfig;

/// Sum of the `k` largest values (selection in O(n)).
pub fn sum_top_k(vals: &[f64], k: usize) -> f64 {
    debug_assert!(k <= vals.len());
    if k == 0 {
        return 0.0;
    }
    if k == vals.len() {
        return vals.iter().sum();
    }
    let mut buf: Vec<f64> = vals.to_vec();
    // nth element so that [0..k) are the k largest (descending comparator).
    buf.select_nth_unstable_by(k - 1, |a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    buf[..k].iter().sum()
}

/// OCP: retained saliency of a candidate partition whose per-column vector
/// saliency is `rem_colsum + cluster_colsum`, keeping the top `k_v` columns
/// (Eq. 2 objective restricted to one partition).
pub fn ocp_partition_retained(rem_colsum: &[f64], cluster_colsum: &[f64], k_v: usize, scratch: &mut Vec<f64>) -> f64 {
    debug_assert_eq!(rem_colsum.len(), cluster_colsum.len());
    scratch.clear();
    scratch.extend(rem_colsum.iter().zip(cluster_colsum).map(|(&a, &b)| a + b));
    sum_top_k(scratch, k_v)
}

/// HiNM-aware OCP cost (extension, DESIGN §7): retained after *both* levels —
/// top-`k_v` columns then 2:4 across those columns per row. `rows` holds the
/// V member-channel saliency rows (each of length n) of remainder ∪ cluster.
pub fn ocp_partition_retained_hinm(
    rows: &[&[f32]],
    k_v: usize,
    cfg: &HinmConfig,
    colsum_scratch: &mut Vec<f64>,
) -> f64 {
    let n = rows[0].len();
    colsum_scratch.clear();
    colsum_scratch.resize(n, 0.0);
    for row in rows {
        for (acc, &s) in colsum_scratch.iter_mut().zip(row.iter()) {
            *acc += s as f64;
        }
    }
    // Select kept columns (top-k_v by vector saliency), ascending ids.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        colsum_scratch[b]
            .partial_cmp(&colsum_scratch[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut kept: Vec<usize> = idx[..k_v].to_vec();
    kept.sort_unstable();
    // 2:4 on the compacted rows.
    let mut retained = 0.0f64;
    let m = cfg.m_group;
    let nk = cfg.n_keep;
    let mut grp: Vec<f64> = vec![0.0; m];
    for row in rows {
        for gcols in kept.chunks_exact(m) {
            for (g, &c) in grp.iter_mut().zip(gcols) {
                *g = row[c] as f64;
            }
            retained += sum_top_k(&grp, nk);
        }
    }
    retained
}

/// ICP: retained saliency of a group of `M` column vectors (each of height V,
/// column-major contiguous) under N:M row pruning. `cols` are the M member
/// vectors of remainder ∪ sample.
pub fn icp_group_retained(cols: &[&[f32]], v: usize, cfg: &HinmConfig) -> f64 {
    debug_assert_eq!(cols.len(), cfg.m_group);
    debug_assert!(cols.iter().all(|c| c.len() == v));
    let mut retained = 0.0f64;
    if cfg.m_group == 4 && cfg.n_keep == 2 {
        let (c0, c1, c2, c3) = (cols[0], cols[1], cols[2], cols[3]);
        for r in 0..v {
            let (a, b, c, d) = (c0[r], c1[r], c2[r], c3[r]);
            let (lo1, hi1) = if a < b { (a, b) } else { (b, a) };
            let (lo2, hi2) = if c < d { (c, d) } else { (d, c) };
            let smallest = if lo1 < lo2 { lo1 } else { lo2 };
            let second = if lo1 < lo2 {
                if lo2 < hi1 { lo2 } else { hi1 }
            } else if lo1 < hi2 {
                lo1
            } else {
                hi2
            };
            retained += (a + b + c + d - smallest - second) as f64;
        }
    } else {
        let mut grp = vec![0.0f64; cfg.m_group];
        for r in 0..v {
            for (g, col) in grp.iter_mut().zip(cols) {
                *g = col[r] as f64;
            }
            retained += sum_top_k(&grp, cfg.n_keep);
        }
    }
    retained
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_selection() {
        assert_eq!(sum_top_k(&[1.0, 5.0, 3.0, 2.0], 2), 8.0);
        assert_eq!(sum_top_k(&[1.0, 5.0], 0), 0.0);
        assert_eq!(sum_top_k(&[1.0, 5.0], 2), 6.0);
        assert_eq!(sum_top_k(&[-1.0, -5.0, -3.0], 1), -1.0);
    }

    #[test]
    fn ocp_retained_adds_and_selects() {
        let rem = vec![1.0, 0.0, 5.0, 0.0];
        let clu = vec![1.0, 4.0, 0.0, 0.0];
        let mut scratch = Vec::new();
        // combined = [2,4,5,0]; top-2 = 9
        assert_eq!(ocp_partition_retained(&rem, &clu, 2, &mut scratch), 9.0);
    }

    #[test]
    fn icp_group_24_picks_row_top2() {
        let cfg = HinmConfig::with_24(4, 0.0);
        let c0 = vec![9.0f32, 1.0];
        let c1 = vec![8.0f32, 2.0];
        let c2 = vec![1.0f32, 3.0];
        let c3 = vec![2.0f32, 4.0];
        let got = icp_group_retained(&[&c0, &c1, &c2, &c3], 2, &cfg);
        assert_eq!(got, (9.0 + 8.0 + 3.0 + 4.0) as f64);
    }

    #[test]
    fn icp_group_generic_nm() {
        let cfg = HinmConfig { v: 1, n_keep: 1, m_group: 3, vector_sparsity: 0.0 };
        let c0 = vec![5.0f32];
        let c1 = vec![7.0f32];
        let c2 = vec![1.0f32];
        assert_eq!(icp_group_retained(&[&c0, &c1, &c2], 1, &cfg), 7.0);
    }

    #[test]
    fn hinm_aware_cost_lower_than_vector_only() {
        // After 2:4, retained ≤ vector-level retained.
        let cfg = HinmConfig::with_24(2, 0.5);
        let r0: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let r1: Vec<f32> = vec![8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0];
        let rows: Vec<&[f32]> = vec![&r0, &r1];
        let mut scratch = Vec::new();
        let hinm = ocp_partition_retained_hinm(&rows, 4, &cfg, &mut scratch);
        let colsum: Vec<f64> = (0..8).map(|c| (r0[c] + r1[c]) as f64).collect();
        let vec_only = sum_top_k(&colsum, 4);
        assert!(hinm <= vec_only + 1e-9, "{hinm} vs {vec_only}");
        assert!(hinm > 0.0);
    }
}
