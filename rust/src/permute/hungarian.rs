//! Hungarian algorithm (Kuhn–Munkres) for minimum-cost perfect assignment.
//!
//! Used by the gyro-permutation **assignment** phase (paper §4.2): after
//! sampling and clustering, the P samples/clusters must be re-assigned to
//! the P partitions minimizing total pruning loss (Eq. 4). This is the
//! O(n³) shortest-augmenting-path formulation (Jonker–Volgenant style
//! potentials) — exact, no approximation.

/// Solve min-cost assignment on a square cost matrix `cost[i][j]` (cost of
/// assigning *column/worker* `j` to *row/task* `i`). Returns `assign` with
/// `assign[i] = j` and the total cost.
pub fn solve(cost: &[Vec<f64>]) -> (Vec<usize>, f64) {
    let n = cost.len();
    assert!(n > 0, "empty cost matrix");
    for row in cost {
        assert_eq!(row.len(), n, "cost matrix must be square");
    }
    // Potentials u (rows), v (cols); way[j] = previous column on the
    // augmenting path; matching p[j] = row assigned to column j.
    // 1-indexed internally per the classical formulation.
    const INF: f64 = f64::INFINITY;
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[j] = row matched to col j (0 = none)
    let mut way = vec![0usize; n + 1];

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // Augment along the path.
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    let mut assign = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            assign[p[j] - 1] = j - 1;
        }
    }
    let total: f64 = (0..n).map(|i| cost[i][assign[i]]).sum();
    (assign, total)
}

/// Brute-force solver for testing (n ≤ 9).
#[cfg(test)]
pub fn brute_force(cost: &[Vec<f64>]) -> f64 {
    let n = cost.len();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut best = f64::INFINITY;
    permute_all(&mut perm, 0, &mut |p| {
        let c: f64 = (0..n).map(|i| cost[i][p[i]]).sum();
        if c < best {
            best = c;
        }
    });
    best
}

#[cfg(test)]
fn permute_all(perm: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
    if k == perm.len() {
        f(perm);
        return;
    }
    for i in k..perm.len() {
        perm.swap(k, i);
        permute_all(perm, k + 1, f);
        perm.swap(k, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn trivial_identity() {
        let cost = vec![vec![0.0, 1.0], vec![1.0, 0.0]];
        let (assign, total) = solve(&cost);
        assert_eq!(assign, vec![0, 1]);
        assert_eq!(total, 0.0);
    }

    #[test]
    fn forced_swap() {
        let cost = vec![vec![10.0, 1.0], vec![1.0, 10.0]];
        let (assign, total) = solve(&cost);
        assert_eq!(assign, vec![1, 0]);
        assert_eq!(total, 2.0);
    }

    #[test]
    fn classic_example() {
        // Known optimum 140: (0→1? ...) classic 4x4.
        let cost = vec![
            vec![82.0, 83.0, 69.0, 92.0],
            vec![77.0, 37.0, 49.0, 92.0],
            vec![11.0, 69.0, 5.0, 86.0],
            vec![8.0, 9.0, 98.0, 23.0],
        ];
        let (_, total) = solve(&cost);
        assert_eq!(total, 140.0);
    }

    #[test]
    fn matches_brute_force_random() {
        let mut rng = Xoshiro256::new(77);
        for n in 2..=7 {
            for _ in 0..20 {
                let cost: Vec<Vec<f64>> = (0..n)
                    .map(|_| (0..n).map(|_| (rng.next_f64() * 100.0).round()).collect())
                    .collect();
                let (assign, total) = solve(&cost);
                // assignment is a permutation
                let mut seen = vec![false; n];
                for &j in &assign {
                    assert!(!seen[j]);
                    seen[j] = true;
                }
                let bf = brute_force(&cost);
                assert!(
                    (total - bf).abs() < 1e-9,
                    "n={n}: hungarian={total} brute={bf} cost={cost:?}"
                );
            }
        }
    }

    #[test]
    fn handles_negative_costs() {
        let cost = vec![vec![-5.0, 0.0], vec![0.0, -5.0]];
        let (assign, total) = solve(&cost);
        assert_eq!(assign, vec![0, 1]);
        assert_eq!(total, -10.0);
    }

    #[test]
    fn large_instance_is_fast_and_valid() {
        let mut rng = Xoshiro256::new(78);
        let n = 128;
        let cost: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..n).map(|_| rng.next_f64()).collect())
            .collect();
        let t0 = std::time::Instant::now();
        let (assign, total) = solve(&cost);
        assert!(t0.elapsed().as_millis() < 2_000);
        let mut seen = vec![false; n];
        for &j in &assign {
            assert!(!seen[j]);
            seen[j] = true;
        }
        // Optimal total must beat identity and a random permutation.
        let id: f64 = (0..n).map(|i| cost[i][i]).sum();
        assert!(total <= id);
    }
}
