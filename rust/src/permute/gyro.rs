//! The gyro-permutation entry point for one layer (paper §4):
//! OCP → column-wise vector pruning → per-tile ICP → N:M packing.
//!
//! Since the strategy-layer refactor this is a thin wrapper over
//! [`PermutePipeline`] with the gyro OCP/ICP strategies — the phase
//! sequence, the parallel tile engine, and the never-worse guard all live in
//! [`super::strategy`] and are shared by every method in the registry.

use super::icp::IcpParams;
use super::ocp::OcpParams;
use super::strategy::{GyroIcp, GyroOcp, IcpStrategy, IdentityIcp, IdentityOcp, OcpStrategy, PermutePipeline};
use crate::sparsity::config::HinmConfig;
use crate::tensor::Matrix;

/// Outcome of the gyro run — the strategy layer's [`PermuteOutcome`]
/// (re-exported under the legacy name; the fields are identical).
///
/// [`PermuteOutcome`]: super::strategy::PermuteOutcome
pub use super::strategy::PermuteOutcome as GyroOutcome;

#[derive(Clone, Debug, Default)]
/// Combined OCP + ICP configuration for the full gyro run.
pub struct GyroParams {
    /// Output-channel-permutation (vector level) parameters.
    pub ocp: OcpParams,
    /// Intra-channel-permutation (N:M level) parameters.
    pub icp: IcpParams,
    /// Skip OCP (ablation arms that replace it).
    pub skip_ocp: bool,
    /// Skip ICP.
    pub skip_icp: bool,
}

/// Run gyro-permutation + HiNM pruning on one layer.
///
/// `w` and `sal` are the dense weights and their saliency; the returned
/// packed matrix stores rows in *permuted* order — callers fold `ocp_perm`
/// into neighbouring layers offline (the paper's consistency argument).
pub fn gyro_permute_and_prune(
    w: &Matrix,
    sal: &Matrix,
    cfg: &HinmConfig,
    params: &GyroParams,
) -> GyroOutcome {
    let ocp: Box<dyn OcpStrategy> = if params.skip_ocp {
        Box::new(IdentityOcp)
    } else {
        Box::new(GyroOcp { params: params.ocp.clone() })
    };
    let icp: Box<dyn IcpStrategy> = if params.skip_icp {
        Box::new(IdentityIcp)
    } else {
        Box::new(GyroIcp { params: params.icp.clone() })
    };
    PermutePipeline::default().run(ocp.as_ref(), icp.as_ref(), w, sal, cfg)
}

/// Convenience: HiNM retention ratio with and without gyro, for quick A/B.
pub fn retention_gain(w: &Matrix, sal: &Matrix, cfg: &HinmConfig, params: &GyroParams) -> (f64, f64) {
    let noperm = crate::sparsity::hinm::prune_oneshot(w, sal, cfg);
    let gyro = gyro_permute_and_prune(w, sal, cfg, params);
    (noperm.retention_ratio, gyro.result.retention_ratio)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn mixed_importance(m: usize, n: usize, seed: u64) -> (Matrix, Matrix) {
        // Heavy-tailed weights: some channels/columns far more important.
        let mut rng = Xoshiro256::new(seed);
        let row_scale: Vec<f32> = (0..m).map(|_| if rng.next_f32() < 0.3 { 3.0 } else { 0.3 }).collect();
        let col_scale: Vec<f32> = (0..n).map(|_| if rng.next_f32() < 0.3 { 3.0 } else { 0.3 }).collect();
        let w = Matrix::from_fn(m, n, |r, c| rng.normal() * row_scale[r] * col_scale[c]);
        let sal = w.abs();
        (w, sal)
    }

    #[test]
    fn gyro_beats_noperm_on_heterogeneous_layers() {
        let (w, sal) = mixed_importance(32, 64, 42);
        let cfg = HinmConfig::with_24(8, 0.5);
        let (noperm, gyro) = retention_gain(&w, &sal, &cfg, &GyroParams::default());
        assert!(
            gyro > noperm,
            "gyro retention {gyro} should beat no-perm {noperm}"
        );
    }

    #[test]
    fn packed_layer_valid_and_correct_density() {
        let (w, sal) = mixed_importance(32, 64, 43);
        let cfg = HinmConfig::with_24(8, 0.5);
        let out = gyro_permute_and_prune(&w, &sal, &cfg, &GyroParams::default());
        out.result.packed.check_invariants().unwrap();
        assert!((out.result.mask.sparsity() - cfg.total_sparsity()).abs() < 0.02);
        assert!(crate::tensor::is_permutation(&out.ocp_perm, 32));
        for ord in &out.tile_orders {
            assert!(crate::tensor::is_permutation(ord, out.result.packed.k_v));
        }
    }

    #[test]
    fn dense_reconstruction_matches_permuted_weights() {
        let (w, sal) = mixed_importance(16, 32, 44);
        let cfg = HinmConfig::with_24(4, 0.5);
        let out = gyro_permute_and_prune(&w, &sal, &cfg, &GyroParams::default());
        let w_p = w.permute_rows(&out.ocp_perm);
        let dense = out.result.packed.to_dense();
        // Every kept value equals the corresponding permuted weight.
        for r in 0..16 {
            for c in 0..32 {
                let d = dense.at(r, c);
                if d != 0.0 {
                    assert_eq!(d, w_p.at(r, c));
                }
            }
        }
    }

    #[test]
    fn skip_flags_disable_phases() {
        let (w, sal) = mixed_importance(16, 32, 45);
        let cfg = HinmConfig::with_24(4, 0.5);
        let out = gyro_permute_and_prune(
            &w,
            &sal,
            &cfg,
            &GyroParams { skip_ocp: true, skip_icp: true, ..Default::default() },
        );
        assert_eq!(out.ocp_perm, (0..16).collect::<Vec<_>>());
        assert!(out.tile_orders.iter().all(|o| o.iter().enumerate().all(|(i, &x)| i == x)));
        // With both phases off this must equal plain one-shot HiNM.
        let noperm = crate::sparsity::hinm::prune_oneshot(&w, &sal, &cfg);
        assert!((out.result.retained - noperm.retained).abs() < 1e-9);
    }

    #[test]
    fn ocp_and_icp_contribute_independently() {
        let (w, sal) = mixed_importance(32, 64, 46);
        let cfg = HinmConfig::with_24(8, 0.5);
        let full = gyro_permute_and_prune(&w, &sal, &cfg, &GyroParams::default());
        let no_icp = gyro_permute_and_prune(
            &w,
            &sal,
            &cfg,
            &GyroParams { skip_icp: true, ..Default::default() },
        );
        let no_ocp = gyro_permute_and_prune(
            &w,
            &sal,
            &cfg,
            &GyroParams { skip_ocp: true, ..Default::default() },
        );
        assert!(full.result.retained >= no_icp.result.retained - 1e-9);
        assert!(full.result.retained >= no_ocp.result.retained * 0.999);
    }

    #[test]
    fn never_worse_guard_holds_on_random_inputs() {
        // The guard lives in PermutePipeline now; pin the wrapper-level
        // behaviour the old in-function fallback provided.
        let mut rng = Xoshiro256::new(48);
        for case in 0..6 {
            let w = Matrix::from_fn(16, 32, |_, _| rng.normal());
            let sal = w.abs();
            let cfg = HinmConfig::with_24(4, 0.5);
            let noperm = crate::sparsity::hinm::prune_oneshot(&w, &sal, &cfg).retained;
            let out = gyro_permute_and_prune(&w, &sal, &cfg, &GyroParams::default());
            assert!(
                out.result.retained >= noperm - 1e-6,
                "case {case}: gyro {} < noperm {noperm}",
                out.result.retained
            );
        }
    }
}
