//! The full gyro-permutation pipeline for one layer (paper §4):
//! OCP → column-wise vector pruning → per-tile ICP → N:M packing.

use super::icp::{gyro_icp, IcpParams, IcpResult};
use super::ocp::{gyro_ocp, OcpParams};
use crate::sparsity::config::HinmConfig;
use crate::sparsity::hinm::{gather_tile, prune_with_kept, HinmResult};
use crate::sparsity::vector_prune::vector_prune;
use crate::tensor::Matrix;

#[derive(Clone, Debug, Default)]
pub struct GyroParams {
    pub ocp: OcpParams,
    pub icp: IcpParams,
    /// Skip OCP (ablation arms that replace it).
    pub skip_ocp: bool,
    /// Skip ICP.
    pub skip_icp: bool,
}

#[derive(Clone, Debug)]
pub struct GyroOutcome {
    /// Output-channel permutation applied to rows (offline; folded into the
    /// adjacent layers, see paper §3.2).
    pub ocp_perm: Vec<usize>,
    /// Per-tile orders over kept columns (consumed by the runtime gather).
    pub tile_orders: Vec<Vec<usize>>,
    /// Final packed layer + retention stats.
    pub result: HinmResult,
    /// Eq. 2 retention after OCP only.
    pub ocp_retained: f64,
    /// ICP iteration stats per tile.
    pub icp_stats: Vec<(usize, usize)>, // (iters_run, accepted)
}

/// Run gyro-permutation + HiNM pruning on one layer.
///
/// `w` and `sal` are the dense weights and their saliency; the returned
/// packed matrix stores rows in *permuted* order — callers fold `ocp_perm`
/// into neighbouring layers offline (the paper's consistency argument).
pub fn gyro_permute_and_prune(
    w: &Matrix,
    sal: &Matrix,
    cfg: &HinmConfig,
    params: &GyroParams,
) -> GyroOutcome {
    cfg.validate(w.rows, w.cols).expect("invalid config");
    assert_eq!(w.shape(), sal.shape());

    // --- Phase 1: output-channel permutation (Eq. 2). ---
    let (ocp_perm, ocp_retained) = if params.skip_ocp {
        ((0..w.rows).collect::<Vec<_>>(), f64::NAN)
    } else {
        let r = gyro_ocp(sal, cfg, &params.ocp);
        (r.perm, r.retained)
    };
    let w_p = w.permute_rows(&ocp_perm);
    let sal_p = sal.permute_rows(&ocp_perm);

    // --- Phase 2: column-wise vector pruning on the permuted layout. ---
    let vp = vector_prune(&sal_p, cfg);
    let k_v = vp.kept[0].len();

    // --- Phase 3: tile-wise ICP (Eq. 3), tiles independent. ---
    let tiles = cfg.tiles(w.rows);
    let mut tile_orders: Vec<Vec<usize>> = Vec::with_capacity(tiles);
    let mut icp_stats = Vec::with_capacity(tiles);
    let mut buf = vec![0.0f32; cfg.v * k_v];
    for t in 0..tiles {
        if params.skip_icp {
            tile_orders.push((0..k_v).collect());
            icp_stats.push((0, 0));
            continue;
        }
        gather_tile(&sal_p, cfg, t, &vp.kept[t], &mut buf);
        // Column-major copy for the ICP cost kernels.
        let cols: Vec<Vec<f32>> = (0..k_v)
            .map(|j| (0..cfg.v).map(|r| buf[r * k_v + j]).collect())
            .collect();
        let icp_params = IcpParams {
            seed: params.icp.seed ^ (t as u64).wrapping_mul(0x9E37_79B9),
            ..params.icp.clone()
        };
        let IcpResult { order, iters_run, accepted, .. } = gyro_icp(&cols, cfg.v, cfg, &icp_params);
        tile_orders.push(order);
        icp_stats.push((iters_run, accepted));
    }

    // --- Phase 4: pack with the permuted kept-column grouping. ---
    let result = prune_with_kept(&w_p, &sal_p, cfg, &vp, Some(&tile_orders));

    // --- Never-worse guard (hierarchical pruning awareness, paper §4.1):
    // OCP optimizes the *vector-level* objective (Eq. 2), which on rare
    // inputs lowers the final hierarchical retention below the unpermuted
    // baseline (elements it consolidates get re-pruned by 2:4). Gyro keeps
    // whichever arrangement retains more — permutation must never hurt. ---
    let baseline = crate::sparsity::hinm::hinm_retained(sal, cfg);
    if result.retained < baseline {
        let id_perm: Vec<usize> = (0..w.rows).collect();
        let vp0 = vector_prune(sal, cfg);
        let k_v0 = vp0.kept[0].len();
        let mut id_orders: Vec<Vec<usize>> = Vec::with_capacity(vp0.kept.len());
        let mut stats = Vec::with_capacity(vp0.kept.len());
        let tiles = cfg.tiles(w.rows);
        let mut buf0 = vec![0.0f32; cfg.v * k_v0];
        for t in 0..tiles {
            // Re-run ICP alone on the unpermuted layout (ICP is always
            // monotone w.r.t. the final objective).
            if params.skip_icp {
                id_orders.push((0..k_v0).collect());
                stats.push((0, 0));
                continue;
            }
            gather_tile(sal, cfg, t, &vp0.kept[t], &mut buf0);
            let cols: Vec<Vec<f32>> = (0..k_v0)
                .map(|j| (0..cfg.v).map(|r| buf0[r * k_v0 + j]).collect())
                .collect();
            let icp_params = IcpParams {
                seed: params.icp.seed ^ (t as u64).wrapping_mul(0x517C_C1B7),
                ..params.icp.clone()
            };
            let res = gyro_icp(&cols, cfg.v, cfg, &icp_params);
            stats.push((res.iters_run, res.accepted));
            id_orders.push(res.order);
        }
        let fallback = prune_with_kept(w, sal, cfg, &vp0, Some(&id_orders));
        if fallback.retained >= result.retained {
            return GyroOutcome {
                ocp_perm: id_perm,
                tile_orders: id_orders,
                result: fallback,
                ocp_retained,
                icp_stats: stats,
            };
        }
    }

    GyroOutcome { ocp_perm, tile_orders, result, ocp_retained, icp_stats }
}

/// Convenience: HiNM retention ratio with and without gyro, for quick A/B.
pub fn retention_gain(w: &Matrix, sal: &Matrix, cfg: &HinmConfig, params: &GyroParams) -> (f64, f64) {
    let noperm = crate::sparsity::hinm::prune_oneshot(w, sal, cfg);
    let gyro = gyro_permute_and_prune(w, sal, cfg, params);
    (noperm.retention_ratio, gyro.result.retention_ratio)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn mixed_importance(m: usize, n: usize, seed: u64) -> (Matrix, Matrix) {
        // Heavy-tailed weights: some channels/columns far more important.
        let mut rng = Xoshiro256::new(seed);
        let row_scale: Vec<f32> = (0..m).map(|_| if rng.next_f32() < 0.3 { 3.0 } else { 0.3 }).collect();
        let col_scale: Vec<f32> = (0..n).map(|_| if rng.next_f32() < 0.3 { 3.0 } else { 0.3 }).collect();
        let w = Matrix::from_fn(m, n, |r, c| rng.normal() * row_scale[r] * col_scale[c]);
        let sal = w.abs();
        (w, sal)
    }

    #[test]
    fn gyro_beats_noperm_on_heterogeneous_layers() {
        let (w, sal) = mixed_importance(32, 64, 42);
        let cfg = HinmConfig::with_24(8, 0.5);
        let (noperm, gyro) = retention_gain(&w, &sal, &cfg, &GyroParams::default());
        assert!(
            gyro > noperm,
            "gyro retention {gyro} should beat no-perm {noperm}"
        );
    }

    #[test]
    fn packed_layer_valid_and_correct_density() {
        let (w, sal) = mixed_importance(32, 64, 43);
        let cfg = HinmConfig::with_24(8, 0.5);
        let out = gyro_permute_and_prune(&w, &sal, &cfg, &GyroParams::default());
        out.result.packed.check_invariants().unwrap();
        assert!((out.result.mask.sparsity() - cfg.total_sparsity()).abs() < 0.02);
        assert!(crate::tensor::is_permutation(&out.ocp_perm, 32));
        for ord in &out.tile_orders {
            assert!(crate::tensor::is_permutation(ord, out.result.packed.k_v));
        }
    }

    #[test]
    fn dense_reconstruction_matches_permuted_weights() {
        let (w, sal) = mixed_importance(16, 32, 44);
        let cfg = HinmConfig::with_24(4, 0.5);
        let out = gyro_permute_and_prune(&w, &sal, &cfg, &GyroParams::default());
        let w_p = w.permute_rows(&out.ocp_perm);
        let dense = out.result.packed.to_dense();
        // Every kept value equals the corresponding permuted weight.
        for r in 0..16 {
            for c in 0..32 {
                let d = dense.at(r, c);
                if d != 0.0 {
                    assert_eq!(d, w_p.at(r, c));
                }
            }
        }
    }

    #[test]
    fn skip_flags_disable_phases() {
        let (w, sal) = mixed_importance(16, 32, 45);
        let cfg = HinmConfig::with_24(4, 0.5);
        let out = gyro_permute_and_prune(
            &w,
            &sal,
            &cfg,
            &GyroParams { skip_ocp: true, skip_icp: true, ..Default::default() },
        );
        assert_eq!(out.ocp_perm, (0..16).collect::<Vec<_>>());
        assert!(out.tile_orders.iter().all(|o| o.iter().enumerate().all(|(i, &x)| i == x)));
        // With both phases off this must equal plain one-shot HiNM.
        let noperm = crate::sparsity::hinm::prune_oneshot(&w, &sal, &cfg);
        assert!((out.result.retained - noperm.retained).abs() < 1e-9);
    }

    #[test]
    fn ocp_and_icp_contribute_independently() {
        let (w, sal) = mixed_importance(32, 64, 46);
        let cfg = HinmConfig::with_24(8, 0.5);
        let full = gyro_permute_and_prune(&w, &sal, &cfg, &GyroParams::default());
        let no_icp = gyro_permute_and_prune(
            &w,
            &sal,
            &cfg,
            &GyroParams { skip_icp: true, ..Default::default() },
        );
        let no_ocp = gyro_permute_and_prune(
            &w,
            &sal,
            &cfg,
            &GyroParams { skip_ocp: true, ..Default::default() },
        );
        assert!(full.result.retained >= no_icp.result.retained - 1e-9);
        assert!(full.result.retained >= no_ocp.result.retained * 0.999);
    }
}
