//! Balanced K-means clustering of channels (paper §4.2 "Clustering").
//!
//! Groups sampled output channels by similarity of their saliency profiles
//! under the constraint that every cluster has exactly `cluster_size`
//! members (so clusters can be assigned one-to-one to partitions). Balanced
//! assignment per round is solved exactly with the Hungarian algorithm on a
//! (points × slots) distance matrix — the same approach OVW/Tan et al. use.

use super::hungarian;
use crate::util::rng::Xoshiro256;

/// Result: `clusters[c]` = indices (into the input point list) of cluster c.
#[derive(Clone, Debug)]
pub struct Clustering {
    /// `clusters[c]` lists the point indices assigned to cluster `c`.
    pub clusters: Vec<Vec<usize>>,
}

/// Balanced K-means over `points` (each a feature vector, e.g. a channel's
/// |saliency| profile). `k` clusters of exactly `cluster_size` points;
/// requires `points.len() == k * cluster_size`.
pub fn balanced_kmeans(
    points: &[Vec<f32>],
    k: usize,
    cluster_size: usize,
    max_iters: usize,
    rng: &mut Xoshiro256,
) -> Clustering {
    assert!(k > 0 && cluster_size > 0);
    assert_eq!(points.len(), k * cluster_size, "balanced kmeans needs k·size points");
    let dim = points[0].len();
    assert!(points.iter().all(|p| p.len() == dim));
    let n = points.len();

    if k == 1 {
        return Clustering { clusters: vec![(0..n).collect()] };
    }

    // Seeding: farthest-point (k-means++-like) for small inputs; random
    // distinct points for large ones (farthest-point is O(n·k²·dim)).
    let mut centroids: Vec<Vec<f32>> = Vec::with_capacity(k);
    if n <= 256 {
        centroids.push(points[rng.below(n)].clone());
        while centroids.len() < k {
            let mut best_i = 0;
            let mut best_d = -1.0f64;
            for (i, p) in points.iter().enumerate() {
                let d = centroids
                    .iter()
                    .map(|c| dist2(p, c))
                    .fold(f64::INFINITY, f64::min);
                if d > best_d {
                    best_d = d;
                    best_i = i;
                }
            }
            centroids.push(points[best_i].clone());
        }
    } else {
        for i in rng.sample_distinct(n, k) {
            centroids.push(points[i].clone());
        }
    }

    // Exact balanced assignment (Hungarian on an n×n slot matrix) is O(n³);
    // beyond this size a greedy fill (sort all point–cluster distances,
    // assign while capacity remains) is the standard approximation — same
    // scheme large-scale balanced-clustering implementations use.
    const EXACT_LIMIT: usize = 256;

    let mut assignment = vec![0usize; n];
    for _ in 0..max_iters {
        let new_assignment: Vec<usize> = if n <= EXACT_LIMIT {
            let cost: Vec<Vec<f64>> = (0..n)
                .map(|i| {
                    (0..n)
                        .map(|s| dist2(&points[i], &centroids[s / cluster_size]))
                        .collect()
                })
                .collect();
            let (assign_slots, _) = hungarian::solve(&cost);
            assign_slots.iter().map(|&s| s / cluster_size).collect()
        } else {
            greedy_balanced(points, &centroids, cluster_size)
        };
        let changed = new_assignment != assignment;
        assignment = new_assignment;

        // Update centroids.
        let mut sums = vec![vec![0.0f32; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, &c) in assignment.iter().enumerate() {
            counts[c] += 1;
            for (s, &x) in sums[c].iter_mut().zip(&points[i]) {
                *s += x;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for s in sums[c].iter_mut() {
                    *s /= counts[c] as f32;
                }
                centroids[c] = sums[c].clone();
            }
        }
        if !changed {
            break;
        }
    }

    let mut clusters = vec![Vec::with_capacity(cluster_size); k];
    for (i, &c) in assignment.iter().enumerate() {
        clusters[c].push(i);
    }
    Clustering { clusters }
}

/// Greedy balanced assignment: globally sort (point, cluster) pairs by
/// distance; assign greedily while the cluster has capacity.
fn greedy_balanced(points: &[Vec<f32>], centroids: &[Vec<f32>], cluster_size: usize) -> Vec<usize> {
    let n = points.len();
    let k = centroids.len();
    let mut pairs: Vec<(f64, u32, u32)> = Vec::with_capacity(n * k);
    for (i, p) in points.iter().enumerate() {
        for (c, cent) in centroids.iter().enumerate() {
            pairs.push((dist2(p, cent), i as u32, c as u32));
        }
    }
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut assignment = vec![usize::MAX; n];
    let mut remaining = vec![cluster_size; k];
    let mut unassigned = n;
    for (_, i, c) in pairs {
        let (i, c) = (i as usize, c as usize);
        if assignment[i] == usize::MAX && remaining[c] > 0 {
            assignment[i] = c;
            remaining[c] -= 1;
            unassigned -= 1;
            if unassigned == 0 {
                break;
            }
        }
    }
    debug_assert!(assignment.iter().all(|&a| a != usize::MAX));
    assignment
}

#[inline]
fn dist2(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clusters_are_balanced() {
        let mut rng = Xoshiro256::new(21);
        let points: Vec<Vec<f32>> = (0..12)
            .map(|i| vec![i as f32, (i * i) as f32 * 0.1])
            .collect();
        let c = balanced_kmeans(&points, 4, 3, 10, &mut rng);
        assert_eq!(c.clusters.len(), 4);
        for cl in &c.clusters {
            assert_eq!(cl.len(), 3);
        }
        // Partition property.
        let mut all: Vec<usize> = c.clusters.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn separated_blobs_recovered() {
        let mut rng = Xoshiro256::new(22);
        // Two tight blobs far apart, 3 points each.
        let mut points = Vec::new();
        for i in 0..3 {
            points.push(vec![0.0 + i as f32 * 0.01, 0.0]);
        }
        for i in 0..3 {
            points.push(vec![100.0 + i as f32 * 0.01, 0.0]);
        }
        let c = balanced_kmeans(&points, 2, 3, 20, &mut rng);
        let mut groups: Vec<Vec<usize>> = c.clusters.clone();
        for g in groups.iter_mut() {
            g.sort_unstable();
        }
        groups.sort();
        assert_eq!(groups, vec![vec![0, 1, 2], vec![3, 4, 5]]);
    }

    #[test]
    fn single_cluster_passthrough() {
        let mut rng = Xoshiro256::new(23);
        let points: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32]).collect();
        let c = balanced_kmeans(&points, 1, 5, 5, &mut rng);
        assert_eq!(c.clusters[0].len(), 5);
    }

    #[test]
    fn deterministic_given_seed() {
        let points: Vec<Vec<f32>> = (0..8).map(|i| vec![(i % 4) as f32, (i / 4) as f32]).collect();
        let a = balanced_kmeans(&points, 2, 4, 10, &mut Xoshiro256::new(5));
        let b = balanced_kmeans(&points, 2, 4, 10, &mut Xoshiro256::new(5));
        assert_eq!(a.clusters, b.clusters);
    }
}

#[cfg(test)]
mod greedy_tests {
    use super::*;

    #[test]
    fn greedy_path_is_balanced_partition() {
        let mut rng = Xoshiro256::new(24);
        // n = 320 > EXACT_LIMIT → greedy path.
        let points: Vec<Vec<f32>> = (0..320)
            .map(|i| vec![(i % 10) as f32, rng.next_f32()])
            .collect();
        let c = balanced_kmeans(&points, 10, 32, 6, &mut rng);
        assert_eq!(c.clusters.len(), 10);
        for cl in &c.clusters {
            assert_eq!(cl.len(), 32);
        }
        let mut all: Vec<usize> = c.clusters.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..320).collect::<Vec<_>>());
    }

    #[test]
    fn greedy_separates_far_blobs() {
        let mut rng = Xoshiro256::new(25);
        let mut points = Vec::new();
        for i in 0..300 {
            let base = if i < 150 { 0.0 } else { 1000.0 };
            points.push(vec![base + rng.next_f32()]);
        }
        let c = balanced_kmeans(&points, 2, 150, 8, &mut rng);
        for cl in &c.clusters {
            let lo = cl.iter().filter(|&&i| i < 150).count();
            assert!(lo == 0 || lo == 150, "blobs mixed: {lo}");
        }
    }
}
