//! Sample-count schedule for the gyro **sampling** phase (paper §4.2).
//!
//! "The effectiveness of permutations is significantly influenced by the
//! number of samples extracted from each partition, akin to the effect of
//! learning rates in model training." Large sample counts escape local
//! minima; small counts converge precisely. The schedule is a geometric
//! ladder with warm restarts: `V/4, V/8, …, 1, V/4, …` — the "gyro" motion
//! that alternates exploration and refinement.

/// Annealed sample-count schedule with warm restarts.
#[derive(Clone, Debug)]
pub struct SampleSchedule {
    ladder: Vec<usize>,
}

impl SampleSchedule {
    /// Ladder for partitions of size `partition_size`: starts at
    /// `partition_size / 4` (at least 1), halves down to 1.
    pub fn for_partition(partition_size: usize) -> Self {
        let mut ladder = Vec::new();
        let mut k = (partition_size / 4).max(1);
        while k > 1 {
            ladder.push(k);
            k /= 2;
        }
        ladder.push(1);
        Self { ladder }
    }

    /// Constant schedule (ICP uses k = 1 always).
    pub fn constant(k: usize) -> Self {
        assert!(k >= 1);
        Self { ladder: vec![k] }
    }

    /// Sample count for iteration `i` (cyclic warm restarts).
    pub fn k_at(&self, iter: usize) -> usize {
        self.ladder[iter % self.ladder.len()]
    }

    /// Length of one warm-restart cycle.
    pub fn cycle_len(&self) -> usize {
        self.ladder.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_for_v32() {
        let s = SampleSchedule::for_partition(32);
        assert_eq!(
            (0..5).map(|i| s.k_at(i)).collect::<Vec<_>>(),
            vec![8, 4, 2, 1, 8] // warm restart at the cycle boundary
        );
    }

    #[test]
    fn ladder_for_small_partitions() {
        assert_eq!(SampleSchedule::for_partition(4).k_at(0), 1);
        assert_eq!(SampleSchedule::for_partition(8).k_at(0), 2);
        assert_eq!(SampleSchedule::for_partition(8).k_at(1), 1);
    }

    #[test]
    fn constant_is_constant() {
        let s = SampleSchedule::constant(1);
        assert!((0..10).all(|i| s.k_at(i) == 1));
    }

    #[test]
    fn k_never_exceeds_quarter_partition() {
        for v in [4usize, 8, 16, 32, 64, 128] {
            let s = SampleSchedule::for_partition(v);
            for i in 0..2 * s.cycle_len() {
                assert!(s.k_at(i) <= (v / 4).max(1));
                assert!(s.k_at(i) >= 1);
            }
        }
    }
}
