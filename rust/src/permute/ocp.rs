//! Gyro **output-channel permutation** (OCP): rearranges the `m` output
//! channels into `P_o = m/V` partitions of `V` so that column-wise vector
//! pruning removes the least saliency (Eq. 2), via sampling → clustering →
//! Hungarian assignment iterations (paper §4.2).

use super::cost::{ocp_partition_retained, ocp_partition_retained_hinm, sum_top_k};
use super::hungarian;
use super::kmeans::balanced_kmeans;
use super::sampling::SampleSchedule;
use crate::sparsity::config::HinmConfig;
use crate::tensor::Matrix;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
/// Tuning knobs for the gyro OCP (sampling → clustering → assignment).
pub struct OcpParams {
    /// Maximum sampling/clustering/assignment iterations.
    pub max_iters: usize,
    /// Stop after this many consecutive non-improving iterations.
    pub patience: usize,
    /// Use the hierarchical-aware cost (retention after vector *and* N:M)
    /// instead of the Eq. 2 vector-level cost. Slower; see DESIGN §7.
    pub hinm_aware: bool,
    /// Base RNG seed for sampling and clustering.
    pub seed: u64,
}

impl Default for OcpParams {
    fn default() -> Self {
        Self { max_iters: 48, patience: 12, hinm_aware: false, seed: 0x0C9 }
    }
}

#[derive(Clone, Debug)]
/// Outcome of the OCP search.
pub struct OcpResult {
    /// `perm[i]` = original output-channel id at permuted position `i`.
    pub perm: Vec<usize>,
    /// Eq. 2 retained saliency of the final arrangement.
    pub retained: f64,
    /// Retained per accepted iteration (for convergence plots).
    pub history: Vec<f64>,
    /// Iterations actually executed.
    pub iters_run: usize,
    /// Iterations that improved the objective.
    pub accepted: usize,
}

/// Objective: Σ over partitions of the top-`k_v` vector-saliency columns.
pub fn ocp_objective(sal: &Matrix, partitions: &[Vec<usize>], k_v: usize) -> f64 {
    let mut total = 0.0;
    let mut colsum = vec![0.0f64; sal.cols];
    for part in partitions {
        colsum.iter_mut().for_each(|x| *x = 0.0);
        for &ch in part {
            for (acc, &s) in colsum.iter_mut().zip(sal.row(ch)) {
                *acc += s as f64;
            }
        }
        total += sum_top_k(&colsum, k_v);
    }
    total
}

/// Run gyro OCP on a saliency grid. Returns the permutation that maximizes
/// Eq. 2 retention over the sampled search.
pub fn gyro_ocp(sal: &Matrix, cfg: &HinmConfig, params: &OcpParams) -> OcpResult {
    cfg.validate(sal.rows, sal.cols).expect("invalid config");
    let v = cfg.v;
    let p_count = sal.rows / v;
    let k_v = cfg.keep_cols(sal.cols);
    let mut rng = Xoshiro256::new(params.seed);
    let schedule = SampleSchedule::for_partition(v);

    // partitions[p] = original channel ids currently in partition p.
    let mut partitions: Vec<Vec<usize>> = (0..p_count)
        .map(|p| (p * v..(p + 1) * v).collect())
        .collect();
    let mut best = ocp_objective(sal, &partitions, k_v);
    let mut history = vec![best];
    let mut accepted = 0usize;
    let mut stale = 0usize;
    let mut iters_run = 0usize;

    // Single-partition degenerate case: any arrangement is equivalent.
    if p_count <= 1 {
        return OcpResult {
            perm: (0..sal.rows).collect(),
            retained: best,
            history,
            iters_run: 0,
            accepted: 0,
        };
    }

    let mut scratch: Vec<f64> = Vec::with_capacity(sal.cols);
    for iter in 0..params.max_iters {
        iters_run = iter + 1;
        let k = schedule.k_at(iter).min(v - 1).max(1);

        // --- Sampling: k random channels from each partition. ---
        let mut sampled: Vec<Vec<usize>> = Vec::with_capacity(p_count); // channel ids per partition
        let mut remainders: Vec<Vec<usize>> = Vec::with_capacity(p_count);
        for part in &partitions {
            let picks = rng.sample_distinct(v, k);
            let mut sel = Vec::with_capacity(k);
            let mut rem = Vec::with_capacity(v - k);
            for (pos, &ch) in part.iter().enumerate() {
                if picks.contains(&pos) {
                    sel.push(ch);
                } else {
                    rem.push(ch);
                }
            }
            sampled.push(sel);
            remainders.push(rem);
        }
        let all_samples: Vec<usize> = sampled.iter().flatten().copied().collect();

        // --- Clustering: group the P·k samples into P clusters of k. ---
        let clusters: Vec<Vec<usize>> = if k == 1 {
            all_samples.iter().map(|&c| vec![c]).collect()
        } else {
            let feats: Vec<Vec<f32>> = all_samples.iter().map(|&c| sal.row(c).to_vec()).collect();
            let clustering = balanced_kmeans(&feats, p_count, k, 8, &mut rng);
            clustering
                .clusters
                .iter()
                .map(|members| members.iter().map(|&i| all_samples[i]).collect())
                .collect()
        };

        // --- Assignment: Hungarian on −retained (Eq. 4 up to constants). ---
        let rem_colsums: Vec<Vec<f64>> = remainders.iter().map(|rem| colsum_of(sal, rem)).collect();
        let clu_colsums: Vec<Vec<f64>> = clusters.iter().map(|clu| colsum_of(sal, clu)).collect();
        let cost: Vec<Vec<f64>> = (0..p_count)
            .map(|i| {
                (0..p_count)
                    .map(|j| {
                        let r = if params.hinm_aware {
                            let rows: Vec<&[f32]> = remainders[i]
                                .iter()
                                .chain(clusters[j].iter())
                                .map(|&ch| sal.row(ch))
                                .collect();
                            ocp_partition_retained_hinm(&rows, k_v, cfg, &mut scratch)
                        } else {
                            ocp_partition_retained(&rem_colsums[i], &clu_colsums[j], k_v, &mut scratch)
                        };
                        -r
                    })
                    .collect()
            })
            .collect();
        let (assign, _) = hungarian::solve(&cost);

        // --- Candidate arrangement & accept/revert. ---
        let candidate: Vec<Vec<usize>> = (0..p_count)
            .map(|i| {
                let mut part = remainders[i].clone();
                part.extend(clusters[assign[i]].iter().copied());
                part.sort_unstable();
                part
            })
            .collect();
        let cand_obj = ocp_objective(sal, &candidate, k_v);
        if cand_obj > best + 1e-9 {
            best = cand_obj;
            partitions = candidate;
            accepted += 1;
            stale = 0;
            history.push(best);
        } else {
            stale += 1;
            if stale >= params.patience {
                break;
            }
        }
    }

    let perm: Vec<usize> = partitions.into_iter().flatten().collect();
    debug_assert!(crate::tensor::is_permutation(&perm, sal.rows));
    OcpResult { perm, retained: best, history, iters_run, accepted }
}

fn colsum_of(sal: &Matrix, channels: &[usize]) -> Vec<f64> {
    let mut out = vec![0.0f64; sal.cols];
    for &ch in channels {
        for (acc, &s) in out.iter_mut().zip(sal.row(ch)) {
            *acc += s as f64;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::vector_prune::vector_retained;
    use crate::tensor::is_permutation;

    fn adversarial_sal(m: usize, n: usize, v: usize) -> Matrix {
        // Interleave "hot" and "cold" channels so natural partitions mix
        // importance patterns — permutation has clear headroom.
        Matrix::from_fn(m, n, |r, c| {
            let hot = r % v < v / 2;
            let col_hot = (c / 4) % 2 == 0;
            match (hot, col_hot) {
                (true, true) => 10.0 + (r + c) as f32 * 0.01,
                (true, false) => 0.1,
                (false, true) => 0.1,
                (false, false) => 10.0 + (r * c % 7) as f32 * 0.01,
            }
        })
    }

    #[test]
    fn returns_valid_permutation() {
        let sal = adversarial_sal(16, 16, 4);
        let cfg = HinmConfig::with_24(4, 0.5);
        let res = gyro_ocp(&sal, &cfg, &OcpParams::default());
        assert!(is_permutation(&res.perm, 16));
    }

    #[test]
    fn improves_vector_retention_on_adversarial_input() {
        let sal = adversarial_sal(32, 32, 8);
        let cfg = HinmConfig::with_24(8, 0.5);
        let before = vector_retained(&sal, &cfg);
        let res = gyro_ocp(&sal, &cfg, &OcpParams { max_iters: 64, ..Default::default() });
        let after = vector_retained(&sal.permute_rows(&res.perm), &cfg);
        assert!(after > before * 1.02, "before={before} after={after}");
        // Internal objective agrees with the real pruner's measure.
        assert!((after - res.retained).abs() < 1e-6 * after.max(1.0));
    }

    #[test]
    fn history_is_monotone() {
        let sal = adversarial_sal(32, 32, 8);
        let cfg = HinmConfig::with_24(8, 0.5);
        let res = gyro_ocp(&sal, &cfg, &OcpParams::default());
        for w in res.history.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert_eq!(res.history.len(), res.accepted + 1);
    }

    #[test]
    fn single_partition_noop() {
        let sal = adversarial_sal(8, 16, 8);
        let cfg = HinmConfig::with_24(8, 0.5);
        let res = gyro_ocp(&sal, &cfg, &OcpParams::default());
        assert_eq!(res.perm, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_for_seed() {
        let sal = adversarial_sal(16, 16, 4);
        let cfg = HinmConfig::with_24(4, 0.5);
        let a = gyro_ocp(&sal, &cfg, &OcpParams::default());
        let b = gyro_ocp(&sal, &cfg, &OcpParams::default());
        assert_eq!(a.perm, b.perm);
    }

    #[test]
    fn hinm_aware_cost_also_improves() {
        let sal = adversarial_sal(16, 16, 4);
        let cfg = HinmConfig::with_24(4, 0.5);
        let params = OcpParams { hinm_aware: true, max_iters: 24, ..Default::default() };
        let res = gyro_ocp(&sal, &cfg, &params);
        assert!(is_permutation(&res.perm, 16));
        let before = vector_retained(&sal, &cfg);
        let after = vector_retained(&sal.permute_rows(&res.perm), &cfg);
        assert!(after >= before);
    }
}
