//! Table 2: gradual pruning on BERT-base shapes — HiNM(+gyro) vs VENOM.
//!
//! Paper: F1 at {75, 87.5}% total sparsity. VENOM uses the same sparsity
//! pattern with pair-wise second-order saliency and *no permutation*; HiNM
//! ramps the vector level first (cubic), then enables 2:4, re-running
//! gyro-permutation at every mask update. The surrogate metric is final
//! retained-saliency ratio under each method's own saliency scores,
//! normalized by its dense total.

use super::common::{eval_gyro_params, materialize, EvalScale};
use crate::models::catalog::bert_base;
use crate::permute::gyro_permute_and_prune;
use crate::saliency::{PairwiseSecondOrder, Saliency, SecondOrder};
use crate::sparsity::hinm::{gradual_schedule, prune_oneshot, step_config};
use crate::sparsity::HinmConfig;
use crate::util::bench::Table;

/// Sparsity levels of Table 2.
pub const SPARSITIES_PCT: [f64; 2] = [75.0, 87.5];

#[derive(Clone, Debug)]
/// One (method, sparsity) measurement of the gradual comparison.
pub struct Tab2Row {
    /// `"HiNM"` or the VENOM-style baseline.
    pub method: &'static str,
    /// Total sparsity in percent.
    pub sparsity_pct: f64,
    /// Retention of the final mask.
    pub retention: f64,
}

/// Gradual HiNM with gyro re-permutation at each step. Retention of the
/// final mask is what matters (intermediate masks only matter for the
/// fine-tuning loop, exercised in the e2e example).
fn gradual_hinm_gyro(
    w: &crate::tensor::Matrix,
    sal: &crate::tensor::Matrix,
    base: &HinmConfig,
    seed: u64,
) -> f64 {
    let steps = gradual_schedule(base.vector_sparsity, 3, 5);
    let mut last = 0.0;
    for s in &steps {
        let cfg = step_config(base, s);
        if cfg.vector_sparsity == 0.0 && !s.nm_active {
            last = sal.l1();
            continue;
        }
        let out = gyro_permute_and_prune(w, sal, &cfg, &eval_gyro_params(seed ^ s.step as u64));
        last = out.result.retained;
    }
    last
}

/// VENOM arm: same gradual schedule, pair-wise second-order saliency,
/// no permutation.
fn gradual_venom(
    w: &crate::tensor::Matrix,
    sal: &crate::tensor::Matrix,
    base: &HinmConfig,
) -> f64 {
    let steps = gradual_schedule(base.vector_sparsity, 3, 5);
    let mut last = 0.0;
    for s in &steps {
        let cfg = step_config(base, s);
        if cfg.vector_sparsity == 0.0 && !s.nm_active {
            last = sal.l1();
            continue;
        }
        last = prune_oneshot(w, sal, &cfg).retained;
    }
    last
}

/// Run the Table 2 gradual-schedule comparison.
pub fn tab2(scale: EvalScale, seed: u64) -> Vec<Tab2Row> {
    let v = if scale == EvalScale::Full { 32 } else { 8 };
    // Base saliency evidence shared by both methods; each method applies its
    // own estimator on top (HiNM: diagonal 2nd-order; VENOM: pair-wise).
    let layers = materialize(&bert_base(), scale, v, false, seed);
    let mut rows = Vec::new();
    for &s in &SPARSITIES_PCT {
        let total = s / 100.0;
        let base = HinmConfig::for_total_sparsity(v, total);
        let mut acc = [(0.0f64, 0.0f64); 2]; // (num, den) per method
        for l in &layers {
            let grads = crate::models::SyntheticGen::default().grad_samples(
                l.weights.rows,
                l.weights.cols,
                4,
                &mut crate::util::rng::Xoshiro256::new(seed ^ l.weights.rows as u64),
            );
            let so = SecondOrder::from_grad_samples(&grads, 1e-8);
            let hinm_sal = so.score(&l.weights);
            let venom_sal = PairwiseSecondOrder { inner: so, m_group: 4, lambda: 0.3 }
                .score(&l.weights);

            let r_hinm = gradual_hinm_gyro(&l.weights, &hinm_sal, &base, seed) / hinm_sal.l1();
            let r_venom = gradual_venom(&l.weights, &venom_sal, &base) / venom_sal.l1();
            acc[0].0 += r_hinm * l.weight;
            acc[0].1 += l.weight;
            acc[1].0 += r_venom * l.weight;
            acc[1].1 += l.weight;
        }
        rows.push(Tab2Row { method: "HiNM", sparsity_pct: s, retention: acc[0].0 / acc[0].1 });
        rows.push(Tab2Row { method: "VENOM", sparsity_pct: s, retention: acc[1].0 / acc[1].1 });
    }
    rows
}

/// Render the Table 2 report.
pub fn render(rows: &[Tab2Row]) -> String {
    let mut t = Table::new(&["method", "s=75%", "s=87.5%"]);
    for method in ["HiNM", "VENOM"] {
        let mut cells = vec![method.to_string()];
        for &s in &SPARSITIES_PCT {
            let r = rows
                .iter()
                .find(|r| r.method == method && r.sparsity_pct == s)
                .map(|r| r.retention)
                .unwrap_or(f64::NAN);
            cells.push(format!("{:.4}", r));
        }
        t.row(cells);
    }
    format!(
        "# Table 2 — BERT-base gradual pruning (HiNM vs VENOM), retained ratio\n{}",
        t.render()
    )
}

/// Marker used by tests/benches: HiNM must beat VENOM at every sparsity.
pub fn hinm_beats_venom(rows: &[Tab2Row]) -> bool {
    SPARSITIES_PCT.iter().all(|&s| {
        let get = |m: &str| {
            rows.iter()
                .find(|r| r.method == m && r.sparsity_pct == s)
                .map(|r| r.retention)
                .unwrap_or(f64::NAN)
        };
        get("HiNM") > get("VENOM")
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::common::EvalScale;

    #[test]
    fn tab2_hinm_beats_venom() {
        let rows = tab2(EvalScale::Tiny, 31);
        assert!(hinm_beats_venom(&rows), "{rows:?}");
    }

    #[test]
    fn retention_decreases_with_sparsity() {
        let rows = tab2(EvalScale::Tiny, 32);
        let get = |m: &str, s: f64| {
            rows.iter()
                .find(|r| r.method == m && r.sparsity_pct == s)
                .unwrap()
                .retention
        };
        assert!(get("HiNM", 75.0) > get("HiNM", 87.5));
        assert!(get("VENOM", 75.0) > get("VENOM", 87.5));
    }
}
