//! Figure 5: latency overhead of gyro-permutation on BERT-base GEMMs.
//!
//! Paper claim: runtime input-channel permutation (the reordered `vec_idx`
//! consumed by the global→shared gather) adds **no detectable latency** at
//! any sparsity ratio or vector size. Two reproductions (DESIGN.md §2):
//!
//! 1. **Measured** — wall-clock of the planned tile-parallel CPU kernel
//!    ([`crate::spmm::SpmmPlan`] through a single-lane engine, the
//!    per-replica serving default) on the packed format with identity vs
//!    gyro-permuted `vec_idx`. Permutation changes only the gather order,
//!    the planned streams are the same size — so the delta should be
//!    noise.
//! 2. **Modeled** — the STC cost model (`spmm::sim`) with the same toggle,
//!    plus the arms the paper discusses: dense, VENOM-style padding, and
//!    Tetris-style index translation.

use crate::eval::common::eval_gyro_params;
use crate::models::SyntheticGen;
use crate::permute::gyro_permute_and_prune;
use crate::sparsity::hinm::prune_oneshot;
use crate::sparsity::{HinmConfig, HinmPacked};
use crate::spmm::sim::{model_dense, model_hinm_spmm, BankStrategy, GpuParams, Workload};
use crate::spmm::{Epilogue, SpmmEngine, SpmmPlan};
use crate::tensor::Matrix;
use crate::util::bench::{black_box, Bencher, Table};
use crate::util::rng::Xoshiro256;

/// BERT-base FFN GEMM (the dominant layer): `[3072, 768] × [768, B]`.
#[derive(Clone, Copy, Debug)]
pub struct Fig5Case {
    /// GEMM rows (output channels).
    pub m: usize,
    /// GEMM cols (input features).
    pub n: usize,
    /// Activation batch width.
    pub batch: usize,
    /// HiNM vector size V.
    pub v: usize,
    /// Total sparsity in `[0,1]`.
    pub total_sparsity: f64,
}

/// The Fig. 5 case grid (full = paper shapes, else reduced).
pub fn cases(full: bool) -> Vec<Fig5Case> {
    let (m, n, batch) = if full { (3072, 768, 64) } else { (256, 128, 16) };
    let mut out = Vec::new();
    for &v in if full { &[32usize, 64, 128][..] } else { &[16, 32][..] } {
        for &s in &[0.5, 0.625, 0.75, 0.875] {
            out.push(Fig5Case { m, n, batch, v, total_sparsity: s });
        }
    }
    out
}

#[derive(Clone, Debug)]
/// Measured + modeled latencies for one case.
pub struct Fig5Row {
    /// The case configuration.
    pub case: Fig5Case,
    /// Measured CPU kernel µs, identity vec_idx.
    pub cpu_identity_us: f64,
    /// Measured CPU kernel µs, gyro-permuted vec_idx.
    pub cpu_permuted_us: f64,
    /// Modeled GPU µs (swizzle, permuted).
    pub gpu_model_us: f64,
    /// Modeled dense GPU µs.
    pub gpu_dense_us: f64,
    /// Modeled Tetris (w/ index translation) µs.
    pub gpu_tetris_us: f64,
}

impl Fig5Row {
    /// Relative measured overhead of the permuted index stream.
    pub fn overhead_pct(&self) -> f64 {
        (self.cpu_permuted_us - self.cpu_identity_us) / self.cpu_identity_us * 100.0
    }
}

fn pack_pair(c: &Fig5Case, seed: u64) -> (HinmPacked, HinmPacked, Matrix) {
    let mut rng = Xoshiro256::new(seed);
    let w = SyntheticGen::default().weights(c.m, c.n, &mut rng);
    let sal = w.abs();
    let cfg = HinmConfig::for_total_sparsity(c.v, c.total_sparsity);
    let identity = prune_oneshot(&w, &sal, &cfg).packed;
    let mut gp = eval_gyro_params(seed);
    gp.ocp.max_iters = 8; // permutation quality irrelevant here; only layout
    gp.icp.max_iters = 6;
    let permuted = gyro_permute_and_prune(&w, &sal, &cfg, &gp).result.packed;
    let x = Matrix::randn(c.n, c.batch, 1.0, &mut rng);
    (identity, permuted, x)
}

/// Run one case: measure both planned kernels, model the GPU arms.
pub fn run_case(c: &Fig5Case, bencher: &Bencher, seed: u64) -> Fig5Row {
    let (identity, permuted, x) = pack_pair(c, seed);
    let engine = SpmmEngine::single();
    let id_plan = SpmmPlan::new(&identity);
    let perm_plan = SpmmPlan::new(&permuted);
    let epi = Epilogue::default();
    let mut y = Matrix::zeros(c.m, c.batch);
    let id_stats = bencher.run("identity", || {
        engine.execute(&id_plan, &x, &mut y, &epi);
        black_box(y.data[0]);
    });
    let perm_stats = bencher.run("permuted", || {
        engine.execute(&perm_plan, &x, &mut y, &epi);
        black_box(y.data[0]);
    });

    let gpu = GpuParams::rtx3090();
    let wl = Workload {
        m: c.m,
        n: c.n,
        batch: c.batch,
        v: c.v,
        k_v: identity.k_v,
        nm_density: 0.5,
    };
    Fig5Row {
        case: *c,
        cpu_identity_us: id_stats.median_us(),
        cpu_permuted_us: perm_stats.median_us(),
        gpu_model_us: model_hinm_spmm(&gpu, &wl, BankStrategy::Swizzle, true, false).total_us(),
        gpu_dense_us: model_dense(&gpu, c.m, c.n, c.batch).total_us(),
        gpu_tetris_us: model_hinm_spmm(&gpu, &wl, BankStrategy::Swizzle, true, true).total_us(),
    }
}

/// Run every Fig. 5 case; `full` selects the paper's shapes.
pub fn run(full: bool, seed: u64) -> Vec<Fig5Row> {
    let bencher = if full { Bencher::default() } else { Bencher::quick() };
    cases(full)
        .iter()
        .enumerate()
        .map(|(i, c)| run_case(c, &bencher, seed ^ i as u64))
        .collect()
}

/// Render the Fig. 5 latency table.
pub fn render(rows: &[Fig5Row]) -> String {
    let mut t = Table::new(&[
        "V",
        "sparsity",
        "cpu id µs",
        "cpu perm µs",
        "overhead %",
        "gpu model µs",
        "gpu dense µs",
        "gpu tetris µs",
    ]);
    for r in rows {
        t.row(vec![
            r.case.v.to_string(),
            format!("{:.1}%", r.case.total_sparsity * 100.0),
            format!("{:.1}", r.cpu_identity_us),
            format!("{:.1}", r.cpu_permuted_us),
            format!("{:+.2}", r.overhead_pct()),
            format!("{:.2}", r.gpu_model_us),
            format!("{:.2}", r.gpu_dense_us),
            format!("{:.2}", r.gpu_tetris_us),
        ]);
    }
    format!(
        "# Fig. 5 — latency overhead of gyro-permutation (BERT FFN GEMM)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permutation_overhead_is_noise() {
        let rows = run(false, 51);
        // Median |overhead| across cases should be small; individual cases
        // can jitter on shared CI hardware, so check the aggregate.
        let mut overheads: Vec<f64> = rows.iter().map(|r| r.overhead_pct().abs()).collect();
        overheads.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = overheads[overheads.len() / 2];
        assert!(median < 12.0, "median measured overhead {median}% — should be noise");
        // The model says exactly zero.
        for r in &rows {
            let wl = Workload {
                m: r.case.m,
                n: r.case.n,
                batch: r.case.batch,
                v: r.case.v,
                k_v: 8,
                nm_density: 0.5,
            };
            let gpu = GpuParams::rtx3090();
            let a = model_hinm_spmm(&gpu, &wl, BankStrategy::Swizzle, false, false).total_us();
            let b = model_hinm_spmm(&gpu, &wl, BankStrategy::Swizzle, true, false).total_us();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn sparser_is_faster_cpu_and_model() {
        let rows = run(false, 52);
        // Group by v; within a group, 87.5% must beat 50% on both metrics.
        for &v in &[16usize, 32] {
            let lo = rows
                .iter()
                .find(|r| r.case.v == v && r.case.total_sparsity == 0.5)
                .unwrap();
            let hi = rows
                .iter()
                .find(|r| r.case.v == v && r.case.total_sparsity == 0.875)
                .unwrap();
            assert!(
                hi.cpu_identity_us < lo.cpu_identity_us,
                "v={v}: cpu {} vs {}",
                hi.cpu_identity_us,
                lo.cpu_identity_us
            );
            assert!(hi.gpu_model_us < lo.gpu_model_us);
        }
    }

    #[test]
    fn tetris_translation_visible_in_model() {
        let rows = run(false, 53);
        for r in &rows {
            assert!(r.gpu_tetris_us > r.gpu_model_us);
        }
    }
}
