//! Shared evaluation machinery: per-model retention sweeps over pruning
//! arms, the accuracy surrogate, and result-table plumbing.
//!
//! Accuracy surrogate (DESIGN.md §2): the drivers report the **retained
//! saliency ratio** `‖M⊙ρ‖₁/‖ρ‖₁` — the exact quantity the permutation
//! objective (Eq. 1) maximizes — aggregated across layers weighted by
//! parameter count. The paper's accuracy *ordering* (who wins, rough gaps)
//! must reproduce in this metric; EXPERIMENTS.md maps one to the other
//! explicitly. Real (small-model) accuracy is measured by the e2e example.

use crate::models::catalog::ModelCatalog;
use crate::models::SyntheticGen;
use crate::permute::baselines::ovw::ovw_retained;
use crate::permute::{GyroParams, IcpParams, OcpParams};
use crate::saliency::{Magnitude, Saliency, SecondOrder};
use crate::sparsity::hinm::prune_oneshot;
use crate::sparsity::unstructured::unstructured_retained;
use crate::sparsity::HinmConfig;
use crate::tensor::Matrix;
use crate::util::rng::Xoshiro256;

/// Scale factor applied to layer shapes so tests stay fast while benches run
/// the full sizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalScale {
    /// Full paper shapes (benches, CLI).
    Full,
    /// Shapes divided by 4 (quick CLI runs).
    Quarter,
    /// Shapes divided by 8, layer count capped (unit tests).
    Tiny,
}

impl EvalScale {
    /// Shape divisor for this scale.
    pub fn div(&self) -> usize {
        match self {
            EvalScale::Full => 1,
            EvalScale::Quarter => 4,
            EvalScale::Tiny => 8,
        }
    }
    /// Cap on distinct layer shapes evaluated.
    pub fn max_layers(&self) -> usize {
        match self {
            EvalScale::Full => usize::MAX,
            EvalScale::Quarter => usize::MAX,
            EvalScale::Tiny => 4,
        }
    }
    /// Parse `full` / `quarter` / `tiny`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "full" => Some(EvalScale::Full),
            "quarter" => Some(EvalScale::Quarter),
            "tiny" => Some(EvalScale::Tiny),
            _ => None,
        }
    }
}

/// The pruning arms evaluated in Figs. 3/4 and Tables 1/3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodArm {
    /// No pruning (retention 1.0 reference).
    Dense,
    /// The paper's method: gyro OCP + gyro ICP.
    HinmGyro,
    /// HiNM with no permutation (`id+id`).
    HinmNoPerm,
    /// Vector-only OVW baseline (Tan et al.).
    Ovw,
    /// Element-wise magnitude pruning (upper bound / CAP stand-in).
    Unstructured,
    /// Ablation V1: OVW OCP + gyro ICP.
    HinmV1,
    /// Ablation V2: gyro OCP + Apex ICP.
    HinmV2,
    /// Extra ablation arm via the strategy registry: gyro OCP + Tetris-style
    /// swap ICP (`gyro+tetris`).
    HinmV3,
}

impl MethodArm {
    /// The paper's arm label.
    pub fn label(&self) -> &'static str {
        match self {
            MethodArm::Dense => "Dense",
            MethodArm::HinmGyro => "HiNM",
            MethodArm::HinmNoPerm => "HiNM-NoPerm",
            MethodArm::Ovw => "OVW",
            MethodArm::Unstructured => "Unstructured",
            MethodArm::HinmV1 => "HiNM-V1",
            MethodArm::HinmV2 => "HiNM-V2",
            MethodArm::HinmV3 => "HiNM-V3",
        }
    }

    /// Strategy-registry spec for the HiNM arms (None for the non-HiNM
    /// baselines, which have dedicated scoring paths).
    pub fn spec(&self) -> Option<crate::permute::StrategySpec> {
        use crate::permute::StrategySpec;
        match self {
            MethodArm::HinmGyro => Some(StrategySpec::new("gyro", "gyro")),
            MethodArm::HinmNoPerm => Some(StrategySpec::new("id", "id")),
            MethodArm::HinmV1 => Some(StrategySpec::new("ovw", "gyro")),
            MethodArm::HinmV2 => Some(StrategySpec::new("gyro", "apex")),
            MethodArm::HinmV3 => Some(StrategySpec::new("gyro", "tetris")),
            _ => None,
        }
    }
}

/// A concrete synthetic layer instance.
pub struct EvalLayer {
    /// Layer name from the catalog.
    pub name: String,
    /// Synthetic trained-like weights at the scaled shape.
    pub weights: Matrix,
    /// Saliency grid for the chosen estimator.
    pub saliency: Matrix,
    /// Multiplicity weight (layer repeat count × params).
    pub weight: f64,
}

/// Materialize a catalog at a given scale with trained-like weights.
/// `second_order` switches the saliency estimator (Tab. 1 uses it).
pub fn materialize(
    catalog: &ModelCatalog,
    scale: EvalScale,
    v: usize,
    second_order: bool,
    seed: u64,
) -> Vec<EvalLayer> {
    let div = scale.div();
    let gen = SyntheticGen::default();
    let mut rng = Xoshiro256::new(seed);
    let mut out = Vec::new();
    for (i, l) in catalog.layers.iter().enumerate() {
        if i >= scale.max_layers() {
            break;
        }
        let rows = round_to(l.out_ch / div, v).max(v);
        let cols = round_to(l.in_dim / div, 16).max(16);
        let w = gen.weights(rows, cols, &mut rng);
        let saliency: Matrix = if second_order {
            let grads = gen.grad_samples(rows, cols, 4, &mut rng);
            SecondOrder::from_grad_samples(&grads, 1e-8).score(&w)
        } else {
            Magnitude.score(&w)
        };
        out.push(EvalLayer {
            name: l.name.clone(),
            weights: w,
            saliency,
            weight: (l.count * rows * cols) as f64,
        });
    }
    out
}

fn round_to(x: usize, k: usize) -> usize {
    ((x + k - 1) / k) * k
}

/// Fast gyro parameters for evaluation sweeps (fewer iterations than the
/// library defaults; the marginal retention gain beyond this is < 0.1%).
pub fn eval_gyro_params(seed: u64) -> GyroParams {
    GyroParams {
        ocp: OcpParams { max_iters: 24, patience: 8, hinm_aware: false, seed },
        icp: IcpParams { max_iters: 20, patience: 6, seed: seed ^ 0xABCD, max_partitions: 64 },
        skip_ocp: false,
        skip_icp: false,
    }
}

/// Retention ratio of one arm on one layer at `total` sparsity.
pub fn arm_retention(arm: MethodArm, layer: &EvalLayer, v: usize, total: f64, seed: u64) -> f64 {
    let sal = &layer.saliency;
    let total_sal = sal.l1();
    if total_sal == 0.0 {
        return 1.0;
    }
    let retained = match arm {
        MethodArm::Dense => total_sal,
        MethodArm::Unstructured => unstructured_retained(sal, total),
        MethodArm::Ovw => ovw_retained(sal, v, total, seed),
        MethodArm::HinmNoPerm => {
            let cfg = HinmConfig::for_total_sparsity(v, total);
            prune_oneshot(&layer.weights, sal, &cfg).retained
        }
        MethodArm::HinmGyro => {
            let cfg = HinmConfig::for_total_sparsity(v, total);
            let out = crate::permute::gyro_permute_and_prune(
                &layer.weights,
                sal,
                &cfg,
                &eval_gyro_params(seed),
            );
            out.result.retained
        }
        MethodArm::HinmV1 | MethodArm::HinmV2 | MethodArm::HinmV3 => {
            // Ablation arms route through the strategy registry — the same
            // code path the coordinator pipeline and the CLI use.
            let cfg = HinmConfig::for_total_sparsity(v, total);
            let pc = crate::coordinator::PipelineConfig {
                cfg,
                method: arm.spec().expect("HiNM arm has a spec"),
                gyro: eval_gyro_params(seed),
                workers: 1,
                tile_workers: 1,
            };
            let job = crate::coordinator::LayerJob {
                name: layer.name.clone(),
                weights: layer.weights.clone(),
                saliency: sal.clone(),
            };
            crate::coordinator::compress_layer(&job, &pc).result.retained
        }
    };
    retained / total_sal
}

/// Weighted-average retention of an arm across a model's layers.
pub fn model_retention(
    arm: MethodArm,
    layers: &[EvalLayer],
    v: usize,
    total: f64,
    seed: u64,
) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for l in layers {
        num += arm_retention(arm, l, v, total, seed) * l.weight;
        den += l.weight;
    }
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::catalog::resnet18;

    #[test]
    fn materialize_respects_scale_and_v() {
        let layers = materialize(&resnet18(), EvalScale::Tiny, 8, false, 1);
        assert!(layers.len() <= 4);
        for l in &layers {
            assert_eq!(l.weights.rows % 8, 0);
            assert!(l.weights.cols >= 16);
            assert_eq!(l.weights.shape(), l.saliency.shape());
        }
    }

    #[test]
    fn arm_ordering_on_tiny_resnet() {
        let layers = materialize(&resnet18(), EvalScale::Tiny, 8, false, 2);
        let l = &layers[0];
        let un = arm_retention(MethodArm::Unstructured, l, 8, 0.75, 3);
        let gyro = arm_retention(MethodArm::HinmGyro, l, 8, 0.75, 3);
        let noperm = arm_retention(MethodArm::HinmNoPerm, l, 8, 0.75, 3);
        let dense = arm_retention(MethodArm::Dense, l, 8, 0.75, 3);
        assert_eq!(dense, 1.0);
        assert!(un <= 1.0 && un > 0.0);
        assert!(gyro >= noperm, "gyro {gyro} vs noperm {noperm}");
        assert!(un >= gyro * 0.98, "unstructured should upper-bound: {un} vs {gyro}");
    }

    #[test]
    fn second_order_materialization_differs() {
        let a = materialize(&resnet18(), EvalScale::Tiny, 8, false, 5);
        let b = materialize(&resnet18(), EvalScale::Tiny, 8, true, 5);
        assert_ne!(a[0].saliency, b[0].saliency);
    }
}
