//! Table 1: one-shot pruning on DeiT-base with second-order saliency.
//!
//! Paper: accuracy at {65, 75, 85}% for Dense / HiNM / HiNM-NoPerm / CAP.
//! CAP (correlation-aware element-wise pruning) is represented by the
//! unstructured arm under the same second-order saliency — the element-wise
//! upper bound HiNM is expected to approach (paper: HiNM even edges it out
//! on accuracy after fine-tuning; in raw retention the unstructured mask is
//! by construction ≥ any structured mask, so the check here is *gap*, not
//! order).

use super::common::{materialize, model_retention, EvalScale, MethodArm};
use crate::models::catalog::deit_base;
use crate::util::bench::Table;

/// Sparsity levels of Table 1.
pub const SPARSITIES_PCT: [usize; 3] = [65, 75, 85];
/// Arms compared in Table 1 (DeiT, second-order saliency).
pub const ARMS: [MethodArm; 4] = [
    MethodArm::Dense,
    MethodArm::HinmGyro,
    MethodArm::HinmNoPerm,
    MethodArm::Unstructured, // CAP stand-in (2nd-order element-wise)
];

#[derive(Clone, Debug)]
/// One (arm, sparsity) measurement.
pub struct Tab1Row {
    /// Pruning arm.
    pub arm: MethodArm,
    /// Total sparsity in percent.
    pub sparsity_pct: usize,
    /// Weighted retained-saliency ratio.
    pub retention: f64,
}

/// Run the Table 1 sweep on the DeiT-base catalog.
pub fn tab1(scale: EvalScale, seed: u64) -> Vec<Tab1Row> {
    let v = if scale == EvalScale::Full { 32 } else { 8 };
    let layers = materialize(&deit_base(), scale, v, /*second_order=*/ true, seed);
    let mut rows = Vec::new();
    for &s in &SPARSITIES_PCT {
        for &arm in &ARMS {
            let retention = model_retention(arm, &layers, v, s as f64 / 100.0, seed ^ s as u64);
            rows.push(Tab1Row { arm, sparsity_pct: s, retention });
        }
    }
    rows
}

/// Render the Table 1 report.
pub fn render(rows: &[Tab1Row]) -> String {
    let mut t = Table::new(&["method", "s=65%", "s=75%", "s=85%"]);
    for &arm in &ARMS {
        let label = if arm == MethodArm::Unstructured { "CAP (elem 2nd-order)" } else { arm.label() };
        let mut cells = vec![label.to_string()];
        for &s in &SPARSITIES_PCT {
            let r = rows
                .iter()
                .find(|r| r.arm == arm && r.sparsity_pct == s)
                .map(|r| r.retention)
                .unwrap_or(f64::NAN);
            cells.push(format!("{:.4}", r));
        }
        t.row(cells);
    }
    format!("# Table 1 — DeiT-base one-shot (2nd-order saliency), retained ratio\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab1_ordering() {
        let rows = tab1(EvalScale::Tiny, 21);
        for &s in &SPARSITIES_PCT {
            let get = |arm| {
                rows.iter()
                    .find(|r| r.arm == arm && r.sparsity_pct == s)
                    .unwrap()
                    .retention
            };
            assert!(get(MethodArm::HinmGyro) > get(MethodArm::HinmNoPerm), "s={s}");
            assert!(get(MethodArm::Unstructured) >= get(MethodArm::HinmGyro) * 0.97, "s={s}");
        }
    }

    #[test]
    fn hinm_gap_to_cap_is_small_at_moderate_sparsity() {
        // Paper: HiNM ≈ CAP at 65/75%. Check the retention gap < 10%.
        let rows = tab1(EvalScale::Tiny, 22);
        let get = |arm: MethodArm, s: usize| {
            rows.iter()
                .find(|r| r.arm == arm && r.sparsity_pct == s)
                .unwrap()
                .retention
        };
        for s in [65, 75] {
            let gap = get(MethodArm::Unstructured, s) - get(MethodArm::HinmGyro, s);
            assert!(gap < 0.12, "s={s}: gap {gap}");
        }
    }
}
