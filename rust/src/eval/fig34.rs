//! Figures 3 & 4: one-shot pruning sweeps on ResNet-18/50 shapes.
//!
//! Paper: top-1 accuracy vs total sparsity {50, 65, 75, 85}% for arms
//! Dense / HiNM(+gyro) / HiNM-NoPerm / OVW / Unstructured, V = 32,
//! magnitude saliency. Here: retained-saliency ratio on the same layer
//! shapes (see `common` for the surrogate rationale). Headline checks:
//! HiNM > OVW > HiNM-NoPerm, HiNM ≈ Unstructured, gaps widening with
//! sparsity.

use super::common::{materialize, model_retention, EvalScale, MethodArm};
use crate::models::catalog::{resnet18, resnet50, ModelCatalog};
use crate::util::bench::Table;

/// Total-sparsity sweep of Figs. 3–4.
pub const SPARSITIES_PCT: [usize; 4] = [50, 65, 75, 85];
/// Arms compared in Figs. 3–4.
pub const ARMS: [MethodArm; 5] = [
    MethodArm::Dense,
    MethodArm::HinmGyro,
    MethodArm::HinmNoPerm,
    MethodArm::Ovw,
    MethodArm::Unstructured,
];

#[derive(Clone, Debug)]
/// One (arm, sparsity) measurement.
pub struct SweepRow {
    /// Pruning arm.
    pub arm: MethodArm,
    /// Total sparsity in percent.
    pub sparsity_pct: usize,
    /// Weighted retained-saliency ratio across layers.
    pub retention: f64,
}

/// Run the one-shot sweep for one model catalog.
pub fn run_model(catalog: &ModelCatalog, scale: EvalScale, v: usize, seed: u64) -> Vec<SweepRow> {
    let layers = materialize(catalog, scale, v, false, seed);
    let mut rows = Vec::new();
    for &s in &SPARSITIES_PCT {
        let total = s as f64 / 100.0;
        for &arm in &ARMS {
            let retention = model_retention(arm, &layers, v, total, seed ^ s as u64);
            rows.push(SweepRow { arm, sparsity_pct: s, retention });
        }
    }
    rows
}

/// Fig. 3 (ResNet-18).
pub fn fig3(scale: EvalScale, seed: u64) -> Vec<SweepRow> {
    let v = if scale == EvalScale::Full { 32 } else { 8 };
    run_model(&resnet18(), scale, v, seed)
}

/// Fig. 4 (ResNet-50).
pub fn fig4(scale: EvalScale, seed: u64) -> Vec<SweepRow> {
    let v = if scale == EvalScale::Full { 32 } else { 8 };
    run_model(&resnet50(), scale, v, seed)
}

/// Render the sweep as the paper's figure layout (arms × sparsities).
pub fn render(rows: &[SweepRow], title: &str) -> String {
    let mut t = Table::new(&["method", "s=50%", "s=65%", "s=75%", "s=85%"]);
    for &arm in &ARMS {
        let mut cells = vec![arm.label().to_string()];
        for &s in &SPARSITIES_PCT {
            let r = rows
                .iter()
                .find(|r| r.arm == arm && r.sparsity_pct == s)
                .map(|r| r.retention)
                .unwrap_or(f64::NAN);
            cells.push(format!("{:.4}", r));
        }
        t.row(cells);
    }
    format!("# {title} — retained saliency ratio\n{}", t.render())
}

/// The paper's headline delta at 75%: gyro-permutation gain over NoPerm.
pub fn permutation_gain_at(rows: &[SweepRow], sparsity_pct: usize) -> f64 {
    let get = |arm| {
        rows.iter()
            .find(|r| r.arm == arm && r.sparsity_pct == sparsity_pct)
            .map(|r| r.retention)
            .unwrap_or(f64::NAN)
    };
    get(MethodArm::HinmGyro) - get(MethodArm::HinmNoPerm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_tiny_preserves_paper_ordering() {
        let rows = fig3(EvalScale::Tiny, 11);
        for &s in &[65usize, 75, 85] {
            let get = |arm| {
                rows.iter()
                    .find(|r| r.arm == arm && r.sparsity_pct == s)
                    .unwrap()
                    .retention
            };
            let dense = get(MethodArm::Dense);
            let gyro = get(MethodArm::HinmGyro);
            let noperm = get(MethodArm::HinmNoPerm);
            let unstructured = get(MethodArm::Unstructured);
            assert_eq!(dense, 1.0);
            assert!(gyro > noperm, "s={s}: gyro {gyro} vs noperm {noperm}");
            assert!(unstructured >= gyro * 0.97, "s={s}");
            assert!(gyro < 1.0 && gyro > 0.0);
        }
    }

    #[test]
    fn gain_grows_with_sparsity() {
        let rows = fig3(EvalScale::Tiny, 12);
        let g65 = permutation_gain_at(&rows, 65);
        let g85 = permutation_gain_at(&rows, 85);
        assert!(g85 > 0.0 && g65 > 0.0);
    }

    #[test]
    fn render_contains_all_arms() {
        let rows = fig3(EvalScale::Tiny, 13);
        let s = render(&rows, "Fig3");
        for arm in ARMS {
            assert!(s.contains(arm.label()));
        }
    }
}
