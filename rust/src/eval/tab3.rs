//! Table 3: permutation ablation at 75% on ResNet-18/50 shapes —
//! HiNM (gyro OCP + gyro ICP) vs HiNM-V1 (OVW K-means OCP + gyro ICP) vs
//! HiNM-V2 (gyro OCP + Apex swap ICP), plus the registry-era extension
//! HiNM-V3 (gyro OCP + Tetris swap ICP). All arms run through the same
//! `StrategyRegistry` → `PermutePipeline` path the CLI uses.

use super::common::{materialize, model_retention, EvalScale, MethodArm};
use crate::models::catalog::{resnet18, resnet50};
use crate::util::bench::Table;

/// OCP/ICP ablation arms of Table 3 (+ the V3 extension).
pub const ARMS: [MethodArm; 4] =
    [MethodArm::HinmGyro, MethodArm::HinmV1, MethodArm::HinmV2, MethodArm::HinmV3];

#[derive(Clone, Debug)]
/// One (model, arm) measurement.
pub struct Tab3Row {
    /// Catalog name (`resnet18` / `resnet50`).
    pub model: &'static str,
    /// Ablation arm.
    pub arm: MethodArm,
    /// Weighted retained-saliency ratio at 75%.
    pub retention: f64,
}

/// Run the Table 3 ablation on both ResNet catalogs.
pub fn tab3(scale: EvalScale, seed: u64) -> Vec<Tab3Row> {
    let v = if scale == EvalScale::Full { 32 } else { 8 };
    let mut rows = Vec::new();
    for (name, catalog) in [("resnet18", resnet18()), ("resnet50", resnet50())] {
        let layers = materialize(&catalog, scale, v, false, seed);
        for &arm in &ARMS {
            let retention = model_retention(arm, &layers, v, 0.75, seed);
            rows.push(Tab3Row { model: name, arm, retention });
        }
    }
    rows
}

/// Render the Table 3 report.
pub fn render(rows: &[Tab3Row]) -> String {
    let mut t = Table::new(&["model", "method", "spec", "retained ratio"]);
    for r in rows {
        let spec = r.arm.spec().map(|s| s.key()).unwrap_or_default();
        t.row(vec![
            r.model.to_string(),
            r.arm.label().to_string(),
            spec,
            format!("{:.4}", r.retention),
        ]);
    }
    format!("# Table 3 — ablation @75% (OCP / ICP variants)\n{}", t.render())
}

/// Paper's check: full gyro ≥ both ablation arms on both models, within
/// `tol` (the paper's own ResNet-50 gaps are < 1%; at reduced scales the
/// arms are within run-to-run noise, so tests pass a small tolerance while
/// the full-scale bench asserts a strict win on the aggregate).
pub fn gyro_wins(rows: &[Tab3Row], tol: f64) -> bool {
    for model in ["resnet18", "resnet50"] {
        let get = |arm: MethodArm| {
            rows.iter()
                .find(|r| r.model == model && r.arm == arm)
                .map(|r| r.retention)
                .unwrap_or(f64::NAN)
        };
        let full = get(MethodArm::HinmGyro);
        if full < get(MethodArm::HinmV1) - tol || full < get(MethodArm::HinmV2) - tol {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab3_gyro_wins_ablation_within_noise() {
        let rows = tab3(EvalScale::Tiny, 41);
        assert!(gyro_wins(&rows, 0.005), "{rows:?}");
        assert_eq!(rows.len(), 8);
        // Gyro must strictly beat V1 (the clustering-only OCP) on ResNet-18,
        // the paper's largest reported gap (4.53%).
        let get = |m: &str, a: MethodArm| {
            rows.iter().find(|r| r.model == m && r.arm == a).unwrap().retention
        };
        assert!(get("resnet18", MethodArm::HinmGyro) >= get("resnet18", MethodArm::HinmV1));
        // The V3 arm (gyro+tetris through the registry) must be sane: a
        // valid retention in (0, 1], and — guarded — never below NoPerm
        // would be checked elsewhere; here just bound it loosely.
        for m in ["resnet18", "resnet50"] {
            let v3 = get(m, MethodArm::HinmV3);
            assert!(v3 > 0.0 && v3 <= 1.0, "{m} V3 retention {v3}");
        }
    }
}
