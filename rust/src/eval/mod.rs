//! Evaluation harness: one driver per table/figure in the paper's §5
//! (see DESIGN.md §5 for the experiment index).

pub mod common;
pub mod fig34;
pub mod fig5;
pub mod tab1;
pub mod tab2;
pub mod tab3;

pub use common::{EvalScale, MethodArm};
