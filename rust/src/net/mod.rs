//! Network serving layer: the HTTP/JSON front door over the batch engine.
//!
//! PR 2's [`BatchServer`](crate::coordinator::BatchServer) is only
//! reachable from in-process Rust; this module opens it to external
//! clients without adding any heavy dependency:
//!
//! * [`http`] — the HTTP/1.1 transport (acceptor + worker pool over
//!   `std::net::TcpListener`) and a matching minimal client.
//! * [`protocol`] — the `/v1/*` JSON wire types over [`crate::util::json`].
//! * [`HttpFront`] — binds an address and routes three endpoints onto a
//!   [`ServerHandle`]:
//!
//! | Route | Method | Behaviour |
//! |---|---|---|
//! | `/v1/infer` | POST | body `{"x": [...], "priority"?, "deadline_ms"?}` → `{"y": [...]}`; scheduling honored by the engine queue |
//! | `/v1/metrics` | GET | engine + scheduler + cache counters as JSON; `?format=prometheus` renders the same counters in the Prometheus text exposition format |
//! | `/healthz` | GET | liveness probe, `{"status": "ok"}` |
//!
//! When serving a model directory ([`HttpFront::start_multi`], DESIGN.md
//! §18) the same front fans out over one engine per registry model:
//!
//! | Route | Method | Behaviour |
//! |---|---|---|
//! | `/v1/infer` | POST | body gains optional `"model"`; unknown name → 404, absent name → the default model (old clients keep working) |
//! | `/v1/models` | GET | model names, the default, and per-model routed-request counts |
//! | `/v1/metrics` | GET | `?model=NAME` selects the engine (default model otherwise); adds per-model routing counters |
//! | `/v1/admin/reload` | POST | rescan the model dir and hot-swap changed versions; returns the per-model [`ReloadReport`](crate::runtime::ReloadReport) |
//! | `/healthz` | GET | liveness probe + model count |
//!
//! Above the single-host fronts sits the `hinm route` router tier
//! ([`route`], DESIGN.md §19): a separate process fanning `POST /v1/infer`
//! out over many `hinm serve` hosts with health probing, deadline-aware
//! retries, hedging, and circuit breaking:
//!
//! | Route | Method | Behaviour |
//! |---|---|---|
//! | `/v1/infer` | POST | proxied to the least-loaded live backend; body and response bytes pass through verbatim; `X-Hinm-Attempt` reports attempts spent |
//! | `/v1/metrics` | GET | router counters (hedges/retries/breaker trips) + per-backend breaker state, JSON or `?format=prometheus` |
//! | `/v1/models` | GET | union of the models the live backends advertise |
//! | `/healthz` | GET | liveness + live/total backend counts |
//!
//! *Below* the single-host front sits the cross-host stage tier
//! ([`stage_wire`], DESIGN.md §20): `hinm serve --stage-hosts` drives a
//! chain of `hinm stage` processes over persistent TCP links speaking a
//! length-prefixed binary activation-frame protocol (schema version,
//! batch dims, seq id, f32 LE payload, FNV-1a64 checksum). The frame
//! codec here is clock-free; link timing, reconnect backoff, and per-link
//! metrics live in [`crate::runtime::RemotePipelinedBackend`] and
//! [`crate::coordinator::StageLinkMetrics`], and the serve head's
//! `/v1/metrics` gains per-link counters in both formats
//! ([`HttpFront::start_with_links`]).
//!
//! Backpressure propagates naturally: a full engine queue blocks the HTTP
//! worker inside `infer_opts`, which stalls that connection while the
//! other pool workers keep serving. Engine errors map onto status codes
//! via [`protocol::status_for`] (timeout → 504, stopped → 503, upstream
//! refused/reset → 502, upstream timeout → 504, …) through the shared
//! [`protocol::error_response`] renderer.

pub mod http;
pub mod protocol;
pub mod route;
pub mod stage_wire;

use crate::coordinator::metrics::ModelCounters;
use crate::coordinator::serve::ServerHandle;
use crate::coordinator::stage_host::StageLinkMetrics;
use crate::runtime::backend::CacheStats;
use crate::spmm::KernelInfo;
use crate::util::json::{self, Json};
use anyhow::{bail, Result};
use http::{Handler, HttpRequest, HttpResponse, HttpServer};
use protocol::InferRequest;
use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

pub use http::HttpClient;
pub use route::{FaultyBackend, RouterFront};

/// The HTTP front door: owns the listener/worker threads and the routes.
///
/// # Examples
///
/// ```
/// use hinm::coordinator::{BatchServer, ServeConfig};
/// use hinm::models::{Activation, HinmModel};
/// use hinm::net::{HttpClient, HttpFront};
/// use hinm::sparsity::HinmConfig;
/// use std::sync::Arc;
/// use std::time::Duration;
///
/// let cfg = HinmConfig::with_24(4, 0.5);
/// let model = Arc::new(HinmModel::synthetic_ffn(16, 32, &cfg, Activation::Relu, 7)?);
/// let server = BatchServer::start_native(
///     model,
///     ServeConfig::new(4, Duration::from_micros(100)),
/// )?;
/// // Port 0 binds an ephemeral port; `local_addr` resolves it.
/// let front = HttpFront::start("127.0.0.1:0", server.handle.clone(), None, None, 2)?;
/// let mut client = HttpClient::connect(front.local_addr())?;
/// let (status, body) = client.get("/healthz")?;
/// assert_eq!(status, 200);
/// assert!(body.contains("ok"));
/// // Stop the front before the engine so in-flight requests get answers.
/// front.stop();
/// server.stop();
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct HttpFront {
    server: HttpServer,
}

impl HttpFront {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve the
    /// engine behind `handle` with `workers` connection-handler threads.
    /// Pass the engine's shared [`CacheStats`] to expose cache counters on
    /// `/v1/metrics`, and the backend's [`KernelInfo`] (native backends:
    /// [`crate::runtime::backend::NativeCpuBackend::kernel_info`]) to
    /// label the metrics with the dispatched microkernel variant.
    pub fn start(
        addr: &str,
        handle: ServerHandle,
        cache: Option<Arc<CacheStats>>,
        kernel: Option<KernelInfo>,
        workers: usize,
    ) -> Result<HttpFront> {
        Self::start_with_links(addr, handle, cache, kernel, None, workers)
    }

    /// [`HttpFront::start`] for a head driving cross-host pipeline stages
    /// (`hinm serve --stage-hosts`, DESIGN.md §20): additionally exposes
    /// the per-link batch/reconnect/failure counters and round-trip p95
    /// from `links` on `/v1/metrics`, in both formats.
    pub fn start_with_links(
        addr: &str,
        handle: ServerHandle,
        cache: Option<Arc<CacheStats>>,
        kernel: Option<KernelInfo>,
        links: Option<Arc<StageLinkMetrics>>,
        workers: usize,
    ) -> Result<HttpFront> {
        let handler: Handler = Arc::new(move |req: &HttpRequest| {
            route(req, &handle, cache.as_deref(), kernel, links.as_deref())
        });
        let server = HttpServer::start(addr, handler, workers)?;
        Ok(HttpFront { server })
    }

    /// The bound address (resolves an ephemeral-port bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.server.local_addr()
    }

    /// Stop accepting and join all HTTP threads. Stop the front *before*
    /// the engine so in-flight requests still get real answers.
    pub fn stop(self) {
        self.server.stop();
    }
}

fn route(
    req: &HttpRequest,
    engine: &ServerHandle,
    cache: Option<&CacheStats>,
    kernel: Option<KernelInfo>,
    links: Option<&StageLinkMetrics>,
) -> HttpResponse {
    let path = req.path.split('?').next().unwrap_or("");
    match path {
        "/healthz" => match req.method.as_str() {
            "GET" => HttpResponse::json(
                200,
                Json::obj(vec![("status", Json::str("ok"))]).compact(),
            ),
            _ => method_not_allowed(req, "GET"),
        },
        "/v1/metrics" => match req.method.as_str() {
            "GET" => metrics_route(req, engine, cache, kernel, links),
            _ => method_not_allowed(req, "GET"),
        },
        "/v1/infer" => match req.method.as_str() {
            "POST" => infer_route(req, engine),
            _ => method_not_allowed(req, "POST"),
        },
        _ => HttpResponse::json(
            404,
            protocol::error_body("not_found", &format!("no route for {} {}", req.method, path))
                .compact(),
        ),
    }
}

/// Content type of the Prometheus text exposition format.
const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// `GET /v1/metrics`: JSON by default, Prometheus text exposition with
/// `?format=prometheus`; any other `format` value is a 400.
fn metrics_route(
    req: &HttpRequest,
    engine: &ServerHandle,
    cache: Option<&CacheStats>,
    kernel: Option<KernelInfo>,
    links: Option<&StageLinkMetrics>,
) -> HttpResponse {
    let query = req.path.split_once('?').map(|(_, q)| q).unwrap_or("");
    let format = query
        .split('&')
        .find_map(|kv| kv.strip_prefix("format="))
        .unwrap_or("json");
    let link_snap = links.map(|l| l.snapshot());
    match format {
        "json" => {
            let mut body = protocol::metrics_json(engine.metrics(), cache, kernel.as_ref());
            if let (Some(snap), Json::Obj(map)) = (&link_snap, &mut body) {
                map.insert("stage_links".to_string(), protocol::stage_links_json(snap));
            }
            HttpResponse::json(200, body.compact())
        }
        "prometheus" => {
            let mut body = protocol::metrics_prometheus(engine.metrics(), cache, kernel.as_ref());
            if let Some(snap) = &link_snap {
                body.push_str(&protocol::stage_links_prometheus(snap));
            }
            HttpResponse {
                status: 200,
                content_type: PROMETHEUS_CONTENT_TYPE,
                body,
                headers: Vec::new(),
            }
        }
        other => HttpResponse::json(
            400,
            protocol::error_body(
                "bad_request",
                &format!("unknown metrics format {other:?} (expected json|prometheus)"),
            )
            .compact(),
        ),
    }
}

fn method_not_allowed(req: &HttpRequest, allowed: &str) -> HttpResponse {
    HttpResponse::json(
        405,
        protocol::error_body(
            "method_not_allowed",
            &format!("{} {} (use {allowed})", req.method, req.path),
        )
        .compact(),
    )
}

fn infer_route(req: &HttpRequest, engine: &ServerHandle) -> HttpResponse {
    let parsed = match json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => return HttpResponse::json(400, protocol::error_body("bad_json", &e).compact()),
    };
    let ir = match InferRequest::from_json(&parsed) {
        Ok(r) => r,
        Err(e) => return HttpResponse::json(400, protocol::error_body("bad_request", &e).compact()),
    };
    let deadline = ir.deadline_ms.map(Duration::from_millis);
    match engine.infer_opts(ir.x, ir.priority, deadline) {
        Ok(y) => HttpResponse::json(200, protocol::infer_response(&y).compact()),
        // One shared mapper (protocol::error_response) instead of an
        // open-coded status match: upstream I/O failures keep their 502/504
        // taxonomy here exactly as on the router tier, rather than
        // collapsing into a blanket 500.
        Err(e) => protocol::error_response(&e),
    }
}

/// One registry model as the multi-model front sees it: the engine handle
/// plus that engine's (per-model) cache counters for `/v1/metrics`.
pub struct ModelService {
    /// Handle into this model's [`BatchServer`](crate::coordinator::BatchServer).
    pub handle: ServerHandle,
    /// The model's cache counters, if its backend stack caches.
    pub cache: Option<Arc<CacheStats>>,
}

/// Rescan-and-swap callback invoked by `POST /v1/admin/reload`; returns
/// the rendered [`ReloadReport`](crate::runtime::ReloadReport) on success.
pub type ReloadFn = Arc<dyn Fn() -> std::result::Result<Json, String> + Send + Sync>;

/// Routing table for [`HttpFront::start_multi`]: one [`ModelService`] per
/// registry model, a default model for bodies without a `"model"` field,
/// the shared per-model request counters, and the reload hook (DESIGN.md
/// §18).
pub struct MultiRouter {
    /// Model name → serving handles, sorted for stable `/v1/models` output.
    pub services: BTreeMap<String, ModelService>,
    /// Model served when the request body has no `"model"` field.
    pub default_model: String,
    /// Per-model routed-request counters, surfaced on `/v1/metrics`.
    pub counters: Arc<ModelCounters>,
    /// Microkernel label for metrics (shared by all native backends).
    pub kernel: Option<KernelInfo>,
    /// Invoked by `POST /v1/admin/reload`.
    pub reload: ReloadFn,
}

impl HttpFront {
    /// Bind `addr` and serve *several* engines behind one front: requests
    /// route on the body's `"model"` field (absent → `default_model`,
    /// unknown → 404), and `POST /v1/admin/reload` triggers the router's
    /// rescan-and-swap hook. See the module docs for the route table.
    pub fn start_multi(addr: &str, router: MultiRouter, workers: usize) -> Result<HttpFront> {
        if !router.services.contains_key(&router.default_model) {
            bail!(
                "default model {:?} is not among the served models ({})",
                router.default_model,
                router.services.keys().cloned().collect::<Vec<_>>().join(", ")
            );
        }
        let router = Arc::new(router);
        let handler: Handler = Arc::new(move |req: &HttpRequest| route_multi(req, &router));
        let server = HttpServer::start(addr, handler, workers)?;
        Ok(HttpFront { server })
    }
}

fn route_multi(req: &HttpRequest, router: &MultiRouter) -> HttpResponse {
    let path = req.path.split('?').next().unwrap_or("");
    match path {
        "/healthz" => match req.method.as_str() {
            "GET" => HttpResponse::json(
                200,
                Json::obj(vec![
                    ("status", Json::str("ok")),
                    ("models", Json::num(router.services.len() as f64)),
                ])
                .compact(),
            ),
            _ => method_not_allowed(req, "GET"),
        },
        "/v1/models" => match req.method.as_str() {
            "GET" => models_route(router),
            _ => method_not_allowed(req, "GET"),
        },
        "/v1/metrics" => match req.method.as_str() {
            "GET" => metrics_multi_route(req, router),
            _ => method_not_allowed(req, "GET"),
        },
        "/v1/infer" => match req.method.as_str() {
            "POST" => infer_multi_route(req, router),
            _ => method_not_allowed(req, "POST"),
        },
        "/v1/admin/reload" => match req.method.as_str() {
            "POST" => match (router.reload)() {
                Ok(report) => HttpResponse::json(
                    200,
                    Json::obj(vec![("status", Json::str("ok")), ("report", report)]).compact(),
                ),
                Err(e) => HttpResponse::json(
                    500,
                    protocol::error_body("reload_failed", &e).compact(),
                ),
            },
            _ => method_not_allowed(req, "POST"),
        },
        _ => HttpResponse::json(
            404,
            protocol::error_body("not_found", &format!("no route for {} {}", req.method, path))
                .compact(),
        ),
    }
}

/// `GET /v1/models`: the catalog the front routes over, the default, and
/// how many requests each model has served so far.
fn models_route(router: &MultiRouter) -> HttpResponse {
    let routed: BTreeMap<String, u64> = router.counters.snapshot().into_iter().collect();
    let models = Json::Arr(
        router
            .services
            .keys()
            .map(|name| {
                Json::obj(vec![
                    ("name", Json::str(name)),
                    (
                        "requests",
                        Json::num(routed.get(name).copied().unwrap_or(0) as f64),
                    ),
                ])
            })
            .collect(),
    );
    HttpResponse::json(
        200,
        Json::obj(vec![
            ("default", Json::str(&router.default_model)),
            ("models", models),
        ])
        .compact(),
    )
}

/// `GET /v1/metrics` on the multi front: `?model=NAME` picks the engine
/// (default model otherwise); renders with the per-model routing counters.
fn metrics_multi_route(req: &HttpRequest, router: &MultiRouter) -> HttpResponse {
    let query = req.path.split_once('?').map(|(_, q)| q).unwrap_or("");
    let format = query
        .split('&')
        .find_map(|kv| kv.strip_prefix("format="))
        .unwrap_or("json");
    let name = query
        .split('&')
        .find_map(|kv| kv.strip_prefix("model="))
        .unwrap_or(&router.default_model);
    let Some(service) = router.services.get(name) else {
        return unknown_model(name, router);
    };
    let cache = service.cache.as_deref();
    let counters = Some(router.counters.as_ref());
    match format {
        "json" => HttpResponse::json(
            200,
            protocol::metrics_json_with_models(
                service.handle.metrics(),
                cache,
                router.kernel.as_ref(),
                counters,
            )
            .compact(),
        ),
        "prometheus" => HttpResponse {
            status: 200,
            content_type: PROMETHEUS_CONTENT_TYPE,
            body: protocol::metrics_prometheus_with_models(
                service.handle.metrics(),
                cache,
                router.kernel.as_ref(),
                counters,
            ),
            headers: Vec::new(),
        },
        other => HttpResponse::json(
            400,
            protocol::error_body(
                "bad_request",
                &format!("unknown metrics format {other:?} (expected json|prometheus)"),
            )
            .compact(),
        ),
    }
}

/// `POST /v1/infer` on the multi front: route on the body's `"model"`.
fn infer_multi_route(req: &HttpRequest, router: &MultiRouter) -> HttpResponse {
    let parsed = match json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => return HttpResponse::json(400, protocol::error_body("bad_json", &e).compact()),
    };
    let ir = match InferRequest::from_json(&parsed) {
        Ok(r) => r,
        Err(e) => return HttpResponse::json(400, protocol::error_body("bad_request", &e).compact()),
    };
    let name = ir.model.as_deref().unwrap_or(&router.default_model);
    let Some(service) = router.services.get(name) else {
        return unknown_model(name, router);
    };
    router.counters.record(name);
    let deadline = ir.deadline_ms.map(Duration::from_millis);
    match service.handle.infer_opts(ir.x, ir.priority, deadline) {
        Ok(y) => HttpResponse::json(200, protocol::infer_response(&y).compact()),
        Err(e) => protocol::error_response(&e),
    }
}

fn unknown_model(name: &str, router: &MultiRouter) -> HttpResponse {
    HttpResponse::json(
        404,
        protocol::error_body(
            "unknown_model",
            &format!(
                "no model {:?} (GET /v1/models lists: {})",
                name,
                router.services.keys().cloned().collect::<Vec<_>>().join(", ")
            ),
        )
        .compact(),
    )
}
