//! Network serving layer: the HTTP/JSON front door over the batch engine.
//!
//! PR 2's [`BatchServer`](crate::coordinator::BatchServer) is only
//! reachable from in-process Rust; this module opens it to external
//! clients without adding any heavy dependency:
//!
//! * [`http`] — the HTTP/1.1 transport (acceptor + worker pool over
//!   `std::net::TcpListener`) and a matching minimal client.
//! * [`protocol`] — the `/v1/*` JSON wire types over [`crate::util::json`].
//! * [`HttpFront`] — binds an address and routes three endpoints onto a
//!   [`ServerHandle`]:
//!
//! | Route | Method | Behaviour |
//! |---|---|---|
//! | `/v1/infer` | POST | body `{"x": [...], "priority"?, "deadline_ms"?}` → `{"y": [...]}`; scheduling honored by the engine queue |
//! | `/v1/metrics` | GET | engine + scheduler + cache counters as JSON; `?format=prometheus` renders the same counters in the Prometheus text exposition format |
//! | `/healthz` | GET | liveness probe, `{"status": "ok"}` |
//!
//! Backpressure propagates naturally: a full engine queue blocks the HTTP
//! worker inside `infer_opts`, which stalls that connection while the
//! other pool workers keep serving. Engine errors map onto status codes
//! via [`protocol::status_for`] (timeout → 504, stopped → 503, …).

pub mod http;
pub mod protocol;

use crate::coordinator::serve::ServerHandle;
use crate::runtime::backend::CacheStats;
use crate::spmm::KernelInfo;
use crate::util::json::{self, Json};
use anyhow::Result;
use http::{Handler, HttpRequest, HttpResponse, HttpServer};
use protocol::InferRequest;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

pub use http::HttpClient;

/// The HTTP front door: owns the listener/worker threads and the routes.
///
/// # Examples
///
/// ```
/// use hinm::coordinator::{BatchServer, ServeConfig};
/// use hinm::models::{Activation, HinmModel};
/// use hinm::net::{HttpClient, HttpFront};
/// use hinm::sparsity::HinmConfig;
/// use std::sync::Arc;
/// use std::time::Duration;
///
/// let cfg = HinmConfig::with_24(4, 0.5);
/// let model = Arc::new(HinmModel::synthetic_ffn(16, 32, &cfg, Activation::Relu, 7)?);
/// let server = BatchServer::start_native(
///     model,
///     ServeConfig::new(4, Duration::from_micros(100)),
/// )?;
/// // Port 0 binds an ephemeral port; `local_addr` resolves it.
/// let front = HttpFront::start("127.0.0.1:0", server.handle.clone(), None, None, 2)?;
/// let mut client = HttpClient::connect(front.local_addr())?;
/// let (status, body) = client.get("/healthz")?;
/// assert_eq!(status, 200);
/// assert!(body.contains("ok"));
/// // Stop the front before the engine so in-flight requests get answers.
/// front.stop();
/// server.stop();
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct HttpFront {
    server: HttpServer,
}

impl HttpFront {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve the
    /// engine behind `handle` with `workers` connection-handler threads.
    /// Pass the engine's shared [`CacheStats`] to expose cache counters on
    /// `/v1/metrics`, and the backend's [`KernelInfo`] (native backends:
    /// [`crate::runtime::backend::NativeCpuBackend::kernel_info`]) to
    /// label the metrics with the dispatched microkernel variant.
    pub fn start(
        addr: &str,
        handle: ServerHandle,
        cache: Option<Arc<CacheStats>>,
        kernel: Option<KernelInfo>,
        workers: usize,
    ) -> Result<HttpFront> {
        let handler: Handler =
            Arc::new(move |req: &HttpRequest| route(req, &handle, cache.as_deref(), kernel));
        let server = HttpServer::start(addr, handler, workers)?;
        Ok(HttpFront { server })
    }

    /// The bound address (resolves an ephemeral-port bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.server.local_addr()
    }

    /// Stop accepting and join all HTTP threads. Stop the front *before*
    /// the engine so in-flight requests still get real answers.
    pub fn stop(self) {
        self.server.stop();
    }
}

fn route(
    req: &HttpRequest,
    engine: &ServerHandle,
    cache: Option<&CacheStats>,
    kernel: Option<KernelInfo>,
) -> HttpResponse {
    let path = req.path.split('?').next().unwrap_or("");
    match path {
        "/healthz" => match req.method.as_str() {
            "GET" => HttpResponse::json(
                200,
                Json::obj(vec![("status", Json::str("ok"))]).compact(),
            ),
            _ => method_not_allowed(req, "GET"),
        },
        "/v1/metrics" => match req.method.as_str() {
            "GET" => metrics_route(req, engine, cache, kernel),
            _ => method_not_allowed(req, "GET"),
        },
        "/v1/infer" => match req.method.as_str() {
            "POST" => infer_route(req, engine),
            _ => method_not_allowed(req, "POST"),
        },
        _ => HttpResponse::json(
            404,
            protocol::error_body("not_found", &format!("no route for {} {}", req.method, path))
                .compact(),
        ),
    }
}

/// Content type of the Prometheus text exposition format.
const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// `GET /v1/metrics`: JSON by default, Prometheus text exposition with
/// `?format=prometheus`; any other `format` value is a 400.
fn metrics_route(
    req: &HttpRequest,
    engine: &ServerHandle,
    cache: Option<&CacheStats>,
    kernel: Option<KernelInfo>,
) -> HttpResponse {
    let query = req.path.split_once('?').map(|(_, q)| q).unwrap_or("");
    let format = query
        .split('&')
        .find_map(|kv| kv.strip_prefix("format="))
        .unwrap_or("json");
    match format {
        "json" => HttpResponse::json(
            200,
            protocol::metrics_json(engine.metrics(), cache, kernel.as_ref()).compact(),
        ),
        "prometheus" => HttpResponse {
            status: 200,
            content_type: PROMETHEUS_CONTENT_TYPE,
            body: protocol::metrics_prometheus(engine.metrics(), cache, kernel.as_ref()),
        },
        other => HttpResponse::json(
            400,
            protocol::error_body(
                "bad_request",
                &format!("unknown metrics format {other:?} (expected json|prometheus)"),
            )
            .compact(),
        ),
    }
}

fn method_not_allowed(req: &HttpRequest, allowed: &str) -> HttpResponse {
    HttpResponse::json(
        405,
        protocol::error_body(
            "method_not_allowed",
            &format!("{} {} (use {allowed})", req.method, req.path),
        )
        .compact(),
    )
}

fn infer_route(req: &HttpRequest, engine: &ServerHandle) -> HttpResponse {
    let parsed = match json::parse(&req.body) {
        Ok(v) => v,
        Err(e) => return HttpResponse::json(400, protocol::error_body("bad_json", &e).compact()),
    };
    let ir = match InferRequest::from_json(&parsed) {
        Ok(r) => r,
        Err(e) => return HttpResponse::json(400, protocol::error_body("bad_request", &e).compact()),
    };
    let deadline = ir.deadline_ms.map(Duration::from_millis);
    match engine.infer_opts(ir.x, ir.priority, deadline) {
        Ok(y) => HttpResponse::json(200, protocol::infer_response(&y).compact()),
        Err(e) => {
            let (status, kind) = protocol::status_for(&e);
            HttpResponse::json(status, protocol::error_body(kind, &e.to_string()).compact())
        }
    }
}
