//! Line-oriented HTTP/1.1 transport over `std::net` — no external deps.
//!
//! The offline environment has no hyper/axum, and the serving front needs
//! only a small, predictable subset of HTTP/1.1: request line + headers +
//! `Content-Length` body, keep-alive connections, and JSON payloads. This
//! module implements exactly that subset as a transport layer:
//!
//! * [`HttpServer`] — one acceptor thread feeding a bounded worker pool
//!   over an mpsc channel; each worker runs a keep-alive read loop per
//!   connection. Routing is a plain `Fn(&HttpRequest) -> HttpResponse`
//!   handler, so the transport knows nothing about the inference engine
//!   (the routes live in [`crate::net`]).
//! * [`HttpClient`] — a matching minimal client (one reused connection,
//!   blocking request/response) used by the integration tests, the
//!   `serve_throughput` bench's socket mode, and available to external
//!   Rust callers.
//!
//! Deliberate non-goals: TLS, chunked transfer encoding, HTTP/2,
//! pipelining. Requests with bodies must send `Content-Length`.

use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use crate::util::sync::lock_unpoisoned;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Largest accepted request body; bigger requests are rejected during
/// header parsing (guards against a client promising a multi-GB body).
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// Longest accepted request/header line in bytes; a longer line is a 400.
/// Bounds per-connection memory against a client streaming an endless
/// header (the body is separately bounded by [`MAX_BODY_BYTES`]).
pub const MAX_LINE_BYTES: u64 = 8 * 1024;

/// Most headers accepted per request; more is a 400.
pub const MAX_HEADERS: usize = 100;

/// How long a worker waits on an idle keep-alive connection before closing
/// it. Bounds how long [`HttpServer::stop`] can block on live connections.
pub const IDLE_TIMEOUT: Duration = Duration::from_secs(5);

/// One parsed HTTP request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    /// Request method, uppercase as sent (`GET`, `POST`, …).
    pub method: String,
    /// Request target as sent, including any query string.
    pub path: String,
    /// Headers with lowercased names, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Decoded body (empty when no `Content-Length` was sent).
    pub body: String,
}

impl HttpRequest {
    /// First header value for `name` (lowercase), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// One HTTP response: status + JSON (or plain-text) body.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    /// Status code (200, 400, 404, 405, 500, 503, 504, …).
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Response body.
    pub body: String,
}

impl HttpResponse {
    /// A JSON response with the given status.
    pub fn json(status: u16, body: String) -> HttpResponse {
        HttpResponse { status, content_type: "application/json", body }
    }
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Route/handler function: pure request → response. Must be `Send + Sync`
/// because every pool worker shares it.
pub type Handler = Arc<dyn Fn(&HttpRequest) -> HttpResponse + Send + Sync>;

/// Threaded HTTP/1.1 server: one acceptor + `workers` handler threads.
///
/// Concurrency model: **one worker per live connection** (a worker runs a
/// connection's keep-alive loop until it closes or idles out after
/// [`IDLE_TIMEOUT`]), so size `workers` to the expected number of
/// concurrent keep-alive clients. Accepted-but-unclaimed connections wait
/// in a *bounded* hand-off queue; when it fills, the acceptor stops
/// accepting and further clients queue in (and eventually overflow) the
/// OS listen backlog instead of growing server memory.
///
/// `stop()` (or drop) closes the acceptor, lets the workers drain any
/// already-accepted connections, and joins every thread. A worker parked
/// on an idle keep-alive connection notices within [`IDLE_TIMEOUT`].
pub struct HttpServer {
    addr: SocketAddr,
    stopping: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting. Every accepted connection is dispatched to one of
    /// `workers` pool threads running `handler` per request.
    pub fn start(addr: &str, handler: Handler, workers: usize) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding HTTP listener on {addr}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let stopping = Arc::new(AtomicBool::new(false));
        // Bounded hand-off: a full queue blocks the acceptor (backpressure
        // via the OS listen backlog) instead of buffering connections
        // without limit while every worker is pinned to a live client.
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(workers.max(1) * 4);
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let mut pool = Vec::with_capacity(workers.max(1));
        for w in 0..workers.max(1) {
            let rx = Arc::clone(&conn_rx);
            let h = Arc::clone(&handler);
            let stop = Arc::clone(&stopping);
            let t = std::thread::Builder::new()
                .name(format!("hinm-http-{w}"))
                .spawn(move || loop {
                    // Hold the lock only while waiting for a connection;
                    // handling runs unlocked so workers serve in parallel.
                    // Poison-tolerant: a worker that panicked mid-recv must
                    // not take the whole acceptor pool down with it — the
                    // surviving workers keep draining connections (R4).
                    let conn = { lock_unpoisoned(&rx).recv() };
                    match conn {
                        Ok(stream) => handle_connection(stream, h.as_ref(), &stop),
                        Err(_) => break, // acceptor gone and queue drained
                    }
                })
                .context("spawning HTTP worker")?;
            pool.push(t);
        }

        let stop2 = Arc::clone(&stopping);
        let acceptor = std::thread::Builder::new()
            .name("hinm-http-accept".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            if conn_tx.send(stream).is_err() {
                                break;
                            }
                        }
                        Err(_) => {
                            if stop2.load(Ordering::SeqCst) {
                                break;
                            }
                            // Persistent accept failures (e.g. fd
                            // exhaustion) must not busy-spin the acceptor.
                            std::thread::sleep(Duration::from_millis(50));
                        }
                    }
                }
                // Dropping conn_tx here lets the pool drain and exit.
            })
            .context("spawning HTTP acceptor")?;

        Ok(HttpServer { addr, stopping, acceptor: Some(acceptor), workers: pool })
    }

    /// The bound socket address (useful with an ephemeral `:0` bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain queued connections, join every thread.
    pub fn stop(self) {
        // Drop runs the shutdown sequence.
    }

    fn shutdown(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        // The acceptor blocks in accept(); a throwaway connection wakes it
        // so it can observe the flag and exit.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Keep-alive loop: parse a request, run the handler, write the response;
/// repeat until EOF, `Connection: close`, idle timeout, a malformed
/// request (answered with 400, then closed), or server shutdown. The
/// `stopping` flag is checked between requests so an *active* keep-alive
/// client cannot pin its worker past [`HttpServer::stop`] — the last
/// response before closing carries `Connection: close`.
fn handle_connection(
    stream: TcpStream,
    handler: &(dyn Fn(&HttpRequest) -> HttpResponse + Send + Sync),
    stopping: &AtomicBool,
) {
    let _ = stream.set_read_timeout(Some(IDLE_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IDLE_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut writer = stream;
    let mut reader = match writer.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    loop {
        if stopping.load(Ordering::SeqCst) {
            break;
        }
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => break, // clean close from the client
            Err(e) => {
                if e.kind() == std::io::ErrorKind::InvalidData {
                    let resp = HttpResponse::json(
                        400,
                        format!("{{\"error\": {{\"kind\": \"bad_http\", \"message\": \"{e}\"}}}}"),
                    );
                    let _ = write_response(&mut writer, &resp, false);
                }
                break; // timeouts and I/O failures close quietly
            }
        };
        let keep_alive = !req
            .header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"))
            && !stopping.load(Ordering::SeqCst);
        let resp = handler(&req);
        if write_response(&mut writer, &resp, keep_alive).is_err() {
            break;
        }
        if !keep_alive {
            break;
        }
    }
}

fn invalid(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

/// Read one `\n`-terminated line of at most [`MAX_LINE_BYTES`] bytes.
/// Returns the byte count (0 = EOF); a line hitting the cap without a
/// newline is `InvalidData`.
fn read_line_limited<R: BufRead>(reader: &mut R, line: &mut String) -> std::io::Result<usize> {
    let n = reader.by_ref().take(MAX_LINE_BYTES).read_line(line)?;
    if n as u64 >= MAX_LINE_BYTES && !line.ends_with('\n') {
        return Err(invalid("header line too long"));
    }
    Ok(n)
}

/// Read one request. `Ok(None)` = clean EOF before a request started;
/// `ErrorKind::InvalidData` = malformed request (caller answers 400); any
/// other error = connection-level failure (caller closes quietly).
///
/// Generic over [`BufRead`] (not tied to a socket) so the fuzz harness
/// (`rust/tests/fuzz_http.rs`) can drive it from in-memory byte slices;
/// the server path instantiates it with `BufReader<TcpStream>`.
pub fn read_request<R: BufRead>(reader: &mut R) -> std::io::Result<Option<HttpRequest>> {
    let mut line = String::new();
    if read_line_limited(reader, &mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| invalid("empty request line"))?.to_string();
    let path = parts.next().ok_or_else(|| invalid("request line has no target"))?.to_string();
    if !parts.next().is_some_and(|v| v.starts_with("HTTP/")) {
        return Err(invalid("request line has no HTTP version"));
    }

    let mut headers = Vec::new();
    let mut content_len: Option<usize> = None;
    loop {
        if headers.len() > MAX_HEADERS {
            return Err(invalid("too many headers"));
        }
        let mut h = String::new();
        if read_line_limited(reader, &mut h)? == 0 {
            return Err(invalid("eof inside headers"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').ok_or_else(|| invalid("header without ':'"))?;
        let k = k.trim().to_ascii_lowercase();
        let v = v.trim().to_string();
        if k == "transfer-encoding" {
            // Only Content-Length framing is spoken here; misparsing a
            // chunked body as the next request would desync the
            // connection (request smuggling), so reject it outright.
            return Err(invalid("Transfer-Encoding is not supported"));
        }
        if k == "content-length" {
            let n: usize = v.parse().map_err(|_| invalid("unparseable Content-Length"))?;
            if content_len.is_some_and(|prev| prev != n) {
                return Err(invalid("conflicting Content-Length headers"));
            }
            if n > MAX_BODY_BYTES {
                return Err(invalid("request body too large"));
            }
            content_len = Some(n);
        }
        headers.push((k, v));
    }
    let content_len = content_len.unwrap_or(0);

    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8(body).map_err(|_| invalid("body is not UTF-8"))?;
    Ok(Some(HttpRequest { method, path, headers, body }))
}

fn write_response(
    stream: &mut TcpStream,
    resp: &HttpResponse,
    keep_alive: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        resp.status,
        status_reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(resp.body.as_bytes())?;
    stream.flush()
}

/// Minimal blocking HTTP/1.1 client over one keep-alive connection.
///
/// Sends `Content-Length`-framed requests and reads framed responses;
/// exactly the dialect [`HttpServer`] speaks. Used by the integration
/// tests and the socket-mode load bench.
pub struct HttpClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl HttpClient {
    /// Connect to a server (e.g. the address from
    /// [`HttpServer::local_addr`]).
    pub fn connect(addr: SocketAddr) -> Result<HttpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone().context("cloning client stream")?);
        Ok(HttpClient { stream, reader })
    }

    /// `GET path` → `(status, body)`.
    pub fn get(&mut self, path: &str) -> Result<(u16, String)> {
        self.request("GET", path, None)
    }

    /// `POST path` with a JSON body → `(status, body)`.
    pub fn post_json(&mut self, path: &str, body: &str) -> Result<(u16, String)> {
        self.request("POST", path, Some(body))
    }

    /// Send one request and block for its response.
    pub fn request(&mut self, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
        let b = body.unwrap_or("");
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: hinm\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n{b}",
            b.len()
        );
        self.stream.write_all(req.as_bytes()).context("writing request")?;
        self.stream.flush().context("flushing request")?;

        let mut line = String::new();
        anyhow::ensure!(
            self.reader.read_line(&mut line).context("reading status line")? > 0,
            "server closed the connection before responding"
        );
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .with_context(|| format!("malformed status line {line:?}"))?
            .parse()
            .with_context(|| format!("malformed status in {line:?}"))?;

        let mut content_len = 0usize;
        loop {
            let mut h = String::new();
            anyhow::ensure!(
                self.reader.read_line(&mut h).context("reading header")? > 0,
                "eof in response headers"
            );
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                if k.trim().eq_ignore_ascii_case("content-length") {
                    content_len =
                        v.trim().parse().with_context(|| format!("bad Content-Length {v:?}"))?;
                }
            }
        }
        let mut body = vec![0u8; content_len];
        self.reader.read_exact(&mut body).context("reading response body")?;
        Ok((status, String::from_utf8(body).context("response body is not UTF-8")?))
    }
}
