//! Line-oriented HTTP/1.1 transport over `std::net` — no external deps.
//!
//! The offline environment has no hyper/axum, and the serving front needs
//! only a small, predictable subset of HTTP/1.1: request line + headers +
//! `Content-Length` body, keep-alive connections, and JSON payloads. This
//! module implements exactly that subset as a transport layer:
//!
//! * [`HttpServer`] — one acceptor thread feeding a bounded worker pool
//!   over an mpsc channel; each worker runs a keep-alive read loop per
//!   connection. Routing is a plain `Fn(&HttpRequest) -> HttpResponse`
//!   handler, so the transport knows nothing about the inference engine
//!   (the routes live in [`crate::net`]).
//! * [`HttpClient`] — a matching minimal client (one reused connection,
//!   blocking request/response) used by the integration tests, the
//!   `serve_throughput` bench's socket mode, and available to external
//!   Rust callers.
//!
//! Deliberate non-goals: TLS, chunked transfer encoding, HTTP/2,
//! pipelining. Requests with bodies must send `Content-Length`.

use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use crate::util::sync::lock_unpoisoned;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Largest accepted request body; bigger requests are rejected during
/// header parsing (guards against a client promising a multi-GB body).
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;

/// Longest accepted request/header line in bytes; a longer line is a 400.
/// Bounds per-connection memory against a client streaming an endless
/// header (the body is separately bounded by [`MAX_BODY_BYTES`]).
pub const MAX_LINE_BYTES: u64 = 8 * 1024;

/// Most headers accepted per request; more is a 400.
pub const MAX_HEADERS: usize = 100;

/// How long a worker waits on an idle keep-alive connection before closing
/// it. Bounds how long [`HttpServer::stop`] can block on live connections.
pub const IDLE_TIMEOUT: Duration = Duration::from_secs(5);

/// One parsed HTTP request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    /// Request method, uppercase as sent (`GET`, `POST`, …).
    pub method: String,
    /// Request target as sent, including any query string.
    pub path: String,
    /// Headers with lowercased names, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Decoded body (empty when no `Content-Length` was sent).
    pub body: String,
}

impl HttpRequest {
    /// First header value for `name` (lowercase), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// One HTTP response: status + JSON (or plain-text) body.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    /// Status code (200, 400, 404, 405, 500, 502, 503, 504, …).
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Response body.
    pub body: String,
    /// Extra response headers `(name, value)`, written verbatim after the
    /// framing headers. Empty for most responses; the router front uses it
    /// for `X-Hinm-Attempt` and `Retry-After`.
    pub headers: Vec<(String, String)>,
}

impl HttpResponse {
    /// A JSON response with the given status.
    pub fn json(status: u16, body: String) -> HttpResponse {
        HttpResponse { status, content_type: "application/json", body, headers: Vec::new() }
    }

    /// Attach an extra response header (builder style).
    pub fn with_header(mut self, name: &str, value: &str) -> HttpResponse {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Route/handler function: pure request → response. Must be `Send + Sync`
/// because every pool worker shares it.
pub type Handler = Arc<dyn Fn(&HttpRequest) -> HttpResponse + Send + Sync>;

/// Threaded HTTP/1.1 server: one acceptor + `workers` handler threads.
///
/// Concurrency model: **one worker per live connection** (a worker runs a
/// connection's keep-alive loop until it closes or idles out after
/// [`IDLE_TIMEOUT`]), so size `workers` to the expected number of
/// concurrent keep-alive clients. Accepted-but-unclaimed connections wait
/// in a *bounded* hand-off queue; when it fills, the acceptor stops
/// accepting and further clients queue in (and eventually overflow) the
/// OS listen backlog instead of growing server memory.
///
/// `stop()` (or drop) closes the acceptor, lets the workers drain any
/// already-accepted connections, and joins every thread. A worker parked
/// on an idle keep-alive connection notices within [`IDLE_TIMEOUT`].
pub struct HttpServer {
    addr: SocketAddr,
    stopping: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting. Every accepted connection is dispatched to one of
    /// `workers` pool threads running `handler` per request.
    pub fn start(addr: &str, handler: Handler, workers: usize) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding HTTP listener on {addr}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let stopping = Arc::new(AtomicBool::new(false));
        // Bounded hand-off: a full queue blocks the acceptor (backpressure
        // via the OS listen backlog) instead of buffering connections
        // without limit while every worker is pinned to a live client.
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(workers.max(1) * 4);
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let mut pool = Vec::with_capacity(workers.max(1));
        for w in 0..workers.max(1) {
            let rx = Arc::clone(&conn_rx);
            let h = Arc::clone(&handler);
            let stop = Arc::clone(&stopping);
            let t = std::thread::Builder::new()
                .name(format!("hinm-http-{w}"))
                .spawn(move || loop {
                    // Hold the lock only while waiting for a connection;
                    // handling runs unlocked so workers serve in parallel.
                    // Poison-tolerant: a worker that panicked mid-recv must
                    // not take the whole acceptor pool down with it — the
                    // surviving workers keep draining connections (R4).
                    let conn = { lock_unpoisoned(&rx).recv() };
                    match conn {
                        Ok(stream) => handle_connection(stream, h.as_ref(), &stop),
                        Err(_) => break, // acceptor gone and queue drained
                    }
                })
                .context("spawning HTTP worker")?;
            pool.push(t);
        }

        let stop2 = Arc::clone(&stopping);
        let acceptor = std::thread::Builder::new()
            .name("hinm-http-accept".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            if conn_tx.send(stream).is_err() {
                                break;
                            }
                        }
                        Err(_) => {
                            if stop2.load(Ordering::SeqCst) {
                                break;
                            }
                            // Persistent accept failures (e.g. fd
                            // exhaustion) must not busy-spin the acceptor.
                            std::thread::sleep(Duration::from_millis(50));
                        }
                    }
                }
                // Dropping conn_tx here lets the pool drain and exit.
            })
            .context("spawning HTTP acceptor")?;

        Ok(HttpServer { addr, stopping, acceptor: Some(acceptor), workers: pool })
    }

    /// The bound socket address (useful with an ephemeral `:0` bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain queued connections, join every thread.
    pub fn stop(self) {
        // Drop runs the shutdown sequence.
    }

    fn shutdown(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        // The acceptor blocks in accept(); a throwaway connection wakes it
        // so it can observe the flag and exit.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Keep-alive loop: parse a request, run the handler, write the response;
/// repeat until EOF, `Connection: close`, idle timeout, a malformed
/// request (answered with 400, then closed), or server shutdown. The
/// `stopping` flag is checked between requests so an *active* keep-alive
/// client cannot pin its worker past [`HttpServer::stop`] — the last
/// response before closing carries `Connection: close`.
fn handle_connection(
    stream: TcpStream,
    handler: &(dyn Fn(&HttpRequest) -> HttpResponse + Send + Sync),
    stopping: &AtomicBool,
) {
    let _ = stream.set_read_timeout(Some(IDLE_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IDLE_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut writer = stream;
    let mut reader = match writer.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    loop {
        if stopping.load(Ordering::SeqCst) {
            break;
        }
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => break, // clean close from the client
            Err(e) => {
                if e.kind() == std::io::ErrorKind::InvalidData {
                    let resp = HttpResponse::json(
                        400,
                        format!("{{\"error\": {{\"kind\": \"bad_http\", \"message\": \"{e}\"}}}}"),
                    );
                    let _ = write_response(&mut writer, &resp, false);
                }
                break; // timeouts and I/O failures close quietly
            }
        };
        let keep_alive = !req
            .header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"))
            && !stopping.load(Ordering::SeqCst);
        let resp = handler(&req);
        if write_response(&mut writer, &resp, keep_alive).is_err() {
            break;
        }
        if !keep_alive {
            break;
        }
    }
}

fn invalid(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

/// Read one `\n`-terminated line of at most [`MAX_LINE_BYTES`] bytes.
/// Returns the byte count (0 = EOF); a line hitting the cap without a
/// newline is `InvalidData`.
fn read_line_limited<R: BufRead>(reader: &mut R, line: &mut String) -> std::io::Result<usize> {
    let n = reader.by_ref().take(MAX_LINE_BYTES).read_line(line)?;
    if n as u64 >= MAX_LINE_BYTES && !line.ends_with('\n') {
        return Err(invalid("header line too long"));
    }
    Ok(n)
}

/// Read one request. `Ok(None)` = clean EOF before a request started;
/// `ErrorKind::InvalidData` = malformed request (caller answers 400); any
/// other error = connection-level failure (caller closes quietly).
///
/// Generic over [`BufRead`] (not tied to a socket) so the fuzz harness
/// (`rust/tests/fuzz_http.rs`) can drive it from in-memory byte slices;
/// the server path instantiates it with `BufReader<TcpStream>`.
pub fn read_request<R: BufRead>(reader: &mut R) -> std::io::Result<Option<HttpRequest>> {
    let mut line = String::new();
    if read_line_limited(reader, &mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| invalid("empty request line"))?.to_string();
    let path = parts.next().ok_or_else(|| invalid("request line has no target"))?.to_string();
    if !parts.next().is_some_and(|v| v.starts_with("HTTP/")) {
        return Err(invalid("request line has no HTTP version"));
    }

    let mut headers = Vec::new();
    let mut content_len: Option<usize> = None;
    loop {
        if headers.len() > MAX_HEADERS {
            return Err(invalid("too many headers"));
        }
        let mut h = String::new();
        if read_line_limited(reader, &mut h)? == 0 {
            return Err(invalid("eof inside headers"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').ok_or_else(|| invalid("header without ':'"))?;
        let k = k.trim().to_ascii_lowercase();
        let v = v.trim().to_string();
        if k == "transfer-encoding" {
            // Only Content-Length framing is spoken here; misparsing a
            // chunked body as the next request would desync the
            // connection (request smuggling), so reject it outright.
            return Err(invalid("Transfer-Encoding is not supported"));
        }
        if k == "content-length" {
            let n: usize = v.parse().map_err(|_| invalid("unparseable Content-Length"))?;
            if content_len.is_some_and(|prev| prev != n) {
                return Err(invalid("conflicting Content-Length headers"));
            }
            if n > MAX_BODY_BYTES {
                return Err(invalid("request body too large"));
            }
            content_len = Some(n);
        }
        headers.push((k, v));
    }
    let content_len = content_len.unwrap_or(0);

    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8(body).map_err(|_| invalid("body is not UTF-8"))?;
    Ok(Some(HttpRequest { method, path, headers, body }))
}

fn write_response(
    stream: &mut TcpStream,
    resp: &HttpResponse,
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        resp.status,
        status_reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (name, value) in &resp.headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(resp.body.as_bytes())?;
    stream.flush()
}

/// Read one HTTP/1.1 response: `(status, headers, body)` with lowercased
/// header names, mirroring [`read_request`]. `Ok(None)` = clean EOF before
/// any status byte arrived (the keep-alive peer closed an idle connection
/// — [`HttpClient`] retries exactly that case once on a reused
/// connection). `ErrorKind::InvalidData` = malformed response.
///
/// The body allocation is bounded by [`MAX_BODY_BYTES`], so an untrusted
/// (or byte-flipped — see `rust/tests/fuzz_http.rs`) downstream cannot
/// make the client allocate unboundedly by promising a huge
/// `Content-Length`. Generic over [`BufRead`] so the fuzz harness can
/// drive it from in-memory byte slices.
pub fn read_response<R: BufRead>(
    reader: &mut R,
) -> std::io::Result<Option<(u16, Vec<(String, String)>, String)>> {
    let mut line = String::new();
    if read_line_limited(reader, &mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    if !parts.next().is_some_and(|v| v.starts_with("HTTP/")) {
        return Err(invalid("status line has no HTTP version"));
    }
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| invalid("status line has no numeric status"))?;

    let mut headers = Vec::new();
    let mut content_len: Option<usize> = None;
    loop {
        if headers.len() > MAX_HEADERS {
            return Err(invalid("too many response headers"));
        }
        let mut h = String::new();
        if read_line_limited(reader, &mut h)? == 0 {
            return Err(invalid("eof inside response headers"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').ok_or_else(|| invalid("response header without ':'"))?;
        let k = k.trim().to_ascii_lowercase();
        let v = v.trim().to_string();
        if k == "transfer-encoding" {
            return Err(invalid("Transfer-Encoding is not supported"));
        }
        if k == "content-length" {
            let n: usize = v.parse().map_err(|_| invalid("unparseable Content-Length"))?;
            if content_len.is_some_and(|prev| prev != n) {
                return Err(invalid("conflicting Content-Length headers"));
            }
            if n > MAX_BODY_BYTES {
                return Err(invalid("response body too large"));
            }
            content_len = Some(n);
        }
        headers.push((k, v));
    }
    let content_len = content_len.unwrap_or(0);

    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8(body).map_err(|_| invalid("response body is not UTF-8"))?;
    Ok(Some((status, headers, body)))
}

/// Minimal blocking HTTP/1.1 client over one keep-alive connection.
///
/// Sends `Content-Length`-framed requests and reads framed responses;
/// exactly the dialect [`HttpServer`] speaks. Used by the integration
/// tests, the socket-mode load bench, and the `hinm route` router's
/// downstream attempts.
///
/// A *reused* keep-alive connection can go stale: the server closed it
/// while idle (e.g. [`IDLE_TIMEOUT`] fired, or the process restarted), so
/// the next request sees a write failure or a clean EOF before any
/// response byte. Both are retried **once** over a fresh connection —
/// transparently, because no response bytes were received, so the server
/// cannot have acted on the request over the dead connection. A failure
/// on a *fresh* connection, or any failure after response bytes arrived,
/// is surfaced to the caller unretried.
pub struct HttpClient {
    addr: SocketAddr,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    connect_timeout: Option<Duration>,
    read_timeout: Option<Duration>,
    /// Responses completed on the current connection; `> 0` marks the
    /// connection as reused (stale-retry eligible).
    served: u64,
}

/// Why one send attempt failed, split so the caller can retry exactly the
/// stale-keep-alive cases (no response bytes ⇒ the request was provably
/// not answered over this connection).
enum SendError {
    /// The reused connection was already dead: write failed, or the
    /// server closed before sending any response byte.
    Stale(&'static str),
    /// A real failure (timeout, malformed response, mid-response EOF).
    Io(std::io::Error),
}

impl HttpClient {
    /// Connect to a server (e.g. the address from
    /// [`HttpServer::local_addr`]).
    pub fn connect(addr: SocketAddr) -> Result<HttpClient> {
        Self::open(addr, None)
    }

    /// [`HttpClient::connect`] with a bound on how long the TCP connect
    /// may block (`TcpStream::connect_timeout`); remembered and re-applied
    /// on stale-keep-alive reconnects. The timeout must be non-zero.
    pub fn connect_timeout(addr: SocketAddr, timeout: Duration) -> Result<HttpClient> {
        Self::open(addr, Some(timeout))
    }

    fn open(addr: SocketAddr, connect_timeout: Option<Duration>) -> Result<HttpClient> {
        let stream = match connect_timeout {
            Some(t) => TcpStream::connect_timeout(&addr, t)
                .with_context(|| format!("connecting to {addr} (timeout {t:?})"))?,
            None => {
                TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?
            }
        };
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone().context("cloning client stream")?);
        Ok(HttpClient {
            addr,
            stream,
            reader,
            connect_timeout,
            read_timeout: None,
            served: 0,
        })
    }

    /// Bound how long a response read may block (`None` = block forever).
    /// Remembered and re-applied on stale-keep-alive reconnects. A read
    /// timeout surfaces as an I/O error from the request, never as a
    /// stale-retry (the server may still be processing the request).
    /// The duration must be non-zero.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout).context("setting read timeout")?;
        self.read_timeout = timeout;
        Ok(())
    }

    /// The server address this client (re)connects to.
    pub fn peer_addr(&self) -> SocketAddr {
        self.addr
    }

    /// `GET path` → `(status, body)`.
    pub fn get(&mut self, path: &str) -> Result<(u16, String)> {
        self.request("GET", path, None)
    }

    /// `POST path` with a JSON body → `(status, body)`.
    pub fn post_json(&mut self, path: &str, body: &str) -> Result<(u16, String)> {
        self.request("POST", path, Some(body))
    }

    /// Send one request and block for its response.
    pub fn request(&mut self, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
        let (status, _headers, body) = self.request_with_headers(method, path, body)?;
        Ok((status, body))
    }

    /// [`HttpClient::request`], also returning the response headers
    /// (lowercased names, arrival order) — the router front reads
    /// `retry-after` and surfaces `x-hinm-attempt` through this.
    pub fn request_with_headers(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, Vec<(String, String)>, String)> {
        match self.send_once(method, path, body) {
            Ok(r) => {
                self.served += 1;
                Ok(r)
            }
            Err(SendError::Stale(why)) if self.served > 0 => {
                // Reused connection went stale while idle; one transparent
                // retry over a fresh connection.
                self.reconnect()
                    .with_context(|| format!("reconnecting after stale keep-alive ({why})"))?;
                match self.send_once(method, path, body) {
                    Ok(r) => {
                        self.served += 1;
                        Ok(r)
                    }
                    Err(e) => Err(send_err(e).context("after one stale-keep-alive retry")),
                }
            }
            Err(e) => Err(send_err(e)),
        }
    }

    fn reconnect(&mut self) -> Result<()> {
        let fresh = Self::open(self.addr, self.connect_timeout)?;
        self.stream = fresh.stream;
        self.reader = fresh.reader;
        self.served = 0;
        if self.read_timeout.is_some() {
            self.stream
                .set_read_timeout(self.read_timeout)
                .context("re-applying read timeout")?;
        }
        Ok(())
    }

    fn send_once(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::result::Result<(u16, Vec<(String, String)>, String), SendError> {
        let b = body.unwrap_or("");
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: hinm\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n{b}",
            b.len()
        );
        if let Err(e) = self.stream.write_all(req.as_bytes()).and_then(|()| self.stream.flush()) {
            return Err(match e.kind() {
                std::io::ErrorKind::BrokenPipe
                | std::io::ErrorKind::ConnectionReset
                | std::io::ErrorKind::ConnectionAborted
                | std::io::ErrorKind::NotConnected => {
                    SendError::Stale("connection closed during write")
                }
                _ => SendError::Io(e),
            });
        }
        match read_response(&mut self.reader) {
            Ok(Some(r)) => Ok(r),
            Ok(None) => Err(SendError::Stale("server closed before responding")),
            Err(e) => Err(SendError::Io(e)),
        }
    }
}

/// Lift a [`SendError`] into `anyhow` *preserving the `io::Error` source*
/// so callers (the router's upstream classifier) can recover the
/// `ErrorKind` from the chain. A stale close is reported as
/// `UnexpectedEof`.
fn send_err(e: SendError) -> anyhow::Error {
    match e {
        SendError::Stale(why) => anyhow::Error::new(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            why,
        ))
        .context("server closed the connection before responding"),
        SendError::Io(e) => anyhow::Error::new(e).context("request failed"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn resp_bytes(s: &str) -> Cursor<Vec<u8>> {
        Cursor::new(s.as_bytes().to_vec())
    }

    #[test]
    fn read_response_parses_a_framed_response() {
        let mut r = resp_bytes(
            "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\nContent-Length: 2\r\n\r\nok",
        );
        let (status, headers, body) = read_response(&mut r).unwrap().unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "ok");
        assert!(headers.iter().any(|(k, v)| k == "content-type" && v == "application/json"));
    }

    #[test]
    fn read_response_clean_eof_is_none() {
        assert!(read_response(&mut resp_bytes("")).unwrap().is_none());
    }

    #[test]
    fn read_response_rejects_malformed_frames() {
        for bad in [
            "nonsense\r\n\r\n",                                    // no HTTP version
            "HTTP/1.1 banana\r\n\r\n",                             // no numeric status
            "HTTP/1.1 200 OK\r\nContent-Length: 5\r\nContent-Length: 7\r\n\r\n", // conflict
            "HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n", // unsupported framing
            &format!("HTTP/1.1 200 OK\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1),
        ] {
            let err = read_response(&mut resp_bytes(bad)).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{bad:?}");
        }
    }

    #[test]
    fn read_response_truncated_body_is_an_io_error_not_a_panic() {
        let mut r = resp_bytes("HTTP/1.1 200 OK\r\nContent-Length: 10\r\n\r\nshort");
        let err = read_response(&mut r).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    /// A server that answers exactly one request per accepted connection,
    /// then closes it *without* `Connection: close` — the shape of a
    /// keep-alive peer idling out between a client's requests.
    fn one_shot_server(conns: usize) -> (SocketAddr, JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            for _ in 0..conns {
                let (stream, _) = listener.accept().unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                if read_request(&mut reader).unwrap().is_some() {
                    let mut w = stream;
                    w.write_all(
                        b"HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n\
                          Content-Length: 2\r\nConnection: keep-alive\r\n\r\nok",
                    )
                    .unwrap();
                    w.flush().unwrap();
                    // Dropping the stream closes the "keep-alive"
                    // connection from the server side.
                }
            }
        });
        (addr, t)
    }

    #[test]
    fn stale_keep_alive_reconnects_transparently_once() {
        let (addr, server) = one_shot_server(2);
        let mut c = HttpClient::connect(addr).unwrap();
        let (status, body) = c.get("/a").unwrap();
        assert_eq!((status, body.as_str()), (200, "ok"));
        // The server closed the first connection after responding; this
        // reused-connection request must transparently reconnect.
        let (status, body) = c.get("/b").unwrap();
        assert_eq!((status, body.as_str()), (200, "ok"), "stale keep-alive must retry once");
        server.join().unwrap();
    }

    #[test]
    fn fresh_connection_failures_are_not_retried() {
        // A listener that accepts and instantly closes: the client's very
        // first request gets EOF before a response. served == 0, so no
        // stale-retry fires and the error surfaces.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            drop(stream);
        });
        let mut c = HttpClient::connect(addr).unwrap();
        let err = c.get("/x").unwrap_err();
        assert!(
            err.to_string().contains("closed the connection"),
            "unexpected error: {err:#}"
        );
        t.join().unwrap();
    }

    #[test]
    fn connect_timeout_applies_and_refused_ports_error() {
        // Bind-then-drop guarantees a port with no listener.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let err = HttpClient::connect_timeout(addr, Duration::from_millis(300)).unwrap_err();
        assert!(err.to_string().contains("connecting to"), "{err:#}");
    }

    #[test]
    fn read_timeout_surfaces_as_an_error_not_a_hang() {
        // A listener that accepts but never responds.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            std::thread::sleep(Duration::from_millis(500));
            drop(stream);
        });
        let mut c = HttpClient::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let err = c.get("/slow").unwrap_err();
        // Timeouts must NOT look like stale keep-alive closes: the chain
        // carries the timeout/would-block io kind, not UnexpectedEof.
        let kind = err
            .chain()
            .find_map(|c| c.downcast_ref::<std::io::Error>())
            .map(|e| e.kind());
        assert!(
            matches!(
                kind,
                Some(std::io::ErrorKind::WouldBlock) | Some(std::io::ErrorKind::TimedOut)
            ),
            "expected a timeout kind, got {kind:?} in {err:#}"
        );
        t.join().unwrap();
    }
}
