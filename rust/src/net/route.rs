//! Wire layer of the `hinm route` router tier (DESIGN.md §19).
//!
//! Everything here is clock-free: this file holds the HTTP surface
//! ([`RouterFront`]), the pure upstream-failure taxonomy
//! ([`classify_upstream`]), and the deterministic fault-injection server
//! ([`FaultyBackend`]) used by the chaos tests. All wall-clock reads,
//! timers, and backoff decisions live in [`crate::coordinator::router`] —
//! the same layering rule (hinm-lint R3) that keeps timing out of the
//! numeric kernels keeps it out of the wire layer, so this module's
//! behaviour is a pure function of bytes in and injected fault schedules.
//!
//! The proxy preserves **bit-identity**: request bodies are parsed only to
//! *read* the `"model"` and `"deadline_ms"` fields and are forwarded
//! verbatim, and downstream response bodies are relayed untouched — a
//! client talking through the router sees byte-identical payloads to one
//! talking to the backend directly (pinned by `rust/tests/router_chaos.rs`),
//! plus one extra `X-Hinm-Attempt` header.

use crate::coordinator::router::{ProxyRequest, RouteReply, Router};
use crate::net::http::{read_request, Handler, HttpRequest, HttpResponse, HttpServer};
use crate::net::protocol;
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How a downstream attempt failed, as classified from its I/O error.
/// Drives both the retry decision and the client-visible status code
/// (`Unreachable` → 502, `TimedOut` → 504 via
/// [`crate::coordinator::serve::InferError`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpstreamClass {
    /// Connection refused/reset/aborted, or the peer closed mid-exchange:
    /// the backend is not answering at all.
    Unreachable,
    /// The socket timed out: the backend is up but too slow.
    TimedOut,
    /// The backend answered bytes we could not parse as HTTP.
    Protocol,
}

/// Pure taxonomy from [`std::io::ErrorKind`] to [`UpstreamClass`]:
/// timeouts (`TimedOut`/`WouldBlock` — platform-dependent for socket read
/// timeouts) map to [`UpstreamClass::TimedOut`]; refused, reset, aborted,
/// broken-pipe, not-connected, and unexpected-EOF all mean the peer is
/// gone ([`UpstreamClass::Unreachable`]); anything else is a framing
/// problem ([`UpstreamClass::Protocol`]).
pub fn classify_upstream(kind: std::io::ErrorKind) -> UpstreamClass {
    use std::io::ErrorKind as K;
    match kind {
        K::TimedOut | K::WouldBlock => UpstreamClass::TimedOut,
        K::ConnectionRefused
        | K::ConnectionReset
        | K::ConnectionAborted
        | K::BrokenPipe
        | K::NotConnected
        | K::UnexpectedEof => UpstreamClass::Unreachable,
        _ => UpstreamClass::Protocol,
    }
}

/// Classify an [`anyhow::Error`] from [`crate::net::http::HttpClient`] by
/// the first [`std::io::Error`] in its chain; errors with no I/O cause
/// (e.g. malformed response framing) are [`UpstreamClass::Protocol`].
pub fn classify_anyhow(e: &anyhow::Error) -> UpstreamClass {
    e.chain()
        .find_map(|c| c.downcast_ref::<std::io::Error>())
        .map(|io| classify_upstream(io.kind()))
        .unwrap_or(UpstreamClass::Protocol)
}

/// HTTP front of the router tier: binds an address and serves the
/// DESIGN.md §19 route table (`POST /v1/infer` proxied through
/// [`Router::dispatch`], plus `/healthz`, `/v1/metrics`, `/v1/models`).
pub struct RouterFront {
    server: HttpServer,
    router: Arc<Router>,
}

impl RouterFront {
    /// Bind `addr` (port 0 for ephemeral) with `workers` connection
    /// threads, proxying onto `router`.
    pub fn start(addr: &str, router: Arc<Router>, workers: usize) -> Result<RouterFront> {
        let r = Arc::clone(&router);
        let handler: Handler = Arc::new(move |req: &HttpRequest| route_front(req, &r));
        let server = HttpServer::start(addr, handler, workers)?;
        Ok(RouterFront { server, router })
    }

    /// The bound address (resolves an ephemeral-port bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.server.local_addr()
    }

    /// The router behind this front (metrics, probing state).
    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// Graceful shutdown: stop accepting first, then drain the router so
    /// in-flight proxied requests still complete.
    pub fn stop(self) {
        self.server.stop();
        self.router.stop();
    }
}

fn route_front(req: &HttpRequest, router: &Router) -> HttpResponse {
    let path = req.path.split('?').next().unwrap_or("");
    match path {
        "/healthz" => match req.method.as_str() {
            "GET" => {
                let (live, total) = router.live_backends();
                HttpResponse::json(
                    200,
                    Json::obj(vec![
                        ("status", Json::str(if live > 0 { "ok" } else { "degraded" })),
                        ("backends_live", Json::num(live as f64)),
                        ("backends_total", Json::num(total as f64)),
                    ])
                    .compact(),
                )
            }
            _ => method_not_allowed(req, "GET"),
        },
        "/v1/metrics" => match req.method.as_str() {
            "GET" => metrics_route(req, router),
            _ => method_not_allowed(req, "GET"),
        },
        "/v1/models" => match req.method.as_str() {
            "GET" => HttpResponse::json(
                200,
                Json::obj(vec![(
                    "models",
                    Json::arr(
                        router
                            .models_union()
                            .iter()
                            .map(|m| Json::obj(vec![("name", Json::str(m))])),
                    ),
                )])
                .compact(),
            ),
            _ => method_not_allowed(req, "GET"),
        },
        "/v1/infer" => match req.method.as_str() {
            "POST" => proxy_infer(req, router),
            _ => method_not_allowed(req, "POST"),
        },
        _ => HttpResponse::json(
            404,
            protocol::error_body("not_found", &format!("no route for {} {}", req.method, path))
                .compact(),
        ),
    }
}

/// `GET /v1/metrics` on the router: JSON by default, Prometheus text with
/// `?format=prometheus` — the same negotiation as the single-host front.
fn metrics_route(req: &HttpRequest, router: &Router) -> HttpResponse {
    let query = req.path.split_once('?').map(|(_, q)| q).unwrap_or("");
    let format = query
        .split('&')
        .find_map(|kv| kv.strip_prefix("format="))
        .unwrap_or("json");
    let snap = router.snapshot();
    match format {
        "json" => HttpResponse::json(200, protocol::router_metrics_json(&snap).compact()),
        "prometheus" => HttpResponse {
            status: 200,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: protocol::router_metrics_prometheus(&snap),
            headers: Vec::new(),
        },
        other => HttpResponse::json(
            400,
            protocol::error_body(
                "bad_request",
                &format!("unknown metrics format {other:?} (use json or prometheus)"),
            )
            .compact(),
        ),
    }
}

/// Read-only routing fields of an infer body: `(model, deadline_ms)`.
/// The body itself is forwarded verbatim — never re-serialized.
fn infer_target(body: &str) -> std::result::Result<(Option<String>, Option<u64>), String> {
    let doc = json::parse(body).map_err(|e| format!("body is not valid JSON: {e}"))?;
    let model = match doc.get("model") {
        Json::Null => None,
        m => Some(
            m.as_str()
                .ok_or_else(|| "\"model\" must be a string".to_string())?
                .to_string(),
        ),
    };
    let deadline_ms = match doc.get("deadline_ms") {
        Json::Null => None,
        d => {
            let ms = d.as_f64().ok_or_else(|| "\"deadline_ms\" must be a number".to_string())?;
            if ms < 0.0 {
                return Err("\"deadline_ms\" must be non-negative".to_string());
            }
            Some(ms as u64)
        }
    };
    Ok((model, deadline_ms))
}

fn proxy_infer(req: &HttpRequest, router: &Router) -> HttpResponse {
    let (model, deadline_ms) = match infer_target(&req.body) {
        Ok(t) => t,
        Err(msg) => {
            return HttpResponse::json(400, protocol::error_body("bad_request", &msg).compact());
        }
    };
    // `POST /v1/infer` is a pure function of its body, so replaying it on
    // another replica is safe: idempotent.
    let proxy = ProxyRequest {
        method: "POST",
        path: "/v1/infer",
        body: &req.body,
        model: model.as_deref(),
        deadline_ms,
        idempotent: true,
    };
    match router.dispatch(&proxy) {
        RouteReply::Replied { status, body, attempts, .. } => HttpResponse::json(status, body)
            .with_header(protocol::X_HINM_ATTEMPT, &attempts.to_string()),
        RouteReply::Failed { error, attempts } => protocol::error_response(&error)
            .with_header(protocol::X_HINM_ATTEMPT, &attempts.to_string()),
        RouteReply::Busy { retry_after_s } => HttpResponse::json(
            503,
            protocol::error_body("busy", "router at capacity; retry later").compact(),
        )
        .with_header("Retry-After", &retry_after_s.to_string()),
    }
}

fn method_not_allowed(req: &HttpRequest, allowed: &str) -> HttpResponse {
    HttpResponse::json(
        405,
        protocol::error_body(
            "method_not_allowed",
            &format!("{} {} (use {allowed})", req.method, req.path),
        )
        .compact(),
    )
}

// ---------------------------------------------------------------------------
// Deterministic fault injection
// ---------------------------------------------------------------------------

/// One scripted behaviour of a [`FaultyBackend`] request slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Answer 200 with a small fixed JSON body.
    Ok,
    /// Sleep this many milliseconds, then answer 200 (the client's read
    /// timeout usually fires first).
    Stall(u64),
    /// Answer a well-formed 500.
    Http500,
    /// Drop the connection without answering (the client sees EOF/reset).
    Reset,
    /// Answer 200 one byte at a time with this many milliseconds between
    /// bytes (the client times out mid-body).
    SlowDrip(u64),
}

/// A scripted stand-in for a downstream `hinm serve` host, for chaos and
/// fuzz tests. Faults are drawn from a fixed schedule indexed by request
/// arrival order (`/v1/infer` and `/healthz` requests consume slots; the
/// last entry repeats forever), so a given schedule replays to the exact
/// same router behaviour — no randomness, no clock reads.
pub struct FaultyBackend {
    addr: SocketAddr,
    stopping: Arc<AtomicBool>,
    arrivals: Arc<AtomicUsize>,
    acceptor: Option<JoinHandle<()>>,
}

impl FaultyBackend {
    /// Bind an ephemeral loopback port and serve `schedule` (must be
    /// non-empty; the final entry repeats for every later request).
    pub fn start(schedule: Vec<Fault>) -> Result<FaultyBackend> {
        anyhow::ensure!(!schedule.is_empty(), "FaultyBackend needs a non-empty schedule");
        let listener =
            TcpListener::bind("127.0.0.1:0").context("binding FaultyBackend listener")?;
        let addr = listener.local_addr().context("resolving FaultyBackend addr")?;
        let stopping = Arc::new(AtomicBool::new(false));
        let arrivals = Arc::new(AtomicUsize::new(0));
        let schedule = Arc::new(schedule);
        let acceptor = {
            let stopping = Arc::clone(&stopping);
            let arrivals = Arc::clone(&arrivals);
            std::thread::Builder::new()
                .name("hinm-faulty-accept".to_string())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stopping.load(Ordering::SeqCst) {
                            return;
                        }
                        let Ok(stream) = conn else { continue };
                        let stopping = Arc::clone(&stopping);
                        let arrivals = Arc::clone(&arrivals);
                        let schedule = Arc::clone(&schedule);
                        // Connection threads are detached; they exit when
                        // the peer closes or the fault script drops them.
                        let _ = std::thread::Builder::new()
                            .name("hinm-faulty-conn".to_string())
                            .spawn(move || {
                                faulty_connection(stream, &schedule, &arrivals, &stopping)
                            });
                    }
                })
                .context("spawning FaultyBackend acceptor")?
        };
        Ok(FaultyBackend { addr, stopping, arrivals, acceptor: Some(acceptor) })
    }

    /// The bound loopback address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Fault-consuming requests seen so far (arrival-order schedule index).
    pub fn arrivals(&self) -> usize {
        self.arrivals.load(Ordering::SeqCst)
    }

    /// Stop accepting and join the acceptor thread.
    pub fn stop(mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        // Wake the blocking accept with one throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.acceptor.take() {
            let _ = t.join();
        }
    }
}

impl Drop for FaultyBackend {
    fn drop(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.acceptor.take() {
            let _ = t.join();
        }
    }
}

/// Serve one connection's keep-alive loop, applying the scheduled fault
/// to each `/v1/infer` / `/healthz` request. Other paths answer without
/// consuming a schedule slot (`/v1/models` is always 404) so capability
/// probes don't perturb fault accounting.
fn faulty_connection(
    stream: TcpStream,
    schedule: &[Fault],
    arrivals: &AtomicUsize,
    stopping: &AtomicBool,
) {
    let _ = stream.set_nodelay(true);
    let Ok(write_half) = stream.try_clone() else { return };
    let mut write_half = write_half;
    let mut reader = BufReader::new(stream);
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) | Err(_) => return,
        };
        let path = req.path.split('?').next().unwrap_or("");
        if path != "/v1/infer" && path != "/healthz" {
            let _ = write_raw(&mut write_half, 404, "{\"error\":\"not_found\"}");
            continue;
        }
        let i = arrivals.fetch_add(1, Ordering::SeqCst);
        let fault = schedule[i.min(schedule.len() - 1)];
        let ok_body = if path == "/healthz" {
            "{\"status\":\"ok\"}"
        } else {
            "{\"y\":[0.25,-0.5,1.0]}"
        };
        match fault {
            Fault::Ok => {
                if write_raw(&mut write_half, 200, ok_body).is_err() {
                    return;
                }
            }
            Fault::Stall(ms) => {
                if chunked_sleep(ms, stopping) {
                    return;
                }
                if write_raw(&mut write_half, 200, ok_body).is_err() {
                    return;
                }
            }
            Fault::Http500 => {
                if write_raw(&mut write_half, 500, "{\"error\":\"injected\"}").is_err() {
                    return;
                }
            }
            Fault::Reset => return, // drop without answering
            Fault::SlowDrip(ms) => {
                let frame = frame(200, ok_body);
                for b in frame.as_bytes() {
                    if stopping.load(Ordering::SeqCst) {
                        return;
                    }
                    if write_half.write_all(std::slice::from_ref(b)).is_err() {
                        return;
                    }
                    let _ = write_half.flush();
                    std::thread::sleep(Duration::from_millis(ms));
                }
            }
        }
    }
}

fn frame(status: u16, body: &str) -> String {
    let reason = match status {
        200 => "OK",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
        body.len()
    )
}

fn write_raw(stream: &mut TcpStream, status: u16, body: &str) -> std::io::Result<()> {
    stream.write_all(frame(status, body).as_bytes())?;
    stream.flush()
}

/// Sleep `ms` in small chunks, returning `true` if `stopping` was set
/// (so stalled connections release promptly at shutdown).
fn chunked_sleep(ms: u64, stopping: &AtomicBool) -> bool {
    let mut left = ms;
    while left > 0 {
        if stopping.load(Ordering::SeqCst) {
            return true;
        }
        let chunk = left.min(25);
        std::thread::sleep(Duration::from_millis(chunk));
        left -= chunk;
    }
    stopping.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::http::HttpClient;
    use std::io::ErrorKind as K;

    #[test]
    fn upstream_taxonomy_is_stable() {
        assert_eq!(classify_upstream(K::TimedOut), UpstreamClass::TimedOut);
        assert_eq!(classify_upstream(K::WouldBlock), UpstreamClass::TimedOut);
        for k in [
            K::ConnectionRefused,
            K::ConnectionReset,
            K::ConnectionAborted,
            K::BrokenPipe,
            K::NotConnected,
            K::UnexpectedEof,
        ] {
            assert_eq!(classify_upstream(k), UpstreamClass::Unreachable, "{k:?}");
        }
        assert_eq!(classify_upstream(K::InvalidData), UpstreamClass::Protocol);
        assert_eq!(
            classify_anyhow(&anyhow::Error::new(std::io::Error::new(K::TimedOut, "t"))),
            UpstreamClass::TimedOut
        );
        assert_eq!(classify_anyhow(&anyhow::anyhow!("no io cause")), UpstreamClass::Protocol);
    }

    #[test]
    fn infer_target_reads_routing_fields_only() {
        let (m, d) = infer_target("{\"x\":[1.0],\"model\":\"a\",\"deadline_ms\":50}")
            .expect("valid body");
        assert_eq!(m.as_deref(), Some("a"));
        assert_eq!(d, Some(50));
        let (m, d) = infer_target("{\"x\":[1.0]}").expect("fields optional");
        assert_eq!(m, None);
        assert_eq!(d, None);
        assert!(infer_target("not json").is_err());
        assert!(infer_target("{\"deadline_ms\":-1}").is_err());
        assert!(infer_target("{\"model\":7}").is_err());
    }

    #[test]
    fn faulty_backend_follows_its_schedule_and_clamps_the_tail() {
        let b = FaultyBackend::start(vec![Fault::Http500, Fault::Ok]).expect("start");
        let mut c = HttpClient::connect(b.addr()).expect("connect");
        let (status, body) = c.post_json("/v1/infer", "{\"x\":[0.0]}").expect("req 1");
        assert_eq!(status, 500);
        assert!(body.contains("injected"));
        // Slot 2 and every later request repeat the final Ok entry.
        for _ in 0..3 {
            let (status, body) = c.post_json("/v1/infer", "{\"x\":[0.0]}").expect("req");
            assert_eq!(status, 200);
            assert_eq!(body, "{\"y\":[0.25,-0.5,1.0]}");
        }
        // /v1/models never consumes a schedule slot.
        let before = b.arrivals();
        let (status, _) = c.get("/v1/models").expect("models");
        assert_eq!(status, 404);
        assert_eq!(b.arrivals(), before);
        b.stop();
    }

    #[test]
    fn faulty_backend_reset_drops_the_connection() {
        let b = FaultyBackend::start(vec![Fault::Reset]).expect("start");
        let mut c = HttpClient::connect(b.addr()).expect("connect");
        let err = c.post_json("/v1/infer", "{}").expect_err("reset must error");
        assert_eq!(classify_anyhow(&err), UpstreamClass::Unreachable);
        b.stop();
    }
}
