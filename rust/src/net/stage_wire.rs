//! Wire layer of cross-host pipeline stages (DESIGN.md §20).
//!
//! Everything here is clock-free: this file holds only the length-prefixed
//! binary activation-frame codec spoken between the serve head
//! ([`crate::runtime::RemotePipelinedBackend`]) and `hinm stage` hosts
//! ([`crate::coordinator::StageHost`]). All wall-clock reads, socket
//! timeouts, reconnect backoff, and latency accounting live in the
//! coordinator/runtime layers — the same layering rule (hinm-lint R3) that
//! keeps timing out of the numeric kernels and out of `net/route.rs` keeps
//! it out of this module, so frame encoding/decoding is a pure function of
//! bytes.
//!
//! ## Frame format (all integers little-endian)
//!
//! | offset | size | field |
//! |---|---|---|
//! | 0  | 4 | `body_len` — bytes that follow this prefix |
//! | 4  | 2 | `version` — [`STAGE_WIRE_VERSION`] |
//! | 6  | 1 | `kind` — 0 activations, 1 typed stage error |
//! | 7  | 1 | reserved, must be 0 |
//! | 8  | 8 | `seq` — batch sequence id, echoed by the peer |
//! | 16 | 4 | `rows` — activation channels (0 for error frames) |
//! | 20 | 4 | `cols` — batch columns (0 for error frames) |
//! | 24 | … | payload — `rows*cols` f32 LE (kind 0) or UTF-8 message (kind 1) |
//! | …  | 8 | `checksum` — FNV-1a64 over bytes 4‥body_len−8 |
//!
//! **Bit-identity.** Activation payloads move as raw IEEE-754 bit patterns
//! (`f32::to_le_bytes` / `from_le_bytes`), so a batch survives any number
//! of link hops bit-exactly — including NaNs, signed zeros, and denormals.
//! The checksum detects corruption; it never "repairs" anything.
//!
//! **Failure taxonomy.** Decode failures are [`std::io::Error`]s whose
//! kinds feed the §19 classifier unchanged: truncation mid-frame is
//! `UnexpectedEof` (the peer died — `Unreachable`), while a bad checksum,
//! wrong version, unknown kind, or a length prefix that disagrees with the
//! batch dims is `InvalidData` (the stream is desynchronized — `Protocol`;
//! the connection must be dropped, not resynchronized).
//!
//! **Recycling.** [`FrameCodec`] owns the scratch body buffer and
//! [`FrameCodec::read_into`] deposits activations into a caller-provided
//! [`Matrix`], so steady-state frame I/O allocates nothing on either end —
//! the cross-host analogue of the §15 recycled hand-off buffers.

use crate::runtime::artifact::fnv1a64;
use crate::tensor::Matrix;
use std::io::{self, Read, Write};

/// Current frame schema version. A reader rejects any other value with
/// `InvalidData`: versioning is a hard ladder (decode what you know,
/// refuse what you don't) — never a silent best-effort parse.
pub const STAGE_WIRE_VERSION: u16 = 1;

/// Upper bound on `body_len` (matches the HTTP front's 64 MB body cap) so
/// a lying length prefix cannot make a reader allocate unboundedly.
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

/// Frame kind 0: an activation batch.
pub const KIND_ACTIVATIONS: u8 = 0;
/// Frame kind 1: a typed per-batch stage error (UTF-8 message payload).
pub const KIND_ERROR: u8 = 1;

/// Fixed header bytes inside the body (version..cols).
const HEADER_BYTES: usize = 20;
/// Trailing checksum bytes.
const TRAILER_BYTES: usize = 8;
/// Smallest legal `body_len` (empty payload).
const MIN_BODY_BYTES: usize = HEADER_BYTES + TRAILER_BYTES;

/// A decoded frame. Activation payloads are deposited into the `out`
/// matrix passed to [`FrameCodec::read_into`] (reshaped in place), so the
/// variant carries only the metadata.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// An activation batch for/from stage execution; the matrix landed in
    /// the caller's recycled buffer.
    Activations {
        /// Batch sequence id, echoed verbatim by the peer's response.
        seq: u64,
    },
    /// The peer executed nothing for this batch: a typed per-batch stage
    /// failure (the connection stays up — only this batch failed).
    Error {
        /// Sequence id of the batch that failed.
        seq: u64,
        /// Human-readable stage error.
        message: String,
    },
}

/// Reusable encoder/decoder: owns the scratch body buffer recycled across
/// frames. One codec per connection end; it is not shared across threads.
#[derive(Default)]
pub struct FrameCodec {
    body: Vec<u8>,
}

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

impl FrameCodec {
    /// A codec with an empty (lazily grown) scratch buffer.
    pub fn new() -> FrameCodec {
        FrameCodec { body: Vec::new() }
    }

    /// Stage the fixed header into the scratch buffer.
    fn begin(&mut self, kind: u8, seq: u64, rows: u32, cols: u32) {
        self.body.clear();
        self.body.extend_from_slice(&STAGE_WIRE_VERSION.to_le_bytes());
        self.body.push(kind);
        self.body.push(0); // reserved
        self.body.extend_from_slice(&seq.to_le_bytes());
        self.body.extend_from_slice(&rows.to_le_bytes());
        self.body.extend_from_slice(&cols.to_le_bytes());
    }

    /// Checksum the staged body and write `len ‖ body ‖ checksum`.
    fn finish(&mut self, w: &mut impl Write) -> io::Result<()> {
        let ck = fnv1a64(&self.body);
        let total = self.body.len() + TRAILER_BYTES;
        debug_assert!(total <= MAX_FRAME_BYTES);
        w.write_all(&(total as u32).to_le_bytes())?;
        w.write_all(&self.body)?;
        w.write_all(&ck.to_le_bytes())?;
        w.flush()
    }

    /// Encode and write one activation frame carrying `m` (row-major f32
    /// bits, verbatim). Errors only on I/O failure or an impossibly large
    /// batch.
    pub fn write_activations(
        &mut self,
        w: &mut impl Write,
        seq: u64,
        m: &Matrix,
    ) -> io::Result<()> {
        let payload = m
            .data
            .len()
            .checked_mul(4)
            .filter(|p| p + MIN_BODY_BYTES <= MAX_FRAME_BYTES)
            .ok_or_else(|| bad(format!("batch {}x{} exceeds the frame cap", m.rows, m.cols)))?;
        if m.rows > u32::MAX as usize || m.cols > u32::MAX as usize {
            return Err(bad(format!("batch dims {}x{} overflow u32", m.rows, m.cols)));
        }
        self.begin(KIND_ACTIVATIONS, seq, m.rows as u32, m.cols as u32);
        self.body.reserve(payload);
        for &v in &m.data {
            self.body.extend_from_slice(&v.to_le_bytes());
        }
        self.finish(w)
    }

    /// Encode and write one typed per-batch error frame.
    pub fn write_error(&mut self, w: &mut impl Write, seq: u64, message: &str) -> io::Result<()> {
        let msg = message.as_bytes();
        let msg = &msg[..msg.len().min(MAX_FRAME_BYTES - MIN_BODY_BYTES)];
        self.begin(KIND_ERROR, seq, 0, 0);
        self.body.extend_from_slice(msg);
        self.finish(w)
    }

    /// Read and decode one frame. Activation payloads are deposited into
    /// `out` (reshaped in place, reusing its capacity). Truncation
    /// surfaces as `UnexpectedEof`; any framing violation (bad checksum,
    /// unknown version/kind, length prefix disagreeing with the batch
    /// dims) is `InvalidData` — after which the stream can no longer be
    /// trusted and the connection must be dropped.
    pub fn read_into(&mut self, r: &mut impl Read, out: &mut Matrix) -> io::Result<Frame> {
        let mut prefix = [0u8; 4];
        r.read_exact(&mut prefix)?;
        let body_len = u32::from_le_bytes(prefix) as usize;
        if body_len < MIN_BODY_BYTES {
            return Err(bad(format!("frame body of {body_len} B is shorter than the header")));
        }
        if body_len > MAX_FRAME_BYTES {
            return Err(bad(format!("frame body of {body_len} B exceeds the {MAX_FRAME_BYTES} B cap")));
        }
        self.body.resize(body_len, 0);
        r.read_exact(&mut self.body)?;

        let (checked, trailer) = self.body.split_at(body_len - TRAILER_BYTES);
        let claimed = u64::from_le_bytes([
            trailer[0], trailer[1], trailer[2], trailer[3], trailer[4], trailer[5], trailer[6],
            trailer[7],
        ]);
        let actual = fnv1a64(checked);
        if claimed != actual {
            return Err(bad(format!("frame checksum mismatch: {claimed:#018x} != {actual:#018x}")));
        }

        let version = u16::from_le_bytes([checked[0], checked[1]]);
        if version != STAGE_WIRE_VERSION {
            return Err(bad(format!("frame version {version} (speaking {STAGE_WIRE_VERSION})")));
        }
        let kind = checked[2];
        if checked[3] != 0 {
            return Err(bad(format!("reserved frame byte is {}", checked[3])));
        }
        let seq = u64::from_le_bytes([
            checked[4], checked[5], checked[6], checked[7], checked[8], checked[9], checked[10],
            checked[11],
        ]);
        let rows = u32::from_le_bytes([checked[12], checked[13], checked[14], checked[15]]) as usize;
        let cols = u32::from_le_bytes([checked[16], checked[17], checked[18], checked[19]]) as usize;
        let payload = &checked[HEADER_BYTES..];

        match kind {
            KIND_ACTIVATIONS => {
                let expected = rows.checked_mul(cols).and_then(|n| n.checked_mul(4));
                if expected != Some(payload.len()) {
                    return Err(bad(format!(
                        "frame payload is {} B but batch dims {rows}x{cols} need {:?} B",
                        payload.len(),
                        expected
                    )));
                }
                out.rows = rows;
                out.cols = cols;
                out.data.clear();
                out.data.extend(
                    payload
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])),
                );
                Ok(Frame::Activations { seq })
            }
            KIND_ERROR => {
                if rows != 0 || cols != 0 {
                    return Err(bad(format!("error frame carries batch dims {rows}x{cols}")));
                }
                let message = std::str::from_utf8(payload)
                    .map_err(|_| bad("error frame message is not UTF-8".to_string()))?
                    .to_string();
                Ok(Frame::Error { seq, message })
            }
            other => Err(bad(format!("unknown frame kind {other}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::route::{classify_upstream, UpstreamClass};

    fn encode_activations(seq: u64, m: &Matrix) -> Vec<u8> {
        let mut codec = FrameCodec::new();
        let mut bytes = Vec::new();
        codec.write_activations(&mut bytes, seq, m).expect("encode");
        bytes
    }

    fn decode(bytes: &[u8]) -> io::Result<(Frame, Matrix)> {
        let mut codec = FrameCodec::new();
        let mut out = Matrix::zeros(0, 0);
        let mut cursor = bytes;
        codec.read_into(&mut cursor, &mut out).map(|f| (f, out))
    }

    #[test]
    fn activations_roundtrip_bit_exact_including_nonfinite() {
        let m = Matrix::from_vec(
            2,
            3,
            vec![1.5, -0.0, f32::NAN, f32::MIN_POSITIVE / 2.0, f32::INFINITY, -7.25e-30],
        );
        let bytes = encode_activations(42, &m);
        let (frame, got) = decode(&bytes).expect("valid frame must decode");
        assert_eq!(frame, Frame::Activations { seq: 42 });
        assert_eq!((got.rows, got.cols), (2, 3));
        let want: Vec<u32> = m.data.iter().map(|v| v.to_bits()).collect();
        let have: Vec<u32> = got.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(want, have, "payload bits must survive the wire untouched");
    }

    #[test]
    fn error_frame_roundtrips() {
        let mut codec = FrameCodec::new();
        let mut bytes = Vec::new();
        codec.write_error(&mut bytes, 9, "stage 2 exploded").expect("encode");
        let (frame, _) = decode(&bytes).expect("decode");
        assert_eq!(frame, Frame::Error { seq: 9, message: "stage 2 exploded".to_string() });
    }

    #[test]
    fn codec_reuses_buffers_across_frames() {
        let mut codec = FrameCodec::new();
        let mut out = Matrix::zeros(0, 0);
        for seq in 0..4u64 {
            let m = Matrix::from_vec(4, 2, (0..8).map(|i| (seq as f32) + i as f32).collect());
            let mut bytes = Vec::new();
            codec.write_activations(&mut bytes, seq, &m).expect("encode");
            let mut cursor = &bytes[..];
            let frame = codec.read_into(&mut cursor, &mut out).expect("decode");
            assert_eq!(frame, Frame::Activations { seq });
            assert_eq!(out.data, m.data);
        }
    }

    #[test]
    fn truncation_is_unexpected_eof_hence_unreachable() {
        let bytes = encode_activations(1, &Matrix::from_vec(1, 2, vec![1.0, 2.0]));
        for cut in [0, 2, 5, bytes.len() - 1] {
            let err = decode(&bytes[..cut]).expect_err("truncated frame must fail");
            assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof, "cut at {cut}");
            assert_eq!(classify_upstream(err.kind()), UpstreamClass::Unreachable);
        }
    }

    #[test]
    fn corruption_is_invalid_data_hence_protocol() {
        let bytes = encode_activations(1, &Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]));
        // Flip one payload byte: the checksum catches it.
        let mut corrupt = bytes.clone();
        corrupt[26] ^= 0x40;
        let err = decode(&corrupt).expect_err("corrupt payload must fail");
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("checksum"), "{err}");
        assert_eq!(classify_upstream(err.kind()), UpstreamClass::Protocol);
    }

    #[test]
    fn future_version_is_rejected() {
        let m = Matrix::from_vec(1, 1, vec![0.5]);
        let mut codec = FrameCodec::new();
        let mut bytes = Vec::new();
        codec.write_activations(&mut bytes, 3, &m).expect("encode");
        // Bump the version field and re-seal the checksum so *only* the
        // version is wrong.
        bytes[4] = bytes[4].wrapping_add(1);
        let body_end = bytes.len() - TRAILER_BYTES;
        let ck = fnv1a64(&bytes[4..body_end]);
        bytes[body_end..].copy_from_slice(&ck.to_le_bytes());
        let err = decode(&bytes).expect_err("future version must be refused");
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn lying_length_prefix_is_rejected() {
        // Dims say 3x3 but the payload carries a single f32.
        let mut codec = FrameCodec::new();
        codec.begin(KIND_ACTIVATIONS, 7, 3, 3);
        codec.body.extend_from_slice(&1.0f32.to_le_bytes());
        let mut bytes = Vec::new();
        codec.finish(&mut bytes).expect("encode");
        let err = decode(&bytes).expect_err("dims/length disagreement must fail");
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);

        // A body_len past the cap is refused before any allocation.
        let mut huge = Vec::new();
        huge.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
        let err = decode(&huge).expect_err("oversized frame must fail");
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);

        // A body_len too small for even the header is refused too.
        let mut tiny = Vec::new();
        tiny.extend_from_slice(&4u32.to_le_bytes());
        tiny.extend_from_slice(&[0, 0, 0, 0]);
        let err = decode(&tiny).expect_err("undersized frame must fail");
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }
}
