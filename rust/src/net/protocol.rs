//! Wire types for the `/v1/*` JSON API — hand-rolled over
//! [`crate::util::json`] (the offline environment has no serde).
//!
//! Request body for `POST /v1/infer`:
//!
//! ```json
//! {"x": [0.1, -0.2, …], "priority": "high", "deadline_ms": 50, "model": "deit-mini"}
//! ```
//!
//! `priority` (optional, default `"normal"`) and `deadline_ms` (optional,
//! default none) map onto [`Priority`] and the scheduler deadline measured
//! from the moment the request is submitted; `model` (optional) routes the
//! request to a named registry model when serving `--model-dir`, and is
//! ignored by the single-model front — old clients that never send it
//! keep hitting the default model (DESIGN.md §18). Success response is
//! `{"y": [...]}`; every error response is
//! `{"error": {"kind": ..., "message": ...}}` with the status code from
//! [`status_for`].

use crate::coordinator::metrics::{EngineMetrics, ModelCounters};
use crate::coordinator::router::RouterSnapshot;
use crate::coordinator::serve::{InferError, Priority};
use crate::coordinator::stage_host::StageLinkSnapshot;
use crate::runtime::backend::CacheStats;
use crate::spmm::KernelInfo;
use crate::util::json::Json;

/// One parsed `POST /v1/infer` body.
#[derive(Clone, Debug, PartialEq)]
pub struct InferRequest {
    /// The activation column (`d_in` values).
    pub x: Vec<f32>,
    /// Scheduling class (default [`Priority::Normal`]).
    pub priority: Priority,
    /// Optional deadline in milliseconds, measured from submission.
    pub deadline_ms: Option<u64>,
    /// Optional registry model name (default model when absent).
    pub model: Option<String>,
}

impl InferRequest {
    /// A normal-priority request with no deadline, for the default model.
    pub fn new(x: Vec<f32>) -> InferRequest {
        InferRequest { x, priority: Priority::Normal, deadline_ms: None, model: None }
    }

    /// Route to a named registry model (builder style).
    pub fn with_model(mut self, model: &str) -> InferRequest {
        self.model = Some(model.to_string());
        self
    }

    /// Parse a request body; the error string is surfaced to the client in
    /// a 400 response.
    pub fn from_json(v: &Json) -> Result<InferRequest, String> {
        let arr = v
            .get("x")
            .as_arr()
            .ok_or_else(|| "missing required field \"x\" (array of numbers)".to_string())?;
        let mut x = Vec::with_capacity(arr.len());
        for e in arr {
            let f = e.as_f64().ok_or_else(|| "\"x\" must contain only numbers".to_string())? as f32;
            // Reject values that overflow f32 (e.g. 3.5e38): they would
            // poison the whole batch with inf/NaN.
            if !f.is_finite() {
                return Err("\"x\" must contain only finite f32 values".to_string());
            }
            x.push(f);
        }
        let priority = match v.get("priority") {
            Json::Null => Priority::Normal,
            p => {
                let s = p
                    .as_str()
                    .ok_or_else(|| "\"priority\" must be a string".to_string())?;
                Priority::parse(s)
                    .ok_or_else(|| format!("unknown priority {s:?} (expected high|normal|low)"))?
            }
        };
        let deadline_ms = match v.get("deadline_ms") {
            Json::Null => None,
            d => {
                let ms = d
                    .as_f64()
                    .ok_or_else(|| "\"deadline_ms\" must be a number".to_string())?;
                if ms < 0.0 {
                    return Err("\"deadline_ms\" must be non-negative".to_string());
                }
                Some(ms as u64)
            }
        };
        let model = match v.get("model") {
            Json::Null => None,
            s => Some(
                s.as_str()
                    .ok_or_else(|| "\"model\" must be a string".to_string())?
                    .to_string(),
            ),
        };
        Ok(InferRequest { x, priority, deadline_ms, model })
    }

    /// Serialize for sending (used by the bench client and tests).
    /// Default-valued fields are omitted.
    pub fn to_json(&self) -> Json {
        let mut pairs =
            vec![("x", Json::arr(self.x.iter().map(|&v| Json::num(v as f64))))];
        if self.priority != Priority::Normal {
            pairs.push(("priority", Json::str(self.priority.as_str())));
        }
        if let Some(ms) = self.deadline_ms {
            pairs.push(("deadline_ms", Json::num(ms as f64)));
        }
        if let Some(model) = &self.model {
            pairs.push(("model", Json::str(model)));
        }
        Json::obj(pairs)
    }
}

/// Success body for `POST /v1/infer`: `{"y": [...]}`.
pub fn infer_response(y: &[f32]) -> Json {
    Json::obj(vec![("y", Json::arr(y.iter().map(|&v| Json::num(v as f64))))])
}

/// Extract `y` from a success body (client side).
pub fn parse_infer_response(v: &Json) -> Result<Vec<f32>, String> {
    let arr = v.get("y").as_arr().ok_or_else(|| "response has no \"y\" array".to_string())?;
    arr.iter()
        .map(|e| e.as_f64().map(|f| f as f32).ok_or_else(|| "\"y\" holds a non-number".to_string()))
        .collect()
}

/// Uniform error body: `{"error": {"kind": ..., "message": ...}}`.
pub fn error_body(kind: &str, message: &str) -> Json {
    Json::obj(vec![(
        "error",
        Json::obj(vec![("kind", Json::str(kind)), ("message", Json::str(message))]),
    )])
}

/// Map an engine error onto `(HTTP status, machine-readable kind)`.
///
/// The upstream variants keep the router tier and the single-host front on
/// one taxonomy: an unreachable replica host (refused/reset — the request
/// may never have reached an engine) is a 502, a replica that accepted but
/// ran out the attempt budget is a 504, and neither collapses into the
/// blanket 500 that `Backend` reserves for *execution* failures.
pub fn status_for(e: &InferError) -> (u16, &'static str) {
    match e {
        InferError::DeadlineExpired => (504, "deadline_expired"),
        InferError::Backend(_) => (500, "backend_error"),
        InferError::Stopped => (503, "server_stopped"),
        InferError::BadRequest(_) => (400, "bad_request"),
        InferError::Upstream(_) => (502, "bad_gateway"),
        InferError::UpstreamTimeout(_) => (504, "upstream_timeout"),
    }
}

/// Render an [`InferError`] as the uniform error body with its mapped
/// status: the one place engine/upstream errors become HTTP responses, so
/// the single-host front, the multi-model front, and the router tier
/// cannot drift apart.
pub fn error_response(e: &InferError) -> crate::net::http::HttpResponse {
    let (status, kind) = status_for(e);
    crate::net::http::HttpResponse::json(status, error_body(kind, &e.to_string()).compact())
}

/// Response header carrying how many downstream attempts (first try +
/// retries + hedges) the router spent answering a request. Lowercase form
/// `x-hinm-attempt` is what [`crate::net::http::HttpRequest::header`] and
/// the client-side header list use.
pub const X_HINM_ATTEMPT: &str = "X-Hinm-Attempt";

/// `GET /v1/metrics` body: aggregate latency/throughput, per-priority and
/// expiry counters, per-replica counters, cache hit/miss stats when a
/// [`CachedBackend`](crate::runtime::backend::CachedBackend) is active,
/// and — when the serving backend exposes one — a `kernel` block with the
/// dispatched microkernel variant and detected cache sizes (DESIGN.md
/// §16), so operators can see which kernel a replica actually runs.
pub fn metrics_json(
    m: &EngineMetrics,
    cache: Option<&CacheStats>,
    kernel: Option<&KernelInfo>,
) -> Json {
    metrics_json_with_models(m, cache, kernel, None)
}

/// [`metrics_json`] plus a `model_requests` block (`name → routed
/// requests`) when the multi-model registry front is serving
/// (DESIGN.md §18).
pub fn metrics_json_with_models(
    m: &EngineMetrics,
    cache: Option<&CacheStats>,
    kernel: Option<&KernelInfo>,
    models: Option<&ModelCounters>,
) -> Json {
    let lat = m.aggregate_latency();
    let pct = lat.percentiles(&[50.0, 95.0, 99.0]);
    let sched = m.scheduler_stats();
    let replicas: Vec<Json> = (0..m.replicas.len())
        .map(|r| {
            let st = m.replica_stats(r);
            Json::obj(vec![
                ("batches", Json::num(st.batches as f64)),
                ("requests", Json::num(st.requests as f64)),
                ("errors", Json::num(st.errors as f64)),
                ("p50_us", Json::num(st.latency.percentile(50.0))),
            ])
        })
        .collect();
    let mut pairs = vec![
        ("requests", Json::num(lat.count() as f64)),
        ("req_per_sec", Json::num(m.requests_per_sec())),
        (
            "latency_us",
            Json::obj(vec![
                ("mean", Json::num(lat.mean())),
                ("p50", Json::num(pct[0])),
                ("p95", Json::num(pct[1])),
                ("p99", Json::num(pct[2])),
            ]),
        ),
        (
            "priorities",
            Json::obj(vec![
                ("high", Json::num(sched.served_for(Priority::High) as f64)),
                ("normal", Json::num(sched.served_for(Priority::Normal) as f64)),
                ("low", Json::num(sched.served_for(Priority::Low) as f64)),
            ]),
        ),
        (
            "expired",
            Json::obj(vec![
                ("at_enqueue", Json::num(sched.expired_at_enqueue as f64)),
                ("in_queue", Json::num(sched.expired_in_queue as f64)),
            ]),
        ),
        ("replicas", Json::Arr(replicas)),
    ];
    if let Some(c) = cache {
        pairs.push((
            "cache",
            Json::obj(vec![
                ("hits", Json::num(c.hits() as f64)),
                ("misses", Json::num(c.misses() as f64)),
                ("hit_rate", Json::num(c.hit_rate())),
            ]),
        ));
    }
    if let Some(k) = kernel {
        let mut kp = vec![
            ("isa", Json::str(k.isa.as_str())),
            ("values", Json::str(k.values.as_str())),
            ("variant", Json::str(&k.variant())),
            ("panel_target_bytes", Json::num(k.panel_target_bytes as f64)),
        ];
        if let Some(b) = k.cache.l1d_bytes {
            kp.push(("l1d_bytes", Json::num(b as f64)));
        }
        if let Some(b) = k.cache.l2_bytes {
            kp.push(("l2_bytes", Json::num(b as f64)));
        }
        pairs.push(("kernel", Json::obj(kp)));
    }
    if let Some(mc) = models {
        let snap = mc.snapshot();
        pairs.push((
            "model_requests",
            Json::obj(snap.iter().map(|(n, c)| (n.as_str(), Json::num(*c as f64))).collect()),
        ));
    }
    Json::obj(pairs)
}

/// `GET /v1/metrics?format=prometheus` body: the same counters as
/// [`metrics_json`] rendered in the Prometheus text exposition format
/// (version 0.0.4) — latency as a `summary` with quantile labels,
/// per-priority / per-replica counters as labeled `counter` families,
/// cache hit/miss counters when a cache is active, and the dispatched
/// microkernel as an info-style gauge (`hinm_kernel_info{isa=…,values=…} 1`
/// plus panel/cache byte gauges) when the backend exposes one.
pub fn metrics_prometheus(
    m: &EngineMetrics,
    cache: Option<&CacheStats>,
    kernel: Option<&KernelInfo>,
) -> String {
    metrics_prometheus_with_models(m, cache, kernel, None)
}

/// [`metrics_prometheus`] plus a `hinm_model_requests_total{model=…}`
/// counter family when the multi-model registry front is serving
/// (DESIGN.md §18).
pub fn metrics_prometheus_with_models(
    m: &EngineMetrics,
    cache: Option<&CacheStats>,
    kernel: Option<&KernelInfo>,
    models: Option<&ModelCounters>,
) -> String {
    let lat = m.aggregate_latency();
    let pct = lat.percentiles(&[50.0, 95.0, 99.0]);
    let sched = m.scheduler_stats();
    let mut out = String::new();

    family(
        &mut out,
        "hinm_requests_total",
        "counter",
        "Requests answered successfully across all replicas.",
        &[format!("hinm_requests_total {}", lat.count())],
    );
    family(
        &mut out,
        "hinm_requests_per_second",
        "gauge",
        "Successful requests per second since engine start.",
        &[format!("hinm_requests_per_second {}", m.requests_per_sec())],
    );

    let mut latency = Vec::new();
    for (q, v) in [("0.5", pct[0]), ("0.95", pct[1]), ("0.99", pct[2])] {
        latency.push(format!("hinm_request_latency_microseconds{{quantile=\"{q}\"}} {v}"));
    }
    latency.push(format!(
        "hinm_request_latency_microseconds_sum {}",
        lat.mean() * lat.count() as f64
    ));
    latency.push(format!("hinm_request_latency_microseconds_count {}", lat.count()));
    family(
        &mut out,
        "hinm_request_latency_microseconds",
        "summary",
        "End-to-end request latency over the retained window.",
        &latency,
    );

    let served: Vec<String> = Priority::ALL
        .iter()
        .map(|p| {
            format!(
                "hinm_requests_served_total{{priority=\"{}\"}} {}",
                p.as_str(),
                sched.served_for(*p)
            )
        })
        .collect();
    family(
        &mut out,
        "hinm_requests_served_total",
        "counter",
        "Successfully served requests by scheduling priority.",
        &served,
    );

    family(
        &mut out,
        "hinm_requests_expired_total",
        "counter",
        "Requests answered with a deadline-expired error, by expiry stage.",
        &[
            format!("hinm_requests_expired_total{{stage=\"enqueue\"}} {}", sched.expired_at_enqueue),
            format!("hinm_requests_expired_total{{stage=\"queue\"}} {}", sched.expired_in_queue),
        ],
    );

    let stats: Vec<_> = (0..m.replicas.len()).map(|r| m.replica_stats(r)).collect();
    family(
        &mut out,
        "hinm_replica_batches_total",
        "counter",
        "Batches flushed per replica.",
        &stats
            .iter()
            .enumerate()
            .map(|(r, st)| format!("hinm_replica_batches_total{{replica=\"{r}\"}} {}", st.batches))
            .collect::<Vec<_>>(),
    );
    family(
        &mut out,
        "hinm_replica_requests_total",
        "counter",
        "Requests answered successfully per replica.",
        &stats
            .iter()
            .enumerate()
            .map(|(r, st)| format!("hinm_replica_requests_total{{replica=\"{r}\"}} {}", st.requests))
            .collect::<Vec<_>>(),
    );
    family(
        &mut out,
        "hinm_replica_errors_total",
        "counter",
        "Failed batch executions per replica.",
        &stats
            .iter()
            .enumerate()
            .map(|(r, st)| format!("hinm_replica_errors_total{{replica=\"{r}\"}} {}", st.errors))
            .collect::<Vec<_>>(),
    );

    if let Some(c) = cache {
        family(
            &mut out,
            "hinm_cache_hits_total",
            "counter",
            "Batches answered from the LRU batch cache.",
            &[format!("hinm_cache_hits_total {}", c.hits())],
        );
        family(
            &mut out,
            "hinm_cache_misses_total",
            "counter",
            "Batches that ran on the wrapped backend.",
            &[format!("hinm_cache_misses_total {}", c.misses())],
        );
    }

    if let Some(k) = kernel {
        family(
            &mut out,
            "hinm_kernel_info",
            "gauge",
            "Dispatched SpMM microkernel (labels carry the identity; value is always 1).",
            &[format!(
                "hinm_kernel_info{{isa=\"{}\",values=\"{}\"}} 1",
                k.isa.as_str(),
                k.values.as_str()
            )],
        );
        family(
            &mut out,
            "hinm_kernel_panel_target_bytes",
            "gauge",
            "Cache-derived byte budget used to size the staged xbuf panel.",
            &[format!("hinm_kernel_panel_target_bytes {}", k.panel_target_bytes)],
        );
        let mut caches = Vec::new();
        if let Some(b) = k.cache.l1d_bytes {
            caches.push(format!("hinm_kernel_cache_bytes{{level=\"l1d\"}} {b}"));
        }
        if let Some(b) = k.cache.l2_bytes {
            caches.push(format!("hinm_kernel_cache_bytes{{level=\"l2\"}} {b}"));
        }
        if !caches.is_empty() {
            family(
                &mut out,
                "hinm_kernel_cache_bytes",
                "gauge",
                "Data-cache sizes detected from sysfs at kernel dispatch.",
                &caches,
            );
        }
    }

    if let Some(mc) = models {
        let samples: Vec<String> = mc
            .snapshot()
            .iter()
            .map(|(n, c)| format!("hinm_model_requests_total{{model=\"{n}\"}} {c}"))
            .collect();
        family(
            &mut out,
            "hinm_model_requests_total",
            "counter",
            "Requests routed per registry model.",
            &samples,
        );
    }

    out
}

/// One family = HELP + TYPE + its samples, emitted as a single group (the
/// exposition format forbids interleaving a family's samples with other
/// families — pinned by `metrics_prometheus_groups_families…`).
fn family(out: &mut String, name: &str, kind: &str, help: &str, samples: &[String]) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    for s in samples {
        out.push_str(s);
        out.push('\n');
    }
}

/// `GET /v1/metrics` body on the `hinm route` router tier: the routing
/// counters (requests/hedges/retries/breaker trips/rejections) plus one
/// block per backend with its breaker state, in-flight count, and measured
/// p95 (DESIGN.md §19). Same dual-format contract as the engine metrics.
pub fn router_metrics_json(s: &RouterSnapshot) -> Json {
    let backends: Vec<Json> = s
        .backends
        .iter()
        .map(|b| {
            Json::obj(vec![
                ("name", Json::str(&b.name)),
                ("state", Json::str(b.health.as_str())),
                ("inflight", Json::num(b.inflight as f64)),
                ("consecutive_failures", Json::num(b.consec_failures as f64)),
                ("requests", Json::num(b.requests as f64)),
                ("failures", Json::num(b.failures as f64)),
                ("p95_us", Json::num(b.p95_us)),
                ("models", Json::arr(b.models.iter().map(|m| Json::str(m)))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("requests", Json::num(s.requests as f64)),
        ("hedges", Json::num(s.hedges as f64)),
        ("retries", Json::num(s.retries as f64)),
        ("breaker_trips", Json::num(s.breaker_trips as f64)),
        ("rejected", Json::num(s.rejected as f64)),
        ("backends", Json::Arr(backends)),
    ])
}

/// [`router_metrics_json`] in the Prometheus text exposition format.
pub fn router_metrics_prometheus(s: &RouterSnapshot) -> String {
    let mut out = String::new();
    family(
        &mut out,
        "hinm_router_requests_total",
        "counter",
        "Requests admitted by the router (answered or failed downstream).",
        &[format!("hinm_router_requests_total {}", s.requests)],
    );
    family(
        &mut out,
        "hinm_router_hedges_total",
        "counter",
        "Hedged second attempts launched after a first attempt exceeded its per-backend p95.",
        &[format!("hinm_router_hedges_total {}", s.hedges)],
    );
    family(
        &mut out,
        "hinm_router_retries_total",
        "counter",
        "Retry attempts launched after a failed downstream attempt.",
        &[format!("hinm_router_retries_total {}", s.retries)],
    );
    family(
        &mut out,
        "hinm_router_breaker_trips_total",
        "counter",
        "Circuit-breaker trips (a backend crossing its failure threshold into Down).",
        &[format!("hinm_router_breaker_trips_total {}", s.breaker_trips)],
    );
    family(
        &mut out,
        "hinm_router_rejected_total",
        "counter",
        "Requests rejected with 503 by admission backpressure or shutdown drain.",
        &[format!("hinm_router_rejected_total {}", s.rejected)],
    );
    family(
        &mut out,
        "hinm_router_backend_state",
        "gauge",
        "Breaker state per backend (labels carry the state; value is always 1).",
        &s.backends
            .iter()
            .map(|b| {
                format!(
                    "hinm_router_backend_state{{backend=\"{}\",state=\"{}\"}} 1",
                    b.name,
                    b.health.as_str()
                )
            })
            .collect::<Vec<_>>(),
    );
    family(
        &mut out,
        "hinm_router_backend_inflight",
        "gauge",
        "Attempts currently in flight per backend.",
        &s.backends
            .iter()
            .map(|b| format!("hinm_router_backend_inflight{{backend=\"{}\"}} {}", b.name, b.inflight))
            .collect::<Vec<_>>(),
    );
    family(
        &mut out,
        "hinm_router_backend_requests_total",
        "counter",
        "Successful downstream responses per backend.",
        &s.backends
            .iter()
            .map(|b| {
                format!("hinm_router_backend_requests_total{{backend=\"{}\"}} {}", b.name, b.requests)
            })
            .collect::<Vec<_>>(),
    );
    family(
        &mut out,
        "hinm_router_backend_failures_total",
        "counter",
        "Failed downstream attempts per backend (passive marks + failed probes).",
        &s.backends
            .iter()
            .map(|b| {
                format!("hinm_router_backend_failures_total{{backend=\"{}\"}} {}", b.name, b.failures)
            })
            .collect::<Vec<_>>(),
    );
    family(
        &mut out,
        "hinm_router_backend_p95_microseconds",
        "gauge",
        "Measured p95 response latency per backend (drives hedging).",
        &s.backends
            .iter()
            .map(|b| {
                format!("hinm_router_backend_p95_microseconds{{backend=\"{}\"}} {}", b.name, b.p95_us)
            })
            .collect::<Vec<_>>(),
    );
    out
}

/// The `stage_links` block a `--stage-hosts` head adds to `/v1/metrics`:
/// one row per TCP link to a stage host (chain order) with its batch,
/// reconnect, and taxonomy-classified failure counters plus round-trip
/// p95 (DESIGN.md §20). Same dual-format contract as every other counter
/// surface; exact values are pinned by `rust/tests/stage_chaos.rs`.
pub fn stage_links_json(s: &StageLinkSnapshot) -> Json {
    Json::Arr(
        s.links
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("host", Json::str(&l.host)),
                    ("batches", Json::num(l.batches as f64)),
                    ("reconnects", Json::num(l.reconnects as f64)),
                    ("failures_unreachable", Json::num(l.failures_unreachable as f64)),
                    ("failures_timeout", Json::num(l.failures_timeout as f64)),
                    ("failures_protocol", Json::num(l.failures_protocol as f64)),
                    ("p95_us", Json::num(l.p95_us)),
                ])
            })
            .collect(),
    )
}

/// [`stage_links_json`] in the Prometheus text exposition format.
pub fn stage_links_prometheus(s: &StageLinkSnapshot) -> String {
    let mut out = String::new();
    family(
        &mut out,
        "hinm_stage_link_batches_total",
        "counter",
        "Batches round-tripped successfully per stage link.",
        &s.links
            .iter()
            .map(|l| format!("hinm_stage_link_batches_total{{host=\"{}\"}} {}", l.host, l.batches))
            .collect::<Vec<_>>(),
    );
    family(
        &mut out,
        "hinm_stage_link_reconnects_total",
        "counter",
        "Successful link re-establishments after a stage link failure.",
        &s.links
            .iter()
            .map(|l| {
                format!("hinm_stage_link_reconnects_total{{host=\"{}\"}} {}", l.host, l.reconnects)
            })
            .collect::<Vec<_>>(),
    );
    let mut failures = Vec::new();
    for l in &s.links {
        failures.push(format!(
            "hinm_stage_link_failures_total{{host=\"{}\",class=\"unreachable\"}} {}",
            l.host, l.failures_unreachable
        ));
        failures.push(format!(
            "hinm_stage_link_failures_total{{host=\"{}\",class=\"timeout\"}} {}",
            l.host, l.failures_timeout
        ));
        failures.push(format!(
            "hinm_stage_link_failures_total{{host=\"{}\",class=\"protocol\"}} {}",
            l.host, l.failures_protocol
        ));
    }
    family(
        &mut out,
        "hinm_stage_link_failures_total",
        "counter",
        "Failed stage-link round-trips, by DESIGN.md §19 taxonomy class.",
        &failures,
    );
    family(
        &mut out,
        "hinm_stage_link_p95_microseconds",
        "gauge",
        "Measured p95 round-trip latency per stage link.",
        &s.links
            .iter()
            .map(|l| format!("hinm_stage_link_p95_microseconds{{host=\"{}\"}} {}", l.host, l.p95_us))
            .collect::<Vec<_>>(),
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn infer_request_roundtrip_with_defaults() {
        let r = InferRequest::new(vec![1.0, -2.5, 0.0]);
        let text = r.to_json().pretty();
        assert!(!text.contains("priority"), "default priority is omitted: {text}");
        assert!(!text.contains("deadline_ms"), "absent deadline is omitted: {text}");
        let back = InferRequest::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn infer_request_roundtrip_with_scheduling() {
        let r = InferRequest {
            x: vec![0.5; 4],
            priority: Priority::High,
            deadline_ms: Some(250),
            model: Some("deit-mini".to_string()),
        };
        let text = r.to_json().pretty();
        assert!(text.contains("\"model\""), "named model is serialized: {text}");
        let back = InferRequest::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
        // Builder form matches the literal.
        let built = InferRequest::new(vec![0.5; 4]).with_model("deit-mini");
        assert_eq!(built.model, r.model);
    }

    #[test]
    fn infer_request_rejects_malformed_bodies() {
        for (body, needle) in [
            (r#"{}"#, "missing required field"),
            (r#"{"x": "nope"}"#, "missing required field"),
            (r#"{"x": [1, "two"]}"#, "only numbers"),
            (r#"{"x": [3.5e38]}"#, "finite"),
            (r#"{"x": [1e999]}"#, "finite"),
            (r#"{"x": [1], "priority": "urgent"}"#, "unknown priority"),
            (r#"{"x": [1], "priority": 3}"#, "must be a string"),
            (r#"{"x": [1], "deadline_ms": "soon"}"#, "must be a number"),
            (r#"{"x": [1], "deadline_ms": -5}"#, "non-negative"),
            (r#"{"x": [1], "model": 7}"#, "\"model\" must be a string"),
        ] {
            let err = InferRequest::from_json(&json::parse(body).unwrap()).unwrap_err();
            assert!(err.contains(needle), "body {body}: expected {needle:?} in {err:?}");
        }
    }

    #[test]
    fn infer_response_roundtrip() {
        let y = vec![1.5f32, -3.25, 0.0];
        let v = infer_response(&y);
        let back = parse_infer_response(&json::parse(&v.pretty()).unwrap()).unwrap();
        assert_eq!(back, y);
    }

    #[test]
    fn status_mapping_is_stable() {
        assert_eq!(status_for(&InferError::DeadlineExpired).0, 504);
        assert_eq!(status_for(&InferError::Stopped).0, 503);
        assert_eq!(status_for(&InferError::Backend("x".into())).0, 500);
        assert_eq!(status_for(&InferError::BadRequest("x".into())).0, 400);
        assert_eq!(status_for(&InferError::Upstream("x".into())), (502, "bad_gateway"));
        assert_eq!(
            status_for(&InferError::UpstreamTimeout("x".into())),
            (504, "upstream_timeout")
        );
        // The shared renderer carries the mapped status and kind.
        let resp = error_response(&InferError::Upstream("refused".into()));
        assert_eq!(resp.status, 502);
        assert!(resp.body.contains("bad_gateway"), "{}", resp.body);
    }

    #[test]
    fn metrics_prometheus_groups_families_and_honors_the_cache() {
        let m = EngineMetrics::new(2);
        m.scheduler.lock().unwrap().served[Priority::High.index()] = 3;
        let text = metrics_prometheus(&m, None, None);
        assert!(text.contains("# TYPE hinm_requests_total counter"), "{text}");
        assert!(text.contains("# TYPE hinm_request_latency_microseconds summary"));
        assert!(text.contains("hinm_requests_served_total{priority=\"high\"} 3"));
        assert!(text.contains("hinm_replica_batches_total{replica=\"1\"} 0"));
        assert!(!text.contains("hinm_cache_hits_total"), "no cache family without a cache");
        assert!(!text.contains("hinm_kernel_info"), "no kernel family without a kernel");
        // Every family is one contiguous group: a TYPE line, then only that
        // family's samples until the next comment line.
        let mut current: Option<String> = None;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                current = Some(rest.split_whitespace().next().unwrap().to_string());
            } else if !line.starts_with('#') && !line.is_empty() {
                let fam = current.as_ref().expect("sample before any TYPE line");
                assert!(
                    line.starts_with(fam.as_str()),
                    "sample {line:?} outside its family {fam:?}"
                );
            }
        }
        let stats = CacheStats::new_shared();
        let ki = KernelInfo::current(crate::spmm::ValueFormat::Bf16);
        let text = metrics_prometheus(&m, Some(stats.as_ref()), Some(&ki));
        assert!(text.contains("hinm_cache_hits_total 0"));
        assert!(text.contains("hinm_cache_misses_total 0"));
        assert!(text.contains("values=\"bf16\"} 1"), "{text}");
        assert!(
            text.contains(&format!("hinm_kernel_info{{isa=\"{}\"", ki.isa.as_str())),
            "{text}"
        );
        assert!(text
            .contains(&format!("hinm_kernel_panel_target_bytes {}", ki.panel_target_bytes)));
    }

    #[test]
    fn metrics_json_has_the_documented_shape() {
        let m = EngineMetrics::new(2);
        m.scheduler.lock().unwrap().served[Priority::High.index()] = 3;
        let v = metrics_json(&m, None, None);
        assert_eq!(v.get("priorities").get("high").as_usize(), Some(3));
        assert_eq!(v.get("replicas").as_arr().unwrap().len(), 2);
        assert!(v.get("cache").as_obj().is_none(), "no cache block without a cache");
        assert!(v.get("kernel").as_obj().is_none(), "no kernel block without a kernel");
        let stats = CacheStats::new_shared();
        let ki = KernelInfo::current(crate::spmm::ValueFormat::F32);
        let v = metrics_json(&m, Some(stats.as_ref()), Some(&ki));
        assert_eq!(v.get("cache").get("hits").as_usize(), Some(0));
        assert_eq!(v.get("kernel").get("values").as_str(), Some("f32"));
        assert_eq!(v.get("kernel").get("isa").as_str(), Some(ki.isa.as_str()));
        assert_eq!(
            v.get("kernel").get("panel_target_bytes").as_usize(),
            Some(ki.panel_target_bytes)
        );
    }

    #[test]
    fn metrics_carry_per_model_counters_when_present() {
        let m = EngineMetrics::new(1);
        let counters = ModelCounters::new_shared();
        counters.record("ffn-relu");
        counters.record("ffn-relu");
        counters.record("deit-mini");
        let v = metrics_json_with_models(&m, None, None, Some(&counters));
        assert_eq!(v.get("model_requests").get("ffn-relu").as_usize(), Some(2));
        assert_eq!(v.get("model_requests").get("deit-mini").as_usize(), Some(1));
        // The plain variant stays model-free (single-model front).
        assert!(metrics_json(&m, None, None).get("model_requests").as_obj().is_none());
        let text = metrics_prometheus_with_models(&m, None, None, Some(&counters));
        assert!(text.contains("# TYPE hinm_model_requests_total counter"), "{text}");
        assert!(text.contains("hinm_model_requests_total{model=\"ffn-relu\"} 2"), "{text}");
        assert!(!metrics_prometheus(&m, None, None).contains("hinm_model_requests_total"));
    }
}
