//! The hierarchical N:M pipeline: column-wise vector pruning → row-wise N:M,
//! one-shot and gradual schedules (paper §3.1, §5.1.2).

use super::config::HinmConfig;
use super::format::{pack, HinmPacked};
use super::mask::Mask;
use super::nm_prune::nm_retained_tile;
use super::vector_prune::{vector_prune, VectorPruneResult};
use crate::tensor::Matrix;

/// Outcome of HiNM pruning a single layer.
#[derive(Clone, Debug)]
pub struct HinmResult {
    /// The layer in packed HiNM form.
    pub packed: HinmPacked,
    /// Dense boolean mask equivalent of the packed layer.
    pub mask: Mask,
    /// `‖M ⊙ ρ‖₁` — the Eq. 1 objective value.
    pub retained: f64,
    /// `retained / ‖ρ‖₁`.
    pub retention_ratio: f64,
}

/// One-shot HiNM pruning without any permutation (the paper's HiNM-NoPerm
/// arm): vector-prune on saliency, then 2:4 on the survivors in natural
/// column order.
pub fn prune_oneshot(w: &Matrix, sal: &Matrix, cfg: &HinmConfig) -> HinmResult {
    let vp = vector_prune(sal, cfg);
    prune_with_kept(w, sal, cfg, &vp, None)
}

/// HiNM pruning given a vector-prune result and optional per-tile column
/// orders (the ICP output). Used by the gyro pipeline after permutation.
pub fn prune_with_kept(
    w: &Matrix,
    sal: &Matrix,
    cfg: &HinmConfig,
    vp: &VectorPruneResult,
    tile_col_order: Option<&[Vec<usize>]>,
) -> HinmResult {
    let packed = pack(w, sal, cfg, &vp.kept, tile_col_order);
    let mask = super::format::packed_mask(&packed);
    let retained = mask.retained(sal);
    let total: f64 = sal.l1();
    HinmResult {
        packed,
        mask,
        retained,
        retention_ratio: if total > 0.0 { retained / total } else { 1.0 },
    }
}

/// Retained saliency of HiNM *without* materializing the packed matrix —
/// the inner-loop objective used by permutation search. Natural column order
/// within each tile (ascending kept index), groups of M consecutive columns.
pub fn hinm_retained(sal: &Matrix, cfg: &HinmConfig) -> f64 {
    let vp = vector_prune(sal, cfg);
    let k_v = vp.kept[0].len();
    let mut total = 0.0;
    let mut tile_buf = vec![0.0f32; cfg.v * k_v];
    for (t, kept) in vp.kept.iter().enumerate() {
        gather_tile(sal, cfg, t, kept, &mut tile_buf);
        total += nm_retained_tile(&tile_buf, cfg.v, k_v, cfg);
    }
    total
}

/// Gather a tile's compacted saliency `[v, |cols|]` into `buf`.
pub fn gather_tile(sal: &Matrix, cfg: &HinmConfig, t: usize, cols: &[usize], buf: &mut [f32]) {
    let k = cols.len();
    debug_assert_eq!(buf.len(), cfg.v * k);
    for r in 0..cfg.v {
        let srow = sal.row(t * cfg.v + r);
        let dst = &mut buf[r * k..(r + 1) * k];
        for (j, &c) in cols.iter().enumerate() {
            dst[j] = srow[c];
        }
    }
}

/// Gather a tile's compacted saliency into `buf` **column-major**: kept
/// column `j` occupies `buf[j*V .. (j+1)*V]`, so each column vector is one
/// contiguous slice — the layout the ICP cost kernels consume. Used by the
/// strategy-layer tile engine with a per-worker reusable scratch buffer.
pub fn gather_tile_colmajor(sal: &Matrix, cfg: &HinmConfig, t: usize, cols: &[usize], buf: &mut [f32]) {
    let k = cols.len();
    debug_assert_eq!(buf.len(), cfg.v * k);
    for r in 0..cfg.v {
        let srow = sal.row(t * cfg.v + r);
        for (j, &c) in cols.iter().enumerate() {
            buf[j * cfg.v + r] = srow[c];
        }
    }
}

/// A step of the gradual schedule (paper §5.1.2): vector sparsity ramps
/// cubically from 0 to the target over `vector_steps`, after which N:M
/// switches on for the remaining steps.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GradualStep {
    /// Schedule step index.
    pub step: usize,
    /// Vector-level sparsity at this step.
    pub vector_sparsity: f64,
    /// Whether the N:M level is switched on yet.
    pub nm_active: bool,
}

/// Cubic sparsity ramp (Zhu & Gupta) used for the vector level.
pub fn gradual_schedule(target_sv: f64, vector_steps: usize, total_steps: usize) -> Vec<GradualStep> {
    assert!(vector_steps >= 1 && total_steps >= vector_steps);
    let mut steps = Vec::with_capacity(total_steps);
    for i in 0..total_steps {
        if i < vector_steps {
            let frac = (i + 1) as f64 / vector_steps as f64;
            let sv = target_sv * (1.0 - (1.0 - frac).powi(3));
            steps.push(GradualStep { step: i, vector_sparsity: sv, nm_active: false });
        } else {
            steps.push(GradualStep { step: i, vector_sparsity: target_sv, nm_active: true });
        }
    }
    steps
}

/// Effective config at a gradual step.
pub fn step_config(base: &HinmConfig, s: &GradualStep) -> HinmConfig {
    HinmConfig {
        v: base.v,
        n_keep: if s.nm_active { base.n_keep } else { base.m_group },
        m_group: base.m_group,
        vector_sparsity: s.vector_sparsity,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn oneshot_density() {
        let mut rng = Xoshiro256::new(7);
        let w = Matrix::randn(32, 64, 1.0, &mut rng);
        let sal = w.abs();
        let cfg = HinmConfig::with_24(8, 0.5); // 75% total
        let res = prune_oneshot(&w, &sal, &cfg);
        assert!((res.mask.sparsity() - 0.75).abs() < 0.02);
        assert!(res.retention_ratio > 0.25 && res.retention_ratio < 1.0);
        res.packed.check_invariants().unwrap();
    }

    #[test]
    fn retained_fast_path_matches_packed() {
        let mut rng = Xoshiro256::new(8);
        for _ in 0..10 {
            let w = Matrix::randn(16, 32, 1.0, &mut rng);
            let sal = w.abs();
            let cfg = HinmConfig::with_24(4, 0.5);
            let fast = hinm_retained(&sal, &cfg);
            let slow = prune_oneshot(&w, &sal, &cfg).retained;
            assert!((fast - slow).abs() < 1e-6 * slow.max(1.0), "{fast} vs {slow}");
        }
    }

    #[test]
    fn retention_monotone_in_sparsity() {
        let mut rng = Xoshiro256::new(9);
        let sal = Matrix::randn(32, 64, 1.0, &mut rng).abs();
        let r50 = hinm_retained(&sal, &HinmConfig::for_total_sparsity(8, 0.5));
        let r75 = hinm_retained(&sal, &HinmConfig::for_total_sparsity(8, 0.75));
        let r875 = hinm_retained(&sal, &HinmConfig::for_total_sparsity(8, 0.875));
        assert!(r50 > r75 && r75 > r875, "{r50} {r75} {r875}");
    }

    #[test]
    fn gradual_schedule_shape() {
        let steps = gradual_schedule(0.5, 4, 7);
        assert_eq!(steps.len(), 7);
        assert!(!steps[0].nm_active && steps[3].vector_sparsity == 0.5);
        assert!(steps[4].nm_active && steps[6].vector_sparsity == 0.5);
        // Monotone non-decreasing ramp.
        for w in steps.windows(2) {
            assert!(w[1].vector_sparsity >= w[0].vector_sparsity - 1e-12);
        }
    }

    #[test]
    fn step_config_disables_nm_during_ramp() {
        let base = HinmConfig::with_24(32, 0.5);
        let ramp = GradualStep { step: 0, vector_sparsity: 0.2, nm_active: false };
        let c = step_config(&base, &ramp);
        assert_eq!(c.n_keep, c.m_group); // N==M → N:M is a no-op
        assert!((c.total_sparsity() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn gather_tile_layout() {
        let sal = Matrix::from_fn(4, 6, |r, c| (r * 10 + c) as f32);
        let cfg = HinmConfig::with_24(4, 0.0);
        let cols = vec![1usize, 3, 4, 5];
        let mut buf = vec![0.0; 4 * 4];
        gather_tile(&sal, &cfg, 0, &cols, &mut buf);
        assert_eq!(&buf[0..4], &[1.0, 3.0, 4.0, 5.0]);
        assert_eq!(&buf[12..16], &[31.0, 33.0, 34.0, 35.0]);
    }

    #[test]
    fn gather_tile_colmajor_is_transpose_of_rowmajor() {
        let sal = Matrix::from_fn(8, 6, |r, c| (r * 10 + c) as f32);
        let cfg = HinmConfig::with_24(4, 0.0);
        let cols = vec![0usize, 2, 5];
        let (v, k) = (cfg.v, cols.len());
        let mut row_buf = vec![0.0; v * k];
        let mut col_buf = vec![0.0; v * k];
        for t in 0..2 {
            gather_tile(&sal, &cfg, t, &cols, &mut row_buf);
            gather_tile_colmajor(&sal, &cfg, t, &cols, &mut col_buf);
            for r in 0..v {
                for j in 0..k {
                    assert_eq!(col_buf[j * v + r], row_buf[r * k + j], "t={t} r={r} j={j}");
                }
            }
            // Column j is contiguous and equals the tile's column cols[j].
            for (j, &c) in cols.iter().enumerate() {
                let col = &col_buf[j * v..(j + 1) * v];
                for r in 0..v {
                    assert_eq!(col[r], sal.at(t * v + r, c));
                }
            }
        }
    }
}
