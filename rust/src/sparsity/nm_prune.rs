//! Row-wise N:M pruning (the fine, hardware-indexed level of HiNM).
//!
//! Operates on the *compacted* tile view: the `K_v` column vectors kept by
//! vector pruning, laid out contiguously in `vec_idx` order. Each row of the
//! tile is split into groups of `M` consecutive surviving columns; the `N`
//! most salient elements of each group are kept (NVIDIA STC semantics).

use super::config::HinmConfig;

/// N:M selection for one logical row segment of length `M`:
/// returns ascending in-group offsets of the kept elements.
pub fn select_nm(group: &[f32], n_keep: usize) -> Vec<u8> {
    debug_assert!(n_keep <= group.len());
    let mut idx: Vec<usize> = (0..group.len()).collect();
    idx.sort_by(|&a, &b| {
        group[b]
            .partial_cmp(&group[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut kept: Vec<u8> = idx.into_iter().take(n_keep).map(|i| i as u8).collect();
    kept.sort_unstable();
    kept
}

/// Result of N:M pruning one tile's compacted saliency `[v, k_v]`.
#[derive(Clone, Debug, PartialEq)]
pub struct NmTile {
    /// `offsets[r][g*n_keep + j]` = in-group offset (0..m_group) of the j-th
    /// kept element of group g in row r. Ascending within each group.
    pub offsets: Vec<Vec<u8>>,
    /// Retained saliency of the tile under the N:M mask.
    pub retained: f64,
}

/// Apply N:M to a compacted tile of saliency values (row-major `[v][k_v]`).
pub fn nm_prune_tile(tile_sal: &[f32], v: usize, k_v: usize, cfg: &HinmConfig) -> NmTile {
    assert_eq!(tile_sal.len(), v * k_v);
    assert_eq!(k_v % cfg.m_group, 0, "compacted width must be a multiple of M");
    let groups = k_v / cfg.m_group;
    let mut offsets = Vec::with_capacity(v);
    let mut retained = 0.0f64;
    for r in 0..v {
        let row = &tile_sal[r * k_v..(r + 1) * k_v];
        let mut row_off = Vec::with_capacity(groups * cfg.n_keep);
        for g in 0..groups {
            let grp = &row[g * cfg.m_group..(g + 1) * cfg.m_group];
            for off in select_nm(grp, cfg.n_keep) {
                retained += grp[off as usize] as f64;
                row_off.push(off);
            }
        }
        offsets.push(row_off);
    }
    NmTile { offsets, retained }
}

/// Retained saliency of a compacted tile under 2:4 without materializing the
/// offsets — used in permutation inner loops (hot path).
#[inline]
pub fn nm_retained_tile(tile_sal: &[f32], v: usize, k_v: usize, cfg: &HinmConfig) -> f64 {
    debug_assert_eq!(tile_sal.len(), v * k_v);
    let m = cfg.m_group;
    let n = cfg.n_keep;
    let mut retained = 0.0f64;
    if m == 4 && n == 2 {
        // Specialized 2:4: keep the two largest of four = sum - two smallest
        // = sum of the two largest; branchless-ish max selection.
        for r in 0..v {
            let row = &tile_sal[r * k_v..(r + 1) * k_v];
            for g in row.chunks_exact(4) {
                let (a, b, c, d) = (g[0], g[1], g[2], g[3]);
                // top2 = sum - min2 where min2 = sum of two smallest
                let (lo1, hi1) = if a < b { (a, b) } else { (b, a) };
                let (lo2, hi2) = if c < d { (c, d) } else { (d, c) };
                // two smallest of {a,b,c,d}
                let smallest = if lo1 < lo2 { lo1 } else { lo2 };
                let second = if lo1 < lo2 {
                    if lo2 < hi1 { lo2 } else { hi1 }
                } else if lo1 < hi2 {
                    lo1
                } else {
                    hi2
                };
                retained += (a + b + c + d - smallest - second) as f64;
            }
        }
    } else {
        for r in 0..v {
            let row = &tile_sal[r * k_v..(r + 1) * k_v];
            for g in row.chunks_exact(m) {
                let mut buf: Vec<f32> = g.to_vec();
                buf.sort_by(|x, y| y.partial_cmp(x).unwrap_or(std::cmp::Ordering::Equal));
                retained += buf[..n].iter().map(|&x| x as f64).sum::<f64>();
            }
        }
    }
    retained
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn cfg() -> HinmConfig {
        HinmConfig::with_24(4, 0.0)
    }

    #[test]
    fn select_24_picks_top2() {
        assert_eq!(select_nm(&[1.0, 9.0, 3.0, 7.0], 2), vec![1, 3]);
        assert_eq!(select_nm(&[5.0, 5.0, 1.0, 0.0], 2), vec![0, 1]); // ties → low idx
        assert_eq!(select_nm(&[-1.0, -2.0, -3.0, -4.0], 2), vec![0, 1]);
    }

    #[test]
    fn tile_retained_counts_top2_per_group() {
        // 1 row, 8 cols = 2 groups.
        let sal = vec![1., 2., 3., 4., 10., 0., 0., 20.];
        let t = nm_prune_tile(&sal, 1, 8, &cfg());
        assert_eq!(t.retained, (3. + 4. + 10. + 20.) as f64);
        assert_eq!(t.offsets[0], vec![2, 3, 0, 3]);
    }

    #[test]
    fn fast_retained_matches_materialized() {
        let mut rng = Xoshiro256::new(11);
        for _ in 0..50 {
            let v = 4 + rng.below(4) * 4;
            let kv = 4 * (1 + rng.below(8));
            let sal: Vec<f32> = (0..v * kv).map(|_| rng.next_f32() * 10.0).collect();
            let a = nm_prune_tile(&sal, v, kv, &cfg()).retained;
            let b = nm_retained_tile(&sal, v, kv, &cfg());
            assert!((a - b).abs() < 1e-6 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn fast_retained_generic_nm() {
        let cfg_13 = HinmConfig { v: 1, n_keep: 1, m_group: 3, vector_sparsity: 0.0 };
        let sal = vec![5., 1., 2., 0., 9., 3.];
        let r = nm_retained_tile(&sal, 1, 6, &cfg_13);
        assert_eq!(r, 14.0);
    }

    #[test]
    fn offsets_shape() {
        let sal = vec![0.0f32; 8 * 16];
        let t = nm_prune_tile(&sal, 8, 16, &cfg());
        assert_eq!(t.offsets.len(), 8);
        assert!(t.offsets.iter().all(|r| r.len() == 16 / 4 * 2));
    }

    #[test]
    fn negative_saliency_still_selects_largest() {
        // Saliency should be nonnegative in practice, but the selector must
        // stay total-order-correct for negatives too.
        let sal = vec![-5., -1., -3., -2.];
        assert_eq!(select_nm(&sal, 2), vec![1, 3]);
    }
}
