//! Column-wise `V×1` vector pruning (the coarse level of HiNM).
//!
//! Within each tile (a band of `V` consecutive output channels), every input
//! channel contributes one `V×1` column vector. The least-salient vectors are
//! removed tile-by-tile; survivors are recorded as a per-tile `vec_idx` list
//! (ascending original column ids) — exactly the index the GPU kernel uses
//! for the global→shared gather.

use super::config::HinmConfig;
use super::mask::Mask;
use crate::tensor::Matrix;

/// Per-tile kept-column result of vector pruning.
#[derive(Clone, Debug, PartialEq)]
pub struct VectorPruneResult {
    /// `kept[t]` = ascending original column indices kept in tile `t`.
    pub kept: Vec<Vec<usize>>,
    /// Dense mask equivalent (vector level only).
    pub mask: Mask,
}

/// Saliency of each column vector: `vecsal[t][c] = Σ_{r in tile t} ρ[r][c]`.
pub fn vector_saliency(sal: &Matrix, cfg: &HinmConfig) -> Vec<Vec<f64>> {
    let tiles = cfg.tiles(sal.rows);
    let mut out = vec![vec![0.0f64; sal.cols]; tiles];
    for t in 0..tiles {
        let acc = &mut out[t];
        for r in t * cfg.v..(t + 1) * cfg.v {
            let row = sal.row(r);
            for (c, &s) in row.iter().enumerate() {
                acc[c] += s as f64;
            }
        }
    }
    out
}

/// Keep the `keep_cols` most salient column vectors of each tile.
pub fn vector_prune(sal: &Matrix, cfg: &HinmConfig) -> VectorPruneResult {
    cfg.validate(sal.rows, sal.cols).expect("invalid HiNM config for shape");
    let k = cfg.keep_cols(sal.cols);
    let vecsal = vector_saliency(sal, cfg);
    let tiles = vecsal.len();
    let mut kept = Vec::with_capacity(tiles);
    let mut mask = Mask::zeros(sal.rows, sal.cols);
    for (t, colsal) in vecsal.iter().enumerate() {
        let cols = top_k_indices(colsal, k);
        for &c in &cols {
            for r in t * cfg.v..(t + 1) * cfg.v {
                mask.set(r, c, true);
            }
        }
        kept.push(cols);
    }
    VectorPruneResult { kept, mask }
}

/// Indices of the `k` largest values, returned in ascending index order
/// (deterministic tie-break: lower index wins).
pub fn top_k_indices(vals: &[f64], k: usize) -> Vec<usize> {
    assert!(k <= vals.len());
    let mut idx: Vec<usize> = (0..vals.len()).collect();
    idx.sort_by(|&a, &b| {
        vals[b]
            .partial_cmp(&vals[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut top: Vec<usize> = idx.into_iter().take(k).collect();
    top.sort_unstable();
    top
}

/// Retained saliency under vector pruning only (Eq. 2 objective).
pub fn vector_retained(sal: &Matrix, cfg: &HinmConfig) -> f64 {
    vector_prune(sal, cfg).mask.retained(sal)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn cfg4(sv: f64) -> HinmConfig {
        HinmConfig::with_24(4, sv)
    }

    #[test]
    fn top_k_basics() {
        assert_eq!(top_k_indices(&[1.0, 5.0, 3.0, 5.0], 2), vec![1, 3]);
        assert_eq!(top_k_indices(&[1.0, 5.0, 3.0], 0), Vec::<usize>::new());
        assert_eq!(top_k_indices(&[2.0, 2.0, 2.0], 2), vec![0, 1]); // tie → low idx
    }

    #[test]
    fn keeps_most_salient_columns_per_tile() {
        // 4×8 = one tile; columns 0..8 with column 6 and 2 clearly dominant.
        let mut sal = Matrix::zeros(4, 8);
        for r in 0..4 {
            *sal.at_mut(r, 6) = 10.0;
            *sal.at_mut(r, 2) = 8.0;
            *sal.at_mut(r, 0) = 1.0;
        }
        let res = vector_prune(&sal, &cfg4(0.5)); // keep 4 of 8
        assert_eq!(res.kept.len(), 1);
        let kept = &res.kept[0];
        assert_eq!(kept.len(), 4);
        assert!(kept.contains(&6) && kept.contains(&2));
    }

    #[test]
    fn tiles_prune_independently() {
        // 8×8, V=4 → 2 tiles with opposite dominant columns.
        let mut sal = Matrix::zeros(8, 8);
        for r in 0..4 {
            *sal.at_mut(r, 0) = 5.0; // tile 0 likes col 0
        }
        for r in 4..8 {
            *sal.at_mut(r, 7) = 5.0; // tile 1 likes col 7
        }
        let res = vector_prune(&sal, &cfg4(0.5));
        assert!(res.kept[0].contains(&0));
        assert!(res.kept[1].contains(&7));
        assert_ne!(res.kept[0], res.kept[1]);
    }

    #[test]
    fn mask_sparsity_matches_config() {
        let mut rng = Xoshiro256::new(3);
        let sal = Matrix::randn(32, 64, 1.0, &mut rng).abs();
        let cfg = HinmConfig::with_24(8, 0.5);
        let res = vector_prune(&sal, &cfg);
        let expect_kept = cfg.keep_cols(64) * 32;
        assert_eq!(res.mask.count_kept(), expect_kept);
        for kept in &res.kept {
            assert_eq!(kept.len(), cfg.keep_cols(64));
            assert!(kept.windows(2).all(|w| w[0] < w[1]), "ascending");
        }
    }

    #[test]
    fn zero_vector_sparsity_keeps_everything() {
        let mut rng = Xoshiro256::new(4);
        let sal = Matrix::randn(8, 16, 1.0, &mut rng).abs();
        let res = vector_prune(&sal, &cfg4(0.0));
        assert_eq!(res.mask.count_kept(), 8 * 16);
    }

    #[test]
    fn retained_is_sum_over_kept_columns() {
        let sal = Matrix::from_vec(4, 4, vec![1.0; 16]);
        // keep 4 of 4 (sv=0): everything retained.
        assert_eq!(vector_retained(&sal, &cfg4(0.0)), 16.0);
    }
}
