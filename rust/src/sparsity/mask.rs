//! Binary pruning masks over 2-D weight grids.

use crate::tensor::Matrix;

/// A dense boolean mask with matrix shape. `true` = kept weight.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    bits: Vec<bool>,
}

impl Mask {
    /// Mask with every bit set to `value`.
    pub fn new_all(rows: usize, cols: usize, value: bool) -> Self {
        Self { rows, cols, bits: vec![value; rows * cols] }
    }

    /// All-kept mask.
    pub fn ones(rows: usize, cols: usize) -> Self {
        Self::new_all(rows, cols, true)
    }

    /// All-pruned mask.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self::new_all(rows, cols, false)
    }

    #[inline]
    /// Bit at `(r, c)`.
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        self.bits[r * self.cols + c]
    }

    #[inline]
    /// Set the bit at `(r, c)`.
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        self.bits[r * self.cols + c] = v;
    }

    /// Number of kept weights.
    pub fn count_kept(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    /// Fraction of weights *removed*.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.count_kept() as f64 / self.bits.len() as f64
    }

    /// Logical AND — composing hierarchical levels.
    pub fn and(&self, other: &Mask) -> Mask {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mask {
            rows: self.rows,
            cols: self.cols,
            bits: self
                .bits
                .iter()
                .zip(&other.bits)
                .map(|(a, b)| *a && *b)
                .collect(),
        }
    }

    /// Apply to weights: kept entries pass through, pruned become 0.
    pub fn apply(&self, w: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (w.rows, w.cols));
        Matrix {
            rows: w.rows,
            cols: w.cols,
            data: w
                .data
                .iter()
                .zip(&self.bits)
                .map(|(&x, &b)| if b { x } else { 0.0 })
                .collect(),
        }
    }

    /// Sum of saliency over kept entries: `‖M ⊙ ρ‖₁` for nonneg ρ.
    pub fn retained(&self, sal: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (sal.rows, sal.cols));
        sal.data
            .iter()
            .zip(&self.bits)
            .filter(|(_, &b)| b)
            .map(|(&s, _)| s as f64)
            .sum()
    }

    /// Row permutation (matches `Matrix::permute_rows`).
    pub fn permute_rows(&self, perm: &[usize]) -> Mask {
        assert_eq!(perm.len(), self.rows);
        let mut out = Mask::zeros(self.rows, self.cols);
        for (i, &p) in perm.iter().enumerate() {
            for c in 0..self.cols {
                out.set(i, c, self.get(p, c));
            }
        }
        out
    }

    /// The mask as a 0.0/1.0 matrix.
    pub fn as_matrix(&self) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.bits.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let mut m = Mask::zeros(2, 3);
        assert_eq!(m.count_kept(), 0);
        m.set(1, 2, true);
        m.set(0, 0, true);
        assert!(m.get(1, 2));
        assert_eq!(m.count_kept(), 2);
        assert!((m.sparsity() - (1.0 - 2.0 / 6.0)).abs() < 1e-12);
    }

    #[test]
    fn and_composes() {
        let mut a = Mask::ones(2, 2);
        a.set(0, 0, false);
        let mut b = Mask::ones(2, 2);
        b.set(1, 1, false);
        let c = a.and(&b);
        assert_eq!(c.count_kept(), 2);
        assert!(!c.get(0, 0) && !c.get(1, 1));
    }

    #[test]
    fn apply_and_retained() {
        let w = Matrix::from_vec(2, 2, vec![1., -2., 3., -4.]);
        let mut m = Mask::zeros(2, 2);
        m.set(0, 1, true);
        m.set(1, 0, true);
        let pruned = m.apply(&w);
        assert_eq!(pruned.data, vec![0., -2., 3., 0.]);
        assert_eq!(m.retained(&w.abs()), 5.0);
    }

    #[test]
    fn permute_rows_consistent_with_matrix() {
        let w = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let mut m = Mask::zeros(3, 2);
        m.set(0, 0, true);
        m.set(2, 1, true);
        let perm = vec![2, 0, 1];
        assert_eq!(m.permute_rows(&perm).apply(&w.permute_rows(&perm)), m.apply(&w).permute_rows(&perm));
    }
}
