//! HiNM sparsity configuration: vector size `V`, `N:M` pattern, and vector
//! sparsity, with the arithmetic tying them to total sparsity.

/// Configuration of the hierarchical N:M pattern.
///
/// A weight matrix `W[m, n]` is tiled into `T = m / v` row-bands ("tiles") of
/// `v` consecutive output channels. Per tile, column-wise `v×1` vector pruning
/// keeps `keep_cols(n)` input columns; row-wise `n_keep:m_group` (e.g. 2:4)
/// pruning then keeps `n_keep` of every `m_group` surviving columns per row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HinmConfig {
    /// Column-vector height V (paper: 32 for ResNets; 32/64/128 in Fig. 5).
    pub v: usize,
    /// N of N:M (kept elements per group).
    pub n_keep: usize,
    /// M of N:M (group width). NVIDIA STC: 2:4.
    pub m_group: usize,
    /// Fraction of column vectors removed per tile, in [0, 1).
    pub vector_sparsity: f64,
}

impl HinmConfig {
    /// Standard 2:4 with the given vector size and vector sparsity.
    pub fn with_24(v: usize, vector_sparsity: f64) -> Self {
        Self { v, n_keep: 2, m_group: 4, vector_sparsity }
    }

    /// Derive the config that reaches `total` overall sparsity with 2:4 fixed:
    /// `total = 1 - (1 - s_v)·(N/M)` ⇒ `s_v = 1 - (1-total)·M/N`.
    pub fn for_total_sparsity(v: usize, total: f64) -> Self {
        let nm_density = 0.5;
        let sv = 1.0 - (1.0 - total) / nm_density;
        assert!(
            (0.0..1.0).contains(&sv),
            "total sparsity {total} unreachable with 2:4 (needs ≥ 0.5)"
        );
        Self::with_24(v, sv)
    }

    /// Overall sparsity implied by the config.
    pub fn total_sparsity(&self) -> f64 {
        1.0 - (1.0 - self.vector_sparsity) * self.nm_density()
    }

    /// Fraction of weights the N:M level keeps (`n_keep / m_group`).
    pub fn nm_density(&self) -> f64 {
        self.n_keep as f64 / self.m_group as f64
    }

    /// Number of column vectors kept per tile for `n` input channels,
    /// rounded to a multiple of `m_group` (the ICP partition width) and
    /// clamped to at least one group.
    pub fn keep_cols(&self, n: usize) -> usize {
        let raw = (n as f64 * (1.0 - self.vector_sparsity)).round() as usize;
        let k = (raw / self.m_group) * self.m_group;
        k.max(self.m_group).min(n - n % self.m_group)
    }

    /// Number of tiles for `m` output channels (requires `m % v == 0`).
    pub fn tiles(&self, m: usize) -> usize {
        assert_eq!(m % self.v, 0, "rows {m} not a multiple of vector size {}", self.v);
        m / self.v
    }

    /// Kept values per tile row after N:M (`keep_cols · N/M`).
    pub fn vals_per_row(&self, n: usize) -> usize {
        self.keep_cols(n) * self.n_keep / self.m_group
    }

    /// Validate against a concrete weight shape.
    pub fn validate(&self, m: usize, n: usize) -> Result<(), String> {
        if self.v == 0 || self.n_keep == 0 || self.m_group == 0 {
            return Err("zero-sized config".into());
        }
        if self.n_keep > self.m_group {
            return Err(format!("N:M with N={} > M={}", self.n_keep, self.m_group));
        }
        if m % self.v != 0 {
            return Err(format!("rows {m} not a multiple of V={}", self.v));
        }
        if n < self.m_group {
            return Err(format!("cols {n} smaller than M={}", self.m_group));
        }
        if !(0.0..1.0).contains(&self.vector_sparsity) {
            return Err(format!("vector sparsity {} out of [0,1)", self.vector_sparsity));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_sparsity_roundtrip() {
        for &total in &[0.5, 0.625, 0.65, 0.75, 0.85, 0.875] {
            let cfg = HinmConfig::for_total_sparsity(32, total);
            assert!((cfg.total_sparsity() - total).abs() < 1e-9, "total {total}");
        }
    }

    #[test]
    fn paper_sparsity_mapping() {
        // 75% total with 2:4 → 50% vector sparsity (paper Fig. 1).
        let cfg = HinmConfig::for_total_sparsity(4, 0.75);
        assert!((cfg.vector_sparsity - 0.5).abs() < 1e-9);
        // 50% total → dense vector level.
        let cfg = HinmConfig::for_total_sparsity(4, 0.5);
        assert!(cfg.vector_sparsity.abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn below_nm_floor_panics() {
        let _ = HinmConfig::for_total_sparsity(4, 0.4);
    }

    #[test]
    fn keep_cols_multiple_of_group() {
        let cfg = HinmConfig::with_24(32, 0.3);
        for n in [16usize, 64, 100, 768, 3072] {
            let k = cfg.keep_cols(n);
            assert_eq!(k % 4, 0);
            assert!(k >= 4 && k <= n);
        }
    }

    #[test]
    fn vals_per_row_is_half_keep() {
        let cfg = HinmConfig::with_24(32, 0.5);
        assert_eq!(cfg.vals_per_row(64), cfg.keep_cols(64) / 2);
    }

    #[test]
    fn validate_catches_bad_shapes() {
        let cfg = HinmConfig::with_24(32, 0.5);
        assert!(cfg.validate(64, 64).is_ok());
        assert!(cfg.validate(65, 64).is_err());
        assert!(cfg.validate(64, 2).is_err());
        let bad = HinmConfig { v: 8, n_keep: 5, m_group: 4, vector_sparsity: 0.0 };
        assert!(bad.validate(8, 8).is_err());
    }
}
