//! Sparsity patterns and pruning: masks, `V×1` column-vector pruning,
//! row-wise N:M, the combined hierarchical (HiNM) pipeline, the packed
//! storage format, and the unstructured baseline.

pub mod config;
pub mod format;
pub mod hinm;
pub mod mask;
pub mod nm_prune;
pub mod unstructured;
pub mod vector_prune;

pub use config::HinmConfig;
pub use format::HinmPacked;
pub use hinm::{prune_oneshot, HinmResult};
pub use mask::Mask;
