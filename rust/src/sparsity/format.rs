//! The packed HiNM storage format — canonical across all three layers.
//!
//! For `W[m, n]`, vector size `V`, kept columns `K_v` per tile, 2:4:
//!
//! ```text
//! vals:    f32 [T, V, K_v·N/M]   compacted kept weights
//! vec_idx: i32 [T, K_v]          original input-channel id per kept column
//! nm_idx:  u8  [T, V, K_v·N/M]   in-group offset (0..M) per kept value
//! ```
//!
//! `vec_idx` is the software-level index the GPU kernel consumes during the
//! global→shared gather; `nm_idx` is what NVIDIA's STC consumes in hardware
//! (2 bits per value — `pack_nm_bits` provides the bit-exact size used in
//! index-overhead accounting).

use super::config::HinmConfig;
use super::mask::Mask;
use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// A weight matrix compressed to the HiNM format.
#[derive(Clone, Debug, PartialEq)]
pub struct HinmPacked {
    /// The sparsity configuration this layer was packed with.
    pub cfg: HinmConfig,
    /// Original (uncompressed) shape.
    pub rows: usize,
    /// Original (uncompressed) column count.
    pub cols: usize,
    /// Kept columns per tile.
    pub k_v: usize,
    /// `[T * V * k_v/2]` compacted values, tile-major then row-major.
    pub vals: Vec<f32>,
    /// `[T * k_v]` original column ids (tile-major).
    pub vec_idx: Vec<i32>,
    /// `[T * V * k_v/2]` in-group offsets, parallel to `vals`.
    pub nm_idx: Vec<u8>,
}

impl HinmPacked {
    /// Number of V-row tiles (`rows / V`).
    pub fn tiles(&self) -> usize {
        self.rows / self.cfg.v
    }

    /// Stored values per row: `k_v · N / M`.
    pub fn vals_per_row(&self) -> usize {
        self.k_v * self.cfg.n_keep / self.cfg.m_group
    }

    /// Slice of `vec_idx` for tile `t`.
    pub fn tile_vec_idx(&self, t: usize) -> &[i32] {
        &self.vec_idx[t * self.k_v..(t + 1) * self.k_v]
    }

    /// Values of row `r` within tile `t` (r in 0..V).
    pub fn tile_row_vals(&self, t: usize, r: usize) -> &[f32] {
        let vpr = self.vals_per_row();
        let base = (t * self.cfg.v + r) * vpr;
        &self.vals[base..base + vpr]
    }

    /// In-group N:M offsets of row `r` within tile `t`, parallel to the values.
    pub fn tile_row_nm(&self, t: usize, r: usize) -> &[u8] {
        let vpr = self.vals_per_row();
        let base = (t * self.cfg.v + r) * vpr;
        &self.nm_idx[base..base + vpr]
    }

    /// Resolve every slot's in-group offset to its **flat compact column**
    /// `g·M + nm_idx[slot]` (in `0..k_v`), in storage order (parallel to
    /// `vals`). This is the per-call index arithmetic the SpMM kernels
    /// would otherwise redo; [`crate::spmm::SpmmPlan`] hoists it here, and
    /// within a row the resolved offsets are strictly ascending (group
    /// base ascending, offsets strictly ascending within a group).
    pub fn slot_compact_cols(&self) -> Vec<u32> {
        let n = self.cfg.n_keep;
        let m = self.cfg.m_group;
        self.nm_idx
            .iter()
            .enumerate()
            .map(|(i, &off)| {
                let slot = i % self.vals_per_row().max(1);
                ((slot / n) * m + off as usize) as u32
            })
            .collect()
    }

    /// Decompress to the dense masked matrix (for testing / verification).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let vpr = self.vals_per_row();
        let compact = self.slot_compact_cols();
        for t in 0..self.tiles() {
            let vidx = self.tile_vec_idx(t);
            for r in 0..self.cfg.v {
                let vals = self.tile_row_vals(t, r);
                let base = (t * self.cfg.v + r) * vpr;
                for (slot, &w) in vals.iter().enumerate() {
                    let orig_col = vidx[compact[base + slot] as usize] as usize;
                    *out.at_mut(t * self.cfg.v + r, orig_col) = w;
                }
            }
        }
        out
    }

    /// Storage footprint in bytes with 2-bit packed NM indices and i16/i32
    /// vector indices — mirrors the paper's index-overhead accounting.
    pub fn storage_bytes(&self) -> usize {
        let vals = self.vals.len() * 4;
        let vecidx = self.vec_idx.len() * if self.cols <= i16::MAX as usize { 2 } else { 4 };
        let nm = self.nm_idx.len().div_ceil(4); // 2 bits each
        vals + vecidx + nm
    }

    /// Compression ratio vs. dense f32.
    pub fn compression_ratio(&self) -> f64 {
        (self.rows * self.cols * 4) as f64 / self.storage_bytes() as f64
    }

    /// Structural invariant check (used by property tests and after permute).
    pub fn check_invariants(&self) -> Result<()> {
        let t = self.tiles();
        let vpr = self.vals_per_row();
        if self.vec_idx.len() != t * self.k_v {
            bail!("vec_idx len {} != {}", self.vec_idx.len(), t * self.k_v);
        }
        if self.vals.len() != t * self.cfg.v * vpr || self.nm_idx.len() != self.vals.len() {
            bail!("vals/nm_idx length mismatch");
        }
        if self.k_v % self.cfg.m_group != 0 {
            bail!("k_v {} not a multiple of M {}", self.k_v, self.cfg.m_group);
        }
        for tt in 0..t {
            let vidx = self.tile_vec_idx(tt);
            for &c in vidx {
                if c < 0 || c as usize >= self.cols {
                    bail!("tile {tt}: column id {c} out of range");
                }
            }
            // Duplicate detection via sort rather than a HashSet: compute
            // paths must stay free of hash-order nondeterminism (R3), and
            // the deterministic error (smallest duplicated id) is more
            // useful in a property-test failure anyway.
            let mut sorted: Vec<i32> = vidx.to_vec();
            sorted.sort_unstable();
            if let Some(w) = sorted.windows(2).find(|w| w[0] == w[1]) {
                bail!("tile {tt}: duplicate column id {}", w[0]);
            }
        }
        for (i, &o) in self.nm_idx.iter().enumerate() {
            if o as usize >= self.cfg.m_group {
                bail!("nm_idx[{i}] = {o} out of group range");
            }
        }
        // Within each group of N offsets, ascending strictly.
        let n = self.cfg.n_keep;
        for row in self.nm_idx.chunks_exact(vpr.max(1)) {
            for grp in row.chunks_exact(n) {
                for w in grp.windows(2) {
                    if w[0] >= w[1] {
                        bail!("nm offsets not strictly ascending within group");
                    }
                }
            }
        }
        Ok(())
    }
}

/// Pack a dense weight matrix given saliency, using saliency to choose both
/// the kept vectors and the N:M survivors. Optionally a per-tile column order
/// (from ICP) controls how kept columns are grouped into M-wide partitions.
///
/// `tile_col_order[t]`, when given, is a permutation of `0..k_v` applied to
/// the (ascending) kept-column list of tile `t` before N:M grouping.
pub fn pack(
    w: &Matrix,
    sal: &Matrix,
    cfg: &HinmConfig,
    kept: &[Vec<usize>],
    tile_col_order: Option<&[Vec<usize>]>,
) -> HinmPacked {
    let (rows, cols) = w.shape();
    cfg.validate(rows, cols).expect("invalid config");
    let tiles = cfg.tiles(rows);
    assert_eq!(kept.len(), tiles);
    let k_v = kept[0].len();
    let vpr = k_v * cfg.n_keep / cfg.m_group;
    let mut vals = vec![0.0f32; tiles * cfg.v * vpr];
    let mut nm_idx = vec![0u8; tiles * cfg.v * vpr];
    let mut vec_idx = vec![0i32; tiles * k_v];

    for t in 0..tiles {
        assert_eq!(kept[t].len(), k_v, "tile {t}: inconsistent K_v");
        // Apply per-tile column order (ICP) to the kept list.
        let order: Vec<usize> = match tile_col_order {
            Some(orders) => {
                assert_eq!(orders[t].len(), k_v);
                orders[t].iter().map(|&j| kept[t][j]).collect()
            }
            None => kept[t].clone(),
        };
        for (j, &c) in order.iter().enumerate() {
            vec_idx[t * k_v + j] = c as i32;
        }
        for r in 0..cfg.v {
            let row_global = t * cfg.v + r;
            let wrow = w.row(row_global);
            let srow = sal.row(row_global);
            let base = row_global * vpr;
            for g in 0..k_v / cfg.m_group {
                let grp_cols = &order[g * cfg.m_group..(g + 1) * cfg.m_group];
                let grp_sal: Vec<f32> = grp_cols.iter().map(|&c| srow[c]).collect();
                let sel = super::nm_prune::select_nm(&grp_sal, cfg.n_keep);
                for (j, &off) in sel.iter().enumerate() {
                    let slot = base + g * cfg.n_keep + j;
                    vals[slot] = wrow[grp_cols[off as usize]];
                    nm_idx[slot] = off;
                }
            }
        }
    }

    HinmPacked { cfg: *cfg, rows, cols, k_v, vals, vec_idx, nm_idx }
}

/// Dense mask equivalent of a packed matrix (kept-weight positions).
pub fn packed_mask(p: &HinmPacked) -> Mask {
    let dense = p.to_dense();
    let mut mask = Mask::zeros(p.rows, p.cols);
    // NOTE: a genuinely-zero kept weight is indistinguishable in to_dense();
    // reconstruct from indices instead for exactness.
    let vpr = p.vals_per_row();
    let n = p.cfg.n_keep;
    let m = p.cfg.m_group;
    for t in 0..p.tiles() {
        let vidx = p.tile_vec_idx(t);
        for r in 0..p.cfg.v {
            let offs = p.tile_row_nm(t, r);
            for slot in 0..vpr {
                let g = slot / n;
                let cc = g * m + offs[slot] as usize;
                mask.set(t * p.cfg.v + r, vidx[cc] as usize, true);
            }
        }
    }
    debug_assert_eq!(mask.count_kept(), dense.nnz().max(mask.count_kept()));
    mask
}

/// Pack the 2-bit NM offsets four-per-byte (size accounting / artifact dump).
pub fn pack_nm_bits(nm_idx: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; nm_idx.len().div_ceil(4)];
    for (i, &o) in nm_idx.iter().enumerate() {
        debug_assert!(o < 4);
        out[i / 4] |= (o & 0b11) << ((i % 4) * 2);
    }
    out
}

/// Inverse of [`pack_nm_bits`].
pub fn unpack_nm_bits(packed: &[u8], len: usize) -> Vec<u8> {
    (0..len).map(|i| (packed[i / 4] >> ((i % 4) * 2)) & 0b11).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::vector_prune::vector_prune;
    use crate::util::rng::Xoshiro256;

    fn make(rows: usize, cols: usize, sv: f64, seed: u64) -> (Matrix, Matrix, HinmConfig) {
        let mut rng = Xoshiro256::new(seed);
        let w = Matrix::randn(rows, cols, 1.0, &mut rng);
        let sal = w.abs();
        (w, sal, HinmConfig::with_24(4, sv))
    }

    #[test]
    fn pack_roundtrip_preserves_kept_values() {
        let (w, sal, cfg) = make(8, 16, 0.5, 1);
        let vp = vector_prune(&sal, &cfg);
        let p = pack(&w, &sal, &cfg, &vp.kept, None);
        p.check_invariants().unwrap();
        let dense = p.to_dense();
        // Every nonzero of dense equals the original weight there.
        let mut nonzero = 0;
        for r in 0..8 {
            for c in 0..16 {
                let d = dense.at(r, c);
                if d != 0.0 {
                    assert_eq!(d, w.at(r, c));
                    nonzero += 1;
                }
            }
        }
        // 16 cols → keep 8 vectors → 4 kept values per row after 2:4.
        assert_eq!(nonzero, 8 * 4);
    }

    #[test]
    fn density_matches_config() {
        let (w, sal, cfg) = make(32, 64, 0.5, 2);
        let vp = vector_prune(&sal, &cfg);
        let p = pack(&w, &sal, &cfg, &vp.kept, None);
        let mask = packed_mask(&p);
        let got = 1.0 - mask.sparsity();
        let want = (1.0 - cfg.total_sparsity());
        assert!((got - want).abs() < 0.02, "density {got} vs {want}");
    }

    #[test]
    fn packed_selects_top2_per_group() {
        // Single tile, V=4, 4 cols kept of 4 (sv=0) → one group per row.
        let w = Matrix::from_vec(4, 4, (1..=16).map(|i| i as f32).collect());
        let sal = w.abs();
        let cfg = HinmConfig::with_24(4, 0.0);
        let kept = vec![(0..4).collect::<Vec<_>>()];
        let p = pack(&w, &sal, &cfg, &kept, None);
        // Row 0 = [1,2,3,4] → keep 3,4 at offsets 2,3.
        assert_eq!(p.tile_row_vals(0, 0), &[3.0, 4.0]);
        assert_eq!(p.tile_row_nm(0, 0), &[2, 3]);
    }

    #[test]
    fn tile_col_order_changes_grouping() {
        // 1×8 tile (V=1 invalid for cfg.v=4? use V=1 config) — V=1, 8 cols.
        let cfg = HinmConfig { v: 1, n_keep: 2, m_group: 4, vector_sparsity: 0.0 };
        let w = Matrix::from_vec(1, 8, vec![9., 8., 7., 6., 1., 2., 3., 4.]);
        let sal = w.abs();
        let kept = vec![(0..8).collect::<Vec<_>>()];
        // Default order: groups {9,8,7,6} {1,2,3,4} → retain 9+8+3+4 = 24.
        let p0 = pack(&w, &sal, &cfg, &kept, None);
        let r0: f32 = p0.vals.iter().sum();
        assert_eq!(r0, 24.0);
        // Interleave: {9,1,8,2} {7,3,6,4} → retain 9+8+7+6 = 30.
        let order = vec![vec![0usize, 4, 1, 5, 2, 6, 3, 7]];
        let p1 = pack(&w, &sal, &cfg, &kept, Some(&order));
        let r1: f32 = p1.vals.iter().sum();
        assert_eq!(r1, 30.0);
        p1.check_invariants().unwrap();
    }

    #[test]
    fn slot_compact_cols_are_row_ascending_and_in_range() {
        let (w, sal, cfg) = make(8, 32, 0.5, 9);
        let vp = vector_prune(&sal, &cfg);
        let p = pack(&w, &sal, &cfg, &vp.kept, None);
        let flat = p.slot_compact_cols();
        assert_eq!(flat.len(), p.vals.len());
        let vpr = p.vals_per_row();
        for row in flat.chunks_exact(vpr) {
            for pair in row.windows(2) {
                assert!(pair[0] < pair[1], "compact cols not strictly ascending: {row:?}");
            }
            assert!((row[vpr - 1] as usize) < p.k_v);
        }
    }

    #[test]
    fn nm_bit_packing_roundtrip() {
        let offs = vec![0u8, 1, 2, 3, 3, 2, 1, 0, 1, 3];
        let packed = pack_nm_bits(&offs);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack_nm_bits(&packed, offs.len()), offs);
    }

    #[test]
    fn storage_accounting() {
        let (w, sal, cfg) = make(64, 128, 0.5, 3);
        let vp = vector_prune(&sal, &cfg);
        let p = pack(&w, &sal, &cfg, &vp.kept, None);
        // 75% total sparsity → vals ~= 25% of dense; ratio > 3 even with indices.
        assert!(p.compression_ratio() > 3.0, "ratio {}", p.compression_ratio());
    }

    #[test]
    fn invariants_catch_corruption() {
        let (w, sal, cfg) = make(8, 16, 0.5, 4);
        let vp = vector_prune(&sal, &cfg);
        let mut p = pack(&w, &sal, &cfg, &vp.kept, None);
        p.check_invariants().unwrap();
        p.vec_idx[0] = 999;
        assert!(p.check_invariants().is_err());
    }
}
