//! Element-wise (unstructured) pruning baselines: global magnitude /
//! saliency top-k, plus the CAP-style second-order arm used in Table 1.

use super::mask::Mask;
use crate::tensor::Matrix;

/// Keep the `keep` most salient elements anywhere in the matrix.
pub fn unstructured_mask(sal: &Matrix, keep: usize) -> Mask {
    let total = sal.rows * sal.cols;
    assert!(keep <= total);
    let mut idx: Vec<u32> = (0..total as u32).collect();
    // Partial selection: sort by saliency descending, take `keep`.
    idx.select_nth_unstable_by(keep.saturating_sub(1).min(total - 1), |&a, &b| {
        sal.data[b as usize]
            .partial_cmp(&sal.data[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut mask = Mask::zeros(sal.rows, sal.cols);
    for &i in &idx[..keep] {
        let i = i as usize;
        mask.set(i / sal.cols, i % sal.cols, true);
    }
    mask
}

/// Unstructured pruning at a target sparsity in [0, 1].
pub fn prune_to_sparsity(sal: &Matrix, sparsity: f64) -> Mask {
    let total = sal.rows * sal.cols;
    let keep = ((1.0 - sparsity) * total as f64).round() as usize;
    unstructured_mask(sal, keep.min(total))
}

/// Retained saliency of unstructured pruning — the upper bound every
/// structured method in the paper is compared against.
pub fn unstructured_retained(sal: &Matrix, sparsity: f64) -> f64 {
    prune_to_sparsity(sal, sparsity).retained(sal)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn keeps_exactly_k_and_the_largest() {
        let sal = Matrix::from_vec(2, 3, vec![0.1, 5.0, 0.2, 4.0, 0.3, 0.05]);
        let m = unstructured_mask(&sal, 2);
        assert_eq!(m.count_kept(), 2);
        assert!(m.get(0, 1) && m.get(1, 0));
    }

    #[test]
    fn sparsity_target() {
        let mut rng = Xoshiro256::new(5);
        let sal = Matrix::randn(32, 32, 1.0, &mut rng).abs();
        let m = prune_to_sparsity(&sal, 0.75);
        assert_eq!(m.count_kept(), 256);
    }

    #[test]
    fn upper_bounds_any_structured_mask() {
        let mut rng = Xoshiro256::new(6);
        let sal = Matrix::randn(16, 32, 1.0, &mut rng).abs();
        let keep = 16 * 32 / 4;
        let un = unstructured_mask(&sal, keep);
        // Any other mask with the same budget retains less or equal.
        let mut other = Mask::zeros(16, 32);
        for i in 0..keep {
            other.set(i / 32, i % 32, true);
        }
        assert!(un.retained(&sal) >= other.retained(&sal));
    }

    #[test]
    fn degenerate_budgets() {
        let sal = Matrix::from_vec(1, 4, vec![1., 2., 3., 4.]);
        assert_eq!(unstructured_mask(&sal, 0).count_kept(), 0);
        assert_eq!(unstructured_mask(&sal, 4).count_kept(), 4);
    }
}
