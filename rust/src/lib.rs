//! # hinm — Hierarchical N:M sparsity with gyro-permutation
//!
//! Production-grade reproduction of *"Toward Efficient Permutation for
//! Hierarchical N:M Sparsity on GPUs"* (Yu et al., 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the offline compression pipeline (saliency →
//!   permutation → HiNM pruning → packed format) built on the
//!   [`permute::strategy`] layer (any OCP×ICP strategy pair from a
//!   string-keyed registry, executed by a parallel tile engine), the PJRT
//!   runtime that executes AOT-lowered JAX/Pallas artifacts, a sharded
//!   batch-inference server with priority/deadline scheduling, optional
//!   pipeline-parallel layer sharding
//!   ([`coordinator::serve::PipelineServer`], DESIGN.md §15), and an
//!   HTTP/JSON front ([`net`]), plus the full evaluation/bench harness
//!   reproducing every table and figure in the paper.
//! * **L2 (`python/compile/model.py`)** — JAX forward/backward graphs calling
//!   the L1 kernel, lowered once to HLO text artifacts.
//! * **L1 (`python/compile/kernels/hinm_spmm.py`)** — the HiNM SpMM Pallas
//!   kernel (interpret mode on CPU).
//!
//! Start with `ARCHITECTURE.md` for the top-to-bottom system narrative
//! (one data-flow diagram per layer); `DESIGN.md` is the per-subsystem
//! reference its anchors point into, and `EXPERIMENTS.md` records
//! paper-vs-measured results.

#![warn(missing_docs)]

pub mod coordinator;
pub mod eval;
pub mod models;
pub mod net;
pub mod permute;
pub mod runtime;
pub mod saliency;
pub mod sparsity;
pub mod spmm;
pub mod tensor;
pub mod util;
