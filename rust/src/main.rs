//! `hinm` — CLI for the HiNM sparsity + gyro-permutation library.
//!
//! Subcommands:
//!   eval <fig3|fig4|tab1|tab2|tab3|fig5|all>   regenerate a paper result
//!   prune                                       compress a .npy weight matrix
//!   spmm                                        run the CPU HiNM SpMM on a pruned layer
//!   info                                        list AOT artifacts
//!   build                                       serialize catalog models to versioned artifacts
//!   serve                                       multi-replica batched inference engine
//!   stage                                       one cross-host pipeline stage over TCP
//!   route                                       fault-tolerant router over serve hosts
//!   serve-demo                                  alias: serve --backend pjrt
//!   train-demo                                  short LM train loop via the AOT step

use anyhow::{bail, Context, Result};
use hinm::coordinator::{Corpus, LmTrainer};
use hinm::eval::{common::EvalScale, fig34, fig5, tab1, tab2, tab3};
use hinm::permute::{StrategyRegistry, StrategySpec};
use hinm::sparsity::HinmConfig;
use hinm::tensor::npy;
use hinm::util::cli::Cli;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args.remove(0);
    let result = match cmd.as_str() {
        "eval" => cmd_eval(args),
        "prune" => cmd_prune(args),
        "spmm" => cmd_spmm(args),
        "info" => cmd_info(args),
        "build" => cmd_build(args),
        "serve" => cmd_serve(args),
        "stage" => cmd_stage(args),
        "route" => cmd_route(args),
        "serve-demo" => {
            // Historical alias for the PJRT path; explicit flags still win.
            let mut full = vec!["--backend".to_string(), "pjrt".to_string()];
            full.extend(args);
            cmd_serve(full)
        }
        "train-demo" => cmd_train_demo(args),
        "--help" | "-h" | "help" => {
            usage();
            Ok(())
        }
        other => {
            usage();
            Err(anyhow::anyhow!("unknown subcommand {other:?}"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "hinm — hierarchical N:M sparsity with gyro-permutation\n\n\
         USAGE: hinm <subcommand> [options]\n\n\
         SUBCOMMANDS:\n\
         \x20 eval <fig3|fig4|tab1|tab2|tab3|fig5|all>  regenerate paper results\n\
         \x20 prune   --weights w.npy --out dir [--sparsity 75] [--v 32] [--method gyro]\n\
         \x20         --method also accepts any <ocp>+<icp> registry pair, e.g. gyro+apex,\n\
         \x20         ovw+gyro, id+tetris (ocp: gyro|ovw|id; icp: gyro|apex|tetris|id)\n\
         \x20 spmm    --weights w.npy [--batch 8] [--sparsity 75]\n\
         \x20 info    list AOT artifacts and data dumps\n\
         \x20 build   [--out DIR] [--models a,b|all] [--seed S] [--version V]\n\
         \x20         [--values f32|bf16] [--note TEXT]\n\
         \x20         serialize catalog models to versioned artifacts (manifest\n\
         \x20         JSON + packed binary payload; see DESIGN.md §18)\n\
         \x20 serve   [--backend native|pjrt] [--replicas R] [--batch B] [--max-wait-us U]\n\
         \x20         [--kernel-threads K] [--pipeline-stages S] [--blocks N]\n\
         \x20         [--values f32|bf16] [--http ADDR] [--http-workers W] [--cache-capacity N]\n\
         \x20         [--model-dir DIR] [--default-model NAME]\n\
         \x20         [--stage-hosts HOST:PORT,HOST:PORT[,…]] [--model NAME]\n\
         \x20         [--link-connect-timeout-ms MS] [--link-io-timeout-ms MS]\n\
         \x20         [--link-attempts N] [--link-backoff-ms MS] [--link-backoff-max-ms MS]\n\
         \x20         sharded batched inference engine; with --http it serves\n\
         \x20         POST /v1/infer, GET /v1/metrics[?format=prometheus], GET /healthz\n\
         \x20         until killed, otherwise it runs a closed-loop load demo;\n\
         \x20         --pipeline-stages S shards the layer chain across S stage\n\
         \x20         workers (native only, bit-identical responses);\n\
         \x20         --stage-hosts runs the same split across `hinm stage`\n\
         \x20         processes over TCP, one host per stage in chain order\n\
         \x20         (native only, still bit-identical; DESIGN.md §20);\n\
         \x20         --model-dir DIR serves every artifact in DIR behind one\n\
         \x20         front (requests route on the body's \"model\" field; POST\n\
         \x20         /v1/admin/reload hot-swaps new artifact versions)\n\
         \x20 stage   --stage K/S [--listen ADDR] [--kernel-threads K] [--model NAME]\n\
         \x20         [--d N] [--d-ff N] [--blocks N] [--sparsity P] [--v V]\n\
         \x20         [--seed S] [--values f32|bf16]\n\
         \x20         serve stage K of an S-way chain split over TCP activation\n\
         \x20         frames for a `hinm serve --stage-hosts` head; both sides\n\
         \x20         must build the same model (same flags/seed), so no\n\
         \x20         weights cross the wire (DESIGN.md §20)\n\
         \x20 route   --backends HOST:PORT,HOST:PORT[,…] [--http ADDR] [--http-workers W]\n\
         \x20         [--probe-interval-ms MS] [--probe-timeout-ms MS] [--fail-threshold N]\n\
         \x20         [--per-try-timeout-ms MS] [--connect-timeout-ms MS] [--max-attempts N]\n\
         \x20         [--hedge-floor-ms MS] [--hedge-ceil-ms MS] [--retry-backoff-ms MS]\n\
         \x20         [--backoff-base-ms MS] [--backoff-max-ms MS] [--max-inflight N] [--seed S]\n\
         \x20         fault-tolerant router over `hinm serve --http` hosts: health\n\
         \x20         probing + circuit breaking, deadline-aware retries, hedged\n\
         \x20         requests, least-loaded dispatch, 503 backpressure (DESIGN.md §19)\n\
         \x20 serve-demo  alias for: serve --backend pjrt\n\
         \x20 train-demo  [--steps 50]      LM training via AOT train step\n"
    );
}

fn cmd_eval(args: Vec<String>) -> Result<()> {
    let cli = Cli::new("hinm eval", "regenerate a paper table/figure")
        .opt("scale", Some("quarter"), "full | quarter | tiny")
        .opt("seed", Some("7"), "rng seed")
        .opt("csv", None, "write the report to this path");
    let mut pos = Vec::new();
    let mut flags = Vec::new();
    let mut it = args.into_iter().peekable();
    while let Some(a) = it.next() {
        if a.starts_with("--") {
            let takes_value = !a.contains('=');
            flags.push(a);
            if takes_value {
                if let Some(v) = it.peek() {
                    if !v.starts_with("--") {
                        flags.push(it.next().unwrap());
                    }
                }
            }
        } else {
            pos.push(a);
        }
    }
    let parsed = cli.parse_tail(flags);
    let scale = EvalScale::parse(&parsed.get_or("scale", "quarter")).context("bad --scale")?;
    let seed = parsed.u64_or("seed", 7);
    let which = pos.first().map(String::as_str).unwrap_or("all");

    let mut outputs: Vec<String> = Vec::new();
    if matches!(which, "fig3" | "all") {
        outputs.push(fig34::render(&fig34::fig3(scale, seed), "Fig. 3 — ResNet18 one-shot"));
    }
    if matches!(which, "fig4" | "all") {
        outputs.push(fig34::render(&fig34::fig4(scale, seed), "Fig. 4 — ResNet50 one-shot"));
    }
    if matches!(which, "tab1" | "all") {
        outputs.push(tab1::render(&tab1::tab1(scale, seed)));
    }
    if matches!(which, "tab2" | "all") {
        outputs.push(tab2::render(&tab2::tab2(scale, seed)));
    }
    if matches!(which, "tab3" | "all") {
        outputs.push(tab3::render(&tab3::tab3(scale, seed)));
    }
    if matches!(which, "fig5" | "all") {
        outputs.push(fig5::render(&fig5::run(scale == EvalScale::Full, seed)));
    }
    if outputs.is_empty() {
        bail!("unknown experiment {which:?} (expected fig3|fig4|tab1|tab2|tab3|fig5|all)");
    }
    let text = outputs.join("\n");
    println!("{text}");
    if let Some(path) = parsed.get("csv") {
        std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_prune(args: Vec<String>) -> Result<()> {
    let cli = Cli::new("hinm prune", "compress a dense .npy weight matrix to HiNM")
        .opt("weights", None, ".npy file with a 2-D f32 matrix (required)")
        .opt("out", Some("pruned_out"), "output directory")
        .opt("sparsity", Some("75"), "total sparsity %")
        .opt("v", Some("32"), "vector size V")
        .opt("method", Some("gyro"), "gyro | noperm | v1 | v2 | v3 | <ocp>+<icp> (registry keys)")
        .opt("workers", Some("0"), "tile-engine threads (0 = all cores)");
    let a = cli.parse_tail(args);
    let wpath = a.get("weights").context("--weights is required")?;
    let w = npy::load_matrix(wpath)?;
    let total = a.usize_or("sparsity", 75) as f64 / 100.0;
    let v = a.usize_or("v", 32);
    let method_str = a.get_or("method", "gyro");
    let spec = StrategySpec::parse(&method_str).with_context(|| {
        format!(
            "bad --method {:?}; expected {}",
            method_str,
            StrategyRegistry::builtin().method_help()
        )
    })?;
    let cfg = HinmConfig::for_total_sparsity(v, total);
    cfg.validate(w.rows, w.cols).map_err(|e| anyhow::anyhow!(e))?;

    let job = hinm::coordinator::LayerJob::from_saliency("cli", w, &hinm::saliency::Magnitude);
    let mut pc = hinm::coordinator::PipelineConfig::new(cfg, spec.clone());
    // Single layer: hand every core to the tile engine instead of the
    // (useless here) layer-level pool.
    pc.tile_workers = a.usize_or("workers", 0);
    let out = hinm::coordinator::compress_layer(&job, &pc);
    let p = &out.result.packed;
    p.check_invariants()?;

    let dir = std::path::PathBuf::from(a.get_or("out", "pruned_out"));
    std::fs::create_dir_all(&dir)?;
    let t = p.tiles();
    let vpr = p.vals_per_row();
    npy::save(dir.join("vals.npy"), &npy::NpyArray::f32(vec![t, cfg.v, vpr], p.vals.clone()))?;
    npy::save(dir.join("vec_idx.npy"), &npy::NpyArray::i32(vec![t, p.k_v], p.vec_idx.clone()))?;
    npy::save(
        dir.join("nm_idx.npy"),
        &npy::NpyArray::i32(vec![t, cfg.v, vpr], p.nm_idx.iter().map(|&o| o as i32).collect()),
    )?;
    let perm: Vec<i32> = out.ocp_perm.iter().map(|&x| x as i32).collect();
    npy::save(dir.join("ocp_perm.npy"), &npy::NpyArray::i32(vec![perm.len()], perm))?;

    println!(
        "{}: {}×{} → HiNM V={} total sparsity {:.1}% | retention {:.4} | {} | {:.0} ms",
        spec.label(),
        p.rows,
        p.cols,
        cfg.v,
        cfg.total_sparsity() * 100.0,
        out.result.retention_ratio,
        hinm::util::human_bytes(p.storage_bytes()),
        out.elapsed_ms
    );
    println!("wrote vals/vec_idx/nm_idx/ocp_perm to {}", dir.display());
    Ok(())
}

fn cmd_spmm(args: Vec<String>) -> Result<()> {
    let cli = Cli::new("hinm spmm", "run the CPU HiNM SpMM on a dense .npy matrix")
        .opt("weights", None, ".npy dense weights (required)")
        .opt("batch", Some("8"), "batch size")
        .opt("sparsity", Some("75"), "total sparsity %")
        .opt("v", Some("32"), "vector size");
    let a = cli.parse_tail(args);
    let w = npy::load_matrix(a.get("weights").context("--weights required")?)?;
    let cfg = HinmConfig::for_total_sparsity(
        a.usize_or("v", 32),
        a.usize_or("sparsity", 75) as f64 / 100.0,
    );
    let res = hinm::sparsity::prune_oneshot(&w, &w.abs(), &cfg);
    let batch = a.usize_or("batch", 8);
    let mut rng = hinm::util::rng::Xoshiro256::new(1);
    let x = hinm::tensor::Matrix::randn(w.cols, batch, 1.0, &mut rng);
    let t0 = std::time::Instant::now();
    let y = hinm::spmm::spmm(&res.packed, &x);
    let dt = t0.elapsed();
    let y_ref = hinm::spmm::dense::matmul(&res.packed.to_dense(), &x);
    println!(
        "spmm {}×{} @ batch {batch}: {:.2} ms, max |Δ| vs dense ref = {:.2e}",
        w.rows,
        w.cols,
        dt.as_secs_f64() * 1e3,
        y.max_abs_diff(&y_ref)
    );
    Ok(())
}

fn cmd_info(_args: Vec<String>) -> Result<()> {
    let reg = hinm::runtime::open_default_registry()?;
    println!("artifact root: {}", reg.root.display());
    println!("\nartifacts:");
    for (name, a) in &reg.artifacts {
        let in_elems: usize = a.inputs.iter().map(|i| i.elements()).sum();
        println!(
            "  {name:<16} {} inputs ({} elements) → {} outputs   [{}]",
            a.inputs.len(),
            in_elems,
            a.n_outputs,
            a.file.file_name().unwrap_or_default().to_string_lossy()
        );
    }
    println!("\ndata dumps: {}", reg.data.len());
    for name in reg.data.keys() {
        println!("  {name}");
    }
    Ok(())
}

fn cmd_build(args: Vec<String>) -> Result<()> {
    let cli = Cli::new("hinm build", "serialize catalog models to versioned artifacts")
        .opt("out", Some("models"), "artifact directory (created if missing)")
        .opt("models", Some("all"), "comma-separated catalog names, or all")
        .opt("seed", Some("7"), "synthetic-weight seed recorded in provenance")
        .opt("version", Some("1"), "artifact version to write")
        .opt("values", Some("f32"), "packed kernel value format (f32|bf16)")
        .opt("note", None, "free-form provenance note stored in the manifest");
    let a = cli.parse_tail(args);
    let out = std::path::PathBuf::from(a.get_or("out", "models"));
    let seed = a.u64_or("seed", 7);
    let version = a.u64_or("version", 1);
    let values = {
        let s = a.get_or("values", "f32");
        hinm::spmm::ValueFormat::parse(&s)
            .with_context(|| format!("bad --values {s:?} (expected f32|bf16)"))?
    };

    let catalog = hinm::models::serving_models(seed)?;
    let want = a.get_or("models", "all");
    let selected: Vec<&str> = if want == "all" {
        catalog.iter().map(|(n, _)| *n).collect()
    } else {
        let names: Vec<&str> = want.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
        for n in &names {
            if !catalog.iter().any(|(c, _)| c == n) {
                bail!(
                    "unknown model {n:?} (catalog: {})",
                    catalog.iter().map(|(c, _)| *c).collect::<Vec<_>>().join(", ")
                );
            }
        }
        names
    };
    if selected.is_empty() {
        bail!("--models selected nothing");
    }

    let provenance = hinm::runtime::Provenance {
        tool: "hinm build".to_string(),
        seed: Some(seed),
        note: a.get("note").map(str::to_string),
    };
    for (name, model) in catalog {
        if !selected.contains(&name) {
            continue;
        }
        let model = model.with_value_format(values);
        let path = hinm::runtime::save_artifact(&out, name, version, &model, &provenance)?;
        println!(
            "wrote {name:<12} v{version} {}→{} ({} layers, {}) → {}",
            model.d_in(),
            model.d_out(),
            model.n_layers(),
            values.as_str(),
            path.display()
        );
    }
    Ok(())
}

fn cmd_serve(args: Vec<String>) -> Result<()> {
    let cli = Cli::new("hinm serve", "multi-replica batched HiNM inference engine")
        .opt("backend", Some("native"), "native | pjrt")
        .opt("replicas", Some("2"), "worker replicas (each owns a backend instance)")
        .opt("batch", Some("8"), "batch size per flush (pjrt: fixed by the artifact)")
        .opt("max-wait-us", Some("200"), "batch window after the first request, µs")
        .opt("queue-depth", Some("0"), "request-queue bound (0 = replicas*batch*4)")
        .opt(
            "kernel-threads",
            Some("1"),
            "native: kernel worker lanes per replica (0 = all cores); bit-identical output",
        )
        .opt(
            "pipeline-stages",
            Some("1"),
            "native: shard the layer chain across this many pipeline stage workers (1 = off); bit-identical output",
        )
        .opt("blocks", Some("1"), "native: FFN blocks in the synthetic model (2·blocks layers)")
        .opt(
            "values",
            Some("f32"),
            "native: packed kernel value format (f32 = bit-exact; bf16 = half the kernel memory traffic, f32 accumulate)",
        )
        .opt("http", None, "serve HTTP/JSON on this address (e.g. 127.0.0.1:8080) until killed")
        .opt("http-workers", Some("8"), "HTTP connection-handler threads")
        .opt("cache-capacity", Some("0"), "per-replica LRU batch-cache entries (0 = off)")
        .opt(
            "model-dir",
            None,
            "serve every artifact in this directory (built by `hinm build`); requests route on the body's \"model\" field",
        )
        .opt(
            "default-model",
            None,
            "model served when a request has no \"model\" field (default: first name in the directory)",
        )
        .opt(
            "stage-hosts",
            None,
            "native: comma-separated `hinm stage` HOST:PORT list, one per pipeline stage in chain order (DESIGN.md §20)",
        )
        .opt(
            "model",
            None,
            "native: serving-catalog model name (same catalog as `hinm build`; overrides the synthetic --d/--d-ff/--blocks flags)",
        )
        .opt("link-connect-timeout-ms", Some("500"), "stage link connect timeout per attempt, ms")
        .opt("link-io-timeout-ms", Some("5000"), "stage link read/write deadline per batch, ms")
        .opt("link-attempts", Some("3"), "stage link connect attempts per (re)establishment")
        .opt("link-backoff-ms", Some("50"), "stage link reconnect backoff base, ms (seeded jitter)")
        .opt("link-backoff-max-ms", Some("2000"), "stage link reconnect backoff cap, ms")
        .opt("requests", Some("256"), "closed-loop demo requests (no --http)")
        .opt("clients", Some("8"), "concurrent demo clients (no --http)")
        .opt("d", Some("256"), "native: model width")
        .opt("d-ff", Some("512"), "native: hidden width")
        .opt("sparsity", Some("75"), "native: total sparsity %")
        .opt("v", Some("32"), "native: vector size V")
        .opt("seed", Some("7"), "native: synthetic-weight seed");
    let a = cli.parse_tail(args);
    let backend = a.get_or("backend", "native");
    let replicas = a.usize_or("replicas", 2).max(1);
    let max_wait = std::time::Duration::from_micros(a.u64_or("max-wait-us", 200));
    let queue_depth = a.usize_or("queue-depth", 0);
    let n_requests = a.usize_or("requests", 256);
    let n_clients = a.usize_or("clients", 8).max(1);
    let cache_capacity = a.usize_or("cache-capacity", 0);
    let cache_stats =
        if cache_capacity > 0 { Some(hinm::runtime::CacheStats::new_shared()) } else { None };
    let values = {
        let s = a.get_or("values", "f32");
        hinm::spmm::ValueFormat::parse(&s)
            .with_context(|| format!("bad --values {s:?} (expected f32|bf16)"))?
    };

    let pipeline_stages = a.usize_or("pipeline-stages", 1).max(1);

    if let Some(dir) = a.get("model-dir") {
        if backend != "native" {
            bail!("--model-dir serves registry artifacts on the native backend only (drop --backend {backend})");
        }
        if a.get("stage-hosts").is_some() {
            bail!(
                "--model-dir and --stage-hosts do not compose yet: stage hosts pin one \
                 sharded model for the server's lifetime, while registry artifacts \
                 hot-swap whole models per replica; drop one of the two flags"
            );
        }
        if pipeline_stages > 1 {
            bail!(
                "--model-dir and --pipeline-stages do not compose yet: registry artifacts \
                 hot-swap whole models per replica, while pipeline stages pin one sharded \
                 model for the server's lifetime; drop one of the two flags"
            );
        }
        let dir = dir.to_string();
        return serve_model_dir(&a, &dir);
    }

    // Keeps the stage workers alive for as long as the engine runs; the
    // engine is stopped first, the pipeline after (see the end of this
    // function).
    let mut pipeline: Option<hinm::coordinator::PipelineServer> = None;

    // Per-link counters when driving remote stage hosts; handed to the
    // HTTP front so /v1/metrics exposes them (DESIGN.md §20).
    let mut stage_links: Option<std::sync::Arc<hinm::coordinator::StageLinkMetrics>> = None;

    // Each branch yields the engine config plus a factory building one
    // backend per replica; the cache decorator then wraps whichever
    // backend was picked.
    let (scfg, base_factory): (hinm::coordinator::ServeConfig, hinm::coordinator::BackendFactory) =
        match backend.as_str() {
            "native" => {
                let kernel_threads = a.usize_or("kernel-threads", 1);
                let model = native_model(&a)?;
                let model = std::sync::Arc::new(model.with_value_format(values));
                println!(
                    "native backend: {}→{} ({} layers) | {replicas} replicas × {kernel_threads} kernel threads",
                    model.d_in(),
                    model.d_out(),
                    model.n_layers()
                );
                // Which microkernel this process actually dispatches to —
                // ISA tier, value format, and the cache sizes that set the
                // panel budget (DESIGN.md §16).
                println!("kernel: {}", hinm::spmm::KernelInfo::current(values));
                let scfg = hinm::coordinator::ServeConfig::new(a.usize_or("batch", 8), max_wait)
                    .with_replicas(replicas)
                    .with_queue_depth(queue_depth);
                let factory: hinm::coordinator::BackendFactory = if let Some(spec) =
                    a.get("stage-hosts")
                {
                    if pipeline_stages > 1 {
                        bail!(
                            "--stage-hosts and --pipeline-stages do not compose: the remote \
                             hosts ARE the pipeline stages (one host per stage, in chain order)"
                        );
                    }
                    let hosts: Vec<String> = spec
                        .split(',')
                        .map(str::trim)
                        .filter(|s| !s.is_empty())
                        .map(str::to_string)
                        .collect();
                    if hosts.is_empty() {
                        bail!("--stage-hosts selected nothing");
                    }
                    // Validate the chain actually splits this many ways and
                    // show the operator the per-stage shapes each host must
                    // be serving (`hinm stage --stage K/S`, same flags).
                    let split = model.split_stages(hosts.len())?;
                    println!("remote pipeline: {} stage host(s)", hosts.len());
                    for (i, (h, m)) in hosts.iter().zip(&split).enumerate() {
                        println!(
                            "  stage {}/{} {h}: {}→{} ({} layers)",
                            i + 1,
                            hosts.len(),
                            m.d_in(),
                            m.d_out(),
                            m.n_layers()
                        );
                    }
                    let lcfg = hinm::runtime::StageLinkConfig {
                        connect_timeout_ms: a.u64_or("link-connect-timeout-ms", 500),
                        io_timeout_ms: a.u64_or("link-io-timeout-ms", 5_000),
                        connect_attempts: a.u64_or("link-attempts", 3) as u32,
                        backoff_base_ms: a.u64_or("link-backoff-ms", 50),
                        backoff_max_ms: a.u64_or("link-backoff-max-ms", 2_000),
                        seed: a.u64_or("seed", 7),
                    };
                    let links = hinm::coordinator::StageLinkMetrics::new(&hosts);
                    stage_links = Some(std::sync::Arc::clone(&links));
                    let (d_in, d_out) = (model.d_in(), model.d_out());
                    std::sync::Arc::new(move |_replica| {
                        let b: Box<dyn hinm::runtime::SpmmBackend> =
                            Box::new(hinm::runtime::RemotePipelinedBackend::connect(
                                &hosts,
                                d_in,
                                d_out,
                                lcfg.clone(),
                                std::sync::Arc::clone(&links),
                            )?);
                        Ok(b)
                    })
                } else if pipeline_stages > 1 {
                    // Pipeline-parallel mode: the chain is sharded across
                    // stage workers; each replica's backend submits whole
                    // batches into stage 0, so replicas keep several
                    // batches in flight at different stages. Responses
                    // stay bit-identical to the unsplit model.
                    let ps = hinm::coordinator::PipelineServer::start(
                        &model,
                        pipeline_stages,
                        kernel_threads,
                        0,
                    )?;
                    println!(
                        "pipeline: {} stages × {kernel_threads} kernel threads (stages balanced by planned FLOPs)",
                        ps.n_stages()
                    );
                    let f = ps.backend_factory();
                    pipeline = Some(ps);
                    f
                } else {
                    // The planned tile-parallel backend: each replica gets
                    // its own kernel pool; tiles write disjoint Y rows, so
                    // output is bit-identical for any --kernel-threads
                    // setting.
                    std::sync::Arc::new(move |_replica| {
                        let b: Box<dyn hinm::runtime::SpmmBackend> =
                            Box::new(hinm::runtime::NativeCpuBackend::with_threads(
                                std::sync::Arc::clone(&model),
                                kernel_threads,
                            ));
                        Ok(b)
                    })
                };
                (scfg, factory)
            }
            "pjrt" => {
                if pipeline_stages > 1 {
                    bail!("--pipeline-stages is native-only (the PJRT artifact is a single compiled graph)");
                }
                if a.get("stage-hosts").is_some() {
                    bail!("--stage-hosts is native-only (the PJRT artifact is a single compiled graph)");
                }
                if values != hinm::spmm::ValueFormat::F32 {
                    bail!("--values bf16 is native-only (the PJRT artifact fixes its own value types)");
                }
                let reg = hinm::runtime::open_default_registry()?;
                let spec = reg.artifact("ffn_serve")?.clone();
                let d = spec.meta["d"] as usize;
                let d_ff = spec.meta["d_ff"] as usize;
                let batch = spec.meta["batch"] as usize;
                let cfg = HinmConfig::with_24(spec.meta["v"] as usize, spec.meta["sv"]);
                println!(
                    "pjrt backend: ffn_serve d={d} d_ff={d_ff} | V={} total sparsity {:.1}% | batch={batch} (artifact) | {replicas} replicas",
                    cfg.v,
                    cfg.total_sparsity() * 100.0
                );
                let w1 = reg.load_data("ffn_w1_dense")?;
                let w2 = reg.load_data("ffn_w2_dense")?;
                let w1 = hinm::tensor::Matrix::from_vec(d_ff, d, w1.as_f32()?.to_vec());
                let w2 = hinm::tensor::Matrix::from_vec(d, d_ff, w2.as_f32()?.to_vec());
                let p1 = hinm::sparsity::prune_oneshot(&w1, &w1.abs(), &cfg).packed;
                let p2 = hinm::sparsity::prune_oneshot(&w2, &w2.abs(), &cfg).packed;
                let mut fixed = hinm::coordinator::serve::packed_host_tensors(&p1);
                fixed.extend(hinm::coordinator::serve::packed_host_tensors(&p2));
                let scfg = hinm::coordinator::ServeConfig::new(batch, max_wait)
                    .with_replicas(replicas)
                    .with_queue_depth(queue_depth);
                let factory: hinm::coordinator::BackendFactory =
                    std::sync::Arc::new(move |_replica| {
                        let b: Box<dyn hinm::runtime::SpmmBackend> = Box::new(
                            hinm::runtime::PjrtBackend::new(&spec, &fixed, d, d, batch)?,
                        );
                        Ok(b)
                    });
                (scfg, factory)
            }
            other => bail!("unknown --backend {other:?} (expected native|pjrt)"),
        };

    let factory = match &cache_stats {
        Some(cs) => {
            println!("batch cache: {cache_capacity} entries per replica");
            hinm::coordinator::cached_factory(
                base_factory,
                cache_capacity,
                std::sync::Arc::clone(cs),
            )
        }
        None => base_factory,
    };
    let server = hinm::coordinator::BatchServer::start(factory, scfg)?;

    if let Some(addr) = a.get("http") {
        // Native kernels carry a dispatch identity worth exposing on
        // /v1/metrics; the PJRT path runs whatever the artifact compiled.
        let kernel_info = (backend == "native")
            .then(|| hinm::spmm::KernelInfo::current(values));
        let front = hinm::net::HttpFront::start_with_links(
            addr,
            server.handle.clone(),
            cache_stats.clone(),
            kernel_info,
            stage_links.clone(),
            a.usize_or("http-workers", 8),
        )?;
        println!("HTTP front listening on http://{}", front.local_addr());
        println!("  POST /v1/infer | GET /v1/metrics | GET /healthz  (Ctrl-C to stop)");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    let handle = server.handle.clone();
    let d_in = handle.d_in;
    let per_client = (n_requests / n_clients).max(1);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let h = handle.clone();
            s.spawn(move || {
                for i in 0..per_client {
                    let x: Vec<f32> = (0..d_in)
                        .map(|j| ((c * 131 + i * 17 + j) % 23) as f32 * 0.04 - 0.4)
                        .collect();
                    let y = h.infer(x).expect("inference failed");
                    assert_eq!(y.len(), h.d_out);
                }
            });
        }
    });
    let wall = t0.elapsed();
    let served = per_client * n_clients;
    println!(
        "served {served} requests from {n_clients} clients in {:.1} ms → {:.0} req/s",
        wall.as_secs_f64() * 1e3,
        served as f64 / wall.as_secs_f64()
    );
    println!("{}", server.metrics.summary());
    if let Some(cs) = &cache_stats {
        println!(
            "cache: {} hits / {} misses ({:.0}% hit rate)",
            cs.hits(),
            cs.misses(),
            cs.hit_rate() * 100.0
        );
    }
    server.stop();
    if let Some(ps) = pipeline {
        // Stage workers stop only after the engine above them: in-flight
        // batches get real answers.
        ps.stop();
    }
    Ok(())
}

/// Build the native model that `serve` and `stage` must agree on: a
/// serving-catalog entry when `--model NAME` is given, otherwise the
/// synthetic FFN/deep chain from the `--d/--d-ff/--blocks/...` flags.
/// Cross-host bit-identity rests on both processes calling this with the
/// same flags and seed, so no weights ever cross the wire (DESIGN.md §20).
fn native_model(a: &hinm::util::cli::Args) -> Result<hinm::models::HinmModel> {
    let seed = a.u64_or("seed", 7);
    if let Some(name) = a.get("model") {
        let catalog = hinm::models::serving_models(seed)?;
        for (n, m) in catalog.into_iter() {
            if n == name {
                return Ok(m);
            }
        }
        let names: Vec<&str> = hinm::models::serving_models(seed)?.iter().map(|(n, _)| *n).collect();
        bail!("unknown --model {name:?} (catalog: {})", names.join(", "));
    }
    let d = a.usize_or("d", 256);
    let d_ff = a.usize_or("d-ff", 512);
    let blocks = a.usize_or("blocks", 1).max(1);
    let cfg = HinmConfig::for_total_sparsity(
        a.usize_or("v", 32),
        a.usize_or("sparsity", 75) as f64 / 100.0,
    );
    if blocks == 1 {
        hinm::models::HinmModel::synthetic_ffn(d, d_ff, &cfg, hinm::models::Activation::Relu, seed)
    } else {
        hinm::models::HinmModel::synthetic_deep(
            d,
            d_ff,
            blocks,
            &cfg,
            hinm::models::Activation::Relu,
            seed,
        )
    }
}

fn cmd_stage(args: Vec<String>) -> Result<()> {
    let cli = Cli::new("hinm stage", "serve one pipeline stage of a HiNM chain over TCP")
        .opt("stage", None, "K/S — serve stage K of an S-way split, 1-based (required)")
        .opt("listen", Some("127.0.0.1:0"), "TCP listen address for activation frames")
        .opt(
            "kernel-threads",
            Some("1"),
            "kernel worker lanes (0 = all cores); bit-identical output",
        )
        .opt("model", None, "serving-catalog model name (must match the serve head)")
        .opt("d", Some("256"), "synthetic model: width")
        .opt("d-ff", Some("512"), "synthetic model: hidden width")
        .opt("blocks", Some("1"), "synthetic model: FFN blocks (2·blocks layers)")
        .opt("sparsity", Some("75"), "synthetic model: total sparsity %")
        .opt("v", Some("32"), "synthetic model: vector size V")
        .opt("seed", Some("7"), "synthetic-weight seed (must match the serve head)")
        .opt("values", Some("f32"), "packed kernel value format (f32|bf16; must match the head)");
    let a = cli.parse_tail(args);

    let spec = a.get("stage").context("--stage K/S is required (e.g. --stage 2/3)")?;
    let (k, s) = spec
        .split_once('/')
        .with_context(|| format!("--stage wants K/S (e.g. 2/3), got {spec:?}"))?;
    let stage: usize = k.trim().parse().with_context(|| format!("bad stage index {k:?}"))?;
    let stages: usize = s.trim().parse().with_context(|| format!("bad stage count {s:?}"))?;
    let values = {
        let s = a.get_or("values", "f32");
        hinm::spmm::ValueFormat::parse(&s)
            .with_context(|| format!("bad --values {s:?} (expected f32|bf16)"))?
    };
    let kernel_threads = a.usize_or("kernel-threads", 1);

    // Same construction path as the serve head; `stage_slice` then picks
    // this host's contiguous sub-chain out of the deterministic split.
    let model = native_model(&a)?.with_value_format(values);
    let sub = model.stage_slice(stage, stages)?;
    let (d_in, d_out, layers) = (sub.d_in(), sub.d_out(), sub.n_layers());
    let host = hinm::coordinator::StageHost::start(&a.get_or("listen", "127.0.0.1:0"), sub, kernel_threads)?;
    println!("kernel: {}", hinm::spmm::KernelInfo::current(values));
    // Tests and operators parse this line for the bound (possibly
    // ephemeral) port; keep its shape stable.
    println!(
        "stage {stage}/{stages} listening on {} | {d_in}→{d_out} ({layers} layers) (Ctrl-C to stop)",
        host.local_addr()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_route(args: Vec<String>) -> Result<()> {
    use std::net::ToSocketAddrs;

    let cli = Cli::new("hinm route", "fault-tolerant router over `hinm serve --http` hosts")
        .opt("backends", None, "comma-separated downstream HOST:PORT list (required)")
        .opt("http", Some("127.0.0.1:8080"), "router listen address")
        .opt("http-workers", Some("8"), "HTTP connection-handler threads")
        .opt("probe-interval-ms", Some("1000"), "health-probe period per backend, ms")
        .opt("probe-timeout-ms", Some("500"), "health-probe connect/read timeout, ms")
        .opt("fail-threshold", Some("3"), "consecutive failures that trip a backend Down")
        .opt("per-try-timeout-ms", Some("2000"), "read timeout per downstream attempt, ms")
        .opt("connect-timeout-ms", Some("500"), "connect timeout per downstream attempt, ms")
        .opt("max-attempts", Some("3"), "attempt budget per request (first try + hedges + retries)")
        .opt("hedge-floor-ms", Some("5"), "lower clamp on the p95 hedge delay, ms")
        .opt("hedge-ceil-ms", Some("500"), "upper clamp on the p95 hedge delay, ms")
        .opt("retry-backoff-ms", Some("10"), "base retry backoff, ms (doubles per retry, seeded jitter)")
        .opt("backoff-base-ms", Some("500"), "base reprobe cooldown after a breaker trip, ms")
        .opt("backoff-max-ms", Some("10000"), "reprobe cooldown cap, ms")
        .opt("max-inflight", Some("256"), "admission cap before answering 503 + Retry-After")
        .opt("seed", Some("7"), "seed for backoff jitter + consistent-hash tiebreaks");
    let a = cli.parse_tail(args);

    let spec = a
        .get("backends")
        .context("--backends is required (comma-separated HOST:PORT list)")?;
    let mut backends = Vec::new();
    for name in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let addr = name
            .to_socket_addrs()
            .with_context(|| format!("resolving backend {name:?}"))?
            .next()
            .with_context(|| format!("backend {name:?} resolved to no address"))?;
        backends.push((name.to_string(), addr));
    }
    if backends.is_empty() {
        bail!("--backends selected nothing");
    }

    let dflt = hinm::coordinator::RouterConfig::default();
    let cfg = hinm::coordinator::RouterConfig {
        probe_interval_ms: a.u64_or("probe-interval-ms", dflt.probe_interval_ms),
        probe_timeout_ms: a.u64_or("probe-timeout-ms", dflt.probe_timeout_ms),
        fail_threshold: a.u64_or("fail-threshold", dflt.fail_threshold as u64) as u32,
        per_try_timeout_ms: a.u64_or("per-try-timeout-ms", dflt.per_try_timeout_ms),
        connect_timeout_ms: a.u64_or("connect-timeout-ms", dflt.connect_timeout_ms),
        max_attempts: a.u64_or("max-attempts", dflt.max_attempts as u64) as u32,
        hedge_floor_ms: a.u64_or("hedge-floor-ms", dflt.hedge_floor_ms),
        hedge_ceil_ms: a.u64_or("hedge-ceil-ms", dflt.hedge_ceil_ms),
        retry_backoff_ms: a.u64_or("retry-backoff-ms", dflt.retry_backoff_ms),
        backoff_base_ms: a.u64_or("backoff-base-ms", dflt.backoff_base_ms),
        backoff_max_ms: a.u64_or("backoff-max-ms", dflt.backoff_max_ms),
        max_inflight: a.usize_or("max-inflight", dflt.max_inflight),
        drain_ms: dflt.drain_ms,
        seed: a.u64_or("seed", 7),
    };

    println!(
        "router over {} backend(s): {}",
        backends.len(),
        backends.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().join(", ")
    );
    println!(
        "policy: fail-threshold {} | per-try {} ms | {} attempts | hedge p95 clamp [{}, {}] ms | max-inflight {}",
        cfg.fail_threshold,
        cfg.per_try_timeout_ms,
        cfg.max_attempts,
        cfg.hedge_floor_ms,
        cfg.hedge_ceil_ms,
        cfg.max_inflight
    );

    let router = hinm::coordinator::Router::start(backends, cfg)?;
    let front = hinm::net::RouterFront::start(
        &a.get_or("http", "127.0.0.1:8080"),
        router,
        a.usize_or("http-workers", 8),
    )?;
    println!("router listening on http://{}", front.local_addr());
    println!("  POST /v1/infer | GET /v1/models | GET /v1/metrics | GET /healthz  (Ctrl-C to stop)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `hinm serve --model-dir DIR`: scan `DIR` into a
/// [`ModelRegistry`](hinm::runtime::ModelRegistry), start one batch engine
/// per model, and route requests by name (DESIGN.md §18). Value formats
/// come from each artifact's manifest, not `--values`.
fn serve_model_dir(a: &hinm::util::cli::Args, dir: &str) -> Result<()> {
    use std::sync::Arc;

    let replicas = a.usize_or("replicas", 2).max(1);
    let max_wait = std::time::Duration::from_micros(a.u64_or("max-wait-us", 200));
    let queue_depth = a.usize_or("queue-depth", 0);
    let kernel_threads = a.usize_or("kernel-threads", 1);
    let cache_capacity = a.usize_or("cache-capacity", 0);

    let registry = Arc::new(hinm::runtime::ModelRegistry::open(dir)?);
    let scfg = hinm::coordinator::ServeConfig::new(a.usize_or("batch", 8), max_wait)
        .with_replicas(replicas)
        .with_queue_depth(queue_depth);

    let names = registry.names();
    let mut services = std::collections::BTreeMap::new();
    let mut servers = Vec::new();
    for name in &names {
        let slot = registry
            .slot(name)
            .with_context(|| format!("registry lost slot {name:?}"))?;
        let stats =
            if cache_capacity > 0 { Some(hinm::runtime::CacheStats::new_shared()) } else { None };
        let server = hinm::coordinator::BatchServer::start_slot(
            slot,
            scfg.clone(),
            kernel_threads,
            cache_capacity,
            stats.clone(),
        )?;
        println!(
            "model {name:<16} v{} {}→{} | {replicas} replicas × {kernel_threads} kernel threads",
            slot.version(),
            slot.d_in(),
            slot.d_out()
        );
        services.insert(
            name.clone(),
            hinm::net::ModelService { handle: server.handle.clone(), cache: stats },
        );
        servers.push((name.clone(), server));
    }

    let default_model = match a.get("default-model") {
        Some(d) if services.contains_key(d) => d.to_string(),
        Some(d) => bail!(
            "--default-model {d:?} is not in {dir:?} (found: {})",
            names.join(", ")
        ),
        None => names
            .first()
            .cloned()
            .with_context(|| format!("no models in {dir:?}"))?,
    };
    println!("default model: {default_model} (requests without a \"model\" field)");

    if let Some(addr) = a.get("http") {
        let counters = hinm::coordinator::ModelCounters::new_shared();
        let reload: hinm::net::ReloadFn = {
            let reg = Arc::clone(&registry);
            Arc::new(move || Ok(reg.reload().to_json()))
        };
        let router = hinm::net::MultiRouter {
            services,
            default_model,
            counters,
            // Artifacts pick their own value format, so no single kernel
            // label describes every engine behind this front.
            kernel: None,
            reload,
        };
        let front = hinm::net::HttpFront::start_multi(addr, router, a.usize_or("http-workers", 8))?;
        println!("HTTP front listening on http://{}", front.local_addr());
        println!(
            "  POST /v1/infer | GET /v1/models | GET /v1/metrics[?model=NAME] | POST /v1/admin/reload | GET /healthz  (Ctrl-C to stop)"
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }

    // Closed-loop demo against the default model, same shape as the
    // single-model path above.
    let n_requests = a.usize_or("requests", 256);
    let n_clients = a.usize_or("clients", 8).max(1);
    let handle = services
        .get(&default_model)
        .with_context(|| format!("registry lost default model {default_model:?}"))?
        .handle
        .clone();
    let d_in = handle.d_in;
    let per_client = (n_requests / n_clients).max(1);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..n_clients {
            let h = handle.clone();
            s.spawn(move || {
                for i in 0..per_client {
                    let x: Vec<f32> = (0..d_in)
                        .map(|j| ((c * 131 + i * 17 + j) % 23) as f32 * 0.04 - 0.4)
                        .collect();
                    let y = h.infer(x).expect("inference failed");
                    assert_eq!(y.len(), h.d_out);
                }
            });
        }
    });
    let wall = t0.elapsed();
    let served = per_client * n_clients;
    println!(
        "served {served} requests from {n_clients} clients in {:.1} ms → {:.0} req/s",
        wall.as_secs_f64() * 1e3,
        served as f64 / wall.as_secs_f64()
    );
    for (name, server) in servers {
        println!("[{name}] {}", server.metrics.summary());
        server.stop();
    }
    Ok(())
}

fn cmd_train_demo(args: Vec<String>) -> Result<()> {
    let cli = Cli::new("hinm train-demo", "LM training through the AOT train step")
        .opt("steps", Some("50"), "SGD steps")
        .opt("lr", Some("0.5"), "learning rate");
    let a = cli.parse_tail(args);
    let steps = a.usize_or("steps", 50);
    let lr = a.f64_or("lr", 0.5) as f32;

    let reg = hinm::runtime::open_default_registry()?;
    let mut trainer = LmTrainer::new(&reg)?;
    let mut corpus = Corpus::new(trainer.vocab, 0.05, 99);
    let (b, s) = (trainer.batch, trainer.seq);
    let (t0s, g0s) = corpus.batch(b, s);
    let initial = trainer.eval_loss(&t0s, &g0s)?;
    println!("initial loss {initial:.4} (uniform = {:.4})", (trainer.vocab as f64).ln());
    let start = std::time::Instant::now();
    for step in 0..steps {
        let (toks, tgts) = corpus.batch(b, s);
        let loss = trainer.step(&toks, &tgts, lr)?;
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    println!(
        "{} steps in {:.1}s ({:.1} steps/s)",
        steps,
        start.elapsed().as_secs_f64(),
        steps as f64 / start.elapsed().as_secs_f64()
    );
    Ok(())
}
