//! Runtime layer: the swappable SpMM serving backends, the native AOT
//! serving artifacts + hot-swap model registry (DESIGN.md §18), and the
//! Rust↔XLA bridge that loads the AOT artifacts emitted by
//! `python/compile/aot.py` and executes them on the request path with
//! Python out of the loop.

pub mod artifact;
pub mod backend;
pub mod executor;
pub mod registry;

pub use artifact::{
    load_artifact, save_artifact, ArtifactError, ArtifactManifest, LoadedArtifact, Provenance,
    ARTIFACT_SCHEMA_VERSION,
};
pub use backend::{
    stage_backoff_ms, CacheStats, CachedBackend, NativeCpuBackend, PipelinedBackend, PjrtBackend,
    RemotePipelinedBackend, SpmmBackend, StageLinkConfig,
};
pub use executor::{client, Executor};
pub use registry::{ModelRegistry, ModelSlot, Registry, ReloadReport};

use anyhow::Result;
use std::path::PathBuf;

/// Default artifact directory: `$HINM_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("HINM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Open the default registry (errors point the user at `make artifacts`).
pub fn open_default_registry() -> Result<Registry> {
    Registry::open(default_artifact_dir())
}
