//! Runtime layer: the swappable SpMM serving backends and the Rust↔XLA
//! bridge that loads the AOT artifacts emitted by `python/compile/aot.py`
//! and executes them on the request path with Python out of the loop.

pub mod backend;
pub mod executor;
pub mod registry;

pub use backend::{
    CacheStats, CachedBackend, NativeCpuBackend, PipelinedBackend, PjrtBackend, SpmmBackend,
};
pub use executor::{client, Executor};
pub use registry::Registry;

use anyhow::Result;
use std::path::PathBuf;

/// Default artifact directory: `$HINM_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("HINM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Open the default registry (errors point the user at `make artifacts`).
pub fn open_default_registry() -> Result<Registry> {
    Registry::open(default_artifact_dir())
}
