//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and hands out typed artifact/data descriptors.

use crate::util::json::{parse, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Dtype of an artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float (`<f4`).
    F32,
    /// 32-bit signed int (`<i4`).
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// One positional input of an artifact.
#[derive(Clone, Debug)]
pub struct InputSpec {
    /// Input name from the manifest.
    pub name: String,
    /// Element dtype.
    pub dtype: Dtype,
    /// Dimensions, C-order.
    pub shape: Vec<usize>,
}

impl InputSpec {
    /// Total element count of this input.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Artifact name (manifest key).
    pub name: String,
    /// Path to the HLO text file.
    pub file: PathBuf,
    /// Positional input specs, in call order.
    pub inputs: Vec<InputSpec>,
    /// Number of outputs the artifact returns.
    pub n_outputs: usize,
    /// Free-form metadata (shapes, hyperparams) recorded at lowering time.
    pub meta: BTreeMap<String, f64>,
}

/// One `.npy` data dump (initial params, demo packed tensors).
#[derive(Clone, Debug)]
pub struct DataSpec {
    /// Dump name (manifest key).
    pub name: String,
    /// Path to the `.npy` file.
    pub file: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Registry {
    /// Artifact root directory.
    pub root: PathBuf,
    /// Artifacts by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Data dumps by name.
    pub data: BTreeMap<String, DataSpec>,
    /// Ordered LM parameter / mask names (for the trainer).
    pub lm_param_names: Vec<String>,
    /// Ordered LM mask names (for the trainer).
    pub lm_mask_names: Vec<String>,
}

impl Registry {
    /// Load `<root>/manifest.json`.
    pub fn open<P: AsRef<Path>>(root: P) -> Result<Registry> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Self::from_json(&root, &text)
    }

    /// Parse a manifest document rooted at `root`.
    pub fn from_json(root: &Path, text: &str) -> Result<Registry> {
        let doc = parse(text).map_err(|e| anyhow::anyhow!("manifest parse error: {e}"))?;
        let mut artifacts = BTreeMap::new();
        for a in doc.get("artifacts").as_arr().context("artifacts missing")? {
            let name = a.get("name").as_str().context("artifact name")?.to_string();
            let file = root.join(a.get("file").as_str().context("artifact file")?);
            let mut inputs = Vec::new();
            for spec in a.get("inputs").as_arr().context("inputs")? {
                inputs.push(InputSpec {
                    name: spec.get("name").as_str().unwrap_or("?").to_string(),
                    dtype: Dtype::parse(spec.get("dtype").as_str().context("dtype")?)?,
                    shape: spec
                        .get("shape")
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                });
            }
            let mut meta = BTreeMap::new();
            if let Some(obj) = a.get("meta").as_obj() {
                for (k, v) in obj {
                    if let Some(x) = v.as_f64() {
                        meta.insert(k.clone(), x);
                    }
                }
            }
            let n_outputs = a.get("n_outputs").as_usize().context("n_outputs")?;
            artifacts.insert(name.clone(), ArtifactSpec { name, file, inputs, n_outputs, meta });
        }
        let mut data = BTreeMap::new();
        for d in doc.get("data").as_arr().unwrap_or(&[]) {
            let name = d.get("name").as_str().context("data name")?.to_string();
            let file = root.join(d.get("file").as_str().context("data file")?);
            data.insert(name.clone(), DataSpec { name, file });
        }
        let str_list = |j: &Json| -> Vec<String> {
            j.as_arr()
                .map(|a| a.iter().filter_map(|s| s.as_str().map(str::to_string)).collect())
                .unwrap_or_default()
        };
        let meta = doc.get("meta");
        Ok(Registry {
            root: root.to_path_buf(),
            lm_param_names: str_list(meta.get("lm_param_names")),
            lm_mask_names: str_list(meta.get("lm_mask_names")),
            artifacts,
            data,
        })
    }

    /// Look up an artifact by name, with a helpful error.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    /// Load a `.npy` data dump.
    pub fn load_data(&self, name: &str) -> Result<crate::tensor::npy::NpyArray> {
        let spec = self
            .data
            .get(name)
            .with_context(|| format!("data {name:?} not in manifest"))?;
        crate::tensor::npy::load(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "spmm", "file": "spmm.hlo.txt", "n_outputs": 1,
         "meta": {"v": 16, "sv": 0.5},
         "inputs": [
           {"name": "vals", "dtype": "float32", "shape": [4, 16, 32]},
           {"name": "vec_idx", "dtype": "int32", "shape": [4, 64]}
         ]}
      ],
      "data": [{"name": "w", "file": "params/w.npy", "dtype": "float32", "shape": [4, 4]}],
      "meta": {"lm_param_names": ["tok_emb", "l0.wq"], "lm_mask_names": ["l0.wq"]}
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let r = Registry::from_json(Path::new("/tmp/art"), SAMPLE).unwrap();
        let a = r.artifact("spmm").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.inputs[1].shape, vec![4, 64]);
        assert_eq!(a.meta["v"], 16.0);
        assert_eq!(a.n_outputs, 1);
        assert_eq!(r.lm_param_names, vec!["tok_emb", "l0.wq"]);
        assert!(r.data.contains_key("w"));
    }

    #[test]
    fn missing_artifact_is_error() {
        let r = Registry::from_json(Path::new("/tmp/art"), SAMPLE).unwrap();
        assert!(r.artifact("nope").is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("float32", "float64");
        assert!(Registry::from_json(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Integration-lite: parse the checked-in artifacts when present.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if root.join("manifest.json").exists() {
            let r = Registry::open(&root).unwrap();
            assert!(r.artifacts.contains_key("spmm_demo"));
            assert!(r.artifacts.contains_key("lm_train_step"));
            assert!(!r.lm_param_names.is_empty());
        }
    }
}
