//! Artifact registries.
//!
//! Two distinct registries live here:
//!
//! * [`Registry`] — the PJRT/XLA registry: parses `artifacts/manifest.json`
//!   (written by `python/compile/aot.py`) and hands out typed artifact/data
//!   descriptors for the AOT-lowered HLO path.
//! * [`ModelRegistry`] — the native serving registry (DESIGN.md §18): scans
//!   a `--model-dir` of versioned [`crate::runtime::artifact`] manifests,
//!   compiles each best-versioned model at load, and swaps new versions in
//!   under live traffic.
//!
//! Hot swap uses epoch semantics: each [`ModelSlot`] holds the current
//! `(Arc<HinmModel>, generation)` behind one mutex, and every replica's
//! backend ([`ModelSlot::backend_factory`]) re-checks the generation at
//! batch granularity — an in-flight batch finishes on the `Arc` it already
//! cloned (old plans stay alive until the last batch drops them), the next
//! batch rebuilds on the new model. The rebuild also replaces the replica's
//! `CachedBackend` with an empty one, so a swap can never serve a stale
//! cached activation batch; cumulative hit/miss counters survive in the
//! shared [`CacheStats`]. [`ModelRegistry::reload`] is all-or-nothing *per
//! model*: a corrupt or shape-changed artifact is reported and the old
//! version keeps serving.

use crate::coordinator::serve::BackendFactory;
use crate::models::HinmModel;
use crate::runtime::artifact::{load_artifact, ArtifactManifest};
use crate::runtime::backend::{CacheStats, CachedBackend, NativeCpuBackend, SpmmBackend};
use crate::util::json::{parse, Json};
use crate::util::sync::lock_unpoisoned;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Dtype of an artifact input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float (`<f4`).
    F32,
    /// 32-bit signed int (`<i4`).
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// One positional input of an artifact.
#[derive(Clone, Debug)]
pub struct InputSpec {
    /// Input name from the manifest.
    pub name: String,
    /// Element dtype.
    pub dtype: Dtype,
    /// Dimensions, C-order.
    pub shape: Vec<usize>,
}

impl InputSpec {
    /// Total element count of this input.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Artifact name (manifest key).
    pub name: String,
    /// Path to the HLO text file.
    pub file: PathBuf,
    /// Positional input specs, in call order.
    pub inputs: Vec<InputSpec>,
    /// Number of outputs the artifact returns.
    pub n_outputs: usize,
    /// Free-form metadata (shapes, hyperparams) recorded at lowering time.
    pub meta: BTreeMap<String, f64>,
}

/// One `.npy` data dump (initial params, demo packed tensors).
#[derive(Clone, Debug)]
pub struct DataSpec {
    /// Dump name (manifest key).
    pub name: String,
    /// Path to the `.npy` file.
    pub file: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Registry {
    /// Artifact root directory.
    pub root: PathBuf,
    /// Artifacts by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Data dumps by name.
    pub data: BTreeMap<String, DataSpec>,
    /// Ordered LM parameter / mask names (for the trainer).
    pub lm_param_names: Vec<String>,
    /// Ordered LM mask names (for the trainer).
    pub lm_mask_names: Vec<String>,
}

impl Registry {
    /// Load `<root>/manifest.json`.
    pub fn open<P: AsRef<Path>>(root: P) -> Result<Registry> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Self::from_json(&root, &text)
    }

    /// Parse a manifest document rooted at `root`.
    pub fn from_json(root: &Path, text: &str) -> Result<Registry> {
        let doc = parse(text).map_err(|e| anyhow::anyhow!("manifest parse error: {e}"))?;
        let mut artifacts = BTreeMap::new();
        for a in doc.get("artifacts").as_arr().context("artifacts missing")? {
            let name = a.get("name").as_str().context("artifact name")?.to_string();
            let file = root.join(a.get("file").as_str().context("artifact file")?);
            let mut inputs = Vec::new();
            for spec in a.get("inputs").as_arr().context("inputs")? {
                inputs.push(InputSpec {
                    name: spec.get("name").as_str().unwrap_or("?").to_string(),
                    dtype: Dtype::parse(spec.get("dtype").as_str().context("dtype")?)?,
                    shape: spec
                        .get("shape")
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                });
            }
            let mut meta = BTreeMap::new();
            if let Some(obj) = a.get("meta").as_obj() {
                for (k, v) in obj {
                    if let Some(x) = v.as_f64() {
                        meta.insert(k.clone(), x);
                    }
                }
            }
            let n_outputs = a.get("n_outputs").as_usize().context("n_outputs")?;
            artifacts.insert(name.clone(), ArtifactSpec { name, file, inputs, n_outputs, meta });
        }
        let mut data = BTreeMap::new();
        for d in doc.get("data").as_arr().unwrap_or(&[]) {
            let name = d.get("name").as_str().context("data name")?.to_string();
            let file = root.join(d.get("file").as_str().context("data file")?);
            data.insert(name.clone(), DataSpec { name, file });
        }
        let str_list = |j: &Json| -> Vec<String> {
            j.as_arr()
                .map(|a| a.iter().filter_map(|s| s.as_str().map(str::to_string)).collect())
                .unwrap_or_default()
        };
        let meta = doc.get("meta");
        Ok(Registry {
            root: root.to_path_buf(),
            lm_param_names: str_list(meta.get("lm_param_names")),
            lm_mask_names: str_list(meta.get("lm_mask_names")),
            artifacts,
            data,
        })
    }

    /// Look up an artifact by name, with a helpful error.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    /// Load a `.npy` data dump.
    pub fn load_data(&self, name: &str) -> Result<crate::tensor::npy::NpyArray> {
        let spec = self
            .data
            .get(name)
            .with_context(|| format!("data {name:?} not in manifest"))?;
        crate::tensor::npy::load(&spec.file)
    }
}

/// The hot-swappable serving state of one model name: the current compiled
/// model, its artifact version, and a generation counter bumped on every
/// successful swap. Fixed `d_in`/`d_out` are pinned at first load —
/// [`ModelRegistry::reload`] rejects artifacts that would change them, so
/// admission-time shape checks stay valid across swaps.
pub struct ModelSlot {
    name: String,
    d_in: usize,
    d_out: usize,
    state: Mutex<SlotState>,
}

struct SlotState {
    model: Arc<HinmModel>,
    version: u64,
    generation: u64,
}

impl ModelSlot {
    fn new(name: String, model: Arc<HinmModel>, version: u64) -> ModelSlot {
        let (d_in, d_out) = (model.d_in(), model.d_out());
        ModelSlot { name, d_in, d_out, state: Mutex::new(SlotState { model, version, generation: 0 }) }
    }

    /// Model name (the routing key).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Input channels (fixed for the slot's lifetime).
    pub fn d_in(&self) -> usize {
        self.d_in
    }

    /// Output channels (fixed for the slot's lifetime).
    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// The current model and swap generation, read atomically (one lock).
    pub fn current(&self) -> (Arc<HinmModel>, u64) {
        let s = lock_unpoisoned(&self.state);
        (Arc::clone(&s.model), s.generation)
    }

    /// The artifact version currently serving.
    pub fn version(&self) -> u64 {
        lock_unpoisoned(&self.state).version
    }

    fn swap(&self, model: Arc<HinmModel>, version: u64) {
        let mut s = lock_unpoisoned(&self.state);
        s.model = model;
        s.version = version;
        s.generation += 1;
    }

    /// A [`BackendFactory`] whose backends follow this slot across swaps:
    /// each replica builds a [`NativeCpuBackend`] (optionally wrapped in a
    /// [`CachedBackend`] when `cache_capacity > 0`) on the current model
    /// and rebuilds it — with a **fresh, empty** cache — the first batch
    /// after the slot's generation moves. `stats`, when given, is shared
    /// across rebuilds and replicas so hit/miss counters are cumulative.
    pub fn backend_factory(
        self: &Arc<Self>,
        kernel_threads: usize,
        cache_capacity: usize,
        stats: Option<Arc<CacheStats>>,
    ) -> BackendFactory {
        let slot = Arc::clone(self);
        Arc::new(move |_replica| {
            let (model, generation) = slot.current();
            Ok(Box::new(SwapBackend {
                slot: Arc::clone(&slot),
                kernel_threads,
                cache_capacity,
                stats: stats.clone(),
                generation,
                inner: build_stack(model, kernel_threads, cache_capacity, stats.clone()),
            }) as Box<dyn SpmmBackend>)
        })
    }
}

fn build_stack(
    model: Arc<HinmModel>,
    kernel_threads: usize,
    cache_capacity: usize,
    stats: Option<Arc<CacheStats>>,
) -> Box<dyn SpmmBackend> {
    let base = Box::new(NativeCpuBackend::with_threads(model, kernel_threads));
    if cache_capacity == 0 {
        return base;
    }
    match stats {
        Some(s) => Box::new(CachedBackend::with_stats(base, cache_capacity, s)),
        None => Box::new(CachedBackend::new(base, cache_capacity)),
    }
}

/// Per-replica backend that re-resolves its [`ModelSlot`] at batch
/// granularity — the epoch half of hot swap (DESIGN.md §18).
struct SwapBackend {
    slot: Arc<ModelSlot>,
    kernel_threads: usize,
    cache_capacity: usize,
    stats: Option<Arc<CacheStats>>,
    generation: u64,
    inner: Box<dyn SpmmBackend>,
}

impl SpmmBackend for SwapBackend {
    fn name(&self) -> &'static str {
        "registry-swap"
    }

    fn d_in(&self) -> usize {
        self.slot.d_in()
    }

    fn d_out(&self) -> usize {
        self.slot.d_out()
    }

    fn run_batch(&mut self, x: &crate::tensor::Matrix) -> Result<crate::tensor::Matrix> {
        let (model, generation) = self.slot.current();
        if generation != self.generation {
            self.inner = build_stack(model, self.kernel_threads, self.cache_capacity, self.stats.clone());
            self.generation = generation;
        }
        self.inner.run_batch(x)
    }
}

/// What a [`ModelRegistry::reload`] did, per model name.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReloadReport {
    /// Models swapped to a new version: `(name, new_version)`.
    pub swapped: Vec<(String, u64)>,
    /// Models whose best on-disk version is already serving.
    pub unchanged: Vec<String>,
    /// Per-name (or per-file) failures; the old version keeps serving.
    pub errors: Vec<(String, String)>,
    /// Artifact names on disk with no serving slot — new names need a
    /// restart (slots are fixed at startup).
    pub ignored: Vec<String>,
}

impl ReloadReport {
    /// JSON rendering for `POST /v1/admin/reload` responses.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "swapped",
                Json::arr(self.swapped.iter().map(|(n, v)| {
                    Json::obj(vec![("name", Json::str(n)), ("version", Json::num(*v as f64))])
                })),
            ),
            ("unchanged", Json::arr(self.unchanged.iter().map(|n| Json::str(n)))),
            (
                "errors",
                Json::arr(self.errors.iter().map(|(n, e)| {
                    Json::obj(vec![("name", Json::str(n)), ("error", Json::str(e))])
                })),
            ),
            ("ignored", Json::arr(self.ignored.iter().map(|n| Json::str(n)))),
        ])
    }
}

/// The native serving registry: one [`ModelSlot`] per artifact name found
/// in the model directory at startup (best version wins), plus
/// [`ModelRegistry::reload`] to pick up dropped-in versions without a
/// restart. See the module docs for the swap semantics.
pub struct ModelRegistry {
    root: PathBuf,
    slots: BTreeMap<String, Arc<ModelSlot>>,
}

/// Scan `dir` for artifact manifests and return the best (highest)
/// version per name: `name → (version, manifest_path)`. Unparseable
/// manifests are collected, not fatal — reload must survive a corrupt
/// drop-in. Paths are sorted so ties resolve deterministically.
fn scan_manifests(
    dir: &Path,
) -> Result<(BTreeMap<String, (u64, PathBuf)>, Vec<(String, String)>)> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("scanning model dir {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    paths.sort();
    let mut best: BTreeMap<String, (u64, PathBuf)> = BTreeMap::new();
    let mut errors = Vec::new();
    for p in paths {
        let file = p
            .file_name()
            .map(|f| f.to_string_lossy().into_owned())
            .unwrap_or_else(|| p.display().to_string());
        let text = match std::fs::read_to_string(&p) {
            Ok(t) => t,
            Err(e) => {
                errors.push((file, format!("read failed: {e}")));
                continue;
            }
        };
        match ArtifactManifest::from_json_text(&text) {
            Ok(m) => {
                let entry = best.entry(m.name.clone()).or_insert((m.version, p.clone()));
                if m.version >= entry.0 {
                    *entry = (m.version, p);
                }
            }
            Err(e) => errors.push((file, e.to_string())),
        }
    }
    Ok((best, errors))
}

impl ModelRegistry {
    /// Scan `dir`, load and compile the best version of every artifact,
    /// and build one slot per name. Startup is strict where reload is
    /// lenient: any unreadable manifest or failing load here is fatal,
    /// because serving a silently reduced catalog is worse than failing
    /// a deploy.
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<ModelRegistry> {
        let root = dir.as_ref().to_path_buf();
        let (best, errors) = scan_manifests(&root)?;
        if let Some((file, err)) = errors.first() {
            bail!("model dir {}: bad manifest {file}: {err}", root.display());
        }
        if best.is_empty() {
            bail!("model dir {} contains no artifact manifests (run `hinm build`)", root.display());
        }
        let mut slots = BTreeMap::new();
        for (name, (version, path)) in best {
            let loaded = load_artifact(&path)
                .map_err(|e| anyhow::anyhow!("loading {}: {e}", path.display()))?;
            slots.insert(name.clone(), Arc::new(ModelSlot::new(name, Arc::new(loaded.model), version)));
        }
        Ok(ModelRegistry { root, slots })
    }

    /// The directory this registry scans.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Model names, sorted (the first is the default model).
    pub fn names(&self) -> Vec<String> {
        self.slots.keys().cloned().collect()
    }

    /// The slot serving `name`, if any.
    pub fn slot(&self, name: &str) -> Option<&Arc<ModelSlot>> {
        self.slots.get(name)
    }

    /// Rescan the directory and swap every slot whose best on-disk
    /// version differs from the serving one (a *lower* best version rolls
    /// back). Per-model failures — unreadable payload, checksum mismatch,
    /// changed `d_in`/`d_out` — land in [`ReloadReport::errors`] and leave
    /// the old version serving. Never fails the models that are fine.
    pub fn reload(&self) -> ReloadReport {
        let mut report = ReloadReport::default();
        let (best, errors) = match scan_manifests(&self.root) {
            Ok(r) => r,
            Err(e) => {
                report.errors.push(("<scan>".to_string(), e.to_string()));
                return report;
            }
        };
        report.errors = errors;
        for name in best.keys() {
            if !self.slots.contains_key(name) {
                report.ignored.push(name.clone());
            }
        }
        for (name, slot) in &self.slots {
            let Some((version, path)) = best.get(name) else {
                report.unchanged.push(name.clone());
                continue;
            };
            if *version == slot.version() {
                report.unchanged.push(name.clone());
                continue;
            }
            let loaded = match load_artifact(path) {
                Ok(l) => l,
                Err(e) => {
                    report.errors.push((name.clone(), e.to_string()));
                    continue;
                }
            };
            if loaded.model.d_in() != slot.d_in() || loaded.model.d_out() != slot.d_out() {
                report.errors.push((
                    name.clone(),
                    format!(
                        "version {version} changes shape to {}→{} (serving {}→{}); \
                         restart to change a model's dimensions",
                        loaded.model.d_in(),
                        loaded.model.d_out(),
                        slot.d_in(),
                        slot.d_out()
                    ),
                ));
                continue;
            }
            slot.swap(Arc::new(loaded.model), *version);
            report.swapped.push((name.clone(), *version));
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "spmm", "file": "spmm.hlo.txt", "n_outputs": 1,
         "meta": {"v": 16, "sv": 0.5},
         "inputs": [
           {"name": "vals", "dtype": "float32", "shape": [4, 16, 32]},
           {"name": "vec_idx", "dtype": "int32", "shape": [4, 64]}
         ]}
      ],
      "data": [{"name": "w", "file": "params/w.npy", "dtype": "float32", "shape": [4, 4]}],
      "meta": {"lm_param_names": ["tok_emb", "l0.wq"], "lm_mask_names": ["l0.wq"]}
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let r = Registry::from_json(Path::new("/tmp/art"), SAMPLE).unwrap();
        let a = r.artifact("spmm").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.inputs[1].shape, vec![4, 64]);
        assert_eq!(a.meta["v"], 16.0);
        assert_eq!(a.n_outputs, 1);
        assert_eq!(r.lm_param_names, vec!["tok_emb", "l0.wq"]);
        assert!(r.data.contains_key("w"));
    }

    #[test]
    fn missing_artifact_is_error() {
        let r = Registry::from_json(Path::new("/tmp/art"), SAMPLE).unwrap();
        assert!(r.artifact("nope").is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("float32", "float64");
        assert!(Registry::from_json(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Integration-lite: parse the checked-in artifacts when present.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if root.join("manifest.json").exists() {
            let r = Registry::open(&root).unwrap();
            assert!(r.artifacts.contains_key("spmm_demo"));
            assert!(r.artifacts.contains_key("lm_train_step"));
            assert!(!r.lm_param_names.is_empty());
        }
    }

    // ── ModelRegistry (native serving artifacts, DESIGN.md §18) ──────

    use crate::models::Activation;
    use crate::runtime::artifact::{save_artifact, Provenance};
    use crate::sparsity::HinmConfig;
    use crate::tensor::Matrix;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hinm-modelreg-{tag}-{}", std::process::id()))
    }

    fn ffn(seed: u64) -> HinmModel {
        HinmModel::synthetic_ffn(16, 32, &HinmConfig::with_24(4, 0.5), Activation::Relu, seed)
            .unwrap()
    }

    fn probe() -> Matrix {
        Matrix::from_vec(16, 2, (0..32).map(|i| (i as f32) * 0.1 - 1.6).collect())
    }

    fn bits(m: &Matrix) -> Vec<u32> {
        m.data.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn model_registry_scans_loads_and_swaps() {
        let dir = tmp("swap");
        let _ = std::fs::remove_dir_all(&dir);
        let (m1, m2) = (ffn(1), ffn(2));
        save_artifact(&dir, "a", 1, &m1, &Provenance::default()).unwrap();
        let reg = ModelRegistry::open(&dir).unwrap();
        assert_eq!(reg.names(), vec!["a".to_string()]);
        let slot = Arc::clone(reg.slot("a").unwrap());
        assert_eq!((slot.version(), slot.d_in(), slot.d_out()), (1, 16, 16));

        let factory = slot.backend_factory(1, 4, None);
        let mut be = factory(0).unwrap();
        let x = probe();
        assert_eq!(bits(&be.run_batch(&x).unwrap()), bits(&m1.forward(&x)));

        save_artifact(&dir, "a", 2, &m2, &Provenance::default()).unwrap();
        let rep = reg.reload();
        assert_eq!(rep.swapped, vec![("a".to_string(), 2)]);
        assert_eq!(slot.version(), 2);
        // The already-built backend follows the swap at its next batch —
        // and with a fresh cache (the pre-swap result for `x` is cached).
        assert_eq!(bits(&be.run_batch(&x).unwrap()), bits(&m2.forward(&x)));

        let rep = reg.reload();
        assert!(rep.swapped.is_empty());
        assert_eq!(rep.unchanged, vec!["a".to_string()]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn model_registry_reload_keeps_old_on_bad_artifact() {
        let dir = tmp("corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let m1 = ffn(3);
        save_artifact(&dir, "a", 1, &m1, &Provenance::default()).unwrap();
        let reg = ModelRegistry::open(&dir).unwrap();
        let slot = Arc::clone(reg.slot("a").unwrap());

        // v2 with a flipped payload byte: reported, not served.
        save_artifact(&dir, "a", 2, &ffn(4), &Provenance::default()).unwrap();
        let bin = dir.join("a-v2.bin");
        let mut bytes = std::fs::read(&bin).unwrap();
        bytes[7] ^= 0x20;
        std::fs::write(&bin, &bytes).unwrap();
        let rep = reg.reload();
        assert!(rep.swapped.is_empty());
        assert_eq!(rep.errors.len(), 1, "report: {rep:?}");
        assert_eq!(slot.version(), 1);

        // v3 changing d_in/d_out: rejected, old keeps serving.
        let wide =
            HinmModel::synthetic_ffn(32, 64, &HinmConfig::with_24(4, 0.5), Activation::Relu, 5)
                .unwrap();
        save_artifact(&dir, "a", 3, &wide, &Provenance::default()).unwrap();
        let rep = reg.reload();
        assert!(rep.swapped.is_empty());
        assert!(rep.errors.iter().any(|(n, e)| n == "a" && e.contains("changes shape")));
        assert_eq!(slot.version(), 1);

        let factory = slot.backend_factory(1, 0, None);
        let mut be = factory(0).unwrap();
        let x = probe();
        assert_eq!(bits(&be.run_batch(&x).unwrap()), bits(&m1.forward(&x)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn model_registry_open_requires_artifacts() {
        let dir = tmp("empty");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(ModelRegistry::open(&dir).is_err(), "missing dir must fail");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(ModelRegistry::open(&dir).is_err(), "empty dir must fail");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
