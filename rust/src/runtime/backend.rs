//! Swappable SpMM serving backends — the execution layer under the batch
//! server.
//!
//! The paper's serving claim (HiNM layers with runtime channel permutation
//! at zero extra cost) meets traffic through [`SpmmBackend`]: a backend
//! owns a fully materialized model and executes one padded activation
//! batch per call. Two implementations ship:
//!
//! * [`NativeCpuBackend`] — the planned tile-parallel CPU kernel
//!   ([`crate::spmm::SpmmEngine`] over the model's precompiled
//!   [`crate::spmm::SpmmPlan`]s, DESIGN.md §14) over a [`HinmModel`]
//!   chain, with per-backend ping-pong activation buffers and an optional
//!   per-backend kernel worker pool (`--kernel-threads`). Runs everywhere
//!   (tests, CI, benches) with no artifacts; output is bit-identical for
//!   any kernel-thread count.
//! * [`PjrtBackend`] — the AOT-lowered XLA/Pallas artifact through the
//!   PJRT [`Executor`]. PJRT handles are `!Send`, so the batch server
//!   constructs this backend *on* the worker thread via its factory.
//!
//! A third implementation is a *decorator*: [`CachedBackend`] wraps any
//! backend with an LRU memo keyed by the (hashed, then bit-exact-verified)
//! activation batch, so repeated identical batches skip the kernel
//! entirely and return a bit-identical stored result. Hit/miss counters
//! live in a shared [`CacheStats`] so multiple replicas can report into
//! one place.
//!
//! Backends are stateful (`&mut self`) precisely so weights and scratch are
//! materialized once at construction and reused across every batch — the
//! fixed packed-weight literals of the PJRT path are created once and
//! passed by reference to each `exe.run`, never deep-copied per flush.

use crate::models::chain::{ActivationBuffers, HinmModel};
use crate::runtime::executor::{lit_f32, lit_i32, lit_to_matrix, Executor};
use crate::runtime::registry::ArtifactSpec;
use crate::spmm::{KernelInfo, SpmmEngine};
use crate::tensor::Matrix;
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A serving execution engine for one fixed model.
///
/// `run_batch` consumes an activation batch `x` of shape `[d_in, w]`
/// (row-major; request `j` in column `j`) and returns `[d_out, w]`. The
/// width `w` is the backend's [`SpmmBackend::fixed_batch`] when it
/// declares one (the engine zero-pads stragglers up to it) and exactly the
/// number of live requests otherwise — so flexible backends never compute
/// padding columns. Implementations may be `!Send`; the batch server
/// builds one per worker thread through a `Send + Sync` factory.
pub trait SpmmBackend {
    /// Short backend identifier for logs/reports.
    fn name(&self) -> &'static str;
    /// Uncompressed input channels per request.
    fn d_in(&self) -> usize;
    /// Output channels per request.
    fn d_out(&self) -> usize;
    /// The batch width this backend was compiled for, if any. `None`
    /// (default) means any width is accepted and padding is wasted work.
    fn fixed_batch(&self) -> Option<usize> {
        None
    }
    /// Execute one batch.
    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix>;
}

/// Host-side tensor data, `Send`-able across threads (PJRT literals are
/// not); a worker thread converts these to literals once at startup.
#[derive(Clone, Debug)]
pub enum HostTensor {
    /// f32 data + shape.
    F32(Vec<f32>, Vec<usize>),
    /// i32 data + shape.
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    /// Convert to an XLA literal (on the consuming thread).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32(d, s) => lit_f32(d, s),
            HostTensor::I32(d, s) => lit_i32(d, s),
        }
    }
}

/// Packed HiNM weights as host tensors (vals, vec_idx, nm_idx) — the fixed
/// inputs of the `ffn_serve` artifact.
pub fn packed_host_tensors(p: &crate::sparsity::HinmPacked) -> Vec<HostTensor> {
    let t = p.tiles();
    let vpr = p.vals_per_row();
    vec![
        HostTensor::F32(p.vals.clone(), vec![t, p.cfg.v, vpr]),
        HostTensor::I32(p.vec_idx.clone(), vec![t, p.k_v]),
        HostTensor::I32(p.nm_idx.iter().map(|&o| o as i32).collect(), vec![t, p.cfg.v, vpr]),
    ]
}

/// CPU backend: the planned tile-parallel HiNM kernel over a layer chain.
///
/// The model (weights + compiled [`crate::spmm::SpmmPlan`]s) is shared
/// (`Arc`) across replicas — plans exist once in the process regardless of
/// replica count — while each backend owns its own [`SpmmEngine`] (kernel
/// worker pool + per-lane staging scratch) and ping-pong activation
/// buffers, so a forward pass of any depth allocates only its output.
pub struct NativeCpuBackend {
    model: Arc<HinmModel>,
    engine: SpmmEngine,
    bufs: ActivationBuffers,
}

impl NativeCpuBackend {
    /// Backend over a shared model, executing kernels inline on the
    /// replica thread (one lane).
    pub fn new(model: Arc<HinmModel>) -> Self {
        Self::with_threads(model, 1)
    }

    /// Backend with a private pool of `kernel_threads` kernel lanes
    /// (0 = available parallelism). Tiles are distributed over the lanes;
    /// the result is bit-identical for any lane count.
    pub fn with_threads(model: Arc<HinmModel>, kernel_threads: usize) -> Self {
        Self {
            model,
            engine: SpmmEngine::new(kernel_threads),
            bufs: ActivationBuffers::new(),
        }
    }

    /// Kernel lanes this backend runs tiles on.
    pub fn kernel_threads(&self) -> usize {
        self.engine.lanes()
    }

    /// The microkernel identity this backend's plans dispatch to (ISA
    /// tier, value format, panel budget + detected caches) — what the
    /// serve startup log and `/v1/metrics` report (DESIGN.md §16).
    pub fn kernel_info(&self) -> KernelInfo {
        KernelInfo::current(self.model.value_format())
    }
}

impl SpmmBackend for NativeCpuBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn d_in(&self) -> usize {
        self.model.d_in()
    }

    fn d_out(&self) -> usize {
        self.model.d_out()
    }

    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        ensure!(
            x.rows == self.model.d_in(),
            "batch has {} input channels, model wants {}",
            x.rows,
            self.model.d_in()
        );
        Ok(self.model.forward_planned(x, &self.engine, &mut self.bufs))
    }
}

/// PJRT backend: a compiled AOT artifact with its fixed inputs resident.
///
/// `inputs` holds the fixed packed-weight literals (created once, at
/// construction) followed by one slot that is overwritten with each batch's
/// activation literal — `Executor::run` takes `&[Literal]`, so the fixed
/// literals are reused by reference across calls instead of being
/// deep-copied per flush.
pub struct PjrtBackend {
    exe: Executor,
    inputs: Vec<xla::Literal>,
    d_in: usize,
    d_out: usize,
    batch: usize,
}

impl PjrtBackend {
    /// Compile `spec` and materialize the fixed literals once.
    pub fn new(
        spec: &ArtifactSpec,
        fixed: &[HostTensor],
        d_in: usize,
        d_out: usize,
        batch: usize,
    ) -> Result<PjrtBackend> {
        ensure!(batch > 0, "batch must be positive");
        let exe = Executor::load(spec)?;
        let mut inputs = Vec::with_capacity(fixed.len() + 1);
        for t in fixed {
            inputs.push(t.to_literal()?);
        }
        // Placeholder for the activation literal, replaced on every call.
        inputs.push(lit_f32(&vec![0.0; d_in * batch], &[d_in, batch])?);
        Ok(PjrtBackend { exe, inputs, d_in, d_out, batch })
    }

    /// The artifact's compiled batch dimension.
    pub fn batch(&self) -> usize {
        self.batch
    }
}

impl SpmmBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn fixed_batch(&self) -> Option<usize> {
        Some(self.batch)
    }

    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        ensure!(
            x.rows == self.d_in && x.cols == self.batch,
            "batch is {}×{}, artifact compiled for {}×{}",
            x.rows,
            x.cols,
            self.d_in,
            self.batch
        );
        let slot = self.inputs.len() - 1;
        self.inputs[slot] = lit_f32(&x.data, &[self.d_in, self.batch])?;
        let outs = self.exe.run(&self.inputs)?;
        lit_to_matrix(&outs[0], self.d_out, self.batch)
    }
}

// ---------------------------------------------------------------------------
// Pipelined backend
// ---------------------------------------------------------------------------

/// Backend adapter onto a running
/// [`PipelineServer`](crate::coordinator::serve::PipelineServer):
/// `run_batch` submits the activation batch to stage 0 and blocks until
/// the final stage answers, so the batch server, [`CachedBackend`], the
/// priority/deadline queue, and the HTTP front all compose unchanged over
/// pipeline-parallel execution (DESIGN.md §15).
///
/// A single replica calling `run_batch` serially keeps only one batch in
/// flight — no overlap. Give *each* engine replica its own
/// `PipelinedBackend` (they all clone one
/// [`PipelineHandle`](crate::coordinator::serve::PipelineHandle), see
/// [`PipelineServer::backend_factory`](crate::coordinator::serve::PipelineServer::backend_factory))
/// and the replicas keep several batches in flight, each executing a
/// different stage concurrently — which is where the
/// `1/max(stage_time)` steady state comes from.
///
/// Output is bit-identical to [`NativeCpuBackend`] over the unsplit model
/// for any stage count (`tests/pipeline_serve.rs`).
pub struct PipelinedBackend {
    handle: crate::coordinator::serve::PipelineHandle,
}

impl PipelinedBackend {
    /// Adapter over a (cloned) pipeline submission handle.
    pub fn new(handle: crate::coordinator::serve::PipelineHandle) -> PipelinedBackend {
        PipelinedBackend { handle }
    }
}

impl SpmmBackend for PipelinedBackend {
    fn name(&self) -> &'static str {
        "pipeline"
    }

    fn d_in(&self) -> usize {
        self.handle.d_in
    }

    fn d_out(&self) -> usize {
        self.handle.d_out
    }

    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        self.handle
            .infer_batch(x)
            .map_err(|e| anyhow::anyhow!("pipeline inference failed: {e}"))
    }
}

// ---------------------------------------------------------------------------
// Cross-host pipeline backend (DESIGN.md §20)
// ---------------------------------------------------------------------------

/// Link policy for [`RemotePipelinedBackend`]: socket deadlines and the
/// seeded reconnect backoff. All timing lives here (runtime layer), never
/// in the clock-free [`crate::net::stage_wire`] codec.
#[derive(Clone, Debug)]
pub struct StageLinkConfig {
    /// TCP connect timeout per attempt, milliseconds.
    pub connect_timeout_ms: u64,
    /// Per-try socket read/write deadline, milliseconds: a stage host
    /// that stalls past this fails the batch with
    /// [`InferError::UpstreamTimeout`](crate::coordinator::InferError)
    /// (504) instead of hanging the replica.
    pub io_timeout_ms: u64,
    /// Connect attempts per (re)establishment before giving up on the
    /// batch with a typed 502.
    pub connect_attempts: u32,
    /// Base reconnect backoff, milliseconds (doubles per attempt).
    pub backoff_base_ms: u64,
    /// Reconnect backoff cap, milliseconds.
    pub backoff_max_ms: u64,
    /// Seed for the deterministic backoff jitter ([`stage_backoff_ms`]).
    pub seed: u64,
}

impl Default for StageLinkConfig {
    fn default() -> StageLinkConfig {
        StageLinkConfig {
            connect_timeout_ms: 500,
            io_timeout_ms: 5_000,
            connect_attempts: 3,
            backoff_base_ms: 50,
            backoff_max_ms: 2_000,
            seed: 0x48_69_4E_4D, // "HiNM"
        }
    }
}

/// Backoff before reconnect attempt `attempt` (1-based) on link `link`:
/// exponential in the attempt, capped, plus deterministic jitter of at
/// most `backoff_base_ms` — a pure function of `(seed, link, epoch,
/// attempt)` so chaos tests replay the exact schedule (same discipline as
/// the router's `retry_backoff_ms`).
pub fn stage_backoff_ms(cfg: &StageLinkConfig, link: usize, epoch: u64, attempt: u32) -> u64 {
    let base = cfg.backoff_base_ms.max(1);
    let exp = base.saturating_mul(1u64 << (attempt.saturating_sub(1)).min(16));
    let stream = (link as u64) << 40 | epoch << 8 | attempt as u64;
    exp.min(cfg.backoff_max_ms) + crate::util::rng::mix_seed(cfg.seed, stream) % base
}

/// One persistent TCP link to an `hinm stage` host.
struct StageLink {
    host: String,
    conn: Option<std::net::TcpStream>,
    /// Successful establishments so far (0 = never connected); feeds the
    /// backoff jitter stream and distinguishes first connects from
    /// reconnects in the metrics.
    epoch: u64,
}

/// Execution backend that drives a chain of `hinm stage` hosts over
/// persistent TCP links (DESIGN.md §20): `run_batch` sends the activation
/// batch to host 1, feeds each host's output frame to the next, and
/// returns the final stage's output — bit-identical to
/// [`NativeCpuBackend`] on the unsplit model, because activations travel
/// as raw f32 bit patterns and each host runs the same planned kernels.
///
/// Like [`PipelinedBackend`], one instance keeps only one batch in
/// flight; give each engine replica its own instance (they share the
/// [`StageLinkMetrics`](crate::coordinator::StageLinkMetrics)) and the
/// replicas overlap batches across hosts, which restores the §15
/// `1/max(stage_time)` steady state across machines.
///
/// Failure semantics per link, using the §19 taxonomy on the I/O error:
/// a timeout fails the batch typed 504; a dead peer fails it typed 502
/// and the *next* batch re-establishes the link with seeded backoff; a
/// framing violation (bad checksum) fails typed 502, drops the link as
/// unrecoverable, and likewise re-establishes on the next batch. A typed
/// error *frame* from the host fails only that batch (500) and keeps the
/// link. Errors carry the [`InferError`](crate::coordinator::InferError)
/// in their chain so the batch server's flush maps them to the right
/// status codes; a mid-batch link death therefore fails exactly that
/// batch — never a hang, never a lost response.
pub struct RemotePipelinedBackend {
    links: Vec<StageLink>,
    d_in: usize,
    d_out: usize,
    cfg: StageLinkConfig,
    metrics: Arc<crate::coordinator::stage_host::StageLinkMetrics>,
    codec: crate::net::stage_wire::FrameCodec,
    seq: u64,
    /// Recycled hop buffers (the §15 hand-off pool, per replica): inputs
    /// consumed by a hop return here; the final output leaves with the
    /// caller, exactly like the in-process pipeline's last stage.
    spares: Vec<Matrix>,
}

/// How many spare hop buffers each replica's backend retains.
const REMOTE_RECYCLE_CAP: usize = 4;

impl RemotePipelinedBackend {
    /// Connect one persistent link per stage host (in chain order,
    /// failing fast if any host is unreachable at startup) for a model
    /// with the given end-to-end dims. `metrics` must have one slot per
    /// host ([`StageLinkMetrics::new`](crate::coordinator::StageLinkMetrics::new)).
    pub fn connect(
        hosts: &[String],
        d_in: usize,
        d_out: usize,
        cfg: StageLinkConfig,
        metrics: Arc<crate::coordinator::stage_host::StageLinkMetrics>,
    ) -> Result<RemotePipelinedBackend> {
        ensure!(!hosts.is_empty(), "need at least one stage host");
        let mut b = RemotePipelinedBackend {
            links: hosts
                .iter()
                .map(|h| StageLink { host: h.clone(), conn: None, epoch: 0 })
                .collect(),
            d_in,
            d_out,
            cfg,
            metrics,
            codec: crate::net::stage_wire::FrameCodec::new(),
            seq: 0,
            spares: Vec::new(),
        };
        for i in 0..b.links.len() {
            b.ensure_connected(i)
                .map_err(|e| anyhow::anyhow!("connecting stage host {}: {e}", hosts[i]))?;
        }
        Ok(b)
    }

    fn take_spare(&mut self) -> Matrix {
        self.spares.pop().unwrap_or_else(|| Matrix::zeros(0, 0))
    }

    fn put_spare(&mut self, m: Matrix) {
        if self.spares.len() < REMOTE_RECYCLE_CAP {
            self.spares.push(m);
        }
    }

    /// (Re-)establish link `i` if it is down, with seeded backoff between
    /// attempts. On failure the batch-level caller reports a typed 502.
    fn ensure_connected(
        &mut self,
        i: usize,
    ) -> std::result::Result<(), crate::coordinator::InferError> {
        use crate::coordinator::InferError;
        if self.links[i].conn.is_some() {
            return Ok(());
        }
        let attempts = self.cfg.connect_attempts.max(1);
        let mut last = String::new();
        for attempt in 1..=attempts {
            if attempt > 1 {
                let ms = stage_backoff_ms(&self.cfg, i, self.links[i].epoch, attempt - 1);
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
            match self.try_connect(i) {
                Ok(stream) => {
                    let link = &mut self.links[i];
                    link.conn = Some(stream);
                    if link.epoch > 0 {
                        self.metrics.record_reconnect(i);
                    }
                    link.epoch += 1;
                    return Ok(());
                }
                Err(e) => last = e.to_string(),
            }
        }
        self.metrics.record_failure(i, crate::net::route::UpstreamClass::Unreachable);
        Err(InferError::Upstream(format!(
            "stage host {} unreachable after {attempts} attempts: {last}",
            self.links[i].host
        )))
    }

    fn try_connect(&self, i: usize) -> std::io::Result<std::net::TcpStream> {
        use std::net::ToSocketAddrs;
        let host = &self.links[i].host;
        let addr = host
            .to_socket_addrs()
            .map_err(|e| {
                std::io::Error::new(std::io::ErrorKind::InvalidInput, format!("{host}: {e}"))
            })?
            .next()
            .ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    format!("{host}: no address"),
                )
            })?;
        let stream = std::net::TcpStream::connect_timeout(
            &addr,
            std::time::Duration::from_millis(self.cfg.connect_timeout_ms.max(1)),
        )?;
        stream.set_nodelay(true)?;
        let io = Some(std::time::Duration::from_millis(self.cfg.io_timeout_ms.max(1)));
        stream.set_read_timeout(io)?;
        stream.set_write_timeout(io)?;
        Ok(stream)
    }

    /// One send+receive on link `i`. On any I/O error the connection is
    /// dropped (desynchronized or dead) and the error is typed by the §19
    /// class; an error *frame* keeps the connection and fails the batch.
    fn roundtrip(
        &mut self,
        i: usize,
        seq: u64,
        x: &Matrix,
        out: &mut Matrix,
    ) -> std::result::Result<(), crate::coordinator::InferError> {
        use crate::coordinator::InferError;
        use crate::net::route::{classify_upstream, UpstreamClass};
        use crate::net::stage_wire::Frame;
        self.ensure_connected(i)?;
        let t0 = std::time::Instant::now();
        let host = self.links[i].host.clone();
        let Some(conn) = self.links[i].conn.as_mut() else {
            return Err(InferError::Upstream(format!("stage host {host} link vanished")));
        };
        let io = self
            .codec
            .write_activations(conn, seq, x)
            .and_then(|()| self.codec.read_into(conn, out));
        match io {
            Ok(Frame::Activations { seq: got }) if got == seq => {
                self.metrics.record_batch(i, t0.elapsed());
                Ok(())
            }
            Ok(Frame::Activations { seq: got }) => {
                // A reply for some other batch means the stream framing
                // drifted: unrecoverable on this connection.
                self.links[i].conn = None;
                self.metrics.record_failure(i, UpstreamClass::Protocol);
                Err(InferError::Upstream(format!(
                    "stage host {host} answered seq {got} for seq {seq} (protocol desync)"
                )))
            }
            Ok(Frame::Error { message, .. }) => {
                Err(InferError::Backend(format!("stage host {host}: {message}")))
            }
            Err(e) => {
                self.links[i].conn = None;
                let class = classify_upstream(e.kind());
                self.metrics.record_failure(i, class);
                Err(match class {
                    UpstreamClass::TimedOut => InferError::UpstreamTimeout(format!(
                        "stage host {host} exceeded the {} ms per-try deadline: {e}",
                        self.cfg.io_timeout_ms
                    )),
                    UpstreamClass::Unreachable => {
                        InferError::Upstream(format!("stage host {host} died mid-batch: {e}"))
                    }
                    UpstreamClass::Protocol => {
                        InferError::Upstream(format!("stage host {host} protocol error: {e}"))
                    }
                })
            }
        }
    }
}

impl SpmmBackend for RemotePipelinedBackend {
    fn name(&self) -> &'static str {
        "remote-pipeline"
    }

    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        let seq = self.seq;
        self.seq += 1;
        // Activations flow head → host1 → head → host2 → … ; each hop's
        // consumed input buffer is recycled for a later hop's output.
        let mut cur: Option<Matrix> = None;
        for i in 0..self.links.len() {
            let mut out = self.take_spare();
            let staged = cur.take();
            let r = self.roundtrip(i, seq, staged.as_ref().unwrap_or(x), &mut out);
            match r {
                Ok(()) => {
                    if let Some(prev) = staged {
                        self.put_spare(prev);
                    }
                    cur = Some(out);
                }
                Err(e) => {
                    self.put_spare(out);
                    if let Some(prev) = staged {
                        self.put_spare(prev);
                    }
                    // Keep the typed error in the chain so the engine's
                    // flush maps it to 502/504 instead of a blanket 500.
                    return Err(anyhow::Error::new(e)
                        .context(format!("remote pipeline batch {seq} failed")));
                }
            }
        }
        cur.ok_or_else(|| anyhow::anyhow!("remote pipeline has no links"))
    }
}

// ---------------------------------------------------------------------------
// Cached decorator
// ---------------------------------------------------------------------------

/// Hit/miss counters for one (or several) [`CachedBackend`]s.
///
/// Lock-free so the serving hot path never blocks on metrics; share one
/// instance across all replicas of an engine to get a single aggregate
/// view (see [`crate::coordinator::serve::cached_factory`]).
#[derive(Debug, Default)]
pub struct CacheStats {
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CacheStats {
    /// A fresh, shareable counter block.
    pub fn new_shared() -> Arc<CacheStats> {
        Arc::new(CacheStats::default())
    }

    /// Batches answered from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Batches that had to run on the wrapped backend.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Hit fraction in `[0, 1]` (0 when nothing was looked up yet).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// One memoized batch: the full key (for bit-exact verification against
/// hash collisions) plus the stored result and an LRU stamp.
struct CacheEntry {
    x_rows: usize,
    x_cols: usize,
    x_data: Vec<f32>,
    y: Matrix,
    last_used: u64,
}

/// FNV-1a over the batch shape and the bit patterns of its elements.
/// Bit patterns (not float values) so `-0.0`/`0.0` and NaN payloads hash
/// deterministically.
fn hash_batch(x: &Matrix) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for b in [x.rows as u64, x.cols as u64] {
        h ^= b;
        h = h.wrapping_mul(PRIME);
    }
    for v in &x.data {
        // Fold each f32 in as its raw bits.
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// LRU-memoizing decorator over any [`SpmmBackend`].
///
/// `run_batch` hashes the incoming activation batch; on a hit (hash match
/// *and* bit-exact data match — collisions can never return wrong results)
/// the stored output is cloned back without touching the wrapped backend,
/// so a cache hit is bit-identical to the miss that populated it. The map
/// holds at most `capacity` entries; inserting past capacity evicts the
/// least-recently-used entry.
///
/// Invariants (see `DESIGN.md` §13): the decorator is exactly transparent
/// — same outputs, same errors, same dimensions as the wrapped backend —
/// and never caches failed executions.
pub struct CachedBackend {
    inner: Box<dyn SpmmBackend>,
    capacity: usize,
    entries: HashMap<u64, CacheEntry>,
    tick: u64,
    stats: Arc<CacheStats>,
}

impl CachedBackend {
    /// Wrap `inner` with an LRU of `capacity` entries (min 1) and private
    /// stats.
    pub fn new(inner: Box<dyn SpmmBackend>, capacity: usize) -> CachedBackend {
        Self::with_stats(inner, capacity, CacheStats::new_shared())
    }

    /// Wrap `inner`, reporting hits/misses into a shared `stats` block.
    pub fn with_stats(
        inner: Box<dyn SpmmBackend>,
        capacity: usize,
        stats: Arc<CacheStats>,
    ) -> CachedBackend {
        CachedBackend {
            inner,
            capacity: capacity.max(1),
            entries: HashMap::new(),
            tick: 0,
            stats,
        }
    }

    /// The shared hit/miss counters.
    pub fn stats(&self) -> &Arc<CacheStats> {
        &self.stats
    }

    /// Entries currently resident (≤ capacity).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is cached yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn evict_lru(&mut self) {
        let victim =
            self.entries.iter().min_by_key(|(_, e)| e.last_used).map(|(&k, _)| k);
        if let Some(k) = victim {
            self.entries.remove(&k);
        }
    }
}

impl SpmmBackend for CachedBackend {
    fn name(&self) -> &'static str {
        "cached"
    }

    fn d_in(&self) -> usize {
        self.inner.d_in()
    }

    fn d_out(&self) -> usize {
        self.inner.d_out()
    }

    fn fixed_batch(&self) -> Option<usize> {
        self.inner.fixed_batch()
    }

    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        let key = hash_batch(x);
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            if e.x_rows == x.rows && e.x_cols == x.cols && e.x_data == x.data {
                e.last_used = self.tick;
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(e.y.clone());
            }
        }
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        let y = self.inner.run_batch(x)?;
        if self.entries.len() >= self.capacity && !self.entries.contains_key(&key) {
            self.evict_lru();
        }
        self.entries.insert(
            key,
            CacheEntry {
                x_rows: x.rows,
                x_cols: x.cols,
                x_data: x.data.clone(),
                y: y.clone(),
                last_used: self.tick,
            },
        );
        Ok(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::chain::Activation;
    use crate::sparsity::HinmConfig;
    use crate::tensor::Matrix;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn native_backend_matches_model_forward() {
        let cfg = HinmConfig::with_24(8, 0.5);
        let model = Arc::new(HinmModel::synthetic_ffn(32, 64, &cfg, Activation::Relu, 5).unwrap());
        let mut backend = NativeCpuBackend::new(Arc::clone(&model));
        assert_eq!(backend.name(), "native");
        assert_eq!((backend.d_in(), backend.d_out()), (32, 32));
        let mut rng = Xoshiro256::new(6);
        for _ in 0..3 {
            let x = Matrix::randn(32, 4, 1.0, &mut rng);
            let y = backend.run_batch(&x).unwrap();
            assert_eq!(y, model.forward(&x));
        }
    }

    #[test]
    fn native_backend_kernel_threads_do_not_change_bits() {
        let cfg = HinmConfig::with_24(8, 0.5);
        let model =
            Arc::new(HinmModel::synthetic_ffn(32, 64, &cfg, Activation::Gelu, 15).unwrap());
        let mut rng = Xoshiro256::new(16);
        let x = Matrix::randn(32, 6, 1.0, &mut rng);
        let mut single = NativeCpuBackend::new(Arc::clone(&model));
        assert_eq!(single.kernel_threads(), 1);
        let want = single.run_batch(&x).unwrap();
        for threads in [2usize, 4] {
            let mut b = NativeCpuBackend::with_threads(Arc::clone(&model), threads);
            assert_eq!(b.kernel_threads(), threads);
            let got = b.run_batch(&x).unwrap();
            assert_eq!(
                got.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{threads} kernel threads"
            );
        }
    }

    #[test]
    fn native_backend_rejects_wrong_input_channels() {
        let cfg = HinmConfig::with_24(4, 0.5);
        let model = Arc::new(HinmModel::synthetic_ffn(16, 32, &cfg, Activation::None, 7).unwrap());
        let mut backend = NativeCpuBackend::new(model);
        assert!(backend.run_batch(&Matrix::zeros(8, 4)).is_err());
    }

    #[test]
    fn host_tensor_literal_roundtrip() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.element_count(), 4);
        let t = HostTensor::I32(vec![7, -3], vec![2]);
        assert_eq!(t.to_literal().unwrap().to_vec::<i32>().unwrap(), vec![7, -3]);
    }

    /// Trivial backend (`y = x + 1`); the cache's hit/miss counters are the
    /// oracle for whether it actually ran.
    struct AddOneBackend;

    impl SpmmBackend for AddOneBackend {
        fn name(&self) -> &'static str {
            "add-one"
        }
        fn d_in(&self) -> usize {
            4
        }
        fn d_out(&self) -> usize {
            4
        }
        fn run_batch(&mut self, x: &Matrix) -> Result<Matrix> {
            let mut y = x.clone();
            for v in &mut y.data {
                *v += 1.0;
            }
            Ok(y)
        }
    }

    #[test]
    fn cached_backend_hits_are_bit_identical_and_skip_the_inner_backend() {
        let mut cb = CachedBackend::new(Box::new(AddOneBackend), 4);
        let mut rng = Xoshiro256::new(3);
        let x = Matrix::randn(4, 2, 1.0, &mut rng);
        let miss = cb.run_batch(&x).unwrap();
        let hit = cb.run_batch(&x).unwrap();
        assert_eq!(
            miss.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            hit.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "cache hit must be bit-identical to the miss that populated it"
        );
        assert_eq!(cb.stats().hits(), 1);
        assert_eq!(cb.stats().misses(), 1);
        assert_eq!(cb.len(), 1);
        // A different batch is a miss.
        let x2 = Matrix::randn(4, 2, 1.0, &mut rng);
        cb.run_batch(&x2).unwrap();
        assert_eq!(cb.stats().misses(), 2);
    }

    #[test]
    fn cached_backend_evicts_least_recently_used() {
        let mut cb = CachedBackend::new(Box::new(AddOneBackend), 2);
        let a = Matrix::from_vec(4, 1, vec![1.0, 0.0, 0.0, 0.0]);
        let b = Matrix::from_vec(4, 1, vec![2.0, 0.0, 0.0, 0.0]);
        let c = Matrix::from_vec(4, 1, vec![3.0, 0.0, 0.0, 0.0]);
        cb.run_batch(&a).unwrap(); // miss → {a}
        cb.run_batch(&b).unwrap(); // miss → {a, b}
        cb.run_batch(&a).unwrap(); // hit, refreshes a
        cb.run_batch(&c).unwrap(); // miss, evicts b (LRU) → {a, c}
        assert_eq!(cb.len(), 2);
        cb.run_batch(&a).unwrap(); // still cached
        cb.run_batch(&c).unwrap(); // still cached
        assert_eq!(cb.stats().hits(), 3);
        cb.run_batch(&b).unwrap(); // evicted earlier → miss again
        assert_eq!(cb.stats().misses(), 4);
    }

    #[test]
    fn cached_backend_is_transparent_over_the_native_backend() {
        let cfg = HinmConfig::with_24(8, 0.5);
        let model = Arc::new(HinmModel::synthetic_ffn(32, 64, &cfg, Activation::Relu, 5).unwrap());
        let mut plain = NativeCpuBackend::new(Arc::clone(&model));
        let mut cached =
            CachedBackend::new(Box::new(NativeCpuBackend::new(Arc::clone(&model))), 8);
        assert_eq!((cached.d_in(), cached.d_out()), (plain.d_in(), plain.d_out()));
        assert_eq!(cached.fixed_batch(), plain.fixed_batch());
        let mut rng = Xoshiro256::new(11);
        let x = Matrix::randn(32, 4, 1.0, &mut rng);
        let y_plain = plain.run_batch(&x).unwrap();
        assert_eq!(cached.run_batch(&x).unwrap(), y_plain, "miss path must match");
        assert_eq!(cached.run_batch(&x).unwrap(), y_plain, "hit path must match");
    }

    #[test]
    fn hash_batch_distinguishes_shape_and_bits() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        assert_ne!(hash_batch(&a), hash_batch(&b), "shape must be part of the key");
        let z1 = Matrix::from_vec(1, 1, vec![0.0]);
        let z2 = Matrix::from_vec(1, 1, vec![-0.0]);
        assert_ne!(hash_batch(&z1), hash_batch(&z2), "keying is by bit pattern");
    }

    #[test]
    fn packed_host_tensors_shapes() {
        let mut rng = Xoshiro256::new(9);
        let w = Matrix::randn(8, 16, 1.0, &mut rng);
        let cfg = HinmConfig::with_24(4, 0.5);
        let p = crate::sparsity::prune_oneshot(&w, &w.abs(), &cfg).packed;
        let ts = packed_host_tensors(&p);
        assert_eq!(ts.len(), 3);
        let lits: Vec<_> = ts.iter().map(|t| t.to_literal().unwrap()).collect();
        assert_eq!(lits[0].element_count(), p.vals.len());
        assert_eq!(lits[1].element_count(), p.vec_idx.len());
        assert_eq!(lits[2].element_count(), p.nm_idx.len());
    }
}
