//! Swappable SpMM serving backends — the execution layer under the batch
//! server.
//!
//! The paper's serving claim (HiNM layers with runtime channel permutation
//! at zero extra cost) meets traffic through [`SpmmBackend`]: a backend
//! owns a fully materialized model and executes one padded activation
//! batch per call. Two implementations ship:
//!
//! * [`NativeCpuBackend`] — the CPU HiNM kernel
//!   ([`crate::spmm::spmm_with_scratch`]) over a [`HinmModel`] chain, with
//!   a per-backend reusable [`SpmmScratch`]. Runs everywhere (tests, CI,
//!   benches) with no artifacts.
//! * [`PjrtBackend`] — the AOT-lowered XLA/Pallas artifact through the
//!   PJRT [`Executor`]. PJRT handles are `!Send`, so the batch server
//!   constructs this backend *on* the worker thread via its factory.
//!
//! Backends are stateful (`&mut self`) precisely so weights and scratch are
//! materialized once at construction and reused across every batch — the
//! fixed packed-weight literals of the PJRT path are created once and
//! passed by reference to each `exe.run`, never deep-copied per flush.

use crate::models::chain::HinmModel;
use crate::runtime::executor::{lit_f32, lit_i32, lit_to_matrix, Executor};
use crate::runtime::registry::ArtifactSpec;
use crate::spmm::SpmmScratch;
use crate::tensor::Matrix;
use anyhow::{ensure, Result};
use std::sync::Arc;

/// A serving execution engine for one fixed model.
///
/// `run_batch` consumes an activation batch `x` of shape `[d_in, w]`
/// (row-major; request `j` in column `j`) and returns `[d_out, w]`. The
/// width `w` is the backend's [`SpmmBackend::fixed_batch`] when it
/// declares one (the engine zero-pads stragglers up to it) and exactly the
/// number of live requests otherwise — so flexible backends never compute
/// padding columns. Implementations may be `!Send`; the batch server
/// builds one per worker thread through a `Send + Sync` factory.
pub trait SpmmBackend {
    fn name(&self) -> &'static str;
    /// Uncompressed input channels per request.
    fn d_in(&self) -> usize;
    /// Output channels per request.
    fn d_out(&self) -> usize;
    /// The batch width this backend was compiled for, if any. `None`
    /// (default) means any width is accepted and padding is wasted work.
    fn fixed_batch(&self) -> Option<usize> {
        None
    }
    /// Execute one batch.
    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix>;
}

/// Host-side tensor data, `Send`-able across threads (PJRT literals are
/// not); a worker thread converts these to literals once at startup.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32(d, s) => lit_f32(d, s),
            HostTensor::I32(d, s) => lit_i32(d, s),
        }
    }
}

/// Packed HiNM weights as host tensors (vals, vec_idx, nm_idx) — the fixed
/// inputs of the `ffn_serve` artifact.
pub fn packed_host_tensors(p: &crate::sparsity::HinmPacked) -> Vec<HostTensor> {
    let t = p.tiles();
    let vpr = p.vals_per_row();
    vec![
        HostTensor::F32(p.vals.clone(), vec![t, p.cfg.v, vpr]),
        HostTensor::I32(p.vec_idx.clone(), vec![t, p.k_v]),
        HostTensor::I32(p.nm_idx.iter().map(|&o| o as i32).collect(), vec![t, p.cfg.v, vpr]),
    ]
}

/// CPU backend: the packed-format HiNM kernel over a layer chain.
///
/// The model is shared (`Arc`) across replicas — weights exist once in the
/// process regardless of replica count — while each backend owns its own
/// scratch, the per-"thread-block" staging buffers of the kernel.
pub struct NativeCpuBackend {
    model: Arc<HinmModel>,
    scratch: SpmmScratch,
}

impl NativeCpuBackend {
    pub fn new(model: Arc<HinmModel>) -> Self {
        Self { model, scratch: SpmmScratch::new() }
    }
}

impl SpmmBackend for NativeCpuBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn d_in(&self) -> usize {
        self.model.d_in()
    }

    fn d_out(&self) -> usize {
        self.model.d_out()
    }

    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        ensure!(
            x.rows == self.model.d_in(),
            "batch has {} input channels, model wants {}",
            x.rows,
            self.model.d_in()
        );
        Ok(self.model.forward_with_scratch(x, &mut self.scratch))
    }
}

/// PJRT backend: a compiled AOT artifact with its fixed inputs resident.
///
/// `inputs` holds the fixed packed-weight literals (created once, at
/// construction) followed by one slot that is overwritten with each batch's
/// activation literal — `Executor::run` takes `&[Literal]`, so the fixed
/// literals are reused by reference across calls instead of being
/// deep-copied per flush.
pub struct PjrtBackend {
    exe: Executor,
    inputs: Vec<xla::Literal>,
    d_in: usize,
    d_out: usize,
    batch: usize,
}

impl PjrtBackend {
    pub fn new(
        spec: &ArtifactSpec,
        fixed: &[HostTensor],
        d_in: usize,
        d_out: usize,
        batch: usize,
    ) -> Result<PjrtBackend> {
        ensure!(batch > 0, "batch must be positive");
        let exe = Executor::load(spec)?;
        let mut inputs = Vec::with_capacity(fixed.len() + 1);
        for t in fixed {
            inputs.push(t.to_literal()?);
        }
        // Placeholder for the activation literal, replaced on every call.
        inputs.push(lit_f32(&vec![0.0; d_in * batch], &[d_in, batch])?);
        Ok(PjrtBackend { exe, inputs, d_in, d_out, batch })
    }

    /// The artifact's compiled batch dimension.
    pub fn batch(&self) -> usize {
        self.batch
    }
}

impl SpmmBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn fixed_batch(&self) -> Option<usize> {
        Some(self.batch)
    }

    fn run_batch(&mut self, x: &Matrix) -> Result<Matrix> {
        ensure!(
            x.rows == self.d_in && x.cols == self.batch,
            "batch is {}×{}, artifact compiled for {}×{}",
            x.rows,
            x.cols,
            self.d_in,
            self.batch
        );
        let slot = self.inputs.len() - 1;
        self.inputs[slot] = lit_f32(&x.data, &[self.d_in, self.batch])?;
        let outs = self.exe.run(&self.inputs)?;
        lit_to_matrix(&outs[0], self.d_out, self.batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::chain::Activation;
    use crate::sparsity::HinmConfig;
    use crate::tensor::Matrix;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn native_backend_matches_model_forward() {
        let cfg = HinmConfig::with_24(8, 0.5);
        let model = Arc::new(HinmModel::synthetic_ffn(32, 64, &cfg, Activation::Relu, 5).unwrap());
        let mut backend = NativeCpuBackend::new(Arc::clone(&model));
        assert_eq!(backend.name(), "native");
        assert_eq!((backend.d_in(), backend.d_out()), (32, 32));
        let mut rng = Xoshiro256::new(6);
        for _ in 0..3 {
            let x = Matrix::randn(32, 4, 1.0, &mut rng);
            let y = backend.run_batch(&x).unwrap();
            assert_eq!(y, model.forward(&x));
        }
    }

    #[test]
    fn native_backend_rejects_wrong_input_channels() {
        let cfg = HinmConfig::with_24(4, 0.5);
        let model = Arc::new(HinmModel::synthetic_ffn(16, 32, &cfg, Activation::None, 7).unwrap());
        let mut backend = NativeCpuBackend::new(model);
        assert!(backend.run_batch(&Matrix::zeros(8, 4)).is_err());
    }

    #[test]
    fn host_tensor_literal_roundtrip() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.element_count(), 4);
        let t = HostTensor::I32(vec![7, -3], vec![2]);
        assert_eq!(t.to_literal().unwrap().to_vec::<i32>().unwrap(), vec![7, -3]);
    }

    #[test]
    fn packed_host_tensors_shapes() {
        let mut rng = Xoshiro256::new(9);
        let w = Matrix::randn(8, 16, 1.0, &mut rng);
        let cfg = HinmConfig::with_24(4, 0.5);
        let p = crate::sparsity::prune_oneshot(&w, &w.abs(), &cfg).packed;
        let ts = packed_host_tensors(&p);
        assert_eq!(ts.len(), 3);
        let lits: Vec<_> = ts.iter().map(|t| t.to_literal().unwrap()).collect();
        assert_eq!(lits[0].element_count(), p.vals.len());
        assert_eq!(lits[1].element_count(), p.vec_idx.len());
        assert_eq!(lits[2].element_count(), p.nm_idx.len());
    }
}
