//! PJRT executor: loads an HLO-text artifact, compiles it on the CPU PJRT
//! client, and executes it with validated literals. Adapted from
//! /opt/xla-example/load_hlo — HLO *text* is the interchange format (the
//! crate's XLA rejects jax ≥ 0.5 serialized protos).

use super::registry::{ArtifactSpec, Dtype, InputSpec};
use crate::tensor::npy::{NpyArray, NpyData};
use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

thread_local! {
    static CLIENT: RefCell<Option<PjRtClient>> = const { RefCell::new(None) };
}

/// The thread's PJRT CPU client. PJRT wrapper types are `Rc`-based
/// (`!Send`), so each thread that touches XLA owns a client; executors must
/// be created and used on the same thread (the batch server and trainer are
/// structured accordingly).
pub fn client() -> Result<PjRtClient> {
    CLIENT.with(|c| {
        let mut slot = c.borrow_mut();
        if let Some(c) = slot.as_ref() {
            return Ok(c.clone());
        }
        let c = PjRtClient::cpu().context("creating PJRT CPU client")?;
        *slot = Some(c.clone());
        Ok(c)
    })
}

/// A compiled artifact ready to execute.
pub struct Executor {
    /// The artifact this executor was compiled from.
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
}

impl Executor {
    /// Load + compile an artifact.
    pub fn load(spec: &ArtifactSpec) -> Result<Executor> {
        let client = client()?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .context("artifact path not utf8")?,
        )
        .with_context(|| format!("parsing HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        Ok(Executor { spec: spec.clone(), exe })
    }

    /// Execute with positional literals; validates count and element counts
    /// against the manifest, returns the flattened output tuple.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest says {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (lit, spec) in inputs.iter().zip(&self.spec.inputs) {
            let want: usize = spec.elements();
            let got = lit.element_count();
            if got != want {
                bail!(
                    "{}: input {:?} has {} elements, expected {} (shape {:?})",
                    self.spec.name,
                    spec.name,
                    got,
                    want,
                    spec.shape
                );
            }
        }
        let result = self
            .exe
            .execute::<Literal>(inputs)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let outs = lit.to_tuple().context("decomposing output tuple")?;
        if outs.len() != self.spec.n_outputs {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                outs.len(),
                self.spec.n_outputs
            );
        }
        Ok(outs)
    }
}

// ---------------------------------------------------------------------------
// Literal <-> host-data conversions
// ---------------------------------------------------------------------------

/// f32 literal with the given dims.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("lit_f32: {} elements vs dims {:?}", data.len(), dims);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

/// i32 literal with the given dims.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("lit_i32: {} elements vs dims {:?}", data.len(), dims);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

/// f32 scalar literal.
pub fn lit_scalar(x: f32) -> Literal {
    Literal::scalar(x)
}

/// Matrix → 2-D f32 literal.
pub fn lit_matrix(m: &Matrix) -> Result<Literal> {
    lit_f32(&m.data, &[m.rows, m.cols])
}

/// Literal → host f32 vec.
pub fn lit_to_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Literal → Matrix with the given shape.
pub fn lit_to_matrix(lit: &Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let v = lit_to_f32(lit)?;
    if v.len() != rows * cols {
        bail!("literal has {} elements, wanted {rows}×{cols}", v.len());
    }
    Ok(Matrix::from_vec(rows, cols, v))
}

/// `.npy` array → literal (dtype-dispatching).
pub fn lit_from_npy(arr: &NpyArray) -> Result<Literal> {
    match &arr.data {
        NpyData::F32(v) => lit_f32(v, &arr.shape),
        NpyData::I32(v) => lit_i32(v, &arr.shape),
    }
}

/// Build a literal of zeros matching an input spec (for warmup/validation).
pub fn lit_zeros(spec: &InputSpec) -> Result<Literal> {
    match spec.dtype {
        Dtype::F32 => lit_f32(&vec![0.0; spec.elements()], &spec.shape),
        Dtype::I32 => lit_i32(&vec![0; spec.elements()], &spec.shape),
    }
}

/// Pack a [`crate::sparsity::HinmPacked`] into the kernel's three literals
/// (vals [T,V,vpr] f32, vec_idx [T,K_v] i32, nm_idx [T,V,vpr] i32).
pub fn lit_packed(p: &crate::sparsity::HinmPacked) -> Result<(Literal, Literal, Literal)> {
    let t = p.tiles();
    let vpr = p.vals_per_row();
    let vals = lit_f32(&p.vals, &[t, p.cfg.v, vpr])?;
    let vidx = lit_i32(&p.vec_idx, &[t, p.k_v])?;
    let nm: Vec<i32> = p.nm_idx.iter().map(|&o| o as i32).collect();
    let nm = lit_i32(&nm, &[t, p.cfg.v, vpr])?;
    Ok((vals, vidx, nm))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(lit_to_f32(&lit).unwrap(), data);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let data = vec![1i32, -2, 3];
        let lit = lit_i32(&data, &[3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn matrix_conversion() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let lit = lit_matrix(&m).unwrap();
        let back = lit_to_matrix(&lit, 2, 2).unwrap();
        assert_eq!(m, back);
    }
}
